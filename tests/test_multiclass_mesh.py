"""Multiclass LDA on the mesh: shard_map matches the simulation.

``distributed_mc_slda_shardmap`` (data-axis machines, model-axis CLIME
columns, one (d, K) pmean) against ``simulated_distributed_mc_slda``
(same pipeline, vmap machines).  Mesh runs happen in a subprocess with
forced host devices (see ``conftest.run_in_subprocess``).
"""

from conftest import run_in_subprocess as _run_in_subprocess


def test_mc_mesh_8dev_remainder_columns():
    """Acceptance case: 8-device (data=2, model=4) mesh, d=70 (70 % 4 != 0):
    mesh output matches the single-device simulation to 1e-5."""
    out = _run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np, math
        from repro.core import multiclass as mc
        from repro.core.dantzig import DantzigConfig
        from repro.core.distributed import distributed_mc_slda_shardmap
        from repro.stats import synthetic

        cfg = DantzigConfig(max_iters=300)
        K, m, n, d = 3, 2, 200, 70
        problem = synthetic.make_mc_problem(
            d=d, num_classes=K, n_signal=5, rho=0.6, signal=1.2)
        xs, labels = synthetic.sample_mc_machines(
            jax.random.PRNGKey(0), problem, m, n)
        lam = 0.3 * math.sqrt(math.log(d) / n) * 4
        t = 0.25 * lam
        sim_b, sim_m = mc.simulated_distributed_mc_slda(
            xs, labels, K, lam, lam, t, cfg)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        out_b, out_m = distributed_mc_slda_shardmap(
            mesh, xs.reshape(m * n, d), labels.reshape(m * n),
            K, lam, lam, t, cfg)
        assert out_b.shape == (d, K) and out_m.shape == (K, d)
        np.testing.assert_allclose(np.asarray(out_b), np.asarray(sim_b), atol=1e-5)
        np.testing.assert_allclose(np.asarray(out_m), np.asarray(sim_m), atol=1e-5)
        print("MC_MESH8_OK")
        """
    )
    assert "MC_MESH8_OK" in out


def test_mc_mesh_4dev_matches_simulation():
    """Satellite case: 4-device (data=2, model=2) mesh, K=5, to 1e-5."""
    out = _run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np, math
        from repro.core import multiclass as mc
        from repro.core.dantzig import DantzigConfig
        from repro.core.distributed import distributed_mc_slda_shardmap
        from repro.stats import synthetic

        cfg = DantzigConfig(max_iters=300)
        K, m, n, d = 5, 2, 200, 45
        problem = synthetic.make_mc_problem(
            d=d, num_classes=K, n_signal=4, rho=0.6)
        xs, labels = synthetic.sample_mc_machines(
            jax.random.PRNGKey(0), problem, m, n)
        lam = 0.3 * math.sqrt(math.log(d) / n) * 4
        t = 0.25 * lam
        sim_b, sim_m = mc.simulated_distributed_mc_slda(
            xs, labels, K, lam, lam, t, cfg)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        out_b, out_m = distributed_mc_slda_shardmap(
            mesh, xs.reshape(m * n, d), labels.reshape(m * n),
            K, lam, lam, t, cfg)
        np.testing.assert_allclose(np.asarray(out_b), np.asarray(sim_b), atol=1e-5)
        np.testing.assert_allclose(np.asarray(out_m), np.asarray(sim_m), atol=1e-5)
        print("MC_MESH4_OK")
        """,
        devices=4,
    )
    assert "MC_MESH4_OK" in out


def test_mc_mesh_fused_solver_path():
    """The padded column sharding composes with the fused Pallas solver
    for a (d, K) block (d=22 over 4 model devices: 6 cols/device, 2 pad)."""
    out = _run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np, math
        from repro.core import multiclass as mc
        from repro.core.dantzig import DantzigConfig
        from repro.core.distributed import distributed_mc_slda_shardmap
        from repro.stats import synthetic

        cfg = DantzigConfig(max_iters=250, adapt_rho=False, fused=True)
        K, m, n, d = 3, 1, 150, 22
        problem = synthetic.make_mc_problem(
            d=d, num_classes=K, n_signal=3, rho=0.6)
        xs, labels = synthetic.sample_mc_machines(
            jax.random.PRNGKey(2), problem, m, n)
        lam = 0.3 * math.sqrt(math.log(d) / n) * 4
        t = 0.25 * lam
        sim_b, _ = mc.simulated_distributed_mc_slda(
            xs, labels, K, lam, lam, t, cfg)
        mesh = jax.make_mesh((1, 4), ("data", "model"))
        out_b, _ = distributed_mc_slda_shardmap(
            mesh, xs.reshape(m * n, d), labels.reshape(m * n),
            K, lam, lam, t, cfg)
        np.testing.assert_allclose(np.asarray(out_b), np.asarray(sim_b), atol=1e-5)
        print("MC_MESH_FUSED_OK")
        """,
        devices=4,
    )
    assert "MC_MESH_FUSED_OK" in out
