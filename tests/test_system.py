"""End-to-end system tests: mesh execution, drivers, checkpointing.

The shard_map/mesh tests run in a subprocess with
``--xla_force_host_platform_device_count=8`` (see
``conftest.run_in_subprocess``) so the main process keeps its own
device count for smoke tests and benches, per the dry-run contract.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_in_subprocess as _run_in_subprocess

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_shardmap_matches_simulation():
    """Algorithm 1 on a (data=4, model=2) mesh == the vmap simulation.

    The mesh run IS the paper's schedule (one pmean round over data,
    CLIME columns sharded over model); the vmap simulation is the
    reference math.  Agreement proves the distribution is lossless.
    """
    out = _run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np, math
        from repro.core.distributed import (
            distributed_slda_shardmap, simulated_distributed_slda,
            naive_averaged_slda_shardmap, simulated_naive_averaged_slda,
        )
        from repro.core.dantzig import DantzigConfig
        from repro.stats import synthetic

        cfg = DantzigConfig(max_iters=400)
        m, n1, n2, d = 4, 100, 100, 48
        problem = synthetic.make_problem(d=d, n_signal=5)
        xs, ys = synthetic.sample_machines(jax.random.PRNGKey(0), problem, m, n1, n2)
        lam = 0.3 * math.sqrt(math.log(d) / (n1 + n2)) * 4
        t = 0.25 * lam

        sim = simulated_distributed_slda(xs, ys, lam, lam, t, cfg)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        x_flat = xs.reshape(m * n1, d)
        y_flat = ys.reshape(m * n2, d)
        mesh_out = distributed_slda_shardmap(mesh, x_flat, y_flat, lam, lam, t, cfg)
        np.testing.assert_allclose(np.asarray(mesh_out), np.asarray(sim),
                                   atol=5e-3, rtol=1e-2)

        naive_sim = simulated_naive_averaged_slda(xs, ys, lam, cfg)
        naive_mesh = naive_averaged_slda_shardmap(mesh, x_flat, y_flat, lam, cfg)
        np.testing.assert_allclose(np.asarray(naive_mesh), np.asarray(naive_sim),
                                   atol=5e-3, rtol=1e-2)
        print("SHARDMAP_OK")
        """
    )
    assert "SHARDMAP_OK" in out


def test_multipod_axes_shardmap():
    """The (pod, data, model) 3-axis variant lowers and aggregates."""
    out = _run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np, math
        from repro.core.distributed import distributed_slda_shardmap
        from repro.core.dantzig import DantzigConfig
        from repro.stats import synthetic

        cfg = DantzigConfig(max_iters=300)
        d = 32
        problem = synthetic.make_problem(d=d, n_signal=4)
        xs, ys = synthetic.sample_machines(jax.random.PRNGKey(1), problem, 4, 80, 80)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        beta = distributed_slda_shardmap(
            mesh, xs.reshape(-1, d), ys.reshape(-1, d),
            0.2, 0.2, 0.05, cfg, data_axes=("pod", "data"))
        assert beta.shape == (d,)
        assert bool(jnp.all(jnp.isfinite(beta)))
        assert int(jnp.sum(beta != 0)) > 0
        print("MULTIPOD_OK")
        """
    )
    assert "MULTIPOD_OK" in out


def test_serve_driver_smoke(tmp_path):
    """The SLDA serving CLI: smoke stream + checkpoint restore parity."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--smoke",
         "--ckpt-dir", str(tmp_path)],
        capture_output=True, text=True, timeout=480, env=env, cwd=REPO,
    )
    assert res.returncode == 0, res.stderr[-4000:]
    assert "sustained qps" in res.stdout
    assert "checkpoint restore OK" in res.stdout


def test_serve_driver_chaos_leg():
    """The chaos CLI leg asserts the degradation contract inline."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--smoke", "--chaos",
         "--corrupt-ingest", "0.3", "--diverge-refit", "0.5",
         "--drop-refresh", "0.2"],
        capture_output=True, text=True, timeout=480, env=env, cwd=REPO,
    )
    assert res.returncode == 0, res.stderr[-4000:]
    assert "fault-free twin accuracy" in res.stdout


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint

    tree = {
        "params": {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones((3,), jnp.bfloat16)},
        "step": jnp.int32(7),
    }
    save_checkpoint(str(tmp_path), 7, tree)
    save_checkpoint(str(tmp_path), 11, tree)
    assert latest_step(str(tmp_path)) == 11
    target = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    restored = restore_checkpoint(str(tmp_path), 11, target)
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_latest_step_skips_torn_write(tmp_path):
    """Kill-mid-write regression: a truncated step file (a writer that
    died before the atomic rename, or a torn copy) must be SKIPPED by
    latest_step, and the previous good step must restore."""
    from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint

    tree = {"w": jnp.arange(12.0).reshape(3, 4), "step": jnp.int32(1)}
    save_checkpoint(str(tmp_path), 1, tree)
    good = (tmp_path / "step_000000001.npz").read_bytes()
    # a torn newer step: first half of a valid archive (no central dir)
    (tmp_path / "step_000000002.npz").write_bytes(good[: len(good) // 2])
    assert latest_step(str(tmp_path)) == 1
    target = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    restored = restore_checkpoint(str(tmp_path), 1, target)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_latest_step_ignores_tmp_and_garbage(tmp_path):
    """Leftover mkstemp .tmp files and non-zip bytes under the step
    pattern never win; an all-torn dir reports no checkpoint at all."""
    from repro.checkpoint import latest_step, save_checkpoint

    assert latest_step(str(tmp_path)) is None
    (tmp_path / "step_000000009.npz").write_bytes(b"not a zip archive")
    (tmp_path / "tmpabc123.tmp").write_bytes(b"half-written scratch")
    assert latest_step(str(tmp_path)) is None
    save_checkpoint(str(tmp_path), 3, {"x": jnp.ones((2,))})
    assert latest_step(str(tmp_path)) == 3


# ---------------------------------------------------------------------------
# trace-contract lint: the full registry sweep is a system-level gate
# ---------------------------------------------------------------------------

from repro.analysis import count_eqns  # noqa: E402


def test_lint_cli_full_registry_passes():
    """`python -m repro.analysis.lint` sweeps every registered entry
    point at representative shapes (incl. the d=70 / model-axis-4
    remainder mesh) and must exit clean."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint"],
        capture_output=True, text=True, timeout=420,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src"),
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8"},
        cwd=REPO,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "all contracts hold" in proc.stdout
    assert "fused-rounds3-mesh2x4-d70-remainder" in proc.stdout
    assert "[skip]" not in proc.stdout  # every case must actually run


def test_system_trace_pin_one_uplink_per_round():
    """System-level jaxpr pin through the analysis counter: the (1, 1)
    mesh face traces exactly one (d, 1) psum for the one-shot schedule."""
    from repro.core.dantzig import DantzigConfig
    from repro.core.distributed import distributed_slda_shardmap

    d = 12
    cfg = DantzigConfig(max_iters=30, adapt_rho=False)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    x = jax.random.normal(jax.random.PRNGKey(0), (30, d))
    y = jax.random.normal(jax.random.PRNGKey(1), (30, d))
    jaxpr = jax.make_jaxpr(
        lambda x, y: distributed_slda_shardmap(
            mesh, x, y, 0.2, 0.2, 0.05, cfg))(x, y)
    assert count_eqns(jaxpr, "psum", (d, 1)) == 1
    assert count_eqns(jaxpr, "eigh") == 1
