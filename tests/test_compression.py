"""Top-k error-feedback compressed uplinks (core/compression.py, DESIGN.md §10).

Pin families:

* **Bit accounting** -- :func:`uplink_bits` is exact wire arithmetic
  (values + adaptively-sized indices + int8 scales), and the SAME
  number the ``AxisPayloadBits`` trace contract pins on the mesh
  jaxpr, so a divergence between the analytic and traced bits fails
  here, not silently in a benchmark table.
* **Codec semantics** -- set-semantics decode: selected coordinates
  land at the machine's EXACT float32 value, unselected keep the
  shared reference; the error-feedback residual is exactly zero at
  selected coordinates.  The identity codec (``k_top = d``,
  unquantized) is bit-exact, so ``compression=Compression(d)``
  reproduces the dense rounds -- and the PR 2 golden -- to the bit.
* **Mesh parity** -- the shard_map path's gather-of-payloads
  aggregation matches the vmap simulation, including d % |model| != 0
  remainder columns under bf16 on an 8-device mesh.
* **Trace structure** -- a compressed trace holds ZERO dense data-axis
  psums and exactly the declared per-round gathers/bits; claiming the
  dense bit budget on a compressed trace is a contract violation.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_in_subprocess

from repro.analysis import check_entry, count_eqns
from repro.core import compression as C
from repro.core import rounds as rounds_core
from repro.core.compression import Compression
from repro.core.dantzig import DantzigConfig
from repro.core.distributed import (
    distributed_slda_shardmap,
    simulated_distributed_slda,
)
from repro.core.pipeline import BinaryHead
from repro.stats import synthetic

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN = os.path.join(REPO, "tests", "golden", "binary_prerefactor.npz")
ATOL = 1e-6


# ---------------------------------------------------------------------------
# bit accounting
# ---------------------------------------------------------------------------


def test_uplink_bits_arithmetic():
    d = 100
    assert C.dense_uplink_bits(d, 1) == d * 32 == 3200
    # int8: 8-bit values + 16-bit indices + one f32 scale per column
    assert C.uplink_bits(Compression(20, "int8"), d, 1) == \
        20 * (8 + 16) + 32 == 512
    assert C.uplink_bits(Compression(20, "bf16"), d, 1) == 20 * (16 + 16)
    assert C.uplink_bits(Compression(12), d, 1) == 12 * (32 + 16)
    assert C.compression_ratio(Compression(20, "int8"), d, 1) == 512 / 3200
    # K columns scale linearly; int8 ships one scale PER column
    assert C.uplink_bits(Compression(5, "int8"), 30, 3) == \
        3 * 5 * (8 + 16) + 3 * 32
    # the identity codec is never cheaper than dense (indices ride along)
    assert C.uplink_bits(Compression(d), d, 1) > C.dense_uplink_bits(d, 1)


def test_index_width_adapts_to_dimension():
    """Indices travel int16 while d fits, int32 beyond -- and the
    accounting counts the same dtype the wire moves."""
    assert C.wire_index_dtype(100) == jnp.int16
    assert C.index_bits(100) == 16
    assert C.wire_index_dtype(32767) == jnp.int16
    assert C.wire_index_dtype(32768) == jnp.int32
    assert C.index_bits(40_000) == 32
    assert C.uplink_bits(Compression(10), 40_000, 1) == 10 * (32 + 32)


def test_validate_rejects_bad_configs():
    with pytest.raises(ValueError):
        Compression(0).validate(10)
    with pytest.raises(ValueError):
        Compression(11).validate(10)
    with pytest.raises(ValueError):
        Compression(2, "fp4").validate(10)
    with pytest.raises(ValueError):
        C.uplink_bits(Compression(0), 10, 1)


def test_payload_wire_dtypes():
    u = jax.random.normal(jax.random.PRNGKey(0), (40, 2))
    ref = jnp.zeros_like(u)
    for quant, dt in ((None, jnp.float32), ("bf16", jnp.bfloat16),
                      ("int8", jnp.int8)):
        p = C.encode(Compression(7, quant), u, ref)
        assert p.values.shape == p.indices.shape == (7, 2)
        assert p.values.dtype == dt
        assert p.indices.dtype == jnp.int16
        if quant == "int8":
            assert p.scales.shape == (2,)
            assert p.scales.dtype == jnp.float32
        else:
            assert p.scales is None


# ---------------------------------------------------------------------------
# codec semantics
# ---------------------------------------------------------------------------


def test_identity_codec_roundtrip_exact():
    key1, key2 = jax.random.split(jax.random.PRNGKey(1))
    u = jax.random.normal(key1, (30, 2))
    ref = jax.random.normal(key2, (30, 2))
    comp = Compression(30)
    out = C.decode(comp, C.encode(comp, u, ref), ref)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(u))
    # and the EF residual is exactly zero
    _, resid = C.ef_step(comp, u, jnp.zeros_like(u), ref)
    assert not np.asarray(resid).any()


def test_topk_selection_and_residual_split():
    """Selected coords: exact value through, residual exactly zero.
    Unselected coords: reference through, full delta into the residual."""
    ref = jnp.full((6, 1), 10.0)
    msg = ref + jnp.asarray([[0.0], [5.0], [-3.0], [0.1], [0.0], [0.0]])
    comp = Compression(2)
    payload, resid = C.ef_step(comp, msg, jnp.zeros_like(msg), ref)
    decoded = C.decode(comp, payload, ref)
    decoded, resid = np.asarray(decoded), np.asarray(resid)
    msg, ref = np.asarray(msg), np.asarray(ref)
    sel = np.asarray(jnp.sort(payload.indices[:, 0])).tolist()
    assert sel == [1, 2]  # the two largest |msg - ref|
    np.testing.assert_array_equal(decoded[[1, 2]], msg[[1, 2]])
    np.testing.assert_array_equal(decoded[[0, 3, 4, 5]], ref[[0, 3, 4, 5]])
    np.testing.assert_array_equal(resid[[1, 2]], 0.0)
    np.testing.assert_array_equal(resid[[0, 3, 4, 5]],
                                  (msg - ref)[[0, 3, 4, 5]])


def test_int8_quantizes_deltas_per_column():
    key1, key2 = jax.random.split(jax.random.PRNGKey(2))
    u = jax.random.normal(key1, (50, 3))
    ref = jax.random.normal(key2, (50, 3))
    comp = Compression(50, "int8")
    payload = C.encode(comp, u, ref)
    decoded = C.decode(comp, payload, ref)
    # symmetric quantization: error at most half a step of the
    # per-column scale, everywhere (k_top = d selects all rows)
    step = np.asarray(payload.scales)[None, :]
    assert np.all(np.abs(np.asarray(decoded - u)) <= 0.5 * step + 1e-7)
    # an all-zero delta column hits the amax==0 guard: scale 1, exact
    same = C.encode(comp, ref, ref)
    np.testing.assert_array_equal(np.asarray(same.values), 0)
    np.testing.assert_array_equal(np.asarray(same.scales), 1.0)
    np.testing.assert_array_equal(
        np.asarray(C.decode(comp, same, ref)), np.asarray(ref))


def test_decode_mean_matches_manual_mean():
    keys = jax.random.split(jax.random.PRNGKey(3), 5)
    msgs = jnp.stack([jax.random.normal(k, (20, 1)) for k in keys])
    ref = jnp.zeros((20, 1))
    comp = Compression(4)
    payloads, _ = jax.vmap(
        lambda m: C.ef_step(comp, m, jnp.zeros_like(m), ref))(msgs)
    manual = jnp.mean(jnp.stack([
        C.decode(comp, jax.tree.map(lambda leaf: leaf[i], payloads), ref)
        for i in range(5)]), axis=0)
    np.testing.assert_array_equal(
        np.asarray(C.decode_mean(comp, payloads, ref)), np.asarray(manual))


# ---------------------------------------------------------------------------
# wire screening: corrupted payloads cannot poison the decode
# ---------------------------------------------------------------------------


def test_decode_screens_corrupted_int8_scale_column():
    """Regression (DESIGN.md §11): ONE corrupted float32 scale column in
    an int8 payload NaN-poisons every decoded coordinate of that column.
    The default decode screens non-finite outputs back to the reference;
    ``screen_nonfinite=False`` (the fault layer's RAW view, so a whole
    machine can be screened instead of silently repaired) propagates."""
    key1, key2 = jax.random.split(jax.random.PRNGKey(12))
    u = jax.random.normal(key1, (24, 3))
    ref = jax.random.normal(key2, (24, 3))
    comp = Compression(6, "int8")
    payload = C.encode(comp, u, ref)
    bad = payload._replace(
        scales=payload.scales.at[1].set(jnp.nan))
    clean = np.asarray(C.decode(comp, payload, ref))

    screened = np.asarray(C.decode(comp, bad, ref))
    assert np.isfinite(screened).all()
    # the poisoned column falls back to the reference at its corrupted
    # coordinates; the other columns are untouched
    np.testing.assert_array_equal(screened[:, [0, 2]], clean[:, [0, 2]])
    sel = np.asarray(bad.indices[:, 1]).tolist()
    np.testing.assert_array_equal(screened[sel, 1],
                                  np.asarray(ref)[sel, 1])

    raw = np.asarray(C.decode(comp, bad, ref, screen_nonfinite=False))
    assert np.isnan(raw[sel, 1]).all()
    np.testing.assert_array_equal(raw[:, [0, 2]], clean[:, [0, 2]])


def test_decode_screens_nonfinite_float_values():
    """Float-mode corruption lands in the transmitted values directly;
    the decode screen repairs exactly those coordinates to the ref."""
    u = jnp.asarray([[3.0], [2.0], [1.0], [0.5]])
    ref = jnp.zeros((4, 1))
    comp = Compression(2)
    payload = C.encode(comp, u, ref)
    bad = payload._replace(values=payload.values.at[0, 0].set(jnp.inf))
    out = np.asarray(C.decode(comp, bad, ref))
    assert np.isfinite(out).all()
    poisoned = int(np.asarray(bad.indices)[0, 0])
    intact = int(np.asarray(bad.indices)[1, 0])
    assert out[poisoned, 0] == 0.0  # repaired to the reference
    assert out[intact, 0] == float(np.asarray(u)[intact, 0])


# ---------------------------------------------------------------------------
# identity codec == dense rounds, bit for bit (the PR 5 fixed point)
# ---------------------------------------------------------------------------


def test_k_top_d_matches_dense_rounds_bitwise_and_zero_residual():
    d = 30
    cfg = DantzigConfig(max_iters=200)
    p = synthetic.make_problem(d=d, n_signal=4, rho=0.5)
    xs, ys = synthetic.sample_machines(jax.random.PRNGKey(4), p, 4, 50, 50)
    _, ws = rounds_core.simulate_multi_round(
        BinaryHead(), (xs, ys), lam=0.2, lam_prime=0.2, rounds=1, cfg=cfg)
    for r in (1, 2, 3):
        dense = rounds_core.simulate_round_loop(ws, rounds=r)
        comp_out, resid = rounds_core.simulate_round_loop(
            ws, rounds=r, compression=Compression(d),
            return_ef_residual=True)
        np.testing.assert_array_equal(np.asarray(comp_out),
                                      np.asarray(dense))
        # the error-feedback stream never accumulates anything: the
        # identity codec's residual is EXACTLY zero after every round
        assert not np.asarray(resid).any()


def test_k_top_d_compression_matches_golden():
    """compression=Compression(d) reproduces the PRE-refactor golden:
    the compressed code path is provably dormant at the identity codec."""
    golden = np.load(GOLDEN)
    cfg = DantzigConfig(max_iters=300)
    p30 = synthetic.make_problem(d=30, n_signal=4)
    xs, ys = synthetic.sample_machines(
        jax.random.PRNGKey(11), p30, 3, 100, 100)
    dense = simulated_distributed_slda(xs, ys, 0.2, 0.2, 0.05, cfg)
    ident = simulated_distributed_slda(
        xs, ys, 0.2, 0.2, 0.05, cfg, compression=Compression(30))
    np.testing.assert_array_equal(np.asarray(ident), np.asarray(dense))
    np.testing.assert_allclose(np.asarray(ident), golden["sim_dist"],
                               atol=ATOL)


def test_residual_replays_dropped_coordinates_exactly():
    """The EF invariant, end to end: a coordinate dropped in round 1 is
    DELAYED, not lost -- the carried residual re-enters round 2's
    message, gets selected, and lands at its exact float32 value, after
    which the residual drains to zero."""
    comp = Compression(1)
    ref = jnp.zeros((4, 1))
    msg1 = jnp.asarray([[4.0], [3.0], [0.0], [0.0]])
    p1, r1 = C.ef_step(comp, msg1, jnp.zeros_like(msg1), ref)
    bar1 = C.decode(comp, p1, ref)
    # k_top=1 transmits only row 0; row 1 parks in the residual
    np.testing.assert_array_equal(np.asarray(bar1),
                                  [[4.0], [0.0], [0.0], [0.0]])
    np.testing.assert_array_equal(np.asarray(r1),
                                  [[0.0], [3.0], [0.0], [0.0]])
    # round 2: the fresh message agrees with the aggregate, so the only
    # delta left IS the carried residual
    p2, r2 = C.ef_step(comp, bar1, r1, bar1)
    bar2 = C.decode(comp, p2, bar1)
    np.testing.assert_array_equal(np.asarray(bar2),
                                  [[4.0], [3.0], [0.0], [0.0]])
    assert not np.asarray(r2).any()


# ---------------------------------------------------------------------------
# mesh parity
# ---------------------------------------------------------------------------


def test_compressed_mesh_1x1_matches_simulation():
    d = 16
    cfg = DantzigConfig(max_iters=150)
    p = synthetic.make_problem(d=d, n_signal=4, rho=0.5)
    xs, ys = synthetic.sample_machines(jax.random.PRNGKey(6), p, 1, 40, 40)
    comp = Compression(5, "int8")
    sim = simulated_distributed_slda(
        xs, ys, 0.2, 0.2, 0.05, cfg, rounds=2, compression=comp)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    out = distributed_slda_shardmap(
        mesh, xs.reshape(-1, d), ys.reshape(-1, d), 0.2, 0.2, 0.05, cfg,
        rounds=2, compression=comp)
    np.testing.assert_allclose(np.asarray(out), np.asarray(sim), atol=ATOL)


def test_compressed_mesh_8dev_remainder_matches_simulation():
    """(data=2, model=4) mesh, d=70 (70 % 4 != 0), rounds=3, top-16
    bf16: the gather-of-payloads aggregation matches the vmap
    simulation -- the encode runs on the REASSEMBLED (replicated)
    correction, so sharded CLIME blocks see the same top-k selection
    the simulation does."""
    out = run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np, math
        from repro.core.compression import Compression
        from repro.core.dantzig import DantzigConfig
        from repro.core.distributed import (
            distributed_slda_shardmap, simulated_distributed_slda)
        from repro.stats import synthetic

        cfg = DantzigConfig(max_iters=300)
        m, d = 2, 70
        comp = Compression(16, "bf16")
        p = synthetic.make_problem(d=d, n_signal=6, rho=0.6)
        xs, ys = synthetic.sample_machines(jax.random.PRNGKey(0), p, m, 100, 100)
        lam = 0.3 * math.sqrt(math.log(d) / 200) * 4
        t = 0.25 * lam
        sim = simulated_distributed_slda(
            xs, ys, lam, lam, t, cfg, rounds=3, compression=comp)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        out = distributed_slda_shardmap(
            mesh, xs.reshape(-1, d), ys.reshape(-1, d), lam, lam, t, cfg,
            rounds=3, compression=comp)
        np.testing.assert_allclose(np.asarray(out), np.asarray(sim), atol=1e-5)
        print("COMPRESSED_MESH8_OK")
        """
    )
    assert "COMPRESSED_MESH8_OK" in out


# ---------------------------------------------------------------------------
# trace structure: the compressed uplink is an asserted property
# ---------------------------------------------------------------------------


def _compressed_trace(d, t_rounds, comp):
    cfg = DantzigConfig(max_iters=40, adapt_rho=False)
    p = synthetic.make_problem(d=d, n_signal=4, rho=0.5)
    xs, ys = synthetic.sample_machines(jax.random.PRNGKey(7), p, 1, 30, 30)
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    def fn(x, y):
        return distributed_slda_shardmap(
            mesh, x, y, 0.2, 0.2, 0.05, cfg, rounds=t_rounds,
            compression=comp)

    return jax.make_jaxpr(fn)(xs.reshape(-1, d), ys.reshape(-1, d))


def test_compressed_trace_no_dense_psum_pinned_bits():
    d, t_rounds = 12, 2
    comp = Compression(5)
    jaxpr = _compressed_trace(d, t_rounds, comp)
    # the dense uplink is GONE from the lowered program, not just unused
    assert count_eqns(jaxpr, "psum") == 0
    # per round: one model-axis correction gather + two data-axis
    # payload gathers (values, indices; f32 mode has no scales)
    assert count_eqns(jaxpr, "all_gather") == t_rounds * 3
    violations = check_entry(
        "distributed.slda_shardmap", jaxpr,
        {"rounds": t_rounds, "dense_psums": 0, "live_psums": 0,
         "total_psums": 0, "screen_ops": 2 * t_rounds,
         "data_gathers": 2 * t_rounds,
         "data_gather_bits": t_rounds * C.uplink_bits(comp, d, 1),
         "data_psum_bits": 0,
         "data_total_bits": t_rounds * C.uplink_bits(comp, d, 1),
         "psum_payload": (d, 1), "pallas_calls": 0})
    assert violations == [], violations


def test_compressed_trace_rejects_dense_bit_budget():
    """Claiming the dense bit budget against a compressed trace -- or
    the compressed budget against a dense trace -- trips the
    AxisPayloadBits contract: the bits column in the benchmark is
    backed by the lowered program."""
    d, t_rounds = 12, 2
    comp = Compression(5)
    jaxpr = _compressed_trace(d, t_rounds, comp)
    violations = check_entry(
        "distributed.slda_shardmap", jaxpr,
        {"rounds": t_rounds, "dense_psums": 0, "live_psums": 0,
         "total_psums": 0, "screen_ops": 2 * t_rounds,
         "data_gathers": 2 * t_rounds,
         "data_gather_bits": t_rounds * C.dense_uplink_bits(d, 1),
         "data_psum_bits": 0,
         "data_total_bits": t_rounds * C.dense_uplink_bits(d, 1),
         "psum_payload": (d, 1), "pallas_calls": 0})
    assert any("bits" in v.message for v in violations), violations
