"""Regenerate the binary pre-refactor golden outputs (PR 2 parity pins).

Run from the repo root:

    PYTHONPATH=src python tests/golden/generate_binary_golden.py

The .npz this writes was produced at commit 38e71e8 (BEFORE the
head-parameterized pipeline refactor) so the parity tests in
``tests/test_pipeline_parity.py`` pin the refactor against the exact
pre-refactor numbers.  Re-running it on a later commit re-bases the pin
to the current implementation -- only do that deliberately.

The shard_map case runs in a subprocess with 2 forced host devices so
the main process keeps its default device count.
"""

import os
import subprocess
import sys
import textwrap

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(os.path.dirname(HERE))
OUT = os.path.join(HERE, "binary_prerefactor.npz")

BODY = textwrap.dedent(
    """
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=2'
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import slda
    from repro.core.dantzig import DantzigConfig
    from repro.core.distributed import (
        distributed_slda_shardmap,
        simulated_debiased_mean,
        simulated_distributed_slda,
        simulated_naive_averaged_slda,
    )
    from repro.stats import synthetic

    out = {}
    cfg = DantzigConfig(max_iters=300)

    # --- local debiased estimator (d=40) --------------------------------
    p40 = synthetic.make_problem(d=40, n_signal=5)
    x, y = synthetic.sample_two_class(jax.random.PRNGKey(10), p40, 200, 200)
    bt, bh = slda.debiased_local_estimator(x, y, 0.2, 0.25, cfg)
    out['local_beta_tilde'] = np.asarray(bt)
    out['local_beta_hat'] = np.asarray(bh)
    # default lam_prime=None branch
    bt2, bh2 = slda.debiased_local_estimator(x, y, 0.2, None, cfg)
    out['local_beta_tilde_lamdefault'] = np.asarray(bt2)

    # --- simulated paths (m=3, d=30) ------------------------------------
    p30 = synthetic.make_problem(d=30, n_signal=4)
    xs, ys = synthetic.sample_machines(jax.random.PRNGKey(11), p30, 3, 100, 100)
    out['sim_dist'] = np.asarray(
        simulated_distributed_slda(xs, ys, 0.2, 0.2, 0.05, cfg))
    out['sim_mean'] = np.asarray(
        simulated_debiased_mean(xs, ys, 0.2, 0.2, cfg))
    out['sim_naive'] = np.asarray(
        simulated_naive_averaged_slda(xs, ys, 0.2, cfg))

    # --- fused-solver simulated path -------------------------------------
    cfg_fused = DantzigConfig(max_iters=250, adapt_rho=False, fused=True)
    out['sim_dist_fused'] = np.asarray(
        simulated_distributed_slda(xs, ys, 0.2, 0.2, 0.05, cfg_fused))

    # --- shard_map with remainder columns: d=7 over |model|=2 ------------
    p7 = synthetic.make_problem(d=7, n_signal=3)
    xs7, ys7 = synthetic.sample_machines(jax.random.PRNGKey(12), p7, 1, 40, 40)
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    out['mesh_d7'] = np.asarray(distributed_slda_shardmap(
        mesh, xs7.reshape(-1, 7), ys7.reshape(-1, 7), 0.2, 0.2, 0.05, cfg))

    np.savez(os.environ['GOLDEN_OUT'], **out)
    print('wrote', os.environ['GOLDEN_OUT'])
    """
)


def main():
    env = dict(
        os.environ,
        PYTHONPATH=os.path.join(REPO, "src"),
        GOLDEN_OUT=OUT,
    )
    res = subprocess.run([sys.executable, "-c", BODY], env=env, cwd=REPO,
                         capture_output=True, text=True, timeout=900)
    sys.stdout.write(res.stdout)
    sys.stderr.write(res.stderr)
    res.check_returncode()


if __name__ == "__main__":
    main()
