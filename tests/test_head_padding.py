"""Head padding (llama4 40->48 on a 16-wide axis) is semantics-preserving."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model_zoo
from repro.models.attention import pad_head_mask


def _pad_like(a, b, kv, g_old, g_new):
    if a.shape == b.shape:
        return a
    out = jnp.zeros(b.shape, b.dtype)
    h_axis = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y][0]
    for k in range(kv):
        src = [slice(None)] * a.ndim
        dst = [slice(None)] * a.ndim
        src[h_axis] = slice(k * g_old, (k + 1) * g_old)
        dst[h_axis] = slice(k * g_new, k * g_new + g_old)
        out = out.at[tuple(dst)].set(a[tuple(src)])
    return out


def test_padded_forward_matches_unpadded():
    cfg = configs.smoke_config(configs.get_config("llama4-maverick-400b-a17b"))
    cfg_pad = dataclasses.replace(cfg, pad_heads_to=6)  # 4 heads, kv=2: g 2->3
    m0 = model_zoo.build_model(cfg)
    m1 = model_zoo.build_model(cfg_pad)
    p0 = m0.init(jax.random.PRNGKey(0))
    p1 = m1.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    l0, _ = m0.forward(p0, toks)
    kv = cfg.num_kv_heads
    p1c = jax.tree.map(
        lambda a, b: _pad_like(a, b, kv, cfg.num_heads // kv, 6 // kv), p0, p1
    )
    l1c, _ = m1.forward(p1c, toks)
    np.testing.assert_allclose(
        np.asarray(l1c, np.float32), np.asarray(l0, np.float32), atol=2e-3, rtol=1e-3
    )


def test_pad_mask_structure():
    cfg = configs.get_config("llama4-maverick-400b-a17b")
    assert cfg.padded_heads == 48 and cfg.num_heads == 40
    mask = pad_head_mask(cfg)
    assert mask.shape == (48,)
    assert int(mask.sum()) == 40
    # per-group tails are the pad slots: groups of 6, last slot padded
    g_new = 48 // cfg.num_kv_heads  # 6
    g_old = 40 // cfg.num_kv_heads  # 5
    m = np.asarray(mask).reshape(cfg.num_kv_heads, g_new)
    assert (m[:, :g_old] == True).all()  # noqa: E712
    assert (m[:, g_old:] == False).all()  # noqa: E712


def test_padded_heads_divisible_by_model_axis():
    """Every attention-bearing arch must shard its heads over 16 devices."""
    for name in configs.list_archs():
        cfg = configs.get_config(name)
        has_attn = any(k.startswith("attn") for k in cfg.pattern) or cfg.encoder_layers
        if has_attn:
            assert cfg.padded_heads % 16 == 0, (name, cfg.padded_heads)
