"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture is instantiated as a REDUCED variant of the
same family (<= 2-layer pattern, d_model <= 512, <= 4 experts) and runs
one forward + one train step + one decode step on CPU, asserting output
shapes and finiteness.  The FULL configs are exercised via the dry-run
(ShapeDtypeStruct lowering, no allocation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch import steps
from repro.models import model_zoo
from repro.models.encdec import EncDecModel
from repro.optim import AdamWConfig, adamw_init

ARCHS = configs.list_archs()

BATCH, SEQ = 2, 32


def _smoke(name):
    return configs.smoke_config(configs.get_config(name))


def _real_batch(cfg, shape, with_labels):
    """Concrete arrays matching steps.batch_specs."""
    out = {}
    for k, spec in steps.batch_specs(cfg, shape, with_labels).items():
        if spec.dtype == jnp.int32:
            out[k] = jax.random.randint(
                jax.random.PRNGKey(hash(k) % 2**31), spec.shape, 0, cfg.vocab_size
            )
        else:
            out[k] = 0.01 * jax.random.normal(
                jax.random.PRNGKey(1), spec.shape, spec.dtype
            )
    return out


@pytest.fixture(scope="module", params=ARCHS)
def arch(request):
    return request.param


def test_full_config_matches_assignment(arch):
    """Exact assigned numbers (layers/d_model/heads/kv/d_ff/vocab/experts)."""
    cfg = configs.get_config(arch)
    expected = {
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064, 16, 2),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000, 0, 0),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936, 0, 0),
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064, 0, 0),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206, 0, 0),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536, 16, 2),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768, 0, 0),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048, 128, 1),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152, 0, 0),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304, 0, 0),
    }[arch]
    got = (
        cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
        cfg.d_ff, cfg.vocab_size, cfg.num_experts, cfg.experts_per_token,
    )
    assert got == expected, f"{arch}: {got} != {expected}"
    assert cfg.citation, f"{arch} missing source citation"


def test_smoke_train_step(arch):
    cfg = _smoke(arch)
    shape = steps.ShapeDef("smoke_train", SEQ, BATCH, "train")
    batch = _real_batch(cfg, shape, with_labels=True)
    model = model_zoo.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    train_step = jax.jit(steps.make_train_step(cfg))
    params2, opt2, metrics = train_step(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + float(jnp.sum(jnp.abs(b[0].astype(jnp.float32)
                                               - b[1].astype(jnp.float32)))),
        jax.tree.map(lambda a, b: (a, b), params, params2), 0.0,
    )
    assert delta > 0
    # no NaNs anywhere in the update
    for leaf in jax.tree.leaves(params2):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), arch


def test_smoke_prefill_logits(arch):
    cfg = _smoke(arch)
    shape = steps.ShapeDef("smoke_prefill", SEQ, BATCH, "prefill")
    batch = _real_batch(cfg, shape, with_labels=False)
    model = model_zoo.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prefill = jax.jit(steps.make_prefill_step(cfg))
    logits = prefill(params, batch)
    assert logits.shape == (BATCH, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)[:, : cfg.vocab_size])))
    # padded vocab ids are masked to -inf-ish
    if cfg.padded_vocab > cfg.vocab_size:
        pad_max = float(jnp.max(logits[:, cfg.vocab_size:]))
        real_max = float(jnp.max(logits[:, : cfg.vocab_size]))
        assert pad_max < real_max


def test_smoke_decode_steps(arch):
    cfg = _smoke(arch)
    model = model_zoo.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache_len = 16
    if isinstance(model, EncDecModel):
        memory = 0.01 * jax.random.normal(
            jax.random.PRNGKey(2), (BATCH, 8, cfg.d_model), cfg.activation_dtype
        )
        state = model.init_decode_state(params, memory, cache_len)
    else:
        state = model.init_decode_state(BATCH, cache_len)
    serve = jax.jit(steps.make_serve_step(cfg))
    tok = jnp.zeros((BATCH, 1), jnp.int32)
    for _ in range(3):
        tok_next, logits, state = serve(params, state, tok)
        assert tok_next.shape == (BATCH,)
        assert logits.shape == (BATCH, 1, cfg.padded_vocab)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)[..., : cfg.vocab_size])))
        assert int(state.pos) >= 1
        tok = tok_next[:, None]


def test_decode_matches_forward(arch):
    """Stepwise decode must reproduce the teacher-forced forward logits."""
    cfg = _smoke(arch)
    model = model_zoo.build_model(cfg)
    if isinstance(model, EncDecModel):
        pytest.skip("enc-dec decode consumes encoder memory, separate test")
    if cfg.modality == "vision":
        pytest.skip("vision prefix changes positions, separate test")
    params = model.init(jax.random.PRNGKey(0))
    T = 8
    toks = jax.random.randint(jax.random.PRNGKey(3), (BATCH, T), 0, cfg.vocab_size)
    logits_fwd, _ = jax.jit(model.forward)(params, toks)
    state = model.init_decode_state(BATCH, T)
    outs = []
    dstep = jax.jit(model.decode_step)
    for t in range(T):
        lg, state = dstep(params, state, toks[:, t : t + 1])
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    lf = logits_fwd.astype(jnp.float32)[..., : cfg.vocab_size]
    ld = logits_dec.astype(jnp.float32)[..., : cfg.vocab_size]
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lf), atol=2e-2, rtol=2e-2)


def test_loss_decreases_over_steps(arch):
    """A few steps on a fixed batch must reduce the loss (overfit check)."""
    cfg = _smoke(arch)
    shape = steps.ShapeDef("fit", SEQ, BATCH, "train")
    batch = _real_batch(cfg, shape, with_labels=True)
    model = model_zoo.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    # short warmup (default 200-step ramp keeps lr ~0 for an 8-step test)
    step = jax.jit(
        steps.make_train_step(cfg, AdamWConfig(lr=1e-3), total_steps=50, warmup_steps=2)
    )
    losses = []
    for _ in range(8):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert min(losses[1:]) < losses[0], f"{arch}: {losses}"
