import os
import subprocess
import sys
import textwrap

import jax
import pytest

jax.config.update("jax_enable_x64", False)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def run_in_subprocess(body: str, devices: int = 8, timeout: int = 480,
                      env_extra: dict | None = None) -> str:
    """Run python code in a fresh interpreter with N forced host devices.

    Mesh tests must set ``--xla_force_host_platform_device_count``
    BEFORE jax import, and the running process may already have
    initialized jax with a different device count -- a subprocess is
    the only clean way.  Returns the subprocess stdout; asserts a zero
    exit status (last 4000 bytes of stderr on failure).
    """
    prog = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        + textwrap.dedent(body)
    )
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    if env_extra:
        env.update(env_extra)
    res = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        timeout=timeout, env=env, cwd=REPO,
    )
    assert res.returncode == 0, f"stderr:\n{res.stderr[-4000:]}"
    return res.stdout
