import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
