"""Streaming refit + resilient serving (DESIGN.md §12).

What is pinned here, and why it is the contract that matters:

* chunked sufficient-stat merges are EXACT: any chunking of the stream
  (including chunks that miss a class entirely, and d/n not multiples
  of the chunk) reproduces the one-shot statistics on the concatenated
  data to float tolerance, for both heads -- so the streaming refit
  solves the SAME problem the batch pipeline would;
* quarantine is bit-identical: a screened-out batch leaves every leaf
  of the accumulated statistics byte-for-byte what it was, because the
  rejection is a ``where``-SELECT, never an arithmetic no-op;
* the serving hot path IS the paper's rule: the binary model slot's
  two-column scores reproduce ``fisher_rule`` prediction-for-
  prediction, and ``mc_classify`` is bit-identical through the
  deduplicated ``classifier.classify_scores``;
* the escalation ladder is bounded and honest: injected divergence
  fails exactly the rungs it poisons, convergence verdicts come from
  executed-iteration counts, and a ladder that runs out of attempts
  returns None (the caller keeps the last-good slot);
* warm refits resume: after a data increment, the warm carry re-solves
  in strictly fewer ADMM iterations than a cold solve of the same
  statistics;
* graceful degradation end to end: under ingest corruption + refit
  divergence + refresh drops, served scores stay finite and accuracy
  stays within slack of a fault-free twin, while the unprotected
  baseline demonstrably collapses on the same fault plan;
* the staleness contract mirrors PR 8: missed refreshes walk
  live -> stale -> degraded at the caller's bound, and a publish
  resets to live;
* crash recovery: a serving runtime restored from its checkpoint
  serves the same predictions as the live instance at the same slot
  version.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import streaming as st
from repro.core.classifier import classify_scores, fisher_rule
from repro.core.dantzig import DantzigConfig
from repro.core.faults import Aggregation
from repro.core.multiclass import mc_classify
from repro.core.pipeline import BinaryHead, mc_suff_stats, suff_stats
from repro.core.slda import hard_threshold
from repro.stats.synthetic import (
    make_problem,
    sample_labeled,
    sample_two_class,
)

CFG = DantzigConfig(tol=1e-3)


def _problem(d=17, seed=0, rho=0.5):
    return make_problem(d=d, n_signal=max(3, d // 4), rho=rho)


def _chunks(x, size):
    return [x[i:i + size] for i in range(0, x.shape[0], size)]


# ---------------------------------------------------------------------------
# merge exactness
# ---------------------------------------------------------------------------

def test_chunked_merge_matches_oneshot_binary():
    """Uneven per-class chunks reproduce the one-shot SuffStats (d=17,
    chunk 48 divides neither class count)."""
    prob = _problem(d=17)
    x, y = sample_two_class(jax.random.PRNGKey(0), prob, 130, 150)
    one = suff_stats(x, y)
    empty = jnp.zeros((0, 17))
    acc = None
    for cx in _chunks(x, 48):
        s = suff_stats(cx, empty)
        acc = s if acc is None else st.merge_suff_stats(acc, s)
    for cy in _chunks(y, 48):
        s = suff_stats(empty, cy)
        acc = st.merge_suff_stats(acc, s)
    assert int(acc.n1) == 130 and int(acc.n2) == 150
    np.testing.assert_allclose(np.asarray(acc.sigma), np.asarray(one.sigma),
                               atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(acc.mu1), np.asarray(one.mu1),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(acc.mu2), np.asarray(one.mu2),
                               atol=1e-5, rtol=1e-5)


def test_single_class_chunks_merge_exactly():
    """A chunk that misses a class entirely (NaN mean from the empty
    side) must not poison the merge: where-SELECT, never 0 * NaN."""
    prob = _problem(d=9)
    x, y = sample_two_class(jax.random.PRNGKey(1), prob, 60, 70)
    empty = jnp.zeros((0, 9))
    only_x = suff_stats(x, empty)
    assert not np.isfinite(np.asarray(only_x.mu2)).any()
    merged = st.merge_suff_stats(only_x, suff_stats(empty, y))
    one = suff_stats(x, y)
    assert np.isfinite(np.asarray(merged.sigma)).all()
    np.testing.assert_allclose(np.asarray(merged.sigma),
                               np.asarray(one.sigma), atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(merged.mu2), np.asarray(one.mu2),
                               atol=1e-5, rtol=1e-5)


def test_chunked_merge_matches_oneshot_multiclass():
    """K=3 chunked MCStats merge == one-shot on the full stream (d=13,
    n=205 not a multiple of the 64-chunk)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    x = jax.random.normal(k1, (205, 13))
    labels = jax.random.randint(k2, (205,), 0, 3)
    one = mc_suff_stats(x, labels, 3)
    acc = None
    for i in range(0, 205, 64):
        s = mc_suff_stats(x[i:i + 64], labels[i:i + 64], 3)
        acc = s if acc is None else st.merge_mc_stats(acc, s)
    np.testing.assert_array_equal(np.asarray(acc.counts),
                                  np.asarray(one.counts))
    np.testing.assert_allclose(np.asarray(acc.sigma), np.asarray(one.sigma),
                               atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(acc.means), np.asarray(one.means),
                               atol=1e-5, rtol=1e-5)


def test_rank1_stream_matches_oneshot():
    """Single-sample (rank-1) ingest, the finest chunking, stays exact."""
    prob = _problem(d=7)
    x, y = sample_two_class(jax.random.PRNGKey(3), prob, 25, 20)
    one = suff_stats(x, y)
    empty = jnp.zeros((0, 7))
    acc = suff_stats(x[:1], empty)
    for i in range(1, 25):
        acc = st.merge_suff_stats(acc, suff_stats(x[i:i + 1], empty))
    for i in range(20):
        acc = st.merge_suff_stats(acc, suff_stats(empty, y[i:i + 1]))
    np.testing.assert_allclose(np.asarray(acc.sigma), np.asarray(one.sigma),
                               atol=1e-4, rtol=1e-4)


def test_head_stats_roundtrip():
    """head_stats_of rebuilds the exact HeadStats the head would emit."""
    prob = _problem(d=11)
    x, y = sample_two_class(jax.random.PRNGKey(4), prob, 40, 44)
    direct = BinaryHead().stats(x, y)
    rebuilt = st.head_stats_of(direct.aux)
    np.testing.assert_array_equal(np.asarray(direct.sigma),
                                  np.asarray(rebuilt.sigma))
    np.testing.assert_array_equal(np.asarray(direct.rhs),
                                  np.asarray(rebuilt.rhs))


# ---------------------------------------------------------------------------
# screening / quarantine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("poison", ["nan", "inf", "garbage"])
def test_quarantine_bit_identical(poison):
    prob = _problem(d=10)
    x, y = sample_two_class(jax.random.PRNGKey(5), prob, 50, 50)
    acc = suff_stats(x, y)
    fill = {"nan": jnp.nan, "inf": jnp.inf, "garbage": 1e12}[poison]
    bad = jnp.full((8, 10), fill)
    bad_stats = suff_stats(bad, jnp.zeros((0, 10)))
    w = st.screen_batch(Aggregation(envelope=1e6), bad)
    assert float(w) == 0.0
    after = st.ingest_stats(acc, bad_stats, w)
    for got, want in zip(jax.tree.leaves(after), jax.tree.leaves(acc)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_clean_batch_passes_screen_and_merges():
    prob = _problem(d=10)
    x, y = sample_two_class(jax.random.PRNGKey(6), prob, 50, 50)
    acc = suff_stats(x, y)
    bx, by = sample_two_class(jax.random.PRNGKey(7), prob, 20, 20)
    w = st.screen_batch(Aggregation(envelope=1e6), bx, by)
    assert float(w) == 1.0
    after = st.ingest_stats(acc, suff_stats(bx, by), w)
    assert int(after.n1) == 70 and int(after.n2) == 70
    one = suff_stats(jnp.concatenate([x, bx]), jnp.concatenate([y, by]))
    np.testing.assert_allclose(np.asarray(after.sigma), np.asarray(one.sigma),
                               atol=5e-5, rtol=5e-5)


def test_garbage_without_envelope_is_not_screened():
    """Finite garbage needs the envelope opt-in, mirroring the PR 8
    wire-screening semantics."""
    bad = jnp.full((4, 6), 1e12)
    assert float(st.screen_batch(Aggregation(envelope=None), bad)) == 1.0
    assert float(st.screen_batch(Aggregation(envelope=1e6), bad)) == 0.0


# ---------------------------------------------------------------------------
# classifier dedup parity
# ---------------------------------------------------------------------------

def test_binary_slot_matches_fisher_rule():
    """The serving slot's 2-column scores reproduce eq. 1.1's rule
    prediction-for-prediction (equal priors)."""
    prob = _problem(d=17)
    x, y = sample_two_class(jax.random.PRNGKey(8), prob, 120, 140)
    aux = suff_stats(x, y)
    res, _ = st.refit_with_escalation(st.head_stats_of(aux), 0.1, 0.2,
                                      CFG, None)
    slot = st.slot_from_stats(aux, res.beta_tilde, 1e-3, version=1)
    z, _ = sample_labeled(jax.random.PRNGKey(9), prob, 400)
    pred, scores = st.classify_batch(z, slot.beta, slot.means, None)
    beta_vec = hard_threshold(res.beta_tilde, 1e-3).reshape(-1)
    want = fisher_rule(z, beta_vec, aux.mu1, aux.mu2)
    np.testing.assert_array_equal(np.asarray(pred), np.asarray(want))
    assert scores.shape == (400, 2)


def test_mc_classify_identical_through_dedup():
    """mc_classify == argmax(classify_scores) bitwise, priors and not."""
    key = jax.random.PRNGKey(10)
    z = jax.random.normal(key, (64, 12))
    beta = jax.random.normal(jax.random.fold_in(key, 1), (12, 4))
    means = jax.random.normal(jax.random.fold_in(key, 2), (4, 12))
    priors = jnp.asarray([0.1, 0.2, 0.3, 0.4])
    for p in (None, priors):
        got = mc_classify(z, beta, means, p)
        want = jnp.argmax(classify_scores(z, beta, means, p), axis=-1)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_priors_shift_decisions():
    """The + log pi_k term must reach the argmax (lopsided priors pull
    borderline queries toward the heavy class)."""
    z = jnp.zeros((1, 2))
    beta = jnp.asarray([[1.0, 0.0], [0.0, 1.0]])
    means = jnp.asarray([[0.1, 0.0], [0.0, 0.1]])
    flat = st.classify_batch(z, beta, means, jnp.asarray([0.5, 0.5]))[0]
    tilted = st.classify_batch(z, beta, means, jnp.asarray([0.99, 0.01]))[0]
    assert int(flat[0]) != int(tilted[0]) or int(tilted[0]) == 0


# ---------------------------------------------------------------------------
# refit: warm resume + escalation ladder
# ---------------------------------------------------------------------------

def test_warm_refit_fewer_iters_than_cold():
    prob = _problem(d=17)
    x, y = sample_two_class(jax.random.PRNGKey(11), prob, 120, 120)
    aux = suff_stats(x, y)
    res0, _ = st.refit_with_escalation(st.head_stats_of(aux), 0.1, 0.2,
                                       CFG, None)
    bx, by = sample_two_class(jax.random.PRNGKey(12), prob, 40, 40)
    aux = st.merge_suff_stats(aux, suff_stats(bx, by))
    hs = st.head_stats_of(aux)
    warm = st.refit_step(hs, 0.1, 0.2, CFG, carry=res0.carry)
    cold = st.refit_step(hs, 0.1, 0.2, CFG)
    warm_total = int(np.max(np.asarray(warm.iters_beta))) + int(
        np.max(np.asarray(warm.iters_theta)))
    cold_total = int(np.max(np.asarray(cold.iters_beta))) + int(
        np.max(np.asarray(cold.iters_theta)))
    assert warm_total < cold_total, (warm_total, cold_total)
    assert st.refit_converged(warm, CFG) and st.refit_converged(cold, CFG)


def test_escalation_ladder_recovers_and_logs():
    prob = _problem(d=12)
    x, y = sample_two_class(jax.random.PRNGKey(13), prob, 80, 80)
    hs = st.head_stats_of(suff_stats(x, y))
    cold, _ = st.refit_with_escalation(hs, 0.1, 0.2, CFG, None)
    res, log = st.refit_with_escalation(hs, 0.1, 0.2, CFG, cold.carry,
                                        inject_fail_attempts=2)
    assert res is not None
    assert [e["attempt"] for e in log] == ["warm", "cold", "refactor"]
    assert [e["converged"] for e in log] == [False, False, True]
    assert np.isfinite(np.asarray(res.beta_tilde)).all()


def test_escalation_ladder_bounded():
    """max_attempts=1 with one injected failure -> honest None."""
    prob = _problem(d=12)
    x, y = sample_two_class(jax.random.PRNGKey(14), prob, 80, 80)
    hs = st.head_stats_of(suff_stats(x, y))
    res, log = st.refit_with_escalation(
        hs, 0.1, 0.2, CFG, None,
        policy=st.EscalationPolicy(max_attempts=1),
        inject_fail_attempts=1)
    assert res is None and len(log) == 1 and not log[0]["converged"]


def test_nonfinite_stats_fail_verdict():
    """A refit on NaN statistics must never pass the verdict."""
    prob = _problem(d=10)
    x, y = sample_two_class(jax.random.PRNGKey(15), prob, 60, 60)
    aux = suff_stats(x, y)
    hs = st.head_stats_of(aux)._replace(
        sigma=jnp.full((10, 10), jnp.nan))
    res = st.refit_step(hs, 0.1, 0.2, CFG)
    assert not st.refit_converged(res, CFG)


# ---------------------------------------------------------------------------
# fault plans + the state machine
# ---------------------------------------------------------------------------

def test_serve_fault_plan_deterministic():
    sched = st.ServeFaultSchedule(0.4, 0.5, 0.3, seed=7)
    a, b = sched.plan(32), sched.plan(32)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    assert a.corrupt.shape == (32,)
    # rate 0 schedules fire nothing
    quiet = st.ServeFaultSchedule().plan(16)
    assert not quiet.corrupt.any() and not quiet.drop.any()
    with pytest.raises(ValueError):
        st.ServeFaultSchedule(corrupt_ingest=1.5).validate()


def test_slot_status_contract():
    assert st.slot_status(0, 2) == st.STATUS_LIVE
    assert st.slot_status(1, 2) == st.STATUS_STALE
    assert st.slot_status(2, 2) == st.STATUS_STALE
    assert st.slot_status(3, 2) == st.STATUS_DEGRADED
    assert st.slot_status(1, 0) == st.STATUS_DEGRADED


def _runtime(d=16, seed=16, **kw):
    prob = _problem(d=d)
    x, y = sample_two_class(jax.random.PRNGKey(seed), prob, 100, 100)
    rt = st.ServingRuntime(suff_stats(x, y), 0.1, 0.2, 1e-3, cfg=CFG, **kw)
    return prob, rt


def test_runtime_staleness_walk():
    """Dropped refreshes walk live -> stale -> degraded; a publish
    resets to live and bumps the version."""
    prob, rt = _runtime(staleness_bound=2)
    assert rt.status == st.STATUS_LIVE and int(rt.slot.version) == 1
    v0 = np.asarray(rt.slot.beta)
    for want in (st.STATUS_STALE, st.STATUS_STALE, st.STATUS_DEGRADED):
        assert rt.refresh(drop=True) is False
        assert rt.status == want
    # the slot itself never changed while degraded
    np.testing.assert_array_equal(np.asarray(rt.slot.beta), v0)
    assert rt.refresh() is True
    assert rt.status == st.STATUS_LIVE and int(rt.slot.version) == 2


def test_failed_refit_keeps_last_good_slot():
    """A ladder that exhausts its attempts must not touch the slot."""
    prob, rt = _runtime(
        escalation=st.EscalationPolicy(max_attempts=1))
    before = np.asarray(rt.slot.beta)
    assert rt.refresh(inject_diverge=1) is False
    np.testing.assert_array_equal(np.asarray(rt.slot.beta), before)
    assert rt.status == st.STATUS_STALE
    # scores off the last-good slot stay finite
    z, _ = sample_labeled(jax.random.PRNGKey(17), prob, 64)
    _, scores = rt.classify(z)
    assert np.isfinite(np.asarray(scores)).all()


# ---------------------------------------------------------------------------
# chaos: protected within slack of fault-free, unprotected collapses
# ---------------------------------------------------------------------------

def _run_stream(rt, prob, plan, ticks, seed=1000, refit_every=2):
    key = jax.random.PRNGKey(seed)
    accs, finite = [], True
    for t in range(ticks):
        key, k1, k2 = jax.random.split(key, 3)
        z, lab = sample_labeled(k1, prob, 250)
        pred, scores = rt.classify(z)
        finite &= bool(np.isfinite(np.asarray(scores)).all())
        accs.append(float(jnp.mean(pred == lab)))
        bx, by = sample_two_class(k2, prob, 40, 40)
        code = int(plan.corrupt[t]) if plan is not None else 0
        bx, by = st.corrupt_batch_arrays(code, (bx, by))
        rt.ingest_batch(suff_stats(bx, by), bx, by)
        if (t + 1) % refit_every == 0:
            drop = bool(plan.drop[t]) if plan is not None else False
            div = int(plan.diverge[t]) if plan is not None else 0
            rt.refresh(drop=drop, inject_diverge=div)
    return float(np.mean(accs)), finite


def test_chaos_protected_vs_unprotected():
    """The acceptance gate: same stream, same fault plan -- protected
    serving stays finite and within 0.02 of fault-free accuracy, the
    unprotected baseline demonstrably degrades."""
    prob = _problem(d=20)
    x, y = sample_two_class(jax.random.PRNGKey(18), prob, 150, 150)
    aux0 = suff_stats(x, y)
    ticks = 10
    plan = st.ServeFaultSchedule(
        corrupt_ingest=0.5, diverge_refit=0.6, drop_refresh=0.25,
        seed=3).plan(ticks)
    assert plan.corrupt.any() and plan.diverge.any()

    def fresh(protect):
        return st.ServingRuntime(aux0, 0.1, 0.2, 1e-3, cfg=CFG,
                                 staleness_bound=2, protect=protect)

    acc_clean, fin_clean = _run_stream(fresh(True), prob, None, ticks)
    acc_prot, fin_prot = _run_stream(fresh(True), prob, plan, ticks)
    acc_unprot, fin_unprot = _run_stream(fresh(False), prob, plan, ticks)
    assert fin_clean and fin_prot
    assert acc_prot >= acc_clean - 0.02, (acc_prot, acc_clean)
    degraded = (not fin_unprot) or (acc_unprot < acc_clean - 0.02)
    assert degraded, (acc_unprot, acc_clean, fin_unprot)


# ---------------------------------------------------------------------------
# checkpoint wiring
# ---------------------------------------------------------------------------

def test_runtime_checkpoint_restore_parity(tmp_path):
    prob, rt = _runtime(ckpt_dir=str(tmp_path))
    bx, by = sample_two_class(jax.random.PRNGKey(19), prob, 40, 40)
    rt.ingest_batch(suff_stats(bx, by), bx, by)
    assert rt.refresh() is True
    restored = st.ServingRuntime.restore(
        str(tmp_path), rt.aux, 0.1, 0.2, 1e-3, cfg=CFG)
    assert int(restored.slot.version) == int(rt.slot.version)
    z, _ = sample_labeled(jax.random.PRNGKey(20), prob, 300)
    p_live, s_live = rt.classify(z)
    p_rest, s_rest = restored.classify(z)
    np.testing.assert_array_equal(np.asarray(p_live), np.asarray(p_rest))
    np.testing.assert_array_equal(np.asarray(s_live), np.asarray(s_rest))
    # and the restored runtime can keep refitting (carry survived)
    assert restored.refresh() is True
    assert int(restored.slot.version) == int(rt.slot.version) + 1
