"""Model-axis CLIME sharding: remainder columns must never be dropped.

The debias correction ``Theta^T (Sigma beta_hat - mu_d)`` uses all d
CLIME columns; these tests pin the padded+masked sharding against the
unsharded simulation for d NOT a multiple of the model-axis size.
Mesh runs happen in a subprocess with forced host devices (see
``conftest.run_in_subprocess``).
"""

from conftest import run_in_subprocess as _run_in_subprocess


def test_remainder_columns_d7_size2():
    """d=7 over a 2-wide model axis: 7 % 2 = 1 column must survive."""
    out = _run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import (
            distributed_slda_shardmap, simulated_distributed_slda,
        )
        from repro.core.dantzig import DantzigConfig
        from repro.stats import synthetic

        cfg = DantzigConfig(max_iters=200)
        m, n1, n2, d = 1, 40, 40, 7
        problem = synthetic.make_problem(d=d, n_signal=3)
        xs, ys = synthetic.sample_machines(jax.random.PRNGKey(1), problem, m, n1, n2)
        sim = simulated_distributed_slda(xs, ys, 0.2, 0.2, 0.05, cfg)
        mesh = jax.make_mesh((1, 2), ("data", "model"))
        out = distributed_slda_shardmap(
            mesh, xs.reshape(m * n1, d), ys.reshape(m * n2, d),
            0.2, 0.2, 0.05, cfg)
        np.testing.assert_allclose(np.asarray(out), np.asarray(sim), atol=1e-5)
        print("REMAINDER7_OK")
        """,
        devices=2,
    )
    assert "REMAINDER7_OK" in out


def test_remainder_columns_d70_size4():
    """Acceptance case: d=70, |model|=4 agrees with the simulation to 1e-5."""
    out = _run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np, math
        from repro.core.distributed import (
            distributed_slda_shardmap, simulated_distributed_slda,
        )
        from repro.core.dantzig import DantzigConfig
        from repro.stats import synthetic

        cfg = DantzigConfig(max_iters=200)
        m, n1, n2, d = 2, 60, 60, 70
        problem = synthetic.make_problem(d=d, n_signal=5)
        xs, ys = synthetic.sample_machines(jax.random.PRNGKey(0), problem, m, n1, n2)
        lam = 0.3 * math.sqrt(math.log(d) / (n1 + n2)) * 4
        t = 0.25 * lam
        sim = simulated_distributed_slda(xs, ys, lam, lam, t, cfg)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        out = distributed_slda_shardmap(
            mesh, xs.reshape(m * n1, d), ys.reshape(m * n2, d),
            lam, lam, t, cfg)
        np.testing.assert_allclose(np.asarray(out), np.asarray(sim), atol=1e-5)
        print("REMAINDER70_OK")
        """
    )
    assert "REMAINDER70_OK" in out


def test_remainder_columns_fused_solver_d11_size4():
    """The padded sharding composes with the fused Pallas solver path
    (d=11 over 4 devices: ceil gives 3 cols/device, 1 pad column)."""
    out = _run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.distributed import (
            distributed_slda_shardmap, simulated_distributed_slda,
        )
        from repro.core.dantzig import DantzigConfig
        from repro.stats import synthetic

        cfg = DantzigConfig(max_iters=250, adapt_rho=False, fused=True)
        m, n1, n2, d = 1, 50, 50, 11
        problem = synthetic.make_problem(d=d, n_signal=3)
        xs, ys = synthetic.sample_machines(jax.random.PRNGKey(2), problem, m, n1, n2)
        sim = simulated_distributed_slda(xs, ys, 0.15, 0.15, 0.02, cfg)
        mesh = jax.make_mesh((1, 4), ("data", "model"))
        out = distributed_slda_shardmap(
            mesh, xs.reshape(m * n1, d), ys.reshape(m * n2, d),
            0.15, 0.15, 0.02, cfg)
        np.testing.assert_allclose(np.asarray(out), np.asarray(sim), atol=1e-5)
        print("REMAINDER_FUSED_OK")
        """,
        devices=4,
    )
    assert "REMAINDER_FUSED_OK" in out
