"""CLIME + sparse LDA statistical behaviour (the paper's core math)."""

import jax
import jax.numpy as jnp
import math
import numpy as np
import pytest

from repro.core import classifier, slda
from repro.core.clime import solve_clime, solve_clime_columns, symmetrize_min
from repro.core.dantzig import DantzigConfig
from repro.stats import synthetic

CFG = DantzigConfig(max_iters=800)


@pytest.fixture(scope="module")
def problem():
    return synthetic.make_problem(d=40, n_signal=5)


def test_suff_stats_consistency(problem):
    x, y = synthetic.sample_two_class(jax.random.PRNGKey(0), problem, 4000, 4000)
    stats = slda.suff_stats(x, y)
    assert float(jnp.max(jnp.abs(stats.sigma - problem.sigma))) < 0.15
    assert float(jnp.max(jnp.abs(stats.mu1 - problem.mu1))) < 0.1
    assert float(jnp.max(jnp.abs(stats.mu2 - problem.mu2))) < 0.1
    # kernel (interpret) path vs jnp path agree
    stats2 = slda.suff_stats(x, y, use_kernel=True)
    np.testing.assert_allclose(stats.sigma, stats2.sigma, rtol=1e-4, atol=1e-4)


def test_clime_recovers_precision(problem):
    x, y = synthetic.sample_two_class(jax.random.PRNGKey(1), problem, 2000, 2000)
    stats = slda.suff_stats(x, y)
    lam = 0.25 * math.sqrt(math.log(40) / 4000) * 4
    theta = solve_clime(stats.sigma, lam, CFG)
    theta = symmetrize_min(theta)
    err = float(jnp.max(jnp.abs(theta - problem.theta)))
    # AR(1) precision is tridiagonal with entries up to ~2.8
    assert err < 0.8
    # near-inverse: Sigma Theta ~ I
    resid = float(jnp.max(jnp.abs(stats.sigma @ theta - jnp.eye(40))))
    assert resid < 0.3


def test_clime_columns_match_full(problem):
    x, y = synthetic.sample_two_class(jax.random.PRNGKey(2), problem, 500, 500)
    stats = slda.suff_stats(x, y)
    lam = 0.1
    full = solve_clime(stats.sigma, lam, CFG)
    cols = jnp.asarray([0, 7, 13])
    block = solve_clime_columns(stats.sigma, cols, lam, CFG)
    # adaptive-rho trajectories differ slightly with batch composition;
    # both solutions are converged to ~1e-5, so compare at solver tol.
    np.testing.assert_allclose(block, full[:, cols], atol=1e-4)


def test_debias_reduces_error_after_averaging(problem):
    """The paper's core claim: debiased averaging beats naive averaging."""
    from repro.core.distributed import (
        simulated_distributed_slda,
        simulated_naive_averaged_slda,
    )

    d = 40
    m, n1, n2 = 4, 150, 150
    N = m * (n1 + n2)
    xs, ys = synthetic.sample_machines(jax.random.PRNGKey(3), problem, m, n1, n2)
    b1 = float(jnp.sum(jnp.abs(problem.beta_star)))
    lam = 0.35 * math.sqrt(math.log(d) / (n1 + n2)) * b1
    t = 0.5 * math.sqrt(math.log(d) / N) * b1
    dist = simulated_distributed_slda(xs, ys, lam, lam, t, CFG)
    naive = simulated_naive_averaged_slda(xs, ys, lam, CFG)
    e_dist = float(classifier.estimation_errors(dist, problem.beta_star)["l2"])
    e_naive = float(classifier.estimation_errors(naive, problem.beta_star)["l2"])
    assert e_dist < e_naive


def test_hard_threshold():
    beta = jnp.asarray([0.5, -0.01, 0.0, -2.0, 0.09])
    out = slda.hard_threshold(beta, 0.1)
    np.testing.assert_allclose(np.asarray(out), [0.5, 0.0, 0.0, -2.0, 0.0])


def test_classifier_accuracy(problem):
    x, y = synthetic.sample_two_class(jax.random.PRNGKey(4), problem, 1000, 1000)
    beta = slda.centralized_slda(x, y, 0.15, CFG)
    z, labels = synthetic.sample_labeled(jax.random.PRNGKey(5), problem, 2000)
    rate = float(classifier.misclassification_rate(
        z, labels, beta, jnp.mean(x, 0), jnp.mean(y, 0)))
    # Bayes error for this problem is low; estimated rule must be decent
    assert rate < 0.2


def test_f1_score_extremes():
    beta_star = jnp.asarray([1.0, 0, 0, 2.0, 0])
    assert float(classifier.f1_score(beta_star, beta_star)) == 1.0
    assert float(classifier.f1_score(jnp.zeros(5), beta_star)) == 0.0


# ---------------------------------------------------------------------------
# eq. 3.3 symmetrization wiring (PR 5 bugfix: exported but never applied)
# ---------------------------------------------------------------------------


def test_symmetrize_flag_applies_eq33_to_the_debias(problem):
    """The estimator-path flag debiases with EXACTLY symmetrize_min of
    the raw column solves (eq. 3.3), and the default keeps the raw
    Theta bit-for-bit (the golden-pin mode)."""
    from repro.core import pipeline
    from repro.core.pipeline import BinaryHead

    x, y = synthetic.sample_two_class(jax.random.PRNGKey(7), problem, 300, 300)
    cfg = DantzigConfig(max_iters=400)
    lam, lam_p = 0.2, 0.25
    ws_raw = pipeline.worker_solves(
        BinaryHead(), x, y, lam=lam, lam_prime=lam_p, cfg=cfg)
    ws_sym = pipeline.worker_solves(
        BinaryHead(), x, y, lam=lam, lam_prime=lam_p, cfg=cfg,
        symmetrize=True)
    # the flag changes Theta exactly as eq. 3.3 specifies
    np.testing.assert_array_equal(
        np.asarray(ws_sym.theta), np.asarray(symmetrize_min(ws_raw.theta)))
    assert float(jnp.max(jnp.abs(ws_sym.theta - ws_raw.theta))) > 0
    # symmetrized Theta is symmetric; the raw solve is not
    np.testing.assert_array_equal(
        np.asarray(ws_sym.theta), np.asarray(ws_sym.theta.T))
    # and it propagates into the debiased estimate through the faces
    bt_raw, bh = slda.debiased_local_estimator(x, y, lam, lam_p, cfg)
    bt_sym, bh2 = slda.debiased_local_estimator(
        x, y, lam, lam_p, cfg, symmetrize=True)
    np.testing.assert_array_equal(np.asarray(bh), np.asarray(bh2))
    expected = slda.debias(
        slda.suff_stats(x, y), bh, symmetrize_min(ws_raw.theta))
    np.testing.assert_allclose(np.asarray(bt_sym), np.asarray(expected),
                               atol=1e-5)
    assert float(jnp.max(jnp.abs(bt_sym - bt_raw))) > 0


def test_symmetrize_flag_on_lambda_path_face(problem):
    """The folded sweep debiases every grid point with the symmetrized
    Theta when asked; default unchanged."""
    x, y = synthetic.sample_two_class(jax.random.PRNGKey(8), problem, 200, 200)
    cfg = DantzigConfig(max_iters=200, adapt_rho=False, fused=True)
    lams = jnp.linspace(0.1, 0.4, 3)
    res_raw = slda.debiased_local_estimator_path(x, y, lams, 0.2, cfg)
    res_sym = slda.debiased_local_estimator_path(
        x, y, lams, 0.2, cfg, symmetrize=True)
    # biased estimates identical, debiased ones move at every lambda
    np.testing.assert_array_equal(
        np.asarray(res_raw.beta_hat), np.asarray(res_sym.beta_hat))
    for i in range(3):
        assert float(jnp.max(jnp.abs(
            res_sym.beta_tilde[i] - res_raw.beta_tilde[i]))) > 0


def test_symmetrize_rejected_on_sharded_path(problem):
    """The model-axis-sharded path cannot symmetrize without an extra
    (d, d) gather -- the flag raises instead of silently skipping."""
    from repro.core import pipeline
    from repro.core.pipeline import BinaryHead

    x, y = synthetic.sample_two_class(jax.random.PRNGKey(9), problem, 50, 50)
    with pytest.raises(ValueError, match="model_axis=None"):
        pipeline.worker_solves(
            BinaryHead(), x, y, lam=0.2, lam_prime=0.2,
            model_axis="model", model_axis_size=2, symmetrize=True)


def test_solve_clime_symmetrize_param(problem):
    x, y = synthetic.sample_two_class(jax.random.PRNGKey(10), problem, 400, 400)
    stats = slda.suff_stats(x, y)
    cfg = DantzigConfig(max_iters=300)
    raw = solve_clime(stats.sigma, 0.1, cfg)
    sym = solve_clime(stats.sigma, 0.1, cfg, symmetrize=True)
    np.testing.assert_array_equal(np.asarray(sym),
                                  np.asarray(symmetrize_min(raw)))
