"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

Kernels run under interpret=True on CPU (the kernel body itself is
executed); on a TPU host the same tests exercise the Mosaic path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.gram import gram_pallas
from repro.kernels.soft_threshold import soft_threshold_pallas


@pytest.mark.parametrize("n,d", [(8, 8), (32, 16), (100, 50), (257, 130), (64, 200)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_matches_ref(n, d, dtype):
    key = jax.random.PRNGKey(n * 1000 + d)
    x = jax.random.normal(key, (n, d)).astype(dtype)
    mu = jnp.mean(x.astype(jnp.float32), axis=0).astype(dtype)
    out = gram_pallas(x, mu, block_n=32, block_d=16, interpret=True)
    expected = ref.gram_ref(x.astype(jnp.float32), mu.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 0.35
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("blocks", [(8, 8), (16, 64), (128, 128)])
def test_gram_block_shapes(blocks):
    bn, bd = blocks
    x = jax.random.normal(jax.random.PRNGKey(0), (48, 24))
    mu = jnp.mean(x, axis=0)
    out = gram_pallas(x, mu, block_n=bn, block_d=bd, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.gram_ref(x, mu)),
                               rtol=1e-4, atol=1e-3)


def test_gram_padding_rows_are_neutral():
    # n not a multiple of block: padded rows must contribute zero
    x = jax.random.normal(jax.random.PRNGKey(1), (13, 8))
    mu = jnp.mean(x, axis=0)
    out = gram_pallas(x, mu, block_n=8, block_d=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.gram_ref(x, mu)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(16,), (7,), (4, 36), (130, 600), (1, 1)])
@pytest.mark.parametrize("t", [0.0, 0.05, 1.5])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_soft_threshold_matches_ref(shape, t, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(42), shape) * 2).astype(dtype)
    out = soft_threshold_pallas(x, t, block_r=8, block_c=16, interpret=True)
    expected = ref.soft_threshold_ref(x, jnp.asarray(t, dtype))
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expected, np.float32),
        rtol=1e-3, atol=1e-3,
    )


def test_soft_threshold_in_solver_path():
    """The kernel-enabled Dantzig solve agrees with the jnp path."""
    from repro.core.dantzig import DantzigConfig, solve_dantzig
    from repro.stats.synthetic import ar1_covariance

    d = 24
    a = jnp.asarray(ar1_covariance(d, 0.6), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(7), (d,))
    x_plain = solve_dantzig(a, b, 0.1, DantzigConfig(max_iters=300, use_kernel=False))
    x_kern = solve_dantzig(a, b, 0.1, DantzigConfig(max_iters=300, use_kernel=True))
    np.testing.assert_allclose(np.asarray(x_plain), np.asarray(x_kern),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# fused Dantzig/CLIME ADMM solve (SSPerf-A2)
# ---------------------------------------------------------------------------

from repro.core.dantzig import DantzigConfig, kkt_violation, solve_dantzig  # noqa: E402
from repro.kernels import ops  # noqa: E402
from repro.kernels.dantzig_fused import dantzig_fused_pallas  # noqa: E402
from repro.stats.synthetic import ar1_covariance  # noqa: E402


@pytest.mark.parametrize("d,k,iters", [(16, 1, 50), (64, 4, 200), (40, 16, 120),
                                       (128, 8, 80)])
def test_dantzig_fused_matches_oracle(d, k, iters):
    a = jnp.asarray(ar1_covariance(d, 0.7), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(d + k), (d, k))
    lam = 0.1
    evals, q = jnp.linalg.eigh(a)
    inv = 1.0 / (evals**2 + 1.0)
    out_k = dantzig_fused_pallas(a, q, inv, b, lam, iters=iters, interpret=True)
    out_r = ref.dantzig_fused_ref(a, q, inv, b, lam, iters=iters)
    # f32 accumulation-order drift grows with iteration count
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=5e-4 * (iters / 50), rtol=1e-3)


def test_dantzig_fused_matches_scan_solver():
    """The kernel and the lax.scan solver share hyperparams -> same sol."""
    d = 48
    a = jnp.asarray(ar1_covariance(d, 0.8), jnp.float32)
    # realistic CLIME right-hand sides (unit vectors) -- bounded solutions
    b = jnp.eye(d)[:, ::12]
    lam = 0.08
    out_k = ops.dantzig_fused(a, b, lam, iters=300)
    out_s = solve_dantzig(a, b, lam, DantzigConfig(max_iters=300, adapt_rho=False))
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_s),
                               atol=5e-3, rtol=5e-3)
    # and both are near-feasible
    assert float(jnp.max(kkt_violation(a, b, out_k, lam))) < 0.05


def test_dantzig_fused_single_rhs_squeeze():
    d = 32
    a = jnp.asarray(ar1_covariance(d, 0.5), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(4), (d,))
    out = ops.dantzig_fused(a, b, 0.2, iters=200)
    assert out.shape == (d,)
    assert float(jnp.max(kkt_violation(a, b, out, 0.2))) < 0.02
