"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

Kernels run under interpret=True on CPU (the kernel body itself is
executed); on a TPU host the same tests exercise the Mosaic path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.gram import gram_pallas
from repro.kernels.soft_threshold import soft_threshold_pallas


@pytest.mark.parametrize("n,d", [(8, 8), (32, 16), (100, 50), (257, 130), (64, 200)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_matches_ref(n, d, dtype):
    key = jax.random.PRNGKey(n * 1000 + d)
    x = jax.random.normal(key, (n, d)).astype(dtype)
    mu = jnp.mean(x.astype(jnp.float32), axis=0).astype(dtype)
    out = gram_pallas(x, mu, block_n=32, block_d=16, interpret=True)
    expected = ref.gram_ref(x.astype(jnp.float32), mu.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 0.35
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("blocks", [(8, 8), (16, 64), (128, 128)])
def test_gram_block_shapes(blocks):
    bn, bd = blocks
    x = jax.random.normal(jax.random.PRNGKey(0), (48, 24))
    mu = jnp.mean(x, axis=0)
    out = gram_pallas(x, mu, block_n=bn, block_d=bd, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.gram_ref(x, mu)),
                               rtol=1e-4, atol=1e-3)


def test_gram_padding_rows_are_neutral():
    # n not a multiple of block: padded rows must contribute zero
    x = jax.random.normal(jax.random.PRNGKey(1), (13, 8))
    mu = jnp.mean(x, axis=0)
    out = gram_pallas(x, mu, block_n=8, block_d=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.gram_ref(x, mu)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(16,), (7,), (4, 36), (130, 600), (1, 1)])
@pytest.mark.parametrize("t", [0.0, 0.05, 1.5])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_soft_threshold_matches_ref(shape, t, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(42), shape) * 2).astype(dtype)
    out = soft_threshold_pallas(x, t, block_r=8, block_c=16, interpret=True)
    expected = ref.soft_threshold_ref(x, jnp.asarray(t, dtype))
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expected, np.float32),
        rtol=1e-3, atol=1e-3,
    )


def test_soft_threshold_in_solver_path():
    """The kernel-enabled Dantzig solve agrees with the jnp path."""
    from repro.core.dantzig import DantzigConfig, solve_dantzig
    from repro.stats.synthetic import ar1_covariance

    d = 24
    a = jnp.asarray(ar1_covariance(d, 0.6), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(7), (d,))
    x_plain = solve_dantzig(a, b, 0.1, DantzigConfig(max_iters=300, use_kernel=False))
    x_kern = solve_dantzig(a, b, 0.1, DantzigConfig(max_iters=300, use_kernel=True))
    np.testing.assert_allclose(np.asarray(x_plain), np.asarray(x_kern),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# fused Dantzig/CLIME ADMM solve (SSPerf-A2)
# ---------------------------------------------------------------------------

from repro.core.dantzig import DantzigConfig, kkt_violation, solve_dantzig  # noqa: E402
from repro.kernels import ops  # noqa: E402
from repro.kernels.dantzig_fused import dantzig_fused_pallas  # noqa: E402
from repro.stats.synthetic import ar1_covariance  # noqa: E402


@pytest.mark.parametrize("d,k,iters", [(16, 1, 50), (64, 4, 200), (40, 16, 120),
                                       (128, 8, 80)])
def test_dantzig_fused_matches_oracle(d, k, iters):
    a = jnp.asarray(ar1_covariance(d, 0.7), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(d + k), (d, k))
    lam = 0.1
    evals, q = jnp.linalg.eigh(a)
    inv = 1.0 / (evals**2 + 1.0)
    out_k = dantzig_fused_pallas(a, q, inv, b, lam, iters=iters, interpret=True)
    out_r = ref.dantzig_fused_ref(a, q, inv, b, lam, iters=iters)
    # f32 accumulation-order drift grows with iteration count
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=5e-4 * (iters / 50), rtol=1e-3)


def test_dantzig_fused_matches_scan_solver():
    """The kernel and the lax.scan solver share hyperparams -> same sol."""
    d = 48
    a = jnp.asarray(ar1_covariance(d, 0.8), jnp.float32)
    # realistic CLIME right-hand sides (unit vectors) -- bounded solutions
    b = jnp.eye(d)[:, ::12]
    lam = 0.08
    out_k = ops.dantzig_fused(a, b, lam, iters=300)
    out_s = solve_dantzig(a, b, lam, DantzigConfig(max_iters=300, adapt_rho=False))
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_s),
                               atol=5e-3, rtol=5e-3)
    # and both are near-feasible
    assert float(jnp.max(kkt_violation(a, b, out_k, lam))) < 0.05


def test_dantzig_fused_single_rhs_squeeze():
    d = 32
    a = jnp.asarray(ar1_covariance(d, 0.5), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(4), (d,))
    out = ops.dantzig_fused(a, b, 0.2, iters=200)
    assert out.shape == (d,)
    assert float(jnp.max(kkt_violation(a, b, out, 0.2))) < 0.02


# ---------------------------------------------------------------------------
# blocked grid: fused-vs-scan parity sweep (incl. non-multiple tail block)
# ---------------------------------------------------------------------------

from repro.core.solver_dispatch import select_solver  # noqa: E402
from repro.kernels.dantzig_fused import (  # noqa: E402
    fused_block_vmem_bytes, pick_block_k,
)


def _scan_reference(a, b, lam, iters):
    """Scan solver with the fused kernel's hyperparams (fixed rho=1)."""
    return solve_dantzig(a, b, lam,
                         DantzigConfig(max_iters=iters, adapt_rho=False))


@pytest.mark.parametrize("d,k", [(64, 1), (256, 64), (300, 7)])
def test_fused_blocked_parity_sweep(d, k):
    """Fused (auto-blocked) matches scan to 1e-4 max-abs on any shape."""
    a = jnp.asarray(ar1_covariance(d, 0.6), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(d * 31 + k), (d, k)) * 0.5
    lam, iters = 0.1, 200
    out_f = ops.dantzig_fused(a, b, lam, iters=iters)
    out_s = _scan_reference(a, b, lam, iters)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_s), atol=1e-4)
    # both near-feasible: the fused path obeys the same KKT bound
    kkt_f = float(jnp.max(kkt_violation(a, b, out_f, lam)))
    kkt_s = float(jnp.max(kkt_violation(a, b, out_s, lam)))
    assert kkt_f < max(2 * kkt_s, 5e-2)


def test_fused_explicit_blocking_with_tail_is_exact():
    """Forcing a tail block (k % block_k != 0) changes nothing: columns
    are independent and the pad columns are inert."""
    d, k = 48, 10
    a = jnp.asarray(ar1_covariance(d, 0.7), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(5), (d, k))
    one_block = ops.dantzig_fused(a, b, 0.1, iters=150)
    tail_blocked = ops.dantzig_fused(a, b, 0.1, iters=150, block_k=4)
    # bitwise under the interpreter; Mosaic may differ in the last ulp
    np.testing.assert_allclose(np.asarray(one_block), np.asarray(tail_blocked),
                               atol=1e-6, rtol=0)


def test_fused_blocked_past_single_block_vmem():
    """A shape whose single-block footprint exceeds 16 MB still matches
    the scan solver once the dispatch tiles it over the grid."""
    d, k, iters = 768, 512, 25
    assert fused_block_vmem_bytes(d, k) > 16 * 10**6
    bk = pick_block_k(d, k)
    assert bk is not None and bk < k  # must be tiled
    assert fused_block_vmem_bytes(d, bk) <= 12 * 2**20
    choice = select_solver(DantzigConfig(fused=True), d, k)
    assert choice.kind == "fused_blocked" and choice.block_k == bk
    a = jnp.asarray(ar1_covariance(d, 0.5), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(6), (d, k)) * 0.3
    out_f = ops.dantzig_fused(a, b, 0.15, iters=iters)
    out_s = _scan_reference(a, b, 0.15, iters)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_s), atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_output_dtype_matches_rhs(dtype):
    """ops.dantzig_fused returns b.dtype (it used to pin float32)."""
    d = 32
    a = jnp.asarray(ar1_covariance(d, 0.5), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(7), (d, 3)).astype(dtype)
    out = ops.dantzig_fused(a, b, 0.1, iters=100)
    assert out.dtype == dtype
    if dtype == jnp.float32:
        # parity with the scan path, which also returns f32 here
        out_s = _scan_reference(a, b, 0.1, 100)
        assert out_s.dtype == out.dtype
        np.testing.assert_allclose(np.asarray(out), np.asarray(out_s), atol=1e-4)
    else:
        # values agree with the f32 solve up to bf16 resolution
        out32 = ops.dantzig_fused(a, b.astype(jnp.float32), 0.1, iters=100)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(out32), atol=2e-2)


def test_fused_per_column_rho_operand():
    """rho is a (k,) operand: per-column values match the oracle and a
    second rho value reuses the compiled kernel (no retrace)."""
    d, k = 40, 6
    a = jnp.asarray(ar1_covariance(d, 0.6), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(8), (d, k))
    evals, q = jnp.linalg.eigh(a)
    inv = 1.0 / (evals**2 + 1.0)
    rhos = jnp.linspace(0.5, 2.0, k)
    out = dantzig_fused_pallas(a, q, inv, b, 0.1, rhos, iters=120,
                               block_k=4, interpret=True)
    out_ref = ref.dantzig_fused_ref(a, q, inv, b, 0.1, rho=rhos, iters=120)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               atol=2e-4, rtol=1e-3)
    n_compiled = dantzig_fused_pallas._cache_size()
    dantzig_fused_pallas(a, q, inv, b, 0.1, rhos * 1.5, iters=120,
                         block_k=4, interpret=True)
    assert dantzig_fused_pallas._cache_size() == n_compiled


# ---------------------------------------------------------------------------
# trace pins via repro.analysis: launch count + VMEM conformance
# ---------------------------------------------------------------------------

from repro.analysis import VmemConformance, count_eqns  # noqa: E402


def test_fused_blocked_trace_conforms_to_vmem_model():
    """The traced BlockMappings of a tiled launch satisfy the analytic
    footprint model -- and a deliberately tiny budget trips the contract
    with the offending launch located in the report."""
    d, k = 48, 10
    a = jnp.asarray(ar1_covariance(d, 0.7), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(9), (d, k))
    jaxpr = jax.make_jaxpr(
        lambda a, b: ops.dantzig_fused(a, b, 0.1, iters=50, block_k=4))(a, b)
    assert count_eqns(jaxpr, "pallas_call") == 1
    assert VmemConformance().check(jaxpr) == []
    violations = VmemConformance(budget=1024).check(jaxpr)
    assert violations, "1 KiB budget must trip the conformance contract"
    assert any("pallas_call" in site for v in violations for site in v.sites)


def test_tol_mode_state_kernel_trace_conforms_to_vmem_model():
    """tol-mode launches the state-I/O kernel (10 operands): the checker
    must pick up state_io=True and still conform."""
    d, k = 32, 6
    a = jnp.asarray(ar1_covariance(d, 0.5), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(10), (d, k))
    cfg = DantzigConfig(max_iters=60, adapt_rho=False, fused=True, tol=1e-3)
    from repro.core.solver_dispatch import solve_dantzig_full

    jaxpr = jax.make_jaxpr(
        lambda a, b: solve_dantzig_full(a, b, 0.1, cfg))(a, b)
    assert count_eqns(jaxpr, "pallas_call") == 1
    assert VmemConformance().check(jaxpr) == []
