"""CI-gate skip semantics: skips are NOTICES, never silent passes.

The gate declines to measure things for legitimate reasons (no
committed baseline in git, cross-host timings, a benchmark that wasn't
run) -- but every such decline must land in the machine-readable skip
tally that ``main()`` prints, with non-zero exit reserved for real
failures.  A missing committed baseline that produced neither a
failure nor a notice would be a silent pass: the exact bug these tests
pin against.  Pure-python (no jax): reads ``benchmarks.ci_gate``
directly against synthetic payloads.
"""

from __future__ import annotations

import json

import benchmarks.ci_gate as cg


def _fault_gate(**kw):
    g = dict(d=100, m=60, rounds=3, dropout=0.1,
             rec_nofault=0.54, rec_masked=0.57, rec_unmasked=0.38,
             f1_nofault=1.0, f1_masked=1.0, f1_unmasked=1.0,
             rec_slack=0.10, f1_slack=0.02)
    g.update(kw)
    return {"faults": g}


def test_missing_committed_baseline_is_notice_not_silent_pass(
        monkeypatch, capsys):
    monkeypatch.setattr(cg, "_committed_baseline", lambda name: None)
    cg.SKIP_NOTICES.clear()
    failures: list = []
    cg._gate_faults(_fault_gate(), failures)
    assert failures == []
    notices = [n for n in cg.SKIP_NOTICES if n["name"] == "fault_rounds"]
    assert notices and "baseline" in notices[0]["reason"]
    # and the notice is printed, not just recorded
    assert "[ci_gate] SKIP fault_rounds" in capsys.readouterr().out


def test_fault_gate_fails_when_masked_recovery_degrades(monkeypatch):
    monkeypatch.setattr(cg, "_committed_baseline", lambda name: None)
    cg.SKIP_NOTICES.clear()
    failures: list = []
    cg._gate_faults(_fault_gate(rec_masked=0.30), failures)
    assert any("below the no-fault" in f for f in failures)


def test_fault_gate_fails_when_unmasked_does_not_degrade(monkeypatch):
    """A fault layer whose fragile baseline doesn't degrade proves the
    injection isn't biting -- that's a failure, not a pass."""
    monkeypatch.setattr(cg, "_committed_baseline", lambda name: None)
    cg.SKIP_NOTICES.clear()
    failures: list = []
    cg._gate_faults(_fault_gate(rec_unmasked=0.54), failures)
    assert any("not biting" in f for f in failures)


def test_fault_gate_cross_pr_f1_drift_fails(monkeypatch):
    base = _fault_gate()
    base["generated_unix"] = 1  # volatile keys must be stripped
    base["host"] = "elsewhere"
    monkeypatch.setattr(cg, "_committed_baseline",
                        lambda name: dict(base, _baseline_ref="HEAD"))
    cg.SKIP_NOTICES.clear()
    failures: list = []
    cg._gate_faults(_fault_gate(f1_masked=0.90), failures)
    assert any("drifted" in f for f in failures)


def test_fault_gate_operating_point_change_skips_cross_pr(monkeypatch):
    base = _fault_gate(m=80)  # baseline recorded at a different point
    monkeypatch.setattr(cg, "_committed_baseline",
                        lambda name: dict(base, _baseline_ref="HEAD"))
    cg.SKIP_NOTICES.clear()
    failures: list = []
    cg._gate_faults(_fault_gate(), failures)
    assert failures == []
    assert any(n["name"] == "fault_rounds"
               and "operating point" in n["reason"]
               for n in cg.SKIP_NOTICES)


def test_main_emits_machine_readable_skip_tally(
        monkeypatch, tmp_path, capsys):
    """main() with only fused_solver present: every other benchmark
    skips with a notice, the tally line parses as JSON with a count,
    and the exit stays zero (skips never flip it)."""
    fused = {"rows": [{"d": 8, "k": 2, "L": 1, "max_abs_diff": 0.0}]}
    (tmp_path / "BENCH_fused_solver.json").write_text(json.dumps(fused))
    monkeypatch.setattr(cg, "bench_json_path",
                        lambda name: str(tmp_path / f"BENCH_{name}.json"))
    monkeypatch.setattr(cg, "_committed_baseline", lambda name: None)
    rc = cg.main()
    out = capsys.readouterr().out
    lines = [ln for ln in out.splitlines()
             if ln.startswith("[ci_gate] skips ")]
    assert len(lines) == 1
    tally = json.loads(lines[0][len("[ci_gate] skips "):])
    assert rc == 0
    assert tally["count"] == len(tally["notices"]) == len(cg.GATED)
    names = {n["name"] for n in tally["notices"]}
    # 5 missing-file skips + the fused_solver wall-clock baseline skip
    assert names == set(cg.GATED)


def test_main_fails_closed_when_fused_solver_missing(
        monkeypatch, tmp_path, capsys):
    """The anchor benchmark is NOT skippable: its absence is a failure,
    and the skip tally still prints for the rest."""
    monkeypatch.setattr(cg, "bench_json_path",
                        lambda name: str(tmp_path / f"BENCH_{name}.json"))
    monkeypatch.setattr(cg, "_committed_baseline", lambda name: None)
    rc = cg.main()
    out = capsys.readouterr().out
    assert rc == 1
    assert any(ln.startswith("[ci_gate] skips ")
               for ln in out.splitlines())
