"""Model-component reference tests: each block vs a naive implementation.

These are block-level (not full-model) checks: blockwise attention vs
materialized softmax, Mamba chunked scan vs per-step recurrence, xLSTM
chunkwise vs sequential, MoE dispatch vs dense mixture.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import mamba, moe, xlstm
from repro.models.blockwise_attn import blockwise_attention
from repro.models.common import ArchConfig


# ---------------------------------------------------------------------------
# blockwise attention vs naive materialized softmax
# ---------------------------------------------------------------------------


def _naive_attention(q, k, v, causal, sliding_window=0):
    b, s, kvh, g, hd = q.shape
    t = k.shape[1]
    scores = jnp.einsum("bqkgh,btkh->bkgqt", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kpos <= qpos
    if sliding_window:
        mask &= kpos > qpos - sliding_window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqt,btkh->bkgqh", w.astype(v.dtype), v)
    return jnp.transpose(out, (0, 3, 1, 2, 4)).astype(q.dtype)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 8), (False, 0)])
@pytest.mark.parametrize("s,t,qc,kc", [(32, 32, 8, 16), (64, 64, 16, 8),
                                       (16, 48, 16, 16)])
def test_blockwise_attention_matches_naive(causal, window, s, t, qc, kc):
    if causal and s != t:
        pytest.skip("causal assumes square")
    b, kvh, g, hd = 2, 2, 2, 16
    key = jax.random.PRNGKey(s * 100 + t)
    q = jax.random.normal(key, (b, s, kvh, g, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, t, kvh, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, t, kvh, hd))
    out = blockwise_attention(q, k, v, causal=causal, sliding_window=window,
                              q_chunk=qc, k_chunk=kc)
    ref = _naive_attention(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_blockwise_chunk_invariance():
    b, s, kvh, g, hd = 1, 64, 1, 2, 8
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, kvh, g, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kvh, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kvh, hd))
    outs = [
        blockwise_attention(q, k, v, causal=True, q_chunk=qc, k_chunk=kc)
        for qc, kc in [(8, 8), (16, 32), (64, 64)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# Mamba: chunked associative scan vs naive per-step recurrence
# ---------------------------------------------------------------------------


def _mamba_cfg(chunk=8):
    return dataclasses.replace(
        configs.smoke_config(configs.get_config("jamba-v0.1-52b")),
        ssm_chunk=chunk,
    )


def test_mamba_chunk_invariance():
    cfg8 = _mamba_cfg(8)
    cfg32 = _mamba_cfg(32)
    p = mamba.init_mamba(jax.random.PRNGKey(0), cfg8, jnp.float32)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg8.d_model))
    y8 = mamba.mamba_train(p, x, cfg8)
    y32 = mamba.mamba_train(p, x, cfg32)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32),
                               atol=1e-4, rtol=1e-4)


def test_mamba_train_matches_decode_recurrence():
    cfg = _mamba_cfg(8)
    p = mamba.init_mamba(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 2, 16
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model))
    y_train = mamba.mamba_train(p, x, cfg)
    state = mamba.init_state(cfg, b, jnp.float32)
    outs = []
    for t in range(s):
        y_t, state = mamba.mamba_decode(p, x[:, t : t + 1], state, cfg)
        outs.append(y_t[:, 0])
    y_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_train),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# xLSTM: chunkwise mLSTM vs sequential decode; sLSTM train vs decode
# ---------------------------------------------------------------------------


def _xlstm_cfg(chunk=8):
    return dataclasses.replace(
        configs.smoke_config(configs.get_config("xlstm-1.3b")), ssm_chunk=chunk
    )


def test_mlstm_chunk_invariance():
    cfg4, cfg16 = _xlstm_cfg(4), _xlstm_cfg(16)
    p = xlstm.init_mlstm(jax.random.PRNGKey(0), cfg4, jnp.float32)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg4.d_model))
    y4 = xlstm.mlstm_train(p, x, cfg4)
    y16 = xlstm.mlstm_train(p, x, cfg16)
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y16),
                               atol=2e-4, rtol=2e-3)


def test_mlstm_train_matches_decode():
    cfg = _xlstm_cfg(8)
    p = xlstm.init_mlstm(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 2, 16
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model))
    y_train = xlstm.mlstm_train(p, x, cfg)
    state = xlstm.init_mlstm_state(cfg, b)
    outs = []
    for t in range(s):
        y_t, state = xlstm.mlstm_decode(p, x[:, t : t + 1], state, cfg)
        outs.append(y_t[:, 0])
    y_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_train),
                               atol=5e-4, rtol=5e-3)


def test_slstm_train_matches_decode():
    cfg = _xlstm_cfg()
    p = xlstm.init_slstm(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 2, 12
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model))
    y_train = xlstm.slstm_train(p, x, cfg)
    state = xlstm.init_slstm_state(cfg, b)
    outs = []
    for t in range(s):
        y_t, state = xlstm.slstm_decode(p, x[:, t : t + 1], state, cfg)
        outs.append(y_t[:, 0])
    y_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_train),
                               atol=2e-4, rtol=2e-3)


# ---------------------------------------------------------------------------
# MoE: dispatch vs dense mixture; router invariants
# ---------------------------------------------------------------------------


def _moe_cfg(**kw):
    base = configs.smoke_config(configs.get_config("phi3.5-moe-42b-a6.6b"))
    return dataclasses.replace(base, **kw)


def _dense_moe_ref(p, x, cfg):
    """Naive reference: every token runs its top-k experts, no capacity."""
    b, s, d = x.shape
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    # run every expert densely
    gate = jnp.einsum("bsd,edf->besf", x, p["w_gate"])
    up = jnp.einsum("bsd,edf->besf", x, p["w_up"])
    h = jax.nn.silu(gate) * up
    y_all = jnp.einsum("besf,efd->besd", h, p["w_down"])  # (b, e, s, d)
    y = jnp.zeros_like(x)
    for j in range(cfg.experts_per_token):
        w = gate_vals[..., j]  # (b, s)
        idx = gate_idx[..., j]  # (b, s)
        sel = jnp.take_along_axis(y_all, idx[:, None, :, None], axis=1)[:, 0]
        y = y + sel * w[..., None].astype(y.dtype)
    if cfg.shared_expert:
        from repro.models import mlp as mlp_mod

        y = y + mlp_mod.mlp(p["shared"], x)
    return y


def test_moe_matches_dense_reference_when_capacity_ample():
    # capacity_factor high enough that nothing is dropped
    cfg = _moe_cfg(capacity_factor=8.0)
    p = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = moe.moe(p, x, cfg)
    ref = _dense_moe_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-4, rtol=1e-3)
    assert np.isfinite(float(aux["moe_lb_loss"]))
    assert float(aux["moe_z_loss"]) >= 0


def test_moe_capacity_drops_are_bounded():
    # tiny capacity: output must still be finite and not exceed the
    # dense mixture in magnitude (dropped tokens get zero, not garbage)
    cfg = _moe_cfg(capacity_factor=0.25)
    p = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    y, _ = moe.moe(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_moe_load_balance_loss_uniform_is_one():
    """With a perfectly uniform router, the Switch LB loss ~= k."""
    cfg = _moe_cfg(capacity_factor=8.0)
    p = moe.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    p = dict(p, router=jnp.zeros_like(p["router"]))  # uniform probs
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model))
    _, aux = moe.moe(p, x, cfg)
    e, k = cfg.num_experts, cfg.experts_per_token
    # me = 1/e; routed ~= k/e (ties broken arbitrarily but count is k)
    expected = e * (1.0 / e) * k
    np.testing.assert_allclose(float(aux["moe_lb_loss"]), expected, rtol=0.2)


# ---------------------------------------------------------------------------
# int8 KV cache (SSPerf-B3): quantized decode tracks the bf16 path
# ---------------------------------------------------------------------------


def test_int8_kv_cache_decode_close_to_fp():
    from repro.models import model_zoo

    cfg = configs.smoke_config(configs.get_config("granite-8b"))
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    model = model_zoo.build_model(cfg)
    model8 = model_zoo.build_model(cfg8)
    params = model.init(jax.random.PRNGKey(0))
    B, T = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, T), 0, cfg.vocab_size)
    state = model.init_decode_state(B, T)
    state8 = model8.init_decode_state(B, T)
    for t in range(T):
        lg, state = model.decode_step(params, state, toks[:, t : t + 1])
        lg8, state8 = model8.decode_step(params, state8, toks[:, t : t + 1])
    # quantization noise is bounded: top-1 next-token choice agrees and
    # logits stay close in the bulk
    a = np.asarray(lg[:, 0, : cfg.vocab_size], np.float32)
    b = np.asarray(lg8[:, 0, : cfg.vocab_size], np.float32)
    assert (a.argmax(-1) == b.argmax(-1)).all()
    # median absolute deviation small relative to the logit range
    mad = np.median(np.abs(a - b))
    rng = np.percentile(a, 95) - np.percentile(a, 5)
    assert mad < 0.05 * rng, (mad, rng)
