"""Warm-carry shape disambiguation in the lambda-path fold (PR 5 bugfix).

``path._fold_state`` used to misread a (d, k) single-solve state as an
(L, d) vector-sweep state whenever ``k == d == L``, and a 1-D ``rho``
silently resolved the ``L == k`` collision as per-lambda by fiat.  Both
are now explicit: ambiguous shapes raise, ``state_layout=`` /
2-D ``rho`` disambiguate, and (L, d, 1) is the always-unambiguous
vector-sweep layout.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import path as rpath
from repro.core.dantzig import AdmmState, DantzigConfig
from repro.stats.synthetic import ar1_covariance

CFG = DantzigConfig(max_iters=150, adapt_rho=False)


def _problem(d, k, seed=0):
    a = jnp.asarray(ar1_covariance(d, 0.5), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(seed), (d, k)) * 0.4
    return a, b


def _state(shape):
    return AdmmState(*(jnp.zeros(shape, jnp.float32) for _ in range(4)))


# ---------------------------------------------------------------------------
# the ambiguous square: L == d == k
# ---------------------------------------------------------------------------


def test_ambiguous_square_state_raises_and_layouts_disambiguate():
    d = L = k = 6
    a, b = _problem(d, k)
    lams = jnp.linspace(0.1, 0.4, L)
    ref = rpath.solve_dantzig_path(a, b, lams, CFG)

    with pytest.raises(ValueError, match="ambiguous"):
        rpath.solve_dantzig_path(a, b, lams, CFG, state=_state((d, k)))

    # zero states under either explicit layout == the cold solve
    for layout in ("single", "grid"):
        res = rpath.solve_dantzig_path(
            a, b, lams, CFG, state=_state((d, k)), state_layout=layout)
        np.testing.assert_allclose(
            np.asarray(res.beta), np.asarray(ref.beta), atol=1e-6)


def test_single_layout_folds_like_the_unambiguous_shape():
    """At L == d == k a real (d, k) single-solve carry must fold exactly
    as it does at an unambiguous geometry: warm-start the square sweep
    under state_layout='single' and compare against re-solving each
    grid point from the same single-solve state directly."""
    from repro.core.solver_dispatch import solve_dantzig_full

    d = L = k = 6
    a, b = _problem(d, k, seed=1)
    lams = jnp.linspace(0.1, 0.4, L)
    short = DantzigConfig(max_iters=40, adapt_rho=False)
    seed_state = solve_dantzig_full(a, b, 0.25, short).state

    warm = rpath.solve_dantzig_path(
        a, b, lams, short, state=seed_state, state_layout="single")
    for i in range(L):
        seq = solve_dantzig_full(
            a, b, float(lams[i]), short, state=seed_state)
        np.testing.assert_allclose(
            np.asarray(warm.beta[i]), np.asarray(seq.beta), atol=1e-5,
            err_msg=f"lambda[{i}]")


def test_grid_layout_folds_vector_sweep_carry():
    """(L, d) vector-sweep carry at L == d: state_layout='grid' reads it
    per-lambda; parity against the (L, d, 1) unambiguous layout."""
    d = L = 8
    a = jnp.asarray(ar1_covariance(d, 0.5), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(2), (d,)) * 0.4
    lams = jnp.linspace(0.1, 0.5, L)
    short = DantzigConfig(max_iters=40, adapt_rho=False)
    prev = rpath.solve_dantzig_path(a, b, lams, short)
    assert prev.state.z.shape == (L, d)

    via_kwarg = rpath.solve_dantzig_path(
        a, b, lams, short, state=prev.state, state_layout="grid")
    via_3d = rpath.solve_dantzig_path(
        a, b, lams, short,
        state=AdmmState(*(leaf[:, :, None] for leaf in prev.state)))
    np.testing.assert_allclose(
        np.asarray(via_kwarg.beta), np.asarray(via_3d.beta), atol=1e-6)


def test_unambiguous_shapes_still_infer():
    """Back-compat: when only one reading fits, auto inference holds."""
    d, k, L = 10, 3, 5
    a, b = _problem(d, k, seed=3)
    lams = jnp.linspace(0.1, 0.4, L)
    ref = rpath.solve_dantzig_path(a, b, lams, CFG)
    # (d, k) single solve and (L, d, k) grid carry both infer
    r1 = rpath.solve_dantzig_path(a, b, lams, CFG, state=_state((d, k)))
    r2 = rpath.solve_dantzig_path(a, b, lams, CFG, state=_state((L, d, k)))
    np.testing.assert_allclose(np.asarray(r1.beta), np.asarray(ref.beta),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(r2.beta), np.asarray(ref.beta),
                               atol=1e-6)


def test_mismatched_state_shapes_raise():
    d, k, L = 10, 3, 5
    a, b = _problem(d, k, seed=4)
    lams = jnp.linspace(0.1, 0.4, L)
    for bad in ((d + 1,), (d, k + 2), (L + 1, d, k), (d, k, L, 1)):
        with pytest.raises(ValueError):
            rpath.solve_dantzig_path(a, b, lams, CFG, state=_state(bad))
    with pytest.raises(ValueError, match="state_layout"):
        rpath.solve_dantzig_path(a, b, lams, CFG, state=_state((d, k)),
                                 state_layout="wide")


# ---------------------------------------------------------------------------
# the 1-D rho collision at L == k
# ---------------------------------------------------------------------------


def test_rho_collision_raises_and_2d_broadcasts_agree():
    d, L = 12, 4
    k = L
    a, b = _problem(d, k, seed=5)
    lams = jnp.linspace(0.1, 0.4, L)
    rho = jnp.linspace(0.5, 2.0, L)

    with pytest.raises(ValueError, match="ambiguous"):
        rpath.solve_dantzig_path(a, b, lams, CFG, rho=rho)

    # the two explicit readings are both accepted and genuinely differ
    per_lam = rpath.solve_dantzig_path(a, b, lams, CFG, rho=rho[:, None])
    per_col = rpath.solve_dantzig_path(a, b, lams, CFG, rho=rho[None, :])
    assert per_lam.beta.shape == per_col.beta.shape == (L, d, k)
    # rho changes the (finite-iteration) ADMM trajectory
    assert float(jnp.max(jnp.abs(per_lam.beta - per_col.beta))) > 0


def test_rho_1d_still_infers_when_unambiguous():
    d, k, L = 12, 2, 4
    a, b = _problem(d, k, seed=6)
    lams = jnp.linspace(0.1, 0.4, L)
    per_lam = rpath.solve_dantzig_path(a, b, lams, CFG,
                                       rho=jnp.linspace(0.5, 2.0, L))
    explicit = rpath.solve_dantzig_path(
        a, b, lams, CFG,
        rho=jnp.broadcast_to(jnp.linspace(0.5, 2.0, L)[:, None], (L, k)))
    np.testing.assert_allclose(np.asarray(per_lam.beta),
                               np.asarray(explicit.beta), atol=1e-6)
    per_col = rpath.solve_dantzig_path(a, b, lams, CFG,
                                       rho=jnp.asarray([0.8, 1.3]))
    assert per_col.beta.shape == (L, d, k)
    with pytest.raises(ValueError, match="matches neither"):
        rpath.solve_dantzig_path(a, b, lams, CFG, rho=jnp.ones(3))
