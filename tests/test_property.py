"""Hypothesis property tests on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is a dev-only dependency (requirements-dev.txt); without it
# the whole module must skip cleanly rather than abort collection.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402
import hypothesis.extra.numpy as hnp  # noqa: E402

from repro.core import slda
from repro.core.dantzig import DantzigConfig, kkt_violation, solve_dantzig
from repro.kernels import ref as kref


finite_f32 = lambda shape: hnp.arrays(
    np.float32, shape,
    elements=st.floats(-50, 50, width=32, allow_nan=False, allow_infinity=False),
)


@given(finite_f32((17,)), st.floats(0, 10))
@settings(max_examples=50, deadline=None)
def test_hard_threshold_properties(x, t):
    out = np.asarray(slda.hard_threshold(jnp.asarray(x), t))
    # idempotent
    out2 = np.asarray(slda.hard_threshold(jnp.asarray(out), t))
    np.testing.assert_array_equal(out, out2)
    # kept entries unchanged, zeroed entries were <= t
    kept = out != 0
    np.testing.assert_array_equal(out[kept], x[kept])
    assert np.all(np.abs(x[~kept]) <= t + 1e-6)
    # support never grows
    assert np.sum(out != 0) <= np.sum(x != 0)


@given(finite_f32((9, 5)))
@settings(max_examples=30, deadline=None)
def test_covariance_psd_and_shift_invariant(x):
    mu = x.mean(0)
    g = np.asarray(kref.gram_ref(jnp.asarray(x), jnp.asarray(mu)))
    # symmetric PSD
    np.testing.assert_allclose(g, g.T, atol=1e-3)
    evals = np.linalg.eigvalsh(g.astype(np.float64))
    assert evals.min() > -1e-2
    # shift invariance: adding a constant shifts the mean, not the Gram
    shift = np.float32(3.25)
    g2 = np.asarray(kref.gram_ref(jnp.asarray(x + shift), jnp.asarray(mu + shift)))
    np.testing.assert_allclose(g, g2, atol=2e-2, rtol=1e-4)


@given(finite_f32((8,)), st.floats(0.01, 5))
@settings(max_examples=50, deadline=None)
def test_soft_threshold_ref_properties(x, t):
    out = np.asarray(kref.soft_threshold_ref(jnp.asarray(x), t))
    # shrink by exactly t toward zero, never across
    assert np.all(np.abs(out) <= np.maximum(np.abs(x) - t, 0) + 1e-5)
    assert np.all(out * x >= -1e-6)  # sign preserved (or zero)
    # 1-Lipschitz w.r.t. input
    y = x + np.float32(0.1)
    outy = np.asarray(kref.soft_threshold_ref(jnp.asarray(y), t))
    assert np.all(np.abs(outy - out) <= 0.1 + 1e-5)


@given(st.integers(0, 2**31 - 1), st.floats(0.05, 0.5))
@settings(max_examples=10, deadline=None)
def test_dantzig_always_feasible(seed, lam):
    """Solver output satisfies the l_inf constraint for random PSD systems."""
    d = 12
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((d, d)).astype(np.float32)
    a = q @ q.T / d + 0.5 * np.eye(d, dtype=np.float32)
    b = rng.standard_normal(d).astype(np.float32)
    lam = np.float32(max(lam, 0.1 * np.abs(b).max()))
    x = solve_dantzig(jnp.asarray(a), jnp.asarray(b), float(lam),
                      DantzigConfig(max_iters=1200))
    assert np.isfinite(np.asarray(x)).all()
    assert float(kkt_violation(jnp.asarray(a), jnp.asarray(b), x, float(lam))) < 2e-2


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_debias_exact_when_theta_exact(seed):
    """With Theta = Sigma^{-1} exactly, debias yields the OLS-like fix:
    beta_tilde = beta_hat - Sigma^{-1}(Sigma beta_hat - mu_d)
              = Sigma^{-1} mu_d  (independent of beta_hat)."""
    d = 10
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((d, d)).astype(np.float32)
    sigma = q @ q.T / d + np.eye(d, dtype=np.float32)
    theta = np.linalg.inv(sigma.astype(np.float64)).astype(np.float32)
    mu_d = rng.standard_normal(d).astype(np.float32)
    beta_hat = rng.standard_normal(d).astype(np.float32)
    stats = slda.SuffStats(jnp.asarray(sigma), jnp.asarray(mu_d),
                           jnp.zeros(d), jnp.asarray(5), jnp.asarray(5))
    bt = slda.debias(stats, jnp.asarray(beta_hat), jnp.asarray(theta))
    target = np.linalg.solve(sigma.astype(np.float64), mu_d.astype(np.float64))
    np.testing.assert_allclose(np.asarray(bt), target, rtol=2e-2, atol=2e-2)


@given(finite_f32((3, 6, 4)), st.floats(0.1, 2))
@settings(max_examples=20, deadline=None)
def test_aggregate_of_identical_is_fixed_point(xs, t):
    """Averaging m identical debiased estimators == one estimator + HT."""
    one = jnp.asarray(xs[0, 0])
    stack = jnp.broadcast_to(one, (5, 4))
    agg = slda.aggregate(stack, t)
    np.testing.assert_allclose(
        np.asarray(agg), np.asarray(slda.hard_threshold(one, t)), atol=1e-6
    )


@given(st.integers(2, 5), st.integers(20, 40))
@settings(max_examples=10, deadline=None)
def test_mc_stats_match_binary_stats(num_classes, d):
    """mc_suff_stats at K=2 equals the paper's pooled two-class stats."""
    from repro.core.multiclass import mc_suff_stats
    from repro.core.slda import suff_stats

    n = 64
    key = jax.random.PRNGKey(num_classes * 100 + d)
    x = jax.random.normal(key, (n, d))
    y = jax.random.normal(jax.random.fold_in(key, 1), (n, d)) + 1.0
    stats2 = suff_stats(x, y)
    z = jnp.concatenate([x, y])
    labels = jnp.concatenate([jnp.zeros(n, jnp.int32), jnp.ones(n, jnp.int32)])
    statsK = mc_suff_stats(z, labels, 2)
    np.testing.assert_allclose(np.asarray(statsK.sigma), np.asarray(stats2.sigma),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(statsK.means[0]), np.asarray(stats2.mu1),
                               atol=1e-5)


@given(finite_f32((30, 3)), st.floats(0.01, 1.0))
@settings(max_examples=15, deadline=None)
def test_mc_classify_shift_invariant(beta_like, t):
    """Adding a constant to all scores never changes the argmax class."""
    from repro.core.multiclass import mc_classify

    d, K = 10, 3
    key = jax.random.PRNGKey(3)
    z = jax.random.normal(key, (8, d))
    beta = jnp.asarray(beta_like.reshape(-1)[: d * K].reshape(d, K)) * t
    means = jax.random.normal(jax.random.fold_in(key, 1), (K, d))
    pred1 = mc_classify(z, beta, means)
    pred2 = mc_classify(z + 0.0, beta, means)
    np.testing.assert_array_equal(np.asarray(pred1), np.asarray(pred2))
