"""Two-way transport layer (core/transport.py, DESIGN.md §13).

Pin families:

* **CommPlan shims** -- ``CommPlan()`` is bit-exact against the legacy
  kwargs AND the PR 5 golden; mixing ``comm=`` with a legacy kwarg is
  a TypeError at every entry point.
* **Downlink identity** -- a ``k_top=d`` f32 downlink is bit-exact
  against the dense (no-downlink) broadcast, on the 1x1 mesh and the
  8-device (2, 4) d=70 remainder mesh.
* **Dual EF resume** -- a T-round two-way-compressed stream split at
  any point replays bit-exactly from the returned
  :class:`TransportState` carries.
* **Downlink fault containment** -- a corrupted downlink payload
  screens every receiver (master included) back to the last received
  aggregate: no NaN escapes, the shared reference never forks.
* **BitBudget planners** -- share laws, budget adherence, validation.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_in_subprocess

from repro.core import rounds as rounds_core
from repro.core import transport as transport_core
from repro.core.compression import Compression, uplink_bits
from repro.core.dantzig import DantzigConfig
from repro.core.distributed import (
    distributed_slda_shardmap,
    simulated_distributed_slda,
)
from repro.core.faults import (
    CORRUPT_NAN,
    CORRUPT_NONE,
    Aggregation,
    FaultPlan,
    FaultSchedule,
)
from repro.core.pipeline import BinaryHead
from repro.core.transport import BitBudget, CommPlan, Transport, TransportState
from repro.stats import synthetic

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "golden", "binary_prerefactor.npz")
CFG = DantzigConfig(max_iters=200)


def _problem(seed=0, d=24, m=4, n=60):
    p = synthetic.make_problem(d=d, n_signal=5, rho=0.5)
    xs, ys = synthetic.sample_machines(jax.random.PRNGKey(seed), p, m, n, n)
    return xs, ys


def _solves(xs, ys, cfg=CFG):
    def one(x, y):
        from repro.core import pipeline
        return pipeline.worker_solves(
            BinaryHead(), x, y, lam=0.2, lam_prime=0.2, cfg=cfg)
    return jax.vmap(one)(xs, ys)


# ---------------------------------------------------------------------------
# CommPlan: the one static config, and its deprecation shims
# ---------------------------------------------------------------------------


def test_commplan_default_matches_legacy_bitwise():
    """comm=CommPlan() and the legacy no-kwargs call produce the SAME
    bits at every rounds setting."""
    xs, ys = _problem()
    for t in (1, 3):
        legacy = simulated_distributed_slda(
            xs, ys, 0.2, 0.2, 0.05, CFG, rounds=t)
        via_plan = simulated_distributed_slda(
            xs, ys, 0.2, 0.2, 0.05, CFG, rounds=t, comm=CommPlan())
        np.testing.assert_array_equal(np.asarray(legacy),
                                      np.asarray(via_plan))


def test_commplan_default_matches_pr5_golden():
    """CommPlan() reproduces the pre-refactor golden exactly -- the
    transport refactor left the dense path untouched."""
    golden = np.load(GOLDEN)
    cfg = DantzigConfig(max_iters=300)
    p30 = synthetic.make_problem(d=30, n_signal=4)
    xs, ys = synthetic.sample_machines(
        jax.random.PRNGKey(11), p30, 3, 100, 100)
    out = simulated_distributed_slda(
        xs, ys, 0.2, 0.2, 0.05, cfg, comm=CommPlan())
    np.testing.assert_allclose(np.asarray(out), golden["sim_dist"],
                               atol=1e-6)


def test_commplan_uplink_matches_legacy_compression_kwarg():
    xs, ys = _problem(seed=1)
    comp = Compression(6, "int8")
    legacy = simulated_distributed_slda(
        xs, ys, 0.2, 0.2, 0.05, CFG, rounds=3, compression=comp)
    via_plan = simulated_distributed_slda(
        xs, ys, 0.2, 0.2, 0.05, CFG, rounds=3,
        comm=CommPlan(uplink=comp))
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(via_plan))


def test_mixing_comm_and_legacy_kwargs_raises():
    xs, ys = _problem()
    ws = _solves(xs, ys)
    with pytest.raises(TypeError, match="not both"):
        rounds_core.simulate_round_loop(
            ws, rounds=2, comm=CommPlan(), compression=Compression(5))
    with pytest.raises(TypeError):
        rounds_core.simulate_round_loop(
            ws, rounds=2, comm=CommPlan(),
            faults=FaultSchedule(dropout=0.2, seed=0))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    x, y = xs.reshape(-1, xs.shape[-1]), ys.reshape(-1, ys.shape[-1])
    with pytest.raises(TypeError):
        distributed_slda_shardmap(
            mesh, x, y, 0.2, 0.2, 0.05, CFG, rounds=2,
            comm=CommPlan(), aggregation=Aggregation())


def test_commplan_schedule_exclusive_with_fixed_codecs():
    with pytest.raises(ValueError, match="schedule"):
        CommPlan(uplink=Compression(5),
                 schedule=BitBudget(total_bits=1000)).validate()
    with pytest.raises(ValueError, match="staleness"):
        CommPlan(staleness=-1).validate()


def test_worker_rounds_rejects_schedule_in_commplan():
    """A FaultSchedule inside CommPlan must be materialized by the
    faces; worker_rounds takes only this machine's FaultPlan row."""
    xs, ys = _problem(m=1)
    with pytest.raises(TypeError, match="materialize"):
        rounds_core.worker_rounds(
            BinaryHead(), xs[0], ys[0], lam=0.2, lam_prime=0.2,
            rounds=2, cfg=CFG,
            comm=CommPlan(faults=FaultSchedule(dropout=0.2, seed=0)))


# ---------------------------------------------------------------------------
# downlink identity: k_top = d f32 downlink == dense broadcast
# ---------------------------------------------------------------------------


def test_downlink_identity_codec_bitexact_vs_dense():
    """k_top=d f32 downlink moves EVERY delta coordinate exactly: the
    received aggregate equals the dense (no-downlink) one bit-for-bit,
    so the downlink close is a pure wire-format change."""
    xs, ys = _problem(seed=2)
    d = xs.shape[-1]
    dense = simulated_distributed_slda(
        xs, ys, 0.2, 0.2, 0.05, CFG, rounds=3)
    down = simulated_distributed_slda(
        xs, ys, 0.2, 0.2, 0.05, CFG, rounds=3,
        comm=CommPlan(downlink=Compression(d)))
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(down))


def test_downlink_identity_mesh_8dev_remainder_bitexact():
    """The same identity on the (2, 4) d=70 remainder mesh: the
    master-masked psum broadcast reproduces the master's payload
    bit-for-bit across real data-axis shards."""
    out = run_in_subprocess(
        """
        import jax, numpy as np
        from repro.core.compression import Compression
        from repro.core.dantzig import DantzigConfig
        from repro.core.distributed import distributed_slda_shardmap
        from repro.core.transport import CommPlan
        from repro.stats import synthetic

        cfg = DantzigConfig(max_iters=300)
        m, d = 2, 70
        p = synthetic.make_problem(d=d, n_signal=6, rho=0.6)
        xs, ys = synthetic.sample_machines(jax.random.PRNGKey(3), p, m, 100, 100)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        x, y = xs.reshape(-1, d), ys.reshape(-1, d)
        dense = distributed_slda_shardmap(
            mesh, x, y, 0.2, 0.2, 0.05, cfg, rounds=3)
        down = distributed_slda_shardmap(
            mesh, x, y, 0.2, 0.2, 0.05, cfg, rounds=3,
            comm=CommPlan(downlink=Compression(d)))
        np.testing.assert_array_equal(np.asarray(dense), np.asarray(down))
        print("DOWNLINK_MESH8_OK")
        """
    )
    assert "DOWNLINK_MESH8_OK" in out


def test_mesh_matches_simulation_two_way_compressed():
    """Mesh vs vmap parity with BOTH directions compressed and a
    taper schedule -- the twin drivers share the one round body."""
    out = run_in_subprocess(
        """
        import jax, numpy as np
        from repro.core.dantzig import DantzigConfig
        from repro.core.distributed import (
            distributed_slda_shardmap, simulated_distributed_slda)
        from repro.core.transport import BitBudget, CommPlan
        from repro.stats import synthetic

        cfg = DantzigConfig(max_iters=300)
        m, d = 2, 40
        p = synthetic.make_problem(d=d, n_signal=5, rho=0.5)
        xs, ys = synthetic.sample_machines(jax.random.PRNGKey(4), p, m, 80, 80)
        comm = CommPlan(schedule=BitBudget(total_bits=6000, mode="taper",
                                           taper=0.5, quantize="int8"))
        sim = simulated_distributed_slda(
            xs, ys, 0.2, 0.2, 0.05, cfg, rounds=3, comm=comm)
        mesh = jax.make_mesh((2, 1), ("data", "model"))
        out = distributed_slda_shardmap(
            mesh, xs.reshape(-1, d), ys.reshape(-1, d), 0.2, 0.2, 0.05,
            cfg, rounds=3, comm=comm)
        np.testing.assert_allclose(np.asarray(out), np.asarray(sim), atol=1e-5)
        print("TWOWAY_PARITY_OK")
        """,
        devices=2,
    )
    assert "TWOWAY_PARITY_OK" in out


# ---------------------------------------------------------------------------
# dual EF resume: both wires' residuals replay deterministically
# ---------------------------------------------------------------------------


def test_transport_state_resume_bitexact():
    """4 two-way-compressed rounds == 2 + 2 resumed from the returned
    TransportState: both EF carries (uplink per-machine, downlink
    aggregator) and the shared reference reconstruct the stream."""
    xs, ys = _problem(seed=5)
    ws = _solves(xs, ys)
    comm = CommPlan(uplink=Compression(8, "int8"), downlink=Compression(6))
    full = rounds_core.simulate_round_loop(ws, rounds=4, comm=comm)
    first, state = rounds_core.simulate_round_loop(
        ws, rounds=2, comm=comm, return_transport_state=True)
    assert isinstance(state, TransportState)
    assert state.up_residual is not None and state.down_residual is not None
    resumed = rounds_core.simulate_round_loop(
        ws, rounds=2, comm=comm, resume_from=first,
        ef_residual=state.up_residual, down_residual=state.down_residual)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(resumed))


def test_transport_state_none_on_dense_directions():
    xs, ys = _problem(seed=6)
    ws = _solves(xs, ys)
    _, state = rounds_core.simulate_round_loop(
        ws, rounds=2, comm=CommPlan(uplink=Compression(5)),
        return_transport_state=True)
    assert state.up_residual is not None and state.down_residual is None
    _, state = rounds_core.simulate_round_loop(
        ws, rounds=2, comm=CommPlan(downlink=Compression(5)),
        return_transport_state=True)
    assert state.up_residual is None and state.down_residual is not None


# ---------------------------------------------------------------------------
# downlink fault containment
# ---------------------------------------------------------------------------


def _plan_corrupt_master(m, rounds, bad_round):
    """All machines live; the AGGREGATOR's wire is NaN at bad_round."""
    live = jnp.ones((m, rounds), jnp.float32)
    stale = jnp.zeros((m, rounds), jnp.int32)
    corrupt = np.full((m, rounds), CORRUPT_NONE, np.int32)
    corrupt[0, bad_round - 1] = CORRUPT_NAN
    return FaultPlan(live, stale, jnp.asarray(corrupt))


def test_corrupted_downlink_screens_to_last_good():
    """A NaN downlink payload at round 2 of 3: every receiver falls
    back to the round-1 aggregate (no NaN escapes), and the stream
    resumes exactly ONE round delayed -- the rolled-back anchors
    regenerate the lost step, so round 3 equals the clean stream's
    round 2 bit-for-bit (identity codec, nothing else differs)."""
    xs, ys = _problem(seed=7, d=20)
    m = xs.shape[0]
    ws = _solves(xs, ys)
    comm = CommPlan(downlink=Compression(20),
                    aggregation=Aggregation(envelope=1e6))
    plan = _plan_corrupt_master(m, 3, bad_round=2)
    bars = rounds_core.simulate_round_loop(
        ws, rounds=3, comm=comm, faults=plan, return_all_rounds=True)
    bars = np.asarray(bars)
    assert np.isfinite(bars).all(), "downlink corruption leaked a NaN"
    # the rejected round holds the previous received aggregate
    np.testing.assert_array_equal(bars[1], bars[0])
    clean = np.asarray(rounds_core.simulate_round_loop(
        ws, rounds=3, comm=comm, return_all_rounds=True))
    np.testing.assert_array_equal(bars[0], clean[0])
    np.testing.assert_array_equal(bars[2], clean[1])


def test_corrupted_downlink_int8_scale_screens():
    """int8 downlink: corruption hits the f32 scales; the whole-block
    screen still catches it."""
    xs, ys = _problem(seed=8, d=16)
    ws = _solves(xs, ys)
    plan = _plan_corrupt_master(xs.shape[0], 2, bad_round=2)
    bars = rounds_core.simulate_round_loop(
        ws, rounds=2, comm=CommPlan(downlink=Compression(6, "int8")),
        faults=plan, return_all_rounds=True)
    bars = np.asarray(bars)
    assert np.isfinite(bars).all()
    np.testing.assert_array_equal(bars[1], bars[0])


# ---------------------------------------------------------------------------
# BitBudget planners
# ---------------------------------------------------------------------------


def test_bitbudget_shares_sum_to_one_and_taper_decays():
    for mode, kw in (("constant", {}), ("taper", {"taper": 0.5}),
                     ("adaptive", {"weights": (3.0, 2.0, 1.0)})):
        b = BitBudget(total_bits=10_000, mode=mode, **kw)
        shares = b.round_shares(3)
        assert abs(sum(shares) - 1.0) < 1e-12
        if mode != "constant":
            assert shares[0] > shares[1] > shares[2]


def test_bitbudget_realized_total_within_budget():
    """The realized schedule fits the nominal budget whenever the
    budget clears the per-round k=1 floors."""
    d, K, T = 100, 1, 3
    for total in (3_000, 10_000, 40_000):
        b = BitBudget(total_bits=total, mode="taper", taper=0.5)
        tr = Transport(CommPlan(schedule=b), d, K, T)
        realized = tr.uplink_total_bits() + tr.downlink_total_bits()
        floor = 2 * T * uplink_bits(Compression(1, "int8"), d, K)
        cap = 2 * T * uplink_bits(Compression(d, "int8"), d, K)
        assert realized <= max(total, floor)
        assert realized <= cap  # the k <= d clamp holds


def test_bitbudget_validation_errors():
    with pytest.raises(ValueError, match="mode"):
        BitBudget(total_bits=100, mode="warp").validate(2)
    with pytest.raises(ValueError, match="weights"):
        BitBudget(total_bits=100, mode="adaptive",
                  weights=(1.0,)).validate(2)
    with pytest.raises(ValueError, match="total_bits"):
        BitBudget(total_bits=0).validate(2)
    with pytest.raises(ValueError, match="down_fraction"):
        BitBudget(total_bits=100, down_fraction=1.5).validate(2)


def test_bitbudget_schedule_runs_and_changes_output():
    xs, ys = _problem(seed=9, d=30)
    dense = simulated_distributed_slda(xs, ys, 0.2, 0.2, 0.05, CFG,
                                       rounds=3)
    sched = simulated_distributed_slda(
        xs, ys, 0.2, 0.2, 0.05, CFG, rounds=3,
        comm=CommPlan(schedule=BitBudget(total_bits=2_000)))
    assert np.isfinite(np.asarray(sched)).all()
    assert sched.shape == dense.shape
    # a tight budget genuinely compresses: outputs differ
    assert float(jnp.max(jnp.abs(sched - dense))) > 0


def test_transport_bit_accounting_matches_links():
    comm = CommPlan(uplink=Compression(8, "int8"), downlink=Compression(4))
    tr = Transport(comm, 50, 2, 3)
    assert tr.uplink_total_bits() == 3 * uplink_bits(
        Compression(8, "int8"), 50, 2)
    assert tr.downlink_total_bits() == 3 * uplink_bits(
        Compression(4), 50, 2)
    dense = Transport(CommPlan(), 50, 2, 3)
    assert dense.downlink_total_bits() == 0  # never on the wire
