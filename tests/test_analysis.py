"""The analyzer analyzed: negative cases per contract kind, nested-jaxpr
recursion, AST import-rule units, and the remainder-shape CLI sweep.

Every contract kind must (a) pass on a conforming trace and (b) trip on
a deliberately violating one, reporting the offending eqn path.
"""

import io
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.analysis import (
    AxisPayloadBits,
    CollectiveContract,
    DtypePolicy,
    Param,
    PrimitiveBudget,
    check_entry,
    count_eqns,
    find_eqns,
    run_contracts,
    trace_contract,
)
from repro.analysis import cases as cases_mod
from repro.analysis import imports as import_rules
from repro.analysis import lint, registry
from repro.core.distributed import _shard_map

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# walker: nested-jaxpr recursion and located paths
# ---------------------------------------------------------------------------


def test_count_eqns_recurses_into_scan_while_cond_pjit():
    def scan_body(c, _):
        return c, jnp.linalg.eigh(c)[0]

    def while_body(s):
        a, i = s
        return jnp.linalg.eigh(a + 1.0)[1], i + 1

    def f(a):
        c, _ = jax.lax.scan(scan_body, a, jnp.arange(2))
        w, _ = jax.lax.while_loop(lambda s: s[1] < 1, while_body, (a, 0))
        e = jax.lax.cond(a[0, 0] > 0,
                         lambda x: jnp.linalg.eigh(x)[1],
                         lambda x: x, a)
        g = jax.jit(lambda x: jnp.linalg.eigh(x)[1])(a)
        return c, w, e, g

    jaxpr = jax.make_jaxpr(f)(jnp.eye(3))
    # scan body traces once (not per iteration); cond holds one eigh in
    # one branch; while body one; the inner jit one
    assert count_eqns(jaxpr, "eigh") == 4
    joined = ["/".join(s.path) for s in find_eqns(jaxpr, "eigh")]
    for enclosing in ("scan", "while", "cond", "pjit"):
        assert any(enclosing in j for j in joined), (enclosing, joined)


def test_count_eqns_accepts_closed_and_raw_jaxpr():
    jaxpr = jax.make_jaxpr(lambda a: jnp.linalg.eigh(a))(jnp.eye(3))
    assert count_eqns(jaxpr, "eigh") == count_eqns(jaxpr.jaxpr, "eigh") == 1


def test_count_eqns_out_shape_matcher():
    def f(x):
        return x @ x.T, x.T @ x  # (2,2) and (3,3) dot_generals

    jaxpr = jax.make_jaxpr(f)(jnp.ones((2, 3)))
    assert count_eqns(jaxpr, "dot_general", (2, 2)) == 1
    assert count_eqns(jaxpr, "dot_general", (3, 3)) == 1
    assert count_eqns(jaxpr, "dot_general", (4, 4)) == 0


def test_count_eqns_recurses_into_shard_map():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    fn = _shard_map(lambda x: jax.lax.psum(x, "data"), mesh,
                    (P("data"),), P())
    jaxpr = jax.make_jaxpr(fn)(jnp.ones((4,)))
    sites = find_eqns(jaxpr, "psum")
    assert len(sites) == 1
    assert "shard_map" in "/".join(sites[0].path)


# ---------------------------------------------------------------------------
# primitive budgets: negative case trips with located sites
# ---------------------------------------------------------------------------


def test_primitive_budget_trips_on_double_eigh():
    def double_eigh(a):
        return jnp.linalg.eigh(a)[1] + jnp.linalg.eigh(a + 1.0)[1]

    jaxpr = jax.make_jaxpr(double_eigh)(jnp.eye(3))
    assert PrimitiveBudget("eigh", exact=1).check(jaxpr) != []
    assert PrimitiveBudget("eigh", max_count=1).check(jaxpr) != []
    assert PrimitiveBudget("eigh", max_count=2).check(jaxpr) == []
    (violation,) = PrimitiveBudget("eigh", exact=1).check(jaxpr)
    assert "found 2" in violation.message
    assert len(violation.sites) == 2
    assert all("eigh" in s for s in violation.sites)


def test_budget_param_resolution_and_missing_param():
    jaxpr = jax.make_jaxpr(lambda a: jnp.linalg.eigh(a))(jnp.eye(3))
    budget = PrimitiveBudget("eigh", exact=Param("eighs"))
    assert run_contracts([budget], jaxpr, {"eighs": 1}) == []
    assert run_contracts([budget], jaxpr, {"eighs": 2}) != []
    (violation,) = run_contracts([budget], jaxpr, {})
    assert "eighs" in violation.message  # missing key is itself reported


# ---------------------------------------------------------------------------
# collective contracts: count, payload shape/dtype, mesh axis
# ---------------------------------------------------------------------------


def _trace_shard(body, *args):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    fn = _shard_map(body, mesh, tuple(P() for _ in args), P())
    return jax.make_jaxpr(fn)(*args)


def test_collective_contract_holds_on_conforming_trace():
    jaxpr = _trace_shard(lambda x: jax.lax.psum(x, "data"), jnp.ones((4,)))
    good = CollectiveContract("psum", count=1, axis="data", shape=(4,),
                              dtype="float32")
    assert good.check(jaxpr) == []


def test_collective_contract_trips_on_extra_psum():
    jaxpr = _trace_shard(
        lambda x: jax.lax.psum(x, "data") + jax.lax.psum(2.0 * x, "data"),
        jnp.ones((4,)))
    violations = CollectiveContract("psum", count=1, axis="data",
                                    shape=(4,)).check(jaxpr)
    assert violations and "found 2" in violations[0].message
    assert all("psum" in s for s in violations[0].sites)


def test_collective_contract_trips_on_wrong_payload_shape():
    jaxpr = _trace_shard(lambda x: jax.lax.psum(x, "data"), jnp.ones((4,)))
    violations = CollectiveContract("psum", count=1,
                                    shape=(5,)).check(jaxpr)
    assert violations and "expected exactly 1" in violations[0].message


def test_collective_contract_trips_on_wrong_axis():
    jaxpr = _trace_shard(lambda x: jax.lax.psum(x, "model"), jnp.ones((4,)))
    violations = CollectiveContract("psum", count=1, axis="data",
                                    shape=(4,)).check(jaxpr)
    assert violations and "'data'" in violations[0].message


def test_collective_contract_trips_on_payload_dtype():
    jaxpr = _trace_shard(
        lambda x: jax.lax.psum(x.astype(jnp.bfloat16), "data"),
        jnp.ones((4,)))
    violations = CollectiveContract("psum", count=1, shape=(4,),
                                    dtype="float32").check(jaxpr)
    assert violations and "bfloat16" in violations[0].message


def test_collective_contract_axis_filter_ignores_other_axes():
    """An axis-scoped contract counts ONLY its axis's collectives:
    model-axis traffic neither satisfies nor violates a data-axis pin."""
    jaxpr = _trace_shard(
        lambda x: jax.lax.psum(x, "data") + jax.lax.psum(x, "model"),
        jnp.ones((4,)))
    assert CollectiveContract("psum", count=1, axis="data", shape=(4,),
                              dtype="float32").check(jaxpr) == []
    assert CollectiveContract("psum", count=1, axis="model").check(jaxpr) \
        == []


# ---------------------------------------------------------------------------
# axis payload bits: total traffic over one mesh axis, at wire dtypes
# ---------------------------------------------------------------------------


def test_axis_payload_bits_exact_max_and_axis_scope():
    # one f32 psum of (4,) over the data axis = 128 bits per link
    jaxpr = _trace_shard(lambda x: jax.lax.psum(x, "data"), jnp.ones((4,)))
    assert AxisPayloadBits("data", exact_bits=128).check(jaxpr) == []
    assert AxisPayloadBits("data", max_bits=128).check(jaxpr) == []
    (violation,) = AxisPayloadBits("data", exact_bits=64).check(jaxpr)
    assert "128" in violation.message and violation.sites
    (violation,) = AxisPayloadBits("data", max_bits=100).check(jaxpr)
    assert "128" in violation.message
    # traffic on OTHER axes does not count toward this axis's total
    assert AxisPayloadBits("model", exact_bits=0).check(jaxpr) == []


def test_axis_payload_bits_sums_wire_dtypes():
    """Mixed-dtype gathers over one axis sum at their WIRE widths --
    the contract prices what one link uplinks (the gather operand),
    not the m-times-larger gathered result."""
    def body(x):
        vals = jax.lax.all_gather(x.astype(jnp.bfloat16), "data")
        idx = jax.lax.all_gather(jnp.arange(4, dtype=jnp.int16), "data")
        return vals.sum() + idx.sum()

    jaxpr = _trace_shard(body, jnp.ones((4,)))
    # 4 bf16 values (64 bits) + 4 int16 indices (64 bits)
    assert AxisPayloadBits("data", exact_bits=128).check(jaxpr) == []
    assert AxisPayloadBits("data", exact_bits=256).check(jaxpr) != []


# ---------------------------------------------------------------------------
# dtype policy: silent promotion past the ceiling
# ---------------------------------------------------------------------------


def test_dtype_policy_passes_f32_and_trips_at_bf16_ceiling():
    def f(x):
        return x.astype(jnp.float32) @ x.astype(jnp.float32).T

    jaxpr = jax.make_jaxpr(f)(jnp.ones((3, 3), jnp.bfloat16))
    assert DtypePolicy().check(jaxpr) == []  # f32 ceiling: clean
    violations = DtypePolicy(max_float="bfloat16").check(jaxpr)
    assert violations and "float32" in violations[0].message
    assert violations[0].sites  # offending eqns are located


# ---------------------------------------------------------------------------
# registry: contracts travel with the entry point; breaks are located
# ---------------------------------------------------------------------------


def test_registry_decorator_registers_and_checks():
    @trace_contract("selftest.double_eigh",
                    contracts=(PrimitiveBudget("eigh", exact=1),))
    def double_eigh(a):
        return jnp.linalg.eigh(a)[1] + jnp.linalg.eigh(a + 1.0)[1]

    try:
        assert "selftest.double_eigh" in registry.registered()
        jaxpr = jax.make_jaxpr(double_eigh)(jnp.eye(3))
        violations = check_entry("selftest.double_eigh", jaxpr, {})
        assert len(violations) == 1
        assert violations[0].sites and all(
            "eigh" in s for s in violations[0].sites)
    finally:
        registry.unregister("selftest.double_eigh")


def test_lint_run_api_passes_on_real_entry():
    buf = io.StringIO()
    n = lint.run(["pipeline.worker_debiased"], include_imports=False,
                 out=buf)
    assert n == 0, buf.getvalue()
    assert "[ok] binary-fused-d12" in buf.getvalue()


def test_lint_run_reports_broken_entry():
    @trace_contract("selftest.lint_broken",
                    contracts=(PrimitiveBudget("pallas_call", exact=1),))
    def plain(x):
        return x * 2.0

    @cases_mod.case("selftest.lint_broken", "neg", {})
    def _build():
        return plain, (jnp.ones((2, 2)),)

    try:
        buf = io.StringIO()
        n = lint.run(["selftest.lint_broken"], include_imports=False,
                     out=buf)
        report = buf.getvalue()
        assert n == 1
        assert "[FAIL] neg" in report and "pallas_call" in report
    finally:
        registry.unregister("selftest.lint_broken")
        cases_mod._CASES.pop("selftest.lint_broken", None)


def test_every_registered_entry_has_cases():
    for name in registry.registered():
        assert cases_mod.cases_for(name), f"{name} has no trace cases"


# ---------------------------------------------------------------------------
# AST import-graph rules (units on synthetic trees)
# ---------------------------------------------------------------------------


def _write_tree(root, files):
    for rel, src in files.items():
        path = root / rel
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(src)


def test_banned_import_rule_flags_both_import_forms(tmp_path):
    _write_tree(tmp_path, {
        "repro/core/dantzig.py": "def solve_dantzig():\n    pass\n",
        "repro/core/solver_dispatch.py":
            "from repro.core.dantzig import solve_dantzig\n",  # allowed
        "repro/core/evil.py":
            "from repro.core.dantzig import solve_dantzig\n",
        "repro/core/sneaky.py":
            "from repro.core import dantzig as dz\n"
            "def f(a, b):\n    return dz.solve_dantzig(a, b)\n",
        "repro/core/innocent.py":
            "# from repro.core.dantzig import solve_dantzig (a comment!)\n"
            "S = 'dantzig.solve_dantzig('\n",
    })
    violations = import_rules.banned_import_violations(tmp_path)
    offenders = {v.sites[0].rsplit(":", 1)[0] for v in violations}
    assert offenders == {str(tmp_path / "repro/core/evil.py"),
                         str(tmp_path / "repro/core/sneaky.py")}


def test_exclusive_call_rule_ignores_comments_and_strings(tmp_path):
    _write_tree(tmp_path, {
        "repro/core/pipeline.py":
            "import jax\ndef g(x):\n"
            "    return jax.lax.all_gather(x, 'model')\n",  # allowed
        "repro/core/rogue.py":
            "import jax\ndef f(x):\n"
            "    return jax.lax.all_gather(x, 'model')\n",
        "repro/core/clean.py":
            "# lax.all_gather( in a comment must not trip\n"
            "DOC = 'lax.all_gather('\n",
    })
    violations = import_rules.exclusive_call_violations(tmp_path)
    assert len(violations) == 1
    assert "rogue" in violations[0].sites[0]


def test_pipeline_unification_rule(tmp_path):
    good = {
        f"repro/core/{leaf}.py":
            "from repro.core import pipeline\n"
            "def run():\n    return pipeline.worker_debiased\n"
        for leaf in ("slda", "distributed", "multiclass")
    }
    good["repro/core/rounds.py"] = (
        "from repro.core import pipeline\n"
        "def step():\n"
        "    return pipeline.worker_solves, pipeline.apply_correction\n")
    _write_tree(tmp_path, good)
    assert import_rules.pipeline_unification_violations(tmp_path) == []
    # break one face: multiclass stops importing the pipeline core
    (tmp_path / "repro/core/multiclass.py").write_text(
        "def run():\n    return 7\n")
    violations = import_rules.pipeline_unification_violations(tmp_path)
    assert violations and any("multiclass" in v.message for v in violations)


def test_structural_rules_hold_on_this_repo():
    assert import_rules.structural_violations() == []


# ---------------------------------------------------------------------------
# remainder-shape sweep (d=70, model axis 4) through the CLI
# ---------------------------------------------------------------------------


def test_remainder_shape_sweep_via_cli():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint",
         "--entry", "distributed.slda_shardmap", "--no-imports"],
        capture_output=True, text=True, timeout=300,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src"),
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8"},
        cwd=REPO,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "[ok] fused-rounds3-mesh2x4-d70-remainder" in proc.stdout
