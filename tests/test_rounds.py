"""Multi-round refinement (core/rounds.py, DESIGN.md §8).

Four pin families:

* **Mesh/simulation parity** -- ``distributed_slda_shardmap`` /
  ``distributed_mc_slda_shardmap`` with ``rounds=3`` on an 8-device
  (data=2, model=4) mesh match the single-device vmap simulation to
  1e-5, including ``d % |model| != 0`` remainder columns.
* **Communication/compute structure** -- the jaxpr of a T-round driver
  traces exactly T ``pmean``s of a (d, K) block over the data axis and
  exactly ONE ``eigh`` per worker: refinement rounds are closed-form,
  they re-solve nothing.
* **Statistics** -- in a large-m regime where the one-shot estimator's
  l2 error visibly degrades versus centralized, T=3 refinement rounds
  recover most of the gap; T=1 reproduces the one-shot bit-for-bit.
* **Warm re-entry** -- re-entering the rounds pipeline with the
  returned WorkerSolves carries resumes both ADMM solves in strictly
  fewer executed iterations.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np

from conftest import run_in_subprocess

from repro.analysis import check_entry, count_eqns
from repro.core import compression as compression_core
from repro.core import pipeline, rounds as rounds_core
from repro.core.dantzig import DantzigConfig
from repro.core.distributed import (
    distributed_slda_shardmap,
    simulated_debiased_mean,
    simulated_distributed_slda,
)
from repro.core.pipeline import BinaryHead
from repro.core.slda import centralized_slda, multi_round_slda
from repro.stats import synthetic


# ---------------------------------------------------------------------------
# jaxpr pins: T pmeans of a (d, K) block, one eigh per worker
# (counter and contracts both come from repro.analysis)
# ---------------------------------------------------------------------------


def test_rounds_trace_T_pmeans_and_one_eigh():
    """T rounds = T (d, K) pmeans over the data axis; the refinement
    rounds reuse the round-one SpectralFactor and CLIME block, so the
    whole T-round worker still traces exactly ONE eigh (pmean lowers to
    a psum; the model-axis gather is all_gather, counted separately)."""
    d = 12
    cfg = DantzigConfig(max_iters=40, adapt_rho=False)
    p = synthetic.make_problem(d=d, n_signal=4, rho=0.5)
    xs, ys = synthetic.sample_machines(jax.random.PRNGKey(0), p, 1, 30, 30)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for t_rounds in (1, 2, 3):
        def fn(x, y, t_rounds=t_rounds):
            return distributed_slda_shardmap(
                mesh, x, y, 0.2, 0.2, 0.05, cfg, rounds=t_rounds)

        jaxpr = jax.make_jaxpr(fn)(xs.reshape(-1, d), ys.reshape(-1, d))
        assert count_eqns(jaxpr, "psum", (d, 1)) == t_rounds
        assert count_eqns(jaxpr, "psum") == t_rounds
        assert count_eqns(jaxpr, "eigh") == 1
        # one intra-machine correction gather per round
        assert count_eqns(jaxpr, "all_gather") == t_rounds
        # and the face's full declared contract set holds on this trace
        # (dense path: every round is one dense psum, no data-axis
        # gathers, and the per-link bits are T dense (d, 1) blocks)
        violations = check_entry(
            "distributed.slda_shardmap", jaxpr,
            {"rounds": t_rounds, "dense_psums": t_rounds,
             "live_psums": 0, "total_psums": t_rounds, "screen_ops": 0,
             "data_gathers": 0,
             "data_gather_bits": 0,
             "data_psum_bits":
                 t_rounds * compression_core.dense_uplink_bits(d, 1),
             "data_total_bits":
                 t_rounds * compression_core.dense_uplink_bits(d, 1),
             "psum_payload": (d, 1), "pallas_calls": 0})
        assert violations == [], violations


def test_mc_rounds_trace_T_direction_pmeans_one_means_pmean():
    """Multiclass: T (d, K) direction pmeans + ONE (K, d) means pmean
    (the class means are round-independent), still one eigh."""
    from repro.core.distributed import distributed_mc_slda_shardmap

    d, K = 10, 3
    cfg = DantzigConfig(max_iters=40, adapt_rho=False)
    problem = synthetic.make_mc_problem(d=d, num_classes=K, n_signal=3)
    xs, labels = synthetic.sample_mc_machines(
        jax.random.PRNGKey(1), problem, 1, 60)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for t_rounds in (1, 3):
        def fn(x, lab, t_rounds=t_rounds):
            return distributed_mc_slda_shardmap(
                mesh, x, lab, K, 0.2, 0.2, 0.05, cfg, rounds=t_rounds)

        jaxpr = jax.make_jaxpr(fn)(
            xs.reshape(-1, d), labels.reshape(-1))
        assert count_eqns(jaxpr, "psum", (d, K)) == t_rounds
        assert count_eqns(jaxpr, "psum", (K, d)) == 1
        assert count_eqns(jaxpr, "eigh") == 1
        violations = check_entry(
            "distributed.mc_slda_shardmap", jaxpr,
            {"rounds": t_rounds, "dense_psums": t_rounds,
             "live_psums": 0, "screen_ops": 0,
             "data_gathers": 0,
             "data_gather_bits": 0,
             "data_psum_bits":
                 t_rounds * compression_core.dense_uplink_bits(d, K)
                 + K * d * 32,  # + the one (K, d) f32 means psum
             "data_total_bits":
                 t_rounds * compression_core.dense_uplink_bits(d, K)
                 + K * d * 32,
             "direction_payload": (d, K),
             "means_payload": (K, d), "total_psums": t_rounds + 1,
             "pallas_calls": 0})
        assert violations == [], violations


# ---------------------------------------------------------------------------
# rounds=1 IS the one-shot estimator
# ---------------------------------------------------------------------------


def test_rounds_one_matches_oneshot_bitwise():
    cfg = DantzigConfig(max_iters=200)
    p = synthetic.make_problem(d=20, n_signal=5, rho=0.5)
    xs, ys = synthetic.sample_machines(jax.random.PRNGKey(2), p, 4, 60, 60)
    legacy = simulated_distributed_slda(xs, ys, 0.2, 0.2, 0.05, cfg)
    one_round = simulated_distributed_slda(
        xs, ys, 0.2, 0.2, 0.05, cfg, rounds=1)
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(one_round))
    # the slda face agrees with the distributed simulation
    face = multi_round_slda(xs, ys, 0.2, 0.2, 0.05, rounds=1, cfg=cfg)
    np.testing.assert_allclose(np.asarray(face), np.asarray(legacy),
                               atol=1e-6)


def test_refine_step_is_the_debias_formula():
    """One refine_step around beta_hat == the one-shot debias (eq. 3.4)."""
    cfg = DantzigConfig(max_iters=200)
    p = synthetic.make_problem(d=16, n_signal=4, rho=0.5)
    x, y = synthetic.sample_two_class(jax.random.PRNGKey(3), p, 80, 80)
    ws = pipeline.worker_solves(
        BinaryHead(), x, y, lam=0.2, lam_prime=0.25, cfg=cfg)
    bt_step = rounds_core.refine_step(ws, ws.beta_hat)
    bt_ref, _, _ = pipeline.worker_debiased(
        BinaryHead(), x, y, lam=0.2, lam_prime=0.25, cfg=cfg)
    np.testing.assert_allclose(np.asarray(bt_step), np.asarray(bt_ref),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# mesh parity vs the single-device simulation (subprocess, 8 devices)
# ---------------------------------------------------------------------------


def test_rounds_mesh_8dev_remainder_matches_simulation():
    """Acceptance case: (data=2, model=4) mesh, d=70 (70 % 4 != 0),
    rounds=3: the mesh multi-round output matches the vmap simulation
    to 1e-5 -- every round's correction gather handles the padded
    remainder columns exactly."""
    out = run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np, math
        from repro.core.dantzig import DantzigConfig
        from repro.core.distributed import (
            distributed_slda_shardmap, simulated_distributed_slda)
        from repro.stats import synthetic

        cfg = DantzigConfig(max_iters=300)
        m, d = 2, 70
        p = synthetic.make_problem(d=d, n_signal=6, rho=0.6)
        xs, ys = synthetic.sample_machines(jax.random.PRNGKey(0), p, m, 100, 100)
        lam = 0.3 * math.sqrt(math.log(d) / 200) * 4
        t = 0.25 * lam
        sim = simulated_distributed_slda(xs, ys, lam, lam, t, cfg, rounds=3)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        out = distributed_slda_shardmap(
            mesh, xs.reshape(-1, d), ys.reshape(-1, d), lam, lam, t, cfg,
            rounds=3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(sim), atol=1e-5)
        print("ROUNDS_MESH8_OK")
        """
    )
    assert "ROUNDS_MESH8_OK" in out


def test_mc_rounds_mesh_matches_simulation():
    """Multiclass rounds=2 on a (2, 2) mesh vs the simulation."""
    out = run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np, math
        from repro.core import multiclass as mc
        from repro.core.dantzig import DantzigConfig
        from repro.core.distributed import distributed_mc_slda_shardmap
        from repro.stats import synthetic

        cfg = DantzigConfig(max_iters=300)
        K, m, n, d = 3, 2, 150, 30
        problem = synthetic.make_mc_problem(d=d, num_classes=K, n_signal=4, rho=0.6)
        xs, labels = synthetic.sample_mc_machines(jax.random.PRNGKey(1), problem, m, n)
        lam = 0.3 * math.sqrt(math.log(d) / n) * 4
        t = 0.25 * lam
        sim_b, sim_m = mc.simulated_distributed_mc_slda(
            xs, labels, K, lam, lam, t, cfg, rounds=2)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        out_b, out_m = distributed_mc_slda_shardmap(
            mesh, xs.reshape(m * n, d), labels.reshape(m * n),
            K, lam, lam, t, cfg, rounds=2)
        np.testing.assert_allclose(np.asarray(out_b), np.asarray(sim_b), atol=1e-5)
        np.testing.assert_allclose(np.asarray(out_m), np.asarray(sim_m), atol=1e-5)
        print("MC_ROUNDS_MESH_OK")
        """,
        devices=4,
    )
    assert "MC_ROUNDS_MESH_OK" in out


# ---------------------------------------------------------------------------
# statistics: refinement recovers past the m-barrier
# ---------------------------------------------------------------------------


def test_rounds_recover_large_m_error():
    """Large-m regime (m=40, n=100, d=60): the one-shot l2 error visibly
    degrades vs centralized; T=3 refinement cuts most of the excess and
    the refined support-recovery F1 stays within 5% of centralized."""
    from benchmarks.common import tuned_metrics

    t_grid = np.geomspace(0.005, 2.0, 25)
    cfg = DantzigConfig(max_iters=300)
    d, m, n = 60, 40, 100
    problem = synthetic.make_problem(d=d, n_signal=8, rho=0.6)
    b1 = float(jnp.sum(jnp.abs(problem.beta_star)))
    lam = 0.3 * math.sqrt(math.log(d) / n) * b1
    lam_c = 0.3 * math.sqrt(math.log(d) / (m * n)) * b1
    xs, ys = synthetic.sample_machines(
        jax.random.PRNGKey(4), problem, m, n // 2, n // 2)
    cent = centralized_slda(xs.reshape(-1, d), ys.reshape(-1, d), lam_c, cfg)
    mc = tuned_metrics(cent, problem.beta_star, t_grid)
    bars, _ = rounds_core.simulate_multi_round(
        BinaryHead(), (xs, ys), lam=lam, lam_prime=lam, rounds=3, cfg=cfg,
        return_all_rounds=True)
    m1 = tuned_metrics(bars[0][:, 0], problem.beta_star, t_grid)
    m3 = tuned_metrics(bars[2][:, 0], problem.beta_star, t_grid)
    # premise: the one-shot is visibly past the barrier
    assert m1["l2"] > 1.5 * mc["l2"], (m1, mc)
    # T=3 cuts at least 30% of the excess error over centralized
    assert m3["l2"] < m1["l2"] - 0.3 * (m1["l2"] - mc["l2"]), (m1, m3, mc)
    # and support recovery stays with the centralized baseline
    assert m3["f1"] >= mc["f1"] - 0.05, (m3, mc)


def test_rounds_param_changes_simulated_mean():
    cfg = DantzigConfig(max_iters=150)
    p = synthetic.make_problem(d=16, n_signal=4, rho=0.5)
    xs, ys = synthetic.sample_machines(jax.random.PRNGKey(5), p, 3, 40, 40)
    r1 = simulated_debiased_mean(xs, ys, 0.2, 0.2, cfg)
    r3 = simulated_debiased_mean(xs, ys, 0.2, 0.2, cfg, rounds=3)
    assert r1.shape == r3.shape == (16,)
    assert float(jnp.max(jnp.abs(r1 - r3))) > 1e-6


# ---------------------------------------------------------------------------
# warm re-entry: carried WorkerSolves state resumes in fewer iterations
# ---------------------------------------------------------------------------


def test_rounds_warm_reentry_fewer_iterations():
    cfg = DantzigConfig(max_iters=800, tol=2e-4, check_every=25)
    p = synthetic.make_problem(d=40, n_signal=5, rho=0.6)
    xs, ys = synthetic.sample_machines(jax.random.PRNGKey(6), p, 3, 150, 150)
    cold_bar, cold = rounds_core.simulate_multi_round(
        BinaryHead(), (xs, ys), lam=0.2, lam_prime=0.2, rounds=2, cfg=cfg,
        collect_info=True)
    assert cold.iters_beta is not None and cold.iters_theta is not None
    cold_total = (int(np.max(cold.iters_beta))
                  + int(np.max(cold.iters_theta)))
    assert cold_total < 2 * 800, "cold solves must converge below the cap"
    warm_bar, warm = rounds_core.simulate_multi_round(
        BinaryHead(), (xs, ys), lam=0.2, lam_prime=0.2, rounds=2, cfg=cfg,
        collect_info=True,
        rho_beta=cold.rho_beta, rho_theta=cold.rho_theta,
        state_beta=cold.state_beta, state_theta=cold.state_theta)
    warm_total = (int(np.max(warm.iters_beta))
                  + int(np.max(warm.iters_theta)))
    assert warm_total < cold_total, (warm_total, cold_total)
    np.testing.assert_allclose(np.asarray(warm_bar), np.asarray(cold_bar),
                               atol=5e-3)


def test_collect_info_default_off_keeps_fields_none():
    cfg = DantzigConfig(max_iters=100)
    p = synthetic.make_problem(d=12, n_signal=3, rho=0.5)
    x, y = synthetic.sample_two_class(jax.random.PRNGKey(7), p, 40, 40)
    ws = pipeline.worker_solves(
        BinaryHead(), x, y, lam=0.2, lam_prime=0.2, cfg=cfg)
    assert ws.iters_beta is None and ws.state_beta is None
    full = pipeline.worker_solves(
        BinaryHead(), x, y, lam=0.2, lam_prime=0.2, cfg=cfg, full=True)
    assert full.iters_beta is not None and full.state_beta is not None
    assert full.theta.shape == (12, 12)
