"""Dantzig solver unit tests: feasibility, LP-oracle agreement, batching."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from scipy.optimize import linprog

from repro.core.dantzig import DantzigConfig, kkt_violation, solve_dantzig
from repro.stats.synthetic import ar1_covariance

CFG = DantzigConfig(max_iters=1500)


def _lp_dantzig(a: np.ndarray, b: np.ndarray, lam: float) -> np.ndarray:
    """Exact LP oracle: min ||x||_1 s.t. ||A x - b||_inf <= lam.

    x = u - v, u,v >= 0; minimize 1^T(u+v) s.t. -lam <= A(u-v) - b <= lam.
    """
    d = a.shape[0]
    c = np.ones(2 * d)
    a_ub = np.vstack([np.hstack([a, -a]), np.hstack([-a, a])])
    b_ub = np.concatenate([b + lam, lam - b])
    res = linprog(c, A_ub=a_ub, b_ub=b_ub, bounds=[(0, None)] * 2 * d,
                  method="highs")
    assert res.success, res.message
    x = res.x[:d] - res.x[d:]
    return x


@pytest.mark.parametrize("d,seed", [(10, 0), (25, 1)])
def test_matches_lp_oracle(d, seed):
    rng = np.random.default_rng(seed)
    a = ar1_covariance(d, 0.6).astype(np.float32)
    x_true = np.zeros(d)
    x_true[:3] = [1.5, -1.0, 0.5]
    b = a @ x_true + 0.01 * rng.standard_normal(d)
    lam = 0.1
    x_lp = _lp_dantzig(a.astype(np.float64), b.astype(np.float64), lam)
    x_admm = np.asarray(solve_dantzig(jnp.asarray(a), jnp.asarray(b, jnp.float32),
                                      lam, CFG))
    # same objective to a few percent, and feasible
    assert np.abs(x_admm).sum() <= np.abs(x_lp).sum() * 1.05 + 1e-3
    assert float(kkt_violation(jnp.asarray(a), jnp.asarray(b, jnp.float32),
                               jnp.asarray(x_admm), lam)) < 5e-3


def test_feasibility_and_shrinkage():
    d = 40
    a = jnp.asarray(ar1_covariance(d, 0.8), jnp.float32)
    key = jax.random.PRNGKey(2)
    b = jax.random.normal(key, (d,))
    prev_l1 = None
    for lam in [0.05, 0.2, 0.5]:
        x = solve_dantzig(a, b, lam, CFG)
        assert float(kkt_violation(a, b, x, lam)) < 1e-2
        l1 = float(jnp.sum(jnp.abs(x)))
        if prev_l1 is not None:
            # larger lam -> weaker constraint -> sparser/smaller solution
            assert l1 <= prev_l1 + 1e-4
        prev_l1 = l1


def test_batched_rhs_matches_single():
    d = 20
    a = jnp.asarray(ar1_covariance(d, 0.5), jnp.float32)
    rhs = jax.random.normal(jax.random.PRNGKey(3), (d, 4))
    lam = 0.15
    batched = solve_dantzig(a, rhs, lam, CFG)
    for j in range(4):
        single = solve_dantzig(a, rhs[:, j], lam, CFG)
        np.testing.assert_allclose(batched[:, j], single, atol=1e-5)


def test_zero_lam_large_recovers_zero():
    # with lam >= ||b||_inf, beta = 0 is optimal
    d = 15
    a = jnp.eye(d)
    b = jnp.ones((d,)) * 0.1
    x = solve_dantzig(a, b, 0.2, CFG)
    np.testing.assert_allclose(np.asarray(x), 0.0, atol=1e-6)
