"""Solver dispatch rules: scan vs fused vs fused-blocked selection."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dantzig import DantzigConfig, solve_dantzig, solve_dantzig_scan
from repro.core.solver_dispatch import (
    DEFAULT_VMEM_BUDGET,
    SolverChoice,
    backend_vmem_budget,
    select_solver,
    fused_block_vmem_bytes,
)
from repro.core import solver_dispatch
from repro.stats.synthetic import ar1_covariance


def test_scan_selected_when_fused_off():
    assert select_solver(DantzigConfig(), 64, 64) == SolverChoice("scan")
    assert select_solver(DantzigConfig(fused=False), 2048, 2048).kind == "scan"


def test_fused_single_block_for_small_shapes():
    choice = select_solver(DantzigConfig(fused=True), 256, 64)
    assert choice == SolverChoice("fused", 64)
    assert fused_block_vmem_bytes(256, 64) <= DEFAULT_VMEM_BUDGET


def test_fused_blocked_for_wide_batches():
    choice = select_solver(DantzigConfig(fused=True), 768, 512)
    assert choice.kind == "fused_blocked"
    assert 0 < choice.block_k < 512
    assert fused_block_vmem_bytes(768, choice.block_k) <= DEFAULT_VMEM_BUDGET


def test_scan_fallback_when_operands_exceed_vmem():
    # A + Q alone are 2 * 4096^2 * 4 B = 128 MiB >> VMEM
    assert select_solver(DantzigConfig(fused=True), 4096, 8).kind == "scan"


def test_explicit_block_k_override():
    choice = select_solver(DantzigConfig(fused=True, block_k=16), 64, 64)
    assert choice == SolverChoice("fused_blocked", 16)
    # override is clamped to the batch width
    choice = select_solver(DantzigConfig(fused=True, block_k=999), 64, 8)
    assert choice == SolverChoice("fused", 8)


def test_dispatch_entry_matches_scan_and_squeezes():
    d = 30
    a = jnp.asarray(ar1_covariance(d, 0.6), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(0), (d,))
    cfg_scan = DantzigConfig(max_iters=200, adapt_rho=False)
    cfg_fused = DantzigConfig(max_iters=200, adapt_rho=False, fused=True)
    out_scan = solve_dantzig(a, b, 0.1, cfg_scan)
    out_fused = solve_dantzig(a, b, 0.1, cfg_fused)
    assert out_scan.shape == out_fused.shape == (d,)
    np.testing.assert_allclose(np.asarray(out_scan), np.asarray(out_fused),
                               atol=1e-4)
    # the shim in core.dantzig and the dispatch entry are the same path
    out_direct = solver_dispatch.solve_dantzig(a, b, 0.1, cfg_scan)
    np.testing.assert_array_equal(np.asarray(out_scan), np.asarray(out_direct))


def test_output_dtype_uniform_across_paths():
    """b.dtype out on BOTH paths: toggling cfg.fused never changes it."""
    d = 16
    a = jnp.asarray(ar1_covariance(d, 0.5), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(2), (d, 2)).astype(jnp.bfloat16)
    for fused in (False, True):
        cfg = DantzigConfig(max_iters=50, adapt_rho=False, fused=fused)
        assert solve_dantzig(a, b, 0.1, cfg).dtype == jnp.bfloat16


def test_scan_accepts_warm_rho_seed():
    """rho0 seeds the adaptive state; a converged solve is insensitive."""
    d, k = 24, 5
    a = jnp.asarray(ar1_covariance(d, 0.5), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (d, k))
    base = solve_dantzig_scan(a, b, 0.1, DantzigConfig(max_iters=1200))
    warm = solve_dantzig(a, b, 0.1, DantzigConfig(max_iters=1200),
                         rho=jnp.full((k,), 2.0))
    np.testing.assert_allclose(np.asarray(base), np.asarray(warm), atol=5e-4)


def test_backend_budgets_drive_selection():
    """The backend parameter is live: it resolves the fast-memory budget."""
    # cpu mirrors the TPU budget so interpreter-validated shapes pick
    # the path they will pick on TPU
    assert backend_vmem_budget("cpu") == backend_vmem_budget("tpu") \
        == DEFAULT_VMEM_BUDGET
    # the active backend is the default (this suite runs on cpu)
    assert backend_vmem_budget() == backend_vmem_budget(
        jax.default_backend())
    cfg = DantzigConfig(fused=True)
    # (256, 64) fits one block under the TPU budget...
    assert select_solver(cfg, 256, 64, backend="tpu").kind == "fused"
    # ...but A + Q at d=256 alone bust a GPU shared-memory-sized
    # budget, so the same shape falls back to scan there
    assert backend_vmem_budget("gpu") < DEFAULT_VMEM_BUDGET
    assert select_solver(cfg, 256, 64, backend="gpu").kind == "scan"
    # an unknown backend gets the conservative default
    assert backend_vmem_budget("wasm") == DEFAULT_VMEM_BUDGET


def test_backend_budget_exact_values():
    """The budget constants are part of the dispatch contract.

    TPU and CPU share the 12 MiB VMEM model (interpreter-validated
    shapes must pick the path they will pick on TPU); GPU gets a
    shared-memory-sized 192 KiB.  A change here silently reroutes
    every shape's scan/fused/fused_blocked decision, so the exact
    numbers are pinned, not just their ordering.
    """
    assert backend_vmem_budget("tpu") == 12 * 2**20
    assert backend_vmem_budget("cpu") == 12 * 2**20
    assert backend_vmem_budget("gpu") == 192 * 2**10


def test_gpu_scan_fallback_boundary():
    """GPU fuses small d, tiles mid d, and bails exactly where A+Q bust 192 KiB."""
    cfg = DantzigConfig(fused=True)
    # d=64: A + Q = 32 KiB, well inside the 192 KiB budget
    choice = select_solver(cfg, 64, 8, backend="gpu")
    assert choice == SolverChoice("fused", 8)
    assert fused_block_vmem_bytes(64, 8) <= backend_vmem_budget("gpu")
    # d=128: A + Q = 128 KiB leave room for a few columns -> tiled,
    # rounded down to the f32 sublane granularity
    assert select_solver(cfg, 128, 64, backend="gpu") == \
        SolverChoice("fused_blocked", 8)
    # d=160: A + Q alone exceed the budget -- not even one column fits,
    # and the fallback ignores any explicit block_k override
    assert select_solver(cfg, 160, 1, backend="gpu").kind == "scan"
    assert select_solver(DantzigConfig(fused=True, block_k=1),
                         160, 1, backend="gpu").kind == "scan"


def test_state_io_footprint_drives_gpu_selection():
    """The adaptive kernel's larger footprint shrinks the GPU block.

    ``cfg.tol`` routes to the adaptive kernel, whose streamed-in/out
    ADMM state costs 14 (d, block_k) arrays instead of 9 -- on the
    tight GPU budget that is visible as a smaller block for the SAME
    shape.  An explicit ``state_io`` overrides the cfg derivation.
    """
    d, k = 144, 16
    fixed = select_solver(DantzigConfig(fused=True), d, k, backend="gpu")
    adaptive = select_solver(DantzigConfig(fused=True, tol=1e-4), d, k,
                             backend="gpu")
    assert fixed.kind == adaptive.kind == "fused_blocked"
    assert adaptive.block_k < fixed.block_k
    assert select_solver(DantzigConfig(fused=True), d, k, backend="gpu",
                         state_io=True) == adaptive


def test_cfg_vmem_budget_overrides_backend():
    """DantzigConfig.vmem_budget wins over any backend derivation."""
    # a budget too small for even one column at d=256 forces scan on
    # every backend
    tiny = DantzigConfig(fused=True, vmem_budget=100_000)
    assert select_solver(tiny, 256, 64).kind == "scan"
    assert select_solver(tiny, 256, 64, backend="tpu").kind == "scan"
    # a budget big enough for one block keeps the whole batch fused
    # even where the backend budget would have tiled or bailed
    huge = DantzigConfig(fused=True, vmem_budget=2**30)
    assert select_solver(huge, 768, 512, backend="gpu") == \
        SolverChoice("fused", 512)
    # and the end-to-end solve under an explicit budget stays exact
    d = 32
    a = jnp.asarray(ar1_covariance(d, 0.6), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(3), (d, 6))
    base = solve_dantzig(a, b, 0.1,
                         DantzigConfig(max_iters=150, adapt_rho=False))
    for budget in (100_000, 2**26):
        cfg = DantzigConfig(max_iters=150, adapt_rho=False, fused=True,
                            vmem_budget=budget)
        np.testing.assert_allclose(
            np.asarray(solve_dantzig(a, b, 0.1, cfg)), np.asarray(base),
            atol=1e-4)


def test_clime_forwards_warm_rho():
    from repro.core.clime import solve_clime_columns

    d = 32
    a = jnp.asarray(ar1_covariance(d, 0.6), jnp.float32)
    cols = jnp.asarray([0, 5, 31])
    cfg = DantzigConfig(max_iters=400, adapt_rho=False, fused=True)
    cold = solve_clime_columns(a, cols, 0.1, cfg)
    warm = solve_clime_columns(a, cols, 0.1, cfg,
                               rho=jnp.ones((3,), jnp.float32))
    np.testing.assert_allclose(np.asarray(cold), np.asarray(warm), atol=1e-6)
