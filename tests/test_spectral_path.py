"""Pins for the single-factorization contract and the lambda-path fold.

* eigh-count regression: the jaxpr of a jitted ``worker_debiased`` (and
  of a whole lambda-path sweep) contains EXACTLY ONE ``eigh`` -- the
  direction solve, the CLIME solve, and every grid point share the
  worker's SpectralFactor.
* fold parity: ``solve_dantzig_path`` matches L independent
  ``solve_dantzig`` calls to 1e-5 on the scan, fused, and fused_blocked
  dispatch paths.
* factor-acceptance: every solver entry point takes a SpectralFactor
  in place of the raw matrix and returns the same solution.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import check_entry, count_eqns
from repro.core import path as rpath
from repro.core import pipeline, slda
from repro.core.clime import solve_clime, solve_clime_columns
from repro.core.dantzig import (
    DantzigConfig,
    SpectralFactor,
    solve_dantzig_scan,
    spectral_factor,
)
from repro.core.pipeline import BinaryHead, MulticlassHead
from repro.core.solver_dispatch import solve_dantzig
from repro.kernels import ops as kops
from repro.stats.synthetic import ar1_covariance


def _ar1(d, rho=0.6):
    return jnp.asarray(ar1_covariance(d, rho), jnp.float32)


# ---------------------------------------------------------------------------
# eigh-count regression (the tentpole's contract, pinned structurally)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fused", [False, True])
def test_worker_debiased_traces_exactly_one_eigh(fused):
    """Direction solve + CLIME solve = ONE factorization, on both paths."""
    cfg = DantzigConfig(max_iters=30, adapt_rho=False, fused=fused)
    x = jax.random.normal(jax.random.PRNGKey(0), (40, 12))
    y = jax.random.normal(jax.random.PRNGKey(1), (44, 12))

    def worker(x, y):
        return pipeline.worker_debiased(
            BinaryHead(), x, y, lam=0.1, lam_prime=0.1, cfg=cfg)

    jaxpr = jax.make_jaxpr(worker)(x, y)
    assert count_eqns(jaxpr, "eigh") == 1


def test_multiclass_worker_traces_exactly_one_eigh():
    cfg = DantzigConfig(max_iters=30, adapt_rho=False, fused=True)
    x = jax.random.normal(jax.random.PRNGKey(2), (60, 10))
    labels = jax.random.randint(jax.random.PRNGKey(3), (60,), 0, 3)

    def worker(x, labels):
        return pipeline.worker_debiased(
            MulticlassHead(3), x, labels, lam=0.1, lam_prime=0.1, cfg=cfg)

    jaxpr = jax.make_jaxpr(worker)(x, labels)
    assert count_eqns(jaxpr, "eigh") == 1


@pytest.mark.parametrize("fused", [False, True])
def test_lambda_path_sweep_traces_exactly_one_eigh(fused):
    """An entire L-point sweep (direction path + CLIME) = ONE eigh."""
    cfg = DantzigConfig(max_iters=30, adapt_rho=False, fused=fused)
    lams = jnp.linspace(0.05, 0.4, 6)
    x = jax.random.normal(jax.random.PRNGKey(4), (40, 12))
    y = jax.random.normal(jax.random.PRNGKey(5), (44, 12))

    def sweep(x, y):
        return rpath.worker_debiased_path(
            BinaryHead(), x, y, lams=lams, lam_prime=0.1, cfg=cfg)

    jaxpr = jax.make_jaxpr(sweep)(x, y)
    assert count_eqns(jaxpr, "eigh") == 1


def test_solve_with_factor_traces_zero_eigh():
    """A solve handed a factor never re-factorizes."""
    a = _ar1(16)
    factor = spectral_factor(a)
    b = jax.random.normal(jax.random.PRNGKey(6), (16, 2))
    for fused in (False, True):
        cfg = DantzigConfig(max_iters=20, adapt_rho=False, fused=fused)
        jaxpr = jax.make_jaxpr(
            lambda f, b: solve_dantzig(f, b, 0.1, cfg))(factor, b)
        assert count_eqns(jaxpr, "eigh") == 0, f"fused={fused}"


def test_adaptive_worker_traces_one_eigh():
    """tol-mode (the while_loop kernel) keeps the one-eigh contract."""
    cfg = DantzigConfig(max_iters=50, adapt_rho=False, fused=True, tol=1e-3)
    x = jax.random.normal(jax.random.PRNGKey(20), (40, 12))
    y = jax.random.normal(jax.random.PRNGKey(21), (44, 12))

    def worker(x, y):
        return pipeline.worker_debiased(
            BinaryHead(), x, y, lam=0.1, lam_prime=0.1, cfg=cfg)

    jaxpr = jax.make_jaxpr(worker)(x, y)
    assert count_eqns(jaxpr, "eigh") == 1


def test_adaptive_sweep_traces_one_eigh_and_one_launch_per_solve():
    """With tol-mode on, an ENTIRE folded sweep still traces ONE eigh
    and ONE kernel launch for the direction fold (plus exactly one for
    the shared CLIME solve) -- the early exit lives INSIDE the kernel,
    it does not fragment the launch."""
    cfg = DantzigConfig(max_iters=50, adapt_rho=False, fused=True, tol=1e-3)
    lams = jnp.linspace(0.05, 0.4, 6)
    x = jax.random.normal(jax.random.PRNGKey(22), (40, 12))
    y = jax.random.normal(jax.random.PRNGKey(23), (44, 12))

    def sweep(x, y):
        return rpath.worker_debiased_path(
            BinaryHead(), x, y, lams=lams, lam_prime=0.1, cfg=cfg)

    jaxpr = jax.make_jaxpr(sweep)(x, y)
    assert count_eqns(jaxpr, "eigh") == 1
    assert count_eqns(jaxpr, "pallas_call") == 2
    # the registered contract set agrees (incl. dtype + VMEM conformance)
    violations = check_entry("path.worker_debiased_path", jaxpr,
                             {"pallas_calls": 2})
    assert violations == [], violations

    # warm re-sweep: threading rho AND full state changes neither count
    res = sweep(x, y)

    def resweep(x, y, rho, state):
        return rpath.worker_debiased_path(
            BinaryHead(), x, y, lams=lams, lam_prime=0.1, cfg=cfg,
            rho_beta=rho, state_beta=state)

    jaxpr = jax.make_jaxpr(resweep)(x, y, res.rho_beta, res.state_beta)
    assert count_eqns(jaxpr, "eigh") == 1
    assert count_eqns(jaxpr, "pallas_call") == 2


# ---------------------------------------------------------------------------
# lambda-path fold parity: one wide launch == L independent launches
# ---------------------------------------------------------------------------


PATH_CFGS = [
    ("scan", DantzigConfig(max_iters=200, adapt_rho=False)),
    ("fused", DantzigConfig(max_iters=200, adapt_rho=False, fused=True)),
    ("fused_blocked",
     DantzigConfig(max_iters=200, adapt_rho=False, fused=True, block_k=4)),
]


@pytest.mark.parametrize("name,cfg", PATH_CFGS, ids=[c[0] for c in PATH_CFGS])
def test_solve_dantzig_path_matches_sequential(name, cfg):
    d, k, L = 40, 3, 5
    a = _ar1(d)
    b = jax.random.normal(jax.random.PRNGKey(7), (d, k)) * 0.4
    lams = jnp.linspace(0.05, 0.4, L)
    res = rpath.solve_dantzig_path(a, b, lams, cfg)
    assert res.beta.shape == (L, d, k)
    assert res.kkt.shape == (L, k) and res.rho.shape == (L, k)
    for i in range(L):
        seq = solve_dantzig(a, b, float(lams[i]), cfg)
        np.testing.assert_allclose(
            np.asarray(res.beta[i]), np.asarray(seq), atol=1e-5,
            err_msg=f"{name} lambda[{i}]")


def test_solve_dantzig_path_vector_rhs_squeezes():
    d, L = 24, 4
    a = _ar1(d)
    b = jax.random.normal(jax.random.PRNGKey(8), (d,)) * 0.4
    lams = jnp.linspace(0.1, 0.4, L)
    cfg = DantzigConfig(max_iters=150, adapt_rho=False, fused=True)
    res = rpath.solve_dantzig_path(a, b, lams, cfg)
    assert res.beta.shape == (L, d)
    assert res.kkt.shape == (L,)
    for i in range(L):
        np.testing.assert_allclose(
            np.asarray(res.beta[i]),
            np.asarray(solve_dantzig(a, b, float(lams[i]), cfg)), atol=1e-5)


def test_worker_path_matches_single_lambda_worker():
    """Each grid point of the folded worker sweep reproduces the
    single-lambda pipeline (same CLIME radius)."""
    cfg = DantzigConfig(max_iters=150, adapt_rho=False, fused=True)
    lams = jnp.linspace(0.08, 0.4, 4)
    x = jax.random.normal(jax.random.PRNGKey(9), (80, 20))
    y = jax.random.normal(jax.random.PRNGKey(10), (90, 20)) + 0.5
    res = rpath.worker_debiased_path(
        BinaryHead(), x, y, lams=lams, lam_prime=0.2, cfg=cfg)
    for i in range(4):
        bt, bh, _ = pipeline.worker_debiased(
            BinaryHead(), x, y, lam=float(lams[i]), lam_prime=0.2, cfg=cfg)
        np.testing.assert_allclose(
            np.asarray(res.beta_hat[i]), np.asarray(bh), atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(res.beta_tilde[i]), np.asarray(bt), atol=1e-5)


def test_path_warm_rho_carry_shape_and_reuse():
    """PathResult.rho threads back in as the next sweep's warm start."""
    d, k, L = 24, 2, 3
    a = _ar1(d)
    b = jax.random.normal(jax.random.PRNGKey(11), (d, k)) * 0.4
    lams = jnp.linspace(0.1, 0.4, L)
    cfg = DantzigConfig(max_iters=300, adapt_rho=False, fused=True)
    first = rpath.solve_dantzig_path(a, b, lams, cfg)
    again = rpath.solve_dantzig_path(a, b, lams, cfg, rho=first.rho)
    # fixed-rho fused path with the same (scalar-equal) warm values:
    # identical solves
    np.testing.assert_allclose(
        np.asarray(first.beta), np.asarray(again.beta), atol=1e-6)
    # scan path adapts rho and reports the adapted values; a converged
    # solve is insensitive to the (different) warm trajectory
    scan_cfg = DantzigConfig(max_iters=1200)
    res = rpath.solve_dantzig_path(a, b, lams, scan_cfg)
    assert res.rho.shape == (L, k)
    warm = rpath.solve_dantzig_path(a, b, lams, scan_cfg, rho=res.rho)
    np.testing.assert_allclose(
        np.asarray(res.beta), np.asarray(warm.beta), atol=5e-4)


def test_lambda_selection_helpers():
    d, L = 30, 5
    a = _ar1(d)
    b = jax.random.normal(jax.random.PRNGKey(12), (d,)) * 0.5
    # a grid reaching down to a radius the iteration budget can't close
    lams = jnp.asarray([1e-5, 0.1, 0.2, 0.3, 0.4])
    cfg = DantzigConfig(max_iters=300, adapt_rho=False, fused=True)
    res = rpath.solve_dantzig_path(a, b, lams, cfg)
    tol = 1e-4
    feasible = [i for i in range(L) if float(res.kkt[i]) <= tol]
    assert feasible and len(feasible) < L, res.kkt  # tol splits the grid
    idx = int(rpath.select_by_kkt(res, tol=tol))
    # rule: the smallest tol-feasible radius
    assert float(res.kkt[idx]) <= tol
    assert float(res.lam[idx]) == min(float(res.lam[i]) for i in feasible)
    # nothing feasible -> fall back to the smallest violation
    idx_none = int(rpath.select_by_kkt(res, tol=1e-9))
    assert idx_none == int(jnp.argmin(res.kkt))
    picked = rpath.take_lambda(res.beta, idx)
    assert picked.shape == (d,)
    # validation scoring picks the argmax of the supplied score
    scores_idx, scores = rpath.select_by_validation(
        res.beta, lambda beta: -jnp.sum(jnp.abs(beta)))
    assert scores.shape == (L,)
    assert int(scores_idx) == int(jnp.argmax(scores))


def test_binary_face_path_and_validation_tuning():
    key = jax.random.PRNGKey(13)
    d = 20
    x = jax.random.normal(key, (100, d))
    y = jax.random.normal(jax.random.fold_in(key, 1), (100, d)) + 0.6
    lams = jnp.linspace(0.08, 0.5, 4)
    cfg = DantzigConfig(max_iters=150, adapt_rho=False, fused=True)
    res = slda.debiased_local_estimator_path(x, y, lams, 0.2, cfg)
    assert res.beta_tilde.shape == (4, d, 1)
    z = jnp.concatenate([
        jax.random.normal(jax.random.fold_in(key, 2), (40, d)),
        jax.random.normal(jax.random.fold_in(key, 3), (40, d)) + 0.6])
    labels = jnp.concatenate([jnp.zeros(40, jnp.int32), jnp.ones(40, jnp.int32)])
    idx, errors = slda.tune_lambda_validation(res, z, labels)
    assert errors.shape == (4,)
    assert float(errors[int(idx)]) == float(jnp.min(errors))
    # a separable draw should classify well at the tuned lambda
    assert float(jnp.min(errors)) < 0.45


# ---------------------------------------------------------------------------
# factor-acceptance across entry points
# ---------------------------------------------------------------------------


def test_every_entry_point_accepts_a_factor():
    d = 32
    a = _ar1(d)
    factor = spectral_factor(a)
    assert isinstance(factor, SpectralFactor) and factor.d == d
    b = jax.random.normal(jax.random.PRNGKey(14), (d, 3)) * 0.4
    for fused in (False, True):
        cfg = DantzigConfig(max_iters=150, adapt_rho=False, fused=fused)
        np.testing.assert_allclose(
            np.asarray(solve_dantzig(factor, b, 0.1, cfg)),
            np.asarray(solve_dantzig(a, b, 0.1, cfg)), atol=1e-5)
    # scan implementation directly
    np.testing.assert_allclose(
        np.asarray(solve_dantzig_scan(factor, b, 0.1,
                                      DantzigConfig(max_iters=150))),
        np.asarray(solve_dantzig_scan(a, b, 0.1,
                                      DantzigConfig(max_iters=150))),
        atol=1e-5)
    # kernel wrapper directly
    np.testing.assert_allclose(
        np.asarray(kops.dantzig_fused(factor, b, 0.1, iters=150)),
        np.asarray(kops.dantzig_fused(a, b, 0.1, iters=150)), atol=1e-5)
    # CLIME entry points
    cols = jnp.asarray([0, 7, 31])
    cfg = DantzigConfig(max_iters=150, adapt_rho=False)
    np.testing.assert_allclose(
        np.asarray(solve_clime_columns(factor, cols, 0.1, cfg)),
        np.asarray(solve_clime_columns(a, cols, 0.1, cfg)), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(solve_clime(factor, 0.1, cfg)),
        np.asarray(solve_clime(a, 0.1, cfg)), atol=1e-5)


def test_factor_is_a_pytree_under_jit():
    a = _ar1(12)
    factor = jax.jit(spectral_factor)(a)
    recon = factor.q @ jnp.diag(factor.evals) @ factor.q.T
    np.testing.assert_allclose(np.asarray(recon), np.asarray(a), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(factor.inv_eig),
        1.0 / (np.asarray(factor.evals) ** 2 + 1.0), rtol=1e-6)
