"""Convergence-adaptive ADMM: early exit, warm starts, pad neutrality.

The DESIGN.md §7 contract, pinned:

  * tol-mode solutions match the fixed-500 baseline to <= 1e-4 on
    every dispatch path (scan / fused / fused_blocked), including
    SpectralFactor-fed calls, while executing strictly fewer
    iterations;
  * a solve resumed from a previous solve's :class:`AdmmState`
    converges in strictly fewer iterations than the cold solve;
  * padded tail columns (b = 0, lam = 1, rho = 1, zero state) report
    zero residual immediately and never hold a block's while_loop
    open;
  * the default config (tol=None) keeps the fixed-iteration schedule
    bit-exact -- the adaptive machinery is strictly opt-in.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import path as rpath
from repro.core.clime import solve_clime_columns
from repro.core.pipeline import BinaryHead
from repro.core.dantzig import AdmmState, DantzigConfig, solve_dantzig_scan
from repro.core.solver_dispatch import (
    select_solver,
    solve_dantzig,
    solve_dantzig_full,
)
from repro.kernels import ops as kops
from repro.kernels.dantzig_fused import (
    DEFAULT_VMEM_BUDGET,
    fused_block_vmem_bytes,
    pick_block_k,
)
from repro.kernels.spectral import spectral_factor
from repro.stats.synthetic import ar1_covariance

# the benchmark's converging operating point: CLIME columns on AR(0.4)
D, LAM, TOL = 64, 0.3, 2e-4
FIXED = 500


def _factor(d=D, ar=0.4):
    return spectral_factor(jnp.asarray(ar1_covariance(d, ar), jnp.float32))


def _clime_b(d=D, k=None):
    return jnp.eye(d, dtype=jnp.float32)[:, : (k or d)]


ADAPTIVE_CFGS = [
    ("scan", DantzigConfig(max_iters=FIXED, adapt_rho=False, tol=TOL)),
    ("fused", DantzigConfig(max_iters=FIXED, adapt_rho=False, fused=True,
                            tol=TOL)),
    ("fused_blocked",
     DantzigConfig(max_iters=FIXED, adapt_rho=False, fused=True, block_k=16,
                   tol=TOL)),
]


# ---------------------------------------------------------------------------
# tol-mode parity vs fixed-500
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,cfg", ADAPTIVE_CFGS,
                         ids=[c[0] for c in ADAPTIVE_CFGS])
def test_tol_mode_matches_fixed_500(name, cfg):
    factor = _factor()
    b = _clime_b()
    fixed = solve_dantzig(factor, b, LAM, cfg._replace(tol=None))
    res = solve_dantzig_full(factor, b, LAM, cfg)
    assert int(res.iters.max()) < FIXED, name  # it actually exited early
    np.testing.assert_allclose(np.asarray(res.beta), np.asarray(fixed),
                               atol=1e-4, err_msg=name)
    # the narrow entry point honors cfg.tol identically
    np.testing.assert_array_equal(
        np.asarray(solve_dantzig(factor, b, LAM, cfg)),
        np.asarray(res.beta))


@pytest.mark.parametrize("name,cfg", ADAPTIVE_CFGS,
                         ids=[c[0] for c in ADAPTIVE_CFGS])
def test_tol_mode_factor_fed_matches_matrix_fed(name, cfg):
    a = jnp.asarray(ar1_covariance(D, 0.4), jnp.float32)
    b = _clime_b(k=8)
    np.testing.assert_allclose(
        np.asarray(solve_dantzig(spectral_factor(a), b, LAM, cfg)),
        np.asarray(solve_dantzig(a, b, LAM, cfg)), atol=1e-5, err_msg=name)


def test_tol_mode_scan_with_adaptive_rho():
    """The while_loop early exit composes with residual balancing."""
    factor = _factor()
    b = _clime_b(k=8)
    cfg = DantzigConfig(max_iters=FIXED, tol=TOL)  # adapt_rho defaults on
    fixed = solve_dantzig(factor, b, LAM, cfg._replace(tol=None))
    res = solve_dantzig_full(factor, b, LAM, cfg)
    assert int(res.iters.max()) < FIXED
    np.testing.assert_allclose(np.asarray(res.beta), np.asarray(fixed),
                               atol=1e-4)


@pytest.mark.parametrize("fused", [False, True])
def test_cap_is_exactly_max_iters_when_check_every_does_not_divide(fused):
    """A non-converging tol-mode solve stops at max_iters, not at the
    next check_every multiple (the final chunk is clamped)."""
    factor = _factor()
    b = jax.random.normal(jax.random.PRNGKey(7), (D, 4)) * 0.5
    cfg = DantzigConfig(max_iters=100, adapt_rho=False, fused=fused,
                        tol=1e-12, check_every=30)
    res = solve_dantzig_full(factor, b, 0.05, cfg)
    assert int(res.iters.max()) == 100
    # and the clamped trajectory equals a straight 100-iteration run
    fixed = solve_dantzig(factor, b, 0.05, cfg._replace(tol=None))
    np.testing.assert_allclose(np.asarray(res.beta), np.asarray(fixed),
                               atol=1e-6)


def test_squeeze_contract_in_tol_mode():
    factor = _factor()
    b = _clime_b(k=1)[:, 0]
    cfg = DantzigConfig(max_iters=FIXED, adapt_rho=False, fused=True, tol=TOL)
    res = solve_dantzig_full(factor, b, LAM, cfg)
    assert res.beta.shape == (D,)
    assert res.iters.shape == ()
    assert res.state.z.shape == (D,)
    np.testing.assert_allclose(
        np.asarray(res.beta),
        np.asarray(solve_dantzig(factor, _clime_b(k=1), LAM, cfg)[:, 0]),
        atol=1e-6)


# ---------------------------------------------------------------------------
# warm starts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,cfg", ADAPTIVE_CFGS,
                         ids=[c[0] for c in ADAPTIVE_CFGS])
def test_resumed_solve_iterates_strictly_less_than_cold(name, cfg):
    factor = _factor()
    b = _clime_b()
    cold = solve_dantzig_full(factor, b, LAM, cfg)
    resumed = solve_dantzig_full(factor, b, LAM, cfg, state=cold.state,
                                 rho=cold.rho)
    assert int(resumed.iters.max()) < int(cold.iters.max()), name
    np.testing.assert_allclose(np.asarray(resumed.beta),
                               np.asarray(cold.beta), atol=1e-3)


def test_state_is_a_resumable_pytree():
    factor = _factor()
    b = _clime_b(k=8)
    cfg = DantzigConfig(max_iters=200, adapt_rho=False, fused=True, tol=TOL)
    res = solve_dantzig_full(factor, b, LAM, cfg)
    assert isinstance(res.state, AdmmState)
    assert all(leaf.shape == (D, 8) for leaf in res.state)
    # flows through jit like any pytree operand
    resumed = jax.jit(
        lambda s: solve_dantzig_full(factor, b, LAM, cfg, state=s).beta
    )(res.state)
    np.testing.assert_allclose(np.asarray(resumed), np.asarray(res.beta),
                               atol=1e-3)


def test_fixed_mode_with_state_runs_exact_iteration_count():
    """tol=None + warm state = exactly max_iters more iterations."""
    factor = _factor()
    b = _clime_b(k=4)
    cfg = DantzigConfig(max_iters=100, adapt_rho=False, fused=True)
    cold = solve_dantzig_full(factor, b, LAM, cfg)
    assert int(cold.iters.max()) == 100
    resumed = solve_dantzig_full(factor, b, LAM, cfg, state=cold.state)
    assert int(resumed.iters.max()) == 100
    # 100 + 100 resumed == 200 straight (same trajectory, fixed rho)
    straight = solve_dantzig_full(
        factor, b, LAM, cfg._replace(max_iters=200))
    np.testing.assert_allclose(np.asarray(resumed.beta),
                               np.asarray(straight.beta), atol=1e-6)


def test_clime_entry_point_forwards_state():
    factor = _factor()
    cols = jnp.asarray([0, 5, 33])
    cfg = DantzigConfig(max_iters=FIXED, adapt_rho=False, fused=True, tol=TOL)
    cold = solve_clime_columns(factor, cols, LAM, cfg)
    rhs = jnp.zeros((D, 3), jnp.float32).at[cols, jnp.arange(3)].set(1.0)
    full = solve_dantzig_full(factor, rhs, LAM, cfg)
    warm = solve_clime_columns(factor, cols, LAM, cfg, state=full.state)
    np.testing.assert_allclose(np.asarray(warm), np.asarray(cold), atol=1e-3)


# ---------------------------------------------------------------------------
# pad-column neutrality under early exit
# ---------------------------------------------------------------------------


def test_pad_columns_never_hold_a_block_open():
    """d=300, k=7 with block_k=4: the remainder tail (one pad column in
    the second block) must not pin its block at max_iters."""
    d, k = 300, 7
    factor = _factor(d=d)
    b = _clime_b(d=d, k=k)
    cfg = DantzigConfig(max_iters=FIXED, adapt_rho=False, fused=True,
                        tol=TOL, block_k=4)
    res = solve_dantzig_full(factor, b, LAM, cfg)
    assert int(res.iters.max()) < FIXED  # neither block ran out the cap
    # and the tail block (3 real columns + 1 pad) agrees with the
    # unblocked solve of the same columns
    whole = solve_dantzig_full(factor, b, LAM, cfg._replace(block_k=None))
    np.testing.assert_allclose(np.asarray(res.beta), np.asarray(whole.beta),
                               atol=1e-4)


def test_pure_pad_block_exits_after_one_chunk():
    """A block made ENTIRELY of pad columns stops at the first check."""
    d, k = 48, 5
    factor = _factor(d=d)
    b = _clime_b(d=d, k=k)
    check_every = 10
    cfg = DantzigConfig(max_iters=FIXED, adapt_rho=False, fused=True,
                        tol=TOL, check_every=check_every, block_k=4)
    # blocks: [4 real], [1 real + 3 pad] -- per-block counts surface
    # through kops.dantzig_fused directly
    res = kops.dantzig_fused(
        factor, b, LAM, iters=FIXED, tol=TOL, check_every=check_every,
        block_k=4, return_info=True)
    assert res.iters.shape == (2,)
    assert int(res.iters.max()) < FIXED
    # solving ONLY pad-equivalent columns (b = 0) exits after one chunk
    zero = kops.dantzig_fused(
        factor, jnp.zeros((d, 4), jnp.float32), 1.0, iters=FIXED, tol=TOL,
        check_every=check_every, rho=1.0, return_info=True)
    assert int(zero.iters.max()) == check_every
    np.testing.assert_array_equal(np.asarray(zero.beta),
                                  np.zeros((d, 4), np.float32))


# ---------------------------------------------------------------------------
# path continuation
# ---------------------------------------------------------------------------


def test_path_resweep_warm_iters_below_cold():
    factor = _factor(d=96)
    b = _clime_b(d=96, k=8)
    lams = jnp.linspace(0.25, 0.55, 5)
    cfg = DantzigConfig(max_iters=FIXED, adapt_rho=False, fused=True,
                        tol=TOL, block_k=8)
    cold = rpath.solve_dantzig_path(factor, b, lams, cfg)
    assert cold.state.z.shape == (5, 96, 8)
    assert cold.iters.shape == (5, 8)
    warm = rpath.solve_dantzig_path(factor, b, lams, cfg,
                                    state=cold.state, rho=cold.rho)
    assert int(warm.iters.sum()) < int(cold.iters.sum())
    np.testing.assert_allclose(np.asarray(warm.beta), np.asarray(cold.beta),
                               atol=1e-3)


def test_seed_path_state_maps_nearest_lambda():
    state = AdmmState(*(jnp.arange(3, dtype=jnp.float32)[:, None, None]
                        * jnp.ones((3, 4, 2)) for _ in range(4)))
    lams_from = jnp.asarray([0.1, 0.2, 0.3])
    lams_to = jnp.asarray([0.1, 0.22, 0.31, 0.05])
    seeded = rpath.seed_path_state(state, lams_from, lams_to)
    np.testing.assert_array_equal(
        np.asarray(seeded.z[:, 0, 0]), np.asarray([0.0, 1.0, 2.0, 0.0]))


def test_worker_path_state_carry_round_trips():
    cfg = DantzigConfig(max_iters=300, adapt_rho=False, fused=True, tol=TOL)
    lams = jnp.linspace(0.2, 0.5, 4)
    x = jax.random.normal(jax.random.PRNGKey(2), (120, 30))
    y = jax.random.normal(jax.random.PRNGKey(3), (130, 30)) + 0.4
    res = rpath.worker_debiased_path(
        BinaryHead(), x, y, lams=lams, lam_prime=0.3, cfg=cfg)
    assert res.state_beta.z.shape == (4, 30, 1)
    assert res.iters.shape == (4, 1)
    again = rpath.worker_debiased_path(
        BinaryHead(), x, y, lams=lams, lam_prime=0.3, cfg=cfg,
        rho_beta=res.rho_beta, state_beta=res.state_beta)
    assert int(again.iters.sum()) < int(res.iters.sum())
    np.testing.assert_allclose(np.asarray(again.beta_tilde),
                               np.asarray(res.beta_tilde), atol=1e-3)


# ---------------------------------------------------------------------------
# VMEM model + selection
# ---------------------------------------------------------------------------


def test_state_io_footprint_is_larger_and_budgeted():
    d = 256
    bk_plain = pick_block_k(d, 4096, DEFAULT_VMEM_BUDGET)
    bk_state = pick_block_k(d, 4096, DEFAULT_VMEM_BUDGET, state_io=True)
    assert bk_state < bk_plain  # state I/O pays for itself in block size
    assert fused_block_vmem_bytes(d, bk_state, state_io=True) \
        <= DEFAULT_VMEM_BUDGET
    assert fused_block_vmem_bytes(d, bk_plain, state_io=True) \
        > DEFAULT_VMEM_BUDGET  # the old sizing would have blown VMEM


def test_select_solver_derives_state_io_from_tol():
    d, k = 256, 4096
    plain = select_solver(DantzigConfig(fused=True), d, k)
    adaptive = select_solver(DantzigConfig(fused=True, tol=1e-4), d, k)
    assert adaptive.kind == plain.kind == "fused_blocked"
    assert adaptive.block_k < plain.block_k
    assert select_solver(
        DantzigConfig(fused=True), d, k, state_io=True) == adaptive


def test_default_config_stays_on_the_fixed_kernel_bit_exact():
    """tol=None end to end == the pre-adaptive fixed path, bitwise."""
    factor = _factor()
    b = _clime_b(k=8)
    cfg = DantzigConfig(max_iters=150, adapt_rho=False, fused=True)
    base = solve_dantzig(factor, b, LAM, cfg)
    via_full = solve_dantzig_full(factor, b, LAM, cfg)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(via_full.beta))
    assert int(via_full.iters.max()) == 150
