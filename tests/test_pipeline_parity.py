"""Pins for the head-parameterized pipeline refactor (PR 2).

Two kinds of pins:

  * numeric -- every binary public API (``debiased_local_estimator``,
    ``simulated_distributed_slda`` & friends, ``distributed_slda_shardmap``
    with remainder columns) must reproduce the PRE-refactor outputs
    stored in ``tests/golden/binary_prerefactor.npz`` (generated at
    commit 38e71e8 by ``tests/golden/generate_binary_golden.py``);
  * structural -- exactly one implementation of the worker debias
    schedule remains: slda / distributed / multiclass call into
    ``core/pipeline.py``, and no module but the dispatch layer imports
    ``solve_dantzig`` from ``core.dantzig``.
"""

import os

import jax
import numpy as np
import pytest

from conftest import run_in_subprocess

from repro.analysis import imports as import_rules
from repro.core import slda
from repro.core.dantzig import DantzigConfig
from repro.core.distributed import (
    simulated_debiased_mean,
    simulated_distributed_slda,
    simulated_naive_averaged_slda,
)
from repro.stats import synthetic

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
GOLDEN = os.path.join(REPO, "tests", "golden", "binary_prerefactor.npz")
ATOL = 1e-6  # pre-refactor parity budget (observed: bit-for-bit on CPU)


@pytest.fixture(scope="module")
def golden():
    return np.load(GOLDEN)


def test_local_estimator_matches_prerefactor(golden):
    cfg = DantzigConfig(max_iters=300)
    p40 = synthetic.make_problem(d=40, n_signal=5)
    x, y = synthetic.sample_two_class(jax.random.PRNGKey(10), p40, 200, 200)
    bt, bh = slda.debiased_local_estimator(x, y, 0.2, 0.25, cfg)
    np.testing.assert_allclose(np.asarray(bt), golden["local_beta_tilde"], atol=ATOL)
    np.testing.assert_allclose(np.asarray(bh), golden["local_beta_hat"], atol=ATOL)
    # lam_prime=None defaults to lam, as before the refactor
    bt2, _ = slda.debiased_local_estimator(x, y, 0.2, None, cfg)
    np.testing.assert_allclose(
        np.asarray(bt2), golden["local_beta_tilde_lamdefault"], atol=ATOL)


def test_simulated_paths_match_prerefactor(golden):
    cfg = DantzigConfig(max_iters=300)
    p30 = synthetic.make_problem(d=30, n_signal=4)
    xs, ys = synthetic.sample_machines(jax.random.PRNGKey(11), p30, 3, 100, 100)
    np.testing.assert_allclose(
        np.asarray(simulated_distributed_slda(xs, ys, 0.2, 0.2, 0.05, cfg)),
        golden["sim_dist"], atol=ATOL)
    np.testing.assert_allclose(
        np.asarray(simulated_debiased_mean(xs, ys, 0.2, 0.2, cfg)),
        golden["sim_mean"], atol=ATOL)
    np.testing.assert_allclose(
        np.asarray(simulated_naive_averaged_slda(xs, ys, 0.2, cfg)),
        golden["sim_naive"], atol=ATOL)


def test_fused_solver_path_matches_prerefactor(golden):
    cfg = DantzigConfig(max_iters=250, adapt_rho=False, fused=True)
    p30 = synthetic.make_problem(d=30, n_signal=4)
    xs, ys = synthetic.sample_machines(jax.random.PRNGKey(11), p30, 3, 100, 100)
    np.testing.assert_allclose(
        np.asarray(simulated_distributed_slda(xs, ys, 0.2, 0.2, 0.05, cfg)),
        golden["sim_dist_fused"], atol=ATOL)


def test_shardmap_remainder_matches_prerefactor():
    """d=7 over |model|=2 (d % size != 0): the padded+masked sharding
    through the new core reproduces the pre-refactor mesh output."""
    out = run_in_subprocess(
        """
        import os
        import jax, numpy as np
        from repro.core.dantzig import DantzigConfig
        from repro.core.distributed import distributed_slda_shardmap
        from repro.stats import synthetic

        g = np.load(os.environ['GOLDEN'])
        p7 = synthetic.make_problem(d=7, n_signal=3)
        xs, ys = synthetic.sample_machines(jax.random.PRNGKey(12), p7, 1, 40, 40)
        mesh = jax.make_mesh((1, 2), ("data", "model"))
        out = distributed_slda_shardmap(
            mesh, xs.reshape(-1, 7), ys.reshape(-1, 7), 0.2, 0.2, 0.05,
            DantzigConfig(max_iters=300))
        np.testing.assert_allclose(np.asarray(out), g['mesh_d7'], atol=1e-6)
        print('MESH_GOLDEN_OK')
        """,
        devices=2,
        env_extra={"GOLDEN": GOLDEN},
    )
    assert "MESH_GOLDEN_OK" in out


# ---------------------------------------------------------------------------
# Structural pins -- AST-based import-graph rules from repro.analysis
# (a comment, docstring, or alias rename can no longer flip these)
# ---------------------------------------------------------------------------


def test_single_pipeline_implementation():
    """slda, distributed and multiclass all call into core/pipeline.py --
    directly (worker_debiased / debias) or through the rounds core
    (worker_rounds / simulate_multi_round, themselves thin over
    pipeline.worker_solves + pipeline.apply_correction) -- and the
    sharded-CLIME gather logic lives only in the pipeline."""
    violations = import_rules.pipeline_unification_violations()
    assert violations == [], [v.render() for v in violations]
    violations = import_rules.exclusive_call_violations()
    assert violations == [], [v.render() for v in violations]
    # the positive half of the gather rule: pipeline really does gather
    pipeline_path = import_rules.SRC_ROOT / "repro" / "core" / "pipeline.py"
    import ast

    calls = [n for n in ast.walk(ast.parse(pipeline_path.read_text()))
             if isinstance(n, ast.Call)
             and isinstance(n.func, ast.Attribute)
             and n.func.attr == "all_gather"]
    assert calls, "pipeline.py lost its all_gather call site"


def test_only_dispatch_layer_imports_dantzig_solver():
    """No module but core/solver_dispatch.py reaches around the dispatch
    layer to core.dantzig's solver entry points."""
    violations = import_rules.banned_import_violations()
    assert violations == [], [v.render() for v in violations]
