"""Multi-class distributed sparse LDA (the paper's future-work extension)."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import multiclass as mc
from repro.core.dantzig import DantzigConfig
from repro.stats import synthetic

CFG = DantzigConfig(max_iters=500)
K = 4


@pytest.fixture(scope="module")
def problem():
    return synthetic.make_mc_problem(d=60, num_classes=K, n_signal=5)


def test_mc_suff_stats(problem):
    xs, labels = synthetic.sample_mc_machines(jax.random.PRNGKey(0), problem, 1, 4000)
    stats = mc.mc_suff_stats(xs[0], labels[0], K)
    assert float(jnp.max(jnp.abs(stats.sigma - problem.sigma))) < 0.2
    assert float(jnp.max(jnp.abs(stats.means - problem.means))) < 0.25
    # within-class scatter is PSD and roughly unit-diagonal for AR(1)
    evals = np.linalg.eigvalsh(np.asarray(stats.sigma, np.float64))
    assert evals.min() > -1e-5


def test_mc_reduces_to_binary(problem):
    """At K=2 the rule reduces to the paper's Fisher rule direction."""
    p2 = synthetic.make_mc_problem(d=40, num_classes=2, n_signal=5)
    # beta_1 - beta_0 = Theta (mu1 - mu0) (the paper's beta*, up to sign)
    diff = p2.betas[:, 1] - p2.betas[:, 0]
    paper = p2.theta @ (p2.means[1] - p2.means[0])
    np.testing.assert_allclose(np.asarray(diff), np.asarray(paper), atol=1e-4)


def test_mc_distributed_recovers_directions(problem):
    d = problem.sigma.shape[0]
    m, n = 4, 500
    xs, labels = synthetic.sample_mc_machines(jax.random.PRNGKey(1), problem, m, n)
    b1 = float(jnp.max(jnp.sum(jnp.abs(problem.betas), axis=0)))
    lam = 0.3 * math.sqrt(math.log(d) / n) * b1
    t = 0.5 * math.sqrt(math.log(d) / (m * n)) * b1
    beta, means = mc.simulated_distributed_mc_slda(xs, labels, K, lam, lam, t, CFG)
    assert beta.shape == (d, K)
    # directions correlate with truth
    for k in range(K):
        bt, bs = beta[:, k], problem.betas[:, k]
        cos = float(bt @ bs / (jnp.linalg.norm(bt) * jnp.linalg.norm(bs) + 1e-9))
        assert cos > 0.75, (k, cos)


def test_mc_distributed_beats_naive_and_classifies(problem):
    d = problem.sigma.shape[0]
    m, n = 4, 400
    xs, labels = synthetic.sample_mc_machines(jax.random.PRNGKey(2), problem, m, n)
    b1 = float(jnp.max(jnp.sum(jnp.abs(problem.betas), axis=0)))
    lam = 0.3 * math.sqrt(math.log(d) / n) * b1
    t = 0.5 * math.sqrt(math.log(d) / (m * n)) * b1
    beta_d, means = mc.simulated_distributed_mc_slda(xs, labels, K, lam, lam, t, CFG)
    beta_n, _ = mc.simulated_naive_mc_slda(xs, labels, K, lam, CFG)
    err_d = float(jnp.linalg.norm(beta_d - problem.betas))
    err_n = float(jnp.linalg.norm(beta_n - problem.betas))
    assert err_d < err_n, (err_d, err_n)

    # held-out classification clearly above chance (K=4 -> 0.25)
    zs, zl = synthetic.sample_mc_machines(jax.random.PRNGKey(3), problem, 1, 2000)
    pred = mc.mc_classify(zs[0], beta_d, means)
    acc = float(jnp.mean(pred == zl[0]))
    assert acc > 0.7, acc


def test_local_mc_slda_dispatches_to_fused_kernel(problem, monkeypatch):
    """cfg.fused=True must reach the fused Pallas kernel.  Multiclass
    used to import solve_dantzig from core.dantzig and relied on that
    module's back-compat shim to reach the dispatch layer; it now routes
    through solver_dispatch directly (structurally pinned by
    test_pipeline_parity), and this test pins the behavior end to end."""
    from repro.core import solver_dispatch

    xs, labels = synthetic.sample_mc_machines(jax.random.PRNGKey(5), problem, 1, 300)
    stats = mc.mc_suff_stats(xs[0], labels[0], K)
    calls = []
    real = solver_dispatch.kops.dantzig_fused

    def spy(*args, **kwargs):
        calls.append(kwargs.get("block_k"))
        return real(*args, **kwargs)

    monkeypatch.setattr(solver_dispatch.kops, "dantzig_fused", spy)
    cfg_fused = DantzigConfig(max_iters=100, adapt_rho=False, fused=True)
    out_fused = mc.local_mc_slda(stats, 0.2, cfg_fused)
    assert calls, "fused=True never reached the Pallas kernel"
    out_scan = mc.local_mc_slda(stats, 0.2, DantzigConfig(max_iters=100, adapt_rho=False))
    assert out_fused.shape == out_scan.shape == (60, K)
    np.testing.assert_allclose(np.asarray(out_fused), np.asarray(out_scan), atol=1e-4)


def test_mc_classify_priors_default_matches_equal(problem):
    """priors=None (default) is exactly the equal-prior rule."""
    xs, labels = synthetic.sample_mc_machines(jax.random.PRNGKey(6), problem, 2, 300)
    beta, means = mc.simulated_distributed_mc_slda(xs, labels, K, 0.2, 0.2, 0.02, CFG)
    zs, _ = synthetic.sample_mc_machines(jax.random.PRNGKey(7), problem, 1, 500)
    pred_default = mc.mc_classify(zs[0], beta, means)
    pred_equal = mc.mc_classify(zs[0], beta, means, priors=jnp.full((K,), 1.0 / K))
    np.testing.assert_array_equal(np.asarray(pred_default), np.asarray(pred_equal))


def test_mc_classify_empirical_priors_beat_equal_when_imbalanced():
    """On an imbalanced draw, + log pi_k with empirical class frequencies
    must beat the equal-prior rule (the docstring promised the term; the
    implementation used to drop it)."""
    K3, d = 3, 40
    problem = synthetic.make_mc_problem(d=d, num_classes=K3, n_signal=4, signal=0.6)
    probs = jnp.asarray([0.7, 0.15, 0.15])
    m, n = 2, 600
    xs, labels = synthetic.sample_mc_machines(
        jax.random.PRNGKey(0), problem, m, n, class_probs=probs)
    lam = 0.3 * math.sqrt(math.log(d) / n) * 4
    beta, means = mc.simulated_distributed_mc_slda(
        xs, labels, K3, lam, lam, 0.2 * lam, DantzigConfig(max_iters=400))
    zs, zl = synthetic.sample_mc_machines(
        jax.random.PRNGKey(1), problem, 1, 4000, class_probs=probs)
    emp = jnp.bincount(labels.reshape(-1), length=K3) / (m * n)
    acc_equal = float(jnp.mean(mc.mc_classify(zs[0], beta, means) == zl[0]))
    acc_priors = float(jnp.mean(
        mc.mc_classify(zs[0], beta, means, priors=emp) == zl[0]))
    assert acc_priors > acc_equal + 0.02, (acc_priors, acc_equal)
