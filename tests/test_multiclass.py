"""Multi-class distributed sparse LDA (the paper's future-work extension)."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import multiclass as mc
from repro.core.dantzig import DantzigConfig
from repro.stats import synthetic

CFG = DantzigConfig(max_iters=500)
K = 4


@pytest.fixture(scope="module")
def problem():
    return synthetic.make_mc_problem(d=60, num_classes=K, n_signal=5)


def test_mc_suff_stats(problem):
    xs, labels = synthetic.sample_mc_machines(jax.random.PRNGKey(0), problem, 1, 4000)
    stats = mc.mc_suff_stats(xs[0], labels[0], K)
    assert float(jnp.max(jnp.abs(stats.sigma - problem.sigma))) < 0.2
    assert float(jnp.max(jnp.abs(stats.means - problem.means))) < 0.25
    # within-class scatter is PSD and roughly unit-diagonal for AR(1)
    evals = np.linalg.eigvalsh(np.asarray(stats.sigma, np.float64))
    assert evals.min() > -1e-5


def test_mc_reduces_to_binary(problem):
    """At K=2 the rule reduces to the paper's Fisher rule direction."""
    p2 = synthetic.make_mc_problem(d=40, num_classes=2, n_signal=5)
    # beta_1 - beta_0 = Theta (mu1 - mu0) (the paper's beta*, up to sign)
    diff = p2.betas[:, 1] - p2.betas[:, 0]
    paper = p2.theta @ (p2.means[1] - p2.means[0])
    np.testing.assert_allclose(np.asarray(diff), np.asarray(paper), atol=1e-4)


def test_mc_distributed_recovers_directions(problem):
    d = problem.sigma.shape[0]
    m, n = 4, 500
    xs, labels = synthetic.sample_mc_machines(jax.random.PRNGKey(1), problem, m, n)
    b1 = float(jnp.max(jnp.sum(jnp.abs(problem.betas), axis=0)))
    lam = 0.3 * math.sqrt(math.log(d) / n) * b1
    t = 0.5 * math.sqrt(math.log(d) / (m * n)) * b1
    beta, means = mc.simulated_distributed_mc_slda(xs, labels, K, lam, lam, t, CFG)
    assert beta.shape == (d, K)
    # directions correlate with truth
    for k in range(K):
        bt, bs = beta[:, k], problem.betas[:, k]
        cos = float(bt @ bs / (jnp.linalg.norm(bt) * jnp.linalg.norm(bs) + 1e-9))
        assert cos > 0.75, (k, cos)


def test_mc_distributed_beats_naive_and_classifies(problem):
    d = problem.sigma.shape[0]
    m, n = 4, 400
    xs, labels = synthetic.sample_mc_machines(jax.random.PRNGKey(2), problem, m, n)
    b1 = float(jnp.max(jnp.sum(jnp.abs(problem.betas), axis=0)))
    lam = 0.3 * math.sqrt(math.log(d) / n) * b1
    t = 0.5 * math.sqrt(math.log(d) / (m * n)) * b1
    beta_d, means = mc.simulated_distributed_mc_slda(xs, labels, K, lam, lam, t, CFG)
    beta_n, _ = mc.simulated_naive_mc_slda(xs, labels, K, lam, CFG)
    err_d = float(jnp.linalg.norm(beta_d - problem.betas))
    err_n = float(jnp.linalg.norm(beta_n - problem.betas))
    assert err_d < err_n, (err_d, err_n)

    # held-out classification clearly above chance (K=4 -> 0.25)
    zs, zl = synthetic.sample_mc_machines(jax.random.PRNGKey(3), problem, 1, 2000)
    pred = mc.mc_classify(zs[0], beta_d, means)
    acc = float(jnp.mean(pred == zl[0]))
    assert acc > 0.7, acc
