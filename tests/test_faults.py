"""Fault-tolerant refinement rounds (DESIGN.md §11).

What is pinned here, and why it is the contract that matters:

* the :class:`FaultSchedule` is DETERMINISTIC and seedable -- a chaos
  run is reproducible bit for bit, so a CI failure is a repro recipe;
* masked aggregation is EXACTLY the mean over the live subset -- not
  an approximation of it -- and with no faults it matches the dense
  round to float tolerance (the legacy unmasked path stays bit-exact
  vs the PR 5 goldens, pinned separately by the golden tests of
  test_compression);
* screening is per machine and total: one NaN/Inf coordinate removes
  that machine's whole contribution; out-of-envelope garbage likewise
  (finite garbage is NOT screened without an envelope -- the envelope
  is the opt-in, the trimmed mean the scale-free alternative);
* graceful degradation: an all-screened round returns the last-good
  aggregate, an all-dead stream returns zeros -- NaN never escapes;
* bounded staleness: a straggler's round-t contribution is its
  correction against the round-(t-s) anchor, s clamped to both the
  bound and the available history;
* the mesh path (shard_map, liveness rows as sharded operands) agrees
  with the vmap twin under the same plan -- the shared round body of
  ``rounds._refinement_rounds`` is what makes this structural.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_in_subprocess
from repro.core import rounds as rounds_core
from repro.core.compression import Compression
from repro.core.dantzig import DantzigConfig
from repro.core.faults import (
    CORRUPT_GARBAGE,
    CORRUPT_INF,
    CORRUPT_NAN,
    Aggregation,
    FaultPlan,
    FaultSchedule,
    masked_mean,
    trimmed_mean,
)
from repro.core.pipeline import BinaryHead
from repro.stats import synthetic

CFG = DantzigConfig(max_iters=80)


def _solves(d=16, m=6, seed=0):
    p = synthetic.make_problem(d=d, n_signal=4, rho=0.5)
    xs, ys = synthetic.sample_machines(jax.random.PRNGKey(seed), p, m,
                                       30, 30)
    _, ws = rounds_core.simulate_multi_round(
        BinaryHead(), (xs, ys), lam=0.3, lam_prime=0.3, rounds=1, cfg=CFG)
    return ws


def _plan(m, rounds, live=None, stale=None, corrupt=None):
    z = jnp.zeros((m, rounds))
    zi = jnp.zeros((m, rounds), jnp.int32)
    return FaultPlan(
        live=jnp.asarray(live, jnp.float32) if live is not None else z + 1,
        stale=jnp.asarray(stale, jnp.int32) if stale is not None else zi,
        corrupt=(jnp.asarray(corrupt, jnp.int32)
                 if corrupt is not None else zi))


# ---------------------------------------------------------------------------
# FaultSchedule: deterministic, seedable, rate-faithful
# ---------------------------------------------------------------------------


def test_schedule_deterministic_and_shaped():
    sched = FaultSchedule(dropout=0.3, straggle=0.2, corrupt=0.1,
                          corrupt_mode="mix", seed=11)
    a = sched.plan(40, 5, max_staleness=2)
    b = sched.plan(40, 5, max_staleness=2)
    for la, lb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    assert a.live.shape == (40, 5)
    assert a.rounds == 5
    assert set(np.unique(np.asarray(a.live))) <= {0.0, 1.0}
    assert np.asarray(a.stale).min() >= 0
    assert np.asarray(a.stale).max() <= 2
    assert np.asarray(a.corrupt).min() >= 0
    assert np.asarray(a.corrupt).max() <= 3
    # a different seed draws a different plan
    c = FaultSchedule(dropout=0.3, straggle=0.2, corrupt=0.1,
                      corrupt_mode="mix", seed=12).plan(40, 5, 2)
    assert not np.array_equal(np.asarray(a.live), np.asarray(c.live))


def test_schedule_rates_approximate_probabilities():
    plan = FaultSchedule(dropout=0.25, seed=3).plan(200, 20)
    rate = 1.0 - float(np.asarray(plan.live).mean())
    assert abs(rate - 0.25) < 0.03


def test_schedule_and_aggregation_validation():
    with pytest.raises(ValueError):
        FaultSchedule(dropout=1.5).validate()
    with pytest.raises(ValueError):
        FaultSchedule(corrupt_mode="bogus").validate()
    with pytest.raises(ValueError):
        Aggregation(trim=0.5).validate()
    with pytest.raises(ValueError):
        Aggregation(envelope=-1.0).validate()


def test_plan_shape_and_type_checks():
    # worker_rounds refuses an unmaterialized schedule (the faces own
    # the plan(m, rounds) call -- a shard can't know m)
    with pytest.raises(TypeError):
        rounds_core._check_plan(FaultSchedule(), (2,), "worker_rounds")
    ws = _solves(m=2)
    with pytest.raises(ValueError):  # machine-count mismatch
        rounds_core.simulate_round_loop(ws, rounds=2, faults=_plan(3, 2))
    with pytest.raises(ValueError):  # round-count mismatch
        rounds_core.simulate_round_loop(ws, rounds=2, faults=_plan(2, 3))


def test_fault_schedule_is_hashable_static():
    a = FaultSchedule(dropout=0.1, seed=2)
    b = FaultSchedule(dropout=0.1, seed=2)
    assert hash(a) == hash(b) and a == b
    assert hash(Aggregation(trim=0.1)) == hash(Aggregation(trim=0.1))


# ---------------------------------------------------------------------------
# masked aggregation == mean over the live subset
# ---------------------------------------------------------------------------


def test_masked_round_is_exact_live_subset_mean():
    ws = _solves(m=6)
    live = [[1.0], [0.0], [1.0], [1.0], [0.0], [1.0]]
    plan = _plan(6, 1, live=live)
    bar = rounds_core.simulate_round_loop(
        ws, rounds=1, faults=plan, aggregation=Aggregation())
    tilde = np.asarray(jax.vmap(rounds_core.refine_step)(ws, ws.beta_hat))
    keep = np.asarray(live)[:, 0] > 0
    expected = tilde[keep].sum(axis=0) / keep.sum()
    np.testing.assert_allclose(np.asarray(bar), expected,
                               rtol=1e-5, atol=1e-7)


def test_masked_nofault_matches_dense_round():
    ws = _solves()
    dense = rounds_core.simulate_round_loop(ws, rounds=3)
    masked = rounds_core.simulate_round_loop(
        ws, rounds=3, aggregation=Aggregation())
    np.testing.assert_allclose(np.asarray(masked), np.asarray(dense),
                               rtol=0, atol=1e-5)


# ---------------------------------------------------------------------------
# screening: NaN / Inf / envelope, per machine, total
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("code", [CORRUPT_NAN, CORRUPT_INF])
def test_nonfinite_machine_screened_entirely(code):
    ws = _solves(m=5)
    corrupt = np.zeros((5, 1), np.int32)
    corrupt[2, 0] = code
    plan = _plan(5, 1, corrupt=corrupt)
    bar = rounds_core.simulate_round_loop(
        ws, rounds=1, faults=plan, aggregation=Aggregation())
    tilde = np.asarray(jax.vmap(rounds_core.refine_step)(ws, ws.beta_hat))
    keep = np.arange(5) != 2
    expected = tilde[keep].mean(axis=0)
    assert np.isfinite(np.asarray(bar)).all()
    np.testing.assert_allclose(np.asarray(bar), expected,
                               rtol=1e-5, atol=1e-7)


def test_envelope_screens_finite_garbage_only_when_set():
    ws = _solves(m=4)
    corrupt = np.zeros((4, 1), np.int32)
    corrupt[1, 0] = CORRUPT_GARBAGE
    plan = _plan(4, 1, corrupt=corrupt)
    tilde = np.asarray(jax.vmap(rounds_core.refine_step)(ws, ws.beta_hat))
    # with an envelope the +-1e12 garbage machine contributes nothing
    bar = rounds_core.simulate_round_loop(
        ws, rounds=1, faults=plan, aggregation=Aggregation(envelope=1e6))
    keep = np.arange(4) != 1
    np.testing.assert_allclose(np.asarray(bar), tilde[keep].mean(axis=0),
                               rtol=1e-5, atol=1e-7)
    # without one, finite garbage is NOT screened (the masked mean is
    # poisoned in magnitude but stays finite) -- the envelope is opt-in
    bar_no = rounds_core.simulate_round_loop(
        ws, rounds=1, faults=plan, aggregation=Aggregation())
    assert np.isfinite(np.asarray(bar_no)).all()
    assert float(np.abs(np.asarray(bar_no)).max()) > 1e9


def test_all_screened_round_returns_last_good_chaos_pin():
    """The chaos pin: a round where EVERY machine is screened falls
    back to the last-good aggregate; the stream never emits NaN."""
    ws = _solves(m=4)
    # round 1 clean, round 2 all-NaN
    corrupt = np.zeros((4, 2), np.int32)
    corrupt[:, 1] = CORRUPT_NAN
    plan = _plan(4, 2, corrupt=corrupt)
    bars = rounds_core.simulate_round_loop(
        ws, rounds=2, faults=plan, aggregation=Aggregation(),
        return_all_rounds=True)
    bars = np.asarray(bars)
    assert np.isfinite(bars).all()
    np.testing.assert_array_equal(bars[1], bars[0])
    # an ALL-NaN stream returns the zeros init, still no NaN
    all_bad = _plan(4, 2, corrupt=np.full((4, 2), CORRUPT_NAN, np.int32))
    bar = rounds_core.simulate_round_loop(
        ws, rounds=2, faults=all_bad, aggregation=Aggregation())
    np.testing.assert_array_equal(np.asarray(bar),
                                  np.zeros_like(np.asarray(bar)))


# ---------------------------------------------------------------------------
# bounded staleness
# ---------------------------------------------------------------------------


def test_zero_stale_plan_with_bound_is_bit_exact():
    ws = _solves()
    ref = rounds_core.simulate_round_loop(
        ws, rounds=3, faults=_plan(6, 3), aggregation=Aggregation())
    stale = rounds_core.simulate_round_loop(
        ws, rounds=3, faults=_plan(6, 3), staleness=2,
        aggregation=Aggregation())
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(stale))


def test_straggler_uses_round_t_minus_s_anchor():
    ws = _solves(m=3)
    stale = np.zeros((3, 2), np.int32)
    stale[0, 1] = 1  # machine 0 straggles in round 2
    plan = _plan(3, 2, stale=stale)
    bars = rounds_core.simulate_round_loop(
        ws, rounds=2, faults=plan, staleness=1, aggregation=Aggregation(),
        return_all_rounds=True)
    # manual: round 1 as usual; in round 2 machine 0's correction is
    # taken against its ROUND-1 anchor (its own beta_hat), machines
    # 1..2 against the round-1 aggregate
    tilde1 = jax.vmap(rounds_core.refine_step)(ws, ws.beta_hat)
    bar1 = jnp.mean(tilde1, axis=0)
    anchor2 = jnp.broadcast_to(bar1[None], ws.beta_hat.shape)
    fresh = jax.vmap(rounds_core.refine_step)(ws, anchor2)
    tilde2 = fresh.at[0].set(tilde1[0])
    np.testing.assert_allclose(np.asarray(bars[0]), np.asarray(bar1),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(bars[1]),
                               np.asarray(jnp.mean(tilde2, axis=0)),
                               rtol=1e-5, atol=1e-7)


def test_staleness_clamped_to_bound_and_history():
    ws = _solves(m=3)
    deep = np.full((3, 2), 5, np.int32)  # deeper than any history
    plan = _plan(3, 2, stale=deep)
    capped = rounds_core.simulate_round_loop(
        ws, rounds=2, faults=plan, staleness=1, aggregation=Aggregation())
    one = _plan(3, 2, stale=np.ones((3, 2), np.int32))
    expected = rounds_core.simulate_round_loop(
        ws, rounds=2, faults=one, staleness=1, aggregation=Aggregation())
    np.testing.assert_array_equal(np.asarray(capped), np.asarray(expected))


# ---------------------------------------------------------------------------
# masked / trimmed aggregation primitives
# ---------------------------------------------------------------------------


def test_trimmed_mean_matches_numpy_reference():
    key = jax.random.PRNGKey(0)
    stack = jax.random.normal(key, (10, 7, 2))
    w = jnp.ones((10,))
    got, den = trimmed_mean(stack, w, 0.2)  # per-side cut = 2
    srt = np.sort(np.asarray(stack), axis=0)
    np.testing.assert_allclose(np.asarray(got), srt[2:-2].mean(axis=0),
                               rtol=1e-5, atol=1e-7)
    assert float(den) == 10.0


def test_trimmed_mean_dead_machines_do_not_occupy_trim_slots():
    stack = jnp.stack([jnp.full((3, 1), v) for v in
                       (0.0, 1.0, 2.0, 3.0, 100.0, -100.0)])
    w = jnp.asarray([1.0, 1.0, 1.0, 1.0, 1.0, 0.0])  # -100 machine dead
    got, den = trimmed_mean(stack, w, 1.0 / 6.0)  # per-side cut = 1
    # live sorted: 0 1 2 3 100 -> drop 0 and 100 -> mean(1, 2, 3) = 2
    np.testing.assert_allclose(np.asarray(got), np.full((3, 1), 2.0),
                               rtol=1e-6)
    assert float(den) == 5.0


def test_masked_mean_all_dead_returns_zero_count():
    stack = jnp.ones((4, 3, 1)) * jnp.nan
    got, den = masked_mean(stack, jnp.zeros((4,)))
    assert float(den) == 0.0
    np.testing.assert_array_equal(np.asarray(got), np.zeros((3, 1)))


def test_trimmed_round_beats_unscreened_garbage():
    """The trimmed mode is the no-envelope defense: per-coordinate
    trimming discards the garbage machine without knowing its scale."""
    ws = _solves(m=8)
    corrupt = np.zeros((8, 1), np.int32)
    corrupt[3, 0] = CORRUPT_GARBAGE
    plan = _plan(8, 1, corrupt=corrupt)
    trimmed = rounds_core.simulate_round_loop(
        ws, rounds=1, faults=plan, aggregation=Aggregation(trim=0.2))
    untrimmed = rounds_core.simulate_round_loop(
        ws, rounds=1, faults=plan, aggregation=Aggregation())
    clean = rounds_core.simulate_round_loop(ws, rounds=1)
    err_t = float(np.abs(np.asarray(trimmed) - np.asarray(clean)).max())
    err_u = float(np.abs(np.asarray(untrimmed) - np.asarray(clean)).max())
    assert err_t < 1.0 < err_u


# ---------------------------------------------------------------------------
# compression interplay
# ---------------------------------------------------------------------------


def test_compressed_masked_dropout_screens_and_stays_finite():
    ws = _solves(m=6, d=16)
    comp = Compression(5, "int8")
    sched = FaultSchedule(dropout=0.3, corrupt=0.3, corrupt_mode="mix",
                          seed=9)
    bar = rounds_core.simulate_round_loop(
        ws, rounds=3, compression=comp, faults=sched,
        aggregation=Aggregation(envelope=1e6))
    assert np.isfinite(np.asarray(bar)).all()


def test_dropped_machine_ef_residual_carries_unchanged():
    ws = _solves(m=3, d=16)
    comp = Compression(4)
    live = np.ones((3, 1), np.float32)
    live[1, 0] = 0.0  # machine 1 drops the round
    plan = _plan(3, 1, live=live)
    _, resid = rounds_core.simulate_round_loop(
        ws, rounds=1, compression=comp, faults=plan,
        aggregation=Aggregation(), return_ef_residual=True)
    # a dropped machine computed nothing: its EF carry is still zero
    np.testing.assert_array_equal(np.asarray(resid[1]),
                                  np.zeros_like(np.asarray(resid[1])))
    assert float(np.abs(np.asarray(resid[0])).max()) > 0


# ---------------------------------------------------------------------------
# mesh parity (forced host devices, subprocess)
# ---------------------------------------------------------------------------


def test_mesh_masked_faulted_matches_sim_twin():
    """(data=2, model=4) mesh under dropout+staleness+mixed corruption,
    dense AND compressed: the liveness rows ride shard_map as sharded
    operands and the result matches the vmap twin under the SAME
    schedule seed."""
    out = run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np, math
        from repro.core.dantzig import DantzigConfig
        from repro.core.compression import Compression
        from repro.core.distributed import (
            distributed_slda_shardmap, simulated_distributed_slda)
        from repro.core.faults import Aggregation, FaultSchedule
        from repro.stats import synthetic

        cfg = DantzigConfig(max_iters=200)
        m, d = 2, 16
        p = synthetic.make_problem(d=d, n_signal=4, rho=0.5)
        xs, ys = synthetic.sample_machines(jax.random.PRNGKey(5), p, m, 40, 40)
        lam = 0.3 * math.sqrt(math.log(d) / 80) * 4
        t = 0.25 * lam
        sched = FaultSchedule(dropout=0.4, straggle=0.3, corrupt=0.3,
                              corrupt_mode="mix", seed=21)
        agg = Aggregation(envelope=1e6)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for comp in (None, Compression(5, "int8")):
            sim = simulated_distributed_slda(
                xs, ys, lam, lam, t, cfg, rounds=3, compression=comp,
                faults=sched, staleness=2, aggregation=agg)
            out = distributed_slda_shardmap(
                mesh, xs.reshape(-1, d), ys.reshape(-1, d), lam, lam, t,
                cfg, rounds=3, compression=comp, faults=sched, staleness=2,
                aggregation=agg)
            np.testing.assert_allclose(np.asarray(out), np.asarray(sim),
                                       atol=1e-5)
        print("FAULT_MESH_PARITY_OK")
        """
    )
    assert "FAULT_MESH_PARITY_OK" in out


def test_mesh_compressed_reentry_matches_uninterrupted():
    """Mid-stream re-entry on the MESH path: a T=3 compressed run split
    as 1+2 via ``return_ef_residual`` + ``resume_from`` reproduces the
    uninterrupted stream bit for bit (the sim twin's replay is pinned
    in test_compression)."""
    out = run_in_subprocess(
        """
        import jax, jax.numpy as jnp, numpy as np, math
        from jax.sharding import PartitionSpec as P
        from repro.core import rounds as rounds_core
        from repro.core.compression import Compression
        from repro.core.dantzig import DantzigConfig
        from repro.core.distributed import _shard_map
        from repro.core.pipeline import BinaryHead
        from repro.stats import synthetic

        cfg = DantzigConfig(max_iters=200)
        m, d = 2, 16
        p = synthetic.make_problem(d=d, n_signal=4, rho=0.5)
        xs, ys = synthetic.sample_machines(jax.random.PRNGKey(6), p, m, 40, 40)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        comp = Compression(5, "int8")
        spec = P("data", None)

        def run(t_rounds, resume_from=None, ef_residual=None):
            extra, specs = (), [spec, spec]
            if ef_residual is not None:
                extra = (ef_residual,)
                specs.append(P("data", None, None))

            def shard_fn(x, y, *rest):
                bar, _, resid = rounds_core.worker_rounds(
                    BinaryHead(), x, y, lam=0.3, lam_prime=0.3,
                    rounds=t_rounds, cfg=cfg, model_axis="model",
                    model_axis_size=4, compression=comp,
                    resume_from=resume_from,
                    ef_residual=rest[0][0] if rest else None,
                    return_ef_residual=True)
                return bar, resid[None]

            fn = _shard_map(shard_fn, mesh, tuple(specs),
                            (P(), P("data", None, None)))
            return fn(xs.reshape(-1, d), ys.reshape(-1, d), *extra)

        full, _ = run(3)
        half, resid = run(1)
        resumed, _ = run(2, resume_from=jnp.asarray(half),
                         ef_residual=jnp.asarray(resid))
        np.testing.assert_array_equal(np.asarray(full), np.asarray(resumed))
        print("MESH_REENTRY_OK")
        """
    )
    assert "MESH_REENTRY_OK" in out


# ---------------------------------------------------------------------------
# the faces thread the knobs
# ---------------------------------------------------------------------------


def test_faces_accept_fault_knobs():
    from repro.core.multiclass import mc_multi_round_slda
    from repro.core.slda import multi_round_slda

    d, m = 12, 4
    p = synthetic.make_problem(d=d, n_signal=3, rho=0.5)
    xs, ys = synthetic.sample_machines(jax.random.PRNGKey(8), p, m, 24, 24)
    sched = FaultSchedule(dropout=0.3, seed=13)
    bar = multi_round_slda(xs, ys, 0.3, 0.3, 0.05, rounds=2, cfg=CFG,
                           faults=sched, staleness=1,
                           aggregation=Aggregation())
    assert np.isfinite(np.asarray(bar)).all()

    mp = synthetic.make_mc_problem(d=10, num_classes=3, n_signal=3)
    mxs, mlabels = synthetic.sample_mc_machines(
        jax.random.PRNGKey(9), mp, 3, 45)
    beta, means = mc_multi_round_slda(
        mxs, mlabels, 3, 0.3, 0.3, 0.05, rounds=2, cfg=CFG,
        faults=sched, aggregation=Aggregation())
    assert np.isfinite(np.asarray(beta)).all()
    assert np.isfinite(np.asarray(means)).all()


def test_fault_free_faces_bit_exact_vs_legacy():
    """faults=None/aggregation=None is LITERALLY the legacy program:
    the threaded call signature changes nothing about the no-fault
    output (the golden files pin the absolute values)."""
    from repro.core.slda import multi_round_slda

    d, m = 12, 4
    p = synthetic.make_problem(d=d, n_signal=3, rho=0.5)
    xs, ys = synthetic.sample_machines(jax.random.PRNGKey(8), p, m, 24, 24)
    legacy = multi_round_slda(xs, ys, 0.3, 0.3, 0.05, rounds=3, cfg=CFG)
    threaded = multi_round_slda(xs, ys, 0.3, 0.3, 0.05, rounds=3, cfg=CFG,
                                faults=None, staleness=0, aggregation=None)
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(threaded))
