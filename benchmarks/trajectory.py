"""Consolidate the root BENCH_*.json mirrors into one trajectory artifact.

Every benchmark mirrors its machine-readable output to the repo root
(``BENCH_<name>.json``, see :func:`benchmarks.common.write_bench_json`);
the committed mirrors are the cross-PR perf/accuracy trajectory that
``benchmarks/ci_gate.py`` gates against.  This module rolls the
CURRENT set of mirrors into ONE ``TRAJECTORY.json`` under
``experiments/bench/`` so CI can upload a single artifact per run --
one file to download and diff across workflow runs instead of a
scatter of per-benchmark blobs.

Run-volatile provenance (``generated_unix``, ``host``) is stripped via
:func:`benchmarks.ci_gate.comparable`, so two trajectory files from
runs of the same code are textually identical -- any diff is a real
change in measured numbers or schema.  Payloads written before
``schema_version`` existed are recorded at version 0.

Usage: ``PYTHONPATH=src python -m benchmarks.trajectory``
"""

from __future__ import annotations

import glob
import json
import os
import sys

from benchmarks.ci_gate import comparable
from benchmarks.common import OUT_DIR, REPO_DIR, SCHEMA_VERSION

TRAJECTORY_PATH = os.path.join(OUT_DIR, "TRAJECTORY.json")


def collect() -> tuple[dict, list[str]]:
    """Read every root BENCH_*.json mirror; returns (trajectory, skipped).

    Unparseable mirrors are skipped with a notice rather than failing
    the run -- a corrupt artifact should surface as a missing entry in
    the uploaded trajectory, not mask the good ones.
    """
    benchmarks = {}
    skipped = []
    for path in sorted(glob.glob(os.path.join(REPO_DIR, "BENCH_*.json"))):
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, ValueError):
            skipped.append(os.path.basename(path))
            continue
        name = payload.get("name") or os.path.basename(path)[len("BENCH_"):-len(".json")]
        payload.setdefault("schema_version", 0)
        benchmarks[name] = comparable(payload)
    return {
        "schema_version": SCHEMA_VERSION,
        "benchmarks": benchmarks,
    }, skipped


def main() -> int:
    trajectory, skipped = collect()
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(TRAJECTORY_PATH, "w") as f:
        f.write(json.dumps(trajectory, indent=2, sort_keys=True,
                           default=float) + "\n")
    for name in skipped:
        print(f"[trajectory] skipped unreadable mirror {name}",
              file=sys.stderr)
    for name, payload in sorted(trajectory["benchmarks"].items()):
        extras = sorted(k for k in payload
                        if k not in ("name", "schema_version", "backend",
                                     "rows"))
        print(f"[trajectory] {name}: {len(payload.get('rows', []))} rows, "
              f"schema v{payload['schema_version']}"
              + (f", extras: {', '.join(extras)}" if extras else ""))
    print(f"[trajectory] wrote {TRAJECTORY_PATH} "
          f"({len(trajectory['benchmarks'])} benchmarks)")
    if not trajectory["benchmarks"]:
        print("[trajectory] no root BENCH_*.json mirrors found",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
