"""Fault-tolerant refinement rounds: recovery under dropout/staleness.

The refinement rounds of ``benchmarks/multi_round.py`` assume all m
machines contribute a finite payload to every round's mean; this
benchmark prices that assumption.  The SAME per-machine solves (one
set per repeat, via :func:`repro.core.rounds.simulate_round_loop`)
drive the round schedule under a deterministic
:class:`~repro.core.faults.FaultSchedule` -- per-round dropout,
bounded-staleness straggling, payload corruption -- with and without
the liveness-masked aggregation of DESIGN.md §11, so every curve
differs only in the fault model and the aggregation rule.

Sections:

  * recovery vs DROPOUT rate (0 / 10% / 20% / 30% per round), masked
    aggregation vs the unmasked mean (dropped slots dilute the
    unmasked mean by the full m -- the paper's aggregate shrinks
    toward zero);
  * recovery vs STALENESS bound (30% stragglers re-submitting against
    the round-(t-s) anchor, s = 1, 2), masked;
  * composition with the PR 7 compressed uplink (top-20% + int8 under
    10% dropout, masked) -- the fault layer screens the decoded
    per-machine blocks, so a corrupted int8 scale cannot poison the
    error-feedback aggregate;
  * chaos sanity, asserted inline: ALL machines corrupted with NaN
    payloads in every round -> the masked aggregate falls back to the
    last-good value and stays finite; all machines dead -> zeros, not
    NaN.

Gates (also enforced by ``benchmarks/ci_gate.py``): at d=100/m=60/T=3
with 10% per-round dropout, masked aggregation keeps excess-l2
recovery ``(l2_t1 - l2_t3) / (l2_t1 - l2_cent)`` within 10%
(relative) of the no-fault run and F1 within 0.02, while the unmasked
baseline lands demonstrably below that floor.

Quick mode (default, CI-sized): the compressed_rounds operating point
-- d=100, N=6000, m=60, 2 repeats, same seed folds.  ``--paper``
scales to d=200, N=10000, m=80, rho=0.8, 6 repeats.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    print_table,
    tuned_metrics,
    write_bench_json,
    write_csv,
)
from repro.core import rounds as rounds_core
from repro.core.compression import Compression
from repro.core.dantzig import DantzigConfig
from repro.core.faults import Aggregation, FaultPlan, FaultSchedule
from repro.core.pipeline import BinaryHead
from repro.core.slda import centralized_slda
from repro.stats import synthetic

T_GRID = np.geomspace(0.005, 2.0, 25)
ROUNDS = 3
T_GATE = 3
DROPOUTS = (0.0, 0.1, 0.2, 0.3)
GATE_DROPOUT = 0.1
STRAGGLE = 0.3
STALENESS_BOUNDS = (1, 2)
# masked recovery within 10% (relative) of no-fault, F1 within 0.02
REC_SLACK = 0.10
F1_SLACK = 0.02
MASKED = Aggregation()


def _scenarios(d: int):
    """(name, dict of simulate_round_loop fault kwargs) rows.

    ``faults`` entries hold a schedule FACTORY (seed folded per repeat
    at run time) so every scenario sees a fresh fault draw per repeat
    while staying deterministic end to end.
    """
    comp = Compression(max(1, d // 5), "int8")
    rows = [("nofault", dict())]
    for p in DROPOUTS:
        if p == 0.0:
            continue
        mk = (lambda p: lambda seed: FaultSchedule(dropout=p, seed=seed))(p)
        rows.append((f"drop{p:.1f}-masked",
                     dict(faults=mk, aggregation=MASKED)))
        rows.append((f"drop{p:.1f}-unmasked", dict(faults=mk)))
    for s in STALENESS_BOUNDS:
        mk = (lambda s: lambda seed: FaultSchedule(
            straggle=STRAGGLE, seed=seed))(s)
        rows.append((f"straggle{STRAGGLE:.1f}-s{s}-masked",
                     dict(faults=mk, staleness=s, aggregation=MASKED)))
    mk = lambda seed: FaultSchedule(dropout=GATE_DROPOUT, seed=seed)
    rows.append((f"drop{GATE_DROPOUT:.1f}-top20pct-int8-masked",
                 dict(faults=mk, aggregation=MASKED, compression=comp)))
    return rows


def _chaos_asserts(ws, m: int) -> None:
    """The graceful-degradation pins, asserted on live numbers."""
    # every machine NaN-corrupted in every round: screening zeroes all
    # of them, the round returns the last-good aggregate (zeros before
    # any round succeeded) -- never NaN
    all_nan = FaultSchedule(corrupt=1.0, corrupt_mode="nan", seed=7)
    bar = rounds_core.simulate_round_loop(
        ws, rounds=ROUNDS, faults=all_nan, aggregation=MASKED)
    assert np.isfinite(np.asarray(bar)).all(), (
        "all-NaN rounds leaked non-finite values through the mask")
    # every machine dead in every round: zeros, not NaN
    dead = FaultPlan(live=jnp.zeros((m, ROUNDS)),
                     stale=jnp.zeros((m, ROUNDS), jnp.int32),
                     corrupt=jnp.zeros((m, ROUNDS), jnp.int32))
    bar = rounds_core.simulate_round_loop(
        ws, rounds=ROUNDS, faults=dead, aggregation=MASKED)
    assert (np.asarray(bar) == 0).all(), (
        "all-dead rounds must return the zeros last-good aggregate")


def recovery_under_faults(paper: bool, seed: int = 0):
    if paper:
        d, n_total, m, repeats = 200, 10_000, 80, 6
        rho, iters = 0.8, 600
    else:
        d, n_total, m, repeats = 100, 6_000, 60, 2
        rho, iters = 0.6, 400
    cfg = DantzigConfig(max_iters=iters)
    problem = synthetic.make_problem(d=d, n_signal=10, rho=rho)
    b1 = float(jnp.sum(jnp.abs(problem.beta_star)))
    n = n_total // m
    n1 = n2 = n // 2
    lam = 0.30 * math.sqrt(math.log(d) / n) * b1
    lam_c = 0.30 * math.sqrt(math.log(d) / n_total) * b1
    swept = _scenarios(d)

    acc: dict[tuple, list] = {}
    for rep in range(repeats):
        # the SAME draws as compressed_rounds/multi_round at this m
        key = jax.random.fold_in(jax.random.PRNGKey(seed), m * 1000 + rep)
        xs, ys = synthetic.sample_machines(key, problem, m, n1, n2)
        cent = centralized_slda(xs.reshape(-1, d), ys.reshape(-1, d),
                                lam_c, cfg)
        acc.setdefault("l2_cent", []).append(
            tuned_metrics(cent, problem.beta_star, T_GRID)["l2"])
        # ONE set of per-machine solves serves every fault scenario
        _, ws = rounds_core.simulate_multi_round(
            BinaryHead(), (xs, ys), lam=lam, lam_prime=lam,
            rounds=1, cfg=cfg)
        for name, kw in swept:
            kw = dict(kw)
            if "faults" in kw:
                kw["faults"] = kw["faults"](1000 + rep)
            bars = rounds_core.simulate_round_loop(
                ws, rounds=ROUNDS, return_all_rounds=True, **kw)
            assert np.isfinite(np.asarray(bars)).all(), (name, rep)
            for t_rounds in range(1, ROUNDS + 1):
                mt = tuned_metrics(bars[t_rounds - 1][:, 0],
                                   problem.beta_star, T_GRID)
                acc.setdefault((name, t_rounds, "f1"), []).append(mt["f1"])
                acc.setdefault((name, t_rounds, "l2"), []).append(mt["l2"])
        _chaos_asserts(ws, m)

    def mean(k):
        return sum(acc[k]) / len(acc[k])

    header = ["scenario", "T", "F1", "l2", "recovery"]
    l2_cent = mean("l2_cent")
    l2_t1 = mean(("nofault", 1, "l2"))

    def recovery(name, t_rounds=T_GATE):
        l2_t = mean((name, t_rounds, "l2"))
        return (l2_t1 - l2_t) / max(l2_t1 - l2_cent, 1e-12)

    rows = []
    for name, _ in swept:
        for t_rounds in range(1, ROUNDS + 1):
            rows.append([name, t_rounds, mean((name, t_rounds, "f1")),
                         mean((name, t_rounds, "l2")),
                         recovery(name, t_rounds)])

    g_masked = f"drop{GATE_DROPOUT:.1f}-masked"
    g_unmasked = f"drop{GATE_DROPOUT:.1f}-unmasked"
    gate = {
        "d": d, "m": m, "rounds": T_GATE, "dropout": GATE_DROPOUT,
        "rec_nofault": recovery("nofault"),
        "rec_masked": recovery(g_masked),
        "rec_unmasked": recovery(g_unmasked),
        "f1_nofault": mean(("nofault", T_GATE, "f1")),
        "f1_masked": mean((g_masked, T_GATE, "f1")),
        "f1_unmasked": mean((g_unmasked, T_GATE, "f1")),
        "rec_slack": REC_SLACK, "f1_slack": F1_SLACK,
        "l2_cent": l2_cent, "l2_t1": l2_t1,
        "l2_t3_masked": mean((g_masked, T_GATE, "l2")),
        "l2_t3_unmasked": mean((g_unmasked, T_GATE, "l2")),
        "rec_compressed": recovery(
            f"drop{GATE_DROPOUT:.1f}-top20pct-int8-masked"),
    }
    return header, rows, gate


def main(paper: bool = False) -> None:
    header, rows, gate = recovery_under_faults(paper)
    print_table("fault-tolerant refinement rounds: recovery under "
                "dropout / staleness / corruption", header, rows)

    write_csv("fault_rounds.csv", header, rows)
    jpath = write_bench_json("fault_rounds", header, rows, faults=gate)
    print(f"[fault_rounds] wrote {jpath}")
    print(f"[fault_rounds] gate at d={gate['d']}/m={gate['m']}/"
          f"T={gate['rounds']}, dropout={gate['dropout']:.0%}: "
          f"masked rec {gate['rec_masked']:.3f} / F1 "
          f"{gate['f1_masked']:.3f} vs no-fault {gate['rec_nofault']:.3f}"
          f" / {gate['f1_nofault']:.3f}; unmasked rec "
          f"{gate['rec_unmasked']:.3f}")

    rec_floor = gate["rec_nofault"] - gate["rec_slack"] * max(
        abs(gate["rec_nofault"]), 1e-9)
    assert gate["rec_masked"] >= rec_floor, (
        "masked aggregation lost more than 10% of the no-fault "
        "excess-l2 recovery under 10% dropout", gate)
    assert gate["f1_masked"] >= gate["f1_nofault"] - gate["f1_slack"], (
        "masked aggregation lost more than 0.02 F1 under 10% dropout",
        gate)
    assert gate["rec_unmasked"] < rec_floor, (
        "the unmasked baseline did not degrade -- the fault injection "
        "is not biting", gate)


if __name__ == "__main__":
    import sys

    main(paper="--paper" in sys.argv)
