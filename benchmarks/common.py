"""Shared benchmark harness: timing, CSV/JSON output, tuning grids."""

from __future__ import annotations

import csv
import json
import os
import platform
import time
from typing import Iterable, Sequence

REPO_DIR = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_DIR = os.path.join(REPO_DIR, "experiments", "bench")

# BENCH_*.json schema version: bump on any structural change to the
# payload layout so the cross-PR trajectory tooling (ci_gate baselines,
# benchmarks/trajectory.py) can refuse to diff incompatible shapes
# instead of misreading them
SCHEMA_VERSION = 1
# run-volatile payload fields: present for provenance, excluded from
# any cross-run comparison (see ci_gate.comparable)
VOLATILE_KEYS = ("generated_unix", "host")


def write_csv(name: str, header: Sequence[str], rows: Iterable[Sequence]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, name)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        for r in rows:
            w.writerow(r)
    return path


def bench_json_path(name: str) -> str:
    return os.path.join(OUT_DIR, f"BENCH_{name}.json")


def write_bench_json(name: str, header: Sequence[str],
                     rows: Iterable[Sequence], **extra) -> str:
    """Machine-readable twin of :func:`write_csv`: BENCH_<name>.json.

    Schema (``schema_version`` = :data:`SCHEMA_VERSION`): ``{"name",
    "schema_version", "generated_unix", "backend", "host", "rows":
    [{col: value, ...}, ...], **extra}``.  Rows mirror the CSV so the
    perf trajectory (timings + HBM model per shape) can be diffed
    across PRs and gated in CI (see ``benchmarks/ci_gate.py``).  Keys
    are SORTED so committed mirrors diff cleanly across regenerations
    -- the only churn in a no-change rerun is the :data:`VOLATILE_KEYS`
    provenance fields, which the comparison tooling strips.

    Every file is MIRRORED to the repo root (``BENCH_<name>.json``):
    the cross-PR perf-trajectory tooling reads the root-level files,
    so writing only ``experiments/bench/`` makes the trajectory read
    as empty.
    """
    import jax

    os.makedirs(OUT_DIR, exist_ok=True)
    payload = {
        "name": name,
        "schema_version": SCHEMA_VERSION,
        "generated_unix": time.time(),
        "backend": jax.default_backend(),
        "host": platform.node(),
        "rows": [dict(zip(header, r)) for r in rows],
    }
    payload.update(extra)
    blob = json.dumps(payload, indent=2, default=float,
                      sort_keys=True) + "\n"
    path = bench_json_path(name)
    with open(path, "w") as f:
        f.write(blob)
    with open(os.path.join(REPO_DIR, f"BENCH_{name}.json"), "w") as f:
        f.write(blob)
    return path


def print_table(title: str, header: Sequence[str], rows: Iterable[Sequence]) -> None:
    rows = [list(map(_fmt, r)) for r in rows]
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(header)]
    print(f"\n== {title} ==")
    print("  ".join(h.ljust(w) for h, w in zip(header, widths)))
    for r in rows:
        print("  ".join(c.ljust(w) for c, w in zip(r, widths)))


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0


def tuned_metrics(raw, beta_star, t_grid):
    """Grid-tune the hard threshold post hoc, per metric.

    The paper tunes constants by grid search and reports the best
    result per method; HT is O(d) so the tuning is free given the raw
    (un-thresholded) estimator.  Returns {f1, l2, linf} at the per-
    metric best t.
    """
    import jax.numpy as jnp

    from repro.core import classifier
    from repro.core.slda import hard_threshold

    best = {"f1": 0.0, "l2": float("inf"), "linf": float("inf")}
    for t in t_grid:
        beta = hard_threshold(raw, float(t))
        err = classifier.estimation_errors(beta, beta_star)
        best["f1"] = max(best["f1"], float(classifier.f1_score(beta, beta_star)))
        best["l2"] = min(best["l2"], float(err["l2"]))
        best["linf"] = min(best["linf"], float(err["linf"]))
    return best
