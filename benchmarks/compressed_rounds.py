"""Accuracy vs bits moved: top-k error-feedback uplinks (DESIGN.md §10).

The refinement rounds of ``benchmarks/multi_round.py`` recover the
centralized rate past the one-shot m-barrier, but each round moves a
dense (d, K) float32 block per machine.  This benchmark prices that
recovery in BITS: the same per-machine solves (ONE set per repeat,
via :func:`repro.core.rounds.simulate_round_loop`) drive the round
schedule under every :class:`~repro.core.compression.Compression`
config, so the accuracy-vs-bits curves differ only in the uplink.

Per config and round count T it reports tuned support-recovery F1 and
l2 error next to the per-round and total uplink bits of
:func:`repro.core.compression.uplink_bits` -- the SAME numbers the
``AxisPayloadBits`` trace contract pins on the mesh path's jaxpr, so
a row's bits column is an asserted property of the lowered program.

Gates (also enforced by ``benchmarks/ci_gate.py``):

  * the gated config (top-20% + int8 delta quantization + int16
    indices) moves <= 25% of the dense per-round bits -- by exact
    accounting, not estimate;
  * at the largest-m operating point and T=3 rounds it stays within
    1% of the DENSE rounds' F1 and of their excess-l2 recovery
    ``(l2_t1_dense - l2_t3) / (l2_t1_dense - l2_cent)`` -- the error
    feedback is what makes this hold: dropped coordinates are delayed
    into later rounds, never lost, so the refinement fixed point is
    unchanged;
  * the identity codec (k_top = d, no quantization) reproduces the
    dense trajectory BIT-EXACTLY (set-semantics decode), asserted on
    every repeat.

Since the two-way transport layer (DESIGN.md §13) the same solves also
drive the SCHEDULE sweep: round-adaptive :class:`~repro.core.transport.
BitBudget` planners (constant / taper / probe-weighted adaptive) that
compress BOTH directions under one TOTAL bit budget.  The gated taper
schedule must move <= 25% of the dense TOTAL (uplink + downlink) bits
at F1 parity with the dense rounds, and its planned per-round
``(k_up, k_down)`` pairs and bit totals are compared EXACTLY against
the committed baseline by ``benchmarks/ci_gate.py``.

Quick mode (default, CI-sized): the multi_round quick operating point
at its largest machine count -- d=100, N=6000, m=60, 2 repeats, the
same draws (same seed fold) as the m-barrier benchmark.  ``--paper``
scales to the section-5 grid of :mod:`repro.configs.paper_synthetic`
(d=200, N=10000, rho=0.8) at m=80, 6 repeats.  ``--schedules`` runs
the schedule sweep alone (CI-sized, no artifact write) as a fast gate.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    print_table,
    tuned_metrics,
    write_bench_json,
    write_csv,
)
from repro.configs import SYNTHETIC
from repro.core import compression as compression_core
from repro.core import rounds as rounds_core
from repro.core.compression import Compression
from repro.core.dantzig import DantzigConfig
from repro.core.pipeline import BinaryHead
from repro.core.slda import centralized_slda
from repro.core.transport import BitBudget, CommPlan, Transport
from repro.stats import synthetic

T_GRID = np.geomspace(0.005, 2.0, 25)
ROUNDS = 4  # trajectory length; the gate reads T = T_GATE
T_GATE = 3
# the headline budget: the gated config must move at most this fraction
# of the dense per-round uplink bits ...
BITS_BUDGET = 0.25
# ... while staying within 1% of the dense rounds' F1 and recovery
F1_SLACK = 0.01
REC_SLACK = 0.01
GATED_CONFIG = "top20pct-int8"
GATED_SCHEDULE = "taper50-int8"
# probe-measured per-round delta norms on the quick operating point
# (the dense trajectory's ||bar_t - bar_{t-1}||, seed 0): the input the
# "adaptive" planner needs, since trace time cannot see data
PROBE_WEIGHTS = (1.63, 0.63, 0.55)


def schedules(dense_total_bits: int) -> list[tuple[str, BitBudget]]:
    """The swept :class:`BitBudget` planners, budgets as fractions of
    the dense TOTAL (uplink + downlink, all ``T_GATE`` rounds).

    ``taper50-int8`` is the gated point (<= 25% of dense total);
    ``const-int8`` spends the same budget evenly (no front-loading);
    ``adaptive-int8`` follows the probe-measured round deltas;
    ``taper50-int8-b50pct`` is the half-dense reference.
    """
    budget = int(BITS_BUDGET * dense_total_bits)
    return [
        (GATED_SCHEDULE, BitBudget(budget, "taper", taper=0.5,
                                   quantize="int8", down_fraction=0.5)),
        ("const-int8", BitBudget(budget, "constant", quantize="int8")),
        ("adaptive-int8", BitBudget(budget, "adaptive", quantize="int8",
                                    weights=PROBE_WEIGHTS)),
        ("taper50-int8-b50pct", BitBudget(int(0.5 * dense_total_bits),
                                          "taper", taper=0.5,
                                          quantize="int8")),
    ]


def configs(d: int) -> list[tuple[str, Compression | None]]:
    """The swept codecs, k_top scaled as a fraction of d.

    ``dense`` is the uncompressed baseline; ``top20pct-int8`` is the
    gated operating point (16% of dense bits at d=100); ``top33pct-f32``
    is the high-fidelity reference (over budget, recorded ungated) that
    separates selection error from quantization error.
    """
    return [
        ("dense", None),
        (GATED_CONFIG, Compression(max(1, d // 5), "int8")),
        ("top20pct-bf16", Compression(max(1, d // 5), "bf16")),
        ("top12pct-f32", Compression(max(1, (12 * d) // 100))),
        ("top33pct-f32", Compression(max(1, d // 3))),
    ]


def accuracy_vs_bits(paper: bool, seed: int = 0, schedules_only: bool = False):
    if paper:
        # the section-5 synthetic grid (repro.configs.paper_synthetic)
        # at the m=80 operating point
        d, n_total, m, repeats = SYNTHETIC.d, SYNTHETIC.N, 80, 6
        rho, iters = SYNTHETIC.rho, 600
    else:
        # the multi_round quick operating point at its largest m: the
        # regime where refinement rounds matter most is where their
        # communication bill is highest
        d, n_total, m, repeats = 100, 6_000, 60, 2
        rho, iters = 0.6, 400
    cfg = DantzigConfig(max_iters=iters)
    problem = synthetic.make_problem(d=d, n_signal=10, rho=rho)
    b1 = float(jnp.sum(jnp.abs(problem.beta_star)))
    n = n_total // m
    n1 = n2 = n // 2
    lam = 0.30 * math.sqrt(math.log(d) / n) * b1
    lam_c = 0.30 * math.sqrt(math.log(d) / n_total) * b1
    swept = [] if schedules_only else configs(d)
    dense_bits = compression_core.dense_uplink_bits(d, 1)
    dense_total = T_GATE * dense_bits
    swept_schedules = schedules(dense_total)

    acc: dict[tuple, list] = {}
    for rep in range(repeats):
        # the SAME draws as multi_round's error_vs_m at this m
        key = jax.random.fold_in(jax.random.PRNGKey(seed), m * 1000 + rep)
        xs, ys = synthetic.sample_machines(key, problem, m, n1, n2)
        cent = centralized_slda(xs.reshape(-1, d), ys.reshape(-1, d),
                                lam_c, cfg)
        acc.setdefault("l2_cent", []).append(
            tuned_metrics(cent, problem.beta_star, T_GRID)["l2"])
        # ONE set of per-machine solves serves every codec, every
        # schedule and every T
        _, ws = rounds_core.simulate_multi_round(
            BinaryHead(), (xs, ys), lam=lam, lam_prime=lam,
            rounds=1, cfg=cfg)
        dense_traj = None
        for name, comp in swept:
            bars = rounds_core.simulate_round_loop(
                ws, rounds=ROUNDS, compression=comp, return_all_rounds=True)
            if name == "dense":
                dense_traj = np.asarray(bars)
            for t_rounds in range(1, ROUNDS + 1):
                mt = tuned_metrics(bars[t_rounds - 1][:, 0],
                                   problem.beta_star, T_GRID)
                acc.setdefault((name, t_rounds, "f1"), []).append(mt["f1"])
                acc.setdefault((name, t_rounds, "l2"), []).append(mt["l2"])
        if not schedules_only:
            # identity-codec premise: k_top = d, unquantized reproduces
            # the dense trajectory bit for bit (the EF stream is zero)
            ident = rounds_core.simulate_round_loop(
                ws, rounds=ROUNDS, compression=Compression(d),
                return_all_rounds=True)
            np.testing.assert_array_equal(np.asarray(ident), dense_traj)
        # the schedule sweep compresses BOTH wires; planned for T_GATE
        # rounds (a schedule is a whole-trajectory budget, so unlike a
        # fixed codec it is not truncatable to a shorter T)
        dense_gate = rounds_core.simulate_round_loop(
            ws, rounds=T_GATE, return_all_rounds=True)
        mt = tuned_metrics(dense_gate[T_GATE - 1][:, 0],
                           problem.beta_star, T_GRID)
        acc.setdefault(("sched-dense", "f1"), []).append(mt["f1"])
        acc.setdefault(("sched-dense", "l2"), []).append(mt["l2"])
        for name, sched in swept_schedules:
            bars = rounds_core.simulate_round_loop(
                ws, rounds=T_GATE, comm=CommPlan(schedule=sched),
                return_all_rounds=True)
            mt = tuned_metrics(bars[T_GATE - 1][:, 0],
                               problem.beta_star, T_GRID)
            acc.setdefault(("sched", name, "f1"), []).append(mt["f1"])
            acc.setdefault(("sched", name, "l2"), []).append(mt["l2"])

    def mean(k):
        return sum(acc[k]) / len(acc[k])

    header = ["config", "quantize", "k_top", "bits_round", "bits_ratio",
              "T", "F1", "l2"]
    rows = []
    for name, comp in swept:
        if comp is None:
            quant, k_top, bits = "f32", d, dense_bits
        else:
            quant = comp.quantize or "f32"
            k_top = comp.k_top
            bits = compression_core.uplink_bits(comp, d, 1)
        for t_rounds in range(1, ROUNDS + 1):
            rows.append([name, quant, k_top, bits, bits / dense_bits,
                         t_rounds, mean((name, t_rounds, "f1")),
                         mean((name, t_rounds, "l2"))])

    gate = None
    if not schedules_only:
        # the headline gate: dense-level recovery at <= 25% of the
        # bits.  recovery normalizes by the SAME denominators for
        # every codec (the dense T=1 start and the centralized floor),
        # so it compares what the rounds achieve under each uplink.
        l2_cent = mean("l2_cent")
        l2_t1_dense = mean(("dense", 1, "l2"))

        def recovery(name):
            l2_t = mean((name, T_GATE, "l2"))
            return (l2_t1_dense - l2_t) / max(l2_t1_dense - l2_cent, 1e-12)

        gated = dict(swept)[GATED_CONFIG]
        gate = {
            "m": m, "d": d, "t_rounds": T_GATE, "config": GATED_CONFIG,
            "k_top": gated.k_top, "quantize": gated.quantize,
            "bits_per_round": compression_core.uplink_bits(gated, d, 1),
            "dense_bits_per_round": dense_bits,
            "bits_ratio": compression_core.compression_ratio(gated, d, 1),
            "bits_budget": BITS_BUDGET,
            "f1_dense": mean(("dense", T_GATE, "f1")),
            "f1_comp": mean((GATED_CONFIG, T_GATE, "f1")),
            "f1_slack": F1_SLACK,
            "rec_dense": recovery("dense"),
            "rec_comp": recovery(GATED_CONFIG),
            "rec_slack": REC_SLACK,
            "l2_cent": l2_cent, "l2_t1_dense": l2_t1_dense,
            "l2_t3_dense": mean(("dense", T_GATE, "l2")),
            "l2_t3_comp": mean((GATED_CONFIG, T_GATE, "l2")),
        }

    # schedule rows: realized plans + TOTAL (up + down) accounting via
    # Transport -- the same numbers the AxisPayloadBits contracts pin
    sched_header = ["schedule", "mode", "budget_bits", "plan_k",
                    "up_bits", "down_bits", "total_bits", "total_ratio",
                    "F1", "l2"]
    sched_rows = [["dense", "dense", dense_total, "-", dense_total, 0,
                   dense_total, 1.0, mean(("sched-dense", "f1")),
                   mean(("sched-dense", "l2"))]]
    for name, sched in swept_schedules:
        tr = Transport(CommPlan(schedule=sched), d, 1, T_GATE)
        up_b, down_b = tr.uplink_total_bits(), tr.downlink_total_bits()
        plan_k = "/".join(f"{up.k_top}+{down.k_top}"
                          for up, down in tr.links)
        sched_rows.append([
            name, sched.mode, sched.total_bits, plan_k, up_b, down_b,
            up_b + down_b, (up_b + down_b) / dense_total,
            mean(("sched", name, "f1")), mean(("sched", name, "l2"))])

    gated_sched = dict(swept_schedules)[GATED_SCHEDULE]
    tr = Transport(CommPlan(schedule=gated_sched), d, 1, T_GATE)
    up_b, down_b = tr.uplink_total_bits(), tr.downlink_total_bits()
    sched_gate = {
        "m": m, "d": d, "t_rounds": T_GATE, "schedule": GATED_SCHEDULE,
        "mode": gated_sched.mode, "taper": gated_sched.taper,
        "quantize": gated_sched.quantize,
        "down_fraction": gated_sched.down_fraction,
        "budget_bits": gated_sched.total_bits,
        # the committed wire format, compared EXACTLY across PRs
        "plan": [[up.k_top, down.k_top] for up, down in tr.links],
        "up_bits": up_b, "down_bits": down_b,
        "total_bits": up_b + down_b, "dense_total_bits": dense_total,
        "bits_ratio": (up_b + down_b) / dense_total,
        "bits_budget": BITS_BUDGET,
        "f1_dense": mean(("sched-dense", "f1")),
        "f1_sched": mean(("sched", GATED_SCHEDULE, "f1")),
        "f1_slack": F1_SLACK,
        "l2_dense": mean(("sched-dense", "l2")),
        "l2_sched": mean(("sched", GATED_SCHEDULE, "l2")),
    }
    return header, rows, gate, sched_header, sched_rows, sched_gate


def _assert_schedule_gate(sg: dict) -> None:
    assert sg["bits_ratio"] <= sg["bits_budget"], (
        "gated schedule over the total bit budget", sg)
    assert sg["f1_sched"] >= sg["f1_dense"] - sg["f1_slack"], (
        "bit-budget schedule lost more than 1% F1 vs dense rounds", sg)


def main(paper: bool = False, schedules_only: bool = False) -> None:
    header, rows, gate, sh, srows, sgate = accuracy_vs_bits(
        paper, schedules_only=schedules_only)
    if not schedules_only:
        print_table("compressed refinement uplinks: accuracy vs bits "
                    "moved (one solve set per repeat)", header, rows)
    print_table("bit-budget schedules: accuracy vs TOTAL (up+down) bits "
                f"at T={T_GATE}", sh, srows)

    if not schedules_only:
        write_csv("compressed_rounds.csv", header, rows)
        write_csv("compressed_schedules.csv", sh, srows)
        jpath = write_bench_json("compressed_rounds", header, rows,
                                 compression=gate, schedule=sgate)
        print(f"[compressed_rounds] wrote {jpath}")
        print(f"[compressed_rounds] gate at m={gate['m']}, "
              f"T={gate['t_rounds']}: "
              f"{gate['config']} moves {gate['bits_per_round']} of "
              f"{gate['dense_bits_per_round']} bits/round "
              f"({gate['bits_ratio']:.0%}); "
              f"F1 {gate['f1_comp']:.3f} vs dense {gate['f1_dense']:.3f}; "
              f"recovery {gate['rec_comp']:.3f} vs dense "
              f"{gate['rec_dense']:.3f}")

        assert gate["bits_ratio"] <= gate["bits_budget"], (
            "gated config over the bit budget", gate)
        assert gate["f1_comp"] >= gate["f1_dense"] - gate["f1_slack"], (
            "compressed rounds lost more than 1% F1 vs dense rounds", gate)
        assert gate["rec_comp"] >= gate["rec_dense"] - gate["rec_slack"], (
            "compressed rounds recover more than 1% less excess l2 than "
            "dense rounds", gate)

    print(f"[compressed_rounds] schedule gate at m={sgate['m']}, "
          f"T={sgate['t_rounds']}: {sgate['schedule']} moves "
          f"{sgate['total_bits']} (up {sgate['up_bits']} + down "
          f"{sgate['down_bits']}) of {sgate['dense_total_bits']} total "
          f"bits ({sgate['bits_ratio']:.0%}); F1 {sgate['f1_sched']:.3f} "
          f"vs dense {sgate['f1_dense']:.3f}")
    _assert_schedule_gate(sgate)


if __name__ == "__main__":
    import sys

    main(paper="--paper" in sys.argv,
         schedules_only="--schedules" in sys.argv)
