"""Micro-benchmark: scan vs fused-blocked Dantzig/CLIME solver (SSPerf-A2).

For each (d, k) shape, runs the XLA ``lax.scan`` ADMM and the blocked
fused Pallas kernel with identical hyperparameters (fixed rho, same
iteration count), and reports:

  * measured wall-clock per solve (best of ``repeats``),
  * the analytic HBM-bytes model for both paths, and the ratio --
    the quantity the fused kernel is designed to collapse,
  * max-abs parity between the two solutions (asserted < 1e-3).

HBM model (f32 bytes):
  scan  : every iteration re-streams A, Q (twice: Q^T v and Q u) and
          ~8 (d, k) state/temporary arrays ->
          iters * 4 * (3 d^2 + 8 d k)
  fused : one read of (A, Q, inv) per column block + one read of b and
          one write of the solution ->
          4 * (ceil(k / block_k) * (2 d^2 + d) + 2 d k + 2 k)

On CPU the kernel executes under the Pallas interpreter, so the bytes
model -- not the CPU wall-clock -- is the TPU-relevant signal; the
wall-clock columns are still printed for regression tracking.  A green
run asserts parity and that the model predicts >= 10x traffic savings
at CLIME scale.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table, write_bench_json, write_csv
from repro.core.dantzig import DantzigConfig
from repro.core.solver_dispatch import select_solver, solve_dantzig
from repro.kernels.dantzig_fused import pick_block_k
from repro.stats.synthetic import ar1_covariance

SHAPES_CI = [(64, 64), (128, 128), (256, 64), (300, 7)]
SHAPES_PAPER = [(256, 256), (512, 512), (768, 512), (1024, 256)]


def scan_hbm_bytes(d: int, k: int, iters: int) -> float:
    return iters * 4.0 * (3 * d * d + 8 * d * k)


def fused_hbm_bytes(d: int, k: int, iters: int, block_k: int) -> float:
    num_blocks = -(-k // block_k)
    return 4.0 * (num_blocks * (2 * d * d + d) + 2 * d * k + 2 * k)


def _time(fn, repeats: int) -> float:
    jax.block_until_ready(fn())  # compile + warm, fully drained
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def main(paper: bool = False) -> None:
    shapes = SHAPES_PAPER if paper else SHAPES_CI
    iters = 300 if paper else 150
    repeats = 3
    rows = []
    for d, k in shapes:
        a = jnp.asarray(ar1_covariance(d, 0.6), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(d + k), (d, k)) * 0.3
        lam = 0.1
        cfg_scan = DantzigConfig(max_iters=iters, adapt_rho=False)
        cfg_fused = cfg_scan._replace(fused=True)
        choice = select_solver(cfg_fused, d, k)
        bk = choice.block_k or pick_block_k(d, k) or k

        t_scan = _time(lambda: solve_dantzig(a, b, lam, cfg_scan), repeats)
        t_fused = _time(lambda: solve_dantzig(a, b, lam, cfg_fused), repeats)
        out_s = solve_dantzig(a, b, lam, cfg_scan)
        out_f = solve_dantzig(a, b, lam, cfg_fused)
        parity = float(jnp.max(jnp.abs(out_s - out_f)))
        assert parity < 1e-3, (d, k, parity)

        bytes_s = scan_hbm_bytes(d, k, iters)
        bytes_f = fused_hbm_bytes(d, k, iters, bk)
        rows.append([d, k, choice.kind, bk, iters, t_scan, t_fused,
                     bytes_s / 1e6, bytes_f / 1e6, bytes_s / bytes_f, parity])

    header = ["d", "k", "path", "block_k", "iters", "scan_s", "fused_s",
              "scan_MB", "fused_MB", "hbm_ratio", "max_abs_diff"]
    print_table("fused Dantzig solver: scan vs fused-blocked", header, rows)
    path = write_csv("fused_solver.csv", header, rows)
    jpath = write_bench_json("fused_solver", header, rows, iters=iters)
    print(f"[fused_solver] wrote {path} and {jpath}")
    # the whole point of the kernel: >= 10x fewer modeled HBM bytes
    assert all(r[9] >= 10.0 for r in rows), "HBM model ratio regressed"


if __name__ == "__main__":
    main()
