"""Folded lambda-path sweep vs L sequential fused launches (SSPerf-A3).

The paper tunes lam ∝ sqrt(log d / n) on a grid, so the per-machine
hot loop is the SAME (d, k) Dantzig batch solved at L box radii.  Run
sequentially that is L fused launches and L eigendecompositions of the
shared Sigma_hat.  :func:`repro.core.path.solve_dantzig_path` folds
the grid into the column axis of ONE blocked launch (k -> k*L columns;
``lam``/``rho`` are per-column operands) over ONE
:class:`~repro.kernels.spectral.SpectralFactor`.

Reported per (d, k, L):

  * wall-clock for the sequential python loop (each iteration passes
    the RAW matrix, so it pays its own eigh -- the pre-PR schedule)
    vs the folded launch, best of ``repeats`` after warmup;
  * the modeled **Sigma-stream HBM bytes**: per launch the kernel
    re-fetches A and Q once per column block and the factorization
    streams Sigma in / Q out once.  The (d, k) payload bytes (b in,
    solution out) are identical in both schedules -- the fold neither
    adds nor removes them -- so the redundant Sigma traffic is the
    quantity the fold collapses:

        seq    = L * (blocks(k) + 1) * (2 d^2 + d) * 4
        folded =     (blocks(k L) + 1) * (2 d^2 + d) * 4

    When the folded batch still fits one block the ratio is exactly
    L * (blocks(k) + 1) / 2 >= L; total-bytes ratios (payload included)
    are also recorded;
  * max-abs parity between folded and sequential solutions (asserted
    < 1e-5: columns are independent, the fold is exact).

On CPU the kernel runs under the Pallas interpreter, so wall-clock
mostly measures the L-1 avoided eigendecompositions and launch
overheads; the bytes model is the TPU-relevant signal.  A green run
asserts the folded sweep wins wall-clock and >= L x on the modeled
Sigma-stream bytes.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table, write_bench_json, write_csv
from repro.core.dantzig import DantzigConfig
from repro.core.path import solve_dantzig_path
from repro.core.solver_dispatch import solve_dantzig
from repro.kernels.dantzig_fused import pick_block_k
from repro.stats.synthetic import ar1_covariance

# (d, k, L): k mirrors the direction-block widths the pipeline solves
# (K = 1 binary, small K multiclass); L is the paper-style tuning grid.
SHAPES_CI = [(128, 1, 8), (256, 4, 8), (256, 1, 16)]
SHAPES_PAPER = [(256, 8, 16), (512, 4, 16), (512, 8, 32)]


def _blocks(d: int, cols: int) -> int:
    bk = pick_block_k(d, cols) or cols
    return -(-cols // bk)


def sigma_stream_bytes(d: int, cols: int, launches: int) -> float:
    """Redundant Sigma traffic: (A + Q per block) + (eigh stream) per launch."""
    per_launch = (_blocks(d, cols) + 1) * (2.0 * d * d + d)
    return launches * per_launch * 4.0


def total_bytes(d: int, k: int, cols_per_launch: int, launches: int) -> float:
    """Sigma stream + the (identical-in-both-schedules) payload bytes."""
    payload = launches * (2.0 * d * cols_per_launch + 2.0 * cols_per_launch)
    return sigma_stream_bytes(d, cols_per_launch, launches) + payload * 4.0


def _time(fn, repeats: int) -> float:
    jax.block_until_ready(fn())  # compile + warm, fully drained
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def main(paper: bool = False) -> None:
    shapes = SHAPES_PAPER if paper else SHAPES_CI
    iters = 200 if paper else 120
    repeats = 3
    rows = []
    for d, k, L in shapes:
        a = jnp.asarray(ar1_covariance(d, 0.6), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(d + k + L), (d, k)) * 0.3
        lams = jnp.linspace(0.05, 0.5, L)
        cfg = DantzigConfig(max_iters=iters, adapt_rho=False, fused=True)

        def sequential():
            # the pre-PR schedule: one launch per grid point, each
            # factorizing the raw matrix it is handed
            return [solve_dantzig(a, b, lams[i], cfg) for i in range(L)]

        def folded():
            return solve_dantzig_path(a, b, lams, cfg).beta

        t_seq = _time(sequential, repeats)
        t_fold = _time(folded, repeats)
        parity = float(jnp.max(jnp.abs(
            folded() - jnp.stack(sequential()))))
        assert parity < 1e-5, (d, k, L, parity)

        sig_seq = sigma_stream_bytes(d, k, L)
        sig_fold = sigma_stream_bytes(d, k * L, 1)
        tot_seq = total_bytes(d, k, k, L)
        tot_fold = total_bytes(d, k, k * L, 1)
        rows.append([
            d, k, L, pick_block_k(d, k * L) or k * L, iters,
            t_seq, t_fold, t_seq / t_fold,
            sig_seq / 1e6, sig_fold / 1e6, sig_seq / sig_fold,
            tot_seq / tot_fold, parity,
        ])

    header = ["d", "k", "L", "block_k", "iters", "seq_s", "folded_s",
              "speedup", "seq_sigma_MB", "folded_sigma_MB",
              "sigma_hbm_ratio", "total_hbm_ratio", "max_abs_diff"]
    print_table("lambda path: folded sweep vs L sequential fused launches",
                header, rows)
    path = write_csv("lambda_path.csv", header, rows)
    jpath = write_bench_json("lambda_path", header, rows, iters=iters)
    print(f"[lambda_path] wrote {path} and {jpath}")
    # the point of the fold: beat the sequential sweep on wall-clock
    # (CPU interpreter) and collapse the redundant Sigma stream >= L x
    for r in rows:
        d, k, L, speedup, sigma_ratio = r[0], r[1], r[2], r[7], r[10]
        assert speedup > 1.0, f"folded sweep slower at {(d, k, L)}: {r}"
        assert sigma_ratio >= L, f"Sigma-stream ratio < L at {(d, k, L)}: {r}"


if __name__ == "__main__":
    main()
