"""One CI chaos leg: a (dropout, staleness, corruption) combo on a mesh.

Each matrix entry of the CI ``chaos`` job runs this module with one
fault combination on a REAL (data=2, model=4) forced-host-device mesh
and asserts the DESIGN.md §11 guarantees on live numbers:

  * the masked mesh aggregate is finite, dense AND int8-compressed;
  * it matches the vmap simulation twin under the SAME schedule seed
    (the liveness rows ride shard_map as sharded per-machine operands);
  * the all-NaN chaos pin: every machine corrupted in every round
    still returns the finite last-good aggregate, never NaN.

``XLA_FLAGS`` must force >= 8 host devices BEFORE jax imports; the
guard below covers local runs (CI sets it at the job level).

Usage::

    PYTHONPATH=src python -m benchmarks.chaos_mesh \
        --dropout 0.3 --staleness 2 --corrupt mix
"""

from __future__ import annotations

import argparse
import math
import os

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.compression import Compression  # noqa: E402
from repro.core.dantzig import DantzigConfig  # noqa: E402
from repro.core.distributed import (  # noqa: E402
    distributed_slda_shardmap,
    simulated_distributed_slda,
)
from repro.core.faults import Aggregation, FaultSchedule  # noqa: E402
from repro.stats import synthetic  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dropout", type=float, default=0.0)
    ap.add_argument("--staleness", type=int, default=0)
    ap.add_argument("--corrupt", default="none",
                    choices=("none", "nan", "inf", "garbage", "mix"))
    ap.add_argument("--seed", type=int, default=23)
    args = ap.parse_args()

    m, d, rounds = 2, 16, 3
    cfg = DantzigConfig(max_iters=200)
    p = synthetic.make_problem(d=d, n_signal=4, rho=0.5)
    xs, ys = synthetic.sample_machines(
        jax.random.PRNGKey(args.seed), p, m, 40, 40)
    lam = 0.3 * math.sqrt(math.log(d) / 80) * 4
    tau = 0.25 * lam
    sched = FaultSchedule(
        dropout=args.dropout,
        straggle=0.3 if args.staleness > 0 else 0.0,
        corrupt=0.0 if args.corrupt == "none" else 0.3,
        corrupt_mode=args.corrupt if args.corrupt != "none" else "nan",
        seed=args.seed)
    agg = Aggregation(envelope=1e6)
    mesh = jax.make_mesh((2, 4), ("data", "model"))

    for name, comp in (("dense", None), ("int8", Compression(5, "int8"))):
        out = distributed_slda_shardmap(
            mesh, xs.reshape(-1, d), ys.reshape(-1, d), lam, lam, tau,
            cfg, rounds=rounds, compression=comp, faults=sched,
            staleness=args.staleness, aggregation=agg)
        assert np.isfinite(np.asarray(out)).all(), (
            f"{name}: non-finite masked aggregate under {sched}")
        sim = simulated_distributed_slda(
            xs, ys, lam, lam, tau, cfg, rounds=rounds, compression=comp,
            faults=sched, staleness=args.staleness, aggregation=agg)
        np.testing.assert_allclose(np.asarray(out), np.asarray(sim),
                                   atol=1e-5)
        print(f"[chaos_mesh] {name}: finite + mesh/sim parity OK "
              f"(dropout={args.dropout} staleness={args.staleness} "
              f"corrupt={args.corrupt})")

    # the all-NaN pin, on the mesh path: every machine screened in
    # every round -> last-good fallback (zeros anchor), never NaN
    all_nan = FaultSchedule(corrupt=1.0, corrupt_mode="nan",
                            seed=args.seed)
    out = distributed_slda_shardmap(
        mesh, xs.reshape(-1, d), ys.reshape(-1, d), lam, lam, tau, cfg,
        rounds=rounds, faults=all_nan, aggregation=Aggregation())
    assert np.isfinite(np.asarray(out)).all(), (
        "all-NaN rounds leaked non-finite values through the mesh mask")
    print("[chaos_mesh] all-NaN last-good pin OK")


if __name__ == "__main__":
    main()
