"""Corollary 4.8 scaling check: the machine-count threshold m*.

The theory says the distributed estimator matches the centralized rate
while m <~ m* = sqrt(N / log d) / max(s, s'), and the second error term
(~ m log d / N) takes over beyond it.  This benchmark sweeps m across
m* at two sample sizes and checks (i) the error is flat (within a
factor) below ~m*/2 and (ii) grows markedly by ~4 m*; and that m* grows
like sqrt(N) -- the doubling-N sweep shifts the elbow right.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table, tuned_metrics, write_csv
from repro.core.dantzig import DantzigConfig
from repro.core.distributed import simulated_debiased_mean
from repro.stats import synthetic

T_GRID = np.geomspace(0.005, 2.0, 25)


def _l2_at(problem, n_total, m, repeats, cfg, seed, d, b1):
    n = n_total // m
    lam = 0.30 * math.sqrt(math.log(d) / n) * b1
    errs = []
    for rep in range(repeats):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), m * 7919 + rep)
        xs, ys = synthetic.sample_machines(key, problem, m, n // 2, n // 2)
        raw = simulated_debiased_mean(xs, ys, lam, lam, cfg)
        errs.append(tuned_metrics(raw, problem.beta_star, T_GRID)["l2"])
    return sum(errs) / len(errs)


def run(paper: bool = False, seed: int = 5):
    d = 100
    repeats = 5 if paper else 2
    cfg = DantzigConfig(max_iters=500 if paper else 350)
    problem = synthetic.make_problem(d=d, n_signal=10, rho=0.8)
    b1 = float(jnp.sum(jnp.abs(problem.beta_star)))
    s = int(jnp.sum(problem.beta_star != 0))  # ~11; s' ~ 3 (tridiag)
    rows = []
    for n_total in (4_000, 16_000):
        m_star = math.sqrt(n_total / math.log(d)) / s
        ms = sorted({max(2, int(round(m_star * f))) for f in (0.5, 1, 2, 4, 8)})
        for m in ms:
            err = _l2_at(problem, n_total, m, repeats, cfg, seed, d, b1)
            rows.append([n_total, m, round(m / m_star, 2), err])
    header = ["N", "m", "m/m_star", "l2_err"]
    print_table(f"Corollary 4.8 threshold sweep (d={d}, s={s}, "
                "m* = sqrt(N/log d)/s)", header, rows)
    write_csv("corollary48_threshold.csv", header, rows)
    return rows


def main(paper: bool = False):
    rows = run(paper)
    by_n = {}
    for n_total, m, ratio, err in rows:
        by_n.setdefault(n_total, []).append((ratio, err))
    for n_total, pts in by_n.items():
        pts.sort()
        below = [e for r, e in pts if r <= 1.01]
        above = [e for r, e in pts if r >= 3.9]
        assert below and above, pts
        # beyond the threshold the error must exceed the sub-threshold
        # error noticeably (second term dominates)
        assert min(above) > 1.15 * min(below), (n_total, pts)


if __name__ == "__main__":
    import sys

    main(paper="--paper" in sys.argv)
