"""Classify-as-a-service: sustained qps, staleness-vs-accuracy, chaos.

The serving runtime of :mod:`repro.core.streaming` prices three claims
(DESIGN.md §12), all on deterministic seeds:

  * the HOT PATH is one fused (B, d) @ (d, K) matmul: sustained
    queries/sec through the jit'd ``classify_batch`` at the gated
    operating point (wall-clock, host/backend-matched cross-PR like
    the solver benchmarks);
  * STALENESS has a measurable price: serve the slot fitted at drift
    step 0 against queries whose population has moved s refresh-steps
    along the discriminant direction -- accuracy vs missed refreshes
    is the curve the bounded-staleness contract trades against, and
    one refreshed refit at the far end shows what a refresh buys back;
  * GRACEFUL DEGRADATION is real, asserted inline and gated in
    ``ci_gate.py``: under the same fault plan (ingest corruption +
    refit divergence + refresh drops) the protected runtime stays
    finite and within ``acc_slack`` of its fault-free twin while the
    unprotected baseline (no screening, no verdict) demonstrably
    collapses; warm streaming refits resume in strictly fewer ADMM
    iterations than cold re-solves of the same merged statistics
    (gated ``warm_vs_cold`` rows, PR 4's contract carried to serving).

Quick mode (default, CI-sized): d=60, B=2048 queries/batch, 12 chaos
ticks.  ``--paper`` scales to d=120, B=8192, 24 ticks.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table, write_bench_json, write_csv
from repro.core import streaming as st
from repro.core.dantzig import DantzigConfig
from repro.core.pipeline import suff_stats
from repro.stats.synthetic import (
    make_problem,
    sample_labeled,
    sample_two_class,
)

CFG = DantzigConfig(tol=1e-3)
ACC_SLACK = 0.02


def _fit_runtime(problem, key, n_seed, **kw):
    x, y = sample_two_class(key, problem, n_seed, n_seed)
    aux = suff_stats(x, y)
    return aux, st.ServingRuntime(aux, 0.1, 0.2, 1e-3, cfg=CFG, **kw)


def qps_section(problem, rt, batch, reps=20):
    """Sustained queries/sec through the jit'd hot path."""
    key = jax.random.PRNGKey(101)
    z, _ = sample_labeled(key, problem, batch)
    rt.classify(z)[0].block_until_ready()  # compile outside the clock
    t0 = time.perf_counter()
    for _ in range(reps):
        pred, _ = rt.classify(z)
    pred.block_until_ready()
    dt = time.perf_counter() - t0
    return batch * reps / dt, dt / reps


def staleness_section(problem, rt, aux0, batch, max_stale):
    """Accuracy of the step-0 slot vs population drift per missed
    refresh, plus the refreshed refit at the far end."""
    d = int(aux0.mu1.shape[0])
    direction = (aux0.mu1 - aux0.mu2) / jnp.maximum(
        jnp.linalg.norm(aux0.mu1 - aux0.mu2), 1e-9)
    step = 0.35 * float(jnp.linalg.norm(aux0.mu1 - aux0.mu2))
    rows = []
    key = jax.random.PRNGKey(202)
    z, lab = sample_labeled(key, problem, batch)
    for s in range(max_stale + 1):
        # the population moved s refresh-steps; the slot did not
        z_s = z + s * step * direction[None, :]
        pred, _ = rt.classify(z_s)
        rows.append([s, round(s * step, 6),
                     float(jnp.mean(pred == lab)), "stale"])
    # one refresh at the far end: refit on drifted data, re-serve
    s = max_stale
    shift = s * step * direction[None, :]
    xs, ys = sample_two_class(jax.random.PRNGKey(203), problem, 400, 400)
    aux_s = suff_stats(xs + shift, ys + shift)
    res, _ = st.refit_with_escalation(
        st.head_stats_of(aux_s), 0.1, 0.2, CFG, None)
    slot = st.slot_from_stats(aux_s, res.beta_tilde, 1e-3, version=99)
    pred, _ = st.classify_batch(z + shift, slot.beta, slot.means,
                                slot.priors)
    rows.append([s, round(s * step, 6), float(jnp.mean(pred == lab)),
                 "refreshed"])
    return rows


def warm_vs_cold_section(problem, aux0):
    """Streaming refit resume: warm iterations strictly below cold on
    the same merged statistics (gated, with a solution-drift budget)."""
    res0, _ = st.refit_with_escalation(
        st.head_stats_of(aux0), 0.1, 0.2, CFG, None)
    bx, by = sample_two_class(jax.random.PRNGKey(301), problem, 150, 150)
    aux = st.merge_suff_stats(aux0, suff_stats(bx, by))
    hs = st.head_stats_of(aux)
    warm = st.refit_step(hs, 0.1, 0.2, CFG, carry=res0.carry)
    cold = st.refit_step(hs, 0.1, 0.2, CFG)
    tot = lambda r: (int(np.max(np.asarray(r.iters_beta)))
                     + int(np.max(np.asarray(r.iters_theta))))
    drift = float(np.max(np.abs(np.asarray(warm.beta_tilde)
                                - np.asarray(cold.beta_tilde))))
    return [{
        "scenario": "streaming-refit-resume",
        "cold_iters": tot(cold),
        "warm_iters": tot(warm),
        "max_abs_diff": drift,
        "drift_budget": 2e-2,
        "gated": True,
    }]


def chaos_section(problem, aux0, ticks, batch):
    """Protected vs unprotected under one deterministic fault plan."""
    plan = st.ServeFaultSchedule(
        corrupt_ingest=0.4, diverge_refit=0.5, drop_refresh=0.2,
        seed=5).plan(ticks)
    assert plan.corrupt.any() and plan.diverge.any(), (
        "the fault plan fired nothing -- raise the rates or the ticks")

    def run(protect, faulted):
        rt = st.ServingRuntime(aux0, 0.1, 0.2, 1e-3, cfg=CFG,
                               staleness_bound=2, protect=protect)
        key = jax.random.PRNGKey(404)
        accs, finite = [], True
        for t in range(ticks):
            key, k1, k2 = jax.random.split(key, 3)
            z, lab = sample_labeled(k1, problem, batch)
            pred, scores = rt.classify(z)
            finite &= bool(np.isfinite(np.asarray(scores)).all())
            accs.append(float(jnp.mean(pred == lab)))
            bx, by = sample_two_class(k2, problem, 60, 60)
            code = int(plan.corrupt[t]) if faulted else 0
            bx, by = st.corrupt_batch_arrays(code, (bx, by))
            rt.ingest_batch(suff_stats(bx, by), bx, by)
            if (t + 1) % 2 == 0:
                rt.refresh(
                    drop=bool(plan.drop[t]) if faulted else False,
                    inject_diverge=int(plan.diverge[t]) if faulted else 0)
        return float(np.mean(accs)), finite

    acc_clean, fin_clean = run(protect=True, faulted=False)
    acc_prot, fin_prot = run(protect=True, faulted=True)
    acc_unprot, fin_unprot = run(protect=False, faulted=True)
    return {
        "ticks": ticks,
        "corrupt": 0.4, "diverge": 0.5, "drop": 0.2,
        "acc_clean": acc_clean,
        "acc_protected": acc_prot,
        "acc_unprotected": acc_unprot,
        "finite_clean": fin_clean,
        "finite_protected": fin_prot,
        "finite_unprotected": fin_unprot,
        "acc_slack": ACC_SLACK,
    }


def main(paper: bool = False) -> None:
    d = 120 if paper else 60
    batch = 8192 if paper else 2048
    ticks = 24 if paper else 12
    max_stale = 4
    problem = make_problem(d=d, n_signal=max(6, d // 10), rho=0.5)
    aux0, rt = _fit_runtime(problem, jax.random.PRNGKey(100), 4 * d)

    qps, s_per_batch = qps_section(problem, rt, batch)
    stale_rows = staleness_section(problem, rt, aux0, batch, max_stale)
    warm_vs_cold = warm_vs_cold_section(problem, aux0)
    chaos = chaos_section(problem, aux0, ticks, batch)

    header = ["missed_refreshes", "mean_shift", "accuracy", "model"]
    print_table(f"staleness-vs-accuracy (d={d}, B={batch})",
                header, stale_rows)
    print(f"[serving] sustained qps: {qps:,.0f} "
          f"({s_per_batch * 1e3:.2f} ms / {batch}-query batch)")
    print(f"[serving] chaos: clean {chaos['acc_clean']:.4f} / protected "
          f"{chaos['acc_protected']:.4f} / unprotected "
          f"{chaos['acc_unprotected']:.4f} "
          f"(finite: {chaos['finite_protected']}/"
          f"{chaos['finite_unprotected']})")
    wc = warm_vs_cold[0]
    print(f"[serving] streaming refit resume: warm {wc['warm_iters']} vs "
          f"cold {wc['cold_iters']} iterations "
          f"(drift {wc['max_abs_diff']:.2e})")

    gate = {
        "d": d, "batch": batch, "refit_every": 2,
        "qps": qps, "s_per_batch": s_per_batch,
        "stale_acc_s0": stale_rows[0][2],
        "stale_acc_smax": stale_rows[max_stale][2],
        "stale_acc_refreshed": stale_rows[-1][2],
        "stale_smax": max_stale,
        **chaos,
    }
    write_csv("serving.csv", header, stale_rows)
    jpath = write_bench_json("serving", header, stale_rows,
                             warm_vs_cold=warm_vs_cold, serving=gate,
                             paper=paper)
    print(f"[serving] wrote {jpath}")

    # inline asserts: a red run IS the repro recipe (ci_gate re-checks
    # the same invariants against the committed baseline)
    assert chaos["finite_protected"], "protected serving emitted non-finite"
    assert chaos["acc_protected"] >= chaos["acc_clean"] - ACC_SLACK, (
        "protected serving lost more than the slack under faults", chaos)
    degraded = (not chaos["finite_unprotected"]
                or chaos["acc_unprotected"] < chaos["acc_clean"] - ACC_SLACK)
    assert degraded, (
        "unprotected serving did not degrade -- the faults are not biting",
        chaos)
    assert wc["warm_iters"] < wc["cold_iters"], wc
    assert gate["stale_acc_smax"] < gate["stale_acc_s0"], (
        "drift did not bite -- the staleness curve is flat", gate)
    assert gate["stale_acc_refreshed"] > gate["stale_acc_smax"], (
        "a refresh bought nothing back at max staleness", gate)


if __name__ == "__main__":
    main()
