"""Paper Table 2: misclassification on the Heart-Disease dataset (m=4).

The container is offline, so this benchmark runs on a SURROGATE with
the published dimensions (920 patients, 22 numeric attributes after
dummy-coding, 4 hospital sites, mild per-site mean heterogeneity) --
clearly labeled as such.  The comparison structure is the paper's:
centralized SLDA vs naive averaged SLDA vs distributed (debiased) SLDA,
4 "hospitals" = 4 machines, half train / half test, repeated splits.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from benchmarks.common import print_table, write_csv
from repro.core import classifier
from repro.core.dantzig import DantzigConfig
from repro.core.distributed import (
    simulated_distributed_slda,
    simulated_naive_averaged_slda,
)
from repro.core.slda import centralized_slda, hard_threshold
from repro.stats import synthetic


def _split_by_site(z, labels, sites, m, key):
    """Per site: random half train / half test; equalized shard sizes."""
    train_x, train_y, test_z, test_l = [], [], [], []
    for s in range(m):
        idx = jnp.nonzero(sites == s, size=sites.shape[0], fill_value=-1)[0]
        idx = idx[idx >= 0]
        idx = jax.random.permutation(jax.random.fold_in(key, s), idx)
        half = idx.shape[0] // 2
        tr, te = idx[:half], idx[half:]
        zx = z[tr]
        lx = labels[tr]
        train_x.append(zx[lx == 0])
        train_y.append(zx[lx == 1])
        test_z.append(z[te])
        test_l.append(labels[te])
    # equalize shard sizes (paper assumes equal n_l; trim to min)
    n1 = min(a.shape[0] for a in train_x)
    n2 = min(a.shape[0] for a in train_y)
    xs = jnp.stack([a[:n1] for a in train_x])
    ys = jnp.stack([a[:n2] for a in train_y])
    return xs, ys, jnp.concatenate(test_z), jnp.concatenate(test_l)


def run(repeats: int = 10, seed: int = 3):
    m, d = 4, 22
    cfg = DantzigConfig(max_iters=500)
    z, labels, sites = synthetic.heart_disease_surrogate(jax.random.PRNGKey(seed))
    n_train = int(z.shape[0]) // 2

    accs = {"cent": [], "naive": [], "dist": []}
    for rep in range(repeats):
        key = jax.random.fold_in(jax.random.PRNGKey(seed + 100), rep)
        xs, ys, test_z, test_l = _split_by_site(z, labels, sites, m, key)
        n = xs.shape[1] + ys.shape[1]
        b1_proxy = 4.0
        lam = 0.5 * math.sqrt(math.log(d) / n) * b1_proxy
        lam_c = 0.5 * math.sqrt(math.log(d) / (m * n)) * b1_proxy
        t = 0.4 * math.sqrt(math.log(d) / (m * n)) * b1_proxy

        mu1 = jnp.mean(xs.reshape(-1, d), axis=0)
        mu2 = jnp.mean(ys.reshape(-1, d), axis=0)

        cent = centralized_slda(xs.reshape(-1, d), ys.reshape(-1, d), lam_c, cfg)
        cent = hard_threshold(cent, 0.25 * t)
        naive = simulated_naive_averaged_slda(xs, ys, lam, cfg)
        dist = simulated_distributed_slda(xs, ys, lam, lam, t, cfg)
        for tag, beta in (("cent", cent), ("naive", naive), ("dist", dist)):
            rate = float(classifier.misclassification_rate(test_z, test_l, beta, mu1, mu2))
            accs[tag].append(rate)

    def stats(v):
        mean = sum(v) / len(v)
        var = sum((x - mean) ** 2 for x in v) / max(len(v) - 1, 1)
        return mean, var ** 0.5

    rows = []
    for tag, label in (("cent", "Centralized SLDA"),
                       ("naive", "Naive Averaged SLDA"),
                       ("dist", "Distributed SLDA")):
        mean, std = stats(accs[tag])
        rows.append([m, label, mean, std])
    header = ["m", "method", "misclass_rate", "std"]
    print_table("Table 2: Heart-Disease SURROGATE (offline container; "
                "matched dims 920x22, 4 sites)", header, rows)
    write_csv("table2_real_surrogate.csv", header, rows)
    return {tag: stats(v) for tag, v in accs.items()}


def main(paper: bool = False):
    res = run(repeats=10 if paper else 5)
    # the paper's ordering: distributed ~ centralized << naive
    assert res["dist"][0] <= res["naive"][0] + 0.02, res
    assert res["dist"][0] <= res["cent"][0] + 0.08, res


if __name__ == "__main__":
    import sys

    main(paper="--paper" in sys.argv)
