"""CI gate over the BENCH_*.json artifacts: fail on perf/parity regression.

Run AFTER ``python -m benchmarks.run --only fused_solver`` (and
optionally ``--only lambda_path`` / ``--only admm_convergence``).
Reads the machine-readable benchmark output and exits nonzero when

  * the scan-vs-fused solver parity (``max_abs_diff``) exceeds the
    pinned 1e-5 budget -- a tighter bar than the benchmark's own
    internal 1e-3 assert, because on the CI CPU the interpreter
    executes the same float ops as the scan path and the observed diff
    is ~0; anything above 1e-5 means a real numerical regression in
    the kernel or the dispatch contract, not noise;
  * the convergence-adaptive solver (``admm_convergence``) drifts
    more than 1e-4 from the fixed-500 solution, or any *gated*
    warm-started lambda-path re-sweep stops converging in fewer
    iterations than its cold counterpart (DESIGN.md §7).

Usage: ``PYTHONPATH=src python -m benchmarks.ci_gate``
"""

from __future__ import annotations

import json
import sys

from benchmarks.common import bench_json_path

PARITY_BUDGET = 1e-5
ADAPTIVE_PARITY_BUDGET = 1e-4  # early-exit solution vs fixed-500

# name -> column holding the gated max-abs parity
GATED = {
    "fused_solver": ("max_abs_diff", PARITY_BUDGET),
    "lambda_path": ("max_abs_diff", PARITY_BUDGET),
    "admm_convergence": ("max_abs_diff", ADAPTIVE_PARITY_BUDGET),
}


def main() -> int:
    failures = []
    checked = 0
    for name, (col, budget) in GATED.items():
        path = bench_json_path(name)
        try:
            with open(path) as f:
                payload = json.load(f)
        except FileNotFoundError:
            if name == "fused_solver":
                failures.append(f"{path} missing -- run "
                                "`python -m benchmarks.run --only fused_solver` first")
            continue  # other benches are gated only when present
        for row in payload["rows"]:
            checked += 1
            val = float(row[col])
            tag = {k: row[k] for k in ("d", "k", "L") if k in row}
            if val > budget:
                failures.append(
                    f"{name} {tag}: {col}={val:g} > {budget:g}")
            else:
                print(f"[ci_gate] {name} {tag}: {col}={val:g} OK")
        if name == "admm_convergence":
            for wc in payload.get("warm_vs_cold", []):
                checked += 1
                if not wc.get("gated", False):
                    print(f"[ci_gate] {name} {wc['scenario']}: "
                          f"cold={wc['cold_iters']} warm={wc['warm_iters']} "
                          "(recorded, ungated)")
                    continue
                if not wc["warm_iters"] < wc["cold_iters"]:
                    failures.append(
                        f"{name} {wc['scenario']}: warm-started sweep "
                        f"iterations {wc['warm_iters']} not below cold "
                        f"{wc['cold_iters']}")
                else:
                    print(f"[ci_gate] {name} {wc['scenario']}: "
                          f"warm {wc['warm_iters']} < cold "
                          f"{wc['cold_iters']} OK")
    if failures:
        for msg in failures:
            print(f"[ci_gate] FAIL: {msg}", file=sys.stderr)
        return 1
    print(f"[ci_gate] all gates green on {checked} rows")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
