"""CI gate over the BENCH_*.json artifacts: fail on perf/parity regression.

Run AFTER ``python -m benchmarks.run --only fused_solver`` (and
optionally ``--only lambda_path`` / ``--only admm_convergence`` /
``--only multi_round``).  Reads the machine-readable benchmark output
and exits nonzero when

  * the scan-vs-fused solver parity (``max_abs_diff``) exceeds the
    pinned 1e-5 budget -- a tighter bar than the benchmark's own
    internal 1e-3 assert, because on the CI CPU the interpreter
    executes the same float ops as the scan path and the observed diff
    is ~0; anything above 1e-5 means a real numerical regression in
    the kernel or the dispatch contract, not noise;
  * the convergence-adaptive solver (``admm_convergence``) drifts
    more than 1e-4 from the fixed-500 solution;
  * any *gated* warm-started re-solve (``admm_convergence``'s
    lambda-path re-sweeps, ``multi_round``'s pipeline re-entry) stops
    converging in strictly fewer iterations than its cold counterpart
    (DESIGN.md §7/§8);
  * multi-round refinement stops recovering: T=3 support-recovery F1
    at the largest machine count must stay within ``RECOVERY_GAP`` of
    the centralized baseline (``multi_round``'s ``recovery`` payload);
  * the compressed uplink regresses (``compressed_rounds``'s
    ``compression`` payload): the gated codec must fit its bit budget
    and stay within the declared slacks of the dense rounds, and --
    against the COMMITTED baseline at an unchanged operating point --
    must move EXACTLY the committed bits (wire-format pin) with F1
    within ``COMPRESSION_F1_DRIFT``.  Run-volatile payload fields
    (``generated_unix``, ``host``) are stripped by :func:`comparable`
    before any cross-run diff;
  * the gated bit-budget schedule regresses (``compressed_rounds``'s
    ``schedule`` payload): TOTAL (uplink + downlink) bits over the
    budget fraction of the dense total, F1 below dense parity, or --
    cross-PR at an unchanged operating point -- a realized per-round
    ``(k_up, k_down)`` plan or bit total differing AT ALL from the
    committed baseline;
  * wall-clock regresses more than ``WALLCLOCK_TOL`` against the
    COMMITTED root ``BENCH_*.json`` baselines for the fused-solver and
    lambda-path suites, summed over the (d, k, L) shapes both runs
    share.  The benchmarks mirror their fresh output to the repo root
    (clobbering the working copy), so the baseline is read from git --
    the default-branch tip when an origin exists (a PR that commits
    its own regenerated mirrors must not be its own baseline), else
    HEAD (local trajectory runs).  When git or the baseline is
    unavailable, or the baseline was recorded on a different backend
    or host (cross-machine timings gate noise, not code; homogeneous
    runner fleets opt in via ``CI_GATE_FORCE_WALLCLOCK=1``), the
    wall-clock gate is skipped with a notice -- parity gates still
    apply.  A fresh payload that stops emitting the timing column
    while the baseline has it FAILS (schema drift must not silently
    disarm the gate).

Usage: ``PYTHONPATH=src python -m benchmarks.ci_gate``
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import REPO_DIR, VOLATILE_KEYS, bench_json_path

PARITY_BUDGET = 1e-5
ADAPTIVE_PARITY_BUDGET = 1e-4  # early-exit solution vs fixed-500
RECOVERY_GAP = 0.05  # T=3 F1 within 5% of the centralized baseline
WALLCLOCK_TOL = 0.20  # fail when >20% slower than the committed baseline
# compressed_rounds cross-PR drift: fresh F1 may trail the committed
# baseline by at most this much (same synthetic seeds, so real drift
# means the codec or the rounds changed behavior)
COMPRESSION_F1_DRIFT = 0.01

# fault_rounds drift vs the committed baseline (same synthetic seeds)
FAULTS_F1_DRIFT = 0.01

# serving cross-PR drift: fresh protected-under-faults accuracy may
# trail the committed baseline by at most this much (same seeds)
SERVING_ACC_DRIFT = 0.01

# name -> column holding the gated max-abs parity
GATED = {
    "fused_solver": ("max_abs_diff", PARITY_BUDGET),
    "lambda_path": ("max_abs_diff", PARITY_BUDGET),
    "admm_convergence": ("max_abs_diff", ADAPTIVE_PARITY_BUDGET),
    "multi_round": (None, None),  # warm_vs_cold + recovery gates only
    "compressed_rounds": (None, None),  # compression-payload gates only
    "fault_rounds": (None, None),  # faults-payload gates only
    "serving": (None, None),  # serving-payload + warm_vs_cold gates only
}

# Skip-with-notice bookkeeping: every gate that declines to measure
# something records WHY here, and main() emits the machine-readable
# tally (non-zero exit stays reserved for real failures -- a skip that
# should fail the build belongs in ``failures``, not here).
SKIP_NOTICES: list[dict] = []


def _skip(name: str, reason: str) -> None:
    SKIP_NOTICES.append({"name": name, "reason": reason})
    print(f"[ci_gate] SKIP {name}: {reason}")


def comparable(payload: dict) -> dict:
    """A BENCH payload with run-volatile provenance stripped.

    ``generated_unix`` and ``host`` change on every regeneration even
    when the measured numbers are identical; any cross-run comparison
    (baseline diffs here, ``benchmarks/trajectory.py``) must go through
    this so provenance churn never reads as a regression.  Internal
    ``_``-prefixed bookkeeping (``_baseline_ref``) is stripped too.
    """
    return {k: v for k, v in payload.items()
            if k not in VOLATILE_KEYS and not k.startswith("_")}

# name -> wall-clock column summed across rows and compared against the
# committed baseline (the cross-PR perf trajectory, PR 4's root mirrors)
WALLCLOCK_GATED = {
    "fused_solver": "fused_s",
    "lambda_path": "folded_s",
}


def _committed_baseline(name: str) -> dict | None:
    """The committed root BENCH_<name>.json (see module doc).

    Prefers the default-branch tip over HEAD: a PR that regenerates and
    commits its own mirrors would otherwise be compared against its own
    numbers and a regression could never trip the gate.  Falls back to
    HEAD where no origin exists (local trajectory runs, where HEAD is
    the pre-change baseline).
    """
    for ref in ("origin/HEAD", "origin/main", "HEAD"):
        try:
            out = subprocess.run(
                ["git", "show", f"{ref}:BENCH_{name}.json"],
                capture_output=True, text=True, cwd=REPO_DIR, timeout=30)
        except (OSError, subprocess.TimeoutExpired):
            return None
        if out.returncode != 0:
            continue
        try:
            payload = json.loads(out.stdout)
        except ValueError:
            continue
        payload["_baseline_ref"] = ref
        return payload
    return None


def _shape_key(row: dict) -> tuple:
    return tuple((k, row[k]) for k in ("d", "k", "L") if k in row)


def _gate_wallclock(name: str, payload: dict, failures: list[str]) -> int:
    col = WALLCLOCK_GATED[name]
    base = _committed_baseline(name)
    if base is None:
        _skip(name, "no committed baseline readable from git "
              "-- wall-clock gate skipped")
        return 0
    ref = base.get("_baseline_ref", "HEAD")
    if base.get("backend") != payload.get("backend"):
        _skip(name, f"baseline backend {base.get('backend')!r} != "
              f"{payload.get('backend')!r} -- wall-clock gate skipped")
        return 0
    if base.get("host") != payload.get("host"):
        # timings are only comparable on the machine class that recorded
        # the baseline; a different host gates noise, not code.  Fleets
        # with homogeneous runners opt in via the env override.
        if not os.environ.get("CI_GATE_FORCE_WALLCLOCK"):
            _skip(name, f"baseline host {base.get('host')!r} != "
                  f"{payload.get('host')!r} -- wall-clock gate skipped "
                  "(set CI_GATE_FORCE_WALLCLOCK=1 on homogeneous runners)")
            return 0
        print(f"[ci_gate] {name}: host mismatch overridden by "
              "CI_GATE_FORCE_WALLCLOCK")
    # sum only over (d, k, L) shapes present in BOTH runs, so a grid
    # change skips cleanly instead of comparing apples to oranges
    base_by = {_shape_key(r): float(r[col]) for r in base["rows"]
               if col in r}
    fresh_by = {_shape_key(r): float(r[col]) for r in payload["rows"]
                if col in r}
    shared = sorted(base_by.keys() & fresh_by.keys())
    if not shared:
        if fresh_by or not base_by:
            _skip(name, f"no shared {col} shapes with the baseline "
                  "-- wall-clock gate skipped")
        else:
            # the baseline has timings but the fresh run emits none:
            # schema drift would silently disarm the gate
            failures.append(
                f"{name}: fresh payload has no {col} rows but the "
                "committed baseline does -- wall-clock gate measured "
                "nothing")
        return 0
    base_s = sum(base_by[k] for k in shared)
    fresh_s = sum(fresh_by[k] for k in shared)
    ratio = fresh_s / base_s
    if ratio > 1.0 + WALLCLOCK_TOL:
        failures.append(
            f"{name}: wall-clock sum({col}) over {len(shared)} shared "
            f"shapes {fresh_s:.4f}s is {ratio:.2f}x the committed "
            f"baseline {base_s:.4f}s at {ref} (> {1 + WALLCLOCK_TOL:.2f}x)")
    else:
        print(f"[ci_gate] {name}: sum({col}) over {len(shared)} shared "
              f"shapes {fresh_s:.4f}s vs baseline {base_s:.4f}s at {ref} "
              f"({ratio:.2f}x) OK")
    return 1


def _gate_compression(payload: dict, failures: list[str]) -> int:
    """The compressed-uplink gates (``benchmarks/compressed_rounds.py``).

    Fresh-run gates mirror the benchmark's own asserts: the gated codec
    must fit the bit budget and stay within the declared slacks of the
    dense rounds' F1 and excess-l2 recovery.  The cross-PR gate then
    compares against the COMMITTED baseline mirror (volatile fields
    stripped via :func:`comparable`): at an unchanged operating point
    the wire format must not silently grow -- bits compared EXACTLY,
    the accounting is deterministic -- and F1 must not drift below the
    committed number by more than ``COMPRESSION_F1_DRIFT``.
    """
    gate = payload["compression"]
    cfg = gate.get("config", "?")
    ratio = float(gate["bits_ratio"])
    budget = float(gate["bits_budget"])
    if ratio > budget:
        failures.append(
            f"compressed_rounds {cfg}: bits_ratio {ratio:.3f} over the "
            f"{budget:.2f} budget")
    f1_slack = float(gate.get("f1_slack", COMPRESSION_F1_DRIFT))
    if float(gate["f1_comp"]) < float(gate["f1_dense"]) - f1_slack:
        failures.append(
            f"compressed_rounds {cfg}: F1 {gate['f1_comp']:.3f} trails "
            f"dense rounds {gate['f1_dense']:.3f} by more than {f1_slack}")
    rec_slack = float(gate.get("rec_slack", COMPRESSION_F1_DRIFT))
    if float(gate["rec_comp"]) < float(gate["rec_dense"]) - rec_slack:
        failures.append(
            f"compressed_rounds {cfg}: recovery {gate['rec_comp']:.3f} "
            f"trails dense rounds {gate['rec_dense']:.3f} by more than "
            f"{rec_slack}")
    else:
        print(f"[ci_gate] compressed_rounds {cfg}: "
              f"{gate['bits_per_round']}/{gate['dense_bits_per_round']} "
              f"bits/round ({ratio:.0%}), F1 {gate['f1_comp']:.3f} vs "
              f"dense {gate['f1_dense']:.3f}, recovery "
              f"{gate['rec_comp']:.3f} vs {gate['rec_dense']:.3f} OK")

    base = _committed_baseline("compressed_rounds")
    if base is None or "compression" not in comparable(base):
        _skip("compressed_rounds", "no committed baseline payload "
              "-- cross-PR gate skipped")
        return 1
    bgate = comparable(base)["compression"]
    point = ("config", "k_top", "quantize", "d", "m")
    if any(gate.get(k) != bgate.get(k) for k in point):
        _skip("compressed_rounds", "gated operating point changed "
              "vs baseline -- cross-PR gate skipped")
        return 1
    ref = base.get("_baseline_ref", "HEAD")
    for key in ("bits_per_round", "dense_bits_per_round"):
        if int(gate[key]) != int(bgate[key]):
            failures.append(
                f"compressed_rounds {cfg}: {key} {gate[key]} != committed "
                f"{bgate[key]} at {ref} -- the wire format changed under "
                "an unchanged operating point")
    drift = float(bgate["f1_comp"]) - float(gate["f1_comp"])
    if drift > COMPRESSION_F1_DRIFT:
        failures.append(
            f"compressed_rounds {cfg}: F1 {gate['f1_comp']:.3f} drifted "
            f"{drift:.3f} below the committed baseline "
            f"{bgate['f1_comp']:.3f} at {ref}")
    else:
        print(f"[ci_gate] compressed_rounds {cfg}: bits exact and F1 "
              f"within {COMPRESSION_F1_DRIFT} of baseline at {ref} OK")
    return 1


def _gate_schedule(payload: dict, failures: list[str]) -> int:
    """The bit-budget schedule gates (two-way transport, DESIGN.md §13).

    Fresh-run: the gated schedule's TOTAL (uplink + downlink) bits must
    fit its budget fraction of the dense total at F1 parity with the
    dense rounds.  Cross-PR: at an unchanged operating point the
    REALIZED schedule -- the per-round ``(k_up, k_down)`` plan and the
    per-direction bit totals -- must match the committed baseline
    EXACTLY (planning is deterministic host-side arithmetic, so any
    diff is a wire-format change), and F1 must not drift below the
    committed number by more than ``COMPRESSION_F1_DRIFT``.
    """
    gate = payload["schedule"]
    tag = f"compressed_rounds schedule {gate.get('schedule', '?')}"
    ratio = float(gate["bits_ratio"])
    budget = float(gate["bits_budget"])
    if ratio > budget:
        failures.append(
            f"{tag}: total (up+down) bits_ratio {ratio:.3f} over the "
            f"{budget:.2f} budget")
    f1_slack = float(gate.get("f1_slack", COMPRESSION_F1_DRIFT))
    if float(gate["f1_sched"]) < float(gate["f1_dense"]) - f1_slack:
        failures.append(
            f"{tag}: F1 {gate['f1_sched']:.3f} trails dense rounds "
            f"{gate['f1_dense']:.3f} by more than {f1_slack}")
    else:
        print(f"[ci_gate] {tag}: {gate['total_bits']} of "
              f"{gate['dense_total_bits']} total bits ({ratio:.0%}), "
              f"F1 {gate['f1_sched']:.3f} vs dense "
              f"{gate['f1_dense']:.3f} OK")

    base = _committed_baseline("compressed_rounds")
    if base is None or "schedule" not in comparable(base):
        _skip("compressed_rounds", "no committed schedule payload "
              "-- cross-PR schedule gate skipped")
        return 1
    bgate = comparable(base)["schedule"]
    point = ("schedule", "mode", "taper", "quantize", "down_fraction",
             "budget_bits", "d", "m", "t_rounds")
    if any(gate.get(k) != bgate.get(k) for k in point):
        _skip("compressed_rounds", "gated schedule operating point "
              "changed vs baseline -- cross-PR schedule gate skipped")
        return 1
    ref = base.get("_baseline_ref", "HEAD")
    for key in ("up_bits", "down_bits", "total_bits", "dense_total_bits"):
        if int(gate[key]) != int(bgate[key]):
            failures.append(
                f"{tag}: {key} {gate[key]} != committed {bgate[key]} at "
                f"{ref} -- the wire format changed under an unchanged "
                "operating point")
    plan = [[int(k) for k in pair] for pair in gate["plan"]]
    bplan = [[int(k) for k in pair] for pair in bgate["plan"]]
    if plan != bplan:
        failures.append(
            f"{tag}: realized plan {plan} != committed {bplan} at {ref}")
    drift = float(bgate["f1_sched"]) - float(gate["f1_sched"])
    if drift > COMPRESSION_F1_DRIFT:
        failures.append(
            f"{tag}: F1 {gate['f1_sched']:.3f} drifted {drift:.3f} below "
            f"the committed baseline {bgate['f1_sched']:.3f} at {ref}")
    else:
        print(f"[ci_gate] {tag}: plan and bits exact and F1 within "
              f"{COMPRESSION_F1_DRIFT} of baseline at {ref} OK")
    return 1


def _gate_faults(payload: dict, failures: list[str]) -> int:
    """The fault-tolerance gates (``benchmarks/fault_rounds.py``).

    At the gated operating point (d=100/m=60/T=3, 10% per-round
    dropout) liveness-masked aggregation must keep excess-l2 recovery
    within ``rec_slack`` (relative) of the no-fault run and F1 within
    ``f1_slack``, while the unmasked mean must demonstrably degrade --
    a fault layer that costs nothing is indistinguishable from one
    that does nothing.  Cross-PR: masked F1 must not drift below the
    committed baseline (same synthetic seeds).
    """
    gate = payload["faults"]
    tag = (f"fault_rounds d={gate['d']}/m={gate['m']}/T={gate['rounds']}"
           f"/dropout={gate['dropout']}")
    rec_nf, rec_m = float(gate["rec_nofault"]), float(gate["rec_masked"])
    rec_u = float(gate["rec_unmasked"])
    f1_nf, f1_m = float(gate["f1_nofault"]), float(gate["f1_masked"])
    rec_slack = float(gate.get("rec_slack", 0.10))
    f1_slack = float(gate.get("f1_slack", 0.02))
    rec_floor = rec_nf - rec_slack * max(abs(rec_nf), 1e-9)
    if rec_m < rec_floor:
        failures.append(
            f"{tag}: masked recovery {rec_m:.3f} more than "
            f"{rec_slack:.0%} below the no-fault run {rec_nf:.3f}")
    if f1_m < f1_nf - f1_slack:
        failures.append(
            f"{tag}: masked F1 {f1_m:.3f} trails no-fault {f1_nf:.3f} "
            f"by more than {f1_slack}")
    if not rec_u < rec_floor:
        failures.append(
            f"{tag}: unmasked recovery {rec_u:.3f} does not degrade "
            f"below the masked floor {rec_floor:.3f} -- the fault "
            "injection is not biting")
    if not failures:
        print(f"[ci_gate] {tag}: masked rec {rec_m:.3f} / F1 {f1_m:.3f} "
              f"vs no-fault {rec_nf:.3f} / {f1_nf:.3f}, unmasked rec "
              f"{rec_u:.3f} degrades OK")

    base = _committed_baseline("fault_rounds")
    if base is None or "faults" not in comparable(base):
        _skip("fault_rounds", "no committed baseline payload "
              "-- cross-PR gate skipped")
        return 1
    bgate = comparable(base)["faults"]
    point = ("d", "m", "rounds", "dropout")
    if any(gate.get(k) != bgate.get(k) for k in point):
        _skip("fault_rounds", "gated operating point changed vs baseline "
              "-- cross-PR gate skipped")
        return 1
    ref = base.get("_baseline_ref", "HEAD")
    drift = float(bgate["f1_masked"]) - f1_m
    if drift > FAULTS_F1_DRIFT:
        failures.append(
            f"{tag}: masked F1 {f1_m:.3f} drifted {drift:.3f} below the "
            f"committed baseline {bgate['f1_masked']:.3f} at {ref}")
    else:
        print(f"[ci_gate] fault_rounds: masked F1 within "
              f"{FAULTS_F1_DRIFT} of baseline at {ref} OK")
    return 1


def _gate_serving(payload: dict, failures: list[str]) -> int:
    """The serving gates (``benchmarks/serving.py``, DESIGN.md §12).

    Under the gated fault plan (ingest corruption + refit divergence +
    refresh drops) the protected runtime must serve finite scores and
    stay within ``acc_slack`` of its fault-free twin, while the
    unprotected baseline must demonstrably degrade (non-finite, or
    accuracy below the slack floor) -- a protection layer that costs
    nothing is indistinguishable from one that does nothing.  The
    staleness curve must actually slope (drift bites) and a refresh
    must buy accuracy back.  Cross-PR: protected accuracy must not
    drift below the committed baseline, and qps gates like wall-clock
    (host/backend-matched, ``WALLCLOCK_TOL`` ratio).
    """
    gate = payload["serving"]
    tag = (f"serving d={gate['d']}/B={gate['batch']}"
           f"/ticks={gate['ticks']}")
    acc_c = float(gate["acc_clean"])
    acc_p = float(gate["acc_protected"])
    acc_u = float(gate["acc_unprotected"])
    slack = float(gate.get("acc_slack", 0.02))
    if not gate.get("finite_protected", False):
        failures.append(f"{tag}: protected serving emitted non-finite "
                        "scores under faults")
    if acc_p < acc_c - slack:
        failures.append(
            f"{tag}: protected accuracy {acc_p:.3f} trails the "
            f"fault-free run {acc_c:.3f} by more than {slack}")
    degraded = (not gate.get("finite_unprotected", True)
                or acc_u < acc_c - slack)
    if not degraded:
        failures.append(
            f"{tag}: unprotected accuracy {acc_u:.3f} does not degrade "
            f"below {acc_c - slack:.3f} -- the fault injection is not "
            "biting")
    s0 = float(gate["stale_acc_s0"])
    smax = float(gate["stale_acc_smax"])
    refreshed = float(gate["stale_acc_refreshed"])
    if not smax < s0:
        failures.append(
            f"{tag}: staleness curve is flat ({smax:.3f} at "
            f"s={gate['stale_smax']} vs {s0:.3f} at s=0) -- the drift "
            "model is not biting")
    if not refreshed > smax:
        failures.append(
            f"{tag}: a refresh at max staleness bought nothing back "
            f"({refreshed:.3f} vs stale {smax:.3f})")
    if not failures:
        print(f"[ci_gate] {tag}: protected {acc_p:.3f} vs clean "
              f"{acc_c:.3f}, unprotected {acc_u:.3f} degrades, staleness "
              f"{s0:.3f}->{smax:.3f} (refresh {refreshed:.3f}) OK")

    base = _committed_baseline("serving")
    if base is None or "serving" not in comparable(base):
        _skip("serving", "no committed baseline payload "
              "-- cross-PR gate skipped")
        return 1
    bgate = comparable(base)["serving"]
    point = ("d", "batch", "ticks", "refit_every",
             "corrupt", "diverge", "drop")
    if any(gate.get(k) != bgate.get(k) for k in point):
        _skip("serving", "gated operating point changed vs baseline "
              "-- cross-PR gate skipped")
        return 1
    ref = base.get("_baseline_ref", "HEAD")
    drift = float(bgate["acc_protected"]) - acc_p
    if drift > SERVING_ACC_DRIFT:
        failures.append(
            f"{tag}: protected accuracy {acc_p:.3f} drifted {drift:.3f} "
            f"below the committed baseline "
            f"{bgate['acc_protected']:.3f} at {ref}")
    else:
        print(f"[ci_gate] serving: protected accuracy within "
              f"{SERVING_ACC_DRIFT} of baseline at {ref} OK")
    # qps is wall-clock: only comparable against the same host+backend
    if (base.get("backend") != payload.get("backend")
            or (base.get("host") != payload.get("host")
                and not os.environ.get("CI_GATE_FORCE_WALLCLOCK"))):
        _skip("serving", "baseline host/backend mismatch "
              "-- qps gate skipped")
        return 1
    base_qps = float(bgate.get("qps", 0.0))
    if base_qps > 0 and float(gate["qps"]) < base_qps / (1 + WALLCLOCK_TOL):
        failures.append(
            f"{tag}: sustained qps {gate['qps']:,.0f} fell more than "
            f"{WALLCLOCK_TOL:.0%} below the baseline {base_qps:,.0f} "
            f"at {ref}")
    else:
        print(f"[ci_gate] serving: qps {gate['qps']:,.0f} vs baseline "
              f"{base_qps:,.0f} at {ref} OK")
    return 2


def main() -> int:
    failures = []
    checked = 0
    SKIP_NOTICES.clear()
    for name, (col, budget) in GATED.items():
        path = bench_json_path(name)
        try:
            with open(path) as f:
                payload = json.load(f)
        except FileNotFoundError:
            if name == "fused_solver":
                failures.append(f"{path} missing -- run "
                                "`python -m benchmarks.run --only fused_solver` first")
            else:
                _skip(name, f"{path} missing -- gated only when present")
            continue
        if col is not None:
            for row in payload["rows"]:
                checked += 1
                val = float(row[col])
                tag = {k: row[k] for k in ("d", "k", "L") if k in row}
                if val > budget:
                    failures.append(
                        f"{name} {tag}: {col}={val:g} > {budget:g}")
                else:
                    print(f"[ci_gate] {name} {tag}: {col}={val:g} OK")
        for wc in payload.get("warm_vs_cold", []):
            checked += 1
            if not wc.get("gated", False):
                print(f"[ci_gate] {name} {wc['scenario']}: "
                      f"cold={wc['cold_iters']} warm={wc['warm_iters']} "
                      "(recorded, ungated)")
                continue
            if not wc["warm_iters"] < wc["cold_iters"]:
                failures.append(
                    f"{name} {wc['scenario']}: warm-started solve "
                    f"iterations {wc['warm_iters']} not below cold "
                    f"{wc['cold_iters']}")
            elif ("drift_budget" in wc
                  and float(wc["max_abs_diff"]) > float(wc["drift_budget"])):
                # fewer iterations only counts if the resumed solve still
                # lands on the cold solution
                failures.append(
                    f"{name} {wc['scenario']}: warm-vs-cold solution "
                    f"drift {wc['max_abs_diff']:g} exceeds the "
                    f"{wc['drift_budget']:g} budget")
            else:
                print(f"[ci_gate] {name} {wc['scenario']}: "
                      f"warm {wc['warm_iters']} < cold "
                      f"{wc['cold_iters']} OK")
        if name == "multi_round" and "recovery" in payload:
            rec = payload["recovery"]
            checked += 1
            gap = float(rec["gap"])
            budget = float(rec.get("gap_budget", RECOVERY_GAP))
            if gap > budget:
                failures.append(
                    f"multi_round m={rec['m']}: T=3 F1 {rec['f1_t3']:.3f} "
                    f"trails centralized {rec['f1_cent']:.3f} by "
                    f"{gap:.3f} (> {budget})")
            else:
                print(f"[ci_gate] multi_round m={rec['m']}: T=3 F1 "
                      f"{rec['f1_t3']:.3f} within {gap:.3f} of centralized "
                      f"{rec['f1_cent']:.3f} OK")
        if name == "compressed_rounds" and "compression" in payload:
            checked += _gate_compression(payload, failures)
        if name == "compressed_rounds" and "schedule" in payload:
            checked += _gate_schedule(payload, failures)
        if name == "fault_rounds" and "faults" in payload:
            checked += _gate_faults(payload, failures)
        if name == "serving" and "serving" in payload:
            checked += _gate_serving(payload, failures)
        if name in WALLCLOCK_GATED:
            checked += _gate_wallclock(name, payload, failures)
    # the machine-readable skip tally: CI log scrapers key on this line,
    # and a skip count > 0 with a green exit is the expected shape for
    # partial runs (only failures may flip the exit code)
    print("[ci_gate] skips "
          + json.dumps({"count": len(SKIP_NOTICES),
                        "notices": SKIP_NOTICES}, sort_keys=True))
    if failures:
        for msg in failures:
            print(f"[ci_gate] FAIL: {msg}", file=sys.stderr)
        return 1
    print(f"[ci_gate] all gates green on {checked} rows")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
