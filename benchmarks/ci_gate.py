"""CI gate over the BENCH_*.json artifacts: fail on parity regression.

Run AFTER ``python -m benchmarks.run --only fused_solver`` (and
optionally ``--only lambda_path``).  Reads the machine-readable
benchmark output and exits nonzero when the scan-vs-fused solver
parity (``max_abs_diff``) exceeds the pinned budget -- a tighter bar
than the benchmark's own internal 1e-3 assert, because on the CI CPU
the interpreter executes the same float ops as the scan path and the
observed diff is ~0; anything above 1e-5 means a real numerical
regression in the kernel or the dispatch contract, not noise.

Usage: ``PYTHONPATH=src python -m benchmarks.ci_gate``
"""

from __future__ import annotations

import json
import sys

from benchmarks.common import bench_json_path

PARITY_BUDGET = 1e-5

# name -> column holding the scan-vs-fused max-abs parity
GATED = {
    "fused_solver": "max_abs_diff",
    "lambda_path": "max_abs_diff",
}


def main() -> int:
    failures = []
    checked = 0
    for name, col in GATED.items():
        path = bench_json_path(name)
        try:
            with open(path) as f:
                payload = json.load(f)
        except FileNotFoundError:
            if name == "fused_solver":
                failures.append(f"{path} missing -- run "
                                "`python -m benchmarks.run --only fused_solver` first")
            continue  # other benches are gated only when present
        for row in payload["rows"]:
            checked += 1
            val = float(row[col])
            tag = {k: row[k] for k in ("d", "k", "L") if k in row}
            if val > PARITY_BUDGET:
                failures.append(
                    f"{name} {tag}: {col}={val:g} > {PARITY_BUDGET:g}")
            else:
                print(f"[ci_gate] {name} {tag}: {col}={val:g} OK")
    if failures:
        for msg in failures:
            print(f"[ci_gate] FAIL: {msg}", file=sys.stderr)
        return 1
    print(f"[ci_gate] parity within {PARITY_BUDGET:g} on {checked} rows")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
