"""Convergence-adaptive fused ADMM: adaptive-vs-fixed + warm-vs-cold (SSPerf-A4).

Three questions, one per section (DESIGN.md §7):

1. **Adaptive vs fixed** -- for CLIME-scale column batches (the
   pipeline's hot workload: d precision columns per worker), how much
   wall-clock does the residual-gated early exit save against the
   fixed-500 schedule, and how far is the early-exit solution from the
   fixed-500 one?  Gate (``benchmarks/ci_gate.py``): parity <= 1e-4
   and >= 2x wall-clock on at least two CI shapes.

2. **Iterations-to-tol histogram** -- the same solves run blocked
   (``block_k=16``) so each grid block exits independently; the
   per-block iteration counts (the kernel's new diagnostic output)
   are recorded per shape in the JSON payload.

3. **Warm vs cold lambda-path re-sweeps** -- full-state continuation:
   (a) re-sweeping the same grid from the previous sweep's
   ``PathResult.state`` (the carry of iterative tuning loops that
   re-enter the worker pipeline), and (b) tolerance continuation
   (resume a tol=2e-4 solve down to 1e-5 vs a cold 1e-5 solve).
   Gate: warm-started iterations strictly below cold on both.
   A data-refresh re-sweep (new sample draw of the same problem) is
   recorded UNGATED: warm starts win there only once the refreshed
   Sigma_hat is close (large n) -- carrying scaled duals across a big
   problem perturbation can cost iterations, which is exactly why the
   state carry is optional everywhere (see RESULTS.md).

On CPU the kernel runs under the Pallas interpreter inside jit, so
wall-clock scales with executed iterations exactly as on TPU; the
speedup column is the TPU-relevant signal up to the interpreter's
per-chunk overheads (which UNDERSTATE the win: the residual check is
VMEM-local on TPU).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table, write_bench_json, write_csv
from repro.core import path as rpath
from repro.core.dantzig import DantzigConfig
from repro.core.solver_dispatch import solve_dantzig, solve_dantzig_full
from repro.kernels.spectral import spectral_factor
from repro.stats import synthetic
from repro.stats.synthetic import ar1_covariance

# (d, ar) CLIME shapes: b = I, one column per precision column
SHAPES_CI = [(64, 0.4), (96, 0.4), (128, 0.4)]
SHAPES_PAPER = [(128, 0.4), (256, 0.4), (384, 0.5)]

LAM = 0.3
TOL = 2e-4
CHECK_EVERY = 25
FIXED_ITERS = 500
HIST_BLOCK_K = 16


def _time(fn, repeats: int) -> float:
    jax.block_until_ready(fn())  # compile + warm, fully drained
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def adaptive_vs_fixed(shapes, repeats: int = 3):
    rows = []
    hists = {}
    for d, ar in shapes:
        factor = spectral_factor(jnp.asarray(ar1_covariance(d, ar), jnp.float32))
        b = jnp.eye(d, dtype=jnp.float32)
        cfg_fixed = DantzigConfig(max_iters=FIXED_ITERS, adapt_rho=False,
                                  fused=True)
        cfg_ad = cfg_fixed._replace(tol=TOL, check_every=CHECK_EVERY)

        out_fixed = solve_dantzig(factor, b, LAM, cfg_fixed)
        res = solve_dantzig_full(factor, b, LAM, cfg_ad)
        parity = float(jnp.max(jnp.abs(res.beta - out_fixed)))

        t_fixed = _time(lambda: solve_dantzig(factor, b, LAM, cfg_fixed),
                        repeats)
        t_ad = _time(lambda: solve_dantzig_full(factor, b, LAM, cfg_ad).beta,
                     repeats)

        # per-block iterations-to-tol histogram (each block exits on its
        # own residual once the batch is tiled over the Pallas grid)
        blocked = solve_dantzig_full(
            factor, b, LAM, cfg_ad._replace(block_k=HIST_BLOCK_K))
        per_block = np.asarray(blocked.iters).reshape(-1, HIST_BLOCK_K)[:, 0]
        vals, counts = np.unique(per_block, return_counts=True)
        hists[f"d{d}"] = {int(v): int(c) for v, c in zip(vals, counts)}

        rows.append([d, ar, LAM, TOL, CHECK_EVERY, FIXED_ITERS,
                     int(res.iters.max()), t_fixed, t_ad, t_fixed / t_ad,
                     parity])
    return rows, hists


def warm_vs_cold():
    """Full-state continuation scenarios; iterations are per column."""
    d = 96
    factor = spectral_factor(jnp.asarray(ar1_covariance(d, 0.4), jnp.float32))
    b = jnp.eye(d, dtype=jnp.float32)[:, :16]  # a CLIME column block
    lams = jnp.linspace(0.25, 0.55, 6)
    cfg = DantzigConfig(max_iters=FIXED_ITERS, adapt_rho=False, fused=True,
                        tol=TOL, check_every=CHECK_EVERY, block_k=16)

    rows = []

    # (a) same-grid re-sweep from the previous sweep's state
    cold = rpath.solve_dantzig_path(factor, b, lams, cfg)
    warm = rpath.solve_dantzig_path(factor, b, lams, cfg,
                                    state=cold.state, rho=cold.rho)
    drift = float(jnp.max(jnp.abs(warm.beta - cold.beta)))
    rows.append(["resweep_same_grid", int(cold.iters.max(axis=1).sum()),
                 int(warm.iters.max(axis=1).sum()), drift, True])

    # (b) tolerance continuation: a solve RESUMED from the pipeline's
    # working-tolerance (2e-4) state down to 1e-5, vs a cold 1e-5
    # solve.  warm_iters counts the resumed stage only -- the 2e-4
    # iterations were paid by the earlier working solve (recorded as
    # stage1_iters in the JSON payload).
    tight = cfg._replace(tol=1e-5, block_k=None)
    bb = jnp.eye(d, dtype=jnp.float32)
    stage1 = solve_dantzig_full(factor, bb, LAM, cfg._replace(block_k=None))
    resumed = solve_dantzig_full(factor, bb, LAM, tight, state=stage1.state)
    cold_tight = solve_dantzig_full(factor, bb, LAM, tight)
    drift = float(jnp.max(jnp.abs(resumed.beta - cold_tight.beta)))
    rows.append(["tol_continuation_resume", int(cold_tight.iters.max()),
                 int(resumed.iters.max()), drift, True])
    extra = {"tol_continuation_stage1_iters": int(stage1.iters.max())}

    # (c) data-refresh re-sweep (recorded, NOT gated: the warm carry
    # only wins once the refreshed Sigma_hat is close -- see module doc)
    n = 20000
    p = synthetic.make_problem(d=d, n_signal=5, rho=0.4)
    x1, y1 = synthetic.sample_two_class(jax.random.PRNGKey(0), p, n, n)
    x2, y2 = synthetic.sample_two_class(jax.random.PRNGKey(9), p, n, n)
    from repro.core.pipeline import suff_stats

    s1, s2 = suff_stats(x1, y1), suff_stats(x2, y2)
    c1 = rpath.solve_dantzig_path(s1.sigma, b, lams, cfg)
    c2 = rpath.solve_dantzig_path(s2.sigma, b, lams, cfg)
    w2 = rpath.solve_dantzig_path(s2.sigma, b, lams, cfg,
                                  state=c1.state, rho=c1.rho)
    drift = float(jnp.max(jnp.abs(w2.beta - c2.beta)))
    rows.append(["resweep_data_refresh", int(c2.iters.max(axis=1).sum()),
                 int(w2.iters.max(axis=1).sum()), drift, False])
    return rows, extra


def main(paper: bool = False) -> None:
    shapes = SHAPES_PAPER if paper else SHAPES_CI
    rows, hists = adaptive_vs_fixed(shapes)
    header = ["d", "ar", "lam", "tol", "check_every", "fixed_iters",
              "adaptive_iters", "fixed_s", "adaptive_s", "speedup",
              "max_abs_diff"]
    print_table("adaptive (tol-gated) vs fixed-500 fused ADMM", header, rows)
    print("iterations-to-tol histograms (per 16-column block):", hists)

    wrows, wextra = warm_vs_cold()
    wheader = ["scenario", "cold_iters", "warm_iters", "max_abs_diff",
               "gated"]
    print_table("warm-started vs cold lambda-path re-sweeps", wheader, wrows)

    write_csv("admm_convergence.csv", header, rows)
    jpath = write_bench_json(
        "admm_convergence", header, rows,
        iters_to_tol_hist=hists,
        warm_vs_cold=[dict(zip(wheader, r)) for r in wrows],
        **wextra)
    print(f"[admm_convergence] wrote {jpath}")

    # the point of the tentpole: converge, don't run out the clock
    assert all(r[-1] <= 1e-4 for r in rows), "adaptive parity regressed"
    fast = [r for r in rows if r[9] >= 2.0]
    assert len(fast) >= 2, f"expected >=2 shapes at >=2x, got {rows}"
    for scenario, cold, warmed, _, gated in wrows:
        if gated:
            assert warmed < cold, (scenario, cold, warmed)


if __name__ == "__main__":
    main()
