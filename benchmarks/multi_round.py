"""Multi-round refinement past the one-shot m-barrier (DESIGN.md §8).

Two questions, one per section:

1. **Error vs machine count at T ∈ {1, 2, 3} rounds.**  Fixed total
   sample size N, growing m: past Theorem 4.5's threshold the one-shot
   (T=1) averaged debiased estimator degrades -- its l2 error grows
   multiplicatively over the centralized solve while oracle-thresholded
   support-recovery F1 plateaus -- and extra O(d) refinement rounds
   pull it back: each round contracts the deviation from the
   fixed-point estimator whose error averages ALL N samples' score
   noise (the centralized rate), with no condition tying m to the
   one-shot threshold.  All T values read from ONE set of per-machine
   solves (`return_all_rounds`), so the sweep itself demonstrates the
   zero-extra-solves round cost.  Gates (``benchmarks/ci_gate.py``):
   at the largest m, T=3 must (a) cut the one-shot's excess l2 error
   over centralized by >= 30% and (b) keep support-recovery F1 within
   5% of the centralized baseline (the ``recovery`` payload).

2. **Warm vs cold pipeline re-entry.**  The realistic tuning loop
   re-enters the rounds pipeline after moving the operating point; the
   returned :class:`~repro.core.pipeline.WorkerSolves` carries the warm
   rho + full ADMM state of BOTH per-machine solves, and a re-entry
   resumes them instead of restarting from zero.  With ``cfg.tol`` set
   the executed iteration counts are measured outputs; gate:
   warm-round iterations strictly below cold-round iterations.

Quick mode (default, CI-sized): d=100, N=6000, m ∈ {12, 24, 60},
2 repeats.  ``--paper`` runs the published Figure-1 design scaled to
the refinement question: d=200, N=10000, m ∈ {10, 20, 40, 80},
10 repeats.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    print_table,
    tuned_metrics,
    write_bench_json,
    write_csv,
)
from repro.core import rounds as rounds_core
from repro.core.dantzig import DantzigConfig
from repro.core.pipeline import BinaryHead
from repro.core.slda import centralized_slda
from repro.stats import synthetic

T_GRID = np.geomspace(0.005, 2.0, 25)
ROUNDS = (1, 2, 3)

# warm-vs-cold re-entry scenario (section 2)
WARM_TOL = 2e-4
WARM_CHECK_EVERY = 25
WARM_MAX_ITERS = 800
# a warm re-entry must land on the cold solution, not just exit early:
# both runs solve to tol=2e-4 per chunk, so the aggregates may differ
# by a few residual tolerances but no more
WARM_DRIFT_BUDGET = 1e-2
# T=3 support-recovery F1 within 5% of the centralized baseline (the
# single source for benchmarks/ci_gate.py's recovery gate)
RECOVERY_GAP = 0.05


def error_vs_m(paper: bool, seed: int = 0):
    if paper:
        # the paper's Figure-1 design (d=200, rho=0.8) -- the scale
        # where the one-shot's F1 degradation is visible on top of the
        # l2 blow-up the quick mode demonstrates
        d, n_total, machines, repeats = 200, 10_000, (10, 20, 40, 80), 10
        rho, iters = 0.8, 600
    else:
        # CI-sized: rho=0.6 keeps min|beta*| (~0.25) well above the
        # refined fixed point's dense null-noise floor (~0.13 at this
        # N), so the F1-recovery gate is stable across draws while the
        # l2 barrier (one-shot 3x centralized at m=60) stays dramatic
        d, n_total, machines, repeats = 100, 6_000, (12, 24, 60), 2
        rho, iters = 0.6, 400
    cfg = DantzigConfig(max_iters=iters)
    problem = synthetic.make_problem(d=d, n_signal=10, rho=rho)
    b1 = float(jnp.sum(jnp.abs(problem.beta_star)))
    lam_c = 0.30 * math.sqrt(math.log(d) / n_total) * b1

    rows = []
    for m in machines:
        n = n_total // m
        n1 = n2 = n // 2
        lam = 0.30 * math.sqrt(math.log(d) / n) * b1
        acc = {}
        for rep in range(repeats):
            key = jax.random.fold_in(jax.random.PRNGKey(seed),
                                     m * 1000 + rep)
            xs, ys = synthetic.sample_machines(key, problem, m, n1, n2)
            cent = centralized_slda(
                xs.reshape(-1, d), ys.reshape(-1, d), lam_c, cfg)
            mc = tuned_metrics(cent, problem.beta_star, T_GRID)
            acc.setdefault("f1_cent", []).append(mc["f1"])
            acc.setdefault("l2_cent", []).append(mc["l2"])
            # ONE set of per-machine solves serves every round count
            bars, _ = rounds_core.simulate_multi_round(
                BinaryHead(), (xs, ys), lam=lam, lam_prime=lam,
                rounds=max(ROUNDS), cfg=cfg, return_all_rounds=True)
            for t_rounds in ROUNDS:
                mt = tuned_metrics(bars[t_rounds - 1][:, 0],
                                   problem.beta_star, T_GRID)
                acc.setdefault(f"f1_t{t_rounds}", []).append(mt["f1"])
                acc.setdefault(f"l2_t{t_rounds}", []).append(mt["l2"])
        mean = {k: sum(v) / len(v) for k, v in acc.items()}
        rows.append([m, n, mean["f1_cent"],
                     *[mean[f"f1_t{t}"] for t in ROUNDS],
                     mean["l2_cent"],
                     *[mean[f"l2_t{t}"] for t in ROUNDS]])
    header = (["m", "n_per_machine", "F1_cent"]
              + [f"F1_T{t}" for t in ROUNDS]
              + ["l2_cent"] + [f"l2_T{t}" for t in ROUNDS])
    return header, rows


def warm_vs_cold(paper: bool):
    """Pipeline re-entry with the carried WorkerSolves warm state.

    Cold = first invocation (zero ADMM start); warm = the SAME
    refinement entry re-run with the returned rho/state carries (the
    tuning-loop pattern: retune lambda or t, re-enter the rounds
    pipeline).  Iterations are the measured per-machine executed ADMM
    counts of BOTH solves, summed over machines.
    """
    d, m, n = (120, 8, 400) if paper else (80, 4, 300)
    problem = synthetic.make_problem(d=d, n_signal=8, rho=0.6)
    xs, ys = synthetic.sample_machines(
        jax.random.PRNGKey(1), problem, m, n // 2, n // 2)
    b1 = float(jnp.sum(jnp.abs(problem.beta_star)))
    lam = 0.30 * math.sqrt(math.log(d) / n) * b1
    cfg = DantzigConfig(max_iters=WARM_MAX_ITERS, tol=WARM_TOL,
                        check_every=WARM_CHECK_EVERY)

    def total_iters(ws):
        return int(np.asarray(ws.iters_beta).max(axis=-1).sum()
                   + np.asarray(ws.iters_theta).max(axis=-1).sum())

    cold_bar, cold_ws = rounds_core.simulate_multi_round(
        BinaryHead(), (xs, ys), lam=lam, lam_prime=lam, rounds=3, cfg=cfg,
        collect_info=True)
    warm_bar, warm_ws = rounds_core.simulate_multi_round(
        BinaryHead(), (xs, ys), lam=lam, lam_prime=lam, rounds=3, cfg=cfg,
        collect_info=True,
        rho_beta=cold_ws.rho_beta, rho_theta=cold_ws.rho_theta,
        state_beta=cold_ws.state_beta, state_theta=cold_ws.state_theta)
    drift = float(jnp.max(jnp.abs(warm_bar - cold_bar)))
    rows = [["rounds_reentry", total_iters(cold_ws), total_iters(warm_ws),
             drift, WARM_DRIFT_BUDGET, True]]
    return rows


def main(paper: bool = False) -> None:
    header, rows = error_vs_m(paper)
    print_table("multi-round refinement vs machine count "
                "(fixed N; T rounds, one solve set)", header, rows)

    wrows = warm_vs_cold(paper)
    wheader = ["scenario", "cold_iters", "warm_iters", "max_abs_diff",
               "drift_budget", "gated"]
    print_table("warm vs cold rounds-pipeline re-entry", wheader, wrows)

    # the headline: at the largest m, T=3 recovers toward centralized
    last = rows[-1]
    f1_cent, f1_t1 = last[2], last[3]
    f1_t3 = last[2 + len(ROUNDS)]
    l2_cent = last[3 + len(ROUNDS)]
    l2_t1 = last[4 + len(ROUNDS)]
    l2_t3 = last[3 + 2 * len(ROUNDS)]
    recovery = {
        "m": last[0], "f1_cent": f1_cent, "f1_t1": f1_t1, "f1_t3": f1_t3,
        "gap": max(0.0, f1_cent - f1_t3), "gap_budget": RECOVERY_GAP,
        "l2_cent": l2_cent, "l2_t1": l2_t1, "l2_t3": l2_t3,
        "l2_excess_cut": ((l2_t1 - l2_t3) / max(l2_t1 - l2_cent, 1e-12)),
    }

    write_csv("multi_round.csv", header, rows)
    jpath = write_bench_json(
        "multi_round", header, rows,
        warm_vs_cold=[dict(zip(wheader, r)) for r in wrows],
        recovery=recovery)
    print(f"[multi_round] wrote {jpath}")
    print(f"[multi_round] recovery at m={last[0]}: "
          f"F1 cent={f1_cent:.3f} T1={f1_t1:.3f} T3={f1_t3:.3f}; "
          f"l2 cent={l2_cent:.3f} T1={l2_t1:.3f} T3={l2_t3:.3f}")

    # the point of the tentpole: refinement rounds break the m-barrier
    assert l2_t1 >= 1.5 * l2_cent, (
        "premise failed: one-shot l2 not visibly degraded vs centralized "
        "at the largest m", rows[-1])
    assert l2_t3 < l2_t1, ("T=3 l2 not below one-shot at the largest m",
                           rows[-1])
    assert recovery["l2_excess_cut"] >= 0.3, (
        "T=3 cut less than 30% of the one-shot's excess l2 error", recovery)
    assert recovery["gap"] <= RECOVERY_GAP, (
        "T=3 F1 trails centralized by more than 5%", recovery)
    for scenario, cold, warmed, drift, budget, gated in wrows:
        if gated:
            assert warmed < cold, (scenario, cold, warmed)
            assert drift <= budget, (scenario, drift, budget)


if __name__ == "__main__":
    import sys

    main(paper="--paper" in sys.argv)
