"""Multiclass one-shot figure: K-class accuracy/F1 vs machine count.

The multicategory extension of the paper's Figure 1 (Chen's one-shot
schedule: each machine uplinks one (d, K) direction block).  For
K in {3, 5} and growing machine count m at fixed n per machine,
reports held-out accuracy and support-recovery F1 for

  * distributed debiased (one (d, K) pmean + hard threshold),
  * naive averaged (biased locals, no debias/HT),
  * centralized (pool all m*n samples, one batched solve).

The hard threshold is grid-tuned post hoc per metric for the debiased
and centralized estimators, matching the paper's protocol ("we report
the best results for all methods"); naive averaging has no threshold
by definition.  Expected shape: debiased tracks centralized and beats
naive averaging in F1 as m grows (the debias+HT recovers the sparse
support the biased average smears), and no method pays materially in
accuracy for distributing.  Every estimator runs through the ONE
pipeline in ``repro.core.pipeline``, so this figure also exercises the
(d, K) generalization of the debias correction.

Quick mode (default, CI-sized): d=60, n=300, m in (2, 4, 8), 2 repeats.
``--paper``: d=120, n=400, m in (2, 5, 10, 20), 5 repeats.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

import numpy as np

from benchmarks.common import print_table, write_csv
from repro.core import classifier
from repro.core import multiclass as mc
from repro.core.dantzig import DantzigConfig
from repro.core.slda import hard_threshold
from repro.stats import synthetic

T_GRID = np.geomspace(0.002, 1.0, 20)


def _tuned(raw, means, betas_star, zs, zl):
    """Best accuracy and best support-F1 over the threshold grid."""
    best_acc, best_f1 = 0.0, 0.0
    for t in T_GRID:
        beta = hard_threshold(raw, float(t))
        best_acc = max(best_acc, float(jnp.mean(
            mc.mc_classify(zs, beta, means) == zl)))
        best_f1 = max(best_f1, float(classifier.f1_score(beta, betas_star)))
    return best_acc, best_f1


def run(paper: bool = False, seed: int = 0):
    if paper:
        d, n, machines, repeats, iters = 120, 400, (2, 5, 10, 20), 5, 600
    else:
        d, n, machines, repeats, iters = 60, 300, (2, 4, 8), 2, 400
    cfg = DantzigConfig(max_iters=iters)

    rows = []
    for K in (3, 5):
        problem = synthetic.make_mc_problem(d=d, num_classes=K, n_signal=5)
        b1 = float(jnp.max(jnp.sum(jnp.abs(problem.betas), axis=0)))
        lam = 0.3 * math.sqrt(math.log(d) / n) * b1
        for m in machines:
            lam_c = 0.3 * math.sqrt(math.log(d) / (m * n)) * b1
            acc = {k: [] for k in ("acc_d", "acc_n", "acc_c",
                                   "f1_d", "f1_n", "f1_c")}
            for rep in range(repeats):
                key = jax.random.fold_in(
                    jax.random.PRNGKey(seed), (K * 100 + m) * 100 + rep)
                xs, labels = synthetic.sample_mc_machines(key, problem, m, n)
                # t=0: raw debiased mean; the threshold is tuned post hoc
                raw_d, means_d = mc.simulated_distributed_mc_slda(
                    xs, labels, K, lam, lam, 0.0, cfg)
                beta_n, means_n = mc.simulated_naive_mc_slda(
                    xs, labels, K, lam, cfg)
                raw_c, means_c = mc.centralized_mc_slda(
                    xs.reshape(-1, d), labels.reshape(-1), K, lam_c, cfg)
                zs, zl = synthetic.sample_mc_machines(
                    jax.random.fold_in(key, 777), problem, 1, 2000)
                acc_d, f1_d = _tuned(raw_d, means_d, problem.betas, zs[0], zl[0])
                acc_c, f1_c = _tuned(raw_c, means_c, problem.betas, zs[0], zl[0])
                acc["acc_d"].append(acc_d)
                acc["f1_d"].append(f1_d)
                acc["acc_c"].append(acc_c)
                acc["f1_c"].append(f1_c)
                acc["acc_n"].append(float(jnp.mean(
                    mc.mc_classify(zs[0], beta_n, means_n) == zl[0])))
                acc["f1_n"].append(float(
                    classifier.f1_score(beta_n, problem.betas)))
            mean = {k: sum(v) / len(v) for k, v in acc.items()}
            rows.append([K, m, n, mean["acc_d"], mean["acc_n"], mean["acc_c"],
                         mean["f1_d"], mean["f1_n"], mean["f1_c"]])

    header = ["K", "m", "n_per_machine", "acc_dist", "acc_naive", "acc_cent",
              "F1_dist", "F1_naive", "F1_cent"]
    print_table(f"fig_multiclass: K-class one-shot vs machine count (d={d})",
                header, rows)
    path = write_csv("fig_multiclass.csv", header, rows)
    print(f"[fig_multiclass] wrote {path}")
    return rows


def main(paper: bool = False) -> None:
    rows = run(paper)
    for r in rows:
        K, m = r[0], r[1]
        acc_d, acc_n, acc_c = r[3], r[4], r[5]
        f1_d, f1_n = r[6], r[7]
        # well above chance for every K
        assert acc_d > 2.0 / K, ("debiased accuracy near chance", r)
        # the debiased one-shot never trails naive averaging by more than
        # noise in accuracy, and recovers a strictly better support
        assert acc_d >= acc_n - 0.02, ("debiased << naive accuracy", r)
        assert f1_d >= f1_n, ("debiased F1 below naive", r)
        # and stays comparable to centralized in accuracy (the gap is
        # widest at small m*n where local estimates are noisiest)
        assert acc_d >= acc_c - 0.08, ("debiased << centralized accuracy", r)


if __name__ == "__main__":
    import sys

    main(paper="--paper" in sys.argv)
