"""Paper Figure 2: fixed per-machine sample size n, N = m*n grows with m.

The paper's prediction (Thm 4.6): the first error term ~ 1/sqrt(N)
shrinks, but the second term ~ m/N = 1/n is constant, so the
distributed error plateaus at a positive constant while the
centralized error keeps decreasing.  Thresholds grid-tuned per
method/metric (paper protocol); naive has no threshold.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table, tuned_metrics, write_csv
from repro.core import classifier
from repro.core.dantzig import DantzigConfig
from repro.core.distributed import (
    simulated_debiased_mean,
    simulated_naive_averaged_slda,
)
from repro.core.slda import centralized_slda
from repro.stats import synthetic

T_GRID = np.geomspace(0.005, 2.0, 25)


def run(paper: bool = False, seed: int = 1):
    if paper:
        d, n, machines, repeats, iters = 200, 200, (2, 5, 10, 20, 50), 20, 700
    else:
        d, n, machines, repeats, iters = 100, 200, (2, 4, 8), 3, 400
    cfg = DantzigConfig(max_iters=iters)
    problem = synthetic.make_problem(d=d, n_signal=10, rho=0.8)
    b1 = float(jnp.sum(jnp.abs(problem.beta_star)))
    n1 = n2 = n // 2
    lam = 0.30 * math.sqrt(math.log(d) / n) * b1

    rows = []
    for m in machines:
        n_total = m * n
        lam_c = 0.30 * math.sqrt(math.log(d) / n_total) * b1
        acc = {k: [] for k in ("f1_d", "f1_c", "f1_n", "l2_d", "l2_c", "l2_n",
                               "linf_d", "linf_c", "linf_n")}
        for rep in range(repeats):
            key = jax.random.fold_in(jax.random.PRNGKey(seed), m * 1000 + rep)
            xs, ys = synthetic.sample_machines(key, problem, m, n1, n2)
            dist_raw = simulated_debiased_mean(xs, ys, lam, lam, cfg)
            naive = simulated_naive_averaged_slda(xs, ys, lam, cfg)
            cent_raw = centralized_slda(xs.reshape(-1, d), ys.reshape(-1, d), lam_c, cfg)
            md = tuned_metrics(dist_raw, problem.beta_star, T_GRID)
            mc = tuned_metrics(cent_raw, problem.beta_star, T_GRID)
            err_n = classifier.estimation_errors(naive, problem.beta_star)
            for tag, res in (("d", md), ("c", mc)):
                acc[f"f1_{tag}"].append(res["f1"])
                acc[f"l2_{tag}"].append(res["l2"])
                acc[f"linf_{tag}"].append(res["linf"])
            acc["f1_n"].append(float(classifier.f1_score(naive, problem.beta_star)))
            acc["l2_n"].append(float(err_n["l2"]))
            acc["linf_n"].append(float(err_n["linf"]))
        mean = {k: sum(v) / len(v) for k, v in acc.items()}
        rows.append([m, n_total, mean["f1_d"], mean["f1_c"], mean["f1_n"],
                     mean["l2_d"], mean["l2_c"], mean["l2_n"],
                     mean["linf_d"], mean["linf_c"], mean["linf_n"]])

    header = ["m", "N", "F1_dist", "F1_cent", "F1_naive",
              "l2_dist", "l2_cent", "l2_naive",
              "linf_dist", "linf_cent", "linf_naive"]
    print_table(f"Fig.2 fixed n={n} per machine, d={d}", header, rows)
    write_csv("fig2_fixed_n.csv", header, rows)
    return rows


def main(paper: bool = False):
    rows = run(paper)
    for r in rows:
        assert r[5] <= r[7], ("l2 dist > naive", r)  # dist beats naive always
    # centralized error decreases as N grows; distributed plateaus above it
    assert rows[-1][6] <= rows[0][6] * 1.1, (rows[0][6], rows[-1][6])


if __name__ == "__main__":
    import sys

    main(paper="--paper" in sys.argv)
