"""Benchmark runner: ``PYTHONPATH=src python -m benchmarks.run``.

One benchmark per paper table/figure (quick CI-sized grids by default;
pass --paper for the published experiment sizes) plus the roofline
aggregation over the dry-run artifacts.  Each module asserts the
paper's qualitative claims, so a green run IS the reproduction check.
"""

from __future__ import annotations

import argparse
import json
import time
import traceback

from benchmarks import (
    admm_convergence,
    compressed_rounds,
    corollary48_threshold,
    fault_rounds,
    fig1_machines,
    fig2_fixed_n,
    fig_multiclass,
    fused_solver,
    lambda_path,
    multi_round,
    roofline,
    serving,
    table1_speedup,
    table2_real,
)
from benchmarks.common import bench_json_path, write_bench_json


BENCHES = [
    ("fig1_machines (fixed N, vary m)", fig1_machines.main),
    ("fig2_fixed_n (fixed n, N = m*n)", fig2_fixed_n.main),
    ("fig_multiclass (K-class accuracy/F1 vs m)", fig_multiclass.main),
    ("table1_speedup (wall-clock vs m)", table1_speedup.main),
    ("table2_real (heart-disease surrogate)", table2_real.main),
    ("corollary48 (machine-count threshold m*)", corollary48_threshold.main),
    ("fused_solver (scan vs fused-blocked kernel)", fused_solver.main),
    ("lambda_path (folded sweep vs sequential launches)", lambda_path.main),
    ("admm_convergence (adaptive early exit + warm starts)",
     admm_convergence.main),
    ("multi_round (refinement rounds past the one-shot m-barrier)",
     multi_round.main),
    ("compressed_rounds (top-k EF uplinks: accuracy vs bits moved)",
     compressed_rounds.main),
    ("fault_rounds (liveness-masked aggregation under faults)",
     fault_rounds.main),
    ("serving (classify hot path + streaming refit under faults)",
     serving.main),
    ("roofline (dry-run aggregation)", roofline.main),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true",
                    help="published experiment sizes (slow)")
    ap.add_argument("--only", default=None, help="substring filter")
    args = ap.parse_args()

    failures = []
    summary_rows = []
    for name, fn in BENCHES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        print(f"\n##### {name}")
        try:
            fn(paper=args.paper)
            summary_rows.append([name, "ok", time.time() - t0])
            print(f"##### {name}: OK ({time.time() - t0:.1f}s)")
        except Exception:
            failures.append(name)
            summary_rows.append([name, "failed", time.time() - t0])
            traceback.print_exc()
            print(f"##### {name}: FAILED")
    # per-benchmark status + wall-clock, diffable across PRs alongside
    # the per-shape BENCH_<name>.json files the benchmarks themselves
    # emit.  Merged by benchmark name so CI's separate --only
    # invocations accumulate into one summary instead of clobbering it.
    header = ["benchmark", "status", "seconds"]
    try:
        with open(bench_json_path("run_summary")) as f:
            prior = {r["benchmark"]: [r[c] for c in header]
                     for r in json.load(f)["rows"]}
    except (OSError, ValueError, KeyError):
        prior = {}
    prior.update({r[0]: r for r in summary_rows})
    write_bench_json("run_summary", header,
                     [prior[name] for name, _ in BENCHES if name in prior],
                     paper=args.paper)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")
    print("\nall benchmarks passed")


if __name__ == "__main__":
    main()
