"""Roofline aggregation: read experiments/dryrun/*.json into the table.

Per (arch x shape x mesh): the three roofline terms in seconds, the
dominant bottleneck, MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D
(inference), the useful-flops ratio, and a markdown table for
EXPERIMENTS.md SSRoofline.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import print_table, write_csv

DRYRUN_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "experiments", "dryrun",
)

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def load_results(dryrun_dir: str = DRYRUN_DIR, include_tagged: bool = False):
    out = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if not include_tagged and r.get("tag"):
            continue
        out.append(r)
    out.sort(key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9), r["mesh"]))
    return out


def one_liner(r) -> str:
    """What would move the dominant term down."""
    dom = r["dominant"]
    if dom == "memory_s":
        if r["shape"] == "train_4k":
            return "reduce remat recompute / bigger fused blocks (bytes ~ activations)"
        return "KV-cache layout + quantization; fuse attention reads"
    if dom == "collective_s":
        if r.get("collectives", {}).get("bytes", {}).get("all-gather", 0) > 0:
            return "shard weights stationary; swap all-gather for reduce-scatter overlap"
        return "overlap all-reduce with backward; hierarchical pod-local reduce"
    return "MXU-align matmul tiles; raise per-chip batch (compute-bound is the goal)"


def build_rows(results):
    rows = []
    for r in results:
        rows.append([
            r["arch"], r["shape"], r["mesh"],
            r["compute_s"], r["memory_s"], r["collective_s"],
            r["dominant"].replace("_s", ""),
            r["useful_flops_ratio"],
            r.get("model_flops_global", 0.0),
        ])
    return rows


def markdown_table(results) -> str:
    lines = [
        "| arch | shape | mesh | compute(s) | memory(s) | collective(s) "
        "| dominant | useful | next lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in results:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant'].replace('_s','')} "
            f"| {r['useful_flops_ratio']:.2f} | {one_liner(r)} |"
        )
    return "\n".join(lines)


def main(paper: bool = False):
    results = load_results()
    if not results:
        print("[roofline] no dry-run results yet "
              f"(run python -m repro.launch.dryrun_slda); dir={DRYRUN_DIR}")
        return
    header = ["arch", "shape", "mesh", "compute_s", "memory_s",
              "collective_s", "dominant", "useful_ratio", "model_flops"]
    rows = build_rows(results)
    print_table(f"Roofline terms from {len(results)} dry-run combos "
                "(v5e: 197TF bf16, 819GB/s HBM, 50GB/s ICI)", header, rows)
    write_csv("roofline.csv", header, rows)
    single = [r for r in results if r["mesh"] == "16x16"]
    if single:
        n_dom = {}
        for r in single:
            n_dom[r["dominant"]] = n_dom.get(r["dominant"], 0) + 1
        print(f"\n[roofline] single-pod dominant-term census: {n_dom}")


if __name__ == "__main__":
    main()
