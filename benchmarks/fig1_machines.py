"""Paper Figure 1: fixed total sample size N, growing machine count m.

Reports F1 score and l2 / linf estimation error for the three
estimators (distributed debiased, centralized, naive averaged) as m
grows.  The paper's claim: distributed ~= centralized while m is below
the threshold of Corollary 4.8, then degrades; naive averaging is
uniformly worse.

Thresholds are grid-tuned per method/metric, matching the paper's
protocol ("we report the best results for all methods").  Naive
averaging has no threshold (that is its definition).

Quick mode (default, CI-sized): d=100, N=4000, 3 repeats.
``--paper`` reproduces the published design: d=200, N=10000, 20 repeats.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table, tuned_metrics, write_csv
from repro.core import classifier
from repro.core.dantzig import DantzigConfig
from repro.core.distributed import (
    simulated_debiased_mean,
    simulated_naive_averaged_slda,
)
from repro.core.slda import centralized_slda
from repro.stats import synthetic

T_GRID = np.geomspace(0.005, 2.0, 25)


def run(paper: bool = False, seed: int = 0):
    if paper:
        d, n_total, machines, repeats = 200, 10_000, (4, 10, 20, 40, 80), 20
        iters = 700
    else:
        d, n_total, machines, repeats = 100, 4_000, (2, 4, 8, 16), 3
        iters = 400
    cfg = DantzigConfig(max_iters=iters)
    problem = synthetic.make_problem(d=d, n_signal=10, rho=0.8)
    b1 = float(jnp.sum(jnp.abs(problem.beta_star)))

    rows = []
    for m in machines:
        n = n_total // m
        n1 = n2 = n // 2
        lam = 0.30 * math.sqrt(math.log(d) / n) * b1
        lam_c = 0.30 * math.sqrt(math.log(d) / n_total) * b1
        acc = {k: [] for k in ("f1_d", "f1_c", "f1_n", "l2_d", "l2_c", "l2_n",
                               "linf_d", "linf_c", "linf_n")}
        for rep in range(repeats):
            key = jax.random.fold_in(jax.random.PRNGKey(seed), m * 1000 + rep)
            xs, ys = synthetic.sample_machines(key, problem, m, n1, n2)
            dist_raw = simulated_debiased_mean(xs, ys, lam, lam, cfg)
            naive = simulated_naive_averaged_slda(xs, ys, lam, cfg)
            cent_raw = centralized_slda(
                xs.reshape(-1, d), ys.reshape(-1, d), lam_c, cfg
            )
            md = tuned_metrics(dist_raw, problem.beta_star, T_GRID)
            mc = tuned_metrics(cent_raw, problem.beta_star, T_GRID)
            err_n = classifier.estimation_errors(naive, problem.beta_star)
            for tag, res in (("d", md), ("c", mc)):
                acc[f"f1_{tag}"].append(res["f1"])
                acc[f"l2_{tag}"].append(res["l2"])
                acc[f"linf_{tag}"].append(res["linf"])
            acc["f1_n"].append(float(classifier.f1_score(naive, problem.beta_star)))
            acc["l2_n"].append(float(err_n["l2"]))
            acc["linf_n"].append(float(err_n["linf"]))
        mean = {k: sum(v) / len(v) for k, v in acc.items()}
        rows.append([m, n, mean["f1_d"], mean["f1_c"], mean["f1_n"],
                     mean["l2_d"], mean["l2_c"], mean["l2_n"],
                     mean["linf_d"], mean["linf_c"], mean["linf_n"]])

    header = ["m", "n_per_machine", "F1_dist", "F1_cent", "F1_naive",
              "l2_dist", "l2_cent", "l2_naive",
              "linf_dist", "linf_cent", "linf_naive"]
    print_table(f"Fig.1 fixed N={n_total}, d={d} (distributed vs centralized vs naive)",
                header, rows)
    write_csv("fig1_machines.csv", header, rows)
    return rows


def main(paper: bool = False):
    rows = run(paper)
    # paper's qualitative claims:
    for i, r in enumerate(rows):
        assert r[5] <= r[7], ("l2 dist > naive", r)  # dist <= naive in l2, all m
        if i >= 1:  # naive degrades with m, dist does not (until threshold)
            assert r[2] >= r[4], ("F1 dist < naive at m>=4", r)
    r0 = rows[0]
    # comparable to centralized at small m (l2; F1 is noise-floor-limited
    # at CI scale where min|beta*_j| = (1-rho)/(1+rho) ~ 0.11)
    assert r0[5] <= 1.5 * r0[6], ("l2 dist not comparable to centralized", r0)
    assert r0[2] >= r0[4] - 0.05, ("F1 dist << naive at m=2", r0)


if __name__ == "__main__":
    import sys

    main(paper="--paper" in sys.argv)
