"""Paper Table 1: wall-clock of distributed vs centralized estimation.

The paper measures one machine's local pipeline (workers run in
parallel, so per-machine time IS the wall-clock) against the
centralized solve over all N samples, d=200.

Hardware-relative caveat (recorded in EXPERIMENTS.md): the paper's
2011-era single-threaded LP stack ran the O(N d^2) covariance pass at
~0.1 GFLOP/s, so it dominated end-to-end time and speedup looked ~linear
up to m=100.  This container's BLAS runs the same pass ~100x faster,
which exposes the m-independent solver floor (CLIME is O(d^2) per
iteration regardless of n).  The *structure* still reproduces: time
decreases monotonically in m and approaches the solver floor; the
covariance portion itself scales ~1/m.

Quick mode: N=400k.  --paper: N=1e6 (the published size).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from benchmarks.common import Timer, print_table, write_bench_json, write_csv
from repro.core.dantzig import DantzigConfig
from repro.core.slda import debiased_local_estimator, local_slda, suff_stats
from repro.stats import synthetic


def _sample(problem, n, key):
    n1 = n2 = n // 2
    x, y = synthetic.sample_two_class(key, problem, n1, n2)
    jax.block_until_ready((x, y))
    return x, y


def _timeit(fn, *args) -> float:
    jax.block_until_ready(fn(*args))  # compile + warm cache
    with Timer() as t:
        jax.block_until_ready(fn(*args))
    return t.seconds


def run(paper: bool = False, seed: int = 2):
    d = 200
    n_total = 1_000_000 if paper else 400_000
    machines = (1, 20, 40, 60, 80, 100) if paper else (1, 10, 20, 40)
    cfg = DantzigConfig(max_iters=200)
    problem = synthetic.make_problem(d=d, n_signal=10, rho=0.8)
    b1 = float(jnp.sum(jnp.abs(problem.beta_star)))
    key = jax.random.PRNGKey(seed)

    # centralized: suff stats over all N + one Dantzig solve (Cai-Liu)
    lam_c = 0.3 * math.sqrt(math.log(d) / n_total) * b1

    def centralized(x, y):
        return local_slda(suff_stats(x, y), lam_c, cfg)

    x_all, y_all = _sample(problem, n_total, key)
    t_cent = _timeit(centralized, x_all, y_all)
    t_cov_cent = _timeit(lambda a, b: suff_stats(a, b).sigma, x_all, y_all)
    del x_all, y_all

    rows = [[1, n_total, t_cent, 1.0, t_cov_cent]]
    for m in machines:
        if m == 1:
            continue
        n = n_total // m
        lam = 0.3 * math.sqrt(math.log(d) / n) * b1

        def worker(x, y):
            return debiased_local_estimator(x, y, lam, None, cfg)[0]

        x, y = _sample(problem, n, jax.random.fold_in(key, m))
        secs = _timeit(worker, x, y)
        t_cov = _timeit(lambda a, b: suff_stats(a, b).sigma, x, y)
        rows.append([m, n, secs, t_cent / secs, t_cov])
        del x, y

    header = ["m", "n_per_machine", "seconds", "speedup_vs_centralized",
              "covariance_seconds"]
    print_table(f"Table 1: per-machine wall-clock, d={d}, N={n_total} "
                "(CPU container; see hardware caveat)", header, rows)
    write_csv("table1_speedup.csv", header, rows)
    write_bench_json("table1_speedup", header, rows, d=d, n_total=n_total)
    return rows


def main(paper: bool = False):
    rows = run(paper)
    # monotone-ish decrease, and the covariance portion scales ~1/m
    assert rows[-1][2] < rows[0][2], rows
    cov1, covm = rows[0][4], rows[-1][4]
    m_last = rows[-1][0]
    assert covm < cov1 / (0.25 * m_last) + 0.01, (cov1, covm, m_last)


if __name__ == "__main__":
    import sys

    main(paper="--paper" in sys.argv)
