"""Quickstart: the paper's Algorithm 1 in ~40 lines.

Generates the synthetic design of SS5.1 (AR(0.8) covariance, sparse
discriminant direction), runs the three estimators, and prints support
recovery + estimation error + misclassification rate.

    PYTHONPATH=src python examples/quickstart.py
"""

import math

import jax
import jax.numpy as jnp

from repro.core import classifier
from repro.core.dantzig import DantzigConfig
from repro.core.distributed import (
    simulated_distributed_slda,
    simulated_naive_averaged_slda,
)
from repro.core.slda import centralized_slda, hard_threshold
from repro.stats import synthetic


def main():
    d, m, n_per_machine = 120, 8, 400
    problem = synthetic.make_problem(d=d, n_signal=10, rho=0.8)
    n1 = n2 = n_per_machine // 2
    N = m * n_per_machine

    key = jax.random.PRNGKey(0)
    xs, ys = synthetic.sample_machines(key, problem, m, n1, n2)

    b1 = float(jnp.sum(jnp.abs(problem.beta_star)))
    lam = 0.3 * math.sqrt(math.log(d) / n_per_machine) * b1  # worker scale
    lam_c = 0.3 * math.sqrt(math.log(d) / N) * b1            # centralized scale
    t = 0.5 * math.sqrt(math.log(d) / N) * b1                # HT threshold

    cfg = DantzigConfig(max_iters=500)
    dist = simulated_distributed_slda(xs, ys, lam, lam, t, cfg)
    naive = simulated_naive_averaged_slda(xs, ys, lam, cfg)
    cent = hard_threshold(
        centralized_slda(xs.reshape(-1, d), ys.reshape(-1, d), lam_c, cfg), 0.5 * t
    )

    z, labels = synthetic.sample_labeled(jax.random.fold_in(key, 1), problem, 4000)
    mu1 = jnp.mean(xs.reshape(-1, d), axis=0)
    mu2 = jnp.mean(ys.reshape(-1, d), axis=0)

    print(f"d={d}  machines={m}  N={N}   (communication: one {d}-float vector per worker)")
    print(f"{'method':<22}{'F1':>6}{'l2 err':>9}{'linf err':>10}{'misclass':>10}")
    for name, beta in (("distributed (paper)", dist),
                       ("centralized", cent),
                       ("naive averaged", naive)):
        f1 = float(classifier.f1_score(beta, problem.beta_star))
        err = classifier.estimation_errors(beta, problem.beta_star)
        rate = float(classifier.misclassification_rate(z, labels, beta, mu1, mu2))
        print(f"{name:<22}{f1:>6.3f}{float(err['l2']):>9.3f}"
              f"{float(err['linf']):>10.3f}{rate:>10.3f}")


if __name__ == "__main__":
    main()
