"""Multi-class distributed sparse LDA (the paper's future-work extension).

K classes share one covariance; all K discriminant directions are
estimated in ONE batched Dantzig solve per machine, debiased with one
CLIME estimate, and aggregated in a single (d, K)-block communication
round -- the natural multi-class generalization of Algorithm 1.

Runs the same estimator twice through the shared pipeline core: once as
the single-device simulation (vmap machines) and once on a real
(data=4, model=2) device mesh via ``distributed_mc_slda_shardmap``
(shard_map machines, model-axis-sharded CLIME columns), and checks the
two agree.

    PYTHONPATH=src python examples/multiclass_lda.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import math  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import multiclass as mc  # noqa: E402
from repro.core.dantzig import DantzigConfig  # noqa: E402
from repro.core.distributed import distributed_mc_slda_shardmap  # noqa: E402
from repro.stats import synthetic  # noqa: E402


def main():
    d, K, m, n = 120, 4, 4, 400
    problem = synthetic.make_mc_problem(d=d, num_classes=K, n_signal=6)
    xs, labels = synthetic.sample_mc_machines(jax.random.PRNGKey(0), problem, m, n)

    b1 = float(jnp.max(jnp.sum(jnp.abs(problem.betas), axis=0)))
    lam = 0.3 * math.sqrt(math.log(d) / n) * b1
    t = 0.5 * math.sqrt(math.log(d) / (m * n)) * b1
    cfg = DantzigConfig(max_iters=500)

    beta_d, means = mc.simulated_distributed_mc_slda(xs, labels, K, lam, lam, t, cfg)
    beta_n, means_n = mc.simulated_naive_mc_slda(xs, labels, K, lam, cfg)

    zs, zl = synthetic.sample_mc_machines(jax.random.PRNGKey(9), problem, 1, 4000)
    acc_d = float(jnp.mean(mc.mc_classify(zs[0], beta_d, means) == zl[0]))
    acc_n = float(jnp.mean(mc.mc_classify(zs[0], beta_n, means_n) == zl[0]))
    err_d = float(jnp.linalg.norm(beta_d - problem.betas))
    err_n = float(jnp.linalg.norm(beta_n - problem.betas))
    nnz = int(jnp.sum(beta_d != 0))

    print(f"K={K} classes, d={d}, m={m} machines x n={n} "
          f"(uplink {4 * d * K} bytes/machine, one round)")
    print(f"{'method':<24}{'frob err':>10}{'accuracy':>10}")
    print(f"{'distributed (debiased)':<24}{err_d:>10.3f}{acc_d:>10.3f}")
    print(f"{'naive averaged':<24}{err_n:>10.3f}{acc_n:>10.3f}")
    print(f"sparse directions: {nnz}/{d * K} nonzeros "
          f"(true {int(jnp.sum(problem.betas != 0))})")

    # ---- the same estimator on a real device mesh ----------------------
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    print(f"\nmesh {dict(zip(mesh.axis_names, mesh.devices.shape))}: "
          f"each data slice = one machine; CLIME columns shard over 'model'")
    t0 = time.time()
    beta_mesh, means_mesh = distributed_mc_slda_shardmap(
        mesh, xs.reshape(m * n, d), labels.reshape(m * n), K, lam, lam, t, cfg)
    beta_mesh.block_until_ready()
    gap = float(jnp.max(jnp.abs(beta_mesh - beta_d)))
    acc_mesh = float(jnp.mean(mc.mc_classify(zs[0], beta_mesh, means_mesh) == zl[0]))
    print(f"mesh one-shot estimate in {time.time() - t0:.1f}s, "
          f"accuracy {acc_mesh:.3f}, max|mesh - simulated| = {gap:.2e}")
    assert gap < 1e-4, gap


if __name__ == "__main__":
    main()
