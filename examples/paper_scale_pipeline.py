"""Paper-scale end-to-end pipeline (the paper's kind of 'driver').

Runs the published synthetic design at full size -- d=200, AR(0.8),
N=10^6 samples split over m machines -- end to end: sharded data
generation, per-machine estimation, one-round aggregation, evaluation,
and a tuning sweep over the hard threshold.  On the production mesh the
machines are data slices; here they stream through one host in chunks
(the math is identical; see examples/mesh_distributed_lda.py for the
mesh execution path).

    PYTHONPATH=src python examples/paper_scale_pipeline.py [--n-total 1000000]
"""

import argparse
import math
import time

import jax
import jax.numpy as jnp

from repro.core import classifier, slda
from repro.core.dantzig import DantzigConfig
from repro.stats import synthetic


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-total", type=int, default=1_000_000)
    ap.add_argument("--machines", type=int, default=40)
    ap.add_argument("--d", type=int, default=200)
    args = ap.parse_args()

    d, m = args.d, args.machines
    n = args.n_total // m
    problem = synthetic.make_problem(d=d, n_signal=10, rho=0.8)
    b1 = float(jnp.sum(jnp.abs(problem.beta_star)))
    lam = 0.3 * math.sqrt(math.log(d) / n) * b1
    cfg = DantzigConfig(max_iters=400)

    print(f"d={d}  m={m}  n={n}/machine  N={m * n}")

    # worker pass: stream machines one at a time (memory-bounded), keep
    # only the debiased d-vector from each -- the paper's O(d) uplink.
    key = jax.random.PRNGKey(0)
    t0 = time.time()
    debiased = []
    worker = jax.jit(
        lambda x, y: slda.debiased_local_estimator(x, y, lam, None, cfg)[0]
    )
    for l in range(m):
        x, y = synthetic.sample_two_class(
            jax.random.fold_in(key, l), problem, n // 2, n // 2
        )
        debiased.append(worker(x, y))
        if l in (0, m // 2, m - 1):
            print(f"  machine {l:3d} done ({time.time() - t0:.1f}s elapsed)")
    beta_tildes = jnp.stack(debiased)

    # master: mean + threshold sweep (the paper grid-tunes t)
    mean = jnp.mean(beta_tildes, axis=0)
    best = None
    for t in jnp.geomspace(0.002, 1.0, 20):
        beta = slda.hard_threshold(mean, float(t))
        f1 = float(classifier.f1_score(beta, problem.beta_star))
        if best is None or f1 > best[1]:
            best = (float(t), f1, beta)
    t_star, f1_star, beta_bar = best
    err = classifier.estimation_errors(beta_bar, problem.beta_star)
    print(f"aggregated in one round: t*={t_star:.4f}  F1={f1_star:.3f}  "
          f"l2={float(err['l2']):.4f}  linf={float(err['linf']):.4f}")

    z, labels = synthetic.sample_labeled(jax.random.fold_in(key, 9999), problem, 20_000)
    rate = float(classifier.misclassification_rate(
        z, labels, beta_bar, problem.mu1, problem.mu2))
    bayes = 0.5 * (1 - jax.scipy.special.erf(
        0.5 * jnp.sqrt(problem.beta_star @ problem.sigma @ problem.beta_star) / jnp.sqrt(2)))
    print(f"misclassification {rate:.4f}  (Bayes optimal ~{float(bayes):.4f})")
    print(f"total wall-clock {time.time() - t0:.1f}s; "
          f"bytes communicated per machine: {4 * d}")


if __name__ == "__main__":
    main()
