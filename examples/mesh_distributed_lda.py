"""End-to-end mesh driver: Algorithm 1 as it would run on a pod.

Forces 8 host devices, builds a (data=4, model=2) mesh, shards the
sample set over the data axis (each data slice = one of the paper's
"machines"), runs the one-shot distributed estimator via shard_map --
the CLIME columns are sharded over the model axis inside each machine,
and the only cross-machine communication is a single d-vector pmean --
then serves batched classification requests with the fitted rule.

Both the binary and the K-class estimator run on the SAME mesh through
the same head-parameterized worker core (``repro.core.pipeline``); the
multiclass round uplinks a (d, K) block instead of a d-vector.

    PYTHONPATH=src python examples/mesh_distributed_lda.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import math  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import classifier  # noqa: E402
from repro.core import multiclass as mc  # noqa: E402
from repro.core.dantzig import DantzigConfig  # noqa: E402
from repro.core.distributed import (  # noqa: E402
    distributed_mc_slda_shardmap,
    distributed_slda_shardmap,
)
from repro.stats import synthetic  # noqa: E402


def main():
    d, m, n_per_machine = 128, 4, 500
    problem = synthetic.make_problem(d=d, n_signal=10, rho=0.8)
    n1 = n2 = n_per_machine // 2
    N = m * n_per_machine

    key = jax.random.PRNGKey(0)
    xs, ys = synthetic.sample_machines(key, problem, m, n1, n2)
    x_flat, y_flat = xs.reshape(-1, d), ys.reshape(-1, d)

    b1 = float(jnp.sum(jnp.abs(problem.beta_star)))
    lam = 0.3 * math.sqrt(math.log(d) / n_per_machine) * b1
    t = 0.5 * math.sqrt(math.log(d) / N) * b1

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    print(f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}; "
          f"each data slice = one of the paper's m={m} machines")

    cfg = DantzigConfig(max_iters=500)
    t0 = time.time()
    beta = distributed_slda_shardmap(mesh, x_flat, y_flat, lam, lam, t, cfg)
    beta.block_until_ready()
    print(f"one-shot distributed estimate in {time.time() - t0:.1f}s "
          f"(communication: ONE pmean of a {d}-vector = {4 * d} bytes/worker)")

    f1 = float(classifier.f1_score(beta, problem.beta_star))
    err = classifier.estimation_errors(beta, problem.beta_star)
    print(f"support F1 {f1:.3f}   l2 err {float(err['l2']):.3f}   "
          f"support size {int(jnp.sum(beta != 0))} (true {int(jnp.sum(problem.beta_star != 0))})")

    # --- serve batched classification requests with the fitted rule ----
    mu1 = jnp.mean(x_flat, axis=0)
    mu2 = jnp.mean(y_flat, axis=0)
    serve = jax.jit(lambda z: classifier.fisher_rule(z, beta, mu1, mu2))
    n_req, batch = 0, 512
    t0 = time.time()
    correct = 0
    for i in range(8):
        z, labels = synthetic.sample_labeled(jax.random.fold_in(key, 100 + i), problem, batch)
        pred = serve(z)
        correct += int(jnp.sum(pred == labels))
        n_req += batch
    dt = time.time() - t0
    print(f"served {n_req} requests in {dt:.2f}s ({n_req / dt:.0f} req/s), "
          f"accuracy {correct / n_req:.3f}")

    # --- same mesh, K-class head: one (d, K) block per machine ---------
    K = 4
    mc_problem = synthetic.make_mc_problem(d=d, num_classes=K, n_signal=8)
    mxs, mlabels = synthetic.sample_mc_machines(
        jax.random.PRNGKey(7), mc_problem, m, n_per_machine)
    b1k = float(jnp.max(jnp.sum(jnp.abs(mc_problem.betas), axis=0)))
    lam_k = 0.3 * math.sqrt(math.log(d) / n_per_machine) * b1k
    t_k = 0.5 * math.sqrt(math.log(d) / N) * b1k
    t0 = time.time()
    beta_k, means_k = distributed_mc_slda_shardmap(
        mesh, mxs.reshape(-1, d), mlabels.reshape(-1), K, lam_k, lam_k, t_k, cfg)
    beta_k.block_until_ready()
    zs, zl = synthetic.sample_mc_machines(jax.random.PRNGKey(8), mc_problem, 1, 2000)
    acc_k = float(jnp.mean(mc.mc_classify(zs[0], beta_k, means_k) == zl[0]))
    print(f"\nK={K} classes on the same mesh in {time.time() - t0:.1f}s "
          f"(communication: ONE pmean of a ({d}, {K}) block = {4 * d * K} "
          f"bytes/worker), held-out accuracy {acc_k:.3f}")


if __name__ == "__main__":
    main()
