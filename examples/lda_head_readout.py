"""LDA-head readout over a transformer: the paper meets the model zoo.

Trains a small decoder LM briefly on the synthetic token stream, then
uses the paper's distributed sparse-LDA estimator as a *supervised
readout* on pooled hidden states: two token populations (distinct
unigram temperature) are classified from d_model-dimensional features,
with the feature shards playing the paper's machines.

This is the integration the framework ships as a first-class feature
(repro.core.lda_head): any zoo architecture's pooled states can feed
Algorithm 1.

    PYTHONPATH=src python examples/lda_head_readout.py [--steps 60]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.lda_head import fit_lda_head, pool_features
from repro.data import tokens as token_data
from repro.launch import steps
from repro.models import model_zoo
from repro.optim import AdamWConfig, adamw_init


def sample_population(key, batch, seq, vocab, alpha):
    """Zipf(alpha) unigram stream; alpha shifts the population."""
    logits = -alpha * jnp.log(jnp.arange(1, vocab + 1, dtype=jnp.float32))
    return jax.random.categorical(key, logits, shape=(batch, seq))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--machines", type=int, default=4)
    args = ap.parse_args()

    cfg = configs.smoke_config(configs.get_config("qwen2.5-3b"))
    model = model_zoo.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    train_step = jax.jit(
        steps.make_train_step(cfg, AdamWConfig(lr=1e-3), total_steps=args.steps,
                              warmup_steps=10)
    )

    print(f"training {cfg.name} ({cfg.param_count() / 1e6:.1f}M params) "
          f"for {args.steps} steps on the synthetic token stream...")
    t0 = time.time()
    for step, batch in enumerate(token_data.batch_stream(0, 8, 64, cfg.vocab_size)):
        if step >= args.steps:
            break
        params, opt, metrics = train_step(params, opt, batch)
        if step % 20 == 0:
            print(f"  step {step:4d} loss {float(metrics['loss']):.3f}")
    print(f"trained in {time.time() - t0:.0f}s")

    # two populations differing in unigram temperature
    key = jax.random.PRNGKey(7)
    n = 64
    tok_a = sample_population(jax.random.fold_in(key, 0), n, 32, cfg.vocab_size, 1.6)
    tok_b = sample_population(jax.random.fold_in(key, 1), n, 32, cfg.vocab_size, 0.7)
    feats_a = pool_features(model, params, tok_a)
    feats_b = pool_features(model, params, tok_b)

    ntr = n // 2
    head = fit_lda_head(
        feats_a[:ntr], feats_b[:ntr], lam=0.25, machines=args.machines
    )
    pred_a = head.predict(feats_a[ntr:])
    pred_b = head.predict(feats_b[ntr:])
    acc = 0.5 * (float(jnp.mean(pred_a == 0)) + float(jnp.mean(pred_b == 1)))
    nnz = int(jnp.sum(head.beta != 0))
    print(f"distributed LDA head ({args.machines} machines): "
          f"holdout accuracy {acc:.3f}, sparse direction uses "
          f"{nnz}/{cfg.d_model} feature dims")
    naive = fit_lda_head(
        feats_a[:ntr], feats_b[:ntr], lam=0.25, machines=args.machines, debias=False
    )
    acc_n = 0.5 * (float(jnp.mean(naive.predict(feats_a[ntr:]) == 0))
                   + float(jnp.mean(naive.predict(feats_b[ntr:]) == 1)))
    print(f"naive averaged head:  holdout accuracy {acc_n:.3f}")


if __name__ == "__main__":
    main()
