"""AdamW with global-norm clipping, f32 master moments over bf16 params."""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.int32(0),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def global_norm(grads) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )


def adamw_update(
    params, grads, state: AdamWState, cfg: AdamWConfig, lr_scale=1.0
):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        update = (mu / b1c) / (jnp.sqrt(nu / b2c) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (update + cfg.weight_decay * p32)
        return p32.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state.mu)
    flat_nu = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step, new_mu, new_nu), {"grad_norm": gnorm}
