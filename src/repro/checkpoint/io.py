"""Pytree <-> disk checkpointing (npz, atomic rename, step-indexed).

Flat key convention: '/'-joined pytree path.  Restore rebuilds into the
caller-provided target structure (shapes validated), so it is safe
against refactors that only reorder dict keys.
"""

from __future__ import annotations

import os
import re
import tempfile
import zipfile

import jax
import jax.numpy as jnp
import numpy as np


# npz cannot represent bfloat16; such leaves are stored as uint16 bit
# views under a marker prefix and re-viewed on restore.
_BF16_PREFIX = "__bf16__/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            flat[_BF16_PREFIX + key] = arr.view(np.uint16)
        else:
            flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(ckpt_dir, f"step_{step:09d}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)
    return path


def _readable(path: str) -> bool:
    """True when the npz at ``path`` is a complete, CRC-clean archive.

    npz is a zip: a writer killed mid-write (or a non-atomic copy torn
    partway) leaves either no central directory or truncated members.
    ``testzip`` walks every member against its CRC, so both tears are
    caught; checkpoints here are small, making the full scan cheap.
    """
    try:
        with zipfile.ZipFile(path) as z:
            return z.testzip() is None
    except (OSError, zipfile.BadZipFile):
        return False


def latest_step(ckpt_dir: str) -> int | None:
    """Newest step whose file is actually restorable.

    Torn/partial writes are SKIPPED, not raised: a server that crashed
    mid-checkpoint must come back on the previous good snapshot, and a
    stray ``.tmp`` from a killed writer never matches the pattern.
    """
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(
        (
            int(m.group(1))
            for f in os.listdir(ckpt_dir)
            if (m := re.match(r"step_(\d+)\.npz$", f))
        ),
        reverse=True,
    )
    for step in steps:
        if _readable(os.path.join(ckpt_dir, f"step_{step:09d}.npz")):
            return step
    return None


def restore_checkpoint(ckpt_dir: str, step: int, target):
    path = os.path.join(ckpt_dir, f"step_{step:09d}.npz")
    with np.load(path) as data:
        flat = dict(data)

    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(target)
    out = []
    for path_t, leaf in leaves_with_path:
        key = "/".join(_path_str(p) for p in path_t)
        if _BF16_PREFIX + key in flat:
            arr = flat[_BF16_PREFIX + key].view(jnp.bfloat16)
        elif key in flat:
            arr = flat[key]
        else:
            raise KeyError(f"checkpoint missing {key}")
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != target {leaf.shape}")
        out.append(jnp.asarray(arr, leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
