"""Pytree <-> disk checkpointing (npz, atomic rename, step-indexed).

Flat key convention: '/'-joined pytree path.  Restore rebuilds into the
caller-provided target structure (shapes validated), so it is safe
against refactors that only reorder dict keys.
"""

from __future__ import annotations

import os
import re
import tempfile

import jax
import jax.numpy as jnp
import numpy as np


# npz cannot represent bfloat16; such leaves are stored as uint16 bit
# views under a marker prefix and re-viewed on restore.
_BF16_PREFIX = "__bf16__/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            flat[_BF16_PREFIX + key] = arr.view(np.uint16)
        else:
            flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(ckpt_dir, f"step_{step:09d}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)
    return path


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(m.group(1))
        for f in os.listdir(ckpt_dir)
        if (m := re.match(r"step_(\d+)\.npz$", f))
    ]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, target):
    path = os.path.join(ckpt_dir, f"step_{step:09d}.npz")
    with np.load(path) as data:
        flat = dict(data)

    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(target)
    out = []
    for path_t, leaf in leaves_with_path:
        key = "/".join(_path_str(p) for p in path_t)
        if _BF16_PREFIX + key in flat:
            arr = flat[_BF16_PREFIX + key].view(jnp.bfloat16)
        elif key in flat:
            arr = flat[key]
        else:
            raise KeyError(f"checkpoint missing {key}")
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != target {leaf.shape}")
        out.append(jnp.asarray(arr, leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
