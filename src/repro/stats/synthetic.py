"""Synthetic two-class Gaussian generators (paper §5.1 and variants).

The paper's synthetic design: d = 200, Sigma*_jk = 0.8^{|j-k|} (AR(1)),
mu1 = 0, mu2 = (1,...,1,0,...,0) with 10 ones; beta* = Theta* mu_d has
11 nonzeros (AR(1) precision is tridiagonal, so the support widens by
one).  r = n1/n = 0.5.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class LDAProblem(NamedTuple):
    sigma: jnp.ndarray  # (d, d) true covariance
    theta: jnp.ndarray  # (d, d) true precision
    mu1: jnp.ndarray
    mu2: jnp.ndarray
    beta_star: jnp.ndarray  # Theta* (mu1 - mu2)
    chol: jnp.ndarray  # cholesky(sigma) for sampling


def ar1_covariance(d: int, rho: float = 0.8) -> np.ndarray:
    idx = np.arange(d)
    return rho ** np.abs(idx[:, None] - idx[None, :])


def block_covariance(d: int, block: int = 10, rho: float = 0.5) -> np.ndarray:
    """Block-diagonal equicorrelation -- an extra design for ablations."""
    sigma = np.eye(d)
    for start in range(0, d, block):
        end = min(start + block, d)
        sigma[start:end, start:end] = rho
    np.fill_diagonal(sigma, 1.0)
    return sigma


def make_problem(
    d: int = 200,
    n_signal: int = 10,
    rho: float = 0.8,
    signal: float = 1.0,
    design: str = "ar1",
) -> LDAProblem:
    if design == "ar1":
        sigma = ar1_covariance(d, rho)
    elif design == "block":
        sigma = block_covariance(d, rho=min(rho, 0.5))
    else:
        raise ValueError(f"unknown design {design!r}")
    theta = np.linalg.inv(sigma)
    mu1 = np.zeros(d)
    mu2 = np.zeros(d)
    mu2[:n_signal] = signal
    beta_star = theta @ (mu1 - mu2)
    # clean up numerically-zero entries so support metrics are exact
    beta_star[np.abs(beta_star) < 1e-10] = 0.0
    chol = np.linalg.cholesky(sigma)
    f32 = lambda a: jnp.asarray(a, jnp.float32)
    return LDAProblem(f32(sigma), f32(theta), f32(mu1), f32(mu2), f32(beta_star), f32(chol))


def sample_two_class(
    key: jax.Array, problem: LDAProblem, n1: int, n2: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Draw (X: (n1,d), Y: (n2,d)) from the two Gaussians."""
    k1, k2 = jax.random.split(key)
    d = problem.mu1.shape[0]
    x = problem.mu1 + jax.random.normal(k1, (n1, d)) @ problem.chol.T
    y = problem.mu2 + jax.random.normal(k2, (n2, d)) @ problem.chol.T
    return x, y


def sample_machines(
    key: jax.Array, problem: LDAProblem, m: int, n1: int, n2: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Draw stacked per-machine shards xs: (m, n1, d), ys: (m, n2, d)."""
    keys = jax.random.split(key, m)
    xs, ys = jax.vmap(lambda k: sample_two_class(k, problem, n1, n2))(keys)
    return xs, ys


def sample_labeled(
    key: jax.Array, problem: LDAProblem, n: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Equal-prior labeled test draw: returns (Z: (n, d), labels in {0,1})."""
    kl, kz = jax.random.split(key)
    labels = jax.random.bernoulli(kl, 0.5, (n,)).astype(jnp.int32)
    d = problem.mu1.shape[0]
    noise = jax.random.normal(kz, (n, d)) @ problem.chol.T
    mus = jnp.where(labels[:, None] == 0, problem.mu1[None, :], problem.mu2[None, :])
    return mus + noise, labels


def heart_disease_surrogate(
    key: jax.Array, n: int = 920, d: int = 22, n_sites: int = 4
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Offline surrogate for the UCI Heart-Disease experiment (§5.2).

    The container has no network access, so we generate a synthetic
    dataset with the published dimensions (920 patients, 22 numeric
    attributes after dummy-coding, 4 hospitals) and a mildly
    heterogeneous per-site mean shift.  Returns (features, labels,
    site_ids).  Benchmarks clearly label results as surrogate.
    """
    kp, ks, kz = jax.random.split(key, 3)
    # strongly correlated attributes (clinical features are collinear);
    # this is what makes the naive averaged estimator pay for its
    # shrinkage bias, as in the paper's real-data table.
    problem = make_problem(d=d, n_signal=6, rho=0.85, signal=0.8)
    z, labels = sample_labeled(kz, problem, n)
    sites = jax.random.randint(ks, (n,), 0, n_sites)
    site_shift = 0.15 * jax.random.normal(kp, (n_sites, d))
    z = z + site_shift[sites]
    return z, labels, sites


class MCProblem(NamedTuple):
    sigma: jnp.ndarray
    theta: jnp.ndarray
    means: jnp.ndarray  # (K, d)
    betas: jnp.ndarray  # (d, K) Theta (mu_k - mu_bar)
    chol: jnp.ndarray


def make_mc_problem(
    d: int = 120, num_classes: int = 4, n_signal: int = 6, rho: float = 0.8,
    signal: float = 1.2,
) -> MCProblem:
    """K classes on disjoint mean supports, shared AR(1) covariance."""
    sigma = ar1_covariance(d, rho)
    theta = np.linalg.inv(sigma)
    means = np.zeros((num_classes, d))
    for k in range(num_classes):
        start = k * n_signal
        means[k, start : start + n_signal] = signal
    mu_bar = means.mean(axis=0)
    betas = theta @ (means - mu_bar).T  # (d, K)
    betas[np.abs(betas) < 1e-10] = 0.0
    chol = np.linalg.cholesky(sigma)
    f32 = lambda a: jnp.asarray(a, jnp.float32)
    return MCProblem(f32(sigma), f32(theta), f32(means), f32(betas), f32(chol))


def sample_mc_machines(
    key: jax.Array,
    problem: MCProblem,
    m: int,
    n_per_machine: int,
    class_probs: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-machine draws: xs (m, n, d), labels (m, n).

    ``class_probs=None`` draws balanced labels (uniform over classes);
    a (K,) probability vector draws imbalanced labels -- the regime
    where :func:`repro.core.multiclass.mc_classify`'s ``priors``
    argument earns its keep.
    """
    num_classes, d = problem.means.shape

    def one(k):
        kl, kz = jax.random.split(k)
        if class_probs is None:
            labels = jax.random.randint(kl, (n_per_machine,), 0, num_classes)
        else:
            labels = jax.random.choice(
                kl, num_classes, (n_per_machine,),
                p=jnp.asarray(class_probs),
            )
        noise = jax.random.normal(kz, (n_per_machine, d)) @ problem.chol.T
        return problem.means[labels] + noise, labels

    keys = jax.random.split(key, m)
    xs, labels = jax.vmap(one)(keys)
    return xs, labels
