"""Statistical substrate: synthetic generators and metrics."""
