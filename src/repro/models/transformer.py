"""Decoder-only model assembly over a repeating block pattern.

A model is ``num_repeats`` copies of ``cfg.pattern`` (e.g. jamba's
("attn",) + ("mamba",)*7).  Per-pattern-position params are stacked on
a leading repeats axis and consumed as scan xs, so the lowered HLO is
O(len(pattern)) regardless of depth.  Each repeat is rematerialized
(jax.checkpoint) in the train path -- the standard memory/compute
trade for 100B-scale training, and a §Perf lever.

Block kinds:
  attn      GQA attention + SwiGLU MLP (two residual subs)
  attn_moe  GQA attention + MoE       (two residual subs)
  mamba     Mamba SSM (single sub)
  mlstm     xLSTM matrix-memory block (single sub)
  slstm     xLSTM scalar-memory block (single sub)
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention, common, mamba, mlp, moe, xlstm
from repro.models.common import ArchConfig
from repro.sharding import constrain


class DecodeState(NamedTuple):
    """Per-model decode state: stacked per-repeat caches + position."""

    caches: Any  # dict "b{i}" -> stacked cache pytree (repeats leading)
    pos: jnp.ndarray  # scalar int32, number of tokens already in cache


# ---------------------------------------------------------------------------
# per-block init / apply
# ---------------------------------------------------------------------------


def _init_block(key, kind: str, cfg: ArchConfig, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    p = {"norm1": jnp.ones((d,), jnp.float32)}
    if kind in ("attn", "attn_moe"):
        p["attn"] = attention.init_attention(k1, cfg, dtype)
        p["norm2"] = jnp.ones((d,), jnp.float32)
        if kind == "attn":
            p["mlp"] = mlp.init_mlp(k2, d, cfg.d_ff, dtype)
        else:
            p["moe"] = moe.init_moe(k2, cfg, dtype)
    elif kind in ("mamba", "mamba_mlp", "mamba_moe"):
        p["mamba"] = mamba.init_mamba(k1, cfg, dtype)
        if kind == "mamba_mlp":
            p["norm2"] = jnp.ones((d,), jnp.float32)
            p["mlp"] = mlp.init_mlp(k2, d, cfg.d_ff, dtype)
        elif kind == "mamba_moe":
            p["norm2"] = jnp.ones((d,), jnp.float32)
            p["moe"] = moe.init_moe(k2, cfg, dtype)
    elif kind == "mlstm":
        p["mlstm"] = xlstm.init_mlstm(k1, cfg, dtype)
    elif kind == "slstm":
        p["slstm"] = xlstm.init_slstm(k1, cfg, dtype)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    return p


def _apply_block_train(p, kind: str, x, cfg: ArchConfig):
    aux = {}
    eps = cfg.norm_eps
    if kind in ("attn", "attn_moe"):
        h = common.rms_norm(x, p["norm1"], eps)
        x = x + attention.attention_train(p["attn"], h, cfg)
        h = common.rms_norm(x, p["norm2"], eps)
        if kind == "attn":
            x = x + mlp.mlp(p["mlp"], h)
        else:
            y, aux = moe.moe(p["moe"], h, cfg)
            x = x + y
    elif kind in ("mamba", "mamba_mlp", "mamba_moe"):
        x = x + mamba.mamba_train(p["mamba"], common.rms_norm(x, p["norm1"], eps), cfg)
        if kind == "mamba_mlp":
            x = x + mlp.mlp(p["mlp"], common.rms_norm(x, p["norm2"], eps))
        elif kind == "mamba_moe":
            y, aux = moe.moe(p["moe"], common.rms_norm(x, p["norm2"], eps), cfg)
            x = x + y
    elif kind == "mlstm":
        x = x + xlstm.mlstm_train(p["mlstm"], common.rms_norm(x, p["norm1"], eps), cfg)
    elif kind == "slstm":
        x = x + xlstm.slstm_train(p["slstm"], common.rms_norm(x, p["norm1"], eps), cfg)
    x = constrain(x, "batch", "seq", "embed")
    return x, aux


def _init_block_cache(kind: str, cfg: ArchConfig, batch: int, cache_len: int, dtype):
    if kind in ("attn", "attn_moe"):
        return attention.init_cache(cfg, batch, cache_len, dtype)
    if kind in ("mamba", "mamba_mlp", "mamba_moe"):
        return mamba.init_state(cfg, batch, dtype)
    if kind == "mlstm":
        return xlstm.init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return xlstm.init_slstm_state(cfg, batch)
    raise ValueError(kind)


def _apply_block_decode(p, kind: str, x, cache, pos, cfg: ArchConfig):
    eps = cfg.norm_eps
    if kind in ("attn", "attn_moe"):
        h = common.rms_norm(x, p["norm1"], eps)
        y, cache = attention.attention_decode(p["attn"], h, cache, pos, cfg)
        x = x + y
        h = common.rms_norm(x, p["norm2"], eps)
        if kind == "attn":
            x = x + mlp.mlp(p["mlp"], h)
        else:
            y, _ = moe.moe(p["moe"], h, cfg)
            x = x + y
    elif kind in ("mamba", "mamba_mlp", "mamba_moe"):
        y, cache = mamba.mamba_decode(p["mamba"], common.rms_norm(x, p["norm1"], eps), cache, cfg)
        x = x + y
        if kind == "mamba_mlp":
            x = x + mlp.mlp(p["mlp"], common.rms_norm(x, p["norm2"], eps))
        elif kind == "mamba_moe":
            y, _ = moe.moe(p["moe"], common.rms_norm(x, p["norm2"], eps), cfg)
            x = x + y
    elif kind == "mlstm":
        y, cache = xlstm.mlstm_decode(p["mlstm"], common.rms_norm(x, p["norm1"], eps), cache, cfg)
        x = x + y
    elif kind == "slstm":
        y, cache = xlstm.slstm_decode(p["slstm"], common.rms_norm(x, p["norm1"], eps), cache, cfg)
        x = x + y
    return x, cache


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DecoderModel:
    cfg: ArchConfig
    remat: bool = True
    # unroll=True replaces the lax.scan over repeats with a Python loop.
    # Used by the dry-run cost correction (XLA cost analysis counts a
    # while body once; an unrolled module is counted fully).
    unroll: bool = False

    def _scan_repeats(self, body, carry, xs):
        if not self.unroll:
            return jax.lax.scan(body, carry, xs)
        ys = []
        for i in range(self.cfg.num_repeats):
            xi = jax.tree.map(lambda a: a[i], xs)
            carry, y = body(carry, xi)
            ys.append(y)
        if all(y is None for y in ys):
            return carry, None
        return carry, jax.tree.map(lambda *a: jnp.stack(a), *ys)

    # -- params ------------------------------------------------------------
    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = cfg.activation_dtype
        kv, ke, ko, kl = jax.random.split(key, 4)
        params: dict = {
            "embedding": common.init_dense(
                ke, (cfg.padded_vocab, cfg.d_model), dtype, scale=cfg.d_model**-0.5
            ),
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        }
        if not getattr(cfg, "tie_embeddings", False):
            params["unembed"] = common.init_dense(
                ko, (cfg.padded_vocab, cfg.d_model), dtype
            )
        layer_keys = jax.random.split(kl, cfg.num_repeats)

        def init_repeat(k):
            ks = jax.random.split(k, len(cfg.pattern))
            return {
                f"b{i}": _init_block(ks[i], kind, cfg, dtype)
                for i, kind in enumerate(cfg.pattern)
            }

        params["layers"] = jax.vmap(init_repeat)(layer_keys)
        if cfg.modality == "vision" and cfg.num_patches:
            params["patch_proj"] = common.init_dense(
                kv, (cfg.d_model, cfg.d_model), dtype
            )
        return params

    # -- embedding front --------------------------------------------------
    def _embed(self, params, tokens, extra_embeds=None):
        x = common.embed_tokens(params["embedding"], tokens)
        if extra_embeds is not None:
            # modality frontend stub: precomputed patch/frame embeddings
            # are projected and prepended (early fusion).
            pe = extra_embeds.astype(x.dtype)
            if "patch_proj" in params:
                pe = jnp.einsum("bpd,de->bpe", pe, params["patch_proj"])
            x = jnp.concatenate([pe, x], axis=1)
        return constrain(x, "batch", "seq", "embed")

    def _unembed_matrix(self, params):
        return params.get("unembed", params["embedding"])

    # -- train forward -----------------------------------------------------
    def forward(self, params, tokens, extra_embeds=None):
        """tokens: (b, s) -> logits (b, s_total, padded_vocab), aux dict."""
        cfg = self.cfg
        x = self._embed(params, tokens, extra_embeds)

        def repeat_body(carry, layer_params):
            x, aux_acc = carry
            for i, kind in enumerate(cfg.pattern):
                x, aux = _apply_block_train(layer_params[f"b{i}"], kind, x, cfg)
                for k, v in aux.items():
                    aux_acc[k] = aux_acc[k] + v
            return (x, aux_acc), None

        body = jax.checkpoint(repeat_body) if self.remat else repeat_body
        aux0 = {"moe_lb_loss": jnp.float32(0.0), "moe_z_loss": jnp.float32(0.0)}
        (x, aux), _ = self._scan_repeats(body, (x, aux0), params["layers"])
        x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = common.unembed(x, self._unembed_matrix(params), cfg.vocab_size)
        n_rep = cfg.num_repeats
        aux = {k: v / n_rep for k, v in aux.items()}
        return logits, aux

    def loss(self, params, batch) -> tuple[jnp.ndarray, dict]:
        cfg = self.cfg
        logits, aux = self.forward(
            params, batch["tokens"], batch.get("extra_embeds")
        )
        # only score token positions (skip the multimodal prefix)
        prefix = logits.shape[1] - batch["labels"].shape[1]
        logits = logits[:, prefix:]
        ce = common.cross_entropy_loss(logits, batch["labels"], cfg.vocab_size)
        total = ce + 0.01 * aux.get("moe_lb_loss", 0.0) + 0.001 * aux.get("moe_z_loss", 0.0)
        metrics = {"ce": ce, **aux}
        return total, metrics

    # -- decode -------------------------------------------------------------
    def cache_len(self, seq_len: int) -> int:
        w = self.cfg.sliding_window
        return min(seq_len, w) if w else seq_len

    def init_decode_state(self, batch: int, seq_len: int) -> DecodeState:
        cfg = self.cfg
        dtype = cfg.activation_dtype
        clen = self.cache_len(seq_len)

        def one_repeat(_):
            return {
                f"b{i}": _init_block_cache(kind, cfg, batch, clen, dtype)
                for i, kind in enumerate(cfg.pattern)
            }

        caches = jax.vmap(one_repeat)(jnp.arange(cfg.num_repeats))
        return DecodeState(caches=caches, pos=jnp.int32(0))

    def decode_step(self, params, state: DecodeState, tokens):
        """tokens: (b, 1) -> (logits (b, 1, vocab), new state)."""
        cfg = self.cfg
        x = self._embed(params, tokens)
        pos = state.pos

        def repeat_body(x, xs):
            layer_params, cache = xs
            new_cache = {}
            for i, kind in enumerate(cfg.pattern):
                x, c = _apply_block_decode(
                    layer_params[f"b{i}"], kind, x, cache[f"b{i}"], pos, cfg
                )
                new_cache[f"b{i}"] = c
            return x, new_cache

        x, new_caches = self._scan_repeats(repeat_body, x, (params["layers"], state.caches))
        x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = common.unembed(x, self._unembed_matrix(params), cfg.vocab_size)
        return logits, DecodeState(caches=new_caches, pos=pos + 1)
