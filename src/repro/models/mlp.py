"""SwiGLU feed-forward block (the dense MLP used by every assigned arch)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common
from repro.sharding import constrain


def init_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": common.init_dense(k1, (d_model, d_ff), dtype),
        "w_up": common.init_dense(k2, (d_model, d_ff), dtype),
        "w_down": common.init_dense(k3, (d_ff, d_model), dtype),
    }


def mlp(p, x: jnp.ndarray) -> jnp.ndarray:
    gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = jax.nn.silu(gate) * up
    h = constrain(h, "batch", "seq", "mlp")
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])
