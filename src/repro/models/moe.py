"""Mixture-of-Experts layer: top-k router + capacity-bounded dispatch.

Routing is *per sequence row*: each (batch row, expert) pair keeps its
top-C tokens by router weight, C = ceil(seq * k / E * capacity_factor).
This keeps all shapes static, avoids a global cross-shard sort, and
drops overflow tokens exactly like MaxText's dropping implementation.

Two sharding modes (config.expert_sharding):
  "tp": expert FFN width sharded on "model" (no all-to-all; behaves
        like 16-way tensor-parallel MLP replicated over experts);
  "ep": experts sharded on "model" (induces all-to-all/all-gather of
        dispatched tokens -- the communication pattern to study in
        §Perf for the MoE-assigned archs).

Aux losses: load-balance (Switch) + router z-loss, returned for logging.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import common, mlp
from repro.models.common import ArchConfig
from repro.sharding import constrain


def init_moe(key, cfg: ArchConfig, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": common.init_dense(ks[0], (d, e), jnp.float32),
        "w_gate": common.init_dense(ks[1], (e, d, f), dtype),
        "w_up": common.init_dense(ks[2], (e, d, f), dtype),
        "w_down": common.init_dense(ks[3], (e, f, d), dtype),
    }
    if cfg.shared_expert:
        p["shared"] = mlp.init_mlp(ks[4], d, f, dtype)
    return p


def capacity(cfg: ArchConfig, seq: int) -> int:
    e, k = cfg.num_experts, cfg.experts_per_token
    c = math.ceil(seq * k / e * cfg.capacity_factor)
    return min(max(8, c), seq)


def moe(p, x: jnp.ndarray, cfg: ArchConfig) -> tuple[jnp.ndarray, dict]:
    """x: (b, s, d) -> (y, aux)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    c = capacity(cfg, s)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k gate per token
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (b, s, k)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    # per-token weight for each expert (0 if not routed)
    token_expert_w = jnp.zeros((b, s, e), jnp.float32)
    token_expert_w = jax.vmap(
        lambda w, row_v, row_i: w.at[jnp.arange(s)[:, None], row_i].set(row_v),
        in_axes=(0, 0, 0),
    )(token_expert_w, gate_vals, gate_idx)

    # per (row, expert): top-C tokens by weight -> static dispatch
    w_t = jnp.swapaxes(token_expert_w, 1, 2)  # (b, e, s)
    disp_w, disp_idx = jax.lax.top_k(w_t, c)  # (b, e, c)
    xg = jnp.take_along_axis(
        x[:, None, :, :], disp_idx[..., None], axis=2
    )  # (b, e, c, d)
    xg = constrain(xg, "batch", "expert", "capacity", "embed")

    gate = jnp.einsum("becd,edf->becf", xg, p["w_gate"])
    up = jnp.einsum("becd,edf->becf", xg, p["w_up"])
    h = jax.nn.silu(gate) * up
    # the installed rules map exactly one of expert/expert_mlp -> "model"
    # depending on cfg.expert_sharding (set by the launcher)
    h = constrain(h, "batch", "expert", "capacity", "expert_mlp")
    y_e = jnp.einsum("becf,efd->becd", h, p["w_down"])  # (b, e, c, d)
    y_e = y_e * disp_w[..., None].astype(y_e.dtype)

    # scatter-add back to token positions
    y = jnp.zeros((b, s, d), x.dtype)
    y = jax.vmap(
        lambda acc, idx, vals: acc.at[idx.reshape(-1)].add(
            vals.reshape(-1, d), mode="drop"
        )
    )(y, disp_idx, y_e)
    y = constrain(y, "batch", "seq", "embed")

    if cfg.shared_expert:
        y = y + mlp.mlp(p["shared"], x)

    # aux losses (Switch load balance + z-loss)
    me = jnp.mean(probs, axis=(0, 1))  # (e,)
    routed = jnp.mean(token_expert_w > 0, axis=(0, 1))
    lb_loss = e * jnp.sum(me * routed)
    z_loss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return y, {"moe_lb_loss": lb_loss, "moe_z_loss": z_loss}
