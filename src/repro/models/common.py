"""Shared model plumbing: the architecture config, norms, RoPE, embeddings.

Parameters are plain nested dicts of jnp arrays.  Per-layer parameters
are *stacked* along a leading repeat axis and the layer stack is applied
with ``jax.lax.scan`` over a repeating block *pattern* -- HLO size stays
O(pattern), not O(depth), which keeps 88-layer/123B lowers tractable and
matches deployment practice (code-cache, compile time).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.sharding import constrain


Params = Any  # nested dict of arrays


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture (exact numbers in repro/configs/*)."""

    name: str
    family: str  # dense | moe | vlm | audio | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # block pattern, e.g. ("attn",), ("attn_moe",), ("attn","attn_moe"),
    # ("attn",) + ("mamba",)*7, ("mlstm",)*7 + ("slstm",)
    pattern: tuple[str, ...] = ("attn",)

    # attention
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0  # 0 = full attention
    # pad q-heads up to this count for model-axis divisibility (llama4:
    # 40 -> 48 for the 16-wide axis).  Padded heads are zero-initialized
    # in wq/wo so the forward pass equals the unpadded model; they are
    # ~1% extra trainable capacity (GSPMD-padding practice).
    pad_heads_to: int = 0

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    shared_expert: bool = False
    expert_sharding: str = "tp"  # "tp" (shard expert ffn width) | "ep" (shard experts)

    # SSM (mamba)
    ssm_expand: int = 2
    ssm_state: int = 16
    conv_width: int = 4
    ssm_chunk: int = 256

    # xLSTM
    xlstm_proj_factor: float = 2.0

    # encoder-decoder
    encoder_layers: int = 0

    # modality frontend stubs
    modality: str = "text"  # text | vision | audio
    num_patches: int = 0  # vision prefix length (anyres tiling stub)

    # KV-cache layout for decode.  "seq_major" = (b, L, kv, hd) (the
    # training activation layout); "head_major" = k:(b, kv, hd, L),
    # v:(b, kv, L, hd) -- matches the decode einsum contractions so the
    # per-step transpose+copy of the whole cache disappears (SSPerf-B).
    decode_cache_layout: str = "head_major"
    # "model" = cache in activation dtype; "int8" = per-token-per-head
    # symmetric int8 quantization (head_major layout only) -- halves
    # cache HBM traffic and doubles the context that fits (SSPerf-B3).
    kv_cache_dtype: str = "model"

    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    vocab_pad_to: int = 256
    citation: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_heads(self) -> int:
        return max(self.pad_heads_to, self.num_heads)

    @property
    def padded_vocab(self) -> int:
        v, p = self.vocab_size, self.vocab_pad_to
        return ((v + p - 1) // p) * p

    @property
    def num_repeats(self) -> int:
        assert self.num_layers % len(self.pattern) == 0, (
            f"{self.name}: num_layers {self.num_layers} not divisible by "
            f"pattern length {len(self.pattern)}"
        )
        return self.num_layers // len(self.pattern)

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        """Exact parameter count via eval_shape (no allocation)."""
        from repro.models import model_zoo

        model = model_zoo.build_model(self)
        shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        return sum(int(math.prod(x.shape)) for x in jax.tree.leaves(shapes))

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k experts)."""
        total = self.param_count()
        if not self.num_experts:
            return total
        # subtract inactive expert FFN weights (any *_moe block kind)
        moe_layers = sum(1 for p in self.pattern if p.endswith("_moe") or p == "attn_moe")
        moe_layers *= self.num_repeats
        per_expert = 3 * self.d_model * self.d_ff
        inactive = moe_layers * (self.num_experts - self.experts_per_token) * per_expert
        return total - inactive


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def init_dense(key, shape, dtype, scale: float | None = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def rope_freqs(positions: jnp.ndarray, head_dim: int, theta: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """positions: (...,) int -> (cos, sin) of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); cos/sin: (..., seq, head_dim//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def embed_tokens(embedding: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    x = jnp.take(embedding, tokens, axis=0)
    return constrain(x, "batch", "seq", "embed")


def unembed(x: jnp.ndarray, embedding: jnp.ndarray, real_vocab: int) -> jnp.ndarray:
    """Project to padded vocab, mask padded ids to -inf."""
    logits = jnp.einsum("...d,vd->...v", x, embedding)
    logits = constrain(logits, "batch", "seq", "vocab")
    v = embedding.shape[0]
    if real_vocab < v:
        mask = jnp.arange(v) < real_vocab
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    return logits


def cross_entropy_loss(logits: jnp.ndarray, labels: jnp.ndarray, real_vocab: int) -> jnp.ndarray:
    """Mean token cross entropy; logits over padded vocab, labels < real_vocab."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    del real_vocab
    return jnp.mean(logz - gold)
