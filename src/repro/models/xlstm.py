"""xLSTM blocks: mLSTM (matrix memory, chunk-parallel) and sLSTM (scalar
memory, strictly sequential) -- Beck et al., arXiv:2405.04517.

TPU adaptation: the mLSTM recurrence
    C_t = f_t C_{t-1} + i_t k_t v_t^T,   n_t = f_t n_{t-1} + i_t k_t
is computed chunkwise (retention-style): within a chunk the output is a
masked quadratic form q K^T with a gate-decay matrix; across chunks a
(b, h, dk, dv) matrix-memory carry is propagated by lax.scan.  Gates
use log-space accumulation with clipping for stability.

sLSTM has a true hidden-to-gate recurrence (block-diagonal R per head),
so it cannot be parallelized over time; it runs as a lax.scan over
steps (an O(1)-HLO while loop).  The assigned xlstm-1.3b interleaves
them 7:1 (pattern ("mlstm",)*7 + ("slstm",)).

Both blocks contain their own up/down projections (the config's
d_ff = 0 is correct: there is no separate FFN).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import ArchConfig
from repro.sharding import constrain


GATE_CLIP = 8.0


def _dims(cfg: ArchConfig) -> tuple[int, int, int]:
    """(d_up, num_heads, head_dim) of the inner mLSTM space."""
    d_up = int(cfg.xlstm_proj_factor * cfg.d_model)
    nh = cfg.num_heads
    return d_up, nh, d_up // nh


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


class MLSTMState(NamedTuple):
    c: jnp.ndarray  # (b, h, dk, dv) matrix memory
    n: jnp.ndarray  # (b, h, dk) normalizer


def init_mlstm(key, cfg: ArchConfig, dtype) -> dict:
    d = cfg.d_model
    d_up, nh, hd = _dims(cfg)
    ks = jax.random.split(key, 8)
    # head-structured layouts (SSPerf-E): w_gate/w_down keep the (h, hd)
    # split so the dk/dv axis can shard over "model" end to end -- the
    # inner-sharded contractions then reduce-scatter into dk-sharded
    # outputs instead of all-reducing 1 GB replicated activations.
    return {
        "w_up": common.init_dense(ks[0], (d, d_up), dtype),
        "w_gate": common.init_dense(ks[1], (d, nh, hd), dtype),
        "w_q": common.init_dense(ks[2], (d_up, nh, hd), dtype),
        "w_k": common.init_dense(ks[3], (d_up, nh, hd), dtype),
        "w_v": common.init_dense(ks[4], (d_up, nh, hd), dtype),
        "w_if": common.init_dense(ks[5], (d_up, nh, 2), jnp.float32),
        "b_if": jnp.concatenate(
            [jnp.zeros((nh, 1)), jnp.full((nh, 1), 3.0)], axis=-1
        ),  # forget-gate bias ~ sigmoid(3) ≈ .95
        "w_down": common.init_dense(ks[6], (nh, hd, d), dtype),
    }


def _mlstm_qkvif(p, x, cfg):
    up = jnp.einsum("bsd,de->bse", x, p["w_up"])
    up = constrain(up, "batch", "seq", "ssm_inner")
    q = jnp.einsum("bse,ehk->bshk", up, p["w_q"])
    k = jnp.einsum("bse,ehk->bshk", up, p["w_k"])
    v = jnp.einsum("bse,ehk->bshk", up, p["w_v"])
    q = constrain(q, "batch", "seq", None, "xlstm_dk")
    k = constrain(k, "batch", "seq", None, "xlstm_dk")
    v = constrain(v, "batch", "seq", None, "xlstm_dk")
    gates = jnp.einsum("bse,ehg->bshg", up.astype(jnp.float32), p["w_if"]) + p["b_if"]
    log_i = jnp.clip(gates[..., 0], -GATE_CLIP, GATE_CLIP)  # (b,s,h)
    log_f = jax.nn.log_sigmoid(gates[..., 1])  # (b,s,h), <= 0
    gate_z = jax.nn.silu(jnp.einsum("bsd,dhk->bshk", x, p["w_gate"]))
    gate_z = constrain(gate_z, "batch", "seq", None, "xlstm_dk")
    return up, q, k, v, log_i, log_f, gate_z


def mlstm_train(p, x, cfg: ArchConfig) -> jnp.ndarray:
    b, s, d = x.shape
    d_up, nh, hd = _dims(cfg)
    chunk = min(cfg.ssm_chunk, s)
    nc = s // chunk
    assert nc * chunk == s

    up, q, k, v, log_i, log_f, gate_z = _mlstm_qkvif(p, x, cfg)
    scale = 1.0 / jnp.sqrt(hd)

    resh = lambda t: t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
    qs, ks_, vs = resh(q.astype(jnp.float32)), resh(k.astype(jnp.float32)), resh(
        v.astype(jnp.float32)
    )
    lis, lfs = resh(log_i), resh(log_f)

    def chunk_step(carry, inp):
        c_prev, n_prev = carry  # (b,h,dk,dv), (b,h,dk)
        qc, kc, vc, lic, lfc = inp  # (b,L,h,*)
        fcum = jnp.cumsum(lfc, axis=1)  # (b,L,h) log prod of f up to t
        # intra-chunk decay D_ij = fcum_i - fcum_j + log i_j  (j <= i)
        dmat = (
            fcum[:, :, None, :] - fcum[:, None, :, :] + lic[:, None, :, :]
        )  # (b, i, j, h)
        l_idx = jnp.arange(qc.shape[1])
        causal = l_idx[:, None] >= l_idx[None, :]
        dmat = jnp.where(causal[None, :, :, None], dmat, -jnp.inf)
        dmat = jnp.clip(dmat, -60.0, GATE_CLIP)
        w = jnp.exp(dmat)  # (b,i,j,h)
        scores = jnp.einsum("bihk,bjhk->bijh", qc, kc) * scale
        intra = jnp.einsum("bijh,bijh,bjhv->bihv", scores, w, vc)
        n_intra = jnp.einsum("bijh,bjhk->bihk", w, kc)
        # inter-chunk: decay from carry = exp(fcum_i)
        decay_i = jnp.exp(jnp.clip(fcum, -60.0, 0.0))  # (b,L,h)
        inter = jnp.einsum("bihk,bhkv,bih->bihv", qc, c_prev, decay_i) * scale
        n_inter = n_prev[:, None] * decay_i[..., None]  # (b,L,h,dk)
        num = intra + inter  # (b,L,h,dv)
        nvec = n_intra + n_inter  # (b,L,h,dk)
        denom = jnp.maximum(
            jnp.abs(jnp.einsum("bihk,bihk->bih", qc, nvec)) * scale, 1.0
        )
        y = num / denom[..., None]
        # carry update: C_new = f_total C_prev + sum_j f_{j+1..L} i_j k_j v_j^T
        f_total = jnp.exp(jnp.clip(fcum[:, -1], -60.0, 0.0))  # (b,h)
        tail = jnp.exp(
            jnp.clip(fcum[:, -1:, :] - fcum + lic, -60.0, GATE_CLIP)
        )  # (b,L,h)
        c_new = f_total[:, :, None, None] * c_prev + jnp.einsum(
            "bjh,bjhk,bjhv->bhkv", tail, kc, vc
        )
        c_new = constrain(c_new, "batch", None, "xlstm_dk", None)
        n_new = f_total[:, :, None] * n_prev + jnp.einsum("bjh,bjhk->bhk", tail, kc)
        n_new = constrain(n_new, "batch", None, "xlstm_dk")
        return (c_new, n_new), y

    c0 = jnp.zeros((b, nh, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, nh, hd), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, (c0, n0), (qs, ks_, vs, lis, lfs))
    y = ys.swapaxes(0, 1).reshape(b, s, nh, hd).astype(x.dtype)  # (b,s,h,dv)
    y = y * gate_z
    y = constrain(y, "batch", "seq", None, "xlstm_dk")
    return jnp.einsum("bshv,hvd->bsd", y, p["w_down"])


def init_mlstm_state(cfg: ArchConfig, batch: int) -> MLSTMState:
    _, nh, hd = _dims(cfg)
    return MLSTMState(
        c=jnp.zeros((batch, nh, hd, hd), jnp.float32),
        n=jnp.zeros((batch, nh, hd), jnp.float32),
    )


def mlstm_decode(p, x, state: MLSTMState, cfg: ArchConfig):
    """x: (b, 1, d) -> (out, new state); exact recurrence."""
    _, nh, hd = _dims(cfg)
    up, q, k, v, log_i, log_f, gate_z = _mlstm_qkvif(p, x, cfg)
    q, k, v = (t.astype(jnp.float32)[:, 0] for t in (q, k, v))  # (b,h,hd)
    i_t = jnp.exp(log_i[:, 0])  # (b,h)
    f_t = jnp.exp(log_f[:, 0])
    # keep the dk axis sharded through the update + readout (SSPerf-D):
    # q/k dk-sharded, v replicated -> C stays dk-sharded; the q.C and
    # q.n contractions become partial sums merged by tiny all-reduces.
    q = constrain(q, "batch", None, "xlstm_dk")
    k = constrain(k, "batch", None, "xlstm_dk")
    c = f_t[..., None, None] * state.c + i_t[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    n = f_t[..., None] * state.n + i_t[..., None] * k
    c = constrain(c, "batch", None, "xlstm_dk", None)
    n = constrain(n, "batch", None, "xlstm_dk")
    scale = 1.0 / jnp.sqrt(hd)
    num = jnp.einsum("bhk,bhkv->bhv", q, c) * scale
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, n)) * scale, 1.0)
    y = (num / denom[..., None])[:, None].astype(x.dtype)  # (b, 1, h, dv)
    y = y * gate_z
    return jnp.einsum("bshv,hvd->bsd", y, p["w_down"]), MLSTMState(c, n)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


class SLSTMState(NamedTuple):
    c: jnp.ndarray  # (b, h, hd)
    n: jnp.ndarray
    h: jnp.ndarray
    m: jnp.ndarray  # (b, h, hd) log-space stabilizer


def init_slstm(key, cfg: ArchConfig, dtype) -> dict:
    d = cfg.d_model
    d_up, nh, hd = _dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "w_up": common.init_dense(ks[0], (d, d_up), dtype),
        # four gates (z, i, f, o) from input
        "w_gates": common.init_dense(ks[1], (d_up, nh, 4 * hd), jnp.float32),
        # block-diagonal recurrent weights per head
        "r_gates": common.init_dense(ks[2], (nh, hd, 4 * hd), jnp.float32),
        "b_gates": jnp.zeros((nh, 4 * hd)),
        "w_down": common.init_dense(ks[3], (d_up, d), dtype),
    }


def _slstm_cell(p, xg, state: SLSTMState) -> SLSTMState:
    """xg: (b, h, 4*hd) pre-activations from the input path."""
    hd = state.c.shape[-1]
    rec = jnp.einsum("bhk,hkg->bhg", state.h, p["r_gates"])
    g = xg + rec + p["b_gates"]
    z_t = jnp.tanh(g[..., :hd])
    log_i = jnp.clip(g[..., hd : 2 * hd], -GATE_CLIP, GATE_CLIP)
    log_f = jax.nn.log_sigmoid(g[..., 2 * hd : 3 * hd])
    o_t = jax.nn.sigmoid(g[..., 3 * hd :])
    m_new = jnp.maximum(log_f + state.m, log_i)
    i_p = jnp.exp(log_i - m_new)
    f_p = jnp.exp(log_f + state.m - m_new)
    c = f_p * state.c + i_p * z_t
    n = f_p * state.n + i_p
    h = o_t * c / jnp.maximum(n, 1.0)
    return SLSTMState(c, n, h, m_new)


def slstm_train(p, x, cfg: ArchConfig) -> jnp.ndarray:
    b, s, d = x.shape
    d_up, nh, hd = _dims(cfg)
    up = jnp.einsum("bsd,de->bse", x, p["w_up"]).astype(jnp.float32)
    xg = jnp.einsum("bse,ehg->bshg", up, p["w_gates"])  # (b,s,h,4hd)

    def step(state, xg_t):
        new = _slstm_cell(p, xg_t, state)
        return new, new.h

    state0 = init_slstm_state(cfg, b)
    _, hs = jax.lax.scan(step, state0, xg.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).reshape(b, s, nh * hd).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", y, p["w_down"])


def init_slstm_state(cfg: ArchConfig, batch: int) -> SLSTMState:
    _, nh, hd = _dims(cfg)
    z = lambda: jnp.zeros((batch, nh, hd), jnp.float32)
    return SLSTMState(z(), z(), z(), z() - 30.0)


def slstm_decode(p, x, state: SLSTMState, cfg: ArchConfig):
    b = x.shape[0]
    d_up, nh, hd = _dims(cfg)
    up = jnp.einsum("bsd,de->bse", x, p["w_up"]).astype(jnp.float32)
    xg = jnp.einsum("bse,ehg->bshg", up, p["w_gates"])[:, 0]
    new = _slstm_cell(p, xg, state)
    y = new.h.reshape(b, 1, nh * hd).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", y, p["w_down"]), new
