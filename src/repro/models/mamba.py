"""Mamba-1 selective SSM block, TPU-adapted with a chunked scan.

GPU Mamba fuses the selective scan into a warp-level kernel; the TPU
adaptation (DESIGN.md §2) restructures it as: sequential ``lax.scan``
over chunks of ``cfg.ssm_chunk`` tokens, parallel first-order
``associative_scan`` within a chunk.  The inner dim is sharded on the
"model" axis so the per-chunk state tensor (b, L, d_inner/16, d_state)
fits VMEM-scale working sets; cross-chunk carry is (b, d_inner, d_state).

Decode is the exact single-step recurrence with a (conv buffer, h)
state -- O(1) per token, which is what makes jamba/long_500k native.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import ArchConfig
from repro.sharding import constrain


class MambaState(NamedTuple):
    conv_buf: jnp.ndarray  # (b, conv_width-1, d_inner) rolling input buffer
    ssm_h: jnp.ndarray  # (b, d_inner, d_state) SSM state


def d_inner(cfg: ArchConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def init_mamba(key, cfg: ArchConfig, dtype) -> dict:
    d, di, ds, w = cfg.d_model, d_inner(cfg), cfg.ssm_state, cfg.conv_width
    ks = jax.random.split(key, 7)
    dt_rank = max(16, d // 16)
    return {
        "in_proj": common.init_dense(ks[0], (d, 2 * di), dtype),
        "conv_w": common.init_dense(ks[1], (w, di), dtype, scale=1.0 / w),
        "conv_b": jnp.zeros((di,), dtype),
        "w_dt": common.init_dense(ks[2], (di, dt_rank), dtype),
        "w_dt_up": common.init_dense(ks[3], (dt_rank, di), dtype),
        "dt_bias": jnp.full((di,), -4.0, jnp.float32),  # softplus^-1(~0.018)
        "w_bc": common.init_dense(ks[4], (di, 2 * ds), dtype),
        "a_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
        ),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": common.init_dense(ks[5], (di, d), dtype),
    }


def _conv_causal(x, conv_w, conv_b):
    """Depthwise causal conv over seq.  x: (b, s, di); conv_w: (w, di)."""
    w = conv_w.shape[0]
    pad = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1], :] * conv_w[i] for i in range(w))
    return out + conv_b


def _ssm_inputs(p, xz, cfg: ArchConfig):
    """Shared front half: returns (x_conv, z, dt, b_in, c_in)."""
    di = d_inner(cfg)
    x, z = xz[..., :di], xz[..., di:]
    x = constrain(x, "batch", "seq", "ssm_inner")
    x = jax.nn.silu(_conv_causal(x, p["conv_w"], p["conv_b"]))
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dr->bsr", x, p["w_dt"]) @ p["w_dt_up"]
        + p["dt_bias"].astype(xz.dtype)
    ).astype(jnp.float32)
    bc = jnp.einsum("bsd,dn->bsn", x, p["w_bc"]).astype(jnp.float32)
    ds = cfg.ssm_state
    return x, z, dt, bc[..., :ds], bc[..., ds:]


def mamba_train(p, x_in, cfg: ArchConfig):
    """x_in: (b, s, d) -> (b, s, d).  s must divide by cfg.ssm_chunk."""
    b, s, d = x_in.shape
    di, ds = d_inner(cfg), cfg.ssm_state
    chunk = min(cfg.ssm_chunk, s)
    nc = s // chunk
    assert nc * chunk == s, f"seq {s} not divisible by ssm chunk {chunk}"

    xz = jnp.einsum("bsd,de->bse", x_in, p["in_proj"])
    x, z, dt, b_in, c_in = _ssm_inputs(p, xz, cfg)

    a = -jnp.exp(p["a_log"])  # (di, ds)
    # per-step decay and increment
    #   h_t = exp(dt_t * A) h_{t-1} + dt_t * x_t * B_t
    x32 = x.astype(jnp.float32)

    def chunk_step(h_carry, inputs):
        xc, dtc, bc, cc = inputs  # (b, L, ...)
        decay = jnp.exp(dtc[..., None] * a)  # (b, L, di, ds)
        inc = (dtc * xc)[..., None] * bc[:, :, None, :]  # (b, L, di, ds)

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a2 * a1, a2 * b1 + b2

        cum_decay, h_within = jax.lax.associative_scan(
            combine, (decay, inc), axis=1
        )
        h = cum_decay * h_carry[:, None] + h_within  # (b, L, di, ds)
        y = jnp.einsum("blds,bls->bld", h, cc)
        return h[:, -1], y

    reshaped = lambda t: t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
    h0 = jnp.zeros((b, di, ds), jnp.float32)
    _, ys = jax.lax.scan(
        chunk_step, h0, (reshaped(x32), reshaped(dt), reshaped(b_in), reshaped(c_in))
    )
    y = ys.swapaxes(0, 1).reshape(b, s, di)
    y = y + x32 * p["d_skip"]
    y = (y.astype(x_in.dtype)) * jax.nn.silu(z)
    y = constrain(y, "batch", "seq", "ssm_inner")
    return jnp.einsum("bsd,de->bse", y, p["out_proj"])


def init_state(cfg: ArchConfig, batch: int, dtype) -> MambaState:
    di, ds, w = d_inner(cfg), cfg.ssm_state, cfg.conv_width
    return MambaState(
        conv_buf=jnp.zeros((batch, w - 1, di), dtype),
        ssm_h=jnp.zeros((batch, di, ds), jnp.float32),
    )


def mamba_decode(p, x_in, state: MambaState, cfg: ArchConfig):
    """One-token step.  x_in: (b, 1, d) -> (out (b, 1, d), new state)."""
    di, ds = d_inner(cfg), cfg.ssm_state
    xz = jnp.einsum("bsd,de->bse", x_in, p["in_proj"])
    x, z = xz[..., :di], xz[..., di:]
    # rolling conv buffer
    buf = jnp.concatenate([state.conv_buf, x], axis=1)  # (b, w, di)
    xc = jnp.einsum("bwd,wd->bd", buf, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc)[:, None, :]  # (b, 1, di)
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dr->bsr", xc, p["w_dt"]) @ p["w_dt_up"]
        + p["dt_bias"].astype(x_in.dtype)
    ).astype(jnp.float32)[:, 0]
    bc = jnp.einsum("bsd,dn->bsn", xc, p["w_bc"]).astype(jnp.float32)[:, 0]
    b_in, c_in = bc[..., :ds], bc[..., ds:]
    a = -jnp.exp(p["a_log"])
    x32 = xc.astype(jnp.float32)[:, 0]
    decay = jnp.exp(dt[..., None] * a)  # (b, di, ds)
    h = decay * state.ssm_h + (dt * x32)[..., None] * b_in[:, None, :]
    y = jnp.einsum("bds,bs->bd", h, c_in) + x32 * p["d_skip"]
    y = (y[:, None, :].astype(x_in.dtype)) * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"])
    return out, MambaState(conv_buf=buf[:, 1:], ssm_h=h)
