"""Model zoo substrate: decoder-only / enc-dec transformers, MoE, SSMs."""
