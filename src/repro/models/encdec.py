"""Encoder-decoder transformer (seamless-m4t backbone).

Encoder: non-causal self-attention blocks over precomputed frame
embeddings (the mel/conv audio frontend is a stub by the assignment's
carve-out -- ``input_specs`` supplies (b, frames, d_model)).
Decoder: causal self-attention + cross-attention + MLP per layer.

Both stacks scan over stacked per-layer params.  Cross-attention K/V
are precomputed once per sequence from the encoder memory and reused
for every decode step (standard serving optimization).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention, common, mlp
from repro.models.common import ArchConfig
from repro.sharding import constrain


class EncDecDecodeState(NamedTuple):
    caches: Any  # stacked KVCache for decoder self-attn
    cross_k: jnp.ndarray  # (layers, b, src, kv, hd)
    cross_v: jnp.ndarray
    pos: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class EncDecModel:
    cfg: ArchConfig
    remat: bool = True
    # unroll=True: Python loop instead of lax.scan (dry-run cost correction)
    unroll: bool = False

    def _scan_layers(self, body, carry, xs, count: int):
        if not self.unroll:
            return jax.lax.scan(body, carry, xs)
        ys = []
        for i in range(count):
            xi = jax.tree.map(lambda a: a[i], xs)
            carry, y = body(carry, xi)
            ys.append(y)
        if all(y is None for y in ys):
            return carry, None
        return carry, jax.tree.map(lambda *a: jnp.stack(a), *ys)

    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = cfg.activation_dtype
        ke, kenc, kdec, kf = jax.random.split(key, 4)
        d = cfg.d_model

        def init_enc_layer(k):
            k1, k2 = jax.random.split(k)
            return {
                "norm1": jnp.ones((d,), jnp.float32),
                "attn": attention.init_attention(k1, cfg, dtype),
                "norm2": jnp.ones((d,), jnp.float32),
                "mlp": mlp.init_mlp(k2, d, cfg.d_ff, dtype),
            }

        def init_dec_layer(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {
                "norm1": jnp.ones((d,), jnp.float32),
                "self_attn": attention.init_attention(k1, cfg, dtype),
                "norm_x": jnp.ones((d,), jnp.float32),
                "cross_attn": attention.init_attention(k2, cfg, dtype),
                "norm2": jnp.ones((d,), jnp.float32),
                "mlp": mlp.init_mlp(k3, d, cfg.d_ff, dtype),
            }

        enc_keys = jax.random.split(kenc, cfg.encoder_layers)
        dec_keys = jax.random.split(kdec, cfg.num_layers)
        return {
            "embedding": common.init_dense(ke, (cfg.padded_vocab, d), dtype, scale=d**-0.5),
            "enc_layers": jax.vmap(init_enc_layer)(enc_keys),
            "dec_layers": jax.vmap(init_dec_layer)(dec_keys),
            "enc_norm": jnp.ones((d,), jnp.float32),
            "final_norm": jnp.ones((d,), jnp.float32),
        }

    # -- encoder -------------------------------------------------------------
    def encode(self, params, frames: jnp.ndarray) -> jnp.ndarray:
        """frames: (b, src, d_model) stub embeddings -> encoder memory."""
        cfg = self.cfg
        x = constrain(frames.astype(cfg.activation_dtype), "batch", "seq", "embed")

        def body(x, p):
            h = common.rms_norm(x, p["norm1"], cfg.norm_eps)
            x = x + attention.attention_train(p["attn"], h, cfg, causal=False)
            h = common.rms_norm(x, p["norm2"], cfg.norm_eps)
            x = x + mlp.mlp(p["mlp"], h)
            return x, None

        body_fn = jax.checkpoint(body) if self.remat else body
        x, _ = self._scan_layers(body_fn, x, params["enc_layers"], cfg.encoder_layers)
        return common.rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def _cross_kv(self, p_attn, memory):
        k = jnp.einsum("bsd,dhk->bshk", memory, p_attn["wk"])
        v = jnp.einsum("bsd,dhk->bshk", memory, p_attn["wv"])
        if self.cfg.qkv_bias:
            k = k + p_attn["bk"]
            v = v + p_attn["bv"]
        k = constrain(k, "batch", "cache_seq", "kv_heads", "head_dim")
        v = constrain(v, "batch", "cache_seq", "kv_heads", "head_dim")
        return k, v

    # -- train ---------------------------------------------------------------
    def forward(self, params, tokens, frames):
        cfg = self.cfg
        memory = self.encode(params, frames)
        x = common.embed_tokens(params["embedding"], tokens)

        def body(x, p):
            h = common.rms_norm(x, p["norm1"], cfg.norm_eps)
            x = x + attention.attention_train(p["self_attn"], h, cfg)
            h = common.rms_norm(x, p["norm_x"], cfg.norm_eps)
            ckv = self._cross_kv(p["cross_attn"], memory)
            x = x + attention.attention_train(p["cross_attn"], h, cfg, cross_kv=ckv)
            h = common.rms_norm(x, p["norm2"], cfg.norm_eps)
            x = x + mlp.mlp(p["mlp"], h)
            return x, None

        body_fn = jax.checkpoint(body) if self.remat else body
        x, _ = self._scan_layers(body_fn, x, params["dec_layers"], cfg.num_layers)
        x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = common.unembed(x, params["embedding"], cfg.vocab_size)
        return logits, {}

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch["tokens"], batch["frames"])
        ce = common.cross_entropy_loss(logits, batch["labels"], self.cfg.vocab_size)
        return ce, {"ce": ce, **aux}

    # -- decode ----------------------------------------------------------------
    def cache_len(self, seq_len: int) -> int:
        w = self.cfg.sliding_window
        return min(seq_len, w) if w else seq_len

    def init_decode_state(self, params, memory, seq_len: int) -> EncDecDecodeState:
        cfg = self.cfg
        b = memory.shape[0]
        clen = self.cache_len(seq_len)

        def per_layer(p):
            return self._cross_kv(p["cross_attn"], memory)

        cross_k, cross_v = jax.vmap(per_layer)(params["dec_layers"])
        caches = jax.vmap(
            lambda _: attention.init_cache(cfg, b, clen, cfg.activation_dtype)
        )(jnp.arange(cfg.num_layers))
        return EncDecDecodeState(caches, cross_k, cross_v, jnp.int32(0))

    def decode_step(self, params, state: EncDecDecodeState, tokens):
        cfg = self.cfg
        x = common.embed_tokens(params["embedding"], tokens)
        pos = state.pos

        def body(x, xs):
            p, cache, ck, cv = xs
            h = common.rms_norm(x, p["norm1"], cfg.norm_eps)
            y, cache = attention.attention_decode(p["self_attn"], h, cache, pos, cfg)
            x = x + y
            h = common.rms_norm(x, p["norm_x"], cfg.norm_eps)
            # direct (non-blockwise) path keeps a seq-sharded memory
            # sharded through the softmax (SSPerf-C)
            x = x + attention.cross_attention_decode(p["cross_attn"], h, ck, cv, cfg)
            h = common.rms_norm(x, p["norm2"], cfg.norm_eps)
            x = x + mlp.mlp(p["mlp"], h)
            return x, cache

        x, new_caches = self._scan_layers(
            body, x, (params["dec_layers"], state.caches, state.cross_k, state.cross_v),
            self.cfg.num_layers,
        )
        x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = common.unembed(x, params["embedding"], cfg.vocab_size)
        return logits, EncDecDecodeState(new_caches, state.cross_k, state.cross_v, pos + 1)
