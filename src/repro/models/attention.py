"""Grouped-query attention with RoPE, KV cache, and sliding-window variant.

Layouts:
  q:      (batch, seq, heads, head_dim)          heads sharded on "model"
  k/v:    (batch, seq, kv_heads, head_dim)       kv heads replicated (GQA)
  cache:  (batch, cache_len, kv_heads, head_dim) per layer-in-pattern

Decode writes one token at position ``pos`` (lockstep batch).  With
``sliding_window = W`` the cache is a rotating buffer of length W
(write slot = pos % W) -- this is the bounded-memory sub-quadratic
variant that makes long_500k decodable for full-attention archs.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import ArchConfig
from repro.sharding import constrain


class KVCache(NamedTuple):
    k: jnp.ndarray  # (batch, cache_len, kv_heads, head_dim)
    v: jnp.ndarray


def pad_head_mask(cfg: ArchConfig) -> jnp.ndarray | None:
    """Bool (padded_heads,) -- True for real heads, False for pad slots.

    GQA assigns heads to kv groups by contiguous blocks of size
    g = heads/kv_heads, so padding must happen at each group's TAIL
    (padding a flat tail would reshuffle the head->group mapping).
    """
    h, kv = cfg.padded_heads, cfg.num_kv_heads
    if h == cfg.num_heads:
        return None
    g_new = h // kv
    g_old = cfg.num_heads // kv
    return (jnp.arange(h) % g_new) < g_old


def init_attention(key, cfg: ArchConfig, dtype) -> dict:
    d, kv, hd = cfg.d_model, cfg.num_kv_heads, cfg.resolved_head_dim
    h = cfg.padded_heads
    ks = jax.random.split(key, 4)
    wq = common.init_dense(ks[0], (d, h, hd), dtype)
    wo = common.init_dense(ks[3], (h, hd, d), dtype)
    mask = pad_head_mask(cfg)
    if mask is not None:
        # zero the padded head slices: forward == the unpadded model
        wq = wq * mask[None, :, None].astype(dtype)
        wo = wo * mask[:, None, None].astype(dtype)
    p = {
        "wq": wq,
        "wk": common.init_dense(ks[1], (d, kv, hd), dtype),
        "wv": common.init_dense(ks[2], (d, kv, hd), dtype),
        "wo": wo,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kv, hd), dtype)
        p["bv"] = jnp.zeros((kv, hd), dtype)
    return p


def _project_qkv(p, x, cfg: ArchConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def _gqa_scores(q, k):
    """q: (b,s,H,hd), k: (b,t,KV,hd) -> scores (b,KV,G,s,t)."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k) / jnp.sqrt(hd).astype(jnp.float32)
    return scores


def _gqa_out(weights, v, p):
    """weights: (b,KV,G,s,t), v: (b,t,KV,hd) -> (b,s,d_model)."""
    b, kvh, g, s, _ = weights.shape
    hd = v.shape[-1]
    out = jnp.einsum("bkgst,btkh->bskgh", weights, v)
    out = out.reshape(b, s, kvh * g, hd)
    out = constrain(out, "batch", "seq", "heads", "head_dim")
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def attention_train(p, x, cfg: ArchConfig, *, cross_kv=None, causal: bool = True):
    """Full-sequence attention via blockwise (flash-style) accumulation.

    ``cross_kv=(k, v)`` switches to cross-attention (non-causal).
    """
    from repro.models.blockwise_attn import blockwise_attention

    b, s, _ = x.shape
    if cross_kv is None:
        q, k, v = _project_qkv(p, x, cfg)
        positions = jnp.arange(s)
        cos, sin = common.rope_freqs(positions, cfg.resolved_head_dim, cfg.rope_theta)
        q = common.apply_rope(q, cos, sin)
        k = common.apply_rope(k, cos, sin)
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        if cfg.qkv_bias:
            q = q + p["bq"]
        q = constrain(q, "batch", "seq", "heads", "head_dim")
        k, v = cross_kv
        causal = False

    h = q.shape[2]
    kvh = k.shape[2]
    qg = q.reshape(b, s, kvh, h // kvh, q.shape[-1])
    out = blockwise_attention(
        qg, k, v, causal=causal, sliding_window=cfg.sliding_window
    )
    out = out.reshape(b, s, h, q.shape[-1])
    out = constrain(out, "batch", "seq", "heads", "head_dim")
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


class KVCacheHM(NamedTuple):
    """Head-major decode cache: contraction-friendly layouts."""

    k_hm: jnp.ndarray  # (batch, kv_heads, head_dim, cache_len)
    v_hm: jnp.ndarray  # (batch, kv_heads, cache_len, head_dim)


class KVCacheHM8(NamedTuple):
    """Int8 head-major cache: symmetric per-token-per-head quantization.

    Scales are f32, one per written (head, position): the dequant is a
    rank-1 rescale of the score/output contractions, so the int8 cache
    is the ONLY large tensor read per step (SSPerf-B3).
    """

    k_hm: jnp.ndarray  # int8 (batch, kv_heads, head_dim, cache_len)
    v_hm: jnp.ndarray  # int8 (batch, kv_heads, cache_len, head_dim)
    k_scale: jnp.ndarray  # f32 (batch, kv_heads, 1, cache_len)
    v_scale: jnp.ndarray  # f32 (batch, kv_heads, cache_len, 1)


def init_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype):
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if cfg.decode_cache_layout == "head_major":
        if cfg.kv_cache_dtype == "int8":
            return KVCacheHM8(
                jnp.zeros((batch, kv, hd, cache_len), jnp.int8),
                jnp.zeros((batch, kv, cache_len, hd), jnp.int8),
                jnp.zeros((batch, kv, 1, cache_len), jnp.float32),
                jnp.zeros((batch, kv, cache_len, 1), jnp.float32),
            )
        return KVCacheHM(
            jnp.zeros((batch, kv, hd, cache_len), dtype),
            jnp.zeros((batch, kv, cache_len, hd), dtype),
        )
    shape = (batch, cache_len, kv, hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def _quantize_token(x, axis):
    """Symmetric int8 quantization along ``axis``: returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _decode_mask(pos, cache_len, window):
    idx = jnp.arange(cache_len)
    if window:
        # slot i holds absolute position: valid iff within last `window`
        # positions and <= pos.  (RoPE was applied at absolute positions
        # when written, so ordering is preserved.)
        age = (pos - idx) % cache_len
        return age < jnp.minimum(pos + 1, cache_len)
    return idx <= pos


def attention_decode(p, x, cache, pos, cfg: ArchConfig):
    """One-token decode.  x: (b, 1, d); pos: scalar int32 position.

    Returns (out (b,1,d), updated cache).  With sliding_window the
    cache length is the window and writes rotate.
    """
    q, k_new, v_new = _project_qkv(p, x, cfg)
    cos, sin = common.rope_freqs(pos[None], cfg.resolved_head_dim, cfg.rope_theta)
    q = common.apply_rope(q, cos[None], sin[None])
    k_new = common.apply_rope(k_new, cos[None], sin[None])
    window = cfg.sliding_window

    if isinstance(cache, KVCacheHM8):
        return _attention_decode_hm8(p, q, k_new, v_new, cache, pos, cfg)
    if isinstance(cache, KVCacheHM):
        return _attention_decode_hm(p, q, k_new, v_new, cache, pos, cfg)

    cache_len = cache.k.shape[1]
    slot = jnp.where(window > 0, pos % cache_len, pos) if window else pos
    k = jax.lax.dynamic_update_slice(cache.k, k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new, (0, slot, 0, 0))
    k = constrain(k, "batch", "cache_seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "cache_seq", "kv_heads", "head_dim")

    scores = _gqa_scores(q, k).astype(jnp.float32)  # (b,KV,G,1,cache_len)
    mask = _decode_mask(pos, cache_len, window)
    scores = jnp.where(mask[None, None, None, None, :], scores, jnp.finfo(jnp.float32).min)
    weights = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = _gqa_out(weights, v, p)
    return out, KVCache(k, v)


def _attention_decode_hm8(p, q, k_new, v_new, cache: KVCacheHM8, pos, cfg: ArchConfig):
    """Int8 head-major single-token decode (SSPerf-B3).

    Dequantization folds into the contractions as rank-1 rescales:
      scores = (q . k_q) * k_scale[pos],  out = (w * v_scale) . v_q.
    """
    b, _, h, hd = q.shape
    kvh = cache.k_hm.shape[1]
    g = h // kvh
    window = cfg.sliding_window
    cache_len = cache.k_hm.shape[-1]
    slot = jnp.where(window > 0, pos % cache_len, pos) if window else pos

    k_col, k_s = _quantize_token(k_new[:, 0][..., None], axis=2)  # (b,kv,hd,1)
    v_row, v_s = _quantize_token(
        jnp.transpose(v_new, (0, 2, 1, 3)), axis=3
    )  # (b,kv,1,hd)
    k = jax.lax.dynamic_update_slice(cache.k_hm, k_col, (0, 0, 0, slot))
    v = jax.lax.dynamic_update_slice(cache.v_hm, v_row, (0, 0, slot, 0))
    ks = jax.lax.dynamic_update_slice(cache.k_scale, k_s, (0, 0, 0, slot))
    vs = jax.lax.dynamic_update_slice(cache.v_scale, v_s, (0, 0, slot, 0))
    k = constrain(k, "batch", "kv_heads", "head_dim", "cache_seq")
    v = constrain(v, "batch", "kv_heads", "cache_seq", "head_dim")

    qg = q[:, 0].reshape(b, kvh, g, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgh,bkhL->bkgL", qg, k.astype(jnp.float32))
    scores = scores * ks[:, :, 0][:, :, None, :]  # rank-1 dequant
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    mask = _decode_mask(pos, cache_len, window)
    scores = jnp.where(mask[None, None, None, :], scores, jnp.finfo(jnp.float32).min)
    weights = jax.nn.softmax(scores, axis=-1)
    wv = weights * vs[:, :, None, :, 0]  # fold v scales into the weights
    out = jnp.einsum("bkgL,bkLh->bkgh", wv, v.astype(jnp.float32))
    out = out.reshape(b, 1, h, hd).astype(q.dtype)
    out = constrain(out, "batch", "seq", "heads", "head_dim")
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, KVCacheHM8(k, v, ks, vs)


def cross_attention_decode(p, x, ck, cv, cfg: ArchConfig):
    """Single-token cross-attention over a (possibly seq-sharded) memory.

    SSPerf-C: the blockwise (flash-style) path dynamically slices the
    source axis, which forces GSPMD to ALL-GATHER the whole cross K/V
    (4.3 GB/step for a 512k-frame memory).  A direct masked-softmax
    einsum chain keeps src sharded end to end: scores stay src-sharded,
    the softmax reduction and the output contraction become partial
    computations merged with KB-sized all-reduces.

    x: (b, 1, d); ck/cv: (b, src, kv, hd).  Non-causal (encoder memory).
    """
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    b, _, h, hd = q.shape
    kvh = ck.shape[2]
    g = h // kvh
    qg = q[:, 0].reshape(b, kvh, g, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, ck).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    weights = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgs,bskh->bkgh", weights, cv)
    out = out.reshape(b, 1, h, hd)
    out = constrain(out, "batch", "seq", "heads", "head_dim")
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def _attention_decode_hm(p, q, k_new, v_new, cache: KVCacheHM, pos, cfg: ArchConfig):
    """Head-major single-token decode (SSPerf-B iteration 2).

    The cache stores k as (b, kv, hd, L) and v as (b, kv, L, hd) --
    exactly the operand layouts of the two decode contractions, so the
    compiler never transposes/copies the full cache per step.  The s=1
    case is specialized away instead of batched through the generic
    5-d GQA path.
    """
    b, _, h, hd = q.shape
    kvh = cache.k_hm.shape[1]
    g = h // kvh
    window = cfg.sliding_window
    cache_len = cache.k_hm.shape[-1]
    slot = jnp.where(window > 0, pos % cache_len, pos) if window else pos

    # k_new/v_new: (b, 1, kv, hd) -> cache layouts
    k_col = k_new[:, 0][..., None]  # (b, kv, hd, 1)
    v_row = jnp.transpose(v_new, (0, 2, 1, 3))  # (b, kv, 1, hd)
    k = jax.lax.dynamic_update_slice(cache.k_hm, k_col, (0, 0, 0, slot))
    v = jax.lax.dynamic_update_slice(cache.v_hm, v_row, (0, 0, slot, 0))
    k = constrain(k, "batch", "kv_heads", "head_dim", "cache_seq")
    v = constrain(v, "batch", "kv_heads", "cache_seq", "head_dim")

    qg = q[:, 0].reshape(b, kvh, g, hd)
    scores = jnp.einsum("bkgh,bkhL->bkgL", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    mask = _decode_mask(pos, cache_len, window)
    scores = jnp.where(mask[None, None, None, :], scores, jnp.finfo(jnp.float32).min)
    weights = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgL,bkLh->bkgh", weights, v)
    out = out.reshape(b, 1, h, hd)
    out = constrain(out, "batch", "seq", "heads", "head_dim")
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, KVCacheHM(k, v)
