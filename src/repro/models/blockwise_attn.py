"""Blockwise (flash-style) attention in pure JAX.

Materializing (s, s) score matrices at 32k context is ~4 GB per head --
the classic memory wall.  This module computes exact softmax attention
with online (running max / denominator) accumulation over key chunks,
scanned per query chunk: peak live memory is O(q_chunk * k_chunk) per
head instead of O(s^2).

This is the TPU adaptation of FlashAttention's insight: on GPU the tiles
live in SRAM via a handwritten kernel; on TPU we express the same tiling
as lax.scan + MXU matmuls and let XLA keep tiles in VMEM.  The query-
chunk loop is a static Python loop (so the causal key-range bound per
chunk is static and the whole thing stays reverse-differentiable);
fully-masked key chunks are skipped by construction, so causal
attention does ~half the FLOPs -- visible in cost_analysis, exactly
like a real flash kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


NEG_INF = -1e30


def _chunk_sizes(s: int, t: int) -> tuple[int, int]:
    q_chunk = min(s, max(512, s // 32))
    k_chunk = min(t, 1024)
    # keep divisibility
    while s % q_chunk:
        q_chunk //= 2
    while t % k_chunk:
        k_chunk //= 2
    return max(q_chunk, 1), max(k_chunk, 1)


def blockwise_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool,
    sliding_window: int = 0,
    q_chunk: int = 0,
    k_chunk: int = 0,
) -> jnp.ndarray:
    """q: (b, s, KV, G, hd); k/v: (b, t, KV, hd) -> out (b, s, KV, G, hd).

    Exact softmax attention; 1/sqrt(hd) scale applied internally.
    """
    b, s, kvh, g, hd = q.shape
    t = k.shape[1]
    qc0, kc0 = _chunk_sizes(s, t)
    q_chunk = q_chunk or qc0
    k_chunk = k_chunk or kc0
    q_chunk, k_chunk = min(q_chunk, s), min(k_chunk, t)
    assert s % q_chunk == 0 and t % k_chunk == 0, (s, t, q_chunk, k_chunk)
    nq, nk = s // q_chunk, t // k_chunk
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    kr = k.reshape(b, nk, k_chunk, kvh, hd)
    vr = v.reshape(b, nk, k_chunk, kvh, hd)

    def make_kv_step(q_idx: int, qi):
        def kv_step(carry, kv_idx):
            acc, row_max, row_sum = carry
            kc = jax.lax.dynamic_index_in_dim(kr, kv_idx, axis=1, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vr, kv_idx, axis=1, keepdims=False)
            scores = (
                jnp.einsum("bqkgh,btkh->bkgqt", qi, kc).astype(jnp.float32) * scale
            )  # (b, kv, g, qc, kc)
            if causal or sliding_window:
                qpos = q_idx * q_chunk + jnp.arange(q_chunk)
                kpos = kv_idx * k_chunk + jnp.arange(k_chunk)
                mask = jnp.ones((q_chunk, k_chunk), bool)
                if causal:
                    mask &= kpos[None, :] <= qpos[:, None]
                if sliding_window:
                    mask &= kpos[None, :] > qpos[:, None] - sliding_window
                scores = jnp.where(mask[None, None, None], scores, NEG_INF)
            new_max = jnp.maximum(row_max, jnp.max(scores, axis=-1))
            correction = jnp.exp(row_max - new_max)
            p = jnp.exp(scores - new_max[..., None])
            new_sum = row_sum * correction + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqt,btkh->bkgqh", p.astype(v.dtype), vc)
            acc = acc * correction[..., None] + pv.astype(jnp.float32)
            return (acc, new_max, new_sum), None

        return kv_step

    outs = []
    for q_idx in range(nq):
        qi = jax.lax.slice_in_dim(q, q_idx * q_chunk, (q_idx + 1) * q_chunk, axis=1)
        acc0 = jnp.zeros((b, kvh, g, q_chunk, hd), jnp.float32)
        max0 = jnp.full((b, kvh, g, q_chunk), NEG_INF, jnp.float32)
        sum0 = jnp.zeros((b, kvh, g, q_chunk), jnp.float32)
        if causal:
            hi_pos = (q_idx + 1) * q_chunk
            lo_pos = max(0, q_idx * q_chunk - sliding_window) if sliding_window else 0
            kv_lo = lo_pos // k_chunk
            kv_hi = (hi_pos + k_chunk - 1) // k_chunk
        else:
            kv_lo, kv_hi = 0, nk
        carry, _ = jax.lax.scan(
            make_kv_step(q_idx, qi),
            (acc0, max0, sum0),
            jnp.arange(kv_lo, kv_hi),
        )
        acc, _, row_sum = carry
        out = acc / jnp.maximum(row_sum, 1e-30)[..., None]
        outs.append(jnp.transpose(out, (0, 3, 1, 2, 4)))  # (b, qc, kv, g, hd)
    return jnp.concatenate(outs, axis=1).astype(q.dtype)
