"""Build a model object from an ArchConfig."""

from __future__ import annotations

from repro.models.common import ArchConfig
from repro.models.encdec import EncDecModel
from repro.models.transformer import DecoderModel


def build_model(cfg: ArchConfig, remat: bool = True, unroll: bool = False):
    if cfg.encoder_layers:
        return EncDecModel(cfg, remat=remat, unroll=unroll)
    return DecoderModel(cfg, remat=remat, unroll=unroll)
