"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Runs real steps on the host devices (CPU container: use --smoke for the
reduced config; the full configs are exercised via the dry-run).  The
same step/sharding construction as the dry-run, so what trains here is
what lowers there.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint import save_checkpoint
from repro.data import tokens as token_data
from repro.launch import mesh as mesh_lib
from repro.launch import shardings as sh
from repro.launch import steps
from repro.optim import AdamWConfig, adamw_init
from repro.sharding.specs import DEFAULT_RULES, set_rules


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--data-parallel", type=int, default=None)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch)
    if args.smoke:
        cfg = configs.smoke_config(cfg)

    mesh = mesh_lib.make_host_mesh(data=args.data_parallel, model=args.model_parallel)
    rules = DEFAULT_RULES.replace(batch=("data",))
    set_rules(rules)

    model_key = jax.random.PRNGKey(args.seed)
    from repro.models import model_zoo

    model = model_zoo.build_model(cfg)
    opt_cfg = AdamWConfig(lr=args.lr)
    train_step = steps.make_train_step(cfg, opt_cfg, total_steps=args.steps)

    params_abs = steps.abstract_params(cfg)
    p_spec = sh.params_pspecs(params_abs, rules)
    p_sh = sh.to_named(mesh, p_spec)
    with mesh:
        params = jax.jit(model.init, out_shardings=p_sh)(model_key)
        opt_state = adamw_init(params)
        jstep = jax.jit(train_step, donate_argnums=(0, 1))

        stream = token_data.batch_stream(args.seed, args.batch, args.seq, cfg.vocab_size)
        t0 = time.time()
        for step, batch in enumerate(stream):
            if step >= args.steps:
                break
            if cfg.modality == "vision" and cfg.num_patches:
                batch["extra_embeds"] = jnp.zeros(
                    (args.batch, cfg.num_patches, cfg.d_model), cfg.activation_dtype
                )
            if cfg.modality == "audio":
                batch["frames"] = jnp.zeros(
                    (args.batch, args.seq, cfg.d_model), cfg.activation_dtype
                )
            params, opt_state, metrics = jstep(params, opt_state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                print(
                    f"step {step:5d} loss {loss:.4f} "
                    f"grad_norm {float(metrics['grad_norm']):.3f} "
                    f"({(time.time() - t0):.1f}s)"
                )
            if args.ckpt_dir and args.ckpt_every and step and step % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, step, {"params": params})
        if args.ckpt_dir:
            save_checkpoint(args.ckpt_dir, args.steps, {"params": params})
            print(f"saved checkpoint at step {args.steps} -> {args.ckpt_dir}")


if __name__ == "__main__":
    main()
