"""Launch layer: mesh construction, dry-run, and the serving driver."""
