"""Serving driver: batched prefill + greedy decode loop.

``python -m repro.launch.serve --arch <id> --smoke --batch 4 --prompt-len 16 --gen 16``
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch import steps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch)
    if args.smoke:
        cfg = configs.smoke_config(cfg)
    from repro.models import model_zoo
    from repro.models.encdec import EncDecModel

    model = model_zoo.build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    serve_step = jax.jit(steps.make_serve_step(cfg), donate_argnums=(1,))

    b = args.batch
    total = args.prompt_len + args.gen
    prompts = jax.random.randint(
        jax.random.fold_in(key, 1), (b, args.prompt_len), 0, cfg.vocab_size
    )
    if isinstance(model, EncDecModel):
        frames = jax.random.normal(
            jax.random.fold_in(key, 2), (b, args.prompt_len, cfg.d_model)
        ).astype(cfg.activation_dtype)
        memory = jax.jit(model.encode)(params, frames)
        state = model.init_decode_state(params, memory, total)
    else:
        state = model.init_decode_state(b, total)

    # prefill by stepping through the prompt (cache fill), then generate
    t0 = time.time()
    generated = []
    tok = prompts[:, :1]
    for i in range(total - 1):
        next_tok, logits, state = serve_step(params, state, tok)
        if i + 1 < args.prompt_len:
            tok = prompts[:, i + 1 : i + 2]
        else:
            tok = next_tok[:, None]
            generated.append(next_tok)
    gen = jnp.stack(generated, axis=1)
    dt = time.time() - t0
    print(f"generated {gen.shape} in {dt:.2f}s "
          f"({b * (total - 1) / dt:.1f} tok/s incl. prefill steps)")
    print("sample row 0:", gen[0][: min(16, gen.shape[1])].tolist())


if __name__ == "__main__":
    main()
