"""SLDA classify-as-a-service driver (DESIGN.md §12).

``python -m repro.launch.serve --smoke`` streams synthetic two-class
(or ``--classes K``) traffic through :class:`repro.core.streaming.
ServingRuntime`: every tick serves one batched query through the jit'd
hot path, ingests one (screened) data batch into the merged sufficient
statistics, and attempts a model refresh on its schedule.  Chaos flags
drive the deterministic :class:`ServeFaultSchedule` harness::

    python -m repro.launch.serve --smoke --chaos \\
        --corrupt-ingest 0.3 --diverge-refit 0.5 --drop-refresh 0.2

``--chaos`` asserts the graceful-degradation contract inline (finite
scores always; accuracy within the slack of a fault-free run) and
exits nonzero on violation.  ``--ckpt-dir`` snapshots every publish
and ends the run with a restore parity self-check; ``--unprotected``
runs the fragile baseline (no screening, no verdict, no staleness
accounting) for side-by-side degradation demos.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dantzig import DantzigConfig
from repro.core.pipeline import mc_suff_stats, suff_stats
from repro.core.streaming import (
    ServeFaultSchedule,
    ServingRuntime,
    corrupt_batch_arrays,
)
from repro.stats.synthetic import (
    make_mc_problem,
    make_problem,
    sample_labeled,
    sample_mc_machines,
    sample_two_class,
)


def _binary_stream(key, problem, n_seed, n_batch, n_query):
    """(seed_aux, per-tick (batch_aux, raw_arrays, queries, labels))."""
    k_seed, k_rest = jax.random.split(key)
    x, y = sample_two_class(k_seed, problem, n_seed, n_seed)

    def tick(k):
        k1, k2 = jax.random.split(k)
        bx, by = sample_two_class(k1, problem, n_batch, n_batch)
        z, lab = sample_labeled(k2, problem, n_query)
        return (bx, by), z, lab

    return suff_stats(x, y), k_rest, tick, lambda arrs: suff_stats(*arrs)


def _mc_stream(key, problem, classes, n_seed, n_batch, n_query):
    k_seed, k_rest = jax.random.split(key)
    xs, labs = sample_mc_machines(k_seed, problem, 1, n_seed * 2)

    def tick(k):
        k1, k2 = jax.random.split(k)
        bx, blab = sample_mc_machines(k1, problem, 1, n_batch * 2)
        z, lab = sample_mc_machines(k2, problem, 1, n_query)
        return (bx[0], blab[0]), z[0], lab[0]

    return (mc_suff_stats(xs[0], labs[0], classes), k_rest, tick,
            lambda arrs: mc_suff_stats(arrs[0], arrs[1], classes))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--d", type=int, default=60)
    ap.add_argument("--classes", type=int, default=2)
    ap.add_argument("--batch", type=int, default=256,
                    help="query batch size per tick")
    ap.add_argument("--ingest", type=int, default=60,
                    help="arriving data samples per class per tick")
    ap.add_argument("--ticks", type=int, default=24)
    ap.add_argument("--refit-every", type=int, default=2)
    ap.add_argument("--staleness-bound", type=int, default=2)
    ap.add_argument("--lam", type=float, default=0.1)
    ap.add_argument("--lam-prime", type=float, default=0.2)
    ap.add_argument("--threshold", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (overrides --d/--ticks/--batch)")
    ap.add_argument("--chaos", action="store_true",
                    help="assert the degradation contract inline")
    ap.add_argument("--acc-slack", type=float, default=0.02)
    ap.add_argument("--corrupt-ingest", type=float, default=0.0)
    ap.add_argument("--diverge-refit", type=float, default=0.0)
    ap.add_argument("--drop-refresh", type=float, default=0.0)
    ap.add_argument("--corrupt-mode", default="mix")
    ap.add_argument("--unprotected", action="store_true",
                    help="fragile baseline: no screening/verdict/staleness")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    if args.smoke:
        args.d, args.ticks, args.batch, args.ingest = 28, 10, 128, 40

    key = jax.random.PRNGKey(args.seed)
    if args.classes == 2:
        problem = make_problem(d=args.d, n_signal=max(4, args.d // 8),
                               rho=0.5)
        aux0, key, tick_fn, stats_fn = _binary_stream(
            key, problem, 4 * args.ingest, args.ingest, args.batch)
    else:
        # rho=0.5 matches the binary stream's conditioning: the AR(1)
        # default (0.8) needs a far larger ADMM budget at tol=1e-3
        problem = make_mc_problem(d=args.d, num_classes=args.classes,
                                  n_signal=max(4, args.d // 10), rho=0.5)
        aux0, key, tick_fn, stats_fn = _mc_stream(
            key, problem, args.classes, 4 * args.ingest, args.ingest,
            args.batch)

    cfg = DantzigConfig(tol=1e-3)
    rt = ServingRuntime(
        aux0, args.lam, args.lam_prime, args.threshold, cfg=cfg,
        staleness_bound=args.staleness_bound, protect=not args.unprotected,
        ckpt_dir=args.ckpt_dir)
    plan = ServeFaultSchedule(
        args.corrupt_ingest, args.diverge_refit, args.drop_refresh,
        args.corrupt_mode, args.seed).plan(args.ticks)

    # fault-free twin for the chaos contract: same stream, no faults
    ref_acc = None
    if args.chaos:
        ref = ServingRuntime(aux0, args.lam, args.lam_prime, args.threshold,
                             cfg=cfg, staleness_bound=args.staleness_bound)

    accs, statuses, quarantined, t_classify, served = [], [], 0, 0.0, 0
    ref_accs = []
    for t in range(args.ticks):
        key, kt = jax.random.split(key)
        raw, z, lab = tick_fn(kt)
        t0 = time.perf_counter()
        pred, scores = rt.classify(z)
        pred.block_until_ready()
        t_classify += time.perf_counter() - t0
        served += int(z.shape[0])
        finite = bool(np.isfinite(np.asarray(scores)).all())
        accs.append(float(jnp.mean(pred == lab)))
        statuses.append(rt.status)
        if args.chaos:
            ref_pred, _ = ref.classify(z)
            ref_accs.append(float(jnp.mean(ref_pred == lab)))
            if not finite:
                raise SystemExit(f"tick {t}: non-finite served scores")
        faulted = corrupt_batch_arrays(int(plan.corrupt[t]), raw)
        if not rt.ingest_batch(stats_fn(faulted), *faulted):
            quarantined += 1
        if (t + 1) % args.refit_every == 0:
            rt.refresh(drop=bool(plan.drop[t]),
                       inject_diverge=int(plan.diverge[t]))
            if args.chaos:
                ref.ingest_batch(stats_fn(raw), *raw)
                ref.refresh()

    qps = served / max(t_classify, 1e-9)
    counts = {s: statuses.count(s) for s in ("live", "stale", "degraded")}
    print(f"served {served} queries over {args.ticks} ticks "
          f"(d={args.d}, K={args.classes}, protect={not args.unprotected})")
    print(f"sustained qps (classify wall-clock only): {qps:,.0f}")
    print(f"mean accuracy: {np.mean(accs):.4f}  status counts: {counts}  "
          f"quarantined batches: {quarantined}  "
          f"model version: {int(rt.slot.version)}")
    ladder = [e["attempt"] for e in rt.ladder_log if not e["converged"]]
    if ladder:
        print(f"escalations past a failed rung: {ladder}")

    if args.chaos:
        ref_acc = float(np.mean(ref_accs))
        drop = ref_acc - float(np.mean(accs))
        print(f"fault-free twin accuracy: {ref_acc:.4f}  "
              f"(faulted run within {drop:+.4f})")
        if drop > args.acc_slack:
            raise SystemExit(
                f"degradation contract violated: accuracy dropped {drop:.4f} "
                f"> slack {args.acc_slack}")

    if args.ckpt_dir is not None:
        restored = ServingRuntime.restore(
            args.ckpt_dir, aux0, args.lam, args.lam_prime, args.threshold,
            cfg=cfg, staleness_bound=args.staleness_bound)
        key, kq = jax.random.split(key)
        _, z, lab = tick_fn(kq)
        p_live, _ = rt.classify(z)
        p_rest, _ = restored.classify(z)
        if int(restored.slot.version) == int(rt.slot.version) and not bool(
                jnp.all(p_live == p_rest)):
            raise SystemExit("restore parity violated: same slot version, "
                             "different predictions")
        print(f"checkpoint restore OK (version {int(restored.slot.version)})")


if __name__ == "__main__":
    main()
