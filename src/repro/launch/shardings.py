"""Pytree -> PartitionSpec derivation for params, optimizer and decode state.

Params are matched by (parent module, leaf name, rank); decode-state
leaves by field name.  Everything resolves through the logical-axis
rules table in repro.sharding.specs, so flipping a rule (e.g.
expert: None -> "model" for expert-parallel MoE, or cache_seq ->
("data", "model") for context-parallel long decode) re-shards the whole
system consistently -- that is the §Perf iteration knob.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.sharding.specs import ShardingRules


# (parent, name) -> logical axes (without the stacked repeats dim)
_PARAM_AXES: dict[tuple[str, str], tuple[str | None, ...]] = {
    # embeddings
    ("", "embedding"): ("vocab", "embed"),
    ("", "unembed"): ("vocab", "embed"),
    ("", "patch_proj"): (None, None),
    # attention
    ("attn", "wq"): ("embed", "heads", "head_dim"),
    ("attn", "wk"): ("embed", "kv_heads", "head_dim"),
    ("attn", "wv"): ("embed", "kv_heads", "head_dim"),
    ("attn", "wo"): ("heads", "head_dim", "embed"),
    ("attn", "bq"): ("heads", "head_dim"),
    ("attn", "bk"): ("kv_heads", "head_dim"),
    ("attn", "bv"): ("kv_heads", "head_dim"),
    # dense mlp (also the MoE shared expert)
    ("mlp", "w_gate"): ("embed", "mlp"),
    ("mlp", "w_up"): ("embed", "mlp"),
    ("mlp", "w_down"): ("mlp", "embed"),
    ("shared", "w_gate"): ("embed", "mlp"),
    ("shared", "w_up"): ("embed", "mlp"),
    ("shared", "w_down"): ("mlp", "embed"),
    # MoE experts
    ("moe", "router"): ("embed", "expert"),
    ("moe", "w_gate"): ("expert", "embed", "expert_mlp"),
    ("moe", "w_up"): ("expert", "embed", "expert_mlp"),
    ("moe", "w_down"): ("expert", "expert_mlp", "embed"),
    # mamba
    ("mamba", "in_proj"): ("embed", "ssm_inner"),
    ("mamba", "conv_w"): (None, "ssm_inner"),
    ("mamba", "conv_b"): ("ssm_inner",),
    ("mamba", "w_dt"): ("ssm_inner", None),
    ("mamba", "w_dt_up"): (None, "ssm_inner"),
    ("mamba", "dt_bias"): ("ssm_inner",),
    ("mamba", "w_bc"): ("ssm_inner", None),
    ("mamba", "a_log"): ("ssm_inner", None),
    ("mamba", "d_skip"): ("ssm_inner",),
    ("mamba", "out_proj"): ("ssm_inner", "embed"),
    # xLSTM mLSTM (head-structured; dk/dv shard over "model" -- SSPerf-E)
    ("mlstm", "w_up"): ("embed", "ssm_inner"),
    ("mlstm", "w_gate"): (None, None, "xlstm_dk"),
    ("mlstm", "w_q"): (None, None, "xlstm_dk"),
    ("mlstm", "w_k"): (None, None, "xlstm_dk"),
    ("mlstm", "w_v"): (None, None, "xlstm_dk"),
    ("mlstm", "w_if"): ("ssm_inner", None, None),
    ("mlstm", "b_if"): (None, None),
    ("mlstm", "w_down"): (None, "xlstm_dk", None),
    # xLSTM sLSTM
    ("slstm", "w_up"): ("embed", "ssm_inner"),
    ("slstm", "w_gates"): ("ssm_inner", None, None),
    ("slstm", "r_gates"): (None, None, None),
    ("slstm", "b_gates"): (None, None),
    ("slstm", "w_down"): ("ssm_inner", "embed"),
    # cross attention (enc-dec) reuses attention names under cross_attn /
    # self_attn parents -- handled by fallback below.
}

_ATTN_ALIASES = {"self_attn": "attn", "cross_attn": "attn"}


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
    return out


def param_spec(path, leaf, rules: ShardingRules) -> P:
    names = _path_names(path)
    name = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    parent = _ATTN_ALIASES.get(parent, parent)
    stacked = any(n in ("layers", "enc_layers", "dec_layers") for n in names)
    key = (parent, name)
    if key not in _PARAM_AXES:
        key = ("", name) if ("", name) in _PARAM_AXES else None
    if key is None:
        # norms, biases, anything unlisted: replicated
        axes: tuple[str | None, ...] = (None,) * (leaf.ndim - (1 if stacked else 0))
    else:
        axes = _PARAM_AXES[key]
    if stacked:
        axes = (None,) + tuple(axes)
    assert len(axes) == leaf.ndim, f"{names}: axes {axes} vs shape {leaf.shape}"
    return rules.spec(axes)


def params_pspecs(abstract_params, rules: ShardingRules):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(path, leaf, rules), abstract_params
    )


# decode state: by leaf field name; leading dim is the stacked repeats axis
# for everything under "caches"/cross tensors of the enc-dec state.
# keys are the leaf field name, optionally suffixed with its ndim to
# disambiguate (mLSTM "c" is 5-d with the stacked repeats axis; sLSTM
# "c" is 4-d).
_STATE_AXES: dict[str, tuple[str | None, ...]] = {
    "k": (None, "batch", "cache_seq", "kv_heads", "head_dim"),
    "v": (None, "batch", "cache_seq", "kv_heads", "head_dim"),
    # head-major decode cache (SSPerf-B): seq is the contraction-minor dim
    "k_hm": (None, "batch", "kv_heads", "head_dim", "cache_seq"),
    "v_hm": (None, "batch", "kv_heads", "cache_seq", "head_dim"),
    # int8 cache scales (SSPerf-B3)
    "k_scale": (None, "batch", "kv_heads", None, "cache_seq"),
    "v_scale": (None, "batch", "kv_heads", "cache_seq", None),
    # xLSTM states (SSPerf-D): dk (the q/k feature dim) shards on
    # "model" for decode -- mLSTM c:(r,b,h,dk,dv), n:(r,b,h,dk);
    # sLSTM c/n/h/m:(r,b,h,hd) share the dk rule.
    "c/5": (None, "batch", None, "xlstm_dk", None),
    "c/4": (None, "batch", None, "xlstm_dk"),
    "n/4": (None, "batch", None, "xlstm_dk"),
    "h/4": (None, "batch", None, "xlstm_dk"),
    "m/4": (None, "batch", None, "xlstm_dk"),
    "conv_buf": (None, "batch", None, "ssm_inner"),
    "ssm_h": (None, "batch", "ssm_inner", None),
    "cross_k": (None, "batch", "cache_seq", "kv_heads", "head_dim"),
    "cross_v": (None, "batch", "cache_seq", "kv_heads", "head_dim"),
}


def state_spec(path, leaf, rules: ShardingRules) -> P:
    names = _path_names(path)
    name = names[-1]
    if name == "pos":
        return P()
    axes = _STATE_AXES.get(f"{name}/{leaf.ndim}", _STATE_AXES.get(name))
    if axes is None or len(axes) != leaf.ndim:
        # xLSTM states (c, n, h, m): batch-sharded, heads/dims replicated
        axes = (None, "batch") + (None,) * (leaf.ndim - 2)
    return rules.spec(axes)


def state_pspecs(abstract_state, rules: ShardingRules):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: state_spec(path, leaf, rules), abstract_state
    )


def zero1_pspecs(mesh, abstract_params, rules: ShardingRules):
    """ZeRO-1 optimizer-state specs: params' specs + data-axis sharding.

    Each moment tensor additionally shards its first still-unsharded
    dim that divides the data-axis size over ("pod","data") -- the
    standard optimizer-state sharding (MaxText/ZeRO-1).  GSPMD then
    reduce-scatters the gradients into the shard and all-gathers
    updated params, trading a little collective traffic for an
    optimizer-state footprint / |data| reduction.
    """
    data_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    size = 1
    for a in data_axes:
        size *= mesh.shape[a]

    def spec_fn(path, leaf):
        base = param_spec(path, leaf, rules)
        parts = list(base) + [None] * (leaf.ndim - len(base))
        for i, (pt, dim) in enumerate(zip(parts, leaf.shape)):
            if pt is None and dim >= size and dim % size == 0:
                parts[i] = data_axes if len(data_axes) > 1 else data_axes[0]
                break
        return P(*parts)

    return jax.tree_util.tree_map_with_path(spec_fn, abstract_params)


def to_named(mesh, pspecs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )
