"""Step functions (train / prefill / decode) + abstract input specs.

Everything here works on ShapeDtypeStructs (dry-run) and on real arrays
(training/serving drivers, smoke tests).

Decode shapes lower ``serve_step`` -- ONE new token against a
``seq_len`` KV cache.  ``long_500k`` swaps full attention for the
sliding-window variant on every attention-bearing arch (window 8192)
and shards the window cache over ("data", "model") -- SSM/hybrid archs
carry O(1) recurrent state natively.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import model_zoo
from repro.models.common import ArchConfig
from repro.models.encdec import EncDecModel
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_warmup
from repro.sharding.specs import DEFAULT_RULES, ShardingRules


class ShapeDef(NamedTuple):
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: dict[str, ShapeDef] = {
    "train_4k": ShapeDef("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeDef("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeDef("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeDef("long_500k", 524_288, 1, "decode"),
}

LONG_CONTEXT_WINDOW = 8_192


def has_attention(cfg: ArchConfig) -> bool:
    return any(k.startswith("attn") for k in cfg.pattern) or cfg.encoder_layers > 0


def arch_for_shape(cfg: ArchConfig, shape: ShapeDef) -> ArchConfig:
    """Shape-conditioned arch variant (sliding window for long decode)."""
    if shape.name == "long_500k" and has_attention(cfg):
        return dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def rules_for(
    cfg: ArchConfig, shape: ShapeDef, mesh_axes: tuple[str, ...]
) -> ShardingRules:
    """Shape-conditioned logical->physical rules for a given mesh."""
    batch_axes = tuple(a for a in mesh_axes if a in ("pod", "data"))
    rules = DEFAULT_RULES.replace(batch=batch_axes)
    if cfg.expert_sharding == "ep":
        rules = rules.replace(expert="model", expert_mlp=None)
    if shape.name == "long_500k":
        # batch=1: context-parallel the rotating KV window instead
        rules = rules.replace(batch=None, cache_seq=batch_axes + ("model",))
    elif shape.kind == "decode":
        # SSPerf-B: the model axis is otherwise idle for the KV cache;
        # sharding cache_seq over it cuts the dominant memory term ~6x
        # (granite decode_32k: 0.413s -> 0.067s).
        rules = rules.replace(cache_seq=("model",))
    return rules


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def make_train_step(
    cfg: ArchConfig, opt_cfg: AdamWConfig = AdamWConfig(), total_steps: int = 10_000,
    unroll: bool = False, warmup_steps: int = 200, microbatches: int = 1,
) -> Callable:
    """Build the jit-able train step.

    ``microbatches > 1`` runs gradient accumulation: the global batch is
    split into M sequential microbatches inside one step (lax.scan), so
    the live activation footprint (the remat window) shrinks ~M x while
    the optimizer math and data-axis collectives are unchanged per step
    (SSPerf-F2).
    """
    model = model_zoo.build_model(cfg, unroll=unroll)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
                params, batch
            )
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                (l, met), g = jax.value_and_grad(model.loss, has_aux=True)(
                    params, mb
                )
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), met

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss_sum), mets = jax.lax.scan(acc_step, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), mets)
        lr_scale = cosine_warmup(opt_state.step, warmup_steps, total_steps)
        params, opt_state, om = adamw_update(
            params, grads, opt_state, opt_cfg, lr_scale
        )
        return params, opt_state, {"loss": loss, **metrics, **om}

    return train_step


def make_prefill_step(cfg: ArchConfig, unroll: bool = False) -> Callable:
    model = model_zoo.build_model(cfg, unroll=unroll)

    def prefill_step(params, batch):
        if isinstance(model, EncDecModel):
            logits, _ = model.forward(params, batch["tokens"], batch["frames"])
        else:
            logits, _ = model.forward(
                params, batch["tokens"], batch.get("extra_embeds")
            )
        return logits[:, -1, :]  # next-token logits (serving prefill output)

    return prefill_step


def make_serve_step(cfg: ArchConfig, unroll: bool = False) -> Callable:
    model = model_zoo.build_model(cfg, unroll=unroll)

    def serve_step(params, state, tokens):
        logits, state = model.decode_step(params, state, tokens)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok, logits, state

    return serve_step


# ---------------------------------------------------------------------------
# abstract specs (ShapeDtypeStruct stand-ins; zero allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ArchConfig, shape: ShapeDef, with_labels: bool) -> dict:
    b, s = shape.global_batch, shape.seq_len
    i32, act = jnp.int32, cfg.activation_dtype
    specs: dict = {}
    if cfg.modality == "audio":
        specs["frames"] = _sds((b, s, cfg.d_model), act)
        specs["tokens"] = _sds((b, s), i32)
    elif cfg.modality == "vision" and cfg.num_patches:
        specs["extra_embeds"] = _sds((b, cfg.num_patches, cfg.d_model), act)
        specs["tokens"] = _sds((b, s - cfg.num_patches), i32)
    else:
        specs["tokens"] = _sds((b, s), i32)
    if with_labels:
        specs["labels"] = _sds(specs["tokens"].shape, i32)
    return specs


def abstract_params(cfg: ArchConfig):
    model = model_zoo.build_model(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def abstract_opt_state(params_abs):
    return jax.eval_shape(adamw_init, params_abs)


def abstract_decode_state(cfg: ArchConfig, shape: ShapeDef):
    model = model_zoo.build_model(cfg)
    b, s = shape.global_batch, shape.seq_len
    if isinstance(model, EncDecModel):
        params_abs = abstract_params(cfg)
        memory = _sds((b, s, cfg.d_model), cfg.activation_dtype)
        return jax.eval_shape(
            lambda p, m: model.init_decode_state(p, m, s), params_abs, memory
        )
    return jax.eval_shape(lambda: model.init_decode_state(b, s))


def input_specs(cfg: ArchConfig, shape_name: str) -> dict:
    """All abstract inputs for the step lowered by this (arch, shape)."""
    shape = INPUT_SHAPES[shape_name]
    cfg = arch_for_shape(cfg, shape)
    params_abs = abstract_params(cfg)
    if shape.kind == "train":
        return {
            "params": params_abs,
            "opt_state": abstract_opt_state(params_abs),
            "batch": batch_specs(cfg, shape, with_labels=True),
        }
    if shape.kind == "prefill":
        return {"params": params_abs, "batch": batch_specs(cfg, shape, with_labels=False)}
    return {
        "params": params_abs,
        "state": abstract_decode_state(cfg, shape),
        "tokens": _sds((shape.global_batch, 1), jnp.int32),
    }
