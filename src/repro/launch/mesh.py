"""Production mesh construction.

Kept as functions (never module-level constants) so importing this
module never touches jax device state -- tests import it with 1 CPU
device, the dry-run with 512 forced host devices.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 chips per pod; 2 pods when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests, examples)."""
    n = jax.device_count()
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis_names(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def data_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    return tuple(ax for ax in mesh.axis_names if ax in ("pod", "data"))
