import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax (device count is now locked) ---------
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core.dantzig import DantzigConfig  # noqa: E402
from repro.core.distributed import distributed_slda_shardmap  # noqa: E402
from repro.launch import mesh as mesh_lib  # noqa: E402

"""Dry-run of the paper's technique on the production mesh.

Lowers Algorithm 1 (the one-shot distributed sparse-LDA estimator) via
shard_map on the 16x16 / 2x16x16 meshes with abstract inputs and
extracts the roofline terms.  This is the baseline/optimized pair
tracked in EXPERIMENTS.md SSPerf-A.

Machines = data slices (16 per pod x pods); CLIME columns sharded over
the 16-wide model axis.
"""

# TPU v5e constants (target hardware; container runtime is CPU)
PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(segment):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective byte totals from a compiled (post-SPMD) HLO dump.

    Sums the *result* shape bytes of every collective op in the
    per-device module -- i.e. bytes landing on each chip's ICI.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        lhs, _, rhs = stripped.partition("=")
        op = None
        rhs_head = rhs.strip()
        for c in _COLLECTIVES:
            if rhs_head.startswith(c + "(") or rhs_head.split(" ", 2)[:2][-1:] == [c]:
                op = c
                break
            # result shape precedes op name: "bf16[..] all-gather(...)"
            m = re.match(r"[\w\[\],{}\s/#*()]*?\b" + re.escape(c) + r"\(", rhs_head)
            if m:
                op = c
                break
        if op is None:
            continue
        # shapes appear on the rhs before the op name
        head = rhs_head.split(op + "(")[0]
        nbytes = _shape_bytes(head) or _shape_bytes(lhs)
        out[op] += nbytes
        counts[op] += 1
    return {"bytes": out, "counts": counts, "total_bytes": sum(out.values())}


def _compile_costs(d, n_machines, n1, multi_pod, iters, variant):
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    data_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    # "fused" variant: whole ADMM solve inside the VMEM-resident Pallas
    # kernel (SSPerf-A2); fixed rho, no per-column adaptation.
    cfg = DantzigConfig(max_iters=iters, fused=(variant == "fused"),
                        adapt_rho=(variant != "fused"))
    x_abs = jax.ShapeDtypeStruct((n_machines * n1, d), jnp.float32)
    y_abs = jax.ShapeDtypeStruct((n_machines * n1, d), jnp.float32)
    in_sh = NamedSharding(mesh, P(data_axes, None))

    def fn(x, y):
        return distributed_slda_shardmap(
            mesh, x, y, 0.05, 0.05, 0.01, cfg, data_axes=data_axes,
            model_axis="model",
        )

    with mesh:
        lowered = jax.jit(fn, in_shardings=(in_sh, in_sh),
                          out_shardings=NamedSharding(mesh, P())).lower(x_abs, y_abs)
        compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older JAX returns a 1-elem list
        ca = ca[0] if ca else {}
    coll = collective_bytes(compiled.as_text())
    return (float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0)),
            float(coll["total_bytes"]), coll, compiled)


def run_one(d: int, n_per_machine: int, multi_pod: bool, max_iters: int,
            out_dir: str | None, tag: str = "", variant: str = "baseline"):
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    data_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    n_machines = 1
    for a in data_axes:
        n_machines *= mesh.shape[a]
    n1 = n_per_machine // 2

    t0 = time.time()
    # XLA cost analysis counts the ADMM scan body once; extrapolate the
    # per-iteration delta from 1- vs 2-iteration lowers.
    f1, b1, c1, _, _ = _compile_costs(d, n_machines, n1, multi_pod, 1, variant)
    f2, b2, c2, coll, compiled = _compile_costs(d, n_machines, n1, multi_pod, 2, variant)
    flops = f1 + (max_iters - 1) * (f2 - f1)
    nbytes = b1 + (max_iters - 1) * (b2 - b1)
    cbytes = c1 + (max_iters - 1) * (c2 - c1)
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    print(mem)
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": nbytes / HBM_BW,
        "collective_s": cbytes / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    # the paper's communication budget: ONE d-vector per machine
    paper_bytes = 4 * d
    result = {
        "arch": "slda-core",
        "variant": variant,
        "d": d,
        "n_per_machine": n_per_machine,
        "machines": n_machines,
        "max_iters": max_iters,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "flops_per_device": flops,
        "bytes_per_device": nbytes,
        "collective_bytes_per_device": cbytes,
        "collectives": coll,
        "paper_uplink_bytes": paper_bytes,
        **terms,
        "dominant": dominant,
        "compile_s": t_compile,
    }
    print(f"[dryrun-slda] d={d} n={n_per_machine} {result['mesh']} {variant}: "
          f"compute={terms['compute_s']:.3e}s memory={terms['memory_s']:.3e}s "
          f"collective={terms['collective_s']:.3e}s dominant={dominant} "
          f"coll_bytes={cbytes:.3e} (compile {t_compile:.0f}s)")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        fname = f"slda-core_d{d}_{result['mesh']}_{variant}{suffix}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(result, f, indent=1, default=str)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--d", type=int, default=256)
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--iters", type=int, default=500)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun_slda")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for mp in meshes:
        run_one(args.d, args.n, mp, args.iters, args.out, args.tag, args.variant)


if __name__ == "__main__":
    main()
