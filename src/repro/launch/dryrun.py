import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax (device count is now locked) ---------
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs  # noqa: E402
from repro.launch import mesh as mesh_lib  # noqa: E402
from repro.launch import shardings as sh  # noqa: E402
from repro.launch import steps  # noqa: E402
from repro.sharding.specs import set_rules  # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) with
ShapeDtypeStruct inputs (zero allocation) and extract the roofline terms.

Proves the distribution config is coherent: sharding mismatches, OOM at
compile, or unsupported collectives all fail here.
"""

# TPU v5e constants (target hardware; container runtime is CPU)
PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(segment):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective byte totals from a compiled (post-SPMD) HLO dump.

    Sums the *result* shape bytes of every collective op in the
    per-device module -- i.e. bytes landing on each chip's ICI.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        lhs, _, rhs = stripped.partition("=")
        op = None
        rhs_head = rhs.strip()
        for c in _COLLECTIVES:
            if rhs_head.startswith(c + "(") or rhs_head.split(" ", 2)[:2][-1:] == [c]:
                op = c
                break
            # result shape precedes op name: "bf16[..] all-gather(...)"
            m = re.match(r"[\w\[\],{}\s/#*()]*?\b" + re.escape(c) + r"\(", rhs_head)
            if m:
                op = c
                break
        if op is None:
            continue
        # shapes appear on the rhs before the op name
        head = rhs_head.split(op + "(")[0]
        nbytes = _shape_bytes(head) or _shape_bytes(lhs)
        out[op] += nbytes
        counts[op] += 1
    return {"bytes": out, "counts": counts, "total_bytes": sum(out.values())}


def model_flops(cfg, shape: steps.ShapeDef) -> float:
    """6 N_active D (train) / 2 N_active D (inference), global."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token / row


def _batch_pspecs(batch_abs, rules):
    def spec(path, leaf):
        name = sh._path_names(path)[-1]
        if name in ("frames", "extra_embeds"):
            return rules.spec(("batch", None, None))
        return rules.spec(("batch", None))

    return jax.tree_util.tree_map_with_path(spec, batch_abs)


def build_lowerable(arch_name: str, shape_name: str, mesh, *, expert_sharding=None,
                    rules_override=None, repeats: int | None = None,
                    zero1: bool = False, microbatches: int = 1,
                    cfg_overrides: dict | None = None):
    """Returns (fn, args_abs, in_shardings, out_shardings, cfg, shape).

    ``repeats`` overrides the depth (used by the scan-cost correction:
    XLA cost analysis counts a while body once, so we lower 1- and
    2-repeat variants and extrapolate the per-repeat delta).
    """
    shape = steps.INPUT_SHAPES[shape_name]
    cfg = configs.get_config(arch_name)
    if expert_sharding:
        cfg = dataclasses.replace(cfg, expert_sharding=expert_sharding)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    cfg = steps.arch_for_shape(cfg, shape)
    unroll = repeats is not None
    if unroll:
        cfg = dataclasses.replace(
            cfg,
            num_layers=len(cfg.pattern) * repeats,
            encoder_layers=repeats if cfg.encoder_layers else 0,
        )
    rules = steps.rules_for(cfg, shape, tuple(mesh.axis_names))
    if rules_override:
        rules = rules.replace(**rules_override)
    set_rules(rules)

    params_abs = steps.abstract_params(cfg)
    named = lambda tree: sh.to_named(mesh, tree)
    p_spec = sh.params_pspecs(params_abs, rules)

    if shape.kind == "train":
        opt_abs = steps.abstract_opt_state(params_abs)
        # optimizer moments mirror the param shardings; step is replicated.
        # zero1 additionally shards each moment over the data axes
        # (ZeRO-1 optimizer-state sharding).
        moment_spec = (sh.zero1_pspecs(mesh, opt_abs.mu, rules) if zero1
                       else sh.params_pspecs(opt_abs.mu, rules))
        o_spec = type(opt_abs)(step=P(), mu=moment_spec, nu=moment_spec)
        batch_abs = steps.batch_specs(cfg, shape, with_labels=True)
        b_spec = _batch_pspecs(batch_abs, rules)
        fn = steps.make_train_step(cfg, unroll=unroll, microbatches=microbatches)
        args = (params_abs, opt_abs, batch_abs)
        in_sh = (named(p_spec), named(o_spec), named(b_spec))
        metrics_abs = jax.eval_shape(fn, *args)[2]
        metrics_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), metrics_abs)
        out_sh = (named(p_spec), named(o_spec), metrics_sh)
        donate = (0, 1)
    elif shape.kind == "prefill":
        batch_abs = steps.batch_specs(cfg, shape, with_labels=False)
        b_spec = _batch_pspecs(batch_abs, rules)
        fn = steps.make_prefill_step(cfg, unroll=unroll)
        args = (params_abs, batch_abs)
        in_sh = (named(p_spec), named(b_spec))
        out_sh = NamedSharding(mesh, rules.spec(("batch", "vocab")))
        donate = ()
    else:  # decode
        state_abs = steps.abstract_decode_state(cfg, shape)
        s_spec = sh.state_pspecs(state_abs, rules)
        tok_abs = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        fn = steps.make_serve_step(cfg, unroll=unroll)
        args = (params_abs, state_abs, tok_abs)
        in_sh = (
            named(p_spec),
            named(s_spec),
            NamedSharding(mesh, rules.spec(("batch", None))),
        )
        out_sh = (
            NamedSharding(mesh, rules.spec(("batch",))),
            NamedSharding(mesh, rules.spec(("batch", None, "vocab"))),
            named(s_spec),
        )
        donate = (1,)
    return fn, args, in_sh, out_sh, donate, cfg, shape


def _compile_costs(arch_name, shape_name, mesh, repeats, **kw):
    """(flops, bytes, collective_bytes, collectives_detail) for one lower."""
    fn, args, in_sh, out_sh, donate, cfg, shape = build_lowerable(
        arch_name, shape_name, mesh, repeats=repeats, **kw
    )
    jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                  donate_argnums=donate)
    with mesh:
        compiled = jfn.lower(*args).compile()
    ca = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return (float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0)),
            float(coll["total_bytes"]), coll)


def run_one(arch_name: str, shape_name: str, multi_pod: bool, out_dir: str | None,
            expert_sharding=None, rules_override=None, tag="",
            scan_correction: bool = True, zero1: bool = False,
            microbatches: int = 1, cfg_overrides: dict | None = None):
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    chips = len(mesh.devices.reshape(-1))
    t0 = time.time()
    kw = dict(expert_sharding=expert_sharding, rules_override=rules_override,
              zero1=zero1, microbatches=microbatches, cfg_overrides=cfg_overrides)
    fn, args, in_sh, out_sh, donate, cfg, shape = build_lowerable(
        arch_name, shape_name, mesh, **kw
    )
    jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                  donate_argnums=donate)
    with mesh:
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    print(mem)  # proves it fits
    ca = compiled.cost_analysis() or {}
    print({k: v for k, v in ca.items() if k in ("flops", "bytes accessed")})
    coll = collective_bytes(compiled.as_text())

    flops_dev = float(ca.get("flops", 0.0))
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    coll_dev = float(coll["total_bytes"])
    if scan_correction:
        # XLA cost analysis counts a while (scan) body ONCE; extrapolate
        # per-repeat costs from 1- and 2-repeat lowers of the same step.
        f1, b1, c1, _ = _compile_costs(arch_name, shape_name, mesh, 1, **kw)
        f2, b2, c2, _ = _compile_costs(arch_name, shape_name, mesh, 2, **kw)
        r = cfg.num_repeats if not cfg.encoder_layers else cfg.num_layers
        flops_dev = f1 + (r - 1) * (f2 - f1)
        bytes_dev = b1 + (r - 1) * (b2 - b1)
        coll_dev = c1 + (r - 1) * (c2 - c1)
    mf = model_flops(cfg, shape)
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll_dev / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    result = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "tag": tag,
        "chips": chips,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "collectives": coll,
        "model_flops_global": mf,
        "model_flops_per_device": mf / chips,
        "useful_flops_ratio": (mf / chips) / flops_dev if flops_dev else 0.0,
        **terms,
        "dominant": dominant,
        "memory_analysis": _mem_dict(mem),
        "lower_s": t_lower,
        "compile_s": t_compile,
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        fname = f"{arch_name}_{shape_name}_{result['mesh']}{suffix}.json".replace("/", "-")
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(result, f, indent=1, default=str)
    print(
        f"[dryrun] {arch_name} x {shape_name} x {result['mesh']}{(' ' + tag) if tag else ''}: "
        f"compute={terms['compute_s']:.3e}s memory={terms['memory_s']:.3e}s "
        f"collective={terms['collective_s']:.3e}s dominant={dominant} "
        f"useful={result['useful_flops_ratio']:.2f} "
        f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)"
    )
    return result


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        if hasattr(mem, attr):
            try:
                out[attr] = int(getattr(mem, attr))
            except Exception:
                pass
    return out


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(steps.INPUT_SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--expert-sharding", default=None, choices=[None, "tp", "ep"])
    ap.add_argument("--zero1", action="store_true",
                    help="shard optimizer moments over the data axes (ZeRO-1)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    combos = []
    archs = configs.list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(steps.INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for a in archs:
        for s in shapes:
            for m in meshes:
                combos.append((a, s, m))

    failures = []
    for a, s, m in combos:
        try:
            run_one(a, s, m, args.out, expert_sharding=args.expert_sharding,
                    tag=args.tag, zero1=args.zero1)
        except Exception as e:  # noqa: BLE001
            failures.append((a, s, m, repr(e)))
            traceback.print_exc()
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print(f"[dryrun] all {len(combos)} combos compiled OK")


if __name__ == "__main__":
    main()
