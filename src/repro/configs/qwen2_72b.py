"""qwen2-72b [arXiv:2407.10671].

80L, d_model 8192, 64 heads (GQA kv=8), d_ff 29568, vocab 152064, QKV bias.
"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    pattern=("attn",),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    citation="arXiv:2407.10671",
)
