"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4-Scout-17B-16E family].

48L, d_model 5120, 40 heads (GQA kv=8), expert d_ff 8192, vocab 202048.
MoE 128 experts top-1 with a shared expert on alternating layers
(interleaved dense/MoE).  Early-fusion multimodality is supported via
the extra_embeds path; assigned input shapes are text-token streams.
"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    # 40 q-heads are not divisible by the 16-wide model axis; pad to 48
    # with zero-initialized pad heads (see ArchConfig.pad_heads_to).
    pad_heads_to=48,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    pattern=("attn", "attn_moe"),
    num_experts=128,
    experts_per_token=1,
    shared_expert=True,
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
)
