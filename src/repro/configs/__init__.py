"""Architecture config registry (``--arch <id>`` lookup).

Module filenames are sanitized ids (dots/dashes -> underscores); the
registry keys are the literal assigned ids.
"""

from __future__ import annotations

import dataclasses

from repro.models.common import ArchConfig

from repro.configs import (  # noqa: E402
    phi3_5_moe_42b_a6_6b,
    llava_next_mistral_7b,
    qwen2_5_3b,
    qwen2_72b,
    seamless_m4t_large_v2,
    jamba_v0_1_52b,
    mistral_large_123b,
    llama4_maverick_400b_a17b,
    granite_8b,
    xlstm_1_3b,
    paper_synthetic,
)

REGISTRY: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        phi3_5_moe_42b_a6_6b,
        llava_next_mistral_7b,
        qwen2_5_3b,
        qwen2_72b,
        seamless_m4t_large_v2,
        jamba_v0_1_52b,
        mistral_large_123b,
        llama4_maverick_400b_a17b,
        granite_8b,
        xlstm_1_3b,
    )
}

PAPER_SYNTHETIC = paper_synthetic


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def list_archs() -> list[str]:
    return sorted(REGISTRY)


_SMOKE_PATTERNS = {
    # cover each block kind with <= 2 pattern entries
    ("attn",): ("attn", "attn"),
    ("attn_moe",): ("attn_moe", "attn_moe"),
    ("attn", "attn_moe"): ("attn", "attn_moe"),
}


def smoke_config(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family variant: <=2-entry pattern x1 repeat,
    d_model <= 512, <= 4 experts (assignment's smoke-test contract)."""
    pattern = cfg.pattern
    if pattern in _SMOKE_PATTERNS:
        pattern = _SMOKE_PATTERNS[pattern]
    else:
        # keep one of each distinct kind, order-preserved, max 2
        seen: list[str] = []
        for k in pattern:
            if k not in seen:
                seen.append(k)
        pattern = tuple(seen[:2]) if len(seen) > 1 else (seen[0], seen[0])
    num_heads = min(cfg.num_heads, 4)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=len(pattern),
        pattern=pattern,
        d_model=256,
        num_heads=num_heads,
        num_kv_heads=min(cfg.num_kv_heads, max(1, num_heads // 2)),
        pad_heads_to=0,  # no model axis to pad for in smoke tests
        head_dim=64,
        d_ff=512 if cfg.d_ff else 0,
        vocab_size=512,
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.num_experts else 0,
        encoder_layers=min(cfg.encoder_layers, 2),
        num_patches=min(cfg.num_patches, 8),
        ssm_chunk=32,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
        dtype="float32",
    )
