"""Experiment configs for the paper's own studies.

The seed scaffold's LLM architecture registry (``--arch`` lookup over
ten transformer/SSM/MoE configs) was dead weight for this repository --
nothing on the paper's reproduction path ever consumed it -- and was
deleted; the reachability rule in :mod:`repro.analysis.imports` keeps
it from growing back.  What remains is the paper's section-5
experimental grid (:mod:`repro.configs.paper_synthetic`).
"""

from __future__ import annotations

from repro.configs import paper_synthetic
from repro.configs.paper_synthetic import (  # noqa: F401
    FIXED_N,
    REAL,
    SYNTHETIC,
    FixedNConfig,
    RealDataConfig,
    SyntheticConfig,
)

PAPER_SYNTHETIC = paper_synthetic

__all__ = [
    "FIXED_N",
    "FixedNConfig",
    "PAPER_SYNTHETIC",
    "REAL",
    "RealDataConfig",
    "SYNTHETIC",
    "SyntheticConfig",
    "paper_synthetic",
]
