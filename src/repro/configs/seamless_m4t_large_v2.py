"""seamless-m4t-large-v2 [arXiv:2308.11596].

Encoder-decoder (24 encoder + 24 decoder layers), d_model 1024, 16 MHA
heads (kv=16), d_ff 8192, vocab 256206 (padded to 256256 for the
16-wide model axis).  The speech frontend (mel + conformer conv) is a
stub per the carve-out: input_specs supplies (b, frames, d_model)
precomputed frame embeddings.
"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    pattern=("attn",),
    modality="audio",
    citation="arXiv:2308.11596",
)
