"""jamba-v0.1-52b [arXiv:2403.19887].

32L hybrid: attention:mamba = 1:7 interleave, MoE (16 experts, top-2)
on every other layer.  Period-8 pattern with 1 attention layer and 4
MoE FFNs, matching the published ratio.
"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    pattern=(
        "mamba_mlp",
        "mamba_moe",
        "mamba_mlp",
        "mamba_moe",
        "attn_moe",
        "mamba_mlp",
        "mamba_moe",
        "mamba_mlp",
    ),
    num_experts=16,
    experts_per_token=2,
    ssm_expand=2,
    ssm_state=16,
    citation="arXiv:2403.19887",
)
