"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407].

88L, d_model 12288, 96 heads (GQA kv=8), d_ff 28672, vocab 32768.
"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="mistral-large-123b",
    family="dense",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=32768,
    pattern=("attn",),
    citation="hf:mistralai/Mistral-Large-Instruct-2407",
)
