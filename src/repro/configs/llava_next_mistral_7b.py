"""llava-next-mistral-7b [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Mistral-7B language backbone consuming anyres-tiled patch embeddings.
Vision tower + projector are a stub per the assignment carve-out:
input_specs supplies (b, 2880, d_model) precomputed patch embeddings
(5 tiles x 576 patches).
"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    pattern=("attn",),
    modality="vision",
    num_patches=2880,
    citation="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
