"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct].

32L, d_model 4096, 32 heads (GQA kv=8), d_ff 6400, vocab 32064,
MoE 16 experts top-2 on every layer.
"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    pattern=("attn_moe",),
    num_experts=16,
    experts_per_token=2,
    citation="hf:microsoft/Phi-3.5-MoE-instruct",
)
