"""xlstm-1.3b [arXiv:2405.04517].

48 blocks, d_model 2048, 4 heads, no separate FFN (d_ff = 0; the
mLSTM/sLSTM blocks contain their own projections).  7:1
mLSTM:sLSTM interleave.
"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    pattern=("mlstm",) * 7 + ("slstm",),
    xlstm_proj_factor=2.0,
    citation="arXiv:2405.04517",
)
