"""granite-8b [arXiv:2405.04324] -- llama-architecture code model.

36L, d_model 4096, 32 heads (GQA kv=8), d_ff 14336, vocab 49152.
"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    pattern=("attn",),
    citation="arXiv:2405.04324",
)
