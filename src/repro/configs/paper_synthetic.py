"""The paper's own experimental configs (section 5)."""

from typing import NamedTuple


class SyntheticConfig(NamedTuple):
    d: int = 200
    rho: float = 0.8
    n_signal: int = 10
    N: int = 10_000
    r: float = 0.5  # n1 / n
    machines: tuple = (1, 5, 10, 20, 50, 100)
    repeats: int = 20


class FixedNConfig(NamedTuple):
    d: int = 200
    rho: float = 0.8
    n_signal: int = 10
    n_per_machine: int = 200
    machines: tuple = (1, 5, 10, 20, 50)
    repeats: int = 20


class RealDataConfig(NamedTuple):
    """UCI Heart-Disease surrogate (offline container; see DESIGN.md)."""

    n: int = 920
    d: int = 22
    sites: int = 4
    repeats: int = 10


SYNTHETIC = SyntheticConfig()
FIXED_N = FixedNConfig()
REAL = RealDataConfig()
