"""qwen2.5-3b [hf:Qwen/Qwen2.5-0.5B family].

36L, d_model 2048, 16 heads (GQA kv=2), d_ff 11008, vocab 151936,
QKV bias, tied embeddings.
"""

from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    d_ff=11008,
    vocab_size=151936,
    pattern=("attn",),
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    citation="hf:Qwen/Qwen2.5-0.5B",
)
