"""AST-based import-graph rules: the structural pins, grep-proofed.

The old pins in ``tests/test_pipeline_parity.py`` regex-scanned source
text, so a comment mentioning ``lax.all_gather(`` or a renamed alias
could flip them either way.  These rules walk the parsed AST instead:
imports are resolved through their aliases and calls through attribute
chains, so only real code can satisfy or violate a rule.
"""

from __future__ import annotations

import ast
import os
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Tuple

from repro.analysis.contracts import Violation

# .../src/repro/analysis/imports.py -> .../src
SRC_ROOT = Path(__file__).resolve().parents[2]


def iter_modules(src_root: Optional[Path] = None) -> Iterator[Tuple[str, Path]]:
    """Yield (dotted module name, path) for every .py file under src."""
    root = Path(src_root) if src_root is not None else SRC_ROOT
    for dirpath, _, files in sorted(os.walk(root)):
        for fname in sorted(files):
            if not fname.endswith(".py"):
                continue
            path = Path(dirpath) / fname
            rel = path.relative_to(root).with_suffix("")
            parts = list(rel.parts)
            if parts[-1] == "__init__":
                parts = parts[:-1]
            yield ".".join(parts), path


def _parse(path: Path) -> ast.Module:
    return ast.parse(path.read_text(), filename=str(path))


def _attr_chain(node: ast.AST) -> Optional[str]:
    """``repro.core.dantzig.solve_dantzig`` -> that dotted string."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _module_aliases(tree: ast.Module, module: str) -> Dict[str, str]:
    """Local names bound to ``module`` (e.g. ``dantzig``, ``dz``)."""
    aliases: Dict[str, str] = {}
    parent, _, leaf = module.rpartition(".")
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == module:
                    # `import repro.core.dantzig` binds `repro`; the full
                    # dotted chain is matched separately in _name_uses.
                    if a.asname:
                        aliases[a.asname] = module
        elif isinstance(node, ast.ImportFrom):
            if node.module == parent:
                for a in node.names:
                    if a.name == leaf:
                        aliases[a.asname or a.name] = module
    return aliases


def _site(path: Path, node: ast.AST) -> Tuple[str, ...]:
    lineno = getattr(node, "lineno", "?")
    return (f"{path}:{lineno}",)


def banned_import_violations(
    src_root: Optional[Path] = None,
    *,
    from_module: str = "repro.core.dantzig",
    name_prefix: str = "solve_dantzig",
    allowed: Tuple[str, ...] = ("repro.core.solver_dispatch",
                               "repro.core.dantzig"),
) -> List[Violation]:
    """Only the dispatch layer may reach ``from_module``'s solver entries.

    Flags ``from repro.core.dantzig import solve_dantzig*`` and any
    attribute use ``<alias>.solve_dantzig*`` where the alias (or the full
    dotted chain) resolves to the banned module.
    """
    rule = f"imports[{from_module}.{name_prefix}* only via {allowed}]"
    violations: List[Violation] = []
    for mod, path in iter_modules(src_root):
        if mod in allowed or not mod:
            continue
        tree = _parse(path)
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module == from_module:
                for a in node.names:
                    if a.name.startswith(name_prefix):
                        violations.append(Violation(
                            rule,
                            f"{mod} imports {a.name} from {from_module}, "
                            f"bypassing the dispatch layer",
                            _site(path, node),
                        ))
        aliases = _module_aliases(tree, from_module)
        for node in ast.walk(tree):
            if not isinstance(node, ast.Attribute):
                continue
            if not node.attr.startswith(name_prefix):
                continue
            base = _attr_chain(node.value)
            if base in aliases or base == from_module:
                violations.append(Violation(
                    rule,
                    f"{mod} calls {base}.{node.attr}, bypassing the "
                    f"dispatch layer",
                    _site(path, node),
                ))
    return violations


def exclusive_call_violations(
    src_root: Optional[Path] = None,
    *,
    func_name: str = "all_gather",
    allowed: Tuple[str, ...] = ("repro.core.pipeline",
                                "repro.core.compression",
                                "repro.core.faults"),
) -> List[Violation]:
    """A function may only be *called* from the allowed modules.

    Matches both ``all_gather(...)`` and any attribute call ending in
    ``.all_gather(...)`` (``jax.lax.all_gather``, ``lax.all_gather``).
    The three allowed sites are the pipeline's intra-machine
    sharded-CLIME gather, the compressed-uplink sparse aggregation of
    :mod:`repro.core.compression`, and the fault layer's machine-stack
    gather (:func:`repro.core.faults.gather_machines`, feeding the
    trimmed mean) -- every other module must route through one of them.
    """
    rule = f"imports[{func_name}() only in {allowed}]"
    violations: List[Violation] = []
    for mod, path in iter_modules(src_root):
        if mod in allowed or not mod:
            continue
        for node in ast.walk(_parse(path)):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            hit = (isinstance(fn, ast.Name) and fn.id == func_name) or (
                isinstance(fn, ast.Attribute) and fn.attr == func_name)
            if hit:
                violations.append(Violation(
                    rule,
                    f"{mod} calls {func_name}(); the sharded gather "
                    f"logic lives only in {', '.join(allowed)}",
                    _site(path, node),
                ))
    return violations


def _imports_module(tree: ast.Module, module: str) -> bool:
    parent, _, leaf = module.rpartition(".")
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            if any(a.name == module or a.name.startswith(module + ".")
                   for a in node.names):
                return True
        elif isinstance(node, ast.ImportFrom):
            if node.module == module:
                return True
            if node.module == parent and any(a.name == leaf
                                             for a in node.names):
                return True
    return False


def _referenced_names(tree: ast.Module) -> set:
    names = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            names.add(node.id)
        elif isinstance(node, ast.Attribute):
            names.add(node.attr)
            chain = _attr_chain(node)
            if chain:
                names.add(chain)
    return names


def pipeline_unification_violations(
    src_root: Optional[Path] = None,
) -> List[Violation]:
    """slda, distributed and multiclass all route through core/pipeline --
    directly (worker_debiased / debias) or via the rounds core
    (worker_rounds / simulate_multi_round), which itself is thin over
    pipeline.worker_solves + pipeline.apply_correction."""
    rule = "imports[single pipeline implementation]"
    root = Path(src_root) if src_root is not None else SRC_ROOT
    violations: List[Violation] = []
    entry_names = {"worker_debiased", "debias", "worker_rounds",
                   "simulate_multi_round"}
    for leaf in ("slda", "distributed", "multiclass"):
        mod = f"repro.core.{leaf}"
        path = root / "repro" / "core" / f"{leaf}.py"
        tree = _parse(path)
        if not (_imports_module(tree, "repro.core.pipeline")
                or _imports_module(tree, "repro.core.rounds")):
            violations.append(Violation(
                rule, f"{mod} does not import the pipeline/rounds core",
                (str(path),),
            ))
        if not (entry_names & _referenced_names(tree)):
            violations.append(Violation(
                rule,
                f"{mod} never calls a pipeline entry point "
                f"({sorted(entry_names)})",
                (str(path),),
            ))
    rounds_path = root / "repro" / "core" / "rounds.py"
    rounds_names = _referenced_names(_parse(rounds_path))
    for needed in ("pipeline.worker_solves", "pipeline.apply_correction"):
        if needed not in rounds_names:
            violations.append(Violation(
                rule,
                f"repro.core.rounds no longer routes through {needed}",
                (str(rounds_path),),
            ))
    return violations


def structural_violations(src_root: Optional[Path] = None) -> List[Violation]:
    """All repo import-graph rules (the former grep pins)."""
    return (
        banned_import_violations(src_root)
        + exclusive_call_violations(src_root)
        + pipeline_unification_violations(src_root)
    )


__all__ = [
    "SRC_ROOT",
    "banned_import_violations",
    "exclusive_call_violations",
    "iter_modules",
    "pipeline_unification_violations",
    "structural_violations",
]
