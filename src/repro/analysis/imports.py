"""AST-based import-graph rules: the structural pins, grep-proofed.

The old pins in ``tests/test_pipeline_parity.py`` regex-scanned source
text, so a comment mentioning ``lax.all_gather(`` or a renamed alias
could flip them either way.  These rules walk the parsed AST instead:
imports are resolved through their aliases and calls through attribute
chains, so only real code can satisfy or violate a rule.
"""

from __future__ import annotations

import ast
import os
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Tuple

from repro.analysis.contracts import Violation

# .../src/repro/analysis/imports.py -> .../src
SRC_ROOT = Path(__file__).resolve().parents[2]


def iter_modules(src_root: Optional[Path] = None) -> Iterator[Tuple[str, Path]]:
    """Yield (dotted module name, path) for every .py file under src."""
    root = Path(src_root) if src_root is not None else SRC_ROOT
    for dirpath, _, files in sorted(os.walk(root)):
        for fname in sorted(files):
            if not fname.endswith(".py"):
                continue
            path = Path(dirpath) / fname
            rel = path.relative_to(root).with_suffix("")
            parts = list(rel.parts)
            if parts[-1] == "__init__":
                parts = parts[:-1]
            yield ".".join(parts), path


def _parse(path: Path) -> ast.Module:
    return ast.parse(path.read_text(), filename=str(path))


def _attr_chain(node: ast.AST) -> Optional[str]:
    """``repro.core.dantzig.solve_dantzig`` -> that dotted string."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _module_aliases(tree: ast.Module, module: str) -> Dict[str, str]:
    """Local names bound to ``module`` (e.g. ``dantzig``, ``dz``)."""
    aliases: Dict[str, str] = {}
    parent, _, leaf = module.rpartition(".")
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == module:
                    # `import repro.core.dantzig` binds `repro`; the full
                    # dotted chain is matched separately in _name_uses.
                    if a.asname:
                        aliases[a.asname] = module
        elif isinstance(node, ast.ImportFrom):
            if node.module == parent:
                for a in node.names:
                    if a.name == leaf:
                        aliases[a.asname or a.name] = module
    return aliases


def _site(path: Path, node: ast.AST) -> Tuple[str, ...]:
    lineno = getattr(node, "lineno", "?")
    return (f"{path}:{lineno}",)


def banned_import_violations(
    src_root: Optional[Path] = None,
    *,
    from_module: str = "repro.core.dantzig",
    name_prefix: str = "solve_dantzig",
    allowed: Tuple[str, ...] = ("repro.core.solver_dispatch",
                               "repro.core.dantzig"),
) -> List[Violation]:
    """Only the dispatch layer may reach ``from_module``'s solver entries.

    Flags ``from repro.core.dantzig import solve_dantzig*`` and any
    attribute use ``<alias>.solve_dantzig*`` where the alias (or the full
    dotted chain) resolves to the banned module.
    """
    rule = f"imports[{from_module}.{name_prefix}* only via {allowed}]"
    violations: List[Violation] = []
    for mod, path in iter_modules(src_root):
        if mod in allowed or not mod:
            continue
        tree = _parse(path)
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module == from_module:
                for a in node.names:
                    if a.name.startswith(name_prefix):
                        violations.append(Violation(
                            rule,
                            f"{mod} imports {a.name} from {from_module}, "
                            f"bypassing the dispatch layer",
                            _site(path, node),
                        ))
        aliases = _module_aliases(tree, from_module)
        for node in ast.walk(tree):
            if not isinstance(node, ast.Attribute):
                continue
            if not node.attr.startswith(name_prefix):
                continue
            base = _attr_chain(node.value)
            if base in aliases or base == from_module:
                violations.append(Violation(
                    rule,
                    f"{mod} calls {base}.{node.attr}, bypassing the "
                    f"dispatch layer",
                    _site(path, node),
                ))
    return violations


def exclusive_call_violations(
    src_root: Optional[Path] = None,
    *,
    func_name: str = "all_gather",
    allowed: Tuple[str, ...] = ("repro.core.pipeline",
                                "repro.core.compression",
                                "repro.core.faults"),
) -> List[Violation]:
    """A function may only be *called* from the allowed modules.

    Matches both ``all_gather(...)`` and any attribute call ending in
    ``.all_gather(...)`` (``jax.lax.all_gather``, ``lax.all_gather``).
    The three allowed sites are the pipeline's intra-machine
    sharded-CLIME gather, the compressed-uplink sparse aggregation of
    :mod:`repro.core.compression`, and the fault layer's machine-stack
    gather (:func:`repro.core.faults.gather_machines`, feeding the
    trimmed mean) -- every other module must route through one of them.
    """
    rule = f"imports[{func_name}() only in {allowed}]"
    violations: List[Violation] = []
    for mod, path in iter_modules(src_root):
        if mod in allowed or not mod:
            continue
        for node in ast.walk(_parse(path)):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            hit = (isinstance(fn, ast.Name) and fn.id == func_name) or (
                isinstance(fn, ast.Attribute) and fn.attr == func_name)
            if hit:
                violations.append(Violation(
                    rule,
                    f"{mod} calls {func_name}(); the sharded gather "
                    f"logic lives only in {', '.join(allowed)}",
                    _site(path, node),
                ))
    return violations


def _imports_module(tree: ast.Module, module: str) -> bool:
    parent, _, leaf = module.rpartition(".")
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            if any(a.name == module or a.name.startswith(module + ".")
                   for a in node.names):
                return True
        elif isinstance(node, ast.ImportFrom):
            if node.module == module:
                return True
            if node.module == parent and any(a.name == leaf
                                             for a in node.names):
                return True
    return False


def _referenced_names(tree: ast.Module) -> set:
    names = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            names.add(node.id)
        elif isinstance(node, ast.Attribute):
            names.add(node.attr)
            chain = _attr_chain(node)
            if chain:
                names.add(chain)
    return names


def pipeline_unification_violations(
    src_root: Optional[Path] = None,
) -> List[Violation]:
    """slda, distributed and multiclass all route through core/pipeline --
    directly (worker_debiased / debias) or via the rounds core
    (worker_rounds / simulate_multi_round), which itself is thin over
    pipeline.worker_solves + pipeline.apply_correction."""
    rule = "imports[single pipeline implementation]"
    root = Path(src_root) if src_root is not None else SRC_ROOT
    violations: List[Violation] = []
    entry_names = {"worker_debiased", "debias", "worker_rounds",
                   "simulate_multi_round"}
    for leaf in ("slda", "distributed", "multiclass"):
        mod = f"repro.core.{leaf}"
        path = root / "repro" / "core" / f"{leaf}.py"
        tree = _parse(path)
        if not (_imports_module(tree, "repro.core.pipeline")
                or _imports_module(tree, "repro.core.rounds")):
            violations.append(Violation(
                rule, f"{mod} does not import the pipeline/rounds core",
                (str(path),),
            ))
        if not (entry_names & _referenced_names(tree)):
            violations.append(Violation(
                rule,
                f"{mod} never calls a pipeline entry point "
                f"({sorted(entry_names)})",
                (str(path),),
            ))
    rounds_path = root / "repro" / "core" / "rounds.py"
    rounds_names = _referenced_names(_parse(rounds_path))
    for needed in ("pipeline.worker_solves", "pipeline.apply_correction"):
        if needed not in rounds_names:
            violations.append(Violation(
                rule,
                f"repro.core.rounds no longer routes through {needed}",
                (str(rounds_path),),
            ))
    return violations


# ---------------------------------------------------------------------------
# reachability: no module may exist that the repo's entry points cannot reach
# ---------------------------------------------------------------------------

#: The repo's real surfaces.  The seed scaffold's LLM stack (models/,
#: optim/, sharding/, data/, launch.train, ...) was deleted in favour of
#: this rule: any src module unreachable from these roots -- via the
#: import graph, ``python -m`` mains included -- is dead weight and a
#: violation, so a dead subsystem cannot silently grow back.
ENTRY_POINTS: Tuple[str, ...] = (
    "repro.core",          # the paper's estimator (library surface)
    "repro.launch.serve",  # the serving driver
    "repro.analysis",      # trace-contract lint + this module
)

#: Out-of-tree script roots whose repro imports also seed reachability.
SCRIPT_DIRS: Tuple[str, ...] = ("benchmarks",)


def _repro_imports(tree: ast.Module, mod: str, known: set) -> set:
    """Resolved ``repro.*`` module names imported by ``tree``.

    ``from repro.core import transport`` yields both ``repro.core`` and
    ``repro.core.transport`` (when the latter is a known module, not an
    attribute); relative imports resolve against ``mod``'s package.
    """
    out: set = set()
    pkg_parts = mod.split(".")[:-1] if mod else []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "repro" or a.name.startswith("repro."):
                    out.add(a.name)
        elif isinstance(node, ast.ImportFrom):
            base = node.module or ""
            if node.level:  # relative: resolve against this package
                anchor = pkg_parts[: len(pkg_parts) - (node.level - 1)]
                base = ".".join(anchor + ([base] if base else []))
            if not (base == "repro" or base.startswith("repro.")):
                continue
            out.add(base)
            for a in node.names:
                sub = f"{base}.{a.name}"
                if sub in known:
                    out.add(sub)
    return out


def unreachable_module_violations(
    src_root: Optional[Path] = None,
    *,
    entry_points: Tuple[str, ...] = ENTRY_POINTS,
    script_dirs: Tuple[str, ...] = SCRIPT_DIRS,
) -> List[Violation]:
    """Every src module must be import-reachable from an entry point.

    Roots are (a) the modules under :data:`ENTRY_POINTS` (prefix match:
    ``repro.core`` seeds the whole package surface), (b) any module with
    a ``python -m`` main guard, and (c) whatever the script dirs
    (benchmarks/) import.  Importing ``repro.core.dantzig`` also marks
    its ancestor packages reachable (their ``__init__`` executes).
    """
    rule = f"imports[reachable from {entry_points + script_dirs}]"
    root = Path(src_root) if src_root is not None else SRC_ROOT
    modules = dict(iter_modules(root))
    trees = {mod: _parse(path) for mod, path in modules.items() if mod}
    known = set(trees)

    def expand(name: str) -> set:
        """A module plus every ancestor package that exists."""
        parts = name.split(".")
        return {".".join(parts[:i]) for i in range(1, len(parts) + 1)} & known

    roots: set = set()
    for mod, tree in trees.items():
        if any(mod == e or mod.startswith(e + ".") for e in entry_points):
            roots |= expand(mod)
        elif any(isinstance(n, ast.If) and isinstance(n.test, ast.Compare)
                 and isinstance(n.test.left, ast.Name)
                 and n.test.left.id == "__name__"
                 for n in tree.body):
            roots |= expand(mod)  # `python -m` target
    for d in script_dirs:
        script_dir = root.parent / d
        if not script_dir.is_dir():
            continue
        for script in sorted(script_dir.glob("*.py")):
            for imp in _repro_imports(_parse(script), "", known):
                roots |= expand(imp)

    reachable: set = set()
    frontier = list(roots)
    while frontier:
        mod = frontier.pop()
        if mod in reachable:
            continue
        reachable.add(mod)
        for imp in _repro_imports(trees[mod], mod, known):
            for hit in expand(imp):
                if hit not in reachable:
                    frontier.append(hit)

    return [
        Violation(
            rule,
            f"{mod} is unreachable from every entry point "
            f"({', '.join(entry_points)}) and script dir "
            f"({', '.join(script_dirs)}/) -- dead code; delete it or "
            f"wire it to a surface",
            (str(modules[mod]),),
        )
        for mod in sorted(known - reachable)
    ]


def structural_violations(src_root: Optional[Path] = None) -> List[Violation]:
    """All repo import-graph rules (the former grep pins)."""
    return (
        banned_import_violations(src_root)
        + exclusive_call_violations(src_root)
        + pipeline_unification_violations(src_root)
        + unreachable_module_violations(src_root)
    )


__all__ = [
    "ENTRY_POINTS",
    "SCRIPT_DIRS",
    "SRC_ROOT",
    "banned_import_violations",
    "exclusive_call_violations",
    "iter_modules",
    "pipeline_unification_violations",
    "structural_violations",
    "unreachable_module_violations",
]
