"""Contract types checked against traced jaxprs.

Each contract is a small declarative object with a ``check(jaxpr, params)``
method returning :class:`Violation` records that carry the offending eqn
path.  Numeric fields accept either a literal or :class:`Param`, a named
placeholder resolved against the per-case params dict at check time --
that is how "T rounds means T psums" stays declarative at the decoration
site while the sweep supplies T.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple, Union

import numpy as np

from repro.analysis import walker

# NOTE: jax itself is imported lazily (inside check methods) so that
# `python -m repro.analysis.lint` can force the host device count
# before jax initializes.


class Violation(NamedTuple):
    """One contract breach, with the located eqn paths that triggered it."""

    contract: str
    message: str
    sites: Tuple[str, ...] = ()

    def render(self) -> str:
        lines = [f"{self.contract}: {self.message}"]
        lines.extend(f"    at {s}" for s in self.sites)
        return "\n".join(lines)


class Param(NamedTuple):
    """Placeholder resolved against the case params dict at check time."""

    key: str


class MissingParam(KeyError):
    pass


def resolve(value, params):
    if isinstance(value, Param):
        if not params or value.key not in params:
            raise MissingParam(value.key)
        return params[value.key]
    return value


def _fmt(sites) -> Tuple[str, ...]:
    return tuple(walker.format_site(s) for s in sites)


IntOrParam = Union[int, Param]
ShapeOrParam = Union[Tuple[int, ...], Param]


class PrimitiveBudget(NamedTuple):
    """Bound the number of occurrences of one primitive in the whole trace.

    ``exact`` pins the count; ``max_count``/``min_count`` bound it.  The
    optional ``out_shape`` matcher restricts counting to eqns producing an
    output of that shape (the old rounds-test filter, now standard).
    """

    prim: str
    exact: Optional[IntOrParam] = None
    max_count: Optional[IntOrParam] = None
    min_count: Optional[IntOrParam] = None
    out_shape: Optional[ShapeOrParam] = None

    def describe(self) -> str:
        parts = []
        if self.exact is not None:
            parts.append(f"=={self.exact}")
        if self.max_count is not None:
            parts.append(f"<={self.max_count}")
        if self.min_count is not None:
            parts.append(f">={self.min_count}")
        shape = f" @{self.out_shape}" if self.out_shape is not None else ""
        return f"budget[{self.prim}{shape} {' '.join(parts) or 'any'}]"

    def check(self, jaxpr, params=None) -> list:
        out_shape = resolve(self.out_shape, params)
        sites = walker.find_eqns(jaxpr, self.prim, out_shape)
        n = len(sites)
        violations = []

        def fail(expected: str):
            violations.append(Violation(
                self.describe(),
                f"found {n} `{self.prim}` eqns, expected {expected}",
                _fmt(sites),
            ))

        exact = resolve(self.exact, params)
        if exact is not None and n != exact:
            fail(f"exactly {exact}")
        max_count = resolve(self.max_count, params)
        if max_count is not None and n > max_count:
            fail(f"at most {max_count}")
        min_count = resolve(self.min_count, params)
        if min_count is not None and n < min_count:
            fail(f"at least {min_count}")
        return violations


def _eqn_axes(eqn) -> Tuple[str, ...]:
    """Named mesh axes a collective eqn reduces/gathers over."""
    axes = eqn.params.get("axes", None)
    if axes is None:
        axes = eqn.params.get("axis_name", ())
    if isinstance(axes, str):
        axes = (axes,)
    return tuple(a for a in axes if isinstance(a, str))


class CollectiveContract(NamedTuple):
    """Pin a collective's count AND its payload shape/dtype per mesh axis.

    The per-round O(d*K) uplink becomes an asserted fact: ``count``
    matching eqns must exist (after the ``shape`` payload filter), and
    every one of them must reduce over ``axis`` and carry ``dtype``.
    """

    prim: str  # "psum" | "all_gather"
    count: IntOrParam
    axis: Optional[str] = None
    shape: Optional[ShapeOrParam] = None
    dtype: Optional[str] = None

    def describe(self) -> str:
        bits = [f"x{self.count}"]
        if self.axis:
            bits.append(f"axis={self.axis}")
        if self.shape is not None:
            bits.append(f"payload={self.shape}")
        if self.dtype:
            bits.append(self.dtype)
        return f"collective[{self.prim} {' '.join(bits)}]"

    def check(self, jaxpr, params=None) -> list:
        shape = resolve(self.shape, params)
        sites = walker.find_eqns(jaxpr, self.prim, shape)
        if self.axis is not None:
            # count only the axis's own collectives: a trace may hold
            # BOTH data-axis and model-axis gathers under separate
            # contracts (the compressed rounds path does)
            sites = [s for s in sites if self.axis in _eqn_axes(s.eqn)]
        count = resolve(self.count, params)
        violations = []
        if len(sites) != count:
            payload = f" with payload {tuple(shape)}" if shape is not None else ""
            axis = f" on axis '{self.axis}'" if self.axis is not None else ""
            violations.append(Violation(
                self.describe(),
                f"found {len(sites)} `{self.prim}` eqns{payload}{axis}, "
                f"expected exactly {count}",
                _fmt(sites),
            ))
        for site in sites:
            if self.dtype is not None:
                want = np.dtype(self.dtype)
                bad = [v for v in site.eqn.outvars
                       if getattr(v.aval, "dtype", want) != want]
                if bad:
                    got = {str(v.aval.dtype) for v in bad}
                    violations.append(Violation(
                        self.describe(),
                        f"`{self.prim}` payload dtype {sorted(got)}, "
                        f"contract requires {want}",
                        _fmt([site]),
                    ))
        return violations


class AxisPayloadBits(NamedTuple):
    """Pin the total per-link bits all collectives move over one mesh axis.

    Sums, over every collective eqn (``prims``) whose named axes include
    ``axis``, the bits of its INPUT operands -- what one device puts on
    the wire: an ``all_gather``'s invar is the per-device shard, a
    ``psum``'s operand is the block each device contributes (``pmean``
    lowers to psum + div, so it is counted at the psum).  ``exact_bits``
    makes the declared uplink budget an asserted property of the lowered
    program: a hidden dense block riding the axis -- whatever primitive
    carries it -- blows the budget and names the eqn.
    """

    axis: str
    exact_bits: Optional[IntOrParam] = None
    max_bits: Optional[IntOrParam] = None
    prims: Tuple[str, ...] = ("psum", "all_gather", "all_to_all",
                              "ppermute")

    def describe(self) -> str:
        parts = []
        if self.exact_bits is not None:
            parts.append(f"=={self.exact_bits}")
        if self.max_bits is not None:
            parts.append(f"<={self.max_bits}")
        return (f"payload_bits[axis={self.axis} "
                f"{' '.join(parts) or 'any'}]")

    @staticmethod
    def _eqn_bits(eqn) -> int:
        bits = 0
        for v in eqn.invars:
            aval = getattr(v, "aval", None)
            shape = getattr(aval, "shape", None)
            dtype = getattr(aval, "dtype", None)
            if shape is None or dtype is None:
                continue
            bits += int(np.prod(shape, dtype=np.int64)) * (
                np.dtype(dtype).itemsize * 8)
        return bits

    def check(self, jaxpr, params=None) -> list:
        sites = []
        total = 0
        for site in walker.iter_eqns(jaxpr):
            if site.eqn.primitive.name not in self.prims:
                continue
            if self.axis not in _eqn_axes(site.eqn):
                continue
            sites.append(site)
            total += self._eqn_bits(site.eqn)
        violations = []

        def fail(expected: str):
            violations.append(Violation(
                self.describe(),
                f"collectives over axis '{self.axis}' move {total} bits "
                f"per link, expected {expected}",
                _fmt(sites),
            ))

        exact = resolve(self.exact_bits, params)
        if exact is not None and total != exact:
            fail(f"exactly {exact}")
        max_bits = resolve(self.max_bits, params)
        if max_bits is not None and total > max_bits:
            fail(f"at most {max_bits}")
        return violations


class VmemConformance(NamedTuple):
    """Cross-check traced fused-ADMM launches against the VMEM model.

    For every ``pallas_call`` whose kernel name contains
    ``kernel_substr``, read the BlockMappings actually traced, recover
    (d, block_k, state_io), and assert the analytic footprint
    ``fused_block_vmem_bytes(d, block_k, state_io)`` fits the budget and
    that ``block_k`` never exceeds what ``pick_block_k`` would allow.
    """

    budget: Optional[IntOrParam] = None  # None -> backend_vmem_budget()
    kernel_substr: str = "_fused_admm"

    def describe(self) -> str:
        budget = self.budget if self.budget is not None else "backend"
        return f"vmem[{self.kernel_substr} <= {budget}]"

    def _kernel_name(self, eqn) -> str:
        info = eqn.params.get("name_and_src_info", None)
        name = getattr(info, "name", None)
        if name is None:
            name = eqn.params.get("name", "") or str(info or "")
        return name

    def check(self, jaxpr, params=None) -> list:
        from repro.kernels.dantzig_fused import (
            backend_vmem_budget,
            fused_block_vmem_bytes,
            pick_block_k,
        )

        budget = resolve(self.budget, params)
        if budget is None:
            budget = backend_vmem_budget()
        violations = []
        for site in walker.find_eqns(jaxpr, "pallas_call"):
            if self.kernel_substr not in self._kernel_name(site.eqn):
                continue
            try:
                gm = site.eqn.params["grid_mapping"]
                mappings = gm.block_mappings
                d = int(mappings[0].block_shape[0])
                block_k = int(mappings[3].block_shape[1])
                k_total = int(mappings[3].array_shape_dtype.shape[1])
                state_io = int(gm.num_inputs) > 6
            except (KeyError, AttributeError, IndexError, TypeError) as exc:
                violations.append(Violation(
                    self.describe(),
                    f"could not read block mappings from pallas_call "
                    f"params ({exc!r}); analyzer needs updating for this "
                    f"jax version",
                    _fmt([site]),
                ))
                continue
            used = fused_block_vmem_bytes(d, block_k, state_io=state_io)
            if used > budget:
                violations.append(Violation(
                    self.describe(),
                    f"fused block (d={d}, block_k={block_k}, "
                    f"state_io={state_io}) needs {used} bytes, "
                    f"budget is {budget}",
                    _fmt([site]),
                ))
            allowed = pick_block_k(d, k_total, budget, state_io=state_io)
            if allowed is not None and block_k > allowed:
                violations.append(Violation(
                    self.describe(),
                    f"traced block_k={block_k} exceeds pick_block_k's "
                    f"choice {allowed} for (d={d}, k={k_total})",
                    _fmt([site]),
                ))
        return violations


class DtypePolicy(NamedTuple):
    """No silent float promotion past ``max_float`` anywhere in the trace.

    Flags every eqn producing a floating value wider than the ceiling --
    which catches both f64 literals leaking in and an explicit
    ``convert_element_type`` promoting the hot path.
    """

    max_float: str = "float32"

    def describe(self) -> str:
        return f"dtype[float <= {self.max_float}]"

    def check(self, jaxpr, params=None) -> list:
        import jax.numpy as jnp

        max_bits = jnp.finfo(jnp.dtype(self.max_float)).bits
        bad_sites = []
        bad_dtypes = set()
        for site in walker.iter_eqns(jaxpr):
            for v in site.eqn.outvars:
                dt = getattr(v.aval, "dtype", None)
                if dt is None or not jnp.issubdtype(dt, jnp.floating):
                    continue
                if jnp.finfo(dt).bits > max_bits:
                    bad_sites.append(site)
                    bad_dtypes.add(str(dt))
                    break
        if not bad_sites:
            return []
        shown = _fmt(bad_sites[:8])
        if len(bad_sites) > 8:
            shown = shown + (f"... and {len(bad_sites) - 8} more",)
        return [Violation(
            self.describe(),
            f"{len(bad_sites)} eqns produce {sorted(bad_dtypes)}, wider "
            f"than the {self.max_float} ceiling",
            shown,
        )]


ContractType = Union[PrimitiveBudget, CollectiveContract,
                     AxisPayloadBits, VmemConformance, DtypePolicy]


def run_contracts(contracts, jaxpr, params: Optional[dict] = None) -> list:
    """Check every contract; a missing case param is itself a violation."""
    violations: list[Violation] = []
    for contract in contracts:
        try:
            violations.extend(contract.check(jaxpr, params))
        except MissingParam as exc:
            violations.append(Violation(
                contract.describe(),
                f"case params missing key {exc.args[0]!r} needed by this "
                f"contract",
            ))
    return violations


def render_report(violations, indent: str = "  ") -> str:
    return "\n".join(
        indent + line
        for v in violations
        for line in v.render().splitlines()
    )


__all__ = [
    "AxisPayloadBits",
    "CollectiveContract",
    "ContractType",
    "DtypePolicy",
    "MissingParam",
    "Param",
    "PrimitiveBudget",
    "Violation",
    "VmemConformance",
    "render_report",
    "resolve",
    "run_contracts",
]
