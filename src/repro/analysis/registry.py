"""The ``@trace_contract`` decorator and the entry-point registry.

Contracts are declared next to the code they guard::

    @trace_contract(
        "rounds.worker_rounds",
        contracts=(
            PrimitiveBudget("eigh", exact=1),
            CollectiveContract("psum", count=Param("rounds"),
                               axis="data", shape=Param("psum_payload"),
                               dtype="float32"),
        ),
    )
    def worker_rounds(...): ...

The decorator only records (name, fn, contracts) -- the wrapped function
is returned unchanged, so decoration costs nothing at trace/compile time.
Representative shapes live in :mod:`repro.analysis.cases`; the lint CLI
joins the two.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

from repro.analysis import contracts as C


class ContractSpec(NamedTuple):
    name: str
    fn: Callable
    contracts: Tuple[Any, ...]


_REGISTRY: Dict[str, ContractSpec] = {}


def trace_contract(name: str, *, contracts) -> Callable:
    """Register ``contracts`` for the decorated entry point under ``name``."""
    bundle = tuple(contracts)

    def decorate(fn: Callable) -> Callable:
        _REGISTRY[name] = ContractSpec(name, fn, bundle)
        return fn

    return decorate


def registered() -> Dict[str, ContractSpec]:
    """Snapshot of the registry (entry name -> spec)."""
    return dict(_REGISTRY)


def contracts_of(name: str) -> Tuple[Any, ...]:
    return _REGISTRY[name].contracts


def unregister(name: str) -> None:
    """Remove an entry (used by the analyzer's own negative tests)."""
    _REGISTRY.pop(name, None)


def check_entry(name: str, jaxpr, params: Optional[dict] = None) -> list:
    """Run every contract registered for ``name`` against a traced jaxpr."""
    return C.run_contracts(contracts_of(name), jaxpr, params)


__all__ = [
    "ContractSpec",
    "check_entry",
    "contracts_of",
    "registered",
    "trace_contract",
    "unregister",
]
