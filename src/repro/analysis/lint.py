"""``python -m repro.analysis.lint`` -- sweep the contract registry.

Runs two rule families and exits nonzero on any violation:

1. import-graph rules (:mod:`repro.analysis.imports`) -- the structural
   pins, checked on the AST;
2. trace contracts -- every registered entry point traced at its
   representative shapes (:mod:`repro.analysis.cases`, including the
   d % model_axis != 0 remainder meshes) and checked against its
   declared contracts, reporting the offending eqn path on failure.

Heavy imports happen inside :func:`main` so the CLI can force an
8-device host platform *before* jax initializes.
"""

from __future__ import annotations

import argparse
import os
import sys

_DEVICE_FLAG = "--xla_force_host_platform_device_count"


def ensure_host_devices(n: int = 8) -> None:
    """Force an n-device CPU host; must run before jax is imported."""
    if "jax" in sys.modules:
        return  # too late to change platform flags
    flags = os.environ.get("XLA_FLAGS", "")
    if _DEVICE_FLAG not in flags:
        os.environ["XLA_FLAGS"] = f"{flags} {_DEVICE_FLAG}={n}".strip()


def run(entries=None, *, include_imports: bool = True, out=None) -> int:
    """Sweep the registry; return the number of failures (0 == clean)."""
    import jax

    from repro.analysis import cases as cases_mod
    from repro.analysis import contracts as C
    from repro.analysis import imports as imports_mod
    from repro.analysis import registry

    out = out or sys.stdout
    failures = 0
    n_devices = len(jax.devices())

    if include_imports:
        violations = imports_mod.structural_violations()
        status = "FAIL" if violations else "ok"
        print(f"[{status}] import-graph rules "
              f"({imports_mod.SRC_ROOT / 'repro'})", file=out)
        if violations:
            failures += 1
            print(C.render_report(violations), file=out)

    specs = registry.registered()
    names = sorted(entries) if entries else sorted(specs)
    for name in names:
        if name not in specs:
            failures += 1
            print(f"[FAIL] {name}: not in the contract registry", file=out)
            continue
        spec = specs[name]
        entry_cases = cases_mod.cases_for(name)
        if not entry_cases:
            failures += 1
            print(f"[FAIL] {name}: no representative cases registered",
                  file=out)
            continue
        print(f"{name} ({len(spec.contracts)} contracts)", file=out)
        for case in entry_cases:
            if case.min_devices > n_devices:
                print(f"  [skip] {case.name}: needs {case.min_devices} "
                      f"devices, host has {n_devices}", file=out)
                continue
            fn, args = case.build()
            jaxpr = jax.make_jaxpr(fn)(*args)
            violations = C.run_contracts(spec.contracts, jaxpr, case.params)
            if violations:
                failures += 1
                print(f"  [FAIL] {case.name}", file=out)
                print(C.render_report(violations, indent="    "), file=out)
            else:
                print(f"  [ok] {case.name}", file=out)
    return failures


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="trace-contract lint over the entry-point registry",
    )
    parser.add_argument("--entry", action="append", default=None,
                        help="lint only this entry (repeatable)")
    parser.add_argument("--no-imports", action="store_true",
                        help="skip the import-graph rules")
    parser.add_argument("--list", action="store_true",
                        help="list registered entries and cases, then exit")
    parser.add_argument("--devices", type=int, default=8,
                        help="host platform device count to force "
                             "(before jax import; default 8)")
    args = parser.parse_args(argv)

    ensure_host_devices(args.devices)

    if args.list:
        from repro.analysis import cases as cases_mod
        from repro.analysis import registry
        for name, spec in sorted(registry.registered().items()):
            print(f"{name} ({len(spec.contracts)} contracts)")
            for case in cases_mod.cases_for(name):
                print(f"  {case.name}")
        return 0

    failures = run(args.entry, include_imports=not args.no_imports)
    if failures:
        print(f"\nrepro.analysis.lint: {failures} FAILURE(S)")
        return 1
    print("\nrepro.analysis.lint: all contracts hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
