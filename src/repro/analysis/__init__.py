"""Trace-contract analyzer: declarative jaxpr lint for cost, communication,
and memory invariants.

The paper's claims are structural: one local factorization per machine, one
O(d*K) aggregation per round, a fused solver that fits its VMEM budget.
This package turns those invariants into machine-checked *contracts*:

- :mod:`repro.analysis.walker` -- recursive jaxpr traversal (pjit / scan /
  while / cond / shard_map / pallas_call sub-jaxprs) with located eqn paths,
  plus the shared :func:`count_eqns` counter used by the test suite.
- :mod:`repro.analysis.contracts` -- the contract types: primitive-count
  budgets, collective payload contracts, VMEM-budget conformance, and a
  floating-point dtype policy.
- :mod:`repro.analysis.registry` -- the ``@trace_contract`` decorator that
  declares contracts next to the code they guard.
- :mod:`repro.analysis.cases` -- representative trace shapes per entry point
  (including the d % model_axis != 0 remainder shapes).
- :mod:`repro.analysis.imports` -- AST-based import-graph rules replacing
  the old source-grep structural pins.
- :mod:`repro.analysis.lint` -- the ``python -m repro.analysis.lint`` CLI.
"""

from repro.analysis.contracts import (  # noqa: F401
    AxisPayloadBits,
    CollectiveContract,
    DtypePolicy,
    Param,
    PrimitiveBudget,
    Violation,
    VmemConformance,
    run_contracts,
)
from repro.analysis.registry import (  # noqa: F401
    check_entry,
    contracts_of,
    registered,
    trace_contract,
)
from repro.analysis.walker import (  # noqa: F401
    EqnSite,
    count_eqns,
    find_eqns,
    format_site,
    iter_eqns,
)

__all__ = [
    "AxisPayloadBits",
    "CollectiveContract",
    "DtypePolicy",
    "EqnSite",
    "Param",
    "PrimitiveBudget",
    "Violation",
    "VmemConformance",
    "check_entry",
    "contracts_of",
    "count_eqns",
    "find_eqns",
    "format_site",
    "iter_eqns",
    "registered",
    "run_contracts",
    "trace_contract",
]
