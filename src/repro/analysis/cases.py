"""Representative trace shapes for every contracted entry point.

Each case builds ``(fn, args)`` for :func:`jax.make_jaxpr` plus the
params dict that resolves the entry's :class:`~repro.analysis.contracts.
Param` placeholders.  Tracing never executes the solver, so even the
d=70 remainder sweep is cheap -- but mesh cases DO need the devices
their mesh asks for (``min_devices``); the lint CLI forces an 8-device
host, in-process callers skip what the host cannot mesh.

Importing this module imports the core entry points, which is what
populates the contract registry.
"""

from __future__ import annotations

from typing import Callable, Dict, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compression as compression_core
from repro.core import path as rpath
from repro.core import pipeline, rounds, streaming
from repro.core import transport as transport_core
from repro.core.compression import Compression
from repro.core.dantzig import DantzigConfig
from repro.core.distributed import (
    _shard_map,
    distributed_mc_slda_shardmap,
    distributed_slda_shardmap,
)
from repro.core.faults import Aggregation, FaultPlan, FaultSchedule
from repro.core.solver_dispatch import solve_dantzig_full
from repro.kernels.spectral import spectral_factor


class Case(NamedTuple):
    entry: str
    name: str
    params: dict
    build: Callable[[], Tuple[Callable, tuple]]
    min_devices: int = 1


_CASES: Dict[str, List[Case]] = {}


def case(entry: str, name: str, params: dict, *, min_devices: int = 1):
    def register(build):
        _CASES.setdefault(entry, []).append(
            Case(entry, name, dict(params), build, min_devices))
        return build
    return register


def cases_for(entry: str) -> List[Case]:
    return list(_CASES.get(entry, []))


def all_cases() -> Dict[str, List[Case]]:
    return {k: list(v) for k, v in _CASES.items()}


def _normal(seed: int, shape) -> jnp.ndarray:
    return jax.random.normal(jax.random.PRNGKey(seed), shape)


def _spd(d: int, seed: int = 0) -> jnp.ndarray:
    g = _normal(seed, (2 * d, d))
    return g.T @ g / (2 * d) + 0.5 * jnp.eye(d)


SCAN = DantzigConfig(max_iters=40, adapt_rho=False)
FUSED = DantzigConfig(max_iters=40, adapt_rho=False, fused=True)
FUSED_TOL = DantzigConfig(max_iters=40, adapt_rho=False, fused=True,
                          tol=1e-3)


# ---------------------------------------------------------------------------
# pipeline.worker_debiased
# ---------------------------------------------------------------------------

def _worker_debiased_case(cfg):
    def build():
        x, y = _normal(0, (40, 12)), _normal(1, (44, 12))

        def fn(x, y):
            return pipeline.worker_debiased(
                pipeline.BinaryHead(), x, y, lam=0.1, lam_prime=0.1,
                cfg=cfg)
        return fn, (x, y)
    return build


case("pipeline.worker_debiased", "binary-scan-d12",
     {"pallas_calls": 0})(_worker_debiased_case(SCAN))
case("pipeline.worker_debiased", "binary-fused-d12",
     {"pallas_calls": 2})(_worker_debiased_case(FUSED))
case("pipeline.worker_debiased", "binary-fused-tol-d12",
     {"pallas_calls": 2})(_worker_debiased_case(FUSED_TOL))


@case("pipeline.worker_debiased", "multiclass-fused-d10-K3",
      {"pallas_calls": 2})
def _worker_debiased_mc():
    x = _normal(2, (60, 10))
    labels = jax.random.randint(jax.random.PRNGKey(3), (60,), 0, 3)

    def fn(x, labels):
        return pipeline.worker_debiased(
            pipeline.MulticlassHead(3), x, labels, lam=0.1,
            lam_prime=0.1, cfg=FUSED)
    return fn, (x, labels)


# ---------------------------------------------------------------------------
# rounds.worker_rounds (inside a minimal shard_map shell)
# ---------------------------------------------------------------------------

def _comm_params(comm, t_rounds, d, num_cols, extra_bits=0):
    """Collective counts + per-direction exact bits for a fault-free,
    unmasked :class:`~repro.core.transport.CommPlan`.

    Walks the resolved :class:`~repro.core.transport.Transport` round by
    round (a :class:`~repro.core.transport.BitBudget` schedule changes
    codecs per round), applying the DESIGN §10/§13 accounting: a dense
    uplink is one (d, K) f32 psum; a compressed uplink is 2 payload
    all_gathers (3 with int8 scales) + 2 decode-sanitize is_finite; a
    compressed downlink is 2 payload psums (3 with int8 scales) + ONE
    whole-block receiver screen (a dense downlink never touches the
    wire -- the aggregate is already replicated).  ``extra_bits`` covers
    one-off psum payloads like the mc class-means pmean.
    """
    tr = transport_core.Transport(comm, d, num_cols, t_rounds)
    dense_psums = down_psums = data_gathers = screen_ops = 0
    gather_bits, psum_bits = 0, extra_bits
    for t in range(1, t_rounds + 1):
        up, down = tr.up(t), tr.down(t)
        if up.compressed:
            data_gathers += 3 if up.comp.quantize == "int8" else 2
            gather_bits += up.bits(d, num_cols)
            screen_ops += 2
        else:
            dense_psums += 1
            psum_bits += compression_core.dense_uplink_bits(d, num_cols)
        if down.compressed:
            down_psums += 3 if down.comp.quantize == "int8" else 2
            psum_bits += down.bits(d, num_cols)
            screen_ops += 1
    return {
        "rounds": t_rounds,
        "dense_psums": dense_psums,
        "live_psums": 0,
        "total_psums": dense_psums + down_psums,
        "screen_ops": screen_ops,
        "data_gathers": data_gathers,
        "data_gather_bits": gather_bits,
        "data_psum_bits": psum_bits,
        "data_total_bits": gather_bits + psum_bits,
    }


def _round_params(t_rounds, d, num_cols, comp=None, extra_bits=0,
                  down=None):
    """Fixed-codec shorthand over :func:`_comm_params`."""
    return _comm_params(
        transport_core.CommPlan(uplink=comp, downlink=down),
        t_rounds, d, num_cols, extra_bits=extra_bits)


def _masked_round_params(t_rounds, d, num_cols, comp=None, *,
                         faulted=False, trim=False, extra_bits=0,
                         down=None):
    """The DESIGN §11 masked-aggregation counterparts.

    Masked dense rounds close with a (d, K) psum + the scalar liveness
    psum (trimmed mode gathers per-machine blocks + weights instead);
    masked compressed rounds gather the payload as before plus, when a
    fault plan rides along, the per-machine liveness scalar.  Screening
    is one is_finite per round on the dense wire, or (compressed) one
    on the ef_step decode + one on the raw decoded stack.  The downlink
    close is orthogonal to the masking and keeps its
    :func:`_comm_params` accounting."""
    base = _round_params(t_rounds, d, num_cols, comp,
                         extra_bits=extra_bits, down=down)
    scalar_bits = 32  # one f32 liveness scalar per round on the wire
    dl_psums = (0 if down is None
                else t_rounds * (3 if down.quantize == "int8" else 2))
    dl_bits = (0 if down is None
               else t_rounds * compression_core.uplink_bits(
                   down, d, num_cols))
    dl_screens = 0 if down is None else t_rounds
    if comp is None:
        dense_bits = t_rounds * compression_core.dense_uplink_bits(
            d, num_cols)
        if trim:
            # all_gather of the (d, K) block + the weight scalar; the
            # trimmed reduction itself is replicated local math
            base.update({
                "dense_psums": 0, "live_psums": 0,
                "total_psums": dl_psums,
                "data_gathers": 2 * t_rounds,
                "screen_ops": t_rounds + dl_screens,
                "data_gather_bits": dense_bits + t_rounds * scalar_bits,
                "data_psum_bits": extra_bits + dl_bits,
            })
        else:
            base.update({
                "live_psums": t_rounds,
                "total_psums": base["total_psums"] + t_rounds,
                "screen_ops": t_rounds + dl_screens,
                "data_psum_bits":
                    base["data_psum_bits"] + t_rounds * scalar_bits,
            })
    else:
        extra_gathers = t_rounds if faulted else 0
        base.update({
            "data_gathers": base["data_gathers"] + extra_gathers,
            "data_gather_bits":
                base["data_gather_bits"] + extra_gathers * scalar_bits,
        })
    base["data_total_bits"] = (base["data_gather_bits"]
                               + base["data_psum_bits"])
    return base


def _worker_rounds_case(cfg, t_rounds, comp=None, agg=None, faults=False,
                        staleness=0, comm=None):
    def build():
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        x, y = _normal(4, (30, 12)), _normal(5, (30, 12))
        plan = (FaultSchedule(dropout=0.3, seed=0).plan(
            1, t_rounds, max(staleness, 1)) if faults else None)
        plan_args = tuple(plan) if plan is not None else ()
        plan_specs = tuple(P("data", None) for _ in plan_args)

        def shard_fn(xs, ys, *plan_leaves):
            row = (FaultPlan(*(leaf[0] for leaf in plan_leaves))
                   if plan_leaves else None)
            beta, _ = rounds.worker_rounds(
                pipeline.BinaryHead(), xs, ys, lam=0.2, lam_prime=0.2,
                rounds=t_rounds, cfg=cfg, model_axis="model",
                model_axis_size=1, comm=comm, compression=comp,
                faults=row, staleness=staleness, aggregation=agg)
            return beta

        spec = P("data", None)
        fn = _shard_map(shard_fn, mesh, (spec, spec) + plan_specs, P())
        return fn, (x, y) + plan_args
    return build


case("rounds.worker_rounds", "rounds3-mesh1x1-d12",
     {**_round_params(3, 12, 1), "psum_payload": (12, 1),
      "pallas_calls": 0})(_worker_rounds_case(SCAN, 3))
case("rounds.worker_rounds", "rounds3-mesh1x1-d12-top5",
     {**_round_params(3, 12, 1, Compression(5)), "psum_payload": (12, 1),
      "pallas_calls": 0})(_worker_rounds_case(SCAN, 3, Compression(5)))
case("rounds.worker_rounds", "rounds2-mesh1x1-d12-top4-int8",
     {**_round_params(2, 12, 1, Compression(4, "int8")),
      "psum_payload": (12, 1), "pallas_calls": 0})(
    _worker_rounds_case(SCAN, 2, Compression(4, "int8")))
# DESIGN §11 masked aggregation: the liveness scalar psum + one
# screening is_finite per round join the budget
case("rounds.worker_rounds", "rounds3-mesh1x1-d12-masked",
     {**_masked_round_params(3, 12, 1), "psum_payload": (12, 1),
      "pallas_calls": 0})(
    _worker_rounds_case(SCAN, 3, agg=Aggregation()))
case("rounds.worker_rounds", "rounds2-mesh1x1-d12-masked-faulted-stale",
     {**_masked_round_params(2, 12, 1), "psum_payload": (12, 1),
      "pallas_calls": 0})(
    _worker_rounds_case(SCAN, 2, agg=Aggregation(), faults=True,
                        staleness=1))
# trimmed mode trades the psums for per-machine block + weight gathers
case("rounds.worker_rounds", "rounds2-mesh1x1-d12-trimmed",
     {**_masked_round_params(2, 12, 1, trim=True),
      "psum_payload": (12, 1), "pallas_calls": 0})(
    _worker_rounds_case(SCAN, 2, agg=Aggregation(trim=0.1)))
# masked compressed + faults: payload gathers + the liveness gather
case("rounds.worker_rounds", "rounds2-mesh1x1-d12-top4-int8-masked-faulted",
     {**_masked_round_params(2, 12, 1, Compression(4, "int8"),
                             faulted=True),
      "psum_payload": (12, 1), "pallas_calls": 0})(
    _worker_rounds_case(SCAN, 2, Compression(4, "int8"),
                        agg=Aggregation(envelope=1e6), faults=True))
# DESIGN §13 two-way transport: the compressed downlink rides the
# master-masked psum broadcast (values + indices, + scales when int8)
# and adds ONE whole-block receiver screen per round
case("rounds.worker_rounds", "rounds2-mesh1x1-d12-top5-down4-int8",
     {**_round_params(2, 12, 1, Compression(5),
                      down=Compression(4, "int8")),
      "psum_payload": (12, 1), "pallas_calls": 0})(
    _worker_rounds_case(SCAN, 2, comm=transport_core.CommPlan(
        uplink=Compression(5), downlink=Compression(4, "int8"))))


# ---------------------------------------------------------------------------
# distributed faces
# ---------------------------------------------------------------------------

def _slda_face_case(cfg, t_rounds, d, mesh_shape, n_per=30, comp=None,
                    faults=None, staleness=0, agg=None, comm=None):
    def build():
        mesh = jax.make_mesh(mesh_shape, ("data", "model"))
        n = n_per * mesh_shape[0]
        x, y = _normal(6, (n, d)), _normal(7, (n, d))

        def fn(x, y):
            return distributed_slda_shardmap(
                mesh, x, y, 0.2, 0.2, 0.05, cfg, rounds=t_rounds,
                comm=comm, compression=comp, faults=faults,
                staleness=staleness, aggregation=agg)
        return fn, (x, y)
    return build


for _t in (1, 3):
    case("distributed.slda_shardmap", f"scan-rounds{_t}-mesh1x1-d12",
         {**_round_params(_t, 12, 1), "psum_payload": (12, 1),
          "pallas_calls": 0})(
        _slda_face_case(SCAN, _t, 12, (1, 1)))
case("distributed.slda_shardmap", "fused-rounds2-mesh1x1-d12",
     {**_round_params(2, 12, 1), "psum_payload": (12, 1),
      "pallas_calls": 2})(
    _slda_face_case(FUSED, 2, 12, (1, 1)))
# the PR-1 regression shape: d % model_axis != 0 (70 over 4 -> pad 72)
case("distributed.slda_shardmap", "fused-rounds3-mesh2x4-d70-remainder",
     {**_round_params(3, 70, 1), "psum_payload": (70, 1),
      "pallas_calls": 2},
     min_devices=8)(
    _slda_face_case(FUSED, 3, 70, (2, 4)))
# compressed uplinks: the jaxpr moves the (k_top, 1) payload, no dense
# psum, and exactly the declared bits -- one f32 and one int8 config,
# plus the 8-device remainder shape under compression
case("distributed.slda_shardmap", "scan-rounds3-mesh1x1-d12-top5",
     {**_round_params(3, 12, 1, Compression(5)), "psum_payload": (12, 1),
      "pallas_calls": 0})(
    _slda_face_case(SCAN, 3, 12, (1, 1), comp=Compression(5)))
case("distributed.slda_shardmap", "scan-rounds2-mesh1x1-d12-top4-int8",
     {**_round_params(2, 12, 1, Compression(4, "int8")),
      "psum_payload": (12, 1), "pallas_calls": 0})(
    _slda_face_case(SCAN, 2, 12, (1, 1), comp=Compression(4, "int8")))
case("distributed.slda_shardmap",
     "fused-rounds3-mesh2x4-d70-remainder-top16-bf16",
     {**_round_params(3, 70, 1, Compression(16, "bf16")),
      "psum_payload": (70, 1), "pallas_calls": 2},
     min_devices=8)(
    _slda_face_case(FUSED, 3, 70, (2, 4), comp=Compression(16, "bf16")))
# the fault-tolerant face (DESIGN §11): masked aggregation with a
# sharded FaultPlan liveness operand, dense and on the 8-device mesh
case("distributed.slda_shardmap", "scan-rounds3-mesh1x1-d12-masked-faulted",
     {**_masked_round_params(3, 12, 1), "psum_payload": (12, 1),
      "pallas_calls": 0})(
    _slda_face_case(SCAN, 3, 12, (1, 1),
                    faults=FaultSchedule(dropout=0.2, seed=1),
                    staleness=1, agg=Aggregation()))
case("distributed.slda_shardmap", "scan-rounds2-mesh1x1-d12-trimmed",
     {**_masked_round_params(2, 12, 1, trim=True),
      "psum_payload": (12, 1), "pallas_calls": 0})(
    _slda_face_case(SCAN, 2, 12, (1, 1),
                    faults=FaultSchedule(corrupt=0.2, seed=2),
                    agg=Aggregation(trim=0.25)))
# DESIGN §13: compressed downlinks -- dense uplink + compressed
# downlink, both directions compressed, and on the 8-device remainder
# mesh (k < d keeps the (k, 1) downlink psum distinct from the dense
# (d, 1) psum the dense_psums contract counts)
case("distributed.slda_shardmap", "scan-rounds3-mesh1x1-d12-down6",
     {**_round_params(3, 12, 1, down=Compression(6)),
      "psum_payload": (12, 1), "pallas_calls": 0})(
    _slda_face_case(SCAN, 3, 12, (1, 1),
                    comm=transport_core.CommPlan(downlink=Compression(6))))
case("distributed.slda_shardmap", "scan-rounds2-mesh1x1-d12-top5-down4-int8",
     {**_round_params(2, 12, 1, Compression(5),
                      down=Compression(4, "int8")),
      "psum_payload": (12, 1), "pallas_calls": 0})(
    _slda_face_case(SCAN, 2, 12, (1, 1), comm=transport_core.CommPlan(
        uplink=Compression(5), downlink=Compression(4, "int8"))))
case("distributed.slda_shardmap",
     "fused-rounds3-mesh2x4-d70-top16-bf16-down8-int8",
     {**_round_params(3, 70, 1, Compression(16, "bf16"),
                      down=Compression(8, "int8")),
      "psum_payload": (70, 1), "pallas_calls": 2},
     min_devices=8)(
    _slda_face_case(FUSED, 3, 70, (2, 4), comm=transport_core.CommPlan(
        uplink=Compression(16, "bf16"), downlink=Compression(8, "int8"))))
# DESIGN §13 bit-budget schedules: the BitBudget planner re-plans both
# directions per round at trace time; the pinned bits are the REALIZED
# schedule totals (what plan_rounds fit under the budget).  Budgets are
# sized so every planned k_top < d: a k=d downlink would put a (d, 1)
# psum on the wire, which the dense_psums contract's shape filter
# counts (it filters by payload shape before checking dtype)
_TAPER = transport_core.BitBudget(total_bits=1100, mode="taper",
                                  taper=0.5, quantize="int8")
case("distributed.slda_shardmap", "scan-rounds3-mesh1x1-d12-taper1100",
     {**_comm_params(transport_core.CommPlan(schedule=_TAPER), 3, 12, 1),
      "psum_payload": (12, 1), "pallas_calls": 0})(
    _slda_face_case(SCAN, 3, 12, (1, 1),
                    comm=transport_core.CommPlan(schedule=_TAPER)))
_CONST = transport_core.BitBudget(total_bits=1500, mode="constant",
                                  quantize=None, down_fraction=0.25)
case("distributed.slda_shardmap", "scan-rounds2-mesh1x1-d12-const1500",
     {**_comm_params(transport_core.CommPlan(schedule=_CONST), 2, 12, 1),
      "psum_payload": (12, 1), "pallas_calls": 0})(
    _slda_face_case(SCAN, 2, 12, (1, 1),
                    comm=transport_core.CommPlan(schedule=_CONST)))
case("distributed.slda_shardmap", "fused-rounds3-mesh2x4-d70-masked-faulted",
     {**_masked_round_params(3, 70, 1), "psum_payload": (70, 1),
      "pallas_calls": 2},
     min_devices=8)(
    _slda_face_case(FUSED, 3, 70, (2, 4),
                    faults=FaultSchedule(dropout=0.3, straggle=0.2,
                                         corrupt=0.1, corrupt_mode="mix",
                                         seed=3),
                    staleness=2, agg=Aggregation(envelope=1e6)))


def _mc_face_case(cfg, t_rounds, d=10, num_classes=3, comp=None,
                  faults=None, staleness=0, agg=None):
    def build():
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        x = _normal(8, (60, d))
        labels = jax.random.randint(jax.random.PRNGKey(9), (60,), 0,
                                    num_classes)

        def fn(x, labels):
            return distributed_mc_slda_shardmap(
                mesh, x, labels, num_classes, 0.2, 0.2, 0.05, cfg,
                rounds=t_rounds, compression=comp, faults=faults,
                staleness=staleness, aggregation=agg)
        return fn, (x, labels)
    return build


def _mc_params(t_rounds, d=10, num_classes=3, comp=None, masked=False,
               faulted=False):
    # the (K, d) class means ride one dense f32 pmean regardless of the
    # direction compression (and outside the fault mask)
    means_bits = num_classes * d * 32
    maker = (_masked_round_params if masked else _round_params)
    kw = {"faulted": faulted} if masked else {}
    p = maker(t_rounds, d, num_classes, comp, extra_bits=means_bits, **kw)
    return {**p, "total_psums": p["total_psums"] + 1,
            "direction_payload": (d, num_classes),
            "means_payload": (num_classes, d), "pallas_calls": 0}


for _t in (1, 3):
    case("distributed.mc_slda_shardmap", f"scan-rounds{_t}-mesh1x1-d10-K3",
         _mc_params(_t))(_mc_face_case(SCAN, _t))
case("distributed.mc_slda_shardmap", "scan-rounds2-mesh1x1-d10-K3-top3",
     _mc_params(2, comp=Compression(3)))(
    _mc_face_case(SCAN, 2, comp=Compression(3)))
case("distributed.mc_slda_shardmap",
     "scan-rounds2-mesh1x1-d10-K3-masked-faulted",
     _mc_params(2, masked=True, faulted=True))(
    _mc_face_case(SCAN, 2, faults=FaultSchedule(dropout=0.2, seed=4),
                  staleness=1, agg=Aggregation()))


# ---------------------------------------------------------------------------
# path.solve_dantzig_path / path.worker_debiased_path
# ---------------------------------------------------------------------------

@case("path.solve_dantzig_path", "fused-factor-fed-d16-k3-L4",
      {"eighs": 0, "pallas_calls": 1})
def _path_factor_fed():
    a = _spd(16, seed=10)
    factor = spectral_factor(a)
    b = _normal(11, (16, 3))
    lams = jnp.linspace(0.05, 0.4, 4)

    def fn(factor, b):
        return rpath.solve_dantzig_path(factor, b, lams, FUSED)
    return fn, (factor, b)


@case("path.solve_dantzig_path", "scan-raw-d16-k2-L4",
      {"eighs": 1, "pallas_calls": 0})
def _path_raw_scan():
    a = _spd(16, seed=12)
    b = _normal(13, (16, 2))
    lams = jnp.linspace(0.05, 0.4, 4)

    def fn(a, b):
        return rpath.solve_dantzig_path(a, b, lams, SCAN)
    return fn, (a, b)


@case("path.solve_dantzig_path", "fused-tol-raw-d16-k2-L4",
      {"eighs": 1, "pallas_calls": 1})
def _path_raw_fused_tol():
    a = _spd(16, seed=14)
    b = _normal(15, (16, 2))
    lams = jnp.linspace(0.05, 0.4, 4)

    def fn(a, b):
        return rpath.solve_dantzig_path(a, b, lams, FUSED_TOL)
    return fn, (a, b)


def _worker_path_case(cfg):
    def build():
        x, y = _normal(16, (40, 12)), _normal(17, (44, 12))
        lams = jnp.linspace(0.05, 0.4, 6)

        def fn(x, y):
            return rpath.worker_debiased_path(
                pipeline.BinaryHead(), x, y, lams=lams, lam_prime=0.1,
                cfg=cfg)
        return fn, (x, y)
    return build


case("path.worker_debiased_path", "scan-d12-L6",
     {"pallas_calls": 0})(_worker_path_case(SCAN))
case("path.worker_debiased_path", "fused-tol-d12-L6",
     {"pallas_calls": 2})(_worker_path_case(FUSED_TOL))


# ---------------------------------------------------------------------------
# solver_dispatch.solve_dantzig_full
# ---------------------------------------------------------------------------

@case("solver_dispatch.solve_dantzig_full", "fused-factor-fed-d16-k4",
      {"eighs": 0, "pallas_calls": 1})
def _full_factor_fed():
    a = _spd(16, seed=18)
    factor = spectral_factor(a)
    b = _normal(19, (16, 4))

    def fn(factor, b):
        return solve_dantzig_full(factor, b, 0.1, FUSED)
    return fn, (factor, b)


@case("solver_dispatch.solve_dantzig_full", "scan-raw-d16-k4",
      {"eighs": 1, "pallas_calls": 0})
def _full_raw_scan():
    a = _spd(16, seed=20)
    b = _normal(21, (16, 4))

    def fn(a, b):
        return solve_dantzig_full(a, b, 0.1, SCAN)
    return fn, (a, b)


# ---------------------------------------------------------------------------
# streaming.classify_batch / streaming.refit_step (the serving runtime)
# ---------------------------------------------------------------------------

@case("streaming.classify_batch", "B32-d16-K3-priors", {})
def _classify_batch_priors():
    z = _normal(22, (32, 16))
    beta = _normal(23, (16, 3))
    means = _normal(24, (3, 16))
    priors = jnp.full((3,), 1.0 / 3.0)

    def fn(z, beta, means, priors):
        return streaming.classify_batch(z, beta, means, priors)
    return fn, (z, beta, means, priors)


@case("streaming.classify_batch", "B8-d12-K2-equal-priors", {})
def _classify_batch_binary():
    z = _normal(25, (8, 12))
    beta = _normal(26, (12, 2))
    means = _normal(27, (2, 12))

    def fn(z, beta, means):
        return streaming.classify_batch(z, beta, means, None)
    return fn, (z, beta, means)


def _refit_stats(d: int = 12):
    x, y = _normal(28, (40, d)), _normal(29, (44, d))
    return streaming.head_stats_of(pipeline.suff_stats(x, y))


def _refit_case(cfg, warm: bool):
    def build():
        stats = _refit_stats()
        if warm:
            carry = streaming.refit_step(stats, 0.1, 0.1, cfg).carry

            def fn(stats, carry):
                return streaming.refit_step(stats, 0.1, 0.1, cfg,
                                            carry=carry)
            return fn, (stats, carry)

        def fn(stats):
            return streaming.refit_step(stats, 0.1, 0.1, cfg)
        return fn, (stats,)
    return build


case("streaming.refit_step", "cold-scan-d12",
     {"pallas_calls": 0})(_refit_case(SCAN, warm=False))
case("streaming.refit_step", "warm-scan-d12",
     {"pallas_calls": 0})(_refit_case(SCAN, warm=True))
case("streaming.refit_step", "cold-fused-tol-d12",
     {"pallas_calls": 2})(_refit_case(FUSED_TOL, warm=False))


__all__ = ["Case", "all_cases", "case", "cases_for"]
