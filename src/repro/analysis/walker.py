"""Recursive jaxpr traversal with located eqn paths.

This is the single home of the eqn counter that used to live as two
divergent private copies in ``tests/test_rounds.py`` and
``tests/test_spectral_path.py``.  Traversal descends into every nested
jaxpr a primitive carries in its params -- pjit, scan, while, cond
branches, shard_map bodies, pallas_call kernels -- so a contract holds
for the whole lowered program, not just the top level.
"""

from __future__ import annotations

from typing import Any, Iterator, NamedTuple


class EqnSite(NamedTuple):
    """One equation plus the chain of enclosing primitives that reach it."""

    eqn: Any
    path: tuple[str, ...]  # enclosing primitive names, outermost first


def as_jaxpr(obj):
    """Accept a ClosedJaxpr, a raw Jaxpr, or anything forwarding ``eqns``."""
    if hasattr(obj, "eqns"):
        return obj
    if hasattr(obj, "jaxpr"):
        return obj.jaxpr
    raise TypeError(f"not a jaxpr: {type(obj).__name__}")


def _sub_jaxprs(value) -> Iterator[Any]:
    """Yield every jaxpr reachable from one params value.

    Handles ClosedJaxpr (``.jaxpr``), raw Jaxpr (``.eqns``), and
    tuples/lists of either (cond branches, custom-call sub-jaxprs).
    """
    if hasattr(value, "jaxpr") and hasattr(value.jaxpr, "eqns"):
        yield value.jaxpr
    elif hasattr(value, "eqns"):
        yield value
    elif isinstance(value, (list, tuple)):
        for item in value:
            yield from _sub_jaxprs(item)


def iter_eqns(jaxpr, path: tuple[str, ...] = ()) -> Iterator[EqnSite]:
    """Depth-first walk over every eqn, including nested sub-jaxprs."""
    jaxpr = as_jaxpr(jaxpr)
    for eqn in jaxpr.eqns:
        yield EqnSite(eqn, path)
        inner = path + (eqn.primitive.name,)
        for value in eqn.params.values():
            for sub in _sub_jaxprs(value):
                yield from iter_eqns(sub, inner)


def _aval_short(var) -> str:
    aval = getattr(var, "aval", None)
    if aval is None:
        return "?"
    short = getattr(aval, "str_short", None)
    return short() if callable(short) else str(aval)


def format_site(site: EqnSite) -> str:
    """Render a located eqn path, e.g. ``shard_map/pjit/eigh -> f32[8,8]``."""
    where = "/".join(site.path + (site.eqn.primitive.name,))
    outs = ",".join(_aval_short(v) for v in site.eqn.outvars)
    return f"{where} -> {outs}"


def find_eqns(jaxpr, prim_name: str, out_shape=None) -> list[EqnSite]:
    """All sites for ``prim_name``; ``out_shape`` keeps only eqns with at
    least one output of that shape (the standard payload matcher)."""
    want = tuple(out_shape) if out_shape is not None else None
    sites = []
    for site in iter_eqns(jaxpr):
        if site.eqn.primitive.name != prim_name:
            continue
        if want is not None and not any(
            getattr(v.aval, "shape", None) == want for v in site.eqn.outvars
        ):
            continue
        sites.append(site)
    return sites


def count_eqns(jaxpr, prim_name: str, out_shape=None) -> int:
    """Count primitive occurrences, descending into nested jaxprs."""
    return len(find_eqns(jaxpr, prim_name, out_shape))
