"""Logical axis names -> mesh axes (MaxText-style rules table).

Model code annotates tensors with *logical* axes ("batch", "embed",
"mlp", "heads", ...).  The launcher installs a rules table mapping
logical axes to physical mesh axes; `constrain` resolves the table and
emits a with_sharding_constraint when a mesh is active, and is a no-op
on bare CPU (unit tests / smoke tests).

Physical axes of the production mesh: ("pod",) "data", "model".
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Mapping logical axis -> mesh axis (or tuple of axes, or None)."""

    rules: Mapping[str, tuple[str, ...] | str | None]

    def spec(self, logical_axes: Sequence[str | None]) -> P:
        parts = []
        for ax in logical_axes:
            if ax is None:
                parts.append(None)
            else:
                parts.append(self.rules.get(ax))
        return P(*parts)

    def replace(self, **updates) -> "ShardingRules":
        merged = dict(self.rules)
        merged.update(updates)
        return ShardingRules(merged)


# Batch sharded over pod+data (pure data parallel across pods);
# width dims (mlp, heads, vocab, expert-ff) over the model axis.
DEFAULT_RULES = ShardingRules(
    {
        "batch": ("pod", "data"),
        "seq": None,
        "cache_seq": None,  # flipped to "model" for long-context decode
        "embed": None,
        "mlp": "model",
        "heads": "model",
        "kv_heads": None,  # kv heads replicated (GQA count < axis size)
        "head_dim": None,
        "vocab": "model",
        "expert": None,  # "model" under expert-parallel MoE
        "expert_mlp": "model",  # expert FFN width under tensor-parallel MoE
        "ssm_inner": "model",
        "ssm_state": None,
        # xLSTM head feature axis (dk/dv): sharded over "model" so the
        # q/k/v/gate projections reduce-scatter instead of all-reducing
        # ~1 GB replicated activations (SSPerf-E) and the (b,h,dk,dv)
        # matrix memory shards instead of replicating (SSPerf-D).
        "xlstm_dk": "model",
        "conv_width": None,
        "capacity": None,
        "frames": None,
    }
)

_ACTIVE_RULES: ShardingRules = DEFAULT_RULES


def set_rules(rules: ShardingRules) -> None:
    global _ACTIVE_RULES
    _ACTIVE_RULES = rules


def get_rules() -> ShardingRules:
    return _ACTIVE_RULES


def logical_to_spec(logical_axes: Sequence[str | None], rules: ShardingRules | None = None) -> P:
    return (rules or _ACTIVE_RULES).spec(logical_axes)


def _mesh_active() -> bool:
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and not am.empty:
            return True
    except Exception:
        pass
    try:  # legacy `with mesh:` context (pre-use_mesh API)
        import warnings

        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            env_mesh = jax.interpreters.pxla.thread_resources.env.physical_mesh
        return not env_mesh.empty
    except Exception:
        return False


def constrain(x, *logical_axes: str | None, rules: ShardingRules | None = None):
    """Annotate activation x with logical axes; no-op without a mesh."""
    if not _mesh_active():
        return x
    spec = logical_to_spec(logical_axes, rules)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x
