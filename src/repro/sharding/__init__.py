"""Logical-axis sharding rules for the model/substrate stack."""

from repro.sharding.specs import (  # noqa: F401
    ShardingRules,
    DEFAULT_RULES,
    logical_to_spec,
    constrain,
    set_rules,
    get_rules,
)
