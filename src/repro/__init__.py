"""repro: communication-efficient distributed sparse LDA on JAX.

A multi-pod training/serving framework reproducing Tian & Gu (2016),
with a transformer model zoo substrate, Pallas TPU kernels for the
covariance hot path, and a one-shot debiased-averaging estimation
schedule mapped onto mesh collectives.
"""

__version__ = "1.0.0"
