"""Pallas TPU kernel: fused soft-threshold (the ADMM shrink step).

``out = sign(x) * max(|x| - t, 0)``

VPU-bound elementwise op; fusing sign/abs/sub/max/mul into one VMEM
pass halves the HBM traffic versus the naive 5-op jnp chain when XLA
fails to fuse across the scan-carry boundary of the ADMM loop.
Blocks are (block_r, 128)-aligned lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_R = 256
DEFAULT_BLOCK_C = 512


def _soft_threshold_kernel(x_ref, t_ref, o_ref):
    x = x_ref[...]
    t = t_ref[0]
    o_ref[...] = jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)


@functools.partial(jax.jit, static_argnames=("block_r", "block_c", "interpret"))
def soft_threshold_pallas(
    x: jnp.ndarray,
    t: jnp.ndarray | float,
    *,
    block_r: int = DEFAULT_BLOCK_R,
    block_c: int = DEFAULT_BLOCK_C,
    interpret: bool = False,
) -> jnp.ndarray:
    """Soft threshold an array of rank 1 or 2 by scalar ``t``."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    r, c = x.shape
    br = min(block_r, r)
    bc = min(block_c, c)
    r_pad = (-r) % br
    c_pad = (-c) % bc
    if r_pad or c_pad:
        x = jnp.pad(x, ((0, r_pad), (0, c_pad)))
    t_arr = jnp.asarray(t, x.dtype).reshape((1,))

    grid = ((r + r_pad) // br, (c + c_pad) // bc)
    out = pl.pallas_call(
        _soft_threshold_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, t_arr)
    out = out[:r, :c]
    return out[0] if squeeze else out
