"""The cached spectral factor every Dantzig/CLIME solve shares.

The exact two-block ADMM iteration (repro.core.dantzig) solves
``(A^2 + I) beta = v`` once per iteration.  With one symmetric
eigendecomposition ``A = Q L Q^T`` the solve is two matmuls:
``Q diag(1/(L^2+1)) Q^T v``.  Crucially the factor depends ONLY on A --
not on the right-hand sides, not on the box radius ``lam``, not on the
ADMM penalty ``rho`` (rho enters the iteration only through the shrink
threshold and the scaled duals).  One factorization therefore serves

  * the direction solve AND the CLIME solve of a worker (both share
    the machine's Sigma_hat),
  * every point of a lambda-regularization-path sweep
    (:mod:`repro.core.path`),
  * every warm-rho re-solve.

:class:`SpectralFactor` packages (A, Q, 1/(L^2+1)) as a NamedTuple --
a pytree, so it flows through jit/vmap/shard_map like any array -- and
every solver entry point accepts it in place of the raw matrix
(`repro.core.solver_dispatch.solve_dantzig`, the scan solver, the
fused kernel wrappers, the CLIME entry points).  Contract: whoever
computes Sigma factorizes it ONCE via :func:`spectral_factor`; callees
never re-factorize a factor they are handed (see DESIGN.md §6).

This lives in the kernels layer because the fused Pallas kernel
(:mod:`repro.kernels.dantzig_fused`) consumes the factor directly as
operands; the core layer imports downward, never the reverse.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class SpectralFactor(NamedTuple):
    """``sigma = q @ diag(evals) @ q.T``, the worker's one factorization.

    The factor stores the RAW eigenvalues; the ADMM diagonal
    ``1 / (evals^2 + 1)`` (the diagonal of ``(sigma^2 + I)^{-1}`` in
    the eigenbasis) is exposed as the :attr:`inv_eig` property and
    recomputed at each use site.  Deliberate: ``eigh`` is bitwise
    stable across jit boundaries but the elementwise chain is not (XLA
    fuses ``e*e + 1`` into an fma inside a larger program), so deriving
    ``inv_eig`` inside the consumer's own trace keeps solves handed a
    factor bit-for-bit identical to solves that factorize internally.
    The recompute is d elementwise ops -- free next to the O(d^3) eigh
    it caches.
    """

    sigma: jnp.ndarray  # (d, d) the matrix itself (PSD sample covariance)
    q: jnp.ndarray  # (d, d) orthonormal eigenvectors
    evals: jnp.ndarray  # (d,) eigenvalues

    @property
    def d(self) -> int:
        return self.sigma.shape[0]

    @property
    def inv_eig(self) -> jnp.ndarray:
        """(d,) diagonal of ``(sigma^2 + I)^{-1}`` in the eigenbasis."""
        return 1.0 / (self.evals * self.evals + 1.0)


def spectral_factor(sigma: jnp.ndarray) -> SpectralFactor:
    """Factorize ``sigma`` ONCE (the only ``eigh`` call in the system).

    O(d^3); everything downstream of it is (d, d) x (d, k) matmuls.
    """
    evals, q = jnp.linalg.eigh(sigma)
    return SpectralFactor(sigma, q, evals)


def as_spectral_factor(a) -> SpectralFactor:
    """Pass a factor through; factorize a raw matrix."""
    if isinstance(a, SpectralFactor):
        return a
    return spectral_factor(a)


def sigma_of(a) -> jnp.ndarray:
    """The raw matrix behind either calling convention."""
    return a.sigma if isinstance(a, SpectralFactor) else a
