"""Pallas TPU kernel: mean-centered Gram matrix  (X - mu)^T (X - mu).

This is the O(N d^2 / m) hot spot of the paper (computing the pooled
intra-class covariance on each machine).  TPU adaptation: tile the
(d, d) output into MXU-aligned (bd, bd) VMEM blocks and stream
(bn, bd) row-chunks of the sample shard from HBM, accumulating the
rank-bn update on the MXU.  Centering is fused: the mean is subtracted
on the fly in VMEM rather than materializing a centered copy of X in
HBM (saves one full read+write of the data set).

Grid: (d/bd, d/bd, n/bn); the n-axis is the innermost reduction so each
output tile stays resident in VMEM across the whole reduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_N = 256
DEFAULT_BLOCK_D = 128


def _gram_kernel(x_i_ref, x_j_ref, mu_i_ref, mu_j_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xi = x_i_ref[...] - mu_i_ref[...]  # (bn, bd) centered in VMEM
    xj = x_j_ref[...] - mu_j_ref[...]
    o_ref[...] += jax.lax.dot_general(
        xi,
        xj,
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_d", "interpret")
)
def gram_pallas(
    x: jnp.ndarray,
    mu: jnp.ndarray,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
) -> jnp.ndarray:
    """(X - mu)^T (X - mu) with X: (n, d), mu: (d,). Returns (d, d) f32.

    n and d are padded to block multiples; mu is broadcast to a (1, d)
    row so BlockSpec tiling stays 2D.  Padding rows are set equal to mu
    so they contribute exactly zero to the Gram accumulation.
    """
    n, d = x.shape
    bn = min(block_n, max(8, n))
    bd = min(block_d, d)
    n_pad = (-n) % bn
    d_pad = (-d) % bd
    if d_pad:
        x = jnp.pad(x, ((0, 0), (0, d_pad)))
        mu = jnp.pad(mu, (0, d_pad))
    if n_pad:
        # pad with the mean so centered padding rows are exactly 0
        x = jnp.concatenate([x, jnp.broadcast_to(mu, (n_pad, d + d_pad))], axis=0)
    dp = d + d_pad
    np_ = n + n_pad
    mu2 = mu[None, :]

    grid = (dp // bd, dp // bd, np_ // bn)
    out = pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, k: (k, i)),
            pl.BlockSpec((bn, bd), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bd), lambda i, j, k: (0, i)),
            pl.BlockSpec((1, bd), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bd, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dp, dp), jnp.float32),
        interpret=interpret,
    )(x, x, mu2, mu2)
    return out[:d, :d]
