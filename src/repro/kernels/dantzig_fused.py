"""Pallas TPU kernel: blocked, grid-parallel fused Dantzig/CLIME ADMM solve.

The per-machine hot loop of the paper is the batched two-block ADMM in
repro.core.dantzig.  Lowered through XLA it re-reads the (d, d) matrix
A, the spectral factor Q and the diagonal (L^2+1)^-1 from HBM on every
one of ~500 iterations -- the dry-run shows the estimator is
memory-bound 107:1 (compute 1.4e-5 s vs memory 1.5e-3 s per solve at
d=256).

TPU adaptation: the columns of a CLIME batch are independent problems
that share only the loop-invariant operands (A, Q, inv).  The kernel
therefore tiles the column batch k over a 1-D Pallas grid:

  grid step i owns columns [i*block_k, (i+1)*block_k) and runs the
  ENTIRE solve for its block in VMEM -- a lax.fori_loop whose body is
  four (d, d) x (d, block_k) MXU matmuls plus clip/shrink on the VPU.

``block_k`` is chosen (see :func:`pick_block_k`) so that
``A + Q + inv + b + out + 4 ADMM state blocks + loop temporaries`` fit
the per-core VMEM budget.  A and Q are re-fetched once per block --
still ~iters x fewer HBM bytes per block than the XLA scan path, which
re-streams them every iteration.  When the whole batch fits, the grid
collapses to a single step and the kernel degenerates to the original
whole-array design.

Tail handling: k is padded up to a multiple of ``block_k`` with
neutral columns (b = 0, lam = 1, rho = 1, whose exact solution is 0),
so *any* (d, k) shape is exact; the wrapper slices the pad columns off
the output.  Columns never interact, so the pad is mathematically
inert, not just approximately so.

``rho`` is a per-column (1, k) *operand* rather than a compile-time
scalar: callers (repro.core.clime) can reuse warm per-column rho
estimates across calls without triggering recompilation.  ``iters``
and ``alpha`` remain static.  No adaptive rho inside the kernel (it is
per-column scalar control flow); the exact-ADMM iteration is robust to
a fixed rho (see EXPERIMENTS.md SSPerf-A1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.spectral import SpectralFactor

# Per-core VMEM is ~16 MiB; leave headroom for Mosaic's own buffers,
# semaphores and the pipeline's double-buffered operand copies.
DEFAULT_VMEM_BUDGET = 12 * 2**20

# Per-backend fast-memory budgets for the blocking model.
#   tpu: the VMEM budget above.
#   cpu: mirrors the TPU budget on purpose -- the Pallas interpreter has
#        no real VMEM limit, but honoring the same blocking means shapes
#        validated on CPU pick the same scan/fused/fused_blocked path
#        they will pick on TPU (see DESIGN.md §5).
#   gpu: the kernel keeps A and Q resident, which maps to shared memory
#        on GPU (~228 KB on H100); with headroom that routes realistic
#        CLIME shapes (d >= ~128) to the XLA scan solver, which is the
#        right call -- the fused design is a TPU design.
BACKEND_VMEM_BUDGETS = {
    "tpu": DEFAULT_VMEM_BUDGET,
    "cpu": DEFAULT_VMEM_BUDGET,
    "gpu": 192 * 2**10,
}


def backend_vmem_budget(backend: str | None = None) -> int:
    """Fast-memory budget for ``backend`` (None = the active backend)."""
    if backend is None:
        backend = jax.default_backend()
    return BACKEND_VMEM_BUDGETS.get(backend, DEFAULT_VMEM_BUDGET)


def fused_block_vmem_bytes(d: int, block_k: int) -> int:
    """f32 VMEM footprint of one grid step of the fused kernel.

    a, q: d*d each; inv: d; b, out: d*block_k; lam, rho: block_k;
    ADMM state (z, w, u1, u2): 4*d*block_k; loop temporaries
    (beta, ab, relaxed copies): ~3*d*block_k.
    """
    return 4 * (2 * d * d + d + 9 * d * block_k + 2 * block_k)


def pick_block_k(d: int, k: int, budget: int = DEFAULT_VMEM_BUDGET) -> int | None:
    """Largest column-block size whose grid step fits the VMEM budget.

    Returns ``k`` when the whole batch fits in one block, a smaller
    (lane-friendly) block size when it must be tiled, or ``None`` when
    even a single column cannot fit (A + Q alone blow the budget) --
    callers fall back to the XLA scan solver in that case.
    """
    avail = budget // 4 - 2 * d * d - d
    if avail <= 0:
        return None
    bk = avail // (9 * d + 2)
    if bk < 1:
        return None
    if bk >= k:
        return k
    # round down to a full-lane multiple when possible; below 128 the
    # budget forces lane-padded tiles either way, so settle for the
    # f32 sublane granularity
    if bk >= 128:
        bk = (bk // 128) * 128
    elif bk >= 8:
        bk = (bk // 8) * 8
    return bk


def _fused_admm_kernel(a_ref, q_ref, inv_ref, b_ref, lam_ref, rho_ref, out_ref,
                       *, iters: int, alpha: float):
    a = a_ref[...]  # (d, d) VMEM-resident across all iterations
    q = q_ref[...]  # (d, d) eigenvectors of A
    inv = inv_ref[...]  # (d, 1) 1/(eig^2 + 1)
    b = b_ref[...]  # (d, block_k) this grid step's column block
    lam = lam_ref[...]  # (1, block_k)
    inv_rho = 1.0 / rho_ref[...]  # (1, block_k) per-column shrink threshold

    def matmul(m, x):
        return jax.lax.dot_general(
            m, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    def solve_m(v):  # (A^2 + I)^{-1} v  via the cached spectral factor
        return matmul(q, inv * matmul(q.T, v))

    def shrink(x, t):
        return jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)

    zeros = jnp.zeros_like(b)

    def body(_, carry):
        z, w, u1, u2 = carry
        beta = solve_m(matmul(a, z + b - u1) + (w - u2))
        ab = matmul(a, beta)
        ab_r = alpha * ab + (1.0 - alpha) * (z + b)
        beta_r = alpha * beta + (1.0 - alpha) * w
        z = jnp.clip(ab_r - b + u1, -lam, lam)
        w = shrink(beta_r + u2, inv_rho)
        u1 = u1 + ab_r - z - b
        u2 = u2 + beta_r - w
        return z, w, u1, u2

    z, w, u1, u2 = jax.lax.fori_loop(0, iters, body, (zeros, zeros, zeros, zeros))
    out_ref[...] = w


@functools.partial(
    jax.jit, static_argnames=("iters", "alpha", "block_k", "interpret")
)
def dantzig_fused_pallas(
    a: jnp.ndarray | SpectralFactor,
    q: jnp.ndarray | None = None,
    inv_eig: jnp.ndarray | None = None,
    b: jnp.ndarray | None = None,
    lam: jnp.ndarray | float | None = None,
    rho: jnp.ndarray | float = 1.0,
    *,
    iters: int = 500,
    alpha: float = 1.7,
    block_k: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Blocked fused ADMM solve.

    Args:
      a, q:    (d, d) f32 matrix and its eigenvectors -- or pass a
               :class:`~repro.kernels.spectral.SpectralFactor` as ``a``
               (with ``q``/``inv_eig`` omitted) and the factor's pieces
               are used as-is; the kernel never re-factorizes.
      inv_eig: (d,) 1/(eig^2 + 1).
      b:       (d, k) right-hand sides.
      lam:     scalar or (k,) per-column box radius.
      rho:     scalar or (k,) per-column fixed ADMM penalty (an operand:
               changing it does NOT recompile).
      block_k: columns per grid step (None = whole batch in one block).
    Returns the sparse ADMM copy w: (d, k) f32.
    """
    if isinstance(a, SpectralFactor):
        if q is not None or inv_eig is not None:
            raise TypeError(
                "dantzig_fused_pallas: pass EITHER a SpectralFactor OR "
                "(a, q, inv_eig), not both")
        a, q, inv_eig = a.sigma, a.q, a.inv_eig
    elif q is None or inv_eig is None:
        raise TypeError(
            "dantzig_fused_pallas: a raw matrix needs q and inv_eig "
            "(or pass a SpectralFactor as the first argument)")
    if b is None:
        raise TypeError("dantzig_fused_pallas: missing right-hand sides b")
    if lam is None:
        raise TypeError("dantzig_fused_pallas: missing box radius lam")
    d, k = b.shape
    if block_k is None:
        block_k = k
    block_k = max(1, min(block_k, k))
    inv2 = inv_eig.reshape(d, 1).astype(jnp.float32)
    lam2 = jnp.broadcast_to(jnp.asarray(lam, jnp.float32), (k,)).reshape(1, k)
    rho2 = jnp.broadcast_to(jnp.asarray(rho, jnp.float32), (k,)).reshape(1, k)
    b2 = b.astype(jnp.float32)

    num_blocks = -(-k // block_k)
    k_pad = num_blocks * block_k
    if k_pad != k:
        # neutral tail columns: b = 0, lam = 1, rho = 1 solve exactly to 0
        pad = k_pad - k
        b2 = jnp.pad(b2, ((0, 0), (0, pad)))
        lam2 = jnp.pad(lam2, ((0, 0), (0, pad)), constant_values=1.0)
        rho2 = jnp.pad(rho2, ((0, 0), (0, pad)), constant_values=1.0)

    kernel = functools.partial(_fused_admm_kernel, iters=iters, alpha=alpha)
    out = pl.pallas_call(
        kernel,
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((d, 1), lambda i: (0, 0)),
            pl.BlockSpec((d, block_k), lambda i: (0, i)),
            pl.BlockSpec((1, block_k), lambda i: (0, i)),
            pl.BlockSpec((1, block_k), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((d, block_k), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((d, k_pad), jnp.float32),
        interpret=interpret,
    )(a.astype(jnp.float32), q.astype(jnp.float32), inv2, b2, lam2, rho2)
    return out[:, :k] if k_pad != k else out
