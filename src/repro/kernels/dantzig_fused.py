"""Pallas TPU kernel: blocked, grid-parallel fused Dantzig/CLIME ADMM solve.

The per-machine hot loop of the paper is the batched two-block ADMM in
repro.core.dantzig.  Lowered through XLA it re-reads the (d, d) matrix
A, the spectral factor Q and the diagonal (L^2+1)^-1 from HBM on every
one of ~500 iterations -- the dry-run shows the estimator is
memory-bound 107:1 (compute 1.4e-5 s vs memory 1.5e-3 s per solve at
d=256).

TPU adaptation: the columns of a CLIME batch are independent problems
that share only the loop-invariant operands (A, Q, inv).  The kernel
therefore tiles the column batch k over a 1-D Pallas grid:

  grid step i owns columns [i*block_k, (i+1)*block_k) and runs the
  ENTIRE solve for its block in VMEM -- an iteration loop whose body is
  four (d, d) x (d, block_k) MXU matmuls plus clip/shrink on the VPU.

``block_k`` is chosen (see :func:`pick_block_k`) so that
``A + Q + inv + b + out + ADMM state blocks + loop temporaries`` fit
the per-core VMEM budget.  A and Q are re-fetched once per block --
still ~iters x fewer HBM bytes per block than the XLA scan path, which
re-streams them every iteration.  When the whole batch fits, the grid
collapses to a single step and the kernel degenerates to the original
whole-array design.

Tail handling: k is padded up to a multiple of ``block_k`` with
neutral columns (b = 0, lam = 1, rho = 1, zero warm state, whose exact
solution is 0), so *any* (d, k) shape is exact; the wrapper slices the
pad columns off the output.  Columns never interact, so the pad is
mathematically inert, not just approximately so -- and because the
neutral column's residual is exactly zero from the first iteration, a
pad column can never hold a block's convergence gate open.

``rho`` is a per-column (1, k) *operand* rather than a compile-time
scalar: callers (repro.core.clime) can reuse warm per-column rho
estimates across calls without triggering recompilation.  ``iters``
and ``alpha`` remain static.  No adaptive rho inside the kernel (it is
per-column scalar control flow); the exact-ADMM iteration is robust to
a fixed rho (see EXPERIMENTS.md SSPerf-A1).

Convergence-adaptive mode (DESIGN.md §7): with a static ``tol`` the
fixed ``fori_loop`` becomes a bounded ``lax.while_loop`` over chunks of
``check_every`` iterations.  After each chunk the kernel computes the
block's max scaled-ADMM residual IN VMEM (no HBM round trip):

  r_pri  = max_j max(||A beta_j - z_j - b_j||_inf, ||beta_j - w_j||_inf)
  s_dual = max_j rho_j * ||A dz_j + dw_j||_inf

(dz/dw are the last in-chunk iteration deltas of the constraint
copies) and stops the whole block when ``max(r_pri, s_dual) <= tol``,
capped at exactly ``max_iters`` iterations (the final chunk is
clamped when ``check_every`` does not divide it).  The executed
iteration count per block rides out as an extra (1, num_blocks) int32
output.  The adaptive kernel also takes and returns the full ADMM
state ``(z, w, u1, u2)`` (:class:`AdmmState`), so a solve can RESUME
from an earlier solution -- glmnet-style warm starts across lambda-path
re-sweeps -- instead of restarting from zero.  ``tol=None`` keeps the
original fixed-iteration kernel (bit-exact with the pre-adaptive
golden pins).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.spectral import SpectralFactor

# Per-core VMEM is ~16 MiB; leave headroom for Mosaic's own buffers,
# semaphores and the pipeline's double-buffered operand copies.
DEFAULT_VMEM_BUDGET = 12 * 2**20

# Per-backend fast-memory budgets for the blocking model.
#   tpu: the VMEM budget above.
#   cpu: mirrors the TPU budget on purpose -- the Pallas interpreter has
#        no real VMEM limit, but honoring the same blocking means shapes
#        validated on CPU pick the same scan/fused/fused_blocked path
#        they will pick on TPU (see DESIGN.md §5).
#   gpu: the kernel keeps A and Q resident, which maps to shared memory
#        on GPU (~228 KB on H100); with headroom that routes realistic
#        CLIME shapes (d >= ~128) to the XLA scan solver, which is the
#        right call -- the fused design is a TPU design.
BACKEND_VMEM_BUDGETS = {
    "tpu": DEFAULT_VMEM_BUDGET,
    "cpu": DEFAULT_VMEM_BUDGET,
    "gpu": 192 * 2**10,
}


class AdmmState(NamedTuple):
    """The full two-block ADMM state of a (d, k) batch -- a pytree.

    Passing a previous solve's state back in resumes the iteration
    instead of restarting from zero (the warm-start carry of lambda-path
    re-sweeps, riding next to the per-column warm ``rho``).  Leaves may
    carry extra leading axes (e.g. the (L, d, k) per-lambda states of a
    folded path sweep).
    """

    z: jnp.ndarray  # box-constrained copy of A beta - b
    w: jnp.ndarray  # sparse copy of beta (the solution estimate)
    u1: jnp.ndarray  # scaled dual for A beta - z = b
    u2: jnp.ndarray  # scaled dual for beta - w = 0

    @classmethod
    def zeros(cls, d: int, k: int, dtype=jnp.float32) -> "AdmmState":
        z = jnp.zeros((d, k), dtype)
        return cls(z, z, z, z)


class FusedSolveResult(NamedTuple):
    """Adaptive-mode kernel outputs (see DESIGN.md §7)."""

    beta: jnp.ndarray  # (d, k) the sparse ADMM copy w
    state: AdmmState  # full final state, resumable
    iters: jnp.ndarray  # (num_blocks,) int32 executed iterations per block


def backend_vmem_budget(backend: str | None = None) -> int:
    """Fast-memory budget for ``backend`` (None = the active backend)."""
    if backend is None:
        backend = jax.default_backend()
    return BACKEND_VMEM_BUDGETS.get(backend, DEFAULT_VMEM_BUDGET)


def fused_block_vmem_bytes(d: int, block_k: int, state_io: bool = False) -> int:
    """f32 VMEM footprint of one grid step of the fused kernel.

    Fixed mode: a, q: d*d each; inv: d; b, out: d*block_k; lam, rho:
    block_k; ADMM state (z, w, u1, u2): 4*d*block_k; loop temporaries
    (beta, ab, relaxed copies): ~3*d*block_k.

    ``state_io`` (the adaptive / warm-start kernel) additionally
    streams the 4-leaf :class:`AdmmState` both IN and OUT and carries
    the last-iteration deltas (dz, dw) for the dual residual: b + 4
    state-in + 4 state-out + ~5 temporaries = 14 (d, block_k) arrays,
    plus the residual row temporaries.
    """
    per_col = 14 if state_io else 9
    rows = 4 if state_io else 2
    return 4 * (2 * d * d + d + per_col * d * block_k + rows * block_k)


def pick_block_k(d: int, k: int, budget: int = DEFAULT_VMEM_BUDGET,
                 state_io: bool = False) -> int | None:
    """Largest column-block size whose grid step fits the VMEM budget.

    Returns ``k`` when the whole batch fits in one block, a smaller
    (lane-friendly) block size when it must be tiled, or ``None`` when
    even a single column cannot fit (A + Q alone blow the budget) --
    callers fall back to the XLA scan solver in that case.
    ``state_io`` selects the adaptive kernel's larger per-column
    footprint (see :func:`fused_block_vmem_bytes`).
    """
    avail = budget // 4 - 2 * d * d - d
    if avail <= 0:
        return None
    per_col = 14 if state_io else 9
    rows = 4 if state_io else 2
    bk = avail // (per_col * d + rows)
    if bk < 1:
        return None
    if bk >= k:
        return k
    # round down to a full-lane multiple when possible; below 128 the
    # budget forces lane-padded tiles either way, so settle for the
    # f32 sublane granularity
    if bk >= 128:
        bk = (bk // 128) * 128
    elif bk >= 8:
        bk = (bk // 8) * 8
    return bk


def _matmul(m, x):
    return jax.lax.dot_general(
        m, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def _shrink(x, t):
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)


def _admm_iteration(a, q, inv, b, lam, inv_rho, alpha, z, w, u1, u2):
    """One exact two-block ADMM iteration (identical on every path)."""
    beta = _matmul(q, inv * _matmul(q.T, _matmul(a, z + b - u1) + (w - u2)))
    ab = _matmul(a, beta)
    ab_r = alpha * ab + (1.0 - alpha) * (z + b)
    beta_r = alpha * beta + (1.0 - alpha) * w
    z_new = jnp.clip(ab_r - b + u1, -lam, lam)
    w_new = _shrink(beta_r + u2, inv_rho)
    u1 = u1 + ab_r - z_new - b
    u2 = u2 + beta_r - w_new
    return z_new, w_new, u1, u2


def _fused_admm_kernel(a_ref, q_ref, inv_ref, b_ref, lam_ref, rho_ref, out_ref,
                       *, iters: int, alpha: float):
    """Fixed-iteration, cold-start kernel (the golden-pinned fast path)."""
    a = a_ref[...]  # (d, d) VMEM-resident across all iterations
    q = q_ref[...]  # (d, d) eigenvectors of A
    inv = inv_ref[...]  # (d, 1) 1/(eig^2 + 1)
    b = b_ref[...]  # (d, block_k) this grid step's column block
    lam = lam_ref[...]  # (1, block_k)
    inv_rho = 1.0 / rho_ref[...]  # (1, block_k) per-column shrink threshold

    zeros = jnp.zeros_like(b)

    def body(_, carry):
        z, w, u1, u2 = carry
        return _admm_iteration(a, q, inv, b, lam, inv_rho, alpha, z, w, u1, u2)

    z, w, u1, u2 = jax.lax.fori_loop(0, iters, body, (zeros, zeros, zeros, zeros))
    out_ref[...] = w


def _fused_admm_state_kernel(a_ref, q_ref, inv_ref, b_ref, lam_ref, rho_ref,
                             z0_ref, w0_ref, u10_ref, u20_ref,
                             w_ref, z_ref, u1_ref, u2_ref, it_ref,
                             *, max_iters: int, alpha: float,
                             tol: float | None, check_every: int):
    """Warm-startable kernel with full state I/O and (optionally) the
    residual-gated early exit (DESIGN.md §7).

    ``tol=None`` runs exactly ``max_iters`` iterations from the given
    state; otherwise the loop runs ``check_every``-iteration chunks
    under a bounded ``lax.while_loop``, stopping the whole block once
    its max scaled residual drops below ``tol`` (capped at exactly
    ``max_iters`` iterations -- the final chunk is clamped).
    """
    a = a_ref[...]
    q = q_ref[...]
    inv = inv_ref[...]
    b = b_ref[...]
    lam = lam_ref[...]
    rho = rho_ref[...]  # (1, block_k)
    inv_rho = 1.0 / rho
    state0 = (z0_ref[...], w0_ref[...], u10_ref[...], u20_ref[...])

    if tol is None:
        def body(_, carry):
            z, w, u1, u2 = carry
            return _admm_iteration(
                a, q, inv, b, lam, inv_rho, alpha, z, w, u1, u2)

        z, w, u1, u2 = jax.lax.fori_loop(0, max_iters, body, state0)
        it = jnp.int32(max_iters)
    else:
        def chunk_body(carry):
            it, z, w, u1, u2, _ = carry
            # the final chunk is clamped so the cap is EXACTLY max_iters
            # even when check_every does not divide it
            n = jnp.minimum(jnp.int32(check_every), max_iters - it)

            def body(_, c):
                z, w, u1, u2, _, _ = c
                zn, wn, u1n, u2n = _admm_iteration(
                    a, q, inv, b, lam, inv_rho, alpha, z, w, u1, u2)
                return zn, wn, u1n, u2n, zn - z, wn - w

            zeros = jnp.zeros_like(b)
            z, w, u1, u2, dz, dw = jax.lax.fori_loop(
                0, n, body, (z, w, u1, u2, zeros, zeros))
            # scaled-ADMM residuals of the block, entirely in VMEM:
            # one extra beta solve (4 matmuls) per chunk -- a
            # 1/check_every relative overhead on the chunk's compute.
            beta = _matmul(q, inv * _matmul(q.T, _matmul(a, z + b - u1)
                                            + (w - u2)))
            ab = _matmul(a, beta)
            r_pri = jnp.maximum(jnp.max(jnp.abs(ab - z - b)),
                                jnp.max(jnp.abs(beta - w)))
            dual_col = jnp.max(jnp.abs(_matmul(a, dz) + dw), axis=0,
                               keepdims=True)  # (1, block_k)
            s_dual = jnp.max(rho * dual_col)
            return it + n, z, w, u1, u2, jnp.maximum(r_pri, s_dual)

        def chunk_cond(carry):
            it, _, _, _, _, res = carry
            return jnp.logical_and(it < max_iters, res > tol)

        it, z, w, u1, u2, _ = jax.lax.while_loop(
            chunk_cond, chunk_body,
            (jnp.int32(0), *state0, jnp.float32(jnp.inf)))

    w_ref[...] = w
    z_ref[...] = z
    u1_ref[...] = u1
    u2_ref[...] = u2
    it_ref[...] = jnp.full((1, 1), it, jnp.int32)


def _pad_cols(x: jnp.ndarray, pad: int, value: float = 0.0) -> jnp.ndarray:
    return jnp.pad(x, ((0, 0), (0, pad)), constant_values=value)


@functools.partial(
    jax.jit,
    static_argnames=("iters", "alpha", "block_k", "interpret",
                     "tol", "check_every", "return_info"),
)
def dantzig_fused_pallas(
    a: jnp.ndarray | SpectralFactor,
    q: jnp.ndarray | None = None,
    inv_eig: jnp.ndarray | None = None,
    b: jnp.ndarray | None = None,
    lam: jnp.ndarray | float | None = None,
    rho: jnp.ndarray | float = 1.0,
    *,
    iters: int = 500,
    alpha: float = 1.7,
    block_k: int | None = None,
    interpret: bool = False,
    tol: float | None = None,
    check_every: int = 10,
    state: AdmmState | None = None,
    return_info: bool = False,
) -> jnp.ndarray | FusedSolveResult:
    """Blocked fused ADMM solve.

    Args:
      a, q:    (d, d) f32 matrix and its eigenvectors -- or pass a
               :class:`~repro.kernels.spectral.SpectralFactor` as ``a``
               (with ``q``/``inv_eig`` omitted) and the factor's pieces
               are used as-is; the kernel never re-factorizes.
      inv_eig: (d,) 1/(eig^2 + 1).
      b:       (d, k) right-hand sides.
      lam:     scalar or (k,) per-column box radius.
      rho:     scalar or (k,) per-column fixed ADMM penalty (an operand:
               changing it does NOT recompile).
      block_k: columns per grid step (None = whole batch in one block).
      tol:     static residual tolerance; None = fixed ``iters``
               iterations (bit-exact with the pre-adaptive kernel),
               else the chunked while_loop early exit (DESIGN.md §7).
      check_every: iterations per residual check (adaptive mode only).
      state:   optional :class:`AdmmState` with (d, k) leaves to resume
               from (zero-state cold start when None).
      return_info: also return the final state and per-block iteration
               counts as a :class:`FusedSolveResult`.

    Returns the sparse ADMM copy w: (d, k) f32, or a
    :class:`FusedSolveResult` when ``return_info``.
    """
    if isinstance(a, SpectralFactor):
        if q is not None or inv_eig is not None:
            raise TypeError(
                "dantzig_fused_pallas: pass EITHER a SpectralFactor OR "
                "(a, q, inv_eig), not both")
        a, q, inv_eig = a.sigma, a.q, a.inv_eig
    elif q is None or inv_eig is None:
        raise TypeError(
            "dantzig_fused_pallas: a raw matrix needs q and inv_eig "
            "(or pass a SpectralFactor as the first argument)")
    if b is None:
        raise TypeError("dantzig_fused_pallas: missing right-hand sides b")
    if lam is None:
        raise TypeError("dantzig_fused_pallas: missing box radius lam")
    d, k = b.shape
    if block_k is None:
        block_k = k
    block_k = max(1, min(block_k, k))
    inv2 = inv_eig.reshape(d, 1).astype(jnp.float32)
    lam2 = jnp.broadcast_to(jnp.asarray(lam, jnp.float32), (k,)).reshape(1, k)
    rho2 = jnp.broadcast_to(jnp.asarray(rho, jnp.float32), (k,)).reshape(1, k)
    b2 = b.astype(jnp.float32)

    num_blocks = -(-k // block_k)
    k_pad = num_blocks * block_k
    pad = k_pad - k
    if pad:
        # neutral tail columns: b = 0, lam = 1, rho = 1 (and zero warm
        # state) solve exactly to 0 AND report zero residual from the
        # first chunk, so a pad column never holds a block's
        # while_loop open
        b2 = _pad_cols(b2, pad)
        lam2 = _pad_cols(lam2, pad, 1.0)
        rho2 = _pad_cols(rho2, pad, 1.0)

    a2 = a.astype(jnp.float32)
    q2 = q.astype(jnp.float32)
    shared_specs = [
        pl.BlockSpec((d, d), lambda i: (0, 0)),
        pl.BlockSpec((d, d), lambda i: (0, 0)),
        pl.BlockSpec((d, 1), lambda i: (0, 0)),
        pl.BlockSpec((d, block_k), lambda i: (0, i)),
        pl.BlockSpec((1, block_k), lambda i: (0, i)),
        pl.BlockSpec((1, block_k), lambda i: (0, i)),
    ]

    if tol is None and state is None and not return_info:
        # the original fixed-iteration kernel: smallest VMEM footprint,
        # bit-exact with the pre-adaptive golden pins
        kernel = functools.partial(_fused_admm_kernel, iters=iters,
                                   alpha=alpha)
        out = pl.pallas_call(
            kernel,
            grid=(num_blocks,),
            in_specs=shared_specs,
            out_specs=pl.BlockSpec((d, block_k), lambda i: (0, i)),
            out_shape=jax.ShapeDtypeStruct((d, k_pad), jnp.float32),
            interpret=interpret,
        )(a2, q2, inv2, b2, lam2, rho2)
        return out[:, :k] if pad else out

    if state is None:
        state = AdmmState.zeros(d, k_pad)
    else:
        leaves = [jnp.asarray(s, jnp.float32) for s in state]
        if pad:
            leaves = [_pad_cols(s, pad) for s in leaves]
        state = AdmmState(*leaves)

    kernel = functools.partial(
        _fused_admm_state_kernel, max_iters=iters, alpha=alpha,
        tol=tol, check_every=check_every)
    col_spec = pl.BlockSpec((d, block_k), lambda i: (0, i))
    w, z, u1, u2, it = pl.pallas_call(
        kernel,
        grid=(num_blocks,),
        in_specs=shared_specs + [col_spec] * 4,
        out_specs=[col_spec] * 4 + [pl.BlockSpec((1, 1), lambda i: (0, i))],
        out_shape=[jax.ShapeDtypeStruct((d, k_pad), jnp.float32)] * 4
        + [jax.ShapeDtypeStruct((1, num_blocks), jnp.int32)],
        interpret=interpret,
    )(a2, q2, inv2, b2, lam2, rho2, *state)
    if pad:
        w, z, u1, u2 = (x[:, :k] for x in (w, z, u1, u2))
    result = FusedSolveResult(w, AdmmState(z, w, u1, u2), it.reshape(-1))
    return result if return_info else result.beta
