"""Pallas TPU kernel: fully fused Dantzig/CLIME ADMM solve (SSPerf-A2).

The per-machine hot loop of the paper is the batched two-block ADMM in
repro.core.dantzig.  Lowered through XLA it re-reads the (d, d) matrix
A, the spectral factor Q and the diagonal (L^2+1)^-1 from HBM on every
one of ~500 iterations -- the dry-run shows the estimator is
memory-bound 107:1 (compute 1.4e-5 s vs memory 1.5e-3 s per solve at
d=256).

TPU adaptation: at CLIME scale (d <= ~1024) ALL loop-invariant operands
fit in VMEM (d=256: A + Q + diag + 4 state blocks ~ 0.8 MB of the
16 MB VMEM).  This kernel runs the entire solve in ONE pallas_call --
a lax.fori_loop whose body is five (d,d)x(d,k) MXU matmuls plus
clip/shrink on the VPU -- so HBM traffic collapses to one read of
(A, Q, b) and one write of the solution: ~iters x fewer HBM bytes.

Grid: single step; every BlockSpec is the whole (VMEM-resident) array.
The batch dim k is the device's CLIME column shard (d / |model| axis).
No adaptive rho inside the kernel (it is a per-column scalar control
flow); callers pick rho once -- the exact-ADMM iteration is robust to
it (see EXPERIMENTS.md SSPerf-A1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_admm_kernel(a_ref, q_ref, inv_ref, b_ref, lam_ref, out_ref,
                       *, iters: int, rho: float, alpha: float):
    a = a_ref[...]  # (d, d) VMEM-resident across all iterations
    q = q_ref[...]  # (d, d) eigenvectors of A
    inv = inv_ref[...]  # (d, 1) 1/(eig^2 + 1)
    b = b_ref[...]  # (d, k)
    lam = lam_ref[...]  # (1, k)

    def matmul(m, x):
        return jax.lax.dot_general(
            m, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    def solve_m(v):  # (A^2 + I)^{-1} v  via the cached spectral factor
        return matmul(q, inv * matmul(q.T, v))

    def shrink(x, t):
        return jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)

    zeros = jnp.zeros_like(b)

    def body(_, carry):
        z, w, u1, u2 = carry
        beta = solve_m(matmul(a, z + b - u1) + (w - u2))
        ab = matmul(a, beta)
        ab_r = alpha * ab + (1.0 - alpha) * (z + b)
        beta_r = alpha * beta + (1.0 - alpha) * w
        z = jnp.clip(ab_r - b + u1, -lam, lam)
        w = shrink(beta_r + u2, 1.0 / rho)
        u1 = u1 + ab_r - z - b
        u2 = u2 + beta_r - w
        return z, w, u1, u2

    z, w, u1, u2 = jax.lax.fori_loop(0, iters, body, (zeros, zeros, zeros, zeros))
    out_ref[...] = w


@functools.partial(
    jax.jit, static_argnames=("iters", "rho", "alpha", "interpret")
)
def dantzig_fused_pallas(
    a: jnp.ndarray,
    q: jnp.ndarray,
    inv_eig: jnp.ndarray,
    b: jnp.ndarray,
    lam: jnp.ndarray,
    *,
    iters: int = 500,
    rho: float = 1.0,
    alpha: float = 1.7,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused ADMM solve.  a,q: (d,d) f32; inv_eig: (d,); b: (d,k); lam: (k,).

    Returns the sparse ADMM copy w: (d, k).
    """
    d, k = b.shape
    inv2 = inv_eig.reshape(d, 1).astype(jnp.float32)
    lam2 = jnp.broadcast_to(jnp.asarray(lam, jnp.float32), (k,)).reshape(1, k)
    kernel = functools.partial(
        _fused_admm_kernel, iters=iters, rho=rho, alpha=alpha
    )
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((d, 1), lambda i: (0, 0)),
            pl.BlockSpec((d, k), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((d, k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, k), jnp.float32),
        interpret=interpret,
    )(a.astype(jnp.float32), q.astype(jnp.float32), inv2,
      b.astype(jnp.float32), lam2)
