"""Jit'd public wrappers over the Pallas kernels.

On a CPU container the kernels execute under ``interpret=True``
(Pallas interpreter runs the kernel body on the host); on a real TPU
the same call sites compile to Mosaic.  Callers never pass
``interpret`` -- it is derived from the backend *per call* (NOT at
import time: tests and launch scripts may switch the backend via
``jax.config`` after this module is imported).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gram import gram_pallas
from repro.kernels.soft_threshold import soft_threshold_pallas


def _interpret() -> bool:
    """Resolve interpret-vs-Mosaic from the backend active *now*."""
    return jax.default_backend() != "tpu"


def gram(x: jnp.ndarray, mu: jnp.ndarray, **kw) -> jnp.ndarray:
    """Mean-centered Gram matrix (X - mu)^T(X - mu), float32 accumulate."""
    kw.setdefault("interpret", _interpret())
    return gram_pallas(x, mu, **kw)


def soft_threshold(x: jnp.ndarray, t, **kw) -> jnp.ndarray:
    """Fused shrink: sign(x) * max(|x| - t, 0)."""
    kw.setdefault("interpret", _interpret())
    return soft_threshold_pallas(x, t, **kw)


@functools.partial(
    jax.jit, static_argnames=("iters", "alpha", "block_k", "interpret",
                              "tol", "check_every", "return_info")
)
def _dantzig_fused_jit(a, b, lam, rho, state, *, iters, alpha, block_k,
                       interpret, tol, check_every, return_info):
    """Spectral factor (O(d^3), skipped when handed one) + the kernel."""
    from repro.kernels.dantzig_fused import dantzig_fused_pallas
    from repro.kernels.spectral import SpectralFactor, spectral_factor

    if not isinstance(a, SpectralFactor):
        a = spectral_factor(a.astype(jnp.float32))
    out = dantzig_fused_pallas(a, b=b, lam=lam, rho=rho,
                               iters=iters, alpha=alpha, block_k=block_k,
                               interpret=interpret, tol=tol,
                               check_every=check_every, state=state,
                               return_info=return_info)
    if return_info:
        return out._replace(beta=out.beta.astype(b.dtype))
    return out.astype(b.dtype)


def dantzig_fused(a, b, lam, *, iters=500, rho=1.0, alpha=1.7,
                  block_k=None, vmem_budget=None, tol=None, check_every=10,
                  state=None, return_info=False, **kw):
    """Whole Dantzig/CLIME ADMM solve in the blocked VMEM-resident kernel.

    ``a`` is either the raw (d, d) matrix -- factorized here, O(d^3)
    once per trace -- or a :class:`~repro.kernels.spectral.SpectralFactor`
    whose eigendecomposition is reused as-is (the pipeline factorizes
    Sigma_hat exactly once and threads the factor through every solve).

    ``rho`` may be a scalar or a (k,) per-column array (a traced
    operand -- warm per-column estimates do not recompile).  ``block_k``
    of None lets :func:`repro.kernels.dantzig_fused.pick_block_k` size
    the block to ``vmem_budget`` (None = the active backend's budget,
    see :func:`repro.kernels.dantzig_fused.backend_vmem_budget`).

    Convergence-adaptive mode (DESIGN.md §7): a static ``tol`` enables
    the kernel's residual-gated early exit (chunked every
    ``check_every`` iterations, capped at ``iters``); ``state`` resumes
    from a previous solve's
    :class:`~repro.kernels.dantzig_fused.AdmmState`; ``return_info``
    returns the full
    :class:`~repro.kernels.dantzig_fused.FusedSolveResult` (solution +
    state + per-block iteration counts).  Any of the three routes to
    the state-I/O kernel, whose larger VMEM footprint the blocking
    model accounts for.

    Returns a (d, k) sparse solution in ``b``'s dtype (the dispatch
    layer applies the same contract to the scan path, so toggling
    ``cfg.fused`` never changes dtypes), or the ``FusedSolveResult``
    when ``return_info``.
    """
    from repro.kernels.dantzig_fused import (
        backend_vmem_budget, fused_block_vmem_bytes, pick_block_k,
    )
    from repro.kernels.spectral import sigma_of

    interpret = kw.pop("interpret", None)
    if interpret is None:
        interpret = _interpret()
    if kw:
        raise TypeError(f"unexpected keyword arguments: {sorted(kw)}")
    if vmem_budget is None:
        vmem_budget = backend_vmem_budget()
    state_io = tol is not None or state is not None or return_info
    d = sigma_of(a).shape[0]
    squeeze = b.ndim == 1
    if squeeze:
        b = b[:, None]
        if state is not None:
            state = type(state)(*(s[:, None] for s in state))
    if block_k is None:
        block_k = pick_block_k(d, b.shape[1], vmem_budget, state_io=state_io)
        if block_k is None:
            if not interpret:
                raise ValueError(
                    f"dantzig_fused: A and Q at d={d} exceed the "
                    "VMEM budget for any column block; use the scan solver "
                    "(repro.core.solver_dispatch falls back automatically)")
            block_k = b.shape[1]  # interpreter has no VMEM limit
    elif not interpret:
        bk = max(1, min(block_k, b.shape[1]))
        if fused_block_vmem_bytes(d, bk, state_io=state_io) > vmem_budget:
            raise ValueError(
                f"dantzig_fused: block_k={block_k} at d={d} exceeds "
                "the VMEM budget; pass block_k=None to auto-size the block")
    out = _dantzig_fused_jit(a, b, lam, rho, state, iters=iters, alpha=alpha,
                             block_k=block_k, interpret=interpret, tol=tol,
                             check_every=check_every,
                             return_info=return_info)
    if return_info:
        if squeeze:
            out = out._replace(
                beta=out.beta[:, 0],
                state=type(out.state)(*(s[:, 0] for s in out.state)))
        return out
    return out[:, 0] if squeeze else out
