"""Jit'd public wrappers over the Pallas kernels.

On a CPU container the kernels execute under ``interpret=True``
(Pallas interpreter runs the kernel body on the host); on a real TPU
the same call sites compile to Mosaic.  Callers never pass
``interpret`` -- it is derived from the backend *per call* (NOT at
import time: tests and launch scripts may switch the backend via
``jax.config`` after this module is imported).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gram import gram_pallas
from repro.kernels.soft_threshold import soft_threshold_pallas


def _interpret() -> bool:
    """Resolve interpret-vs-Mosaic from the backend active *now*."""
    return jax.default_backend() != "tpu"


def gram(x: jnp.ndarray, mu: jnp.ndarray, **kw) -> jnp.ndarray:
    """Mean-centered Gram matrix (X - mu)^T(X - mu), float32 accumulate."""
    kw.setdefault("interpret", _interpret())
    return gram_pallas(x, mu, **kw)


def soft_threshold(x: jnp.ndarray, t, **kw) -> jnp.ndarray:
    """Fused shrink: sign(x) * max(|x| - t, 0)."""
    kw.setdefault("interpret", _interpret())
    return soft_threshold_pallas(x, t, **kw)


@functools.partial(
    jax.jit, static_argnames=("iters", "alpha", "block_k", "interpret")
)
def _dantzig_fused_jit(a, b, lam, rho, *, iters, alpha, block_k, interpret):
    """Spectral factor (O(d^3), cached by jit) + the blocked kernel."""
    from repro.kernels.dantzig_fused import dantzig_fused_pallas

    evals, q = jnp.linalg.eigh(a.astype(jnp.float32))
    inv_eig = 1.0 / (evals * evals + 1.0)
    out = dantzig_fused_pallas(a, q, inv_eig, b, lam, rho,
                               iters=iters, alpha=alpha, block_k=block_k,
                               interpret=interpret)
    return out.astype(b.dtype)


def dantzig_fused(a, b, lam, *, iters=500, rho=1.0, alpha=1.7,
                  block_k=None, **kw):
    """Whole Dantzig/CLIME ADMM solve in the blocked VMEM-resident kernel.

    Computes the spectral factor outside the kernel (O(d^3) once), then
    runs all iterations on-chip, one column block per grid step.

    ``rho`` may be a scalar or a (k,) per-column array (a traced
    operand -- warm per-column estimates do not recompile).  ``block_k``
    of None lets :func:`repro.kernels.dantzig_fused.pick_block_k` size
    the block to the VMEM budget.  Returns a (d, k) sparse solution in
    ``b``'s dtype (the dispatch layer applies the same contract to the
    scan path, so toggling ``cfg.fused`` never changes dtypes).
    """
    from repro.kernels.dantzig_fused import (
        DEFAULT_VMEM_BUDGET, fused_block_vmem_bytes, pick_block_k,
    )

    interpret = kw.pop("interpret", None)
    if interpret is None:
        interpret = _interpret()
    if kw:
        raise TypeError(f"unexpected keyword arguments: {sorted(kw)}")
    squeeze = b.ndim == 1
    if squeeze:
        b = b[:, None]
    if block_k is None:
        block_k = pick_block_k(a.shape[0], b.shape[1])
        if block_k is None:
            if not interpret:
                raise ValueError(
                    f"dantzig_fused: A and Q at d={a.shape[0]} exceed the "
                    "VMEM budget for any column block; use the scan solver "
                    "(repro.core.solver_dispatch falls back automatically)")
            block_k = b.shape[1]  # interpreter has no VMEM limit
    elif not interpret:
        bk = max(1, min(block_k, b.shape[1]))
        if fused_block_vmem_bytes(a.shape[0], bk) > DEFAULT_VMEM_BUDGET:
            raise ValueError(
                f"dantzig_fused: block_k={block_k} at d={a.shape[0]} exceeds "
                "the VMEM budget; pass block_k=None to auto-size the block")
    out = _dantzig_fused_jit(a, b, lam, rho, iters=iters, alpha=alpha,
                             block_k=block_k, interpret=interpret)
    return out[:, 0] if squeeze else out
