"""Jit'd public wrappers over the Pallas kernels.

On the CPU container the kernels execute under ``interpret=True``
(Pallas interpreter runs the kernel body on the host); on a real TPU
the same call sites compile to Mosaic.  Callers never pass
``interpret`` -- it is derived from the backend once at import time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gram import gram_pallas
from repro.kernels.soft_threshold import soft_threshold_pallas

_INTERPRET = jax.default_backend() != "tpu"


def gram(x: jnp.ndarray, mu: jnp.ndarray, **kw) -> jnp.ndarray:
    """Mean-centered Gram matrix (X - mu)^T(X - mu), float32 accumulate."""
    kw.setdefault("interpret", _INTERPRET)
    return gram_pallas(x, mu, **kw)


def soft_threshold(x: jnp.ndarray, t, **kw) -> jnp.ndarray:
    """Fused shrink: sign(x) * max(|x| - t, 0)."""
    kw.setdefault("interpret", _INTERPRET)
    return soft_threshold_pallas(x, t, **kw)


def dantzig_fused(a, b, lam, *, iters=500, rho=1.0, alpha=1.7, **kw):
    """Whole Dantzig/CLIME ADMM solve in one VMEM-resident kernel.

    Computes the spectral factor outside the kernel (O(d^3) once), then
    runs all iterations on-chip.  Returns (d, k) sparse solution.
    """
    from repro.kernels.dantzig_fused import dantzig_fused_pallas

    kw.setdefault("interpret", _INTERPRET)
    evals, q = jnp.linalg.eigh(a.astype(jnp.float32))
    inv_eig = 1.0 / (evals * evals + 1.0)
    squeeze = b.ndim == 1
    if squeeze:
        b = b[:, None]
    out = dantzig_fused_pallas(a, q, inv_eig, b, lam,
                               iters=iters, rho=rho, alpha=alpha, **kw)
    return out[:, 0] if squeeze else out
