"""Pallas TPU kernels for the paper's compute hot-spots.

- ``gram``: mean-centered Gram/covariance accumulation (O(N d^2 / m)).
- ``soft_threshold``: fused ADMM shrink step.
- ``dantzig_fused``: whole Dantzig/CLIME ADMM solve, column batch
  tiled over a Pallas grid so any (d, k) shape fits VMEM.
- ``spectral``: the SpectralFactor value type every solver entry point
  accepts in place of a raw matrix (one eigendecomposition per
  Sigma_hat, shared by the direction solve, CLIME, and lambda sweeps).

Each kernel ships with a pure-jnp oracle in :mod:`repro.kernels.ref`.
"""

from repro.kernels import ops, ref  # noqa: F401
