"""Pallas TPU kernels for the paper's compute hot-spots.

- ``gram``: mean-centered Gram/covariance accumulation (O(N d^2 / m)).
- ``soft_threshold``: fused ADMM shrink step.

Each kernel ships with a pure-jnp oracle in :mod:`repro.kernels.ref`.
"""

from repro.kernels import ops, ref  # noqa: F401
