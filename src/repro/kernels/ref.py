"""Pure-jnp oracles for every Pallas kernel (the correctness reference)."""

from __future__ import annotations

import jax.numpy as jnp


def gram_ref(x: jnp.ndarray, mu: jnp.ndarray) -> jnp.ndarray:
    """(X - mu)^T (X - mu) in float32."""
    xc = (x - mu[None, :]).astype(jnp.float32)
    return xc.T @ xc


def soft_threshold_ref(x: jnp.ndarray, t) -> jnp.ndarray:
    t = jnp.asarray(t, x.dtype)
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)


def hard_threshold_ref(x: jnp.ndarray, t) -> jnp.ndarray:
    t = jnp.asarray(t, x.dtype)
    return jnp.where(jnp.abs(x) > t, x, jnp.zeros_like(x))


def dantzig_fused_ref(a, q, inv_eig, b, lam, *, iters=500, rho=1.0, alpha=1.7):
    """Oracle for the fused ADMM kernel: identical math in plain jnp.

    ``rho`` may be a scalar or a (k,) per-column array, mirroring the
    kernel's per-column rho operand.
    """
    a = a.astype(jnp.float32)
    q = q.astype(jnp.float32)
    b = b.astype(jnp.float32)
    d, k = b.shape
    inv = inv_eig.reshape(d, 1).astype(jnp.float32)
    lam = jnp.broadcast_to(jnp.asarray(lam, jnp.float32), (k,)).reshape(1, k)
    rho = jnp.broadcast_to(jnp.asarray(rho, jnp.float32), (k,)).reshape(1, k)

    def solve_m(v):
        return q @ (inv * (q.T @ v))

    def shrink(x, t):
        return jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)

    z = w = u1 = u2 = jnp.zeros_like(b)
    for _ in range(iters):
        beta = solve_m(a @ (z + b - u1) + (w - u2))
        ab = a @ beta
        ab_r = alpha * ab + (1.0 - alpha) * (z + b)
        beta_r = alpha * beta + (1.0 - alpha) * w
        z = jnp.clip(ab_r - b + u1, -lam, lam)
        w = shrink(beta_r + u2, 1.0 / rho)
        u1 = u1 + ab_r - z - b
        u2 = u2 + beta_r - w
    return w
