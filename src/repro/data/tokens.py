"""Deterministic synthetic LM token pipeline.

Produces next-token-prediction batches from a stateless PRNG stream so
every data-parallel shard draws a disjoint, reproducible slice without
host coordination: shard l of step t seeds from fold_in(fold_in(key, t), l).

A light Zipfian unigram + order-2 mixing makes the loss non-trivial
(pure uniform tokens give a constant-loss plateau, useless for testing
optimizer plumbing).
"""

from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp


def _zipf_logits(vocab: int, alpha: float = 1.1) -> jnp.ndarray:
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    return -alpha * jnp.log(ranks)


def sample_batch(
    key: jax.Array, batch: int, seq_len: int, vocab: int, alpha: float = 1.1
) -> dict:
    """Returns {"tokens": (b, s), "labels": (b, s)} int32."""
    logits = _zipf_logits(vocab, alpha)
    kz, km = jax.random.split(key)
    toks = jax.random.categorical(kz, logits, shape=(batch, seq_len + 1))
    # order-2 structure: with prob .5 a token copies t-2 (learnable signal)
    copy = jax.random.bernoulli(km, 0.5, toks.shape)
    toks = jnp.where(
        copy & (jnp.arange(seq_len + 1) >= 2), jnp.roll(toks, 2, axis=1), toks
    )
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def batch_stream(
    seed: int, batch: int, seq_len: int, vocab: int, shard: int = 0
) -> Iterator[dict]:
    key = jax.random.fold_in(jax.random.PRNGKey(seed), shard)
    step = 0
    while True:
        yield sample_batch(jax.random.fold_in(key, step), batch, seq_len, vocab)
        step += 1
