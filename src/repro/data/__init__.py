"""Data pipeline substrate: synthetic token streams and Gaussian feeds."""
