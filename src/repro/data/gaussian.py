"""Sharded two-class Gaussian feed for the distributed LDA estimator.

Each "machine" (mesh data-slice) draws its own i.i.d. shard from the
same population -- matching the paper's data model, where the N samples
are split uniformly at random across m machines.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.stats.synthetic import LDAProblem, sample_machines


def machine_shards(
    seed: int, problem: LDAProblem, m: int, n1: int, n2: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Stacked shards xs: (m, n1, d), ys: (m, n2, d)."""
    return sample_machines(jax.random.PRNGKey(seed), problem, m, n1, n2)


def flat_shards(
    seed: int, problem: LDAProblem, m: int, n1: int, n2: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Same draw flattened to (m*n1, d) for mesh sharding over machines."""
    xs, ys = machine_shards(seed, problem, m, n1, n2)
    d = xs.shape[-1]
    return xs.reshape(-1, d), ys.reshape(-1, d)
