"""CLIME precision-matrix estimation (Cai, Liu & Luo 2011), eq. 3.2-3.3.

``Theta_hat = argmin ||Theta||_{1,1}  s.t.  ||Sigma_hat Theta - I||_inf <= lam'``

decomposes into ``d`` independent Dantzig problems (one per column,
RHS = e_j).  All columns share the matrix, so the whole solve batches
into one (d, d) x (d, d) matmul per ADMM iteration -- MXU-shaped.

Column parallelism: :func:`solve_clime_columns` solves an arbitrary
column block, which :mod:`repro.core.distributed` shards across the
``model`` mesh axis (each device owns ceil(d/|model|) columns).

Solves route through :mod:`repro.core.solver_dispatch`, which picks
the scan or (blocked) fused Pallas path from the shape and config.
Both entry points accept either the raw Sigma_hat or its
:class:`~repro.kernels.spectral.SpectralFactor` -- the pipeline hands
over the factor it already computed for the direction solve, so CLIME
adds zero O(d^3) work.  Both take an optional per-column ``rho`` -- on
the fused path it is a traced operand, so warm rho estimates carried
across regularization-path sweeps never recompile.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.dantzig import AdmmState, DantzigConfig, SpectralFactor
from repro.core.solver_dispatch import SolveResult, solve_dantzig, solve_dantzig_full
from repro.kernels.spectral import sigma_of


def _clime_rhs(sigma, cols: jnp.ndarray) -> jnp.ndarray:
    mat = sigma_of(sigma)
    d = mat.shape[0]
    return jnp.zeros((d, cols.shape[0]), mat.dtype).at[
        cols, jnp.arange(cols.shape[0])].set(1.0)


def solve_clime_columns(
    sigma: jnp.ndarray | SpectralFactor,
    cols: jnp.ndarray,
    lam: float | jnp.ndarray,
    cfg: DantzigConfig = DantzigConfig(),
    rho: jnp.ndarray | None = None,
    state: AdmmState | None = None,
) -> jnp.ndarray:
    """Solve CLIME for the columns indexed by ``cols``.

    ``state`` optionally resumes the column block from a previous
    solve's ADMM state (leaves (d, len(cols))) -- the warm-start carry
    of repeated re-solves, riding next to the warm per-column ``rho``.
    Returns (d, len(cols)) block of Theta_hat.
    """
    return solve_dantzig(sigma, _clime_rhs(sigma, cols), lam, cfg, rho=rho,
                         state=state)


def solve_clime_columns_full(
    sigma: jnp.ndarray | SpectralFactor,
    cols: jnp.ndarray,
    lam: float | jnp.ndarray,
    cfg: DantzigConfig = DantzigConfig(),
    rho: jnp.ndarray | None = None,
    state: AdmmState | None = None,
) -> SolveResult:
    """:func:`solve_clime_columns` returning the full warm-carry result.

    The :class:`~repro.core.solver_dispatch.SolveResult` carries the
    final per-column rho, the resumable ADMM state and the executed
    iteration counts -- what multi-round drivers and iteration
    benchmarks thread across repeated invocations (DESIGN.md §7/§8).
    """
    return solve_dantzig_full(sigma, _clime_rhs(sigma, cols), lam, cfg,
                              rho=rho, state=state)


def solve_clime(
    sigma: jnp.ndarray | SpectralFactor,
    lam: float | jnp.ndarray,
    cfg: DantzigConfig = DantzigConfig(),
    rho: jnp.ndarray | None = None,
    state: AdmmState | None = None,
    symmetrize: bool = False,
) -> jnp.ndarray:
    """Full (d, d) CLIME estimate (all columns in one batched solve).

    ``symmetrize`` applies eq. 3.3's min-magnitude symmetrization
    (:func:`symmetrize_min`) to the raw column solves -- possible here
    because this entry point owns ALL d columns (the model-axis-sharded
    column path cannot pair theta_ij with theta_ji without an extra
    (d, d) gather; see ``pipeline.worker_solves``).  Default False
    preserves the historical raw-column estimate bit-for-bit.
    """
    mat = sigma_of(sigma)
    rhs = jnp.eye(mat.shape[0], dtype=mat.dtype)
    theta = solve_dantzig(sigma, rhs, lam, cfg, rho=rho, state=state)
    return symmetrize_min(theta) if symmetrize else theta


def symmetrize_min(theta: jnp.ndarray) -> jnp.ndarray:
    """CLIME symmetrization: keep the entry of smaller magnitude.

    theta_ij <- theta_ij if |theta_ij| <= |theta_ji| else theta_ji.
    """
    take_t = jnp.abs(theta) <= jnp.abs(theta.T)
    return jnp.where(take_t, theta, theta.T)
