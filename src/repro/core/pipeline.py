"""The ONE worker pipeline behind every estimator entry point.

Algorithm 1's per-machine schedule -- sufficient statistics -> batched
Dantzig solve for the direction block -> CLIME precision columns ->
debias -- used to exist four times (``slda.debiased_local_estimator``,
``distributed._worker_debiased``, the simulated ``one_machine``
closures, ``multiclass.mc_debiased_local``), so improvements like the
blocked fused solver or the pad-and-mask column sharding landed in one
copy and missed the rest.  This module is the single implementation;
everything else is a thin head- or mesh-specific wrapper (see
DESIGN.md §3).

A :class:`DiscriminantHead` turns raw per-machine samples into
``HeadStats(sigma, rhs, aux)`` where ``rhs`` is the (d, K) block of
direction right-hand sides:

  * :class:`BinaryHead` -- the paper's two-sample problem, K = 1,
    ``rhs = (mu1 - mu2)[:, None]`` (eq. 3.1);
  * :class:`MulticlassHead` -- K classes sharing one covariance
    (Chen's multicategory one-shot schedule), ``rhs[:, k] =
    mu_k - mu_bar``; all K directions ride ONE batched solve.

:func:`worker_debiased` then runs the shared schedule:

  * the (d, K) direction block solves in one batched Dantzig call;
  * the CLIME columns solve unsharded (``model_axis=None``) or sharded
    over a mesh model axis with the pad-to-multiple + masked-gather
    scheme (any (d, |model|) pair is exact -- pad columns are clamped
    onto column d-1 and their (cols_per, K) correction rows are masked
    out of the ``all_gather``);
  * the debias correction generalizes the paper's (d,) vector to a
    (d, K) block: ``beta_tilde = beta_hat - Theta^T (Sigma beta_hat -
    rhs)``.

Every solve routes through :mod:`repro.core.solver_dispatch` (scan /
fused / fused_blocked picked from shape + config), and warm per-column
ADMM penalties thread through as ``rho_beta`` (K,) / ``rho_theta``
(columns-per-device,): on the fused paths they are traced operands, so
warm estimates carried across lambda sweeps never recompile.

Sigma_hat is factorized EXACTLY ONCE per worker
(:func:`~repro.kernels.spectral.spectral_factor`, one ``eigh``): the
direction solve and the CLIME columns both consume the same
:class:`~repro.kernels.spectral.SpectralFactor`, halving the O(d^3)
work per machine on every path, including the shard_map mesh paths
(the factorization sits inside the per-device shard function, so each
model-device factorizes its replicated Sigma_hat once).  The invariant
is pinned by the eigh-count jaxpr test in ``tests/test_spectral_path.py``.
Lambda-path sweeps extend the same sharing across an entire grid of
box radii -- see :mod:`repro.core.path`.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core.clime import (
    solve_clime_columns,
    solve_clime_columns_full,
    symmetrize_min,
)
from repro.analysis import (
    DtypePolicy,
    Param,
    PrimitiveBudget,
    VmemConformance,
    trace_contract,
)
from repro.core.dantzig import AdmmState, DantzigConfig
from repro.core.solver_dispatch import solve_dantzig, solve_dantzig_full
from repro.kernels import ops as kops
from repro.kernels.spectral import SpectralFactor, spectral_factor


class HeadStats(NamedTuple):
    """What a head hands the shared pipeline."""

    sigma: jnp.ndarray  # (d, d) pooled within-class covariance
    rhs: jnp.ndarray  # (d, K) direction right-hand sides
    aux: Any  # head-specific stats (SuffStats / MCStats)


@runtime_checkable
class DiscriminantHead(Protocol):
    """Maps raw per-machine samples to :class:`HeadStats`.

    Heads must be hashable (NamedTuples of static fields) so they can
    ride as static arguments under ``jax.jit``.
    """

    def stats(self, *data: jnp.ndarray) -> HeadStats: ...


# ---------------------------------------------------------------------------
# Sufficient statistics (canonical home; slda / multiclass re-export)
# ---------------------------------------------------------------------------


class SuffStats(NamedTuple):
    """Per-machine sufficient statistics of the two-class sample."""

    sigma: jnp.ndarray  # (d, d) pooled intra-class covariance
    mu1: jnp.ndarray  # (d,)
    mu2: jnp.ndarray  # (d,)
    n1: jnp.ndarray  # scalar
    n2: jnp.ndarray  # scalar

    @property
    def mu_d(self) -> jnp.ndarray:
        return self.mu1 - self.mu2


def suff_stats(x: jnp.ndarray, y: jnp.ndarray, use_kernel: bool | None = None) -> SuffStats:
    """Compute (Sigma_hat, mu1, mu2) from class samples X:(n1,d), Y:(n2,d).

    Sigma_hat = [sum (X_i-mu1)(X_i-mu1)^T + sum (Y_i-mu2)(Y_i-mu2)^T] / n

    ``use_kernel=None`` (default) selects the Pallas gram kernel on TPU
    and the jnp path elsewhere -- the CPU interpreter path is for
    correctness tests only, not a performance path.
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    n1, n2 = x.shape[0], y.shape[0]
    mu1 = jnp.mean(x, axis=0)
    mu2 = jnp.mean(y, axis=0)
    if use_kernel:
        g1 = kops.gram(x, mu1)
        g2 = kops.gram(y, mu2)
    else:
        xc = x - mu1[None, :]
        yc = y - mu2[None, :]
        g1 = xc.T @ xc
        g2 = yc.T @ yc
    sigma = (g1 + g2) / (n1 + n2)
    return SuffStats(sigma, mu1, mu2, jnp.asarray(n1), jnp.asarray(n2))


class MCStats(NamedTuple):
    sigma: jnp.ndarray  # (d, d) pooled within-class covariance
    means: jnp.ndarray  # (K, d) class means
    counts: jnp.ndarray  # (K,)


def mc_suff_stats(x: jnp.ndarray, labels: jnp.ndarray, num_classes: int) -> MCStats:
    """x: (n, d), labels: (n,) in [0, K) -> pooled stats.

    Within-class scatter via the one-hot trick (static shapes, no sort).
    """
    n, d = x.shape
    onehot = jax.nn.one_hot(labels, num_classes, dtype=x.dtype)  # (n, K)
    counts = jnp.sum(onehot, axis=0)  # (K,)
    sums = onehot.T @ x  # (K, d)
    means = sums / jnp.maximum(counts, 1.0)[:, None]
    centered = x - means[labels]  # (n, d)
    sigma = centered.T @ centered / n
    return MCStats(sigma, means, counts)


def mc_direction_rhs(stats: MCStats) -> jnp.ndarray:
    """(d, K) Dantzig right-hand sides ``mu_k - mu_bar`` (shared mu_bar)."""
    mu_bar = jnp.mean(stats.means, axis=0)
    return (stats.means - mu_bar[None, :]).T


# ---------------------------------------------------------------------------
# Heads
# ---------------------------------------------------------------------------


class BinaryHead(NamedTuple):
    """The paper's two-sample head: K = 1, rhs = mu1 - mu2."""

    use_kernel: bool | None = None

    def stats(self, x: jnp.ndarray, y: jnp.ndarray) -> HeadStats:
        s = suff_stats(x, y, self.use_kernel)
        return HeadStats(s.sigma, s.mu_d[:, None], s)


class MulticlassHead(NamedTuple):
    """K-class shared-covariance head: rhs[:, k] = mu_k - mu_bar."""

    num_classes: int

    def stats(self, x: jnp.ndarray, labels: jnp.ndarray) -> HeadStats:
        s = mc_suff_stats(x, labels, self.num_classes)
        return HeadStats(s.sigma, mc_direction_rhs(s), s)


# ---------------------------------------------------------------------------
# The shared worker schedule
# ---------------------------------------------------------------------------


def debias(
    sigma: jnp.ndarray,
    rhs: jnp.ndarray,
    beta_hat: jnp.ndarray,
    theta_hat: jnp.ndarray,
) -> jnp.ndarray:
    """beta_tilde = beta_hat - Theta^T (Sigma beta_hat - rhs)  (eq. 3.4).

    Shapes broadcast: (d,)/(d, K) ``rhs``/``beta_hat`` both work.
    """
    resid = sigma @ beta_hat - rhs
    return beta_hat - theta_hat.T @ resid


class WorkerSolves(NamedTuple):
    """One machine's round-zero heavy lifting, reusable across rounds.

    Everything downstream of the two ADMM solves -- the debias
    correction of the one-shot schedule AND every refinement round of
    :mod:`repro.core.rounds` -- is closed-form in these fields, so a
    T-round run pays the eigendecomposition and both solves exactly
    once.  The warm-carry fields (``rho_*`` / ``state_*`` /
    ``iters_*``) are populated only by ``full=True`` solves
    (:func:`worker_solves`); the narrow mode leaves them ``None`` and
    keeps the historical solver kernels bit-exact.
    """

    stats: HeadStats
    beta_hat: jnp.ndarray  # (d, K) biased local direction block
    theta: jnp.ndarray  # (d, cols) CLIME block ((d, d) unsharded)
    valid: jnp.ndarray | None  # (cols,) non-pad mask (sharded paths only)
    rho_beta: jnp.ndarray | None  # warm carries of the two solves
    rho_theta: jnp.ndarray | None
    state_beta: AdmmState | None
    state_theta: AdmmState | None
    iters_beta: jnp.ndarray | None  # executed ADMM iterations per column
    iters_theta: jnp.ndarray | None
    # the worker's ONE factorization, shared by both solves; carried so
    # streaming refits can snapshot it without a second eigh
    factor: "SpectralFactor | None" = None


def worker_solves(
    head: DiscriminantHead,
    *data: jnp.ndarray,
    lam,
    lam_prime,
    cfg: DantzigConfig = DantzigConfig(),
    model_axis: str | None = None,
    model_axis_size: int = 1,
    rho_beta: jnp.ndarray | None = None,
    rho_theta: jnp.ndarray | None = None,
    state_beta: AdmmState | None = None,
    state_theta: AdmmState | None = None,
    symmetrize: bool = False,
    full: bool = False,
) -> WorkerSolves:
    """Run one machine's ADMM solves (direction block + CLIME columns).

    The expensive, round-independent part of Algorithm 1's worker
    schedule: sufficient statistics, ONE eigendecomposition, the (d, K)
    direction solve and the CLIME column block.  :func:`worker_debiased`
    composes this with one :func:`apply_correction`;
    :mod:`repro.core.rounds` reuses the same result across T refinement
    rounds.

    ``symmetrize`` applies the CLIME symmetrization (eq. 3.3,
    ``theta_ij <- the smaller-magnitude of theta_ij / theta_ji``) to the
    full (d, d) Theta_hat.  It requires the UNSHARDED path: a
    model-axis device owns only its column block, and eq. 3.3 pairs
    ``theta_ij`` with ``theta_ji`` across blocks, so symmetrizing under
    sharding would need an extra (d, d) all-to-all gather -- exactly
    the communication the column sharding avoids.  ``model_axis`` +
    ``symmetrize`` therefore raises.

    ``full=False`` (the default) issues the narrow dispatched solves --
    bit-identical to the historical pipeline, the mode the golden
    pre-refactor pins require.  ``full=True`` routes both solves
    through :func:`~repro.core.solver_dispatch.solve_dantzig_full` and
    populates the warm-carry fields (final rho, resumable
    :class:`AdmmState`, executed iteration counts) -- the mode
    multi-round drivers and iteration-count benchmarks use.
    """
    if symmetrize and model_axis is not None:
        raise ValueError(
            "symmetrize=True needs the full (d, d) Theta_hat on one "
            "device; the model-axis-sharded path would need an extra "
            "(d, d) gather to pair theta_ij with theta_ji (eq. 3.3). "
            "Run with model_axis=None to symmetrize.")
    hs = head.stats(*data)
    return solves_from_stats(
        hs, lam=lam, lam_prime=lam_prime, cfg=cfg, model_axis=model_axis,
        model_axis_size=model_axis_size, rho_beta=rho_beta,
        rho_theta=rho_theta, state_beta=state_beta, state_theta=state_theta,
        symmetrize=symmetrize, full=full)


def solves_from_stats(
    hs: HeadStats,
    *,
    lam,
    lam_prime,
    cfg: DantzigConfig = DantzigConfig(),
    model_axis: str | None = None,
    model_axis_size: int = 1,
    rho_beta: jnp.ndarray | None = None,
    rho_theta: jnp.ndarray | None = None,
    state_beta: AdmmState | None = None,
    state_theta: AdmmState | None = None,
    symmetrize: bool = False,
    full: bool = False,
) -> WorkerSolves:
    """The solve body of :func:`worker_solves`, from pre-built statistics.

    Factored out so the sufficient statistics can come from somewhere
    OTHER than one machine's raw sample pass: the streaming serving
    loop (:mod:`repro.core.streaming`) accumulates chunk-merged
    :class:`HeadStats` and re-solves through this exact body, so the
    served estimator is the pipeline's estimator by construction.
    """
    # ONE eigendecomposition per worker: the direction solve and every
    # CLIME column share this factor (it is rho- and lam-independent).
    factor = spectral_factor(hs.sigma)
    d = hs.rhs.shape[0]
    if model_axis is None:
        cols = jnp.arange(d)
        valid = None
    else:
        size = model_axis_size
        idx = jax.lax.axis_index(model_axis)
        cols_per = -(-d // size)  # ceil: pad d to a multiple of size
        cols = idx * cols_per + jnp.arange(cols_per)
        valid = cols < d
        cols = jnp.minimum(cols, d - 1)
    if full:
        dir_res = solve_dantzig_full(factor, hs.rhs, lam, cfg, rho=rho_beta,
                                     state=state_beta)
        theta_res = solve_clime_columns_full(
            factor, cols, lam_prime, cfg, rho=rho_theta, state=state_theta)
        beta_hat, theta = dir_res.beta, theta_res.beta
        carries = dict(
            rho_beta=dir_res.rho, rho_theta=theta_res.rho,
            state_beta=dir_res.state, state_theta=theta_res.state,
            iters_beta=dir_res.iters, iters_theta=theta_res.iters)
    else:
        beta_hat = solve_dantzig(factor, hs.rhs, lam, cfg, rho=rho_beta,
                                 state=state_beta)
        theta = solve_clime_columns(
            factor, cols, lam_prime, cfg, rho=rho_theta, state=state_theta)
        carries = dict(rho_beta=None, rho_theta=None, state_beta=None,
                       state_theta=None, iters_beta=None, iters_theta=None)
    if symmetrize:
        theta = symmetrize_min(theta)
    return WorkerSolves(stats=hs, beta_hat=beta_hat, theta=theta,
                        valid=valid, factor=factor, **carries)


def apply_correction(
    theta: jnp.ndarray,
    valid: jnp.ndarray | None,
    resid: jnp.ndarray,
    model_axis: str | None = None,
) -> jnp.ndarray:
    """Assemble the (d, K) debias correction ``Theta^T resid``.

    The correction must use ALL d CLIME columns (Theorem 4.5's
    one-round guarantee is exact only then), so on the sharded path
    (``model_axis`` set, ``valid`` the non-pad mask from
    :func:`worker_solves`) each device contributes its (cols, K) slice,
    pad rows are masked to zero, and one intra-machine ``all_gather``
    over the model axis reassembles the full vector -- global column j
    lands at row j, pad columns sit at rows >= d and are dropped.
    """
    if model_axis is None:
        return theta.T @ resid
    corr_slice = jnp.where(valid[:, None], theta.T @ resid, 0.0)
    gathered = jax.lax.all_gather(
        corr_slice, model_axis, axis=0, tiled=True
    )  # (size * cols_per, K), device i's block at [i*cols_per, ...)
    return gathered[: resid.shape[0]]


@trace_contract(
    "pipeline.worker_debiased",
    contracts=(
        # one SpectralFactor per worker: refinement and the lambda path
        # both reuse it, so a second eigh is always a regression
        PrimitiveBudget("eigh", exact=1),
        # fused cfg: direction solve + CLIME block = exactly 2 launches;
        # scan cfg: none (a third launch means the factor stopped folding)
        PrimitiveBudget("pallas_call", exact=Param("pallas_calls")),
        # the unsharded worker communicates nothing
        PrimitiveBudget("psum", exact=0),
        PrimitiveBudget("all_gather", exact=0),
        DtypePolicy(),
        VmemConformance(),
    ),
)
def worker_debiased(
    head: DiscriminantHead,
    *data: jnp.ndarray,
    lam,
    lam_prime,
    cfg: DantzigConfig = DantzigConfig(),
    model_axis: str | None = None,
    model_axis_size: int = 1,
    rho_beta: jnp.ndarray | None = None,
    rho_theta: jnp.ndarray | None = None,
    state_beta: AdmmState | None = None,
    state_theta: AdmmState | None = None,
    symmetrize: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray, HeadStats]:
    """One machine's full debiased estimate of the (d, K) direction block.

    Args:
      head: the discriminant head (static under jit).
      data: the head's raw samples -- ``(x, y)`` for :class:`BinaryHead`,
        ``(x, labels)`` for :class:`MulticlassHead`.
      lam / lam_prime: Dantzig / CLIME box radii.
      model_axis: if set, this call must be inside shard_map over that
        mesh axis; the d CLIME columns shard across it with
        ``model_axis_size`` devices (pad-and-mask, exact for any d).
      rho_beta / rho_theta: optional warm per-column ADMM penalties for
        the direction / CLIME solves (traced on the fused paths).
      state_beta / state_theta: optional warm ADMM states for the same
        two solves (leaves (d, K) / (d, columns-per-device)) -- a
        re-solve resumes from them instead of restarting from zero,
        riding exactly like the warm rho (DESIGN.md §7).
      symmetrize: apply eq. 3.3's CLIME symmetrization to Theta_hat
        before debiasing (unsharded paths only -- see
        :func:`worker_solves`; default False preserves the historical
        raw-column debias bit-for-bit).

    Returns ``(beta_tilde, beta_hat, stats)`` with (d, K) blocks.

    The schedule decomposes as :func:`worker_solves` (suff stats + one
    eigh + both ADMM solves) followed by one closed-form
    :func:`apply_correction`; multi-round refinement
    (:mod:`repro.core.rounds`, DESIGN.md §8) reuses the same solves and
    re-applies the correction around the master's aggregate.
    """
    ws = worker_solves(
        head, *data, lam=lam, lam_prime=lam_prime, cfg=cfg,
        model_axis=model_axis, model_axis_size=model_axis_size,
        rho_beta=rho_beta, rho_theta=rho_theta,
        state_beta=state_beta, state_theta=state_theta,
        symmetrize=symmetrize,
    )
    resid = ws.stats.sigma @ ws.beta_hat - ws.stats.rhs  # (d, K)
    correction = apply_correction(ws.theta, ws.valid, resid, model_axis)
    return ws.beta_hat - correction, ws.beta_hat, ws.stats
