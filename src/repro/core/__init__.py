"""The paper's contribution: one-shot distributed sparse LDA.

Modules
-------
dantzig      two-block ADMM Dantzig-type l1 solver (the numerical engine)
solver_dispatch  scan / fused / fused-blocked solver selection
clime        CLIME precision-matrix estimation (column-parallel Dantzig)
path         lambda-regularization-path sweeps folded into one launch
             (one SpectralFactor + per-column lam/rho operands)
pipeline     THE worker schedule (head-parameterized; every estimator
             entry point wraps it)
slda         binary (K=1) face: local estimator, debias, hard threshold
multiclass   K-class face (shared covariance, one (d, K) uplink block)
distributed  Algorithm 1 over a jax mesh (shard_map + one pmean),
             binary and multiclass, plus single-device simulations
classifier   Fisher discriminant rule, evaluation metrics
transport    two-way comms abstraction: CommPlan, links, bit budgets
"""
