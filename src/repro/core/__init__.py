"""The paper's contribution: one-shot distributed sparse LDA.

Modules
-------
dantzig      linearized-ADMM Dantzig-type l1 solver (the numerical engine)
clime        CLIME precision-matrix estimation (column-parallel Dantzig)
slda         local sparse-LDA estimator, debiasing, hard threshold
distributed  Algorithm 1 over a jax mesh (shard_map + one psum)
classifier   Fisher discriminant rule, evaluation metrics
lda_head     distributed LDA readout over transformer hidden states
"""
