"""One dispatch layer for every Dantzig/CLIME solve in the system.

Every solver entry point (:mod:`repro.core.slda`, :mod:`repro.core.clime`,
:mod:`repro.core.distributed`) routes through :func:`solve_dantzig` here,
which picks the implementation from the problem shape and config:

``scan``
    The ``lax.scan`` ADMM in :func:`repro.core.dantzig.solve_dantzig_scan`.
    Selected when ``cfg.fused`` is False (it is the only path with
    residual-balancing adaptive rho), or as the fallback when the fused
    kernel cannot fit even one column block in VMEM (the two (d, d)
    operands A and Q alone exceed the budget, d ≳ 1250 at f32 with the
    default 12 MiB budget).

``fused``
    The Pallas kernel in :mod:`repro.kernels.dantzig_fused` with the
    whole (d, k) batch in one VMEM-resident grid step.

``fused_blocked``
    The same kernel with the column batch tiled over a Pallas grid;
    chosen when the single-block footprint exceeds the VMEM budget.
    Block size comes from :func:`repro.kernels.dantzig_fused.pick_block_k`
    (override with ``cfg.block_k``).

The choice is made at trace time from static shapes, so it adds zero
runtime cost and composes with jit/vmap/shard_map.  On non-TPU backends
the fused kernel runs under the Pallas interpreter -- a correctness
path, not a performance one; ``cfg.fused`` still selects it so tests
exercise identical code on every backend.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import dantzig as _dantzig
from repro.kernels import ops as kops
from repro.kernels.dantzig_fused import (
    DEFAULT_VMEM_BUDGET,
    fused_block_vmem_bytes,
    pick_block_k,
)

__all__ = [
    "SolverChoice",
    "select_solver",
    "solve_dantzig",
    "fused_block_vmem_bytes",
    "DEFAULT_VMEM_BUDGET",
]


class SolverChoice(NamedTuple):
    """Trace-time solver selection for a (d, k) Dantzig batch."""

    kind: str  # "scan" | "fused" | "fused_blocked"
    block_k: int | None = None  # columns per grid step (fused paths)


def select_solver(
    cfg: "_dantzig.DantzigConfig",
    d: int,
    k: int,
    backend: str | None = None,
) -> SolverChoice:
    """Pick the solver implementation for a (d, k) batch.

    ``backend`` is reserved for backend-specific budgets and currently
    unused: the VMEM model is TPU's, and the interpreter honors the
    same blocking so shapes validated on CPU behave identically on TPU.
    """
    del backend
    if not cfg.fused:
        return SolverChoice("scan")
    bk = pick_block_k(d, k)
    if bk is None:
        # even one column per block cannot fit next to A and Q; an
        # explicit cfg.block_k cannot override infeasibility
        return SolverChoice("scan")
    if cfg.block_k is not None:
        # an override may force FINER blocking but never a block that
        # busts the VMEM budget (bk from pick_block_k is the max that fits)
        bk = max(1, min(cfg.block_k, k, bk))
    if bk >= k:
        return SolverChoice("fused", k)
    return SolverChoice("fused_blocked", bk)


def solve_dantzig(
    a: jnp.ndarray,
    b: jnp.ndarray,
    lam,
    cfg: "_dantzig.DantzigConfig | None" = None,
    *,
    rho: jnp.ndarray | None = None,
    backend: str | None = None,
) -> jnp.ndarray:
    """Solve a (batch of) Dantzig problems via the dispatched implementation.

    Args:
      a:   (d, d) PSD matrix.
      b:   (d,) or (d, k) right-hand side(s).
      lam: scalar or (k,) per-problem box radius.
      rho: optional scalar or (k,) per-column ADMM penalty.  On the
           fused paths it is a traced operand (warm per-column
           estimates never recompile); on the scan path it seeds the
           adaptive-rho state in place of ``cfg.rho``.
    Returns beta with the same trailing shape as ``b``, in ``b``'s
    dtype on every path (so toggling ``cfg.fused`` never changes the
    output dtype).
    """
    if cfg is None:
        cfg = _dantzig.DantzigConfig()
    squeeze = b.ndim == 1
    b2 = b[:, None] if squeeze else b
    d, k = b2.shape
    choice = select_solver(cfg, d, k, backend)
    if choice.kind == "scan":
        out = _dantzig.solve_dantzig_scan(a, b2, lam, cfg, rho0=rho)
        out = out.astype(b.dtype)
    else:
        out = kops.dantzig_fused(
            a, b2, lam,
            iters=cfg.max_iters,
            rho=cfg.rho if rho is None else rho,
            alpha=cfg.alpha,
            block_k=choice.block_k,
        )
    return out[:, 0] if squeeze else out
