"""One dispatch layer for every Dantzig/CLIME solve in the system.

Every solver entry point (:mod:`repro.core.slda`, :mod:`repro.core.clime`,
:mod:`repro.core.distributed`, :mod:`repro.core.path`) routes through
:func:`solve_dantzig` here, which picks the implementation from the
problem shape and config:

``scan``
    The ``lax.scan`` ADMM in :func:`repro.core.dantzig.solve_dantzig_scan`.
    Selected when ``cfg.fused`` is False (it is the only path with
    residual-balancing adaptive rho), or as the fallback when the fused
    kernel cannot fit even one column block in the fast-memory budget
    (the two (d, d) operands A and Q alone exceed it, d ≳ 1250 at f32
    with the default TPU 12 MiB budget).

``fused``
    The Pallas kernel in :mod:`repro.kernels.dantzig_fused` with the
    whole (d, k) batch in one VMEM-resident grid step.

``fused_blocked``
    The same kernel with the column batch tiled over a Pallas grid;
    chosen when the single-block footprint exceeds the budget.  Block
    size comes from :func:`repro.kernels.dantzig_fused.pick_block_k`
    (override with ``cfg.block_k``).

The fast-memory budget is ``cfg.vmem_budget`` when set, else derived
from the backend (:func:`repro.kernels.dantzig_fused.backend_vmem_budget`):
TPU gets the 12 MiB VMEM budget, CPU mirrors it so shapes validated
under the interpreter pick the TPU's path, and GPU gets a shared-memory
-sized budget that routes realistic CLIME shapes to the scan solver
(the fused kernel is a TPU design).

Every entry point accepts either the raw (d, d) matrix or its
:class:`~repro.kernels.spectral.SpectralFactor`; a factor is threaded
to the implementation untouched, so the O(d^3) eigendecomposition
happens exactly once per Sigma_hat no matter how many solves share it.

Convergence-adaptive mode (DESIGN.md §7): ``cfg.tol`` switches every
path -- scan, fused, fused_blocked -- from the fixed-iteration
schedule to the residual-gated early exit, and every entry point
accepts a warm :class:`~repro.kernels.dantzig_fused.AdmmState` to
resume from.  :func:`solve_dantzig_full` exposes the full result
(solution, warm rho, resumable state, executed per-column iteration
counts); the narrower entry points discard what they don't return.
The adaptive fused kernel streams the 4-leaf state in AND out, so its
blocking model uses the larger ``state_io`` footprint in
``fused_block_vmem_bytes``/``pick_block_k``.

The choice is made at trace time from static shapes, so it adds zero
runtime cost and composes with jit/vmap/shard_map.  On non-TPU backends
the fused kernel runs under the Pallas interpreter -- a correctness
path, not a performance one; ``cfg.fused`` still selects it so tests
exercise identical code on every backend.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.analysis import (
    DtypePolicy,
    Param,
    PrimitiveBudget,
    VmemConformance,
    trace_contract,
)
from repro.core import dantzig as _dantzig
from repro.kernels import ops as kops
from repro.kernels.dantzig_fused import (
    DEFAULT_VMEM_BUDGET,
    AdmmState,
    backend_vmem_budget,
    fused_block_vmem_bytes,
    pick_block_k,
)
from repro.kernels.spectral import SpectralFactor  # noqa: F401  (re-export)

__all__ = [
    "SolverChoice",
    "SolveResult",
    "select_solver",
    "solve_dantzig",
    "solve_dantzig_with_rho",
    "solve_dantzig_full",
    "AdmmState",
    "fused_block_vmem_bytes",
    "backend_vmem_budget",
    "DEFAULT_VMEM_BUDGET",
]


class SolverChoice(NamedTuple):
    """Trace-time solver selection for a (d, k) Dantzig batch."""

    kind: str  # "scan" | "fused" | "fused_blocked"
    block_k: int | None = None  # columns per grid step (fused paths)


def select_solver(
    cfg: "_dantzig.DantzigConfig",
    d: int,
    k: int,
    backend: str | None = None,
    state_io: bool | None = None,
) -> SolverChoice:
    """Pick the solver implementation for a (d, k) batch.

    The fast-memory budget is ``cfg.vmem_budget`` when set, else the
    ``backend``'s budget (None = the active ``jax.default_backend()``).
    ``state_io`` selects the adaptive kernel's larger VMEM footprint
    (full ADMM state streamed in and out); None derives it from the
    config -- ``cfg.tol`` routes to the adaptive kernel.
    """
    if not cfg.fused:
        return SolverChoice("scan")
    if state_io is None:
        state_io = cfg.tol is not None
    budget = cfg.vmem_budget
    if budget is None:
        budget = backend_vmem_budget(backend)
    bk = pick_block_k(d, k, budget, state_io=state_io)
    if bk is None:
        # even one column per block cannot fit next to A and Q; an
        # explicit cfg.block_k cannot override infeasibility
        return SolverChoice("scan")
    if cfg.block_k is not None:
        # an override may force FINER blocking but never a block that
        # busts the budget (bk from pick_block_k is the max that fits)
        bk = max(1, min(cfg.block_k, k, bk))
    if bk >= k:
        return SolverChoice("fused", k)
    return SolverChoice("fused_blocked", bk)


class SolveResult(NamedTuple):
    """Everything a dispatched solve can hand back (DESIGN.md §7)."""

    beta: jnp.ndarray  # the sparse solution, trailing shape of b
    rho: jnp.ndarray  # (k,) warm per-problem ADMM penalties
    state: AdmmState  # full final state, resumable via `state=`
    iters: jnp.ndarray  # (k,) int32 executed iterations per column


def solve_dantzig(
    a: "jnp.ndarray | SpectralFactor",
    b: jnp.ndarray,
    lam,
    cfg: "_dantzig.DantzigConfig | None" = None,
    *,
    rho: jnp.ndarray | None = None,
    state: AdmmState | None = None,
    backend: str | None = None,
) -> jnp.ndarray:
    """Solve a (batch of) Dantzig problems via the dispatched implementation.

    Args:
      a:   (d, d) PSD matrix, or its precomputed
           :class:`~repro.kernels.spectral.SpectralFactor` (skips the
           O(d^3) eigendecomposition -- the pipeline shares one factor
           across the direction solve, CLIME, and lambda sweeps).
      b:   (d,) or (d, k) right-hand side(s).
      lam: scalar or (k,) per-problem box radius.
      rho: optional scalar or (k,) per-column ADMM penalty.  On the
           fused paths it is a traced operand (warm per-column
           estimates never recompile); on the scan path it seeds the
           adaptive-rho state in place of ``cfg.rho``.
      state: optional warm :class:`AdmmState` (leaves shaped like
           ``b``) to resume from instead of the zero cold start.
    Returns beta with the same trailing shape as ``b``, in ``b``'s
    dtype on every path (so toggling ``cfg.fused`` never changes the
    output dtype).
    """
    out, _ = solve_dantzig_with_rho(
        a, b, lam, cfg, rho=rho, state=state, backend=backend)
    return out


def solve_dantzig_with_rho(
    a: "jnp.ndarray | SpectralFactor",
    b: jnp.ndarray,
    lam,
    cfg: "_dantzig.DantzigConfig | None" = None,
    *,
    rho: jnp.ndarray | None = None,
    state: AdmmState | None = None,
    backend: str | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """:func:`solve_dantzig` plus the final per-problem rho.

    On the scan path the returned rho is the residual-balanced adapted
    value; on the fused paths (fixed rho) it is the input broadcast to
    (k,).  Either way it is the warm estimate to thread into the next
    solve of a regularization-path sweep.
    """
    if cfg is None:
        cfg = _dantzig.DantzigConfig()
    if cfg.tol is not None or state is not None:
        # the adaptive / warm-started modes carry full state anyway;
        # route through the full solve and discard the extras
        result = solve_dantzig_full(
            a, b, lam, cfg, rho=rho, state=state, backend=backend)
        return result.beta, result.rho
    squeeze = b.ndim == 1
    b2 = b[:, None] if squeeze else b
    d, k = b2.shape
    choice = select_solver(cfg, d, k, backend, state_io=False)
    if choice.kind == "scan":
        out, rho_final = _dantzig.solve_dantzig_scan(
            a, b2, lam, cfg, rho0=rho, return_rho=True)
        out = out.astype(b.dtype)
    else:
        rho_in = cfg.rho if rho is None else rho
        out = kops.dantzig_fused(
            a, b2, lam,
            iters=cfg.max_iters,
            rho=rho_in,
            alpha=cfg.alpha,
            block_k=choice.block_k,
            vmem_budget=cfg.vmem_budget,
        )
        rho_final = jnp.broadcast_to(
            jnp.asarray(rho_in, jnp.float32), (k,))
    if squeeze:
        return out[:, 0], rho_final if rho_final.ndim == 0 else rho_final[0]
    return out, rho_final


@trace_contract(
    "solver_dispatch.solve_dantzig_full",
    contracts=(
        # factor-fed solves must not re-factorize; raw input costs one
        PrimitiveBudget("eigh", exact=Param("eighs")),
        PrimitiveBudget("pallas_call", exact=Param("pallas_calls")),
        DtypePolicy(),
        VmemConformance(),
    ),
)
def solve_dantzig_full(
    a: "jnp.ndarray | SpectralFactor",
    b: jnp.ndarray,
    lam,
    cfg: "_dantzig.DantzigConfig | None" = None,
    *,
    rho: jnp.ndarray | None = None,
    state: AdmmState | None = None,
    backend: str | None = None,
) -> SolveResult:
    """Dispatched solve returning the full :class:`SolveResult`.

    The convergence-adaptive entry point: honors ``cfg.tol`` /
    ``cfg.check_every`` on every path (scan's while_loop, the fused
    kernel's chunked while_loop), resumes from ``state`` when given,
    and returns the final state + executed per-column iteration counts
    next to the solution and warm rho.  With ``cfg.tol=None`` it runs
    exactly ``cfg.max_iters`` iterations (from ``state`` if provided)
    and ``iters`` reports the fixed count.

    Iteration counts are reported at the solver's native granularity
    broadcast to columns: the whole batch shares one count on the scan
    path, each fused grid block shares its block's count.
    """
    if cfg is None:
        cfg = _dantzig.DantzigConfig()
    squeeze = b.ndim == 1
    b2 = b[:, None] if squeeze else b
    d, k = b2.shape
    if state is not None:
        leaves = [jnp.asarray(v) for v in state]
        leaves = [v[:, None] if v.ndim == 1 else v for v in leaves]
        state = AdmmState(*leaves)
    choice = select_solver(cfg, d, k, backend, state_io=True)
    if choice.kind == "scan":
        out, rho_final, fstate, iters = _dantzig.solve_dantzig_scan(
            a, b2, lam, cfg, rho0=rho, return_rho=True,
            state0=state, return_info=True)
        out = out.astype(b.dtype)
        iters_col = jnp.broadcast_to(iters, (k,))
    else:
        rho_in = cfg.rho if rho is None else rho
        fused = kops.dantzig_fused(
            a, b2, lam,
            iters=cfg.max_iters,
            rho=rho_in,
            alpha=cfg.alpha,
            block_k=choice.block_k,
            vmem_budget=cfg.vmem_budget,
            tol=cfg.tol,
            check_every=cfg.check_every,
            state=state,
            return_info=True,
        )
        out = fused.beta.astype(b.dtype)
        fstate = fused.state
        rho_final = jnp.broadcast_to(jnp.asarray(rho_in, jnp.float32), (k,))
        # per-block counts -> per-column (each block's columns share it)
        iters_col = jnp.repeat(fused.iters, choice.block_k or k)[:k]
    if squeeze:
        return SolveResult(
            out[:, 0],
            rho_final if rho_final.ndim == 0 else rho_final[0],
            AdmmState(*(v[:, 0] for v in fstate)),
            iters_col[0],
        )
    return SolveResult(out, rho_final, fstate, iters_col)
