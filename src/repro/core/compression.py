"""Top-k error-feedback compressed uplinks under an explicit bit budget.

The paper's headline is *communication* efficiency, yet every
refinement round of :mod:`repro.core.rounds` moves a dense (d, K)
float32 block per machine -- 32 d K bits -- even though the
round-over-round correction concentrates on a few coordinates once the
iteration contracts (Fonseca & Nadler give the theory target for
sparse estimation under explicit communication constraints; EDSL shows
sparsity-exploiting rounds keep the centralized rate).  This module is
the compressed-collective layer that makes the claim measurable in
bits (DESIGN.md §10).

The codec (per machine, per round, per direction column):

* **Selection** is top-k on the DELTA ``|u - ref|``, where ``u =
  message + residual`` and ``ref`` is the round's shared reference --
  the previous replicated aggregate (zeros in round 1, when the anchor
  is still per-machine).  Once the iteration contracts the delta is
  concentrated, so few coordinates carry almost all of it.
* **Transmission** sends the ABSOLUTE values ``u[idx]`` (not the
  delta), and the receiver reconstructs ``ref.at[idx].set(vals)``:
  selected coordinates land at the machine's exact float32 value,
  unselected ones keep the reference.  Set-semantics is what makes
  ``k_top = d`` bit-exact -- transmitting deltas would reconstruct
  ``ref + (u - ref)``, which float addition does NOT round-trip.
  (int8 mode quantizes the delta instead -- symmetric per-column
  scale over a small-magnitude block quantizes far better than the
  absolute values -- and reconstructs by add; quantization already
  forfeits exactness there.)
* **Error feedback**: the residual ``e' = u - decode(payload)`` -- the
  unselected delta plus any quantization error -- is carried to the
  next round's message, a per-machine carry exactly like the warm
  ``AdmmState``/``SpectralFactor`` carries.  The compressed stream
  then telescopes: what a machine has not yet sent is never dropped,
  only delayed, so the refinement fixed point (DESIGN.md §8) is
  unchanged.  With ``k_top = d`` and no quantization the codec is the
  identity and the residual is EXACTLY zero forever (pinned in
  ``tests/test_compression.py`` against the PR 5 goldens).
* **Exact bit accounting** (:func:`uplink_bits` /
  :func:`dense_uplink_bits`): what one machine actually puts on the
  wire, counted at the wire dtypes the collective moves -- the same
  numbers the :class:`repro.analysis.contracts.AxisPayloadBits` trace
  contract pins on the jaxpr, so "compressed" is an asserted property
  of the lowered program, not a comment.
* **Sparse aggregation** (:func:`sparse_mean_mesh` /
  :func:`decode_mean`): the dense per-round ``pmean`` is replaced by
  an ``all_gather`` of the (k_top, K) value/index pairs over the data
  axes -- the ONLY data crossing them -- followed by a local
  per-machine reconstruction and machine-axis mean, the SAME reduction
  order as the dense path's ``jnp.mean``/``pmean``.

Everything here is stateless and mesh-agnostic; :mod:`repro.core.rounds`
threads it through both the shard_map and the vmap-simulated drivers.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "Compression",
    "Payload",
    "QUANTIZE_MODES",
    "decode",
    "decode_mean",
    "decode_stack",
    "dense_uplink_bits",
    "ef_step",
    "encode",
    "gather_payloads",
    "index_bits",
    "sparse_mean_mesh",
    "uplink_bits",
    "wire_index_dtype",
    "wire_value_dtype",
]

# wire width of one transmitted value, per quantization mode
QUANTIZE_MODES = {None: 32, "bf16": 16, "int8": 8}
# int8 mode ships one float32 scale per direction column
SCALE_BITS = 32


def wire_index_dtype(d: int) -> jnp.dtype:
    """The narrowest integer dtype whose range covers row indices [0, d).

    Row indices travel at this width -- int16 up to d = 32767, int32
    beyond -- and :func:`index_bits` counts the same dtype, so the
    analytic accounting and the traced collective payload agree.  (An
    entropy coder could get to ceil(log2 d) bits; the accounting here
    counts the wire format the collective actually moves, not a
    hypothetical one.)
    """
    return jnp.int16 if d <= jnp.iinfo(jnp.int16).max else jnp.int32


def index_bits(d: int) -> int:
    """Wire width of one transmitted row index (see wire_index_dtype)."""
    return jnp.iinfo(wire_index_dtype(d)).bits


class Compression(NamedTuple):
    """Static description of the per-round uplink codec.

    Hashable (ints + str), so it rides as a static argument under
    ``jax.jit`` exactly like :class:`~repro.core.dantzig.DantzigConfig`
    -- changing the codec recompiles, using it does not.

    Attributes:
      k_top: coordinates kept per direction column (1 <= k_top <= d).
        ``k_top = d`` keeps everything -- the identity codec.
      quantize: wire format of the transmitted values -- ``None``
        (float32 absolute values), ``"bf16"`` (bfloat16 absolute
        values), or ``"int8"`` (8-bit symmetric per-column delta
        quantization; one float32 scale per column rides along).
    """

    k_top: int
    quantize: str | None = None

    def validate(self, d: int) -> None:
        if self.quantize not in QUANTIZE_MODES:
            raise ValueError(
                f"quantize must be one of {sorted(map(str, QUANTIZE_MODES))}, "
                f"got {self.quantize!r}")
        if not 1 <= self.k_top <= d:
            raise ValueError(
                f"k_top must be in [1, d={d}], got {self.k_top}")


class Payload(NamedTuple):
    """One machine's per-round uplink, at wire dtypes.

    ``values``/``indices`` are (k_top, K); ``scales`` is the (K,)
    float32 dequantization scale in int8 mode and ``None`` otherwise
    (``None`` is an empty pytree leaf-set, so the structure is static
    per :class:`Compression` and vmaps/gathers cleanly).
    """

    values: jnp.ndarray  # (k_top, K) float32 | bfloat16 | int8
    indices: jnp.ndarray  # (k_top, K) int16/int32 row indices into [0, d)
    scales: jnp.ndarray | None  # (K,) float32, int8 mode only


def wire_value_dtype(comp: Compression) -> jnp.dtype:
    """The dtype the value payload actually travels as."""
    return {None: jnp.float32, "bf16": jnp.bfloat16,
            "int8": jnp.int8}[comp.quantize]


# ---------------------------------------------------------------------------
# Bit accounting (the numbers AxisPayloadBits pins on the jaxpr)
# ---------------------------------------------------------------------------


def uplink_bits(comp: Compression, d: int, num_cols: int) -> int:
    """Bits ONE machine puts on the wire in ONE compressed round.

    values (k_top, K) at the wire width + indices (k_top, K) at
    :func:`index_bits` width [+ the (K,) float32 scales in int8 mode].
    This is exactly the payload of :func:`sparse_mean_mesh`'s
    all_gathers, so the analytic number and the traced number must
    agree -- the ``AxisPayloadBits`` contract checks the traced side.
    """
    comp.validate(d)
    bits = comp.k_top * num_cols * (QUANTIZE_MODES[comp.quantize]
                                    + index_bits(d))
    if comp.quantize == "int8":
        bits += num_cols * SCALE_BITS
    return bits


def dense_uplink_bits(d: int, num_cols: int) -> int:
    """Bits one machine moves per DENSE round: the (d, K) float32 pmean."""
    return d * num_cols * 32


def compression_ratio(comp: Compression, d: int, num_cols: int) -> float:
    """Compressed / dense per-round uplink bits (< 1 means smaller)."""
    return uplink_bits(comp, d, num_cols) / dense_uplink_bits(d, num_cols)


# ---------------------------------------------------------------------------
# Encode / decode
# ---------------------------------------------------------------------------


def _cols(num_cols: int) -> jnp.ndarray:
    return jnp.arange(num_cols, dtype=jnp.int32)[None, :]  # (1, K)


def encode(comp: Compression, u: jnp.ndarray,
           ref: jnp.ndarray) -> Payload:
    """Select top-k of ``|u - ref|`` per column; emit wire values.

    ``u`` and ``ref`` are (d, K) float32.  Ties resolve to the lower
    row index (``lax.top_k`` order), so the encoding is deterministic.
    float32/bf16 modes transmit the absolute ``u`` values at the
    selected rows; int8 quantizes the selected deltas.
    """
    d, num_cols = u.shape
    comp.validate(d)
    delta = u - ref
    _, idx = jax.lax.top_k(jnp.abs(delta).T, comp.k_top)  # (K, k_top)
    idx_t = idx.T.astype(wire_index_dtype(d))  # (k_top, K)
    if comp.quantize == "int8":
        dvals = jnp.take_along_axis(delta.T, idx, axis=1).T  # (k_top, K)
        amax = jnp.max(jnp.abs(dvals), axis=0)  # (K,)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
        q = jnp.clip(jnp.round(dvals / scale[None, :]), -127, 127)
        return Payload(q.astype(jnp.int8), idx_t, scale)
    vals = jnp.take_along_axis(u.T, idx, axis=1).T  # (k_top, K) absolute
    if comp.quantize == "bf16":
        return Payload(vals.astype(jnp.bfloat16), idx_t, None)
    return Payload(vals.astype(jnp.float32), idx_t, None)


def decode(comp: Compression, payload: Payload,
           ref: jnp.ndarray, *, screen_nonfinite: bool = True) -> jnp.ndarray:
    """One machine's dense (d, K) reconstruction against ``ref``.

    Selected rows take the transmitted absolute value (set-semantics:
    at ``k_top = d`` with float32 values this reproduces the encoded
    block EXACTLY -- no float add round-trip); unselected rows keep
    the reference.  int8 payloads carry deltas, so they reconstruct by
    add -- quantization already forfeits exactness there.

    ``screen_nonfinite`` (default) replaces non-finite reconstructed
    coordinates with the reference: a single NaN in one machine's int8
    scale would otherwise ride the scatter_add into the shared
    aggregate and poison every later round.  For finite wire values
    the ``where`` selects the reconstruction bit-for-bit, so the
    identity-codec and golden pins are unaffected.  The fault-aware
    aggregation of :mod:`repro.core.rounds` decodes RAW
    (``screen_nonfinite=False``) instead, so its per-machine screen
    can zero the whole contribution rather than keep a ref-filled one.
    """
    num_cols = payload.values.shape[1]
    rows = payload.indices.astype(jnp.int32)  # widen off-wire for scatter
    if comp.quantize == "int8":
        deltas = payload.values.astype(jnp.float32) * payload.scales[None, :]
        out = ref + jnp.zeros_like(ref).at[
            rows, _cols(num_cols)].add(deltas)
    else:
        vals = payload.values.astype(jnp.float32)
        out = ref.at[rows, _cols(num_cols)].set(vals)
    if screen_nonfinite:
        out = jnp.where(jnp.isfinite(out), out, ref)
    return out


def ef_step(
    comp: Compression,
    message: jnp.ndarray,
    residual: jnp.ndarray,
    ref: jnp.ndarray,
) -> tuple[Payload, jnp.ndarray]:
    """One error-feedback compression step against the round's reference.

    ``u = message + residual`` is encoded; the new residual is
    everything of ``u`` the receiver will not see -- the unselected
    delta AND any quantization error -- replayed into the next round's
    message.  With the identity codec (``k_top = d``, no quantization)
    ``decode(encode(u)) == u`` elementwise, so the residual is exactly
    zero forever (the invariant the k_top=d regression pins).
    """
    u = message + residual
    payload = encode(comp, u, ref)
    return payload, u - decode(comp, payload, ref)


# ---------------------------------------------------------------------------
# Sparse aggregation: the compressed round's collective
# ---------------------------------------------------------------------------


def decode_stack(
    comp: Compression, payloads: Payload, ref: jnp.ndarray,
    *, screen_nonfinite: bool = True,
) -> jnp.ndarray:
    """Each machine's dense reconstruction: (m, k_top, K) leaves -> (m, d, K).

    Vmapped :func:`decode` against the SHARED reference.  The
    fault-aware aggregation decodes raw (``screen_nonfinite=False``)
    so its per-machine screen sees the poisoned values it must reject.
    """
    if comp.quantize == "int8":
        return jax.vmap(
            lambda v, i, s: decode(comp, Payload(v, i, s), ref,
                                   screen_nonfinite=screen_nonfinite)
        )(payloads.values, payloads.indices, payloads.scales)
    return jax.vmap(
        lambda v, i: decode(comp, Payload(v, i, None), ref,
                            screen_nonfinite=screen_nonfinite)
    )(payloads.values, payloads.indices)


def decode_mean(
    comp: Compression, payloads: Payload, ref: jnp.ndarray
) -> jnp.ndarray:
    """Mean of machine-stacked payloads: (m, k_top, K) leaves -> (d, K).

    Reconstructs each machine's dense (d, K) contribution against the
    SHARED reference (vmapped :func:`decode`) and means over the
    machine axis -- the same reduction the dense path's
    ``jnp.mean``/``pmean`` performs, which is what keeps the
    ``k_top = d`` identity case bit-exact with it.
    """
    return jnp.mean(decode_stack(comp, payloads, ref), axis=0)


def gather_payloads(
    comp: Compression, payload: Payload, data_axes: Sequence[str]
) -> Payload:
    """All-gather one machine's payload leaves over the data axes.

    The ONLY data a compressed round moves across the data axes, at
    wire dtypes -- exactly what the ``AxisPayloadBits`` trace contract
    pins.  Returns the (m, ...)-stacked :class:`Payload` every machine
    then reconstructs identically.
    """
    axes = tuple(data_axes)
    return Payload(
        jax.lax.all_gather(payload.values, axes),
        jax.lax.all_gather(payload.indices, axes),
        jax.lax.all_gather(payload.scales, axes)
        if comp.quantize == "int8" else None,
    )


def sparse_mean_mesh(
    comp: Compression,
    payload: Payload,
    ref: jnp.ndarray,
    data_axes: Sequence[str],
) -> jnp.ndarray:
    """The compressed round's collective, from inside shard_map.

    Replaces the dense (d, K) ``pmean`` over ``data_axes`` with the
    payload gather of :func:`gather_payloads` followed by the local
    reconstruction + mean of :func:`decode_mean`.  Returns the
    replicated (d, K) aggregate.
    """
    return decode_mean(comp, gather_payloads(comp, payload, data_axes), ref)
