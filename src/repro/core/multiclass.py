"""Multi-class distributed sparse LDA (the paper's stated future work).

Extension of Algorithm 1 to K classes sharing one covariance
(Chen's multicategory one-shot schedule):

  * discriminant directions  beta_k* = Theta* (mu_k - mu_bar), where
    mu_bar is the grand mean of class means -- all K directions solve
    Dantzig problems with the SAME matrix Sigma_hat, so the whole
    multi-class estimation is ONE batched solve (the k directions ride
    the same (d,d) x (d,K) MXU matmuls the CLIME columns use);
  * debiasing reuses the single CLIME estimate Theta_hat:
      beta_tilde_k = beta_hat_k - Theta_hat^T (Sigma_hat beta_hat_k - mu_dk);
  * aggregation stays one round: each machine uplinks a (d, K) block
    (still O(dK) bytes, no covariance travels);
  * classification: argmax_k (Z - mu_k/2)^T beta_k + log pi_k (equal
    priors by default), reducing to the paper's rule at K=2.

The worker schedule lives ONCE in :mod:`repro.core.pipeline`
(:func:`mc_debiased_local` wraps ``pipeline.worker_debiased`` with a
:class:`~repro.core.pipeline.MulticlassHead`), so every solve routes
through :mod:`repro.core.solver_dispatch` -- ``cfg.fused`` dispatches
the batched (d, K) direction solve and the CLIME columns to the
(blocked) fused Pallas kernel exactly as the binary path does.  Mesh
execution is :func:`repro.core.distributed.distributed_mc_slda_shardmap`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import classifier
from repro.core import path as _path
from repro.core import pipeline
from repro.core import rounds as _rounds
from repro.core.dantzig import DantzigConfig
from repro.core.pipeline import (  # noqa: F401
    MCStats,
    MulticlassHead,
    mc_direction_rhs,
    mc_suff_stats,
)
from repro.core.slda import hard_threshold
from repro.core.solver_dispatch import solve_dantzig

__all__ = [
    "MCStats",
    "mc_suff_stats",
    "mc_direction_rhs",
    "local_mc_slda",
    "mc_debias",
    "mc_debiased_local",
    "mc_debiased_local_path",
    "mc_multi_round_slda",
    "simulated_distributed_mc_slda",
    "simulated_naive_mc_slda",
    "centralized_mc_slda",
    "mc_classify",
]


def local_mc_slda(
    stats: MCStats, lam, cfg: DantzigConfig = DantzigConfig()
) -> jnp.ndarray:
    """Batched estimation of all K directions: returns (d, K)."""
    return solve_dantzig(stats.sigma, mc_direction_rhs(stats), lam, cfg)


def mc_debias(stats: MCStats, beta_hat: jnp.ndarray, theta_hat: jnp.ndarray) -> jnp.ndarray:
    """beta_tilde_k = beta_hat_k - Theta^T (Sigma beta_hat_k - mu_dk)."""
    return pipeline.debias(stats.sigma, mc_direction_rhs(stats), beta_hat, theta_hat)


def mc_debiased_local(
    x: jnp.ndarray,
    labels: jnp.ndarray,
    num_classes: int,
    lam: float,
    lam_prime: float | None = None,
    cfg: DantzigConfig = DantzigConfig(),
    symmetrize: bool = False,
) -> tuple[jnp.ndarray, MCStats]:
    """Full worker-side pipeline: returns (beta_tilde (d, K), stats).

    ``symmetrize`` debiases with the eq.-3.3-symmetrized Theta_hat
    (unsharded full-CLIME path only; default False keeps the
    historical raw-column debias).
    """
    beta_tilde, _, hs = pipeline.worker_debiased(
        MulticlassHead(num_classes), x, labels,
        lam=lam, lam_prime=lam if lam_prime is None else lam_prime, cfg=cfg,
        symmetrize=symmetrize,
    )
    return beta_tilde, hs.aux


def mc_multi_round_slda(
    xs: jnp.ndarray,
    labels: jnp.ndarray,
    num_classes: int,
    lam: float,
    lam_prime: float,
    t: float,
    rounds: int = 3,
    cfg: DantzigConfig = DantzigConfig(),
    compression: "_rounds.Compression | None" = None,
    faults: "_rounds.FaultSchedule | None" = None,
    staleness: int = 0,
    aggregation: "_rounds.Aggregation | None" = None,
    comm: "_rounds.CommPlan | None" = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """T-round refined K-class estimator on stacked machine draws.

    The large-m face (DESIGN.md §8): xs (m, n, d) / labels (m, n) ->
    (beta_bar (d, K), means (K, d)) after ``rounds`` O(dK)
    communication rounds sharing one set of per-machine solves.
    ``comm`` (a hashable :class:`~repro.core.transport.CommPlan`,
    DESIGN.md §13) carries the whole comms config; the legacy
    ``compression`` / ``faults`` / ``staleness`` / ``aggregation``
    kwargs remain as deprecation shims (DESIGN.md §10/§11).
    """
    return simulated_distributed_mc_slda(
        xs, labels, num_classes, lam, lam_prime, t, cfg, rounds,
        compression, faults, staleness, aggregation, comm)


def mc_debiased_local_path(
    x: jnp.ndarray,
    labels: jnp.ndarray,
    num_classes: int,
    lams: jnp.ndarray,
    lam_prime: float | None = None,
    cfg: DantzigConfig = DantzigConfig(),
    rho_beta: jnp.ndarray | None = None,
    state_beta: "_path.AdmmState | None" = None,
    symmetrize: bool = False,
) -> _path.WorkerPathResult:
    """All K directions at EVERY lambda in one folded launch.

    The K-class analogue of
    :func:`repro.core.slda.debiased_local_estimator_path`: the K*L
    direction columns ride one blocked fused call, and one
    eigendecomposition + one CLIME solve serve the whole sweep (see
    :mod:`repro.core.path`).  ``lam_prime=None`` pins the CLIME radius
    to the middle of the grid.  Returns the (L, d, K)-blocked
    :class:`~repro.core.path.WorkerPathResult`.
    """
    lams = jnp.asarray(lams)
    if lam_prime is None:
        lam_prime = lams[lams.shape[0] // 2]
    return _path.worker_debiased_path(
        MulticlassHead(num_classes), x, labels,
        lams=lams, lam_prime=lam_prime, cfg=cfg, rho_beta=rho_beta,
        state_beta=state_beta, symmetrize=symmetrize,
    )


@functools.partial(jax.jit, static_argnames=("num_classes", "cfg", "rounds",
                                             "compression", "faults",
                                             "staleness", "aggregation",
                                             "comm"))
def simulated_distributed_mc_slda(
    xs: jnp.ndarray,
    labels: jnp.ndarray,
    num_classes: int,
    lam: float,
    lam_prime: float,
    t: float,
    cfg: DantzigConfig = DantzigConfig(),
    rounds: int = 1,
    compression: "_rounds.Compression | None" = None,
    faults: "_rounds.FaultSchedule | None" = None,
    staleness: int = 0,
    aggregation: "_rounds.Aggregation | None" = None,
    comm: "_rounds.CommPlan | None" = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """xs: (m, n, d), labels: (m, n) -> (beta_bar (d, K), means (K, d)).

    The vmap axis is the machine; the master aggregation is one mean of
    (d, K) blocks per round + hard threshold -- the multi-class
    analogue of the paper's schedule (``rounds=1`` one-shot, T > 1
    refined around the aggregate, DESIGN.md §8).  ``comm`` (a hashable
    :class:`~repro.core.transport.CommPlan`, DESIGN.md §13) carries
    the whole comms config; the legacy ``compression`` / ``faults`` /
    ``staleness`` / ``aggregation`` kwargs remain as deprecation shims
    (DESIGN.md §10/§11).  Mesh-executed twin:
    :func:`repro.core.distributed.distributed_mc_slda_shardmap`.
    """
    beta_bar, ws = _rounds.simulate_multi_round(
        MulticlassHead(num_classes), (xs, labels),
        lam=lam, lam_prime=lam_prime, rounds=rounds, cfg=cfg,
        comm=comm, compression=compression, faults=faults,
        staleness=staleness, aggregation=aggregation)
    return hard_threshold(beta_bar, t), jnp.mean(ws.stats.aux.means, axis=0)


@functools.partial(jax.jit, static_argnames=("num_classes", "cfg"))
def simulated_naive_mc_slda(
    xs: jnp.ndarray,
    labels: jnp.ndarray,
    num_classes: int,
    lam: float,
    cfg: DantzigConfig = DantzigConfig(),
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Baseline: average the biased local estimators (no debias/HT)."""

    def one_machine(x, lab):
        stats = mc_suff_stats(x, lab, num_classes)
        return local_mc_slda(stats, lam, cfg), stats.means

    betas, means = jax.vmap(one_machine)(xs, labels)
    return jnp.mean(betas, axis=0), jnp.mean(means, axis=0)


def centralized_mc_slda(
    x: jnp.ndarray,
    labels: jnp.ndarray,
    num_classes: int,
    lam: float,
    cfg: DantzigConfig = DantzigConfig(),
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Centralized baseline: pool everything, one batched solve (m=1, n=N)."""
    stats = mc_suff_stats(x, labels, num_classes)
    return local_mc_slda(stats, lam, cfg), stats.means


def mc_classify(
    z: jnp.ndarray,
    beta: jnp.ndarray,
    means: jnp.ndarray,
    priors: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """z: (n, d), beta: (d, K), means: (K, d) -> predicted class (n,).

    score_k(Z) = (Z - mu_k / 2)^T beta_k + log pi_k; ``priors=None``
    means equal priors (the + log pi_k term is a constant shift and
    drops out of the argmax).  At K=2 the equal-prior rule reduces to
    the paper's Fisher rule up to the shared mu_bar shift.  The score
    computation is shared with the serving hot path through
    :func:`repro.core.classifier.classify_scores` (bit-identical to
    the pre-dedup inline form, pinned by the parity tests).
    """
    return jnp.argmax(classifier.classify_scores(z, beta, means, priors),
                      axis=-1)
