"""Multi-class distributed sparse LDA (the paper's stated future work).

Extension of Algorithm 1 to K classes sharing one covariance:

  * discriminant directions  beta_k* = Theta* (mu_k - mu_bar), where
    mu_bar is the grand mean of class means -- all K directions solve
    Dantzig problems with the SAME matrix Sigma_hat, so the whole
    multi-class estimation is ONE batched solve (the k directions ride
    the same (d,d) x (d,K) MXU matmuls the CLIME columns use);
  * debiasing reuses the single CLIME estimate Theta_hat:
      beta_tilde_k = beta_hat_k - Theta_hat^T (Sigma_hat beta_hat_k - mu_dk);
  * aggregation stays one round: each machine uplinks a (d, K) block
    (still O(dK) bytes, no covariance travels);
  * classification: argmax_k (Z - mu_k/2)^T beta_k + log pi_k (equal
    priors by default), reducing to the paper's rule at K=2.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.clime import solve_clime
from repro.core.dantzig import DantzigConfig, solve_dantzig
from repro.core.slda import hard_threshold


class MCStats(NamedTuple):
    sigma: jnp.ndarray  # (d, d) pooled within-class covariance
    means: jnp.ndarray  # (K, d) class means
    counts: jnp.ndarray  # (K,)


def mc_suff_stats(x: jnp.ndarray, labels: jnp.ndarray, num_classes: int) -> MCStats:
    """x: (n, d), labels: (n,) in [0, K) -> pooled stats.

    Within-class scatter via the one-hot trick (static shapes, no sort).
    """
    n, d = x.shape
    onehot = jax.nn.one_hot(labels, num_classes, dtype=x.dtype)  # (n, K)
    counts = jnp.sum(onehot, axis=0)  # (K,)
    sums = onehot.T @ x  # (K, d)
    means = sums / jnp.maximum(counts, 1.0)[:, None]
    centered = x - means[labels]  # (n, d)
    sigma = centered.T @ centered / n
    return MCStats(sigma, means, counts)


def local_mc_slda(
    stats: MCStats, lam, cfg: DantzigConfig = DantzigConfig()
) -> jnp.ndarray:
    """Batched estimation of all K directions: returns (d, K)."""
    mu_bar = jnp.mean(stats.means, axis=0)
    rhs = (stats.means - mu_bar[None, :]).T  # (d, K)
    return solve_dantzig(stats.sigma, rhs, lam, cfg)


def mc_debias(stats: MCStats, beta_hat: jnp.ndarray, theta_hat: jnp.ndarray) -> jnp.ndarray:
    """beta_tilde_k = beta_hat_k - Theta^T (Sigma beta_hat_k - mu_dk)."""
    mu_bar = jnp.mean(stats.means, axis=0)
    rhs = (stats.means - mu_bar[None, :]).T  # (d, K)
    resid = stats.sigma @ beta_hat - rhs
    return beta_hat - theta_hat.T @ resid


def mc_debiased_local(
    x: jnp.ndarray,
    labels: jnp.ndarray,
    num_classes: int,
    lam: float,
    lam_prime: float | None = None,
    cfg: DantzigConfig = DantzigConfig(),
) -> tuple[jnp.ndarray, MCStats]:
    stats = mc_suff_stats(x, labels, num_classes)
    beta_hat = local_mc_slda(stats, lam, cfg)
    theta_hat = solve_clime(stats.sigma, lam if lam_prime is None else lam_prime, cfg)
    return mc_debias(stats, beta_hat, theta_hat), stats


@functools.partial(jax.jit, static_argnames=("num_classes", "cfg"))
def simulated_distributed_mc_slda(
    xs: jnp.ndarray,
    labels: jnp.ndarray,
    num_classes: int,
    lam: float,
    lam_prime: float,
    t: float,
    cfg: DantzigConfig = DantzigConfig(),
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """xs: (m, n, d), labels: (m, n) -> (beta_bar (d, K), means (K, d)).

    The vmap axis is the machine; the master aggregation is one mean of
    (d, K) blocks + hard threshold -- the multi-class analogue of the
    paper's one-round schedule.
    """

    def one_machine(x, lab):
        bt, stats = mc_debiased_local(x, lab, num_classes, lam, lam_prime, cfg)
        return bt, stats.means

    betas, means = jax.vmap(one_machine)(xs, labels)
    beta_bar = hard_threshold(jnp.mean(betas, axis=0), t)
    return beta_bar, jnp.mean(means, axis=0)


@functools.partial(jax.jit, static_argnames=("num_classes", "cfg"))
def simulated_naive_mc_slda(
    xs: jnp.ndarray,
    labels: jnp.ndarray,
    num_classes: int,
    lam: float,
    cfg: DantzigConfig = DantzigConfig(),
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Baseline: average the biased local estimators (no debias/HT)."""

    def one_machine(x, lab):
        stats = mc_suff_stats(x, lab, num_classes)
        return local_mc_slda(stats, lam, cfg), stats.means

    betas, means = jax.vmap(one_machine)(xs, labels)
    return jnp.mean(betas, axis=0), jnp.mean(means, axis=0)


def mc_classify(z: jnp.ndarray, beta: jnp.ndarray, means: jnp.ndarray) -> jnp.ndarray:
    """z: (n, d), beta: (d, K), means: (K, d) -> predicted class (n,).

    score_k(Z) = (Z - mu_k / 2)^T beta_k   (equal priors); at K=2 this
    reduces to the paper's Fisher rule up to the shared mu_bar shift.
    """
    proj = z @ beta  # (n, K)
    offset = 0.5 * jnp.sum(means * beta.T, axis=1)  # (K,)
    return jnp.argmax(proj - offset[None, :], axis=-1)
