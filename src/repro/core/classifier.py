"""Fisher discriminant rule and evaluation metrics (paper eq. 1.1, §5)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def classify_scores(
    z: jnp.ndarray,
    beta: jnp.ndarray,
    mu: jnp.ndarray,
    priors: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Batched K-class discriminant scores: (B, d) queries -> (B, K).

    ``score_k(Z) = (Z - mu_k / 2)^T beta_k + log pi_k`` -- one fused
    (B, d) @ (d, K) matmul plus elementwise per-class offsets; this is
    the single scoring kernel behind both ``multiclass.mc_classify``
    and the serving hot path (``streaming.classify_batch``), which
    pins its trace to exactly one ``dot_general``.  ``priors=None``
    means equal priors (a constant shift, dropped from the argmax).
    """
    proj = z @ beta  # (B, K)
    offset = 0.5 * jnp.sum(mu * beta.T, axis=1)  # (K,)
    scores = proj - offset[None, :]
    if priors is not None:
        priors = jnp.asarray(priors, scores.dtype)
        scores = scores + jnp.log(priors)[None, :]
    return scores


def fisher_rule(z: jnp.ndarray, beta: jnp.ndarray, mu1: jnp.ndarray, mu2: jnp.ndarray) -> jnp.ndarray:
    """psi(Z) = 1((Z - (mu1+mu2)/2)^T beta > 0); returns class index {0, 1}.

    Class 0 = N(mu1, Sigma), class 1 = N(mu2, Sigma).
    """
    mu = 0.5 * (mu1 + mu2)
    score = (z - mu) @ beta
    return jnp.where(score > 0, 0, 1)


@jax.jit
def misclassification_rate(
    z: jnp.ndarray, labels: jnp.ndarray, beta: jnp.ndarray, mu1: jnp.ndarray, mu2: jnp.ndarray
) -> jnp.ndarray:
    pred = fisher_rule(z, beta, mu1, mu2)
    return jnp.mean((pred != labels).astype(jnp.float32))


def support(beta: jnp.ndarray, tol: float = 0.0) -> jnp.ndarray:
    return jnp.abs(beta) > tol


@jax.jit
def f1_score(beta_hat: jnp.ndarray, beta_star: jnp.ndarray) -> jnp.ndarray:
    """Support-recovery F1 between an estimate and the truth (paper §5.1)."""
    s_hat = support(beta_hat)
    s_star = support(beta_star)
    inter = jnp.sum(s_hat & s_star).astype(jnp.float32)
    precision = inter / jnp.maximum(jnp.sum(s_hat), 1)
    recall = inter / jnp.maximum(jnp.sum(s_star), 1)
    return jnp.where(
        precision + recall > 0, 2 * precision * recall / (precision + recall), 0.0
    )


@jax.jit
def estimation_errors(beta_hat: jnp.ndarray, beta_star: jnp.ndarray) -> dict:
    diff = beta_hat - beta_star
    return {
        "l1": jnp.sum(jnp.abs(diff)),
        "l2": jnp.sqrt(jnp.sum(diff * diff)),
        "linf": jnp.max(jnp.abs(diff)),
        "rel_l2": jnp.sqrt(jnp.sum(diff * diff))
        / jnp.maximum(jnp.sqrt(jnp.sum(beta_star * beta_star)), 1e-30),
    }
