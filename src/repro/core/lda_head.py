"""Distributed sparse-LDA readout over transformer hidden states.

This is the integration point between the paper's estimator and the
model zoo: pooled final hidden states of any architecture become the
feature vectors X/Y of the two classes, and the discriminant direction
is estimated with the paper's one-shot distributed schedule -- each
data shard accumulates its own features and the master aggregation is a
single d-vector mean.

Typical use (examples/train_lda_head.py):
    feats = pool_features(model, params, tokens)        # per shard
    head  = fit_lda_head(feats_x, feats_y, lam=...)     # Algorithm 1
    pred  = head.predict(pool_features(model, params, new_tokens))
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import classifier, slda
from repro.core.dantzig import DantzigConfig
from repro.core.distributed import (
    simulated_distributed_slda,
    simulated_naive_averaged_slda,
)
from repro.models import common as mcommon


class LDAHead(NamedTuple):
    beta: jnp.ndarray  # (d,) sparse discriminant direction
    mu1: jnp.ndarray
    mu2: jnp.ndarray

    def predict(self, feats: jnp.ndarray) -> jnp.ndarray:
        """feats: (n, d) -> class in {0, 1}."""
        return classifier.fisher_rule(feats, self.beta, self.mu1, self.mu2)


def pool_features(model, params, tokens, extra_embeds=None) -> jnp.ndarray:
    """Mean-pooled final hidden states: (b, s) tokens -> (b, d_model).

    Runs the model forward without the unembed projection.
    """
    cfg = model.cfg
    x = model._embed(params, tokens, extra_embeds)

    def repeat_body(carry, layer_params):
        x, aux = carry
        from repro.models.transformer import _apply_block_train

        for i, kind in enumerate(cfg.pattern):
            x, _ = _apply_block_train(layer_params[f"b{i}"], kind, x, cfg)
        return (x, aux), None

    (x, _), _ = jax.lax.scan(repeat_body, (x, 0.0), params["layers"])
    x = mcommon.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return jnp.mean(x.astype(jnp.float32), axis=1)


def fit_lda_head(
    feats_x: jnp.ndarray,
    feats_y: jnp.ndarray,
    lam: float,
    lam_prime: float | None = None,
    threshold: float | None = None,
    machines: int = 1,
    cfg: DantzigConfig = DantzigConfig(),
    debias: bool = True,
) -> LDAHead:
    """Fit the sparse LDA head on pooled features.

    feats_x: (n1, d) class-0 features; feats_y: (n2, d) class-1.
    ``machines > 1`` splits the features into shards and runs the
    paper's distributed estimator (single-host simulation; the mesh
    version lives in repro.core.distributed).
    """
    d = feats_x.shape[-1]
    lam_prime = lam if lam_prime is None else lam_prime
    n = feats_x.shape[0] + feats_y.shape[0]
    if threshold is None:
        threshold = 2.0 * jnp.sqrt(jnp.log(d) / n)
    mu1 = jnp.mean(feats_x, axis=0)
    mu2 = jnp.mean(feats_y, axis=0)
    if machines <= 1:
        beta = slda.centralized_slda(feats_x, feats_y, lam, cfg)
        beta = slda.hard_threshold(beta, threshold)
    else:
        m = machines
        n1, n2 = feats_x.shape[0] // m, feats_y.shape[0] // m
        xs = feats_x[: m * n1].reshape(m, n1, d)
        ys = feats_y[: m * n2].reshape(m, n2, d)
        if debias:
            beta = simulated_distributed_slda(xs, ys, lam, lam_prime, threshold, cfg)
        else:
            beta = simulated_naive_averaged_slda(xs, ys, lam, cfg)
    return LDAHead(beta=beta, mu1=mu1, mu2=mu2)
