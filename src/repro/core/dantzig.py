"""Dantzig-type l1 solver via two-block ADMM with a cached spectral factor.

Solves   min ||beta||_1   s.t.  ||A beta - b||_inf <= lam
for PSD ``A`` (a sample covariance).  This is the primitive behind both
the sparse-LDA estimator (eq. 3.1, ``b = mu_d``) and every CLIME column
(eq. 3.3, ``b = e_j``).

The paper's reference solvers (parametric simplex / FastCLIME) are
branchy, pivot-based LP codes -- a poor fit for XLA/TPU.  We adapt the
algorithm to the hardware.  A first attempt (linearized ADMM) needs a
step size ~ 1/sigma_max(A)^2 and crawls on ill-conditioned covariances
(AR(0.8) at d=40 has cond ~ 81; KKT violation 0.18 after 1.5k iters).
Instead we use *exact* two-block ADMM on the splitting

    min ||w||_1 + I_{B_inf(lam)}(z)
    s.t.  A beta - z = b,     beta - w = 0

whose beta-subproblem is the linear solve (A^2 + I) beta = A(z+b-u1) +
(w-u2).  ``A`` is symmetric, so with one eigendecomposition A = Q L Q^T
(cached; O(d^3) once) the solve is Q diag(1/(L^2+1)) Q^T v -- two
matmuls.  Every iteration is therefore a handful of (d,d)x(d,k)
matmuls + clip + shrink: fixed shapes, MXU-shaped, batchable over many
right-hand sides (CLIME batches the model-axis shard of columns).
Empirically this reaches KKT 1e-3 where the linearized variant sat at
0.18 (same iteration count).  (The linearized variant also needed a
power-iteration estimate of sigma_max(A) for its step size; the exact
splitting has no such tuning knob, so that helper is gone with it.)

The cached factor is rho- and lam-independent, so it is shared across
EVERY solve on a machine: pass a
:class:`~repro.kernels.spectral.SpectralFactor` (from
:func:`~repro.kernels.spectral.spectral_factor`) in place of ``a`` to
any solver entry point and the O(d^3) eigendecomposition is skipped --
the pipeline factorizes Sigma_hat once and threads the factor through
the direction solve, the CLIME columns, and whole lambda-path sweeps
(:mod:`repro.core.path`).

Extras, all fixed-shape and `lax.scan`-able:
  * over-relaxation (alpha ~ 1.7),
  * residual-balancing adaptive rho -- free here because the cached
    factor (A^2+I) does not depend on rho; only the scaled duals and
    the shrink threshold rescale,
  * residual-gated early exit (``cfg.tol``): the fixed ``lax.scan``
    becomes a bounded ``lax.while_loop`` over ``cfg.check_every``-
    iteration chunks that stops once the batch's max scaled residual
    drops below ``tol`` (same residual definitions as the fused
    kernel -- DESIGN.md §7), and full-state warm starts (``state0`` /
    the returned :class:`~repro.kernels.dantzig_fused.AdmmState`)
    that resume a solve instead of restarting from zero.  The default
    ``cfg.tol=None`` keeps the historical fixed-iteration scan --
    bit-exact with the pre-adaptive golden pins.

Dispatch rules: :func:`solve_dantzig` is a thin shim over
:func:`repro.core.solver_dispatch.solve_dantzig`, which picks between

  * ``scan``           -- this module's ``lax.scan`` path: the default
    (``cfg.fused=False``, the only path with adaptive rho), and the
    fallback whenever A + Q cannot fit VMEM at all;
  * ``fused``          -- whole batch in one VMEM-resident Pallas call
    (``cfg.fused=True`` and the (d, k) footprint fits the budget);
  * ``fused_blocked``  -- ``cfg.fused=True`` with the column batch
    tiled over a Pallas grid (block size from ``pick_block_k``, or the
    explicit ``cfg.block_k`` override).

The selection happens at trace time from static shapes; per-column
``rho`` is a traced operand on the fused paths, so warm rho estimates
never recompile.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels.dantzig_fused import AdmmState  # noqa: F401  (re-export)
from repro.kernels.spectral import (  # noqa: F401  (re-exported API)
    SpectralFactor,
    spectral_factor,
)


class DantzigConfig(NamedTuple):
    """Solver knobs (static under jit)."""

    max_iters: int = 600
    rho: float = 1.0
    # over-relaxation coefficient (1.0 disables; 1.5-1.8 typical)
    alpha: float = 1.7
    # residual-balancing: rho *= / /= rho_tau when residuals differ by
    # more than rho_mu x; adapt every `adapt_every` iterations.
    adapt_rho: bool = True
    rho_mu: float = 10.0
    rho_tau: float = 2.0
    adapt_every: int = 10
    # use the Pallas soft-threshold kernel for the shrink step
    use_kernel: bool = False
    # run the WHOLE solve in the fused VMEM-resident Pallas kernel
    # (kernels/dantzig_fused.py; fixed rho, no adaptation).  Wide
    # batches are tiled over a Pallas grid automatically -- see the
    # dispatch rules in the module docstring.
    fused: bool = False
    # explicit columns-per-grid-step override for the fused kernel
    # (None = size the block to the VMEM budget)
    block_k: int | None = None
    # fast-memory budget in bytes for the fused kernel's blocking model
    # (None = derive from the active backend, see
    # repro.kernels.dantzig_fused.backend_vmem_budget)
    vmem_budget: int | None = None
    # residual-gated early exit (DESIGN.md §7): stop once the batch's
    # max scaled primal/dual residual drops below `tol`, checking every
    # `check_every` iterations, capped at `max_iters`.  None (default)
    # keeps the historical fixed-`max_iters` schedule bit-exact -- the
    # mode the golden pre-refactor pins require.  `tol` is static:
    # changing it recompiles (it gates trace-time control flow).
    tol: float | None = None
    check_every: int = 10


def soft_threshold(x: jnp.ndarray, t: jnp.ndarray, use_kernel: bool = False) -> jnp.ndarray:
    """Elementwise shrink.  Kernel path used on 2D batched CLIME updates."""
    if use_kernel:
        return kops.soft_threshold(x, t)
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)


class DantzigState(NamedTuple):
    z: jnp.ndarray  # (d, k) box-constrained copy of A beta - b
    w: jnp.ndarray  # (d, k) sparse copy of beta
    u1: jnp.ndarray  # scaled dual for A beta - z = b
    u2: jnp.ndarray  # scaled dual for beta - w = 0
    rho: jnp.ndarray  # (k,) per-problem penalty


def solve_dantzig(
    a: jnp.ndarray | SpectralFactor,
    b: jnp.ndarray,
    lam: jnp.ndarray | float,
    cfg: DantzigConfig = DantzigConfig(),
    *,
    rho: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Solve a (batch of) Dantzig problems sharing the same matrix ``a``.

    Thin shim over :func:`repro.core.solver_dispatch.solve_dantzig`
    (kept here so every historical import site keeps working); see the
    module docstring for the dispatch rules.

    Args:
      a:   (d, d) PSD matrix, or its :class:`SpectralFactor`.
      b:   (d,) or (d, k) right-hand side(s).
      lam: scalar or (k,) per-problem box radius.
      rho: optional scalar or (k,) per-column ADMM penalty override.
    Returns:
      beta with the same trailing shape as ``b`` (the sparse ADMM copy,
      exactly sparse thanks to the shrink step).
    """
    from repro.core import solver_dispatch  # deferred: avoids import cycle

    return solver_dispatch.solve_dantzig(a, b, lam, cfg, rho=rho)


@partial(jax.jit, static_argnames=("cfg", "return_rho", "return_info"))
def solve_dantzig_scan(
    a: jnp.ndarray | SpectralFactor,
    b: jnp.ndarray,
    lam: jnp.ndarray | float,
    cfg: DantzigConfig = DantzigConfig(),
    rho0: jnp.ndarray | None = None,
    *,
    return_rho: bool = False,
    state0: AdmmState | None = None,
    return_info: bool = False,
) -> jnp.ndarray:
    """The XLA ADMM implementation (adaptive rho lives here).

    ``a`` may be the raw matrix (factorized here) or a
    :class:`SpectralFactor` (the eigendecomposition is reused as-is).
    ``rho0`` optionally seeds the per-problem rho state (scalar or
    (k,)); it defaults to ``cfg.rho``.  With ``return_rho`` the final
    adapted per-problem rho rides along -- the warm estimate that
    lambda-path sweeps carry into their next call.

    ``state0`` optionally resumes the iteration from a previous solve's
    :class:`~repro.kernels.dantzig_fused.AdmmState` (zero cold start
    when None).  With ``cfg.tol`` set the fixed ``lax.scan`` becomes a
    bounded ``lax.while_loop`` over ``cfg.check_every``-iteration
    chunks with the residual-gated early exit of DESIGN.md §7;
    ``return_info`` appends ``(state, iters)`` to the return value:
    ``(beta[, rho], state, iters)`` with ``iters`` the scalar executed
    iteration count.
    """
    squeeze = b.ndim == 1
    if squeeze:
        b = b[:, None]
    d, k = b.shape

    # cached spectral factor of (A^2 + I); rho- and lam-independent.
    factor = a if isinstance(a, SpectralFactor) else spectral_factor(a)
    a = factor.sigma
    q = factor.q
    inv_eig = factor.inv_eig[:, None]

    lam = jnp.broadcast_to(jnp.asarray(lam, a.dtype), (k,))[None, :]

    def solve_m(v):  # (A^2 + I)^{-1} v
        return q @ (inv_eig * (q.T @ v))

    zeros = jnp.zeros((d, k), a.dtype)
    rho_init = (jnp.full((k,), cfg.rho, a.dtype) if rho0 is None
                else jnp.broadcast_to(jnp.asarray(rho0, a.dtype), (k,)))
    if state0 is None:
        init = DantzigState(
            z=zeros, w=zeros, u1=zeros, u2=zeros, rho=rho_init,
        )
    else:
        s0 = [jnp.asarray(v, a.dtype) for v in state0]
        s0 = [v[:, None] if v.ndim == 1 else v for v in s0]
        init = DantzigState(z=s0[0], w=s0[1], u1=s0[2], u2=s0[3],
                            rho=rho_init)

    alpha = cfg.alpha

    def body(state: DantzigState, i):
        z0, w0 = state.z, state.w
        rho = state.rho[None, :]
        beta = solve_m(a @ (z0 + b - state.u1) + (w0 - state.u2))
        ab = a @ beta
        # over-relaxation mixes in the previous constraint copies
        ab_r = alpha * ab + (1.0 - alpha) * (z0 + b)
        beta_r = alpha * beta + (1.0 - alpha) * w0
        z = jnp.clip(ab_r - b + state.u1, -lam, lam)
        w = soft_threshold(beta_r + state.u2, 1.0 / rho, cfg.use_kernel)
        u1 = state.u1 + ab_r - z - b
        u2 = state.u2 + beta_r - w
        if not cfg.adapt_rho:
            return DantzigState(z, w, u1, u2, state.rho), None
        # residual balancing (per problem in the batch)
        r_pri = jnp.sqrt(jnp.sum((ab - z - b) ** 2 + (beta - w) ** 2, axis=0))
        s_dual = state.rho * jnp.sqrt(
            jnp.sum((a @ (z - z0)) ** 2 + (w - w0) ** 2, axis=0)
        )
        up = r_pri > cfg.rho_mu * s_dual
        down = s_dual > cfg.rho_mu * r_pri
        do_adapt = (i % cfg.adapt_every) == 0
        scale = jnp.where(
            do_adapt & up, cfg.rho_tau, jnp.where(do_adapt & down, 1.0 / cfg.rho_tau, 1.0)
        )
        new_rho = state.rho * scale
        # scaled duals u = y/rho must rescale with rho
        u1 = u1 / scale[None, :]
        u2 = u2 / scale[None, :]
        return DantzigState(z, w, u1, u2, new_rho), None

    if cfg.tol is None:
        state, _ = jax.lax.scan(body, init, jnp.arange(cfg.max_iters))
        iters = jnp.int32(cfg.max_iters)
    else:
        # residual-gated early exit, mirroring the fused kernel's
        # chunked while_loop (DESIGN.md §7): run `check_every`
        # iterations, then compute the batch's max scaled residual and
        # stop once it drops below tol (capped at exactly max_iters --
        # the final chunk is clamped when check_every does not divide).
        check_every = cfg.check_every

        def chunk_body(carry):
            it, state, _ = carry
            n = jnp.minimum(jnp.int32(check_every), cfg.max_iters - it)

            def inner(j, c):
                state, _, _ = c
                new, _ = body(state, it + j)
                return new, new.z - state.z, new.w - state.w

            state, dz, dw = jax.lax.fori_loop(
                0, n, inner, (state, zeros, zeros))
            beta = solve_m(a @ (state.z + b - state.u1)
                           + (state.w - state.u2))
            ab = a @ beta
            r_pri = jnp.maximum(jnp.max(jnp.abs(ab - state.z - b)),
                                jnp.max(jnp.abs(beta - state.w)))
            s_dual = jnp.max(state.rho[None, :]
                             * jnp.max(jnp.abs(a @ dz + dw), axis=0,
                                       keepdims=True))
            return it + n, state, jnp.maximum(r_pri, s_dual)

        def chunk_cond(carry):
            it, _, res = carry
            return jnp.logical_and(it < cfg.max_iters, res > cfg.tol)

        iters, state, _ = jax.lax.while_loop(
            chunk_cond, chunk_body,
            (jnp.int32(0), init, jnp.asarray(jnp.inf, a.dtype)))

    beta = state.w[:, 0] if squeeze else state.w
    out = (beta,)
    if return_rho:
        out += (state.rho[0] if squeeze else state.rho,)
    if return_info:
        leaves = (state.z, state.w, state.u1, state.u2)
        if squeeze:
            leaves = tuple(v[:, 0] for v in leaves)
        out += (AdmmState(*leaves), iters)
    return out if len(out) > 1 else out[0]


def kkt_violation(a: jnp.ndarray, b: jnp.ndarray, beta: jnp.ndarray, lam) -> jnp.ndarray:
    """Max constraint violation ``max(||A beta - b||_inf - lam, 0)``."""
    if beta.ndim == 1:
        resid = a @ beta - b
        return jnp.maximum(jnp.max(jnp.abs(resid)) - lam, 0.0)
    resid = a @ beta - b
    return jnp.maximum(jnp.max(jnp.abs(resid), axis=0) - lam, 0.0)
