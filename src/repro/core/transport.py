"""The two-way transport layer: one comms abstraction for both wires.

PR 7 compressed the uplink (worker -> aggregate) and PR 8 made the
rounds fault-tolerant, but the configuration surface sprawled: four
separately-threaded kwargs (``compression=`` / ``faults=`` /
``staleness=`` / ``aggregation=``) through every entry point, a dense
f32 downlink nobody accounted for, and a fixed ``k_top`` for every
round even though the round-over-round delta concentrates (Fonseca &
Nadler analyze sparse estimation under an explicit TOTAL bit
constraint; EDSL motivates spending bits early and tapering).  This
module is the single place all of that now lives (DESIGN.md §13):

* :class:`CommPlan` -- ONE hashable static config subsuming the four
  legacy kwargs plus the new ``downlink`` codec and ``schedule``
  planner.  ``CommPlan()`` (all defaults) is the legacy dense path,
  bit-exact against the PR 5 goldens.  The legacy kwargs survive as
  thin deprecation shims resolved by :func:`resolve_comm`.
* :class:`BitBudget` -- round-adaptive schedule planners under a fixed
  TOTAL bit budget (both directions, all rounds): ``constant`` splits
  evenly, ``taper`` front-loads geometrically, ``adaptive`` follows
  caller-measured per-round delta-norm weights.  Planning happens at
  trace time (the rounds unroll statically), so the analyzer's
  ``AxisPayloadBits`` contract can pin the traced uplink AND downlink
  bits to the analytic schedule totals exactly.
* :class:`Transport` -- the per-trace resolution of a plan: a
  ``(Uplink, Downlink)`` :class:`Link` pair per round, each owning its
  direction's encode/decode/EF step against the SHARED delta reference
  (the previous *received* aggregate), plus the exact per-direction
  bit accounting.
* :func:`psum_broadcast` -- the downlink's wire.  The aggregate is
  replicated, so a broadcast could be free; putting the payload on a
  master-masked ``psum`` keeps the bits on the traced wire (where the
  contracts count them) and gives ``corrupt_payload`` a wire to hit.
  Every non-master contributes exact zeros, so the sum reproduces the
  master's payload bit-for-bit (only a -0.0 can flip to +0.0).

Both wires reuse the PR 7 codec (:mod:`repro.core.compression`)
unchanged; :mod:`repro.core.rounds` drives the round loop.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core import compression as compression_core
from repro.core.compression import (
    Compression,
    QUANTIZE_MODES,
    SCALE_BITS,
    dense_uplink_bits,
    index_bits,
    uplink_bits,
)
from repro.core.faults import Aggregation, FaultSchedule

__all__ = [
    "BitBudget",
    "CommPlan",
    "Link",
    "Transport",
    "TransportState",
    "link_bits",
    "psum_broadcast",
    "resolve_comm",
]


# ---------------------------------------------------------------------------
# Bit-budget schedule planners
# ---------------------------------------------------------------------------


class BitBudget(NamedTuple):
    """A round-adaptive codec schedule under a fixed TOTAL bit budget.

    ``total_bits`` is the budget for ONE machine's link over ALL
    ``rounds`` rounds and BOTH directions.  The planner splits it into
    per-round shares by ``mode``, gives ``down_fraction`` of each
    round's share to the downlink, and inverts the wire-format cost
    (:func:`repro.core.compression.uplink_bits`) to the largest
    ``k_top`` that fits -- all host-side at trace time, so the rounds
    still unroll statically and the jaxpr pins hold exactly.

    Modes:
      * ``"constant"``: every round gets ``total_bits / rounds``.
      * ``"taper"``: round t gets a share proportional to
        ``taper**(t-1)`` -- front-loaded for ``taper < 1`` (the EDSL
        regime: the round-1 delta is the whole anchor, later deltas
        concentrate).
      * ``"adaptive"``: round t's share is proportional to
        ``weights[t-1]`` -- caller-measured per-round residual/delta
        norms from a probe run (trace time cannot see data, so the
        measurement is an input, not a peek).

    Hashable (ints/floats/str/tuple) so it rides inside
    :class:`CommPlan` as a static jit argument.
    """

    total_bits: int
    mode: str = "taper"
    taper: float = 0.5
    quantize: str | None = "int8"
    down_fraction: float = 0.5
    weights: tuple[float, ...] | None = None

    def validate(self, rounds: int) -> None:
        if self.total_bits < 1:
            raise ValueError(f"total_bits must be >= 1, got {self.total_bits}")
        if self.mode not in ("constant", "taper", "adaptive"):
            raise ValueError(f"unknown schedule mode {self.mode!r}")
        if self.quantize not in QUANTIZE_MODES:
            raise ValueError(f"unknown quantize mode {self.quantize!r}")
        if not 0.0 <= self.down_fraction <= 1.0:
            raise ValueError(
                f"down_fraction must be in [0, 1], got {self.down_fraction}")
        if self.mode == "taper" and not self.taper > 0:
            raise ValueError(f"taper ratio must be > 0, got {self.taper}")
        if self.mode == "adaptive":
            if self.weights is None or len(self.weights) != rounds:
                raise ValueError(
                    f"adaptive mode needs weights of length rounds={rounds}, "
                    f"got {self.weights!r}")
            if not all(w > 0 for w in self.weights):
                raise ValueError(f"weights must be positive: {self.weights}")

    def round_shares(self, rounds: int) -> tuple[float, ...]:
        """Fraction of ``total_bits`` each round gets (sums to 1)."""
        self.validate(rounds)
        if self.mode == "constant":
            w = [1.0] * rounds
        elif self.mode == "taper":
            w = [self.taper ** t for t in range(rounds)]
        else:
            w = list(self.weights)
        s = sum(w)
        return tuple(wi / s for wi in w)

    def plan_rounds(
        self, d: int, num_cols: int, rounds: int
    ) -> tuple[tuple[Compression, Compression], ...]:
        """The realized per-round ``(uplink, downlink)`` codec pairs.

        Each direction's per-round bit share is inverted to the largest
        ``k_top`` whose wire cost fits (clamped to [1, d] -- the floor
        keeps every round a legal codec, the ceiling stops a generous
        budget from exceeding the identity codec).  The REALIZED total
        (:func:`schedule_bits` summed) is therefore <= ``total_bits``
        up to the per-round floors; the analyzer pins the realized
        number, not the nominal budget.
        """
        out = []
        for share in self.round_shares(rounds):
            bits_t = self.total_bits * share
            up = _fit_codec(bits_t * (1.0 - self.down_fraction),
                            d, num_cols, self.quantize)
            down = _fit_codec(bits_t * self.down_fraction,
                              d, num_cols, self.quantize)
            out.append((up, down))
        return tuple(out)


def _fit_codec(budget_bits: float, d: int, num_cols: int,
               quantize: str | None) -> Compression:
    """Largest ``k_top`` whose :func:`uplink_bits` fits ``budget_bits``."""
    per_coord = num_cols * (QUANTIZE_MODES[quantize] + index_bits(d))
    overhead = num_cols * SCALE_BITS if quantize == "int8" else 0
    k = int((budget_bits - overhead) // per_coord)
    return Compression(max(1, min(k, d)), quantize)


# ---------------------------------------------------------------------------
# CommPlan: the one static comms config
# ---------------------------------------------------------------------------


class CommPlan(NamedTuple):
    """ONE hashable static config for everything on the wire.

    Subsumes the four legacy kwargs (``compression=`` -> ``uplink``,
    ``faults=`` / ``staleness=`` / ``aggregation=`` verbatim) plus the
    downlink codec and the bit-budget schedule.  ``CommPlan()`` -- and
    therefore ``CommPlan(None)`` -- is the legacy dense fragile path,
    bit-exact against the PR 5 goldens.

    ``faults`` holds the hashable :class:`FaultSchedule` only; a
    materialized :class:`~repro.core.faults.FaultPlan` is DATA (arrays)
    and keeps riding as an operand exactly as before.  ``schedule`` is
    exclusive with the fixed per-direction codecs: a
    :class:`BitBudget` re-plans both directions every round.
    """

    uplink: Compression | None = None
    downlink: Compression | None = None
    schedule: BitBudget | None = None
    faults: FaultSchedule | None = None
    staleness: int = 0
    aggregation: Aggregation | None = None

    def validate(self) -> None:
        if self.schedule is not None and (
                self.uplink is not None or self.downlink is not None):
            raise ValueError(
                "CommPlan.schedule replans both directions per round; "
                "fixed uplink/downlink codecs cannot be combined with it")
        if self.staleness < 0:
            raise ValueError(f"staleness must be >= 0, got {self.staleness}")


def resolve_comm(
    comm: CommPlan | None,
    *,
    compression: Compression | None = None,
    faults: FaultSchedule | None = None,
    staleness: int = 0,
    aggregation: Aggregation | None = None,
    where: str = "this entry point",
) -> CommPlan:
    """The legacy-kwarg deprecation shim: four kwargs -> one CommPlan.

    ``comm=None`` packs the legacy kwargs into a :class:`CommPlan`
    (their long-standing meaning, so old call sites keep working
    unchanged); an explicit ``comm`` forbids mixing -- the plan is the
    single source of truth.
    """
    if comm is None:
        comm = CommPlan(uplink=compression, faults=faults,
                        staleness=staleness, aggregation=aggregation)
    elif (compression is not None or faults is not None or staleness
          or aggregation is not None):
        raise TypeError(
            f"{where}: pass comm=CommPlan(...) OR the deprecated "
            "compression=/faults=/staleness=/aggregation= kwargs, not both")
    comm.validate()
    return comm


# ---------------------------------------------------------------------------
# Transport: the per-trace resolution
# ---------------------------------------------------------------------------


class Link(NamedTuple):
    """One direction of one round: the codec, or dense (``comp=None``)."""

    comp: Compression | None

    @property
    def compressed(self) -> bool:
        return self.comp is not None

    def bits(self, d: int, num_cols: int) -> int:
        """What this link moves in one round, at wire dtypes."""
        return link_bits(self.comp, d, num_cols)

    def encode(self, u, ref):
        return compression_core.encode(self.comp, u, ref)

    def decode(self, payload, ref, *, screen_nonfinite: bool = True):
        return compression_core.decode(
            self.comp, payload, ref, screen_nonfinite=screen_nonfinite)

    def ef_step(self, message, residual, ref):
        return compression_core.ef_step(self.comp, message, residual, ref)


def link_bits(comp: Compression | None, d: int, num_cols: int) -> int:
    """Per-round per-machine bits of one direction (dense when None)."""
    if comp is None:
        return dense_uplink_bits(d, num_cols)
    return uplink_bits(comp, d, num_cols)


class TransportState(NamedTuple):
    """The carries a split round stream needs to resume bit-exactly.

    ``up_residual`` is the per-machine uplink EF carry ((d, K) on the
    mesh, (m, d, K) in the simulation); ``down_residual`` the
    aggregator-held downlink EF carry (replicated (d, K) -- identical
    on every machine, since it is a pure function of replicated
    values).  ``None`` on an uncompressed direction.
    """

    up_residual: Any = None
    down_residual: Any = None


class Transport:
    """A :class:`CommPlan` resolved against one trace's (d, K, T).

    Owns the per-round :class:`Link` pairs (fixed codecs, or the
    :class:`BitBudget` schedule realized) and the per-direction
    analytic bit totals the ``AxisPayloadBits`` contracts pin.
    """

    def __init__(self, comm: CommPlan, d: int, num_cols: int, rounds: int):
        comm.validate()
        self.comm = comm
        self.d, self.num_cols, self.rounds = d, num_cols, rounds
        if comm.schedule is not None:
            self.links = comm.schedule.plan_rounds(d, num_cols, rounds)
        else:
            self.links = ((comm.uplink, comm.downlink),) * rounds
        for up, down in self.links:
            if up is not None:
                up.validate(d)
            if down is not None:
                down.validate(d)
        self.any_up = any(up is not None for up, _ in self.links)
        self.any_down = any(down is not None for _, down in self.links)

    @property
    def staleness(self) -> int:
        return self.comm.staleness

    @property
    def aggregation(self) -> Aggregation | None:
        return self.comm.aggregation

    def up(self, t: int) -> Link:
        """Round t's uplink (1-indexed, like the round loop)."""
        return Link(self.links[t - 1][0])

    def down(self, t: int) -> Link:
        """Round t's downlink (1-indexed)."""
        return Link(self.links[t - 1][1])

    def uplink_total_bits(self) -> int:
        """Analytic per-machine uplink bits over all rounds."""
        return sum(link_bits(up, self.d, self.num_cols)
                   for up, _ in self.links)

    def downlink_total_bits(self) -> int:
        """Analytic downlink bits over all rounds (0 when dense: the
        replicated dense broadcast never touches the wire)."""
        return sum(link_bits(down, self.d, self.num_cols)
                   for _, down in self.links if down is not None)


# ---------------------------------------------------------------------------
# The downlink wire
# ---------------------------------------------------------------------------


def psum_broadcast(payload, data_axes: Sequence[str]):
    """Broadcast the master's payload leaves over the data axes.

    Machine (0, ..., 0) on the data axes is the aggregator; every other
    machine contributes exact zeros, so the ``psum`` reproduces the
    master's leaf bit-for-bit (x + 0.0 == x for every float except
    -0.0, which lands as the numerically-equal +0.0).  This is how the
    downlink payload gets ON the traced wire: the aggregate is
    replicated, so a free broadcast would be invisible to the
    ``AxisPayloadBits`` accounting and unreachable by fault injection.
    """
    axes = tuple(data_axes)
    is_master = functools.reduce(
        jnp.logical_and,
        [jax.lax.axis_index(ax) == 0 for ax in axes])

    def send(leaf):
        x = jnp.where(is_master, leaf, jnp.zeros_like(leaf))
        for ax in axes:
            x = jax.lax.psum(x, ax)
        return x

    return jax.tree.map(send, payload)
