"""Local sparse-LDA estimation, debiasing and aggregation primitives.

Implements the per-machine computations of Algorithm 1:

  * pooled intra-class covariance  Sigma_hat (Pallas gram kernel)
  * local Dantzig-type sparse LDA  beta_hat           (eq. 3.1)
  * CLIME precision estimate       Theta_hat          (eq. 3.2)
  * debiased estimator             beta_tilde         (eq. 3.4)
  * hard threshold                 HT(., t)           (eq. 3.5)

plus the two baselines the paper compares against (centralized SLDA,
naive averaging -- the naive one is just `mean of beta_hat`, assembled
in :mod:`repro.core.distributed`).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.dantzig import DantzigConfig
from repro.core.clime import solve_clime
from repro.core.solver_dispatch import solve_dantzig
from repro.kernels import ops as kops


class SuffStats(NamedTuple):
    """Per-machine sufficient statistics of the two-class sample."""

    sigma: jnp.ndarray  # (d, d) pooled intra-class covariance
    mu1: jnp.ndarray  # (d,)
    mu2: jnp.ndarray  # (d,)
    n1: jnp.ndarray  # scalar
    n2: jnp.ndarray  # scalar

    @property
    def mu_d(self) -> jnp.ndarray:
        return self.mu1 - self.mu2


def suff_stats(x: jnp.ndarray, y: jnp.ndarray, use_kernel: bool | None = None) -> SuffStats:
    """Compute (Sigma_hat, mu1, mu2) from class samples X:(n1,d), Y:(n2,d).

    Sigma_hat = [sum (X_i-mu1)(X_i-mu1)^T + sum (Y_i-mu2)(Y_i-mu2)^T] / n

    ``use_kernel=None`` (default) selects the Pallas gram kernel on TPU
    and the jnp path elsewhere -- the CPU interpreter path is for
    correctness tests only, not a performance path.
    """
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    n1, n2 = x.shape[0], y.shape[0]
    mu1 = jnp.mean(x, axis=0)
    mu2 = jnp.mean(y, axis=0)
    if use_kernel:
        g1 = kops.gram(x, mu1)
        g2 = kops.gram(y, mu2)
    else:
        xc = x - mu1[None, :]
        yc = y - mu2[None, :]
        g1 = xc.T @ xc
        g2 = yc.T @ yc
    sigma = (g1 + g2) / (n1 + n2)
    return SuffStats(sigma, mu1, mu2, jnp.asarray(n1), jnp.asarray(n2))


def local_slda(
    stats: SuffStats, lam: float, cfg: DantzigConfig = DantzigConfig()
) -> jnp.ndarray:
    """Biased local estimator beta_hat (eq. 3.1)."""
    return solve_dantzig(stats.sigma, stats.mu_d, lam, cfg)


def debias(
    stats: SuffStats,
    beta_hat: jnp.ndarray,
    theta_hat: jnp.ndarray,
) -> jnp.ndarray:
    """beta_tilde = beta_hat - Theta_hat^T (Sigma_hat beta_hat - mu_d)  (eq. 3.4)."""
    resid = stats.sigma @ beta_hat - stats.mu_d
    return beta_hat - theta_hat.T @ resid


def debiased_local_estimator(
    x: jnp.ndarray,
    y: jnp.ndarray,
    lam: float,
    lam_prime: float | None = None,
    cfg: DantzigConfig = DantzigConfig(),
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full worker-side pipeline: returns (beta_tilde, beta_hat)."""
    stats = suff_stats(x, y)
    beta_hat = local_slda(stats, lam, cfg)
    theta_hat = solve_clime(stats.sigma, lam if lam_prime is None else lam_prime, cfg)
    return debias(stats, beta_hat, theta_hat), beta_hat


def hard_threshold(beta: jnp.ndarray, t) -> jnp.ndarray:
    """HT(beta, t)_j = beta_j * 1(|beta_j| > t)."""
    t = jnp.asarray(t, beta.dtype)
    return jnp.where(jnp.abs(beta) > t, beta, jnp.zeros_like(beta))


def aggregate(beta_tildes: jnp.ndarray, t) -> jnp.ndarray:
    """Master-side aggregation (eq. 3.5): mean over machines + HT."""
    return hard_threshold(jnp.mean(beta_tildes, axis=0), t)


def centralized_slda(
    x: jnp.ndarray, y: jnp.ndarray, lam: float, cfg: DantzigConfig = DantzigConfig()
) -> jnp.ndarray:
    """Centralized baseline: pool everything, solve (3.1) once (m=1, n=N)."""
    stats = suff_stats(x, y)
    return local_slda(stats, lam, cfg)
