"""Binary sparse-LDA estimation, debiasing and aggregation primitives.

The per-machine computations of Algorithm 1:

  * pooled intra-class covariance  Sigma_hat (Pallas gram kernel)
  * local Dantzig-type sparse LDA  beta_hat           (eq. 3.1)
  * CLIME precision estimate       Theta_hat          (eq. 3.2)
  * debiased estimator             beta_tilde         (eq. 3.4)
  * hard threshold                 HT(., t)           (eq. 3.5)

The worker schedule itself (suff stats -> Dantzig -> CLIME -> debias)
lives ONCE in :mod:`repro.core.pipeline`; this module is the binary
(K=1) face of it -- :func:`debiased_local_estimator` is a thin wrapper
over ``pipeline.worker_debiased(BinaryHead(), ...)`` -- plus the
master-side aggregation and the two baselines the paper compares
against (centralized SLDA, naive averaging -- assembled in
:mod:`repro.core.distributed`).

Lambda tuning (the paper's lam ∝ sqrt(log d / n) with grid-tuned
constants) goes through :func:`debiased_local_estimator_path`: the
whole grid solves in ONE folded launch sharing ONE eigendecomposition
(:mod:`repro.core.path`), and :func:`tune_lambda_validation` picks the
operating point by held-out misclassification.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import classifier, path, pipeline
from repro.core import rounds as _rounds
from repro.core.dantzig import DantzigConfig
from repro.core.pipeline import BinaryHead, SuffStats, suff_stats  # noqa: F401
from repro.core.solver_dispatch import solve_dantzig

__all__ = [
    "SuffStats",
    "suff_stats",
    "local_slda",
    "debias",
    "debiased_local_estimator",
    "debiased_local_estimator_path",
    "multi_round_slda",
    "tune_lambda_validation",
    "hard_threshold",
    "aggregate",
    "centralized_slda",
]


def local_slda(
    stats: SuffStats, lam: float, cfg: DantzigConfig = DantzigConfig()
) -> jnp.ndarray:
    """Biased local estimator beta_hat (eq. 3.1)."""
    return solve_dantzig(stats.sigma, stats.mu_d, lam, cfg)


def debias(
    stats: SuffStats,
    beta_hat: jnp.ndarray,
    theta_hat: jnp.ndarray,
) -> jnp.ndarray:
    """beta_tilde = beta_hat - Theta_hat^T (Sigma_hat beta_hat - mu_d)  (eq. 3.4)."""
    return pipeline.debias(stats.sigma, stats.mu_d, beta_hat, theta_hat)


def debiased_local_estimator(
    x: jnp.ndarray,
    y: jnp.ndarray,
    lam: float,
    lam_prime: float | None = None,
    cfg: DantzigConfig = DantzigConfig(),
    symmetrize: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full worker-side pipeline: returns (beta_tilde, beta_hat).

    ``symmetrize`` debiases with the eq.-3.3-symmetrized Theta_hat
    (unsharded full-CLIME path only; default False keeps the
    historical raw-column debias bit-for-bit -- the golden pins).
    """
    beta_tilde, beta_hat, _ = pipeline.worker_debiased(
        BinaryHead(), x, y,
        lam=lam, lam_prime=lam if lam_prime is None else lam_prime, cfg=cfg,
        symmetrize=symmetrize,
    )
    return beta_tilde[:, 0], beta_hat[:, 0]


@functools.partial(jax.jit, static_argnames=("rounds", "cfg", "comm",
                                             "compression", "faults",
                                             "staleness", "aggregation"))
def multi_round_slda(
    xs: jnp.ndarray,
    ys: jnp.ndarray,
    lam: float,
    lam_prime: float,
    t: float,
    rounds: int = 3,
    cfg: DantzigConfig = DantzigConfig(),
    compression: "_rounds.Compression | None" = None,
    faults: "_rounds.FaultSchedule | None" = None,
    staleness: int = 0,
    aggregation: "_rounds.Aggregation | None" = None,
    comm: "_rounds.CommPlan | None" = None,
) -> jnp.ndarray:
    """T-round refined distributed estimator on stacked machine draws.

    The large-m face (DESIGN.md §8): xs (m, n1, d) / ys (m, n2, d) ->
    beta_bar (d,) after ``rounds`` O(d) communication rounds, all
    sharing one set of per-machine solves (``rounds=1`` is the paper's
    one-shot aggregate).  ``comm`` (a hashable
    :class:`~repro.core.transport.CommPlan`, DESIGN.md §13) carries
    the whole comms config -- per-direction codecs / bit-budget
    schedule (DESIGN.md §10), fault schedule / staleness / aggregation
    (DESIGN.md §11); the legacy ``compression`` / ``faults`` /
    ``staleness`` / ``aggregation`` kwargs remain as deprecation
    shims.  Mesh twin:
    :func:`repro.core.distributed.distributed_slda_shardmap` with
    the same ``rounds=`` / ``comm=`` knobs.
    """
    beta_bar, _ = _rounds.simulate_multi_round(
        BinaryHead(), (xs, ys), lam=lam, lam_prime=lam_prime,
        rounds=rounds, cfg=cfg, comm=comm, compression=compression,
        faults=faults, staleness=staleness, aggregation=aggregation)
    return hard_threshold(beta_bar[:, 0], t)


def debiased_local_estimator_path(
    x: jnp.ndarray,
    y: jnp.ndarray,
    lams: jnp.ndarray,
    lam_prime: float | None = None,
    cfg: DantzigConfig = DantzigConfig(),
    rho_beta: jnp.ndarray | None = None,
    state_beta: "path.AdmmState | None" = None,
    symmetrize: bool = False,
) -> path.WorkerPathResult:
    """The worker pipeline at EVERY lambda in ``lams``, in one launch.

    One eigendecomposition + one folded direction launch + one CLIME
    solve serve the whole grid (vs L launches and L+1 eigh's run
    naively); see :mod:`repro.core.path`.  ``lam_prime=None`` pins the
    CLIME radius to the middle of the grid (a lambda-independent
    choice keeps Theta_hat shared across the sweep).  ``rho_beta`` /
    ``state_beta`` accept the warm carries from a previous sweep's
    result (with ``cfg.tol`` set, a resumed sweep exits in fewer
    iterations -- DESIGN.md §7).  Returns the full
    :class:`~repro.core.path.WorkerPathResult` ((L, d, 1) blocks;
    squeeze the trailing axis for the paper's vectors).
    """
    lams = jnp.asarray(lams)
    if lam_prime is None:
        lam_prime = lams[lams.shape[0] // 2]
    return path.worker_debiased_path(
        BinaryHead(), x, y, lams=lams, lam_prime=lam_prime, cfg=cfg,
        rho_beta=rho_beta, state_beta=state_beta, symmetrize=symmetrize,
    )


def tune_lambda_validation(
    result: path.WorkerPathResult,
    z_val: jnp.ndarray,
    labels_val: jnp.ndarray,
):
    """Pick lambda by held-out misclassification of the Fisher rule.

    ``result.stats.aux`` carries the worker's (mu1, mu2), so the rule
    needs only the validation draw.  Returns ``(idx, error_rates)``;
    the tuned estimator is ``result.beta_tilde[idx, :, 0]`` (use
    :func:`repro.core.path.take_lambda` under jit).
    """
    s = result.stats.aux

    def err(beta_block):  # (d, 1) -> scalar error rate
        return classifier.misclassification_rate(
            z_val, labels_val, beta_block[:, 0], s.mu1, s.mu2)

    errors = jax.vmap(err)(result.beta_tilde)  # (L,)
    return jnp.argmin(errors), errors


def hard_threshold(beta: jnp.ndarray, t) -> jnp.ndarray:
    """HT(beta, t)_j = beta_j * 1(|beta_j| > t)."""
    t = jnp.asarray(t, beta.dtype)
    return jnp.where(jnp.abs(beta) > t, beta, jnp.zeros_like(beta))


def aggregate(beta_tildes: jnp.ndarray, t) -> jnp.ndarray:
    """Master-side aggregation (eq. 3.5): mean over machines + HT."""
    return hard_threshold(jnp.mean(beta_tildes, axis=0), t)


def centralized_slda(
    x: jnp.ndarray, y: jnp.ndarray, lam: float, cfg: DantzigConfig = DantzigConfig()
) -> jnp.ndarray:
    """Centralized baseline: pool everything, solve (3.1) once (m=1, n=N)."""
    stats = suff_stats(x, y)
    return local_slda(stats, lam, cfg)
