"""Algorithm 1 on a JAX device mesh (the paper's distributed schedule).

Mapping (see DESIGN.md §2):

  * the paper's ``m`` machines  <->  the ``("pod", "data")`` mesh axes;
    each data-slice holds an i.i.d. shard of the N samples and runs the
    *entire* worker pipeline locally (suff stats -> beta_hat -> CLIME
    -> debias) with zero communication;
  * the paper's intra-machine CLIME column parallelism  <->  the
    ``"model"`` axis: each model-device solves ceil(d/|model|) Dantzig
    columns (d is padded to a multiple of the axis; pad columns are
    masked out of the gather, so any (d, |model|) pair is exact) and
    produces its slice of the debias correction, then one
    ``all_gather`` over "model" reassembles beta_tilde (this gather is
    *inside* a machine in the paper's cost model);
  * the paper's one-round worker->master send + average  <->  a single
    ``pmean`` of a (d, K) block over ("pod", "data") -- O(dK) bytes per
    link (K=1 for the paper's binary problem), exactly the paper's
    communication budget;
  * the master's hard threshold runs replicated (it is dK cheap ops).

The suff-stats/beta_hat computation is intentionally *replicated*
across the "model" axis instead of sharded: replicating O(n d + d^2)
FLOPs is cheaper than broadcasting Sigma_hat (d^2 bytes) across the
axis, and it keeps the one-round communication claim exact.

The worker schedule itself lives ONCE in :mod:`repro.core.pipeline`;
every entry point here is a head- or mesh-specific wrapper:
``distributed_slda_shardmap`` (binary, K=1) and
``distributed_mc_slda_shardmap`` (K-class, Chen's multicategory
one-shot schedule: each machine uplinks one (d, K) block) share the
same core, as do the single-device simulations below.  That includes
the single-factorization invariant: inside every shard function the
pipeline computes ONE :class:`~repro.kernels.spectral.SpectralFactor`
of the device's replicated Sigma_hat and threads it through both the
direction solve and the CLIME column block -- the mesh paths pay one
eigendecomposition per model-device per round, not two.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.analysis import (
    AxisPayloadBits,
    CollectiveContract,
    DtypePolicy,
    Param,
    PrimitiveBudget,
    VmemConformance,
    trace_contract,
)
from repro.core import rounds as rounds_core, slda
from repro.core import transport as transport_core
from repro.core.compression import Compression
from repro.core.dantzig import DantzigConfig
from repro.core.faults import Aggregation, FaultPlan, FaultSchedule
from repro.core.pipeline import BinaryHead, MulticlassHead
from repro.core.transport import CommPlan


def _shard_map(f, mesh, in_specs, out_specs):
    """shard_map across JAX versions (``check_vma`` vs ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map

    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def _materialize_plan(faults, mesh, data_axes, rounds, staleness):
    """Resolve ``faults`` to a full (m, rounds) :class:`FaultPlan`.

    The mesh faces accept either a :class:`FaultSchedule` (materialized
    here against the mesh's machine count) or an already-built plan;
    the per-machine rows then ride into shard_map as ONE extra sharded
    operand per plan leaf (the "liveness operand" of DESIGN.md §11) so
    each machine sees only its own (rounds,) row.
    """
    if faults is None:
        return None
    m = 1
    for ax in data_axes:
        m *= mesh.shape[ax]
    if isinstance(faults, FaultSchedule):
        faults = faults.plan(m, rounds, max(staleness, 1))
    if faults.live.shape != (m, rounds):
        raise ValueError(
            f"FaultPlan leaves must be ({m}, {rounds}) for this mesh, "
            f"got {faults.live.shape}")
    return faults


@trace_contract(
    "distributed.slda_shardmap",
    contracts=(
        PrimitiveBudget("eigh", exact=1),
        # Algorithm 1's dense uplink: one (d, 1) psum per dense round --
        # nothing else crosses the data axis (0 psums when compressed)
        CollectiveContract("psum", count=Param("dense_psums"), axis="data",
                           shape=Param("psum_payload"), dtype="float32"),
        # the DESIGN §11 liveness mask: one scalar f32 psum per masked
        # dense round (0 on the legacy path), and nothing else -- the
        # total psum budget closes the loophole
        CollectiveContract("psum", count=Param("live_psums"), axis="data",
                           shape=(), dtype="float32"),
        PrimitiveBudget("psum", exact=Param("total_psums")),
        CollectiveContract("all_gather", count=Param("rounds"),
                           axis="model"),
        # compressed uplink: the payload gathers, and the exact bits
        # per direction -- uplink payloads on all_gathers, dense psums
        # + liveness masks + downlink payloads on psums (DESIGN.md §13)
        CollectiveContract("all_gather", count=Param("data_gathers"),
                           axis="data"),
        AxisPayloadBits("data", exact_bits=Param("data_gather_bits"),
                        prims=("all_gather",)),
        AxisPayloadBits("data", exact_bits=Param("data_psum_bits"),
                        prims=("psum",)),
        AxisPayloadBits("data", exact_bits=Param("data_total_bits")),
        PrimitiveBudget("is_finite", exact=Param("screen_ops")),
        PrimitiveBudget("pallas_call", exact=Param("pallas_calls")),
        DtypePolicy(),
        VmemConformance(),
    ),
)
def distributed_slda_shardmap(
    mesh: jax.sharding.Mesh,
    x: jnp.ndarray,
    y: jnp.ndarray,
    lam: float,
    lam_prime: float,
    t: float,
    cfg: DantzigConfig = DantzigConfig(),
    data_axes: Sequence[str] = ("data",),
    model_axis: str | None = "model",
    rounds: int = 1,
    comm: CommPlan | None = None,
    compression: Compression | None = None,
    faults: FaultPlan | FaultSchedule | None = None,
    staleness: int = 0,
    aggregation: Aggregation | None = None,
) -> jnp.ndarray:
    """Distributed sparse LDA over a mesh (one-shot, or T-round refined).

    Args:
      x: (N1, d) class-1 samples, shardable over the data axes.
      y: (N2, d) class-2 samples.
      rounds: communication rounds.  1 (default) is the paper's
        one-shot schedule; T > 1 runs T-1 extra refinement rounds
        around the aggregate (DESIGN.md §8) -- each an O(d) ``pmean``
        reusing the round-one solves, no extra eigendecompositions --
        recovering the centralized rate past the one-shot m-barrier.
      comm: the ONE static comms config
        (:class:`~repro.core.transport.CommPlan`, DESIGN.md §13):
        uplink/downlink codecs or a
        :class:`~repro.core.transport.BitBudget` schedule, the fault
        schedule, the staleness bound, and the aggregation policy.
        The default plan moves each round's dense (d, 1) float32
        block, bit-exact vs the legacy path.
      compression / faults / staleness / aggregation: DEPRECATED shims
        for the corresponding :class:`CommPlan` fields (mutually
        exclusive with ``comm``; ``faults`` additionally accepts an
        (m, rounds) :class:`~repro.core.faults.FaultPlan`).  A fault
        schedule is materialized against this mesh's machine count and
        each machine's row rides in as a sharded liveness operand
        (DESIGN.md §11).
    Returns:
      beta_bar: (d,) aggregated sparse discriminant vector (replicated).
    """
    data_axes = tuple(data_axes)
    in_spec = P(data_axes, None)
    model_size = mesh.shape[model_axis] if model_axis is not None else 1
    if comm is not None and faults is not None:
        raise TypeError("distributed_slda_shardmap: pass the fault schedule "
                        "inside comm=CommPlan(faults=...), not alongside it")
    comm = transport_core.resolve_comm(
        comm, compression=compression, staleness=staleness,
        aggregation=aggregation, where="distributed_slda_shardmap")
    plan = _materialize_plan(faults if faults is not None else comm.faults,
                             mesh, data_axes, rounds, comm.staleness)
    worker_comm = comm._replace(faults=None)  # the row is the operand
    plan_args = tuple(plan) if plan is not None else ()
    plan_specs = tuple(P(data_axes, None) for _ in plan_args)

    def shard_fn(xs, ys, *plan_leaves):
        row = (FaultPlan(*(leaf[0] for leaf in plan_leaves))
               if plan_leaves else None)
        # ---- the T communication rounds of Algorithm 1 / DESIGN §8 ----
        beta_bar, _ = rounds_core.worker_rounds(
            BinaryHead(), xs, ys, lam=lam, lam_prime=lam_prime,
            rounds=rounds, cfg=cfg, data_axes=data_axes,
            model_axis=model_axis, model_axis_size=model_size,
            comm=worker_comm, faults=row,
        )
        return slda.hard_threshold(beta_bar[:, 0], t)

    fn = _shard_map(shard_fn, mesh, (in_spec, in_spec) + plan_specs, P())
    return fn(x, y, *plan_args)


@trace_contract(
    "distributed.mc_slda_shardmap",
    contracts=(
        PrimitiveBudget("eigh", exact=1),
        # one (d, K) direction psum per DENSE round over the data axis
        # (0 when compressed) ...
        CollectiveContract("psum", count=Param("dense_psums"), axis="data",
                           shape=Param("direction_payload"),
                           dtype="float32"),
        # ... plus exactly one (K, d) class-means psum, and nothing else
        CollectiveContract("psum", count=1, axis="data",
                           shape=Param("means_payload"), dtype="float32"),
        # the liveness-mask scalar psum of masked rounds (DESIGN §11)
        CollectiveContract("psum", count=Param("live_psums"), axis="data",
                           shape=(), dtype="float32"),
        PrimitiveBudget("psum", exact=Param("total_psums")),
        CollectiveContract("all_gather", count=Param("rounds"),
                           axis="model"),
        # compressed uplink: the payload gathers, and the exact bits
        # everything moves over the data axis, split by direction
        # (the one-time means psum counts on the psum side)
        CollectiveContract("all_gather", count=Param("data_gathers"),
                           axis="data"),
        AxisPayloadBits("data", exact_bits=Param("data_gather_bits"),
                        prims=("all_gather",)),
        AxisPayloadBits("data", exact_bits=Param("data_psum_bits"),
                        prims=("psum",)),
        AxisPayloadBits("data", exact_bits=Param("data_total_bits")),
        PrimitiveBudget("is_finite", exact=Param("screen_ops")),
        PrimitiveBudget("pallas_call", exact=Param("pallas_calls")),
        DtypePolicy(),
        VmemConformance(),
    ),
)
def distributed_mc_slda_shardmap(
    mesh: jax.sharding.Mesh,
    x: jnp.ndarray,
    labels: jnp.ndarray,
    num_classes: int,
    lam: float,
    lam_prime: float,
    t: float,
    cfg: DantzigConfig = DantzigConfig(),
    data_axes: Sequence[str] = ("data",),
    model_axis: str | None = "model",
    rounds: int = 1,
    comm: CommPlan | None = None,
    compression: Compression | None = None,
    faults: FaultPlan | FaultSchedule | None = None,
    staleness: int = 0,
    aggregation: Aggregation | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Distributed K-class sparse LDA over a mesh (one-shot or T-round).

    The multiclass analogue of :func:`distributed_slda_shardmap`: each
    data-slice is one machine, the d CLIME columns shard over the model
    axis, and each communication round is one ``pmean`` of a (d, K)
    direction block -- O(dK) bytes per link, the multicategory budget.
    The (K, d) class means ride one extra ``pmean`` once (they are
    round-independent), and ``rounds`` > 1 refines the direction block
    around the aggregate exactly as in the binary driver (DESIGN.md §8).
    ``comm`` is the one static :class:`~repro.core.transport.CommPlan`
    (DESIGN.md §13) -- per-direction codecs / schedule / faults /
    staleness / aggregation exactly as in the binary driver; the
    legacy kwargs remain as deprecation shims.  The one-time means
    pmean stays dense and is NOT fault-masked; it rides the round-1
    uplink in the paper's cost model.

    Args:
      x: (N, d) samples, shardable over the data axes.
      labels: (N,) int labels in [0, num_classes).
    Returns:
      (beta_bar (d, K), means (K, d)), both replicated.
    """
    data_axes = tuple(data_axes)
    model_size = mesh.shape[model_axis] if model_axis is not None else 1
    if comm is not None and faults is not None:
        raise TypeError("distributed_mc_slda_shardmap: pass the fault "
                        "schedule inside comm=CommPlan(faults=...), not "
                        "alongside it")
    comm = transport_core.resolve_comm(
        comm, compression=compression, staleness=staleness,
        aggregation=aggregation, where="distributed_mc_slda_shardmap")
    plan = _materialize_plan(faults if faults is not None else comm.faults,
                             mesh, data_axes, rounds, comm.staleness)
    worker_comm = comm._replace(faults=None)  # the row is the operand
    plan_args = tuple(plan) if plan is not None else ()
    plan_specs = tuple(P(data_axes, None) for _ in plan_args)

    def shard_fn(xs, labs, *plan_leaves):
        row = (FaultPlan(*(leaf[0] for leaf in plan_leaves))
               if plan_leaves else None)
        beta_bar, ws = rounds_core.worker_rounds(
            MulticlassHead(num_classes), xs, labs,
            lam=lam, lam_prime=lam_prime, rounds=rounds, cfg=cfg,
            data_axes=data_axes,
            model_axis=model_axis, model_axis_size=model_size,
            comm=worker_comm, faults=row,
        )
        means = ws.stats.aux.means
        for ax in data_axes:
            means = jax.lax.pmean(means, ax)
        return slda.hard_threshold(beta_bar, t), means

    fn = _shard_map(
        shard_fn, mesh,
        (P(data_axes, None), P(data_axes)) + plan_specs, (P(), P())
    )
    return fn(x, labels, *plan_args)


def naive_averaged_slda_shardmap(
    mesh: jax.sharding.Mesh,
    x: jnp.ndarray,
    y: jnp.ndarray,
    lam: float,
    cfg: DantzigConfig = DantzigConfig(),
    data_axes: Sequence[str] = ("data",),
) -> jnp.ndarray:
    """Baseline: average the *biased* local estimators (no debias, no HT)."""
    data_axes = tuple(data_axes)

    def shard_fn(xs, ys):
        stats = slda.suff_stats(xs, ys)
        beta_hat = slda.local_slda(stats, lam, cfg)
        for ax in data_axes:
            beta_hat = jax.lax.pmean(beta_hat, ax)
        return beta_hat

    fn = _shard_map(shard_fn, mesh, (P(data_axes, None), P(data_axes, None)), P())
    return fn(x, y)


# ---------------------------------------------------------------------------
# Single-device simulation (statistical experiments / tests).  Identical
# math; machines are a leading vmap axis instead of mesh shards.  The
# per-machine body is the SAME pipeline.worker_solves schedule the mesh
# runs, driven through the same rounds core (pipeline.worker_debiased's
# one-shot correction is its rounds=1 case).
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg", "rounds", "comm",
                                             "compression", "faults",
                                             "staleness", "aggregation"))
def simulated_debiased_mean(
    xs: jnp.ndarray,
    ys: jnp.ndarray,
    lam: float,
    lam_prime: float,
    cfg: DantzigConfig = DantzigConfig(),
    rounds: int = 1,
    compression: Compression | None = None,
    faults: FaultSchedule | None = None,
    staleness: int = 0,
    aggregation: Aggregation | None = None,
    comm: CommPlan | None = None,
) -> jnp.ndarray:
    """Mean of debiased locals WITHOUT the hard threshold.

    Benchmarks tune the threshold t post hoc over a grid (the paper
    reports grid-tuned best results); exposing the raw mean makes that
    tuning free (HT is O(d)).  ``rounds`` > 1 applies the extra
    refinement rounds around the aggregate (DESIGN.md §8).  ``comm``
    (a hashable :class:`~repro.core.transport.CommPlan` -- static, so
    changing the plan recompiles) carries the whole comms config:
    codecs/schedule (DESIGN.md §10/§13), fault schedule (materialized
    inside the jit), staleness, aggregation (DESIGN.md §11).  The
    legacy ``compression``/``faults``/``staleness``/``aggregation``
    kwargs remain as deprecation shims."""
    beta_bar, _ = rounds_core.simulate_multi_round(
        BinaryHead(), (xs, ys), lam=lam, lam_prime=lam_prime,
        rounds=rounds, cfg=cfg, comm=comm, compression=compression,
        faults=faults, staleness=staleness, aggregation=aggregation)
    return beta_bar[:, 0]


@functools.partial(jax.jit, static_argnames=("cfg", "rounds", "comm",
                                             "compression", "faults",
                                             "staleness", "aggregation"))
def simulated_distributed_slda(
    xs: jnp.ndarray,
    ys: jnp.ndarray,
    lam: float,
    lam_prime: float,
    t: float,
    cfg: DantzigConfig = DantzigConfig(),
    rounds: int = 1,
    compression: Compression | None = None,
    faults: FaultSchedule | None = None,
    staleness: int = 0,
    aggregation: Aggregation | None = None,
    comm: CommPlan | None = None,
) -> jnp.ndarray:
    """xs: (m, n1, d), ys: (m, n2, d) -> aggregated beta_bar (d,)."""
    return slda.hard_threshold(
        simulated_debiased_mean(xs, ys, lam, lam_prime, cfg, rounds,
                                compression, faults, staleness,
                                aggregation, comm), t)


@functools.partial(jax.jit, static_argnames=("cfg",))
def simulated_naive_averaged_slda(
    xs: jnp.ndarray,
    ys: jnp.ndarray,
    lam: float,
    cfg: DantzigConfig = DantzigConfig(),
) -> jnp.ndarray:
    def one_machine(x, y):
        stats = slda.suff_stats(x, y)
        return slda.local_slda(stats, lam, cfg)

    return jnp.mean(jax.vmap(one_machine)(xs, ys), axis=0)
