"""Algorithm 1 on a JAX device mesh (the paper's distributed schedule).

Mapping (see DESIGN.md §2):

  * the paper's ``m`` machines  <->  the ``("pod", "data")`` mesh axes;
    each data-slice holds an i.i.d. shard of the N samples and runs the
    *entire* worker pipeline locally (suff stats -> beta_hat -> CLIME
    -> debias) with zero communication;
  * the paper's intra-machine CLIME column parallelism  <->  the
    ``"model"`` axis: each model-device solves d/|model| Dantzig
    columns and produces its slice of the debias correction, then one
    ``all_gather`` over "model" reassembles beta_tilde (this gather is
    *inside* a machine in the paper's cost model);
  * the paper's one-round worker->master send + average  <->  a single
    ``pmean`` of a d-vector over ("pod", "data") -- O(d) bytes per
    link, exactly the paper's communication budget;
  * the master's hard threshold runs replicated (it is d cheap ops).

The suff-stats/beta_hat computation is intentionally *replicated*
across the "model" axis instead of sharded: replicating O(n d + d^2)
FLOPs is cheaper than broadcasting Sigma_hat (d^2 bytes) across the
axis, and it keeps the one-round communication claim exact.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.dantzig import DantzigConfig
from repro.core.clime import solve_clime_columns
from repro.core import slda


def _worker_debiased(x, y, lam, lam_prime, cfg: DantzigConfig, model_axis: str | None):
    """Worker pipeline on one machine; model-axis shards CLIME columns."""
    stats = slda.suff_stats(x, y)
    beta_hat = slda.local_slda(stats, lam, cfg)
    d = beta_hat.shape[0]
    if model_axis is None:
        theta = solve_clime_columns(stats.sigma, jnp.arange(d), lam_prime, cfg)
        resid = stats.sigma @ beta_hat - stats.mu_d
        correction = theta.T @ resid
    else:
        size = jax.lax.axis_size(model_axis)
        idx = jax.lax.axis_index(model_axis)
        cols_per = d // size
        # remainder columns go to the last device via padding with
        # out-of-range -> clamp; d is padded upstream to a multiple.
        cols = idx * cols_per + jnp.arange(cols_per)
        theta_block = solve_clime_columns(stats.sigma, cols, lam_prime, cfg)
        resid = stats.sigma @ beta_hat - stats.mu_d
        corr_slice = theta_block.T @ resid  # (cols_per,)
        correction = jax.lax.all_gather(
            corr_slice, model_axis, axis=0, tiled=True
        )  # (d,)
    return beta_hat - correction, beta_hat


def distributed_slda_shardmap(
    mesh: jax.sharding.Mesh,
    x: jnp.ndarray,
    y: jnp.ndarray,
    lam: float,
    lam_prime: float,
    t: float,
    cfg: DantzigConfig = DantzigConfig(),
    data_axes: Sequence[str] = ("data",),
    model_axis: str | None = "model",
) -> jnp.ndarray:
    """One-shot distributed sparse LDA over a mesh.

    Args:
      x: (N1, d) class-1 samples, shardable over the data axes.
      y: (N2, d) class-2 samples.
    Returns:
      beta_bar: (d,) aggregated sparse discriminant vector (replicated).
    """
    data_axes = tuple(data_axes)
    in_spec = P(data_axes, None)

    def shard_fn(xs, ys):
        beta_tilde, _ = _worker_debiased(xs, ys, lam, lam_prime, cfg, model_axis)
        # ---- the single communication round of Algorithm 1 ----
        beta_mean = beta_tilde
        for ax in data_axes:
            beta_mean = jax.lax.pmean(beta_mean, ax)
        return slda.hard_threshold(beta_mean, t)

    fn = jax.shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(in_spec, in_spec),
        out_specs=P(),
        check_vma=False,
    )
    return fn(x, y)


def naive_averaged_slda_shardmap(
    mesh: jax.sharding.Mesh,
    x: jnp.ndarray,
    y: jnp.ndarray,
    lam: float,
    cfg: DantzigConfig = DantzigConfig(),
    data_axes: Sequence[str] = ("data",),
) -> jnp.ndarray:
    """Baseline: average the *biased* local estimators (no debias, no HT)."""
    data_axes = tuple(data_axes)

    def shard_fn(xs, ys):
        stats = slda.suff_stats(xs, ys)
        beta_hat = slda.local_slda(stats, lam, cfg)
        for ax in data_axes:
            beta_hat = jax.lax.pmean(beta_hat, ax)
        return beta_hat

    fn = jax.shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(P(data_axes, None), P(data_axes, None)),
        out_specs=P(),
        check_vma=False,
    )
    return fn(x, y)


# ---------------------------------------------------------------------------
# Single-device simulation (statistical experiments / tests).  Identical
# math; machines are a leading vmap axis instead of mesh shards.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg",))
def simulated_debiased_mean(
    xs: jnp.ndarray,
    ys: jnp.ndarray,
    lam: float,
    lam_prime: float,
    cfg: DantzigConfig = DantzigConfig(),
) -> jnp.ndarray:
    """Mean of debiased locals WITHOUT the hard threshold.

    Benchmarks tune the threshold t post hoc over a grid (the paper
    reports grid-tuned best results); exposing the raw mean makes that
    tuning free (HT is O(d))."""

    def one_machine(x, y):
        bt, _ = _worker_debiased(x, y, lam, lam_prime, cfg, model_axis=None)
        return bt

    return jnp.mean(jax.vmap(one_machine)(xs, ys), axis=0)


@functools.partial(jax.jit, static_argnames=("cfg",))
def simulated_distributed_slda(
    xs: jnp.ndarray,
    ys: jnp.ndarray,
    lam: float,
    lam_prime: float,
    t: float,
    cfg: DantzigConfig = DantzigConfig(),
) -> jnp.ndarray:
    """xs: (m, n1, d), ys: (m, n2, d) -> aggregated beta_bar (d,)."""

    def one_machine(x, y):
        bt, _ = _worker_debiased(x, y, lam, lam_prime, cfg, model_axis=None)
        return bt

    beta_tildes = jax.vmap(one_machine)(xs, ys)
    return slda.aggregate(beta_tildes, t)


@functools.partial(jax.jit, static_argnames=("cfg",))
def simulated_naive_averaged_slda(
    xs: jnp.ndarray,
    ys: jnp.ndarray,
    lam: float,
    cfg: DantzigConfig = DantzigConfig(),
) -> jnp.ndarray:
    def one_machine(x, y):
        stats = slda.suff_stats(x, y)
        return slda.local_slda(stats, lam, cfg)

    return jnp.mean(jax.vmap(one_machine)(xs, ys), axis=0)
