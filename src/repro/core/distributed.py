"""Algorithm 1 on a JAX device mesh (the paper's distributed schedule).

Mapping (see DESIGN.md §2):

  * the paper's ``m`` machines  <->  the ``("pod", "data")`` mesh axes;
    each data-slice holds an i.i.d. shard of the N samples and runs the
    *entire* worker pipeline locally (suff stats -> beta_hat -> CLIME
    -> debias) with zero communication;
  * the paper's intra-machine CLIME column parallelism  <->  the
    ``"model"`` axis: each model-device solves ceil(d/|model|) Dantzig
    columns (d is padded to a multiple of the axis; pad columns are
    masked out of the gather, so any (d, |model|) pair is exact) and
    produces its slice of the debias correction, then one
    ``all_gather`` over "model" reassembles beta_tilde (this gather is
    *inside* a machine in the paper's cost model);
  * the paper's one-round worker->master send + average  <->  a single
    ``pmean`` of a d-vector over ("pod", "data") -- O(d) bytes per
    link, exactly the paper's communication budget;
  * the master's hard threshold runs replicated (it is d cheap ops).

The suff-stats/beta_hat computation is intentionally *replicated*
across the "model" axis instead of sharded: replicating O(n d + d^2)
FLOPs is cheaper than broadcasting Sigma_hat (d^2 bytes) across the
axis, and it keeps the one-round communication claim exact.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.dantzig import DantzigConfig
from repro.core.clime import solve_clime_columns
from repro.core import slda


def _shard_map(f, mesh, in_specs, out_specs):
    """shard_map across JAX versions (``check_vma`` vs ``check_rep``)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map

    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def _worker_debiased(x, y, lam, lam_prime, cfg: DantzigConfig,
                     model_axis: str | None, model_axis_size: int = 1):
    """Worker pipeline on one machine; model-axis shards CLIME columns.

    The debias correction ``Theta^T (Sigma beta_hat - mu_d)`` must use
    ALL d CLIME columns (Theorem 4.5's one-round guarantee is exact only
    then), so when d is not a multiple of the model-axis size, d is
    padded up to ``size * ceil(d / size)``: each device solves the same
    number of columns, pad columns are clamped onto column d-1 and
    their contribution is masked out of the gather.
    """
    stats = slda.suff_stats(x, y)
    beta_hat = slda.local_slda(stats, lam, cfg)
    d = beta_hat.shape[0]
    if model_axis is None:
        theta = solve_clime_columns(stats.sigma, jnp.arange(d), lam_prime, cfg)
        resid = stats.sigma @ beta_hat - stats.mu_d
        correction = theta.T @ resid
    else:
        size = model_axis_size
        idx = jax.lax.axis_index(model_axis)
        cols_per = -(-d // size)  # ceil: pad d to a multiple of size
        cols = idx * cols_per + jnp.arange(cols_per)
        valid = cols < d
        theta_block = solve_clime_columns(
            stats.sigma, jnp.minimum(cols, d - 1), lam_prime, cfg
        )
        resid = stats.sigma @ beta_hat - stats.mu_d
        corr_slice = jnp.where(valid, theta_block.T @ resid, 0.0)  # (cols_per,)
        gathered = jax.lax.all_gather(
            corr_slice, model_axis, axis=0, tiled=True
        )  # (size * cols_per,), device i's block at [i*cols_per, ...)
        # global column j lands at position j; pad columns sit at >= d
        correction = gathered[:d]
    return beta_hat - correction, beta_hat


def distributed_slda_shardmap(
    mesh: jax.sharding.Mesh,
    x: jnp.ndarray,
    y: jnp.ndarray,
    lam: float,
    lam_prime: float,
    t: float,
    cfg: DantzigConfig = DantzigConfig(),
    data_axes: Sequence[str] = ("data",),
    model_axis: str | None = "model",
) -> jnp.ndarray:
    """One-shot distributed sparse LDA over a mesh.

    Args:
      x: (N1, d) class-1 samples, shardable over the data axes.
      y: (N2, d) class-2 samples.
    Returns:
      beta_bar: (d,) aggregated sparse discriminant vector (replicated).
    """
    data_axes = tuple(data_axes)
    in_spec = P(data_axes, None)
    model_size = mesh.shape[model_axis] if model_axis is not None else 1

    def shard_fn(xs, ys):
        beta_tilde, _ = _worker_debiased(
            xs, ys, lam, lam_prime, cfg, model_axis, model_size
        )
        # ---- the single communication round of Algorithm 1 ----
        beta_mean = beta_tilde
        for ax in data_axes:
            beta_mean = jax.lax.pmean(beta_mean, ax)
        return slda.hard_threshold(beta_mean, t)

    fn = _shard_map(shard_fn, mesh, (in_spec, in_spec), P())
    return fn(x, y)


def naive_averaged_slda_shardmap(
    mesh: jax.sharding.Mesh,
    x: jnp.ndarray,
    y: jnp.ndarray,
    lam: float,
    cfg: DantzigConfig = DantzigConfig(),
    data_axes: Sequence[str] = ("data",),
) -> jnp.ndarray:
    """Baseline: average the *biased* local estimators (no debias, no HT)."""
    data_axes = tuple(data_axes)

    def shard_fn(xs, ys):
        stats = slda.suff_stats(xs, ys)
        beta_hat = slda.local_slda(stats, lam, cfg)
        for ax in data_axes:
            beta_hat = jax.lax.pmean(beta_hat, ax)
        return beta_hat

    fn = _shard_map(shard_fn, mesh, (P(data_axes, None), P(data_axes, None)), P())
    return fn(x, y)


# ---------------------------------------------------------------------------
# Single-device simulation (statistical experiments / tests).  Identical
# math; machines are a leading vmap axis instead of mesh shards.
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg",))
def simulated_debiased_mean(
    xs: jnp.ndarray,
    ys: jnp.ndarray,
    lam: float,
    lam_prime: float,
    cfg: DantzigConfig = DantzigConfig(),
) -> jnp.ndarray:
    """Mean of debiased locals WITHOUT the hard threshold.

    Benchmarks tune the threshold t post hoc over a grid (the paper
    reports grid-tuned best results); exposing the raw mean makes that
    tuning free (HT is O(d))."""

    def one_machine(x, y):
        bt, _ = _worker_debiased(x, y, lam, lam_prime, cfg, model_axis=None)
        return bt

    return jnp.mean(jax.vmap(one_machine)(xs, ys), axis=0)


@functools.partial(jax.jit, static_argnames=("cfg",))
def simulated_distributed_slda(
    xs: jnp.ndarray,
    ys: jnp.ndarray,
    lam: float,
    lam_prime: float,
    t: float,
    cfg: DantzigConfig = DantzigConfig(),
) -> jnp.ndarray:
    """xs: (m, n1, d), ys: (m, n2, d) -> aggregated beta_bar (d,)."""

    def one_machine(x, y):
        bt, _ = _worker_debiased(x, y, lam, lam_prime, cfg, model_axis=None)
        return bt

    beta_tildes = jax.vmap(one_machine)(xs, ys)
    return slda.aggregate(beta_tildes, t)


@functools.partial(jax.jit, static_argnames=("cfg",))
def simulated_naive_averaged_slda(
    xs: jnp.ndarray,
    ys: jnp.ndarray,
    lam: float,
    cfg: DantzigConfig = DantzigConfig(),
) -> jnp.ndarray:
    def one_machine(x, y):
        stats = slda.suff_stats(x, y)
        return slda.local_slda(stats, lam, cfg)

    return jnp.mean(jax.vmap(one_machine)(xs, ys), axis=0)
