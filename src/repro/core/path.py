"""Lambda-regularization-path solves folded into ONE blocked launch.

The paper picks the Dantzig box radius lam ∝ sqrt(log d / n) with
constants tuned on held-out data (§5; Lee et al.'s one-shot sparse
regression and Wang et al.'s EDSL run the same per-machine sweeps), so
in practice every worker solves the SAME problem across an L-point
lambda grid.  Run naively that is L sequential solver launches and
L + 1 eigendecompositions per worker (each launch re-factorizes, plus
the CLIME solve).  Both redundancies fold away:

  * the spectral factor (:mod:`repro.kernels.spectral`) is lam- and
    rho-independent, so ONE ``eigh`` serves the whole sweep AND the
    CLIME solve;
  * ``lam`` and ``rho`` are per-column operands of the blocked fused
    kernel, so an L-point grid over a (d, k) batch is just a
    (d, k*L) batch with ``lam`` varying across the replicated column
    blocks -- one launch, with
    :func:`repro.kernels.dantzig_fused.pick_block_k` sizing the Pallas
    grid exactly as for any other wide batch.

:func:`solve_dantzig_path` implements the fold for a raw solve;
:func:`worker_debiased_path` runs a worker's ENTIRE debiased pipeline
across the grid (one eigh, one wide direction launch, one CLIME solve
shared by every lambda).  Selection helpers pick the operating point
from the single launch: :func:`select_by_kkt` (most-constrained
feasible lambda) or :func:`select_by_validation` (held-out score).
Warm per-(column, lambda) rho rides along in the results, so repeated
sweeps (e.g. across bootstrap draws or data refreshes) thread their
penalties forward without recompiling -- rho is a traced operand.

Column layout: lambda index l owns columns [l*k, (l+1)*k); outputs
unfold to a leading (L, ...) axis.  Columns never interact in the
kernel, so the folded sweep is exact, not approximate -- pinned to
1e-5 against L independent solves on every dispatch path by
``tests/test_spectral_path.py``.

Continuation (DESIGN.md §7): every sweep returns the full per-(lambda,
column) ADMM state next to the warm rho, and accepts one back via
``state=`` -- the re-sweep resumes each grid point from its previous
solution instead of restarting from zero (glmnet-style homotopy).  A
single solve's (d, k) state broadcasts across the grid, and
:func:`seed_path_state` re-maps a sweep's states onto a NEW grid by
nearest lambda (grid refinement seeds each lambda's columns from the
adjacent grid point).  With ``cfg.tol`` set the solver's
residual-gated early exit turns those warm starts into measured
iteration savings (``PathResult.iters``).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.analysis import (
    DtypePolicy,
    Param,
    PrimitiveBudget,
    VmemConformance,
    trace_contract,
)
from repro.core.clime import solve_clime_columns, symmetrize_min
from repro.core.dantzig import AdmmState, DantzigConfig, kkt_violation
from repro.core.pipeline import DiscriminantHead, HeadStats
from repro.core.solver_dispatch import solve_dantzig_full
from repro.kernels.spectral import SpectralFactor, as_spectral_factor

__all__ = [
    "PathResult",
    "WorkerPathResult",
    "solve_dantzig_path",
    "worker_debiased_path",
    "seed_path_state",
    "select_by_kkt",
    "select_by_validation",
    "take_lambda",
]


class PathResult(NamedTuple):
    """One folded sweep: everything indexed by the leading lambda axis."""

    beta: jnp.ndarray  # (L, d, k) solutions ((L, d) for vector rhs)
    lam: jnp.ndarray  # (L,) the grid
    kkt: jnp.ndarray  # (L, k) constraint violations ((L,) for vector rhs)
    rho: jnp.ndarray  # (L, k) final per-(lambda, column) ADMM penalties
    state: AdmmState  # full final states, leaves (L, d, k) ((L, d) vector)
    iters: jnp.ndarray  # (L, k) executed iterations ((L,) for vector rhs)


def _unfold(wide: jnp.ndarray, d: int, L: int, k: int) -> jnp.ndarray:
    """(d, L*k) -> (L, d, k) under the lambda-owns-contiguous-columns fold."""
    return jnp.moveaxis(wide.reshape(d, L, k), 1, 0)


_STATE_LAYOUTS = ("auto", "grid", "single")


def _fold_state(state: AdmmState, d: int, L: int, k: int,
                layout: str = "auto") -> AdmmState:
    """Warm path state -> the (d, L*k) wide layout.

    Accepts leaves of shape (L, d, k) or (L, d, 1) (a previous sweep,
    e.g. ``PathResult.state``; the ``grid`` layout), or (d, k) / (d,)
    (a single solve, broadcast to every grid point -- seeding the whole
    grid from one adjacent solution; the ``single`` layout).

    2-D leaves are ambiguous when the static shapes collide: a (d, k)
    single-solve leaf and an (L, d) vector-sweep leaf are
    indistinguishable once ``L == d == k`` (and ``(d, d)`` collides
    with ``(L, d)`` whenever ``L == d``).  ``layout="auto"`` infers the
    kind only when exactly one reading fits and raises on a collision;
    pass ``layout="grid"`` / ``layout="single"`` (or reshape vector-
    sweep leaves to the always-unambiguous (L, d, 1)) to disambiguate
    explicitly.
    """
    if layout not in _STATE_LAYOUTS:
        raise ValueError(
            f"state_layout must be one of {_STATE_LAYOUTS}, got {layout!r}")
    leaves = []
    for leaf in state:
        leaf = jnp.asarray(leaf, jnp.float32)
        if leaf.ndim == 1:  # (d,) single vector solve
            if leaf.shape != (d,):
                raise ValueError(
                    f"1-D warm-state leaf {leaf.shape} != (d,)=({d},)")
            leaf = leaf[None, :, None]
        elif leaf.ndim == 2:
            as_single = leaf.shape in ((d, k), (d, 1))
            as_grid = leaf.shape == (L, d)
            kind = layout
            if kind == "auto":
                if as_single and as_grid:
                    raise ValueError(
                        f"warm-state leaf {leaf.shape} is ambiguous at "
                        f"L={L}, d={d}, k={k}: it reads both as a (d, k) "
                        "single solve and as an (L, d) vector sweep. Pass "
                        "state_layout='single' or 'grid' (or reshape "
                        "sweep leaves to (L, d, 1)).")
                kind = "single" if as_single else "grid"
            if kind == "single":
                if not as_single:
                    raise ValueError(
                        f"single-solve warm-state leaf {leaf.shape} != "
                        f"(d, k)=({d}, {k})")
                leaf = leaf[None]  # (1, d, k|1): broadcast to the grid
            else:
                if not as_grid:
                    raise ValueError(
                        f"vector-sweep warm-state leaf {leaf.shape} != "
                        f"(L, d)=({L}, {d})")
                leaf = leaf[:, :, None]
        elif leaf.ndim == 3:
            if leaf.shape not in ((L, d, k), (L, d, 1)):
                raise ValueError(
                    f"3-D warm-state leaf {leaf.shape} matches neither "
                    f"(L, d, k)=({L}, {d}, {k}) nor (L, d, 1)")
        else:
            raise ValueError(
                f"warm-state leaf has ndim={leaf.ndim}; expected 1-3")
        leaf = jnp.broadcast_to(leaf, (L, d, k))
        leaves.append(jnp.moveaxis(leaf, 0, 1).reshape(d, L * k))
    return AdmmState(*leaves)


def seed_path_state(
    state: AdmmState, lams_from: jnp.ndarray, lams_to: jnp.ndarray
) -> AdmmState:
    """Re-map a sweep's per-lambda states onto a NEW lambda grid.

    Each new grid point is seeded from the nearest old grid point's
    state (glmnet-style homotopy for grid refinement): leaves go
    (L_from, d, k) -> (L_to, d, k).  Feed the result straight into
    :func:`solve_dantzig_path`'s ``state=``.
    """
    lams_from = jnp.asarray(lams_from)
    lams_to = jnp.asarray(lams_to)
    nearest = jnp.argmin(
        jnp.abs(lams_to[:, None] - lams_from[None, :]), axis=1)  # (L_to,)
    return AdmmState(*(jnp.take(leaf, nearest, axis=0) for leaf in state))


@trace_contract(
    "path.solve_dantzig_path",
    contracts=(
        # a raw Sigma is factorized once for the WHOLE sweep; a
        # SpectralFactor input must trace zero eighs
        PrimitiveBudget("eigh", exact=Param("eighs")),
        # the lambda grid folds into the column batch: one fused launch
        # covers all L grid points (scan cfg: none)
        PrimitiveBudget("pallas_call", exact=Param("pallas_calls")),
        PrimitiveBudget("psum", exact=0),
        DtypePolicy(),
        VmemConformance(),
    ),
)
def solve_dantzig_path(
    a: jnp.ndarray | SpectralFactor,
    b: jnp.ndarray,
    lams: jnp.ndarray,
    cfg: DantzigConfig = DantzigConfig(),
    *,
    rho: jnp.ndarray | None = None,
    state: AdmmState | None = None,
    state_layout: str = "auto",
    backend: str | None = None,
) -> PathResult:
    """Solve a (d, k) Dantzig batch at EVERY lambda in one launch.

    Args:
      a:    (d, d) PSD matrix or its :class:`SpectralFactor`; a raw
            matrix is factorized once for the whole sweep.
      b:    (d,) or (d, k) right-hand side(s), shared by all lambdas.
      lams: (L,) box-radius grid.
      rho:  optional warm per-(lambda, column) penalties -- scalar,
            (L,) per-lambda, (k,) per-column, or (L, k) (e.g.
            ``PathResult.rho`` from the previous sweep); a traced
            operand on the fused paths, so re-sweeping never
            recompiles.  When ``L == k`` the two 1-D readings collide
            and a 1-D rho raises -- pass the explicit 2-D broadcast
            (``rho[:, None]`` per-lambda, ``rho[None, :]`` per-column).
      state: optional warm ADMM state -- a previous sweep's
            ``PathResult.state`` (leaves (L, d, k) / (L, d) / the
            always-unambiguous (L, d, 1)), or a single solve's state
            (leaves (d, k) / (d,), broadcast to every grid point).  Use
            :func:`seed_path_state` to re-map states across different
            grids.  Traced operands: warm re-sweeps never recompile.
      state_layout: disambiguates 2-D warm-state leaves when the
            shapes collide (``L == d == k``): ``"grid"`` reads them as
            (L, d) vector-sweep carries, ``"single"`` as (d, k) single
            solves; the default ``"auto"`` infers when only one
            reading fits and raises on a collision.

    The k*L columns dispatch as ONE batch: ``select_solver`` sees
    (d, k*L) and tiles it over the Pallas grid with the same
    ``pick_block_k`` sizing as any other batch (or falls back to scan
    under the usual rules).  Returns a :class:`PathResult`.
    """
    factor = as_spectral_factor(a)
    squeeze = b.ndim == 1
    b2 = b[:, None] if squeeze else b
    d, k = b2.shape
    lams = jnp.asarray(lams)
    (L,) = lams.shape

    # fold: lambda l owns columns [l*k, (l+1)*k)
    wide_b = jnp.tile(b2, (1, L))
    wide_lam = jnp.repeat(lams.astype(b2.dtype), k)
    wide_rho = None
    if rho is not None:
        r = jnp.asarray(rho, jnp.float32)
        if r.ndim == 0:
            r = jnp.broadcast_to(r, (L, k))
        elif r.ndim == 1:
            # (L,) = per-lambda, (k,) = per-column; at L == k the two
            # readings collide and silently picking one would misfold
            # the warm carry -- demand the explicit 2-D broadcast.
            if L == k and r.shape[0] == L:
                raise ValueError(
                    f"1-D rho of shape {r.shape} is ambiguous at "
                    f"L == k == {L}: pass rho[:, None] for per-lambda "
                    "or rho[None, :] for per-column.")
            if r.shape[0] == L:
                r = jnp.broadcast_to(r[:, None], (L, k))
            elif r.shape[0] == k:
                r = jnp.broadcast_to(r[None, :], (L, k))
            else:
                raise ValueError(f"rho shape {r.shape} matches neither "
                                 f"(L,)=({L},) nor (k,)=({k},)")
        else:
            r = jnp.broadcast_to(r, (L, k))
        wide_rho = r.reshape(L * k)
    wide_state = (None if state is None
                  else _fold_state(state, d, L, k, state_layout))

    result = solve_dantzig_full(
        factor, wide_b, wide_lam, cfg, rho=wide_rho, state=wide_state,
        backend=backend)

    wide_kkt = kkt_violation(factor.sigma, wide_b, result.beta, wide_lam)

    beta = _unfold(result.beta, d, L, k)  # (L, d, k)
    kkt = wide_kkt.reshape(L, k)
    rho_final = jnp.broadcast_to(
        jnp.asarray(result.rho, jnp.float32), (L * k,)).reshape(L, k)
    state_final = AdmmState(
        *(_unfold(leaf, d, L, k) for leaf in result.state))
    iters = result.iters.reshape(L, k)
    if squeeze:
        return PathResult(
            beta[:, :, 0], lams, kkt[:, 0], rho_final,
            AdmmState(*(leaf[:, :, 0] for leaf in state_final)),
            iters[:, 0])
    return PathResult(beta, lams, kkt, rho_final, state_final, iters)


class WorkerPathResult(NamedTuple):
    """A worker's debiased pipeline swept across the lambda grid."""

    beta_tilde: jnp.ndarray  # (L, d, K) debiased direction blocks
    beta_hat: jnp.ndarray  # (L, d, K) biased local estimates
    lam: jnp.ndarray  # (L,)
    kkt: jnp.ndarray  # (L, K) direction-solve constraint violations
    rho_beta: jnp.ndarray  # (L, K) warm penalties for the next sweep
    stats: HeadStats  # the head's sufficient statistics (lambda-free)
    state_beta: AdmmState  # (L, d, K) direction states for the next sweep
    iters: jnp.ndarray  # (L, K) executed direction-solve iterations


@trace_contract(
    "path.worker_debiased_path",
    contracts=(
        # one eigh funds the direction sweep AND the CLIME block
        PrimitiveBudget("eigh", exact=1),
        # fused cfg: folded direction sweep + CLIME = 2 launches
        PrimitiveBudget("pallas_call", exact=Param("pallas_calls")),
        DtypePolicy(),
        VmemConformance(),
    ),
)
def worker_debiased_path(
    head: DiscriminantHead,
    *data: jnp.ndarray,
    lams: jnp.ndarray,
    lam_prime,
    cfg: DantzigConfig = DantzigConfig(),
    rho_beta: jnp.ndarray | None = None,
    rho_theta: jnp.ndarray | None = None,
    state_beta: AdmmState | None = None,
    state_theta: AdmmState | None = None,
    state_layout: str = "auto",
    symmetrize: bool = False,
) -> WorkerPathResult:
    """One machine's debiased estimate at EVERY lambda in one launch.

    The lambda-path analogue of
    :func:`repro.core.pipeline.worker_debiased`: one ``eigh``
    factorizes Sigma_hat for the entire sweep, the (d, K) direction
    block solves at all L grid points in a single folded launch
    (k = K -> K*L columns), and ONE CLIME solve at ``lam_prime``
    (lambda-independent, like the factor) debiases every grid point:

        beta_tilde_l = beta_hat_l - Theta^T (Sigma beta_hat_l - rhs).

    That is 1 launch + 1 eigendecomposition where the sequential sweep
    pays L launches + L+1 eigendecompositions.  ``rho_beta`` /
    ``rho_theta`` thread warm penalties exactly as in the single-point
    pipeline (``rho_beta`` additionally accepts the (L, K) carry from a
    previous :class:`WorkerPathResult`), and ``state_beta`` /
    ``state_theta`` thread the full ADMM states the same way
    (``state_beta`` accepts the ``state_beta`` carry of a previous
    result; with ``cfg.tol`` set the resumed sweep exits in fewer
    iterations -- see ``WorkerPathResult.iters``).

    Runs unsharded (the mesh paths tune lambda per machine before
    entering shard_map; the CLIME model-axis sharding composes with a
    single chosen lambda, not with the sweep).  ``symmetrize`` debiases
    every grid point with the eq.-3.3-symmetrized Theta_hat (this path
    always owns the full (d, d) estimate, so the symmetrization the
    sharded pipeline cannot afford is free here); default False keeps
    the historical raw-column debias.  ``state_layout`` disambiguates
    2-D ``state_beta`` leaves exactly as in :func:`solve_dantzig_path`.
    """
    hs = head.stats(*data)
    factor = as_spectral_factor(hs.sigma)
    dir_path = solve_dantzig_path(
        factor, hs.rhs, lams, cfg, rho=rho_beta,
        state=state_beta, state_layout=state_layout)  # beta: (L, d, K)
    d = hs.rhs.shape[0]
    theta = solve_clime_columns(
        factor, jnp.arange(d), lam_prime, cfg, rho=rho_theta,
        state=state_theta)  # (d, d)
    if symmetrize:
        theta = symmetrize_min(theta)
    # debias every grid point with the ONE shared Theta_hat
    resid = jnp.einsum("ij,ljk->lik", hs.sigma, dir_path.beta) - hs.rhs[None]
    beta_tilde = dir_path.beta - jnp.einsum("ji,ljk->lik", theta, resid)
    return WorkerPathResult(
        beta_tilde=beta_tilde,
        beta_hat=dir_path.beta,
        lam=dir_path.lam,
        kkt=dir_path.kkt,
        rho_beta=dir_path.rho,
        stats=hs,
        state_beta=dir_path.state,
        iters=dir_path.iters,
    )


def select_by_kkt(result: "PathResult | WorkerPathResult", tol: float = 1e-3):
    """Index of the smallest lambda whose solve is tol-feasible.

    Smaller lambda = tighter box = better statistical rate (the paper's
    lam ∝ sqrt(log d / n) is the smallest radius the concentration
    bound allows), but below the solvable radius ADMM leaves a
    constraint violation.  Rule: among grid points with
    ``max_k kkt <= tol`` pick the smallest lambda; if none qualify,
    fall back to the smallest violation.  Returns a traced scalar index
    into ``result.lam``.
    """
    kkt = result.kkt
    kkt_max = kkt if kkt.ndim == 1 else jnp.max(kkt, axis=-1)  # (L,)
    feasible = kkt_max <= tol
    lam_key = jnp.where(feasible, result.lam, jnp.inf)
    return jnp.where(
        jnp.any(feasible), jnp.argmin(lam_key), jnp.argmin(kkt_max))


def select_by_validation(betas: jnp.ndarray, score_fn):
    """Index of the best-scoring estimate along the leading lambda axis.

    ``score_fn(beta) -> scalar`` (higher is better, e.g. held-out
    accuracy); evaluated per grid point.  Returns ``(index, scores)``.
    """
    scores = jnp.stack([score_fn(betas[i]) for i in range(betas.shape[0])])
    return jnp.argmax(scores), scores


def take_lambda(path_values: jnp.ndarray, idx) -> jnp.ndarray:
    """Select one grid point from any (L, ...) path output (traced-safe)."""
    return jnp.take(path_values, idx, axis=0)
