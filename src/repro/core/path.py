"""Lambda-regularization-path solves folded into ONE blocked launch.

The paper picks the Dantzig box radius lam ∝ sqrt(log d / n) with
constants tuned on held-out data (§5; Lee et al.'s one-shot sparse
regression and Wang et al.'s EDSL run the same per-machine sweeps), so
in practice every worker solves the SAME problem across an L-point
lambda grid.  Run naively that is L sequential solver launches and
L + 1 eigendecompositions per worker (each launch re-factorizes, plus
the CLIME solve).  Both redundancies fold away:

  * the spectral factor (:mod:`repro.kernels.spectral`) is lam- and
    rho-independent, so ONE ``eigh`` serves the whole sweep AND the
    CLIME solve;
  * ``lam`` and ``rho`` are per-column operands of the blocked fused
    kernel, so an L-point grid over a (d, k) batch is just a
    (d, k*L) batch with ``lam`` varying across the replicated column
    blocks -- one launch, with
    :func:`repro.kernels.dantzig_fused.pick_block_k` sizing the Pallas
    grid exactly as for any other wide batch.

:func:`solve_dantzig_path` implements the fold for a raw solve;
:func:`worker_debiased_path` runs a worker's ENTIRE debiased pipeline
across the grid (one eigh, one wide direction launch, one CLIME solve
shared by every lambda).  Selection helpers pick the operating point
from the single launch: :func:`select_by_kkt` (most-constrained
feasible lambda) or :func:`select_by_validation` (held-out score).
Warm per-(column, lambda) rho rides along in the results, so repeated
sweeps (e.g. across bootstrap draws or data refreshes) thread their
penalties forward without recompiling -- rho is a traced operand.

Column layout: lambda index l owns columns [l*k, (l+1)*k); outputs
unfold to a leading (L, ...) axis.  Columns never interact in the
kernel, so the folded sweep is exact, not approximate -- pinned to
1e-5 against L independent solves on every dispatch path by
``tests/test_spectral_path.py``.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax.numpy as jnp

from repro.core.clime import solve_clime_columns
from repro.core.dantzig import DantzigConfig, kkt_violation
from repro.core.pipeline import DiscriminantHead, HeadStats
from repro.core.solver_dispatch import solve_dantzig_with_rho
from repro.kernels.spectral import SpectralFactor, as_spectral_factor

__all__ = [
    "PathResult",
    "WorkerPathResult",
    "solve_dantzig_path",
    "worker_debiased_path",
    "select_by_kkt",
    "select_by_validation",
    "take_lambda",
]


class PathResult(NamedTuple):
    """One folded sweep: everything indexed by the leading lambda axis."""

    beta: jnp.ndarray  # (L, d, k) solutions ((L, d) for vector rhs)
    lam: jnp.ndarray  # (L,) the grid
    kkt: jnp.ndarray  # (L, k) constraint violations ((L,) for vector rhs)
    rho: jnp.ndarray  # (L, k) final per-(lambda, column) ADMM penalties


def solve_dantzig_path(
    a: jnp.ndarray | SpectralFactor,
    b: jnp.ndarray,
    lams: jnp.ndarray,
    cfg: DantzigConfig = DantzigConfig(),
    *,
    rho: jnp.ndarray | None = None,
    backend: str | None = None,
) -> PathResult:
    """Solve a (d, k) Dantzig batch at EVERY lambda in one launch.

    Args:
      a:    (d, d) PSD matrix or its :class:`SpectralFactor`; a raw
            matrix is factorized once for the whole sweep.
      b:    (d,) or (d, k) right-hand side(s), shared by all lambdas.
      lams: (L,) box-radius grid.
      rho:  optional warm per-(lambda, column) penalties -- scalar,
            (L,), (k,), or (L, k) (e.g. ``PathResult.rho`` from the
            previous sweep); a traced operand on the fused paths, so
            re-sweeping never recompiles.

    The k*L columns dispatch as ONE batch: ``select_solver`` sees
    (d, k*L) and tiles it over the Pallas grid with the same
    ``pick_block_k`` sizing as any other batch (or falls back to scan
    under the usual rules).  Returns a :class:`PathResult`.
    """
    factor = as_spectral_factor(a)
    squeeze = b.ndim == 1
    b2 = b[:, None] if squeeze else b
    d, k = b2.shape
    lams = jnp.asarray(lams)
    (L,) = lams.shape

    # fold: lambda l owns columns [l*k, (l+1)*k)
    wide_b = jnp.tile(b2, (1, L))
    wide_lam = jnp.repeat(lams.astype(b2.dtype), k)
    wide_rho = None
    if rho is not None:
        r = jnp.asarray(rho, jnp.float32)
        if r.ndim == 0:
            r = jnp.broadcast_to(r, (L, k))
        elif r.ndim == 1:
            # (L,) = per-lambda (wins the L == k ambiguity), (k,) = per-column
            if r.shape[0] == L:
                r = jnp.broadcast_to(r[:, None], (L, k))
            elif r.shape[0] == k:
                r = jnp.broadcast_to(r[None, :], (L, k))
            else:
                raise ValueError(f"rho shape {r.shape} matches neither "
                                 f"(L,)=({L},) nor (k,)=({k},)")
        else:
            r = jnp.broadcast_to(r, (L, k))
        wide_rho = r.reshape(L * k)

    wide_out, wide_rho_final = solve_dantzig_with_rho(
        factor, wide_b, wide_lam, cfg, rho=wide_rho, backend=backend)

    wide_kkt = kkt_violation(factor.sigma, wide_b, wide_out, wide_lam)

    beta = jnp.moveaxis(wide_out.reshape(d, L, k), 1, 0)  # (L, d, k)
    kkt = wide_kkt.reshape(L, k)
    rho_final = jnp.broadcast_to(
        jnp.asarray(wide_rho_final, jnp.float32), (L * k,)).reshape(L, k)
    if squeeze:
        return PathResult(beta[:, :, 0], lams, kkt[:, 0], rho_final)
    return PathResult(beta, lams, kkt, rho_final)


class WorkerPathResult(NamedTuple):
    """A worker's debiased pipeline swept across the lambda grid."""

    beta_tilde: jnp.ndarray  # (L, d, K) debiased direction blocks
    beta_hat: jnp.ndarray  # (L, d, K) biased local estimates
    lam: jnp.ndarray  # (L,)
    kkt: jnp.ndarray  # (L, K) direction-solve constraint violations
    rho_beta: jnp.ndarray  # (L, K) warm penalties for the next sweep
    stats: HeadStats  # the head's sufficient statistics (lambda-free)


def worker_debiased_path(
    head: DiscriminantHead,
    *data: jnp.ndarray,
    lams: jnp.ndarray,
    lam_prime,
    cfg: DantzigConfig = DantzigConfig(),
    rho_beta: jnp.ndarray | None = None,
    rho_theta: jnp.ndarray | None = None,
) -> WorkerPathResult:
    """One machine's debiased estimate at EVERY lambda in one launch.

    The lambda-path analogue of
    :func:`repro.core.pipeline.worker_debiased`: one ``eigh``
    factorizes Sigma_hat for the entire sweep, the (d, K) direction
    block solves at all L grid points in a single folded launch
    (k = K -> K*L columns), and ONE CLIME solve at ``lam_prime``
    (lambda-independent, like the factor) debiases every grid point:

        beta_tilde_l = beta_hat_l - Theta^T (Sigma beta_hat_l - rhs).

    That is 1 launch + 1 eigendecomposition where the sequential sweep
    pays L launches + L+1 eigendecompositions.  ``rho_beta`` /
    ``rho_theta`` thread warm penalties exactly as in the single-point
    pipeline (``rho_beta`` additionally accepts the (L, K) carry from a
    previous :class:`WorkerPathResult`).

    Runs unsharded (the mesh paths tune lambda per machine before
    entering shard_map; the CLIME model-axis sharding composes with a
    single chosen lambda, not with the sweep).
    """
    hs = head.stats(*data)
    factor = as_spectral_factor(hs.sigma)
    dir_path = solve_dantzig_path(
        factor, hs.rhs, lams, cfg, rho=rho_beta)  # beta: (L, d, K)
    d = hs.rhs.shape[0]
    theta = solve_clime_columns(
        factor, jnp.arange(d), lam_prime, cfg, rho=rho_theta)  # (d, d)
    # debias every grid point with the ONE shared Theta_hat
    resid = jnp.einsum("ij,ljk->lik", hs.sigma, dir_path.beta) - hs.rhs[None]
    beta_tilde = dir_path.beta - jnp.einsum("ji,ljk->lik", theta, resid)
    return WorkerPathResult(
        beta_tilde=beta_tilde,
        beta_hat=dir_path.beta,
        lam=dir_path.lam,
        kkt=dir_path.kkt,
        rho_beta=dir_path.rho,
        stats=hs,
    )


def select_by_kkt(result: "PathResult | WorkerPathResult", tol: float = 1e-3):
    """Index of the smallest lambda whose solve is tol-feasible.

    Smaller lambda = tighter box = better statistical rate (the paper's
    lam ∝ sqrt(log d / n) is the smallest radius the concentration
    bound allows), but below the solvable radius ADMM leaves a
    constraint violation.  Rule: among grid points with
    ``max_k kkt <= tol`` pick the smallest lambda; if none qualify,
    fall back to the smallest violation.  Returns a traced scalar index
    into ``result.lam``.
    """
    kkt = result.kkt
    kkt_max = kkt if kkt.ndim == 1 else jnp.max(kkt, axis=-1)  # (L,)
    feasible = kkt_max <= tol
    lam_key = jnp.where(feasible, result.lam, jnp.inf)
    return jnp.where(
        jnp.any(feasible), jnp.argmin(lam_key), jnp.argmin(kkt_max))


def select_by_validation(betas: jnp.ndarray, score_fn):
    """Index of the best-scoring estimate along the leading lambda axis.

    ``score_fn(beta) -> scalar`` (higher is better, e.g. held-out
    accuracy); evaluated per grid point.  Returns ``(index, scores)``.
    """
    scores = jnp.stack([score_fn(betas[i]) for i in range(betas.shape[0])])
    return jnp.argmax(scores), scores


def take_lambda(path_values: jnp.ndarray, idx) -> jnp.ndarray:
    """Select one grid point from any (L, ...) path output (traced-safe)."""
    return jnp.take(path_values, idx, axis=0)
