"""Streaming refit + resilient serving core (DESIGN.md §12).

The paper's estimator exists to *classify* (eq. 1.1); this module is
the layer between the trained estimator and live traffic:

* **Mergeable sufficient statistics** -- :func:`merge_suff_stats` /
  :func:`merge_mc_stats` combine two machines'/chunks' ``SuffStats`` /
  ``MCStats`` exactly (per-class rank-1 mean-shift corrections on the
  pooled scatter), so data can arrive in chunks of any size -- down to
  rank-1 single samples -- and the merged statistics equal the
  one-shot statistics on the concatenated data.
* **Ingest screening** -- :func:`screen_batch` reuses the
  :func:`repro.core.faults.screen_weight` policy (non-finite /
  envelope) on the RAW arriving batch; :func:`ingest_stats` then
  quarantines a poisoned batch with a ``where``-select, leaving the
  accumulated statistics bit-identical to never having seen it.
* **Incremental refit** -- :func:`refit_step` re-solves the estimator
  directly from merged :class:`~repro.core.pipeline.HeadStats` (one
  fresh ``eigh``, pinned by trace contract) resuming through the warm
  ``AdmmState``/rho carries of PR 4; :func:`refit_with_escalation`
  wraps it in the bounded non-convergence ladder (warm retry -> cold
  retry -> full refactorize with a boosted iteration budget).
* **Graceful degradation** -- :class:`ModelSlot` double buffering (a
  failed or diverged refit never touches the serving estimator), the
  live/stale/degraded bounded-staleness contract
  (:func:`slot_status`), and the deterministic seedable
  :class:`ServeFaultSchedule` fault-injection harness (ingest
  corruption, refit divergence, refresh drops).
* **The serving hot path** -- :func:`classify_batch`, a fused
  ``(B, d) @ (d, K)`` score + argmax with priors, trace-contracted to
  0 eigh / 0 ADMM loops / 0 collectives / exactly 1 matmul per query
  batch.

:class:`ServingRuntime` composes all of it into the host-side loop
behind ``python -m repro.launch.serve``, ``benchmarks/serving.py``
and the chaos tests, including crash recovery through
:mod:`repro.checkpoint` model-slot snapshots.
"""

from __future__ import annotations

import time
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import (
    DtypePolicy,
    Param,
    PrimitiveBudget,
    VmemConformance,
    trace_contract,
)
from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.core import classifier
from repro.core import transport as transport_core
from repro.core.dantzig import AdmmState, DantzigConfig
from repro.core.faults import _CORRUPT_CODES, Aggregation, screen_weight
from repro.core.pipeline import (
    HeadStats,
    MCStats,
    SuffStats,
    debias,
    mc_direction_rhs,
    solves_from_stats,
)
from repro.core.slda import hard_threshold
from repro.kernels.spectral import SpectralFactor

__all__ = [
    "STATUS_DEGRADED",
    "STATUS_LIVE",
    "STATUS_STALE",
    "EscalationPolicy",
    "ModelSlot",
    "RefitCarry",
    "RefitResult",
    "ServeFaultPlan",
    "ServeFaultSchedule",
    "ServingRuntime",
    "classify_batch",
    "head_stats_of",
    "ingest_stats",
    "merge_mc_stats",
    "merge_stats",
    "merge_suff_stats",
    "refit_converged",
    "refit_step",
    "refit_with_escalation",
    "screen_batch",
    "slot_from_stats",
    "slot_status",
    "snapshot_template",
]


# ---------------------------------------------------------------------------
# Mergeable sufficient statistics (chunked / rank-1 streaming ingest)
# ---------------------------------------------------------------------------


def _wmean(ma, na, mb, nb):
    """Count-weighted mean of two class means, safe for empty classes.

    An empty class's mean may be NaN (``suff_stats`` divides by a zero
    count); the contribution is SELECTED out with ``where``, never
    multiplied -- 0 * NaN would re-poison the merge.
    """
    na_f = jnp.asarray(na, ma.dtype)
    nb_f = jnp.asarray(nb, mb.dtype)
    num = (jnp.where(na_f > 0, na_f * ma, 0.0)
           + jnp.where(nb_f > 0, nb_f * mb, 0.0))
    return num / jnp.maximum(na_f + nb_f, 1.0)


def _shift_outer(ma, na, mb, nb):
    """The rank-1 pooled-scatter correction of one class across a merge.

    ``scatter_ab = scatter_a + scatter_b + w * delta delta^T`` with
    ``w = n_a n_b / (n_a + n_b)`` and ``delta = mu_a - mu_b`` -- the
    exact parallel-axis decomposition of the within-class scatter, so
    chunked merging reproduces the one-shot statistics.
    """
    na_f = jnp.asarray(na, ma.dtype)
    nb_f = jnp.asarray(nb, mb.dtype)
    both = (na_f > 0) & (nb_f > 0)
    w = jnp.where(both, na_f * nb_f / jnp.maximum(na_f + nb_f, 1.0), 0.0)
    delta = jnp.where(both, ma - mb, 0.0)
    return w * jnp.outer(delta, delta)


def merge_suff_stats(a: SuffStats, b: SuffStats) -> SuffStats:
    """Exact merge of two two-class :class:`SuffStats` accumulators.

    ``sigma`` is the pooled within-class scatter over n1 + n2, so the
    merge rebuilds the scatter, applies the per-class rank-1 mean-shift
    corrections, and re-normalizes.  Associative up to float rounding;
    a single sample in ``b`` is the rank-1 update of DESIGN.md §12.
    """
    n_a = jnp.asarray(a.n1 + a.n2, a.sigma.dtype)
    n_b = jnp.asarray(b.n1 + b.n2, b.sigma.dtype)
    scatter = a.sigma * n_a + b.sigma * n_b
    scatter = scatter + _shift_outer(a.mu1, a.n1, b.mu1, b.n1)
    scatter = scatter + _shift_outer(a.mu2, a.n2, b.mu2, b.n2)
    sigma = scatter / jnp.maximum(n_a + n_b, 1.0)
    return SuffStats(
        sigma,
        _wmean(a.mu1, a.n1, b.mu1, b.n1),
        _wmean(a.mu2, a.n2, b.mu2, b.n2),
        a.n1 + b.n1,
        a.n2 + b.n2,
    )


def merge_mc_stats(a: MCStats, b: MCStats) -> MCStats:
    """Exact merge of two K-class :class:`MCStats` accumulators.

    Same parallel-axis decomposition as :func:`merge_suff_stats`, one
    rank-1 correction per class (``mc_suff_stats`` zero-fills empty
    class means, so no NaN guards are needed on the means themselves).
    """
    n_a = jnp.sum(a.counts)
    n_b = jnp.sum(b.counts)
    counts = a.counts + b.counts
    means = ((a.counts[:, None] * a.means + b.counts[:, None] * b.means)
             / jnp.maximum(counts, 1.0)[:, None])
    delta = a.means - b.means  # (K, d)
    both = (a.counts > 0) & (b.counts > 0)
    w = jnp.where(both, a.counts * b.counts / jnp.maximum(counts, 1.0), 0.0)
    corr = jnp.einsum("k,ki,kj->ij", w, delta, delta)
    sigma = (a.sigma * n_a + b.sigma * n_b + corr) / jnp.maximum(n_a + n_b, 1.0)
    return MCStats(sigma, means, counts)


def merge_stats(a, b):
    """Type-dispatched merge of two same-head sufficient statistics."""
    if isinstance(a, SuffStats):
        return merge_suff_stats(a, b)
    if isinstance(a, MCStats):
        return merge_mc_stats(a, b)
    raise TypeError(f"unmergeable stats type {type(a).__name__}")


def head_stats_of(aux) -> HeadStats:
    """Rebuild the pipeline-facing :class:`HeadStats` from merged aux.

    The inverse of ``head.stats(*data).aux``: streaming accumulates the
    aux statistics (they merge exactly); the direction right-hand sides
    are re-derived from them at refit time.
    """
    if isinstance(aux, SuffStats):
        return HeadStats(aux.sigma, aux.mu_d[:, None], aux)
    if isinstance(aux, MCStats):
        return HeadStats(aux.sigma, mc_direction_rhs(aux), aux)
    raise TypeError(f"headless stats type {type(aux).__name__}")


# ---------------------------------------------------------------------------
# Ingest screening / quarantine
# ---------------------------------------------------------------------------


def screen_batch(agg: Aggregation, *arrays: jnp.ndarray) -> jnp.ndarray:
    """Ingest-screening weight in {0., 1.} over a batch's float arrays.

    Reuses the per-machine :func:`repro.core.faults.screen_weight`
    policy on the RAW arriving data -- BEFORE any statistic is formed,
    so one poisoned batch cannot contaminate the accumulators.  Integer
    arrays (labels) pass through unscreened.
    """
    w = jnp.ones(())
    for arr in arrays:
        if jnp.issubdtype(arr.dtype, jnp.floating):
            w = w * screen_weight(agg, arr)
    return w


def ingest_stats(aux, batch_aux, weight: jnp.ndarray):
    """Merge a batch's statistics, quarantining when ``weight == 0``.

    The quarantine is a ``where``-SELECT on every leaf: a rejected
    batch leaves the accumulated statistics bit-identical to never
    having seen it (NaN in the discarded merge branch cannot leak --
    ``where`` selects, never multiplies).
    """
    merged = merge_stats(aux, batch_aux)
    return jax.tree.map(
        lambda new, old: jnp.where(weight > 0, new,
                                   jnp.asarray(old, new.dtype)),
        merged, aux)


# ---------------------------------------------------------------------------
# The serving hot path (trace-contracted)
# ---------------------------------------------------------------------------


@trace_contract(
    "streaming.classify_batch",
    contracts=(
        # a query batch touches NO estimator machinery: the score matmul
        # is the only dot, and there is no eigh, no ADMM loop (while /
        # scan), no kernel launch and no collective anywhere in the trace
        PrimitiveBudget("eigh", exact=0),
        PrimitiveBudget("while", exact=0),
        PrimitiveBudget("scan", exact=0),
        PrimitiveBudget("pallas_call", exact=0),
        PrimitiveBudget("psum", exact=0),
        PrimitiveBudget("all_gather", exact=0),
        PrimitiveBudget("dot_general", exact=1),
        DtypePolicy(),
    ),
)
def classify_batch(
    z: jnp.ndarray,
    beta: jnp.ndarray,
    means: jnp.ndarray,
    priors: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The fused (B, d) @ (d, K) serving hot path.

    Returns ``(pred (B,) int, scores (B, K))`` -- the scores ride along
    so the serving loop can monitor finiteness without a second pass.
    One ``dot_general``; the per-class offsets and priors are
    elementwise (see :func:`repro.core.classifier.classify_scores`).
    """
    scores = classifier.classify_scores(z, beta, means, priors)
    return jnp.argmax(scores, axis=-1), scores


# ---------------------------------------------------------------------------
# Incremental refit + escalation ladder
# ---------------------------------------------------------------------------


class RefitCarry(NamedTuple):
    """Warm-start carries threaded across streaming refits (PR 4/5)."""

    rho_beta: jnp.ndarray  # (K,)
    rho_theta: jnp.ndarray  # (d,)
    state_beta: AdmmState  # leaves (d, K)
    state_theta: AdmmState  # leaves (d, d)


class RefitResult(NamedTuple):
    beta_tilde: jnp.ndarray  # (d, K) debiased direction block
    beta_hat: jnp.ndarray  # (d, K) biased solution
    theta: jnp.ndarray  # (d, d) CLIME block
    factor: SpectralFactor  # the refit's ONE factorization
    carry: RefitCarry  # resumable warm state for the next refit
    iters_beta: jnp.ndarray  # (K,) executed ADMM iterations
    iters_theta: jnp.ndarray  # (d,)


@trace_contract(
    "streaming.refit_step",
    contracts=(
        # ONE fresh factorization per refit -- the moved sigma must be
        # re-factorized, but never twice (direction + CLIME share it)
        PrimitiveBudget("eigh", exact=1),
        PrimitiveBudget("pallas_call", exact=Param("pallas_calls")),
        # refit is a single-machine operation: nothing on the wire
        PrimitiveBudget("psum", exact=0),
        PrimitiveBudget("all_gather", exact=0),
        DtypePolicy(),
        VmemConformance(),
    ),
)
def refit_step(
    stats: HeadStats,
    lam,
    lam_prime,
    cfg: DantzigConfig = DantzigConfig(),
    carry: RefitCarry | None = None,
    symmetrize: bool = False,
) -> RefitResult:
    """Re-solve the estimator from merged sufficient statistics.

    The streaming twin of :func:`repro.core.pipeline.worker_solves`:
    the raw-sample pass is replaced by the accumulated
    :class:`HeadStats`, and a ``carry`` resumes both ADMM solves from
    the previous refit's warm rho/:class:`AdmmState` -- the
    slightly-moved-problem machinery of PR 4 applied to data drift.
    The solves themselves run through the factored-out
    :func:`~repro.core.pipeline.solves_from_stats`, so the served
    estimator is the pipeline's estimator by construction.
    """
    kw = {}
    if carry is not None:
        kw = dict(rho_beta=carry.rho_beta, rho_theta=carry.rho_theta,
                  state_beta=carry.state_beta, state_theta=carry.state_theta)
    ws = solves_from_stats(stats, lam=lam, lam_prime=lam_prime, cfg=cfg,
                           symmetrize=symmetrize, full=True, **kw)
    beta_tilde = debias(stats.sigma, stats.rhs, ws.beta_hat, ws.theta)
    return RefitResult(
        beta_tilde, ws.beta_hat, ws.theta, ws.factor,
        RefitCarry(ws.rho_beta, ws.rho_theta, ws.state_beta, ws.state_theta),
        ws.iters_beta, ws.iters_theta)


def refit_converged(res: RefitResult, cfg: DantzigConfig) -> bool:
    """Host-side convergence verdict for one refit attempt.

    Non-finite output is always a failure.  With a residual tolerance
    configured, a solve that burned its whole iteration budget without
    early-exiting is treated as non-converged (``iters == max_iters``);
    the fixed-iteration schedule (``tol=None``) can only fail by
    producing non-finite values.
    """
    finite = bool(np.isfinite(np.asarray(res.beta_tilde)).all()
                  and np.isfinite(np.asarray(res.theta)).all())
    if not finite:
        return False
    if cfg.tol is None:
        return True
    executed = max(int(np.max(np.asarray(res.iters_beta))),
                   int(np.max(np.asarray(res.iters_theta))))
    return executed < cfg.max_iters


class EscalationPolicy(NamedTuple):
    """Bounded-attempt escalation on refit non-convergence.

    The ladder is warm retry (resume the carry) -> cold retry (fresh
    ADMM state, same statistics) -> full refactorize (fresh state, a
    re-symmetrized sigma and a ``refactor_scale``-boosted iteration
    budget).  ``max_attempts`` bounds how far the ladder is climbed;
    ``backoff_s`` sleeps ``backoff_s * 2^attempt`` between rungs (0 in
    CI -- the schedule is still exercised, just without the waiting).
    """

    max_attempts: int = 3
    backoff_s: float = 0.0
    refactor_scale: int = 2

    def validate(self) -> None:
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.backoff_s < 0:
            raise ValueError("backoff_s must be >= 0")
        if self.refactor_scale < 1:
            raise ValueError("refactor_scale must be >= 1")


def refit_with_escalation(
    stats: HeadStats,
    lam,
    lam_prime,
    cfg: DantzigConfig,
    carry: RefitCarry | None,
    policy: EscalationPolicy = EscalationPolicy(),
    inject_fail_attempts: int = 0,
) -> tuple[RefitResult | None, list[dict]]:
    """Climb the escalation ladder until a refit converges.

    Returns ``(result, attempt_log)``; ``result`` is ``None`` when every
    attempt within ``policy.max_attempts`` failed (the caller keeps
    serving the last-good slot and counts a missed refresh).

    ``inject_fail_attempts`` is the deterministic divergence hook of the
    fault harness: the first n attempts have their solutions poisoned to
    NaN AFTER solving, so the detection + escalation path is exercised
    end to end exactly as a genuinely diverged solve would drive it.
    """
    policy.validate()
    ladder: list[tuple[str, RefitCarry | None, DantzigConfig, HeadStats]] = []
    if carry is not None:
        ladder.append(("warm", carry, cfg, stats))
    ladder.append(("cold", None, cfg, stats))
    refactor_cfg = cfg._replace(
        max_iters=cfg.max_iters * policy.refactor_scale)
    refactor_stats = stats._replace(
        sigma=0.5 * (stats.sigma + stats.sigma.T))
    ladder.append(("refactor", None, refactor_cfg, refactor_stats))
    log: list[dict] = []
    for attempt, (name, c, cfg_a, st) in enumerate(
            ladder[: policy.max_attempts]):
        if attempt > 0 and policy.backoff_s > 0:
            time.sleep(policy.backoff_s * (2 ** (attempt - 1)))
        res = refit_step(st, lam, lam_prime, cfg_a, carry=c)
        if attempt < inject_fail_attempts:
            res = res._replace(
                beta_tilde=jnp.full_like(res.beta_tilde, jnp.nan))
        ok = refit_converged(res, cfg_a)
        log.append({
            "attempt": name,
            "converged": ok,
            "iters_beta": int(np.max(np.asarray(res.iters_beta))),
            "iters_theta": int(np.max(np.asarray(res.iters_theta))),
        })
        if ok:
            return res, log
    return None, log


# ---------------------------------------------------------------------------
# Model slots + the live/stale/degraded contract
# ---------------------------------------------------------------------------

STATUS_LIVE = "live"
STATUS_STALE = "stale"
STATUS_DEGRADED = "degraded"


class ModelSlot(NamedTuple):
    """One immutable published model: everything the hot path reads.

    ``means`` rows are the per-class scoring anchors ``c_k`` of
    ``score_k(z) = (z - c_k / 2) @ beta[:, k] + log priors[k]``.  For
    the K-class head they ARE the class means; for the binary head the
    anchors are ``mu_k + mu_bar`` with directions ``+-beta / 2``, which
    makes the two-column rule EXACTLY the paper's Fisher rule at equal
    priors (pinned by the parity tests).
    """

    beta: jnp.ndarray  # (d, Kc) classifier direction columns
    means: jnp.ndarray  # (Kc, d) scoring anchors
    priors: jnp.ndarray  # (Kc,)
    version: jnp.ndarray  # scalar int32, bumped per publish


def _binary_slot(s: SuffStats, beta: jnp.ndarray, version: int) -> ModelSlot:
    beta = beta.reshape(-1)
    mu_bar = 0.5 * (s.mu1 + s.mu2)
    cols = jnp.stack([0.5 * beta, -0.5 * beta], axis=1)
    anchors = jnp.stack([s.mu1 + mu_bar, s.mu2 + mu_bar])
    n1 = jnp.asarray(s.n1, beta.dtype)
    n2 = jnp.asarray(s.n2, beta.dtype)
    priors = jnp.stack([n1, n2]) / jnp.maximum(n1 + n2, 1.0)
    return ModelSlot(cols, anchors, priors, jnp.asarray(version, jnp.int32))


def _mc_slot(s: MCStats, beta: jnp.ndarray, version: int) -> ModelSlot:
    priors = s.counts / jnp.maximum(jnp.sum(s.counts), 1.0)
    return ModelSlot(beta, s.means, priors, jnp.asarray(version, jnp.int32))


def slot_from_stats(aux, beta_raw: jnp.ndarray, threshold: float,
                    version: int = 0) -> ModelSlot:
    """Publishable :class:`ModelSlot` from a refit + the aux statistics."""
    beta = hard_threshold(beta_raw, threshold)
    if isinstance(aux, SuffStats):
        return _binary_slot(aux, beta, version)
    if isinstance(aux, MCStats):
        return _mc_slot(aux, beta, version)
    raise TypeError(f"slotless stats type {type(aux).__name__}")


def slot_status(missed: int, bound: int) -> str:
    """The bounded-staleness verdict, mirroring ``select_anchor``.

    ``missed`` consecutive missed refreshes clip against the caller's
    bound exactly like a straggler's requested staleness (DESIGN.md
    §11.3): within the bound the slot serves as ``stale``; past it the
    server KEEPS SERVING the last-good slot but must report
    ``degraded`` -- degradation is a reporting contract, not an outage.
    """
    if missed <= 0:
        return STATUS_LIVE
    return STATUS_STALE if missed <= bound else STATUS_DEGRADED


# ---------------------------------------------------------------------------
# Deterministic serving fault plans
# ---------------------------------------------------------------------------


class ServeFaultPlan(NamedTuple):
    """Materialized per-tick fault outcomes (host-side numpy arrays)."""

    corrupt: np.ndarray  # (ticks,) int32 CORRUPT_* code for the ingest batch
    diverge: np.ndarray  # (ticks,) int32 refit attempts to poison
    drop: np.ndarray  # (ticks,) bool -- the tick's refresh is dropped


class ServeFaultSchedule(NamedTuple):
    """Seedable per-tick serving faults (:class:`FaultSchedule` twin).

    Hashable scalars; :meth:`plan` materializes the outcomes so a chaos
    run reproduces bit-for-bit from the seed.  ``corrupt_ingest``
    poisons the tick's arriving data batch (``corrupt_mode`` as in
    :mod:`repro.core.faults` -- ``"mix"`` cycles NaN/Inf/garbage);
    ``diverge_refit`` poisons the first 1-2 refit attempts of the
    tick's refresh; ``drop_refresh`` skips the refresh entirely.
    """

    corrupt_ingest: float = 0.0
    diverge_refit: float = 0.0
    drop_refresh: float = 0.0
    corrupt_mode: str = "mix"
    seed: int = 0

    def validate(self) -> None:
        for name, p in (("corrupt_ingest", self.corrupt_ingest),
                        ("diverge_refit", self.diverge_refit),
                        ("drop_refresh", self.drop_refresh)):
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p}")
        if self.corrupt_mode != "mix" and self.corrupt_mode not in _CORRUPT_CODES:
            raise ValueError(f"unknown corrupt_mode {self.corrupt_mode!r}")

    def plan(self, ticks: int) -> ServeFaultPlan:
        self.validate()
        k_c, k_d, k_r = jax.random.split(jax.random.PRNGKey(self.seed), 3)
        hit_c = np.asarray(jax.random.uniform(k_c, (ticks,))
                           < self.corrupt_ingest)
        if self.corrupt_mode == "mix":
            code = 1 + np.arange(ticks) % 3
        else:
            code = _CORRUPT_CODES[self.corrupt_mode]
        corrupt = np.where(hit_c, code, 0).astype(np.int32)
        hit_d = np.asarray(jax.random.uniform(k_d, (ticks,))
                           < self.diverge_refit)
        # alternate 1- and 2-rung divergence so both the cold retry and
        # the full refactorize rung get exercised deterministically
        diverge = np.where(hit_d, 1 + np.arange(ticks) % 2, 0).astype(np.int32)
        drop = np.asarray(jax.random.uniform(k_r, (ticks,))
                          < self.drop_refresh)
        return ServeFaultPlan(corrupt, diverge, drop)


# ---------------------------------------------------------------------------
# Checkpoint templates (crash recovery of the serving loop)
# ---------------------------------------------------------------------------


def _zeros_admm(d: int, k: int) -> AdmmState:
    z = jnp.zeros((d, k))
    return AdmmState(z, z, z, z)


def snapshot_template(aux) -> dict:
    """Zeros pytree matching a serving snapshot's structure and shapes.

    The snapshot is the full last-good serving state: the published
    :class:`ModelSlot`, the accumulated aux statistics, the refit's
    :class:`SpectralFactor` and the warm :class:`RefitCarry` ADMM
    states -- everything :func:`ServingRuntime.restore` needs to resume
    serving AND refitting after a crash.
    """
    zero = jax.tree.map(jnp.zeros_like, aux)
    if isinstance(aux, SuffStats):
        d = aux.mu1.shape[0]
        k_solve, k_cls = 1, 2
    else:
        k_cls, d = aux.means.shape
        k_solve = k_cls
    slot = ModelSlot(jnp.zeros((d, k_cls)), jnp.zeros((k_cls, d)),
                     jnp.zeros((k_cls,)), jnp.zeros((), jnp.int32))
    factor = SpectralFactor(jnp.zeros((d, d)), jnp.zeros((d, d)),
                            jnp.zeros((d,)))
    carry = RefitCarry(jnp.zeros((k_solve,)), jnp.zeros((d,)),
                       _zeros_admm(d, k_solve), _zeros_admm(d, d))
    return {"slot": slot, "aux": zero, "factor": factor, "carry": carry}


# ---------------------------------------------------------------------------
# The serving runtime (host loop)
# ---------------------------------------------------------------------------


class ServingRuntime:
    """Classify-as-a-service over a streaming refit loop.

    Host-side driver composing the pieces above.  The jit'd hot path
    reads ONLY the active :class:`ModelSlot` (double-buffered: refits
    build a candidate slot off to the side and :meth:`refresh` swaps it
    in atomically on success); ingest screens before merging; refits
    climb the escalation ladder; missed refreshes count against the
    bounded-staleness contract.  ``protect=False`` is the deliberately
    fragile baseline -- no screening, no convergence verdict, no
    staleness accounting -- that the chaos gates must show degrading.
    """

    def __init__(
        self,
        aux,
        lam: float,
        lam_prime: float,
        threshold: float,
        cfg: DantzigConfig = DantzigConfig(),
        staleness_bound: int = 2,
        escalation: EscalationPolicy = EscalationPolicy(),
        ingest: Aggregation = Aggregation(envelope=1e6),
        protect: bool = True,
        ckpt_dir: str | None = None,
        comm: "transport_core.CommPlan | None" = None,
        _defer_fit: bool = False,
    ):
        self.lam, self.lam_prime, self.threshold = lam, lam_prime, threshold
        self.cfg = cfg
        if comm is not None:
            # the CommPlan shim (DESIGN.md §13): the runtime's comms
            # knobs come from the one plan -- its staleness bound maps
            # onto the refresh contract, its aggregation onto ingest
            # screening (the refit itself is single-machine: nothing of
            # the plan's codecs rides a wire here)
            comm.validate()
            staleness_bound = (comm.staleness if comm.staleness > 0
                               else staleness_bound)
            if comm.aggregation is not None:
                ingest = comm.aggregation
        self.staleness_bound = int(staleness_bound)
        self.escalation = escalation
        self.ingest_policy = ingest
        self.protect = bool(protect)
        self.ckpt_dir = ckpt_dir
        self.aux = aux
        self.carry: RefitCarry | None = None
        self.factor: SpectralFactor | None = None
        self.missed = 0
        self.ladder_log: list[dict] = []
        self.queries = 0
        self._jit_classify = jax.jit(classify_batch)
        self.slot: ModelSlot | None = None
        if not _defer_fit:
            res, log = refit_with_escalation(
                head_stats_of(aux), lam, lam_prime, cfg, None, escalation)
            self.ladder_log.extend(log)
            if res is None:
                raise RuntimeError("initial fit did not converge within "
                                   f"{escalation.max_attempts} attempts")
            self._stage(res, version=1)

    # -- lifecycle ---------------------------------------------------------

    def _stage(self, res: RefitResult, version: int) -> None:
        """Publish a converged refit: build + atomically swap the slot."""
        candidate = slot_from_stats(self.aux, res.beta_tilde,
                                    self.threshold, version)
        # the swap is the double-buffer commit point: the hot path holds
        # the previous slot until this rebind, so a failed refit (which
        # never reaches here) cannot expose partial state
        self.slot = candidate
        self.carry = res.carry
        self.factor = res.factor
        self.missed = 0
        if self.ckpt_dir is not None:
            save_checkpoint(self.ckpt_dir, int(candidate.version),
                            self.snapshot())

    def snapshot(self) -> dict:
        return {"slot": self.slot, "aux": self.aux,
                "factor": self.factor, "carry": self.carry}

    @classmethod
    def restore(cls, ckpt_dir: str, aux_like, lam, lam_prime, threshold,
                cfg: DantzigConfig = DantzigConfig(), **kw) -> "ServingRuntime":
        """Resume serving from the latest READABLE snapshot.

        ``latest_step`` skips torn/partial writes, so a server killed
        mid-checkpoint restores the previous good snapshot.
        """
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no restorable checkpoint in {ckpt_dir}")
        snap = restore_checkpoint(ckpt_dir, step,
                                  snapshot_template(aux_like))
        rt = cls(snap["aux"], lam, lam_prime, threshold, cfg=cfg,
                 ckpt_dir=ckpt_dir, _defer_fit=True, **kw)
        rt.slot = snap["slot"]
        rt.factor = snap["factor"]
        rt.carry = snap["carry"]
        return rt

    @property
    def status(self) -> str:
        return slot_status(self.missed, self.staleness_bound)

    # -- the three serving verbs ------------------------------------------

    def classify(self, z: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
        """The hot path: (B, d) queries -> (pred (B,), scores (B, Kc))."""
        s = self.slot
        self.queries += int(z.shape[0])
        return self._jit_classify(z, s.beta, s.means, s.priors)

    def ingest_batch(self, batch_aux, *raw: jnp.ndarray) -> bool:
        """Screen + merge one arriving batch; returns acceptance.

        ``raw`` are the arriving arrays (screened before the statistics
        are touched); ``batch_aux`` their sufficient statistics.  The
        unprotected baseline merges blindly.
        """
        if not self.protect:
            self.aux = merge_stats(self.aux, batch_aux)
            return True
        w = screen_batch(self.ingest_policy, *raw)
        self.aux = ingest_stats(self.aux, batch_aux, w)
        return bool(w > 0)

    def refresh(self, drop: bool = False, inject_diverge: int = 0) -> bool:
        """Attempt one model refresh; returns True when published.

        ``drop`` simulates a lost refresh (the staleness path);
        ``inject_diverge`` poisons the first n refit attempts (the
        divergence path).  Failures leave the active slot untouched and
        count a missed refresh against the staleness bound.
        """
        if drop:
            self.missed += 1
            return False
        if not self.protect:
            # fragile baseline: one attempt, no verdict, publish whatever
            res = refit_step(head_stats_of(self.aux), self.lam,
                             self.lam_prime, self.cfg, carry=None)
            if inject_diverge > 0:
                res = res._replace(
                    beta_tilde=jnp.full_like(res.beta_tilde, jnp.nan))
            self._stage(res, version=int(self.slot.version) + 1)
            return True
        res, log = refit_with_escalation(
            head_stats_of(self.aux), self.lam, self.lam_prime, self.cfg,
            self.carry, self.escalation,
            inject_fail_attempts=inject_diverge)
        self.ladder_log.extend(log)
        if res is None:
            self.missed += 1
            return False
        self._stage(res, version=int(self.slot.version) + 1)
        return True


def corrupt_batch_arrays(code: int, arrays: Sequence[jnp.ndarray]) -> tuple:
    """Apply one tick's ingest corruption to the float arrays of a batch."""
    from repro.core.faults import corrupt_block

    out: list[Any] = []
    for arr in arrays:
        if code and jnp.issubdtype(arr.dtype, jnp.floating):
            out.append(corrupt_block(jnp.asarray(code), arr))
        else:
            out.append(arr)
    return tuple(out)
