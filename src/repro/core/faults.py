"""Fault-tolerant aggregation for the refinement rounds (DESIGN.md §11).

The paper's premise is m genuinely remote machines, yet every
aggregation in :mod:`repro.core.rounds` assumed all m contribute a
finite payload to every round -- one dropped, straggling, or corrupted
uplink poisoned the round's mean for everyone, and the T-round
schedule multiplies the exposure by T.  Both one-shot averaging (Lee
et al.) and EDSL-style rounds (Wang et al.) tolerate a shrunken or
stale contributor set as long as the aggregation is weighted by who
actually showed up; this module makes that weighting explicit.

Three pieces, all stateless:

* :class:`FaultSchedule` -- a deterministic, seedable description of
  per-machine / per-round faults (dropout, straggle-by-s-rounds,
  payload corruption).  ``schedule.plan(m, rounds, bound)``
  materializes it into a :class:`FaultPlan` of (m, rounds) arrays that
  the drivers shard (mesh) or index (vmap twin).  The schedule itself
  is a hashable NamedTuple of scalars, so it rides as a static
  argument under ``jax.jit`` exactly like
  :class:`~repro.core.dantzig.DantzigConfig`.
* :class:`Aggregation` -- the robust-aggregation policy: screening of
  non-finite / out-of-envelope payloads (a screened machine
  contributes NOTHING to the round), liveness-masked mean that divides
  by the live count instead of m, and an optional per-coordinate
  trimmed mean dropping the top/bottom ``trim`` fraction.  If every
  machine of a round is screened the round falls back to the
  last-good aggregate -- no NaN ever escapes the loop.
* wire-fault injection (:func:`corrupt_block` /
  :func:`corrupt_payload`) -- what the receiver sees when an uplink is
  corrupted: NaN / Inf fills, or finite "garbage" of magnitude
  :data:`GARBAGE_MAGNITUDE` that only the envelope screen (or the
  trimmed mean) catches.  int8-compressed uplinks corrupt the per
  -column float32 scale -- the exact single-NaN-scale failure the
  decode screen of :mod:`repro.core.compression` also guards.

The mesh liveness mask travels as ONE extra scalar float32 psum on the
data axis per masked dense round (the live count); the trimmed mean
and the compressed masked path instead gather the per-machine blocks /
weights (:func:`gather_machines`), which is why this module is on the
``all_gather`` allow-list of :mod:`repro.analysis.imports`.  Both are
budgeted by the ``AxisPayloadBits`` / ``live_psums`` /  ``screen_ops``
params of the trace contracts in :mod:`repro.core.rounds` and
:mod:`repro.core.distributed`.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.compression import Compression, Payload

__all__ = [
    "Aggregation",
    "CORRUPT_GARBAGE",
    "CORRUPT_INF",
    "CORRUPT_NAN",
    "CORRUPT_NONE",
    "FaultPlan",
    "FaultSchedule",
    "GARBAGE_MAGNITUDE",
    "LIVENESS_BITS",
    "corrupt_block",
    "corrupt_payload",
    "gather_machines",
    "masked_mean",
    "screen_weight",
    "select_anchor",
    "trimmed_mean",
]

# corruption codes carried in FaultPlan.corrupt
CORRUPT_NONE = 0
CORRUPT_NAN = 1
CORRUPT_INF = 2
CORRUPT_GARBAGE = 3

_CORRUPT_CODES = {"nan": CORRUPT_NAN, "inf": CORRUPT_INF,
                  "garbage": CORRUPT_GARBAGE}
CORRUPT_MODES = (*_CORRUPT_CODES, "mix")

# magnitude of garbage corruption: FINITE, so the isfinite screen alone
# does not catch it -- only the envelope screen or the trimmed mean do
GARBAGE_MAGNITUDE = 1e12

# wire width of the per-round liveness mask on the dense masked path:
# one scalar float32 psum (the live count) rides next to the payload
LIVENESS_BITS = 32


class FaultPlan(NamedTuple):
    """Materialized per-machine, per-round fault outcomes (arrays).

    Leaves are (m, rounds) in driver/face hands, or (rounds,) inside
    one mesh shard (this machine's row -- the per-machine liveness
    operand the faces feed through ``shard_map``).

    Attributes:
      live: float32 1/0 -- 0 means the machine's round-t uplink is
        dropped entirely (it contributes nothing and its error
        -feedback residual carry is left untouched).
      stale: int32 >= 0 -- a straggler's requested staleness: at round
        t it re-submits its correction against the round-(t - s)
        anchor.  Clipped to the caller's ``staleness`` bound (and to
        t - 1) at use; 0 means fresh.
      corrupt: int32 CORRUPT_* code applied to the machine's uplink ON
        THE WIRE (the machine itself is honest: its residual carry
        uses its own uncorrupted payload).
    """

    live: jnp.ndarray
    stale: jnp.ndarray
    corrupt: jnp.ndarray

    @property
    def rounds(self) -> int:
        return self.live.shape[-1]

    def row(self, t: int):
        """Round-``t`` (1-indexed) slice: per-machine (live, stale, code)."""
        return (self.live[..., t - 1], self.stale[..., t - 1],
                self.corrupt[..., t - 1])


class FaultSchedule(NamedTuple):
    """Deterministic, seedable per-machine / per-round fault rates.

    Hashable (floats + str + int), so it is a static jit argument.
    Each (machine, round) cell draws dropout, straggle, and corruption
    independently from ``PRNGKey(seed)``; :meth:`plan` materializes
    the outcomes.  ``corrupt_mode`` picks the wire corruption --
    ``"nan"`` / ``"inf"`` / ``"garbage"`` (finite, magnitude
    :data:`GARBAGE_MAGNITUDE`) or ``"mix"`` cycling all three.
    """

    dropout: float = 0.0
    straggle: float = 0.0
    corrupt: float = 0.0
    corrupt_mode: str = "nan"
    seed: int = 0

    def validate(self) -> None:
        if self.corrupt_mode not in CORRUPT_MODES:
            raise ValueError(
                f"corrupt_mode must be one of {CORRUPT_MODES}, "
                f"got {self.corrupt_mode!r}")
        for name, p in (("dropout", self.dropout),
                        ("straggle", self.straggle),
                        ("corrupt", self.corrupt)):
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p}")

    def plan(self, m: int, rounds: int, max_staleness: int = 1) -> FaultPlan:
        """Materialize the (m, rounds) outcome arrays.

        Stragglers draw a staleness uniformly in [1, max_staleness];
        the bound the round loop actually honors is its ``staleness``
        kwarg (requests are clipped there), so passing the same bound
        here just keeps the drawn values meaningful.
        """
        self.validate()
        k_drop, k_strag, k_s, k_corr = jax.random.split(
            jax.random.PRNGKey(self.seed), 4)
        shape = (m, rounds)
        live = (jax.random.uniform(k_drop, shape)
                >= self.dropout).astype(jnp.float32)
        strag = jax.random.uniform(k_strag, shape) < self.straggle
        s = jax.random.randint(k_s, shape, 1, max(max_staleness, 1) + 1)
        stale = jnp.where(strag, s, 0).astype(jnp.int32)
        hit = jax.random.uniform(k_corr, shape) < self.corrupt
        if self.corrupt_mode == "mix":
            code = 1 + (jnp.arange(m)[:, None]
                        + jnp.arange(rounds)[None, :]) % 3
        else:
            code = _CORRUPT_CODES[self.corrupt_mode]
        corrupt = jnp.where(hit, code, CORRUPT_NONE).astype(jnp.int32)
        return FaultPlan(live, stale, corrupt)


class Aggregation(NamedTuple):
    """Robust-aggregation policy for the refinement rounds.

    ``None`` (in the drivers) keeps the legacy unweighted mean --
    bit-exact with the PR 5 path when no faults are injected, and the
    deliberately fragile baseline (dropped machines contribute zeros
    diluted by m, corruption reaches the mean unscreened) when they
    are.

    Attributes:
      trim: per-side trimmed fraction q in [0, 0.5).  0 (default) is
        the liveness-masked mean; q > 0 sorts each coordinate over the
        live machines and drops the top/bottom floor(q m) before
        averaging (shrinking the cut so at least one value survives).
      screen: screen each machine's contribution for non-finite values
        -- a screened machine gets weight 0 for the round.
      envelope: optional ceiling on |coordinate|; contributions beyond
        it are screened like non-finite ones (the only per-machine
        defense against FINITE garbage when ``trim == 0``).
    """

    trim: float = 0.0
    screen: bool = True
    envelope: float | None = None

    def validate(self) -> None:
        if not 0.0 <= self.trim < 0.5:
            raise ValueError(f"trim must be in [0, 0.5), got {self.trim}")
        if self.envelope is not None and not self.envelope > 0:
            raise ValueError(
                f"envelope must be positive, got {self.envelope}")


# ---------------------------------------------------------------------------
# Wire-fault injection (what the receiver sees)
# ---------------------------------------------------------------------------


def _garbage_like(x: jnp.ndarray) -> jnp.ndarray:
    """Deterministic finite garbage: +-GARBAGE_MAGNITUDE by row parity."""
    rows = jnp.arange(x.shape[0])
    sign = jnp.where(rows % 2 == 0, 1.0, -1.0).astype(jnp.float32)
    shape = sign.shape + (1,) * (x.ndim - 1)
    return (GARBAGE_MAGNITUDE * sign.reshape(shape)
            * jnp.ones_like(x, jnp.float32)).astype(x.dtype)


def corrupt_block(code: jnp.ndarray, block: jnp.ndarray) -> jnp.ndarray:
    """Apply wire-corruption ``code`` (scalar) to one dense (d, K) block.

    The vmap twin maps this over the machine axis.  ``CORRUPT_NONE``
    is the identity; the fills are deterministic so the injected
    failure reproduces bit-for-bit from the schedule seed.
    """
    out = jnp.where(code == CORRUPT_NAN,
                    jnp.asarray(jnp.nan, block.dtype), block)
    out = jnp.where(code == CORRUPT_INF,
                    jnp.asarray(jnp.inf, block.dtype), out)
    return jnp.where(code == CORRUPT_GARBAGE, _garbage_like(block), out)


def corrupt_payload(comp: Compression, code: jnp.ndarray,
                    payload: Payload) -> Payload:
    """Wire corruption of one machine's compressed uplink.

    int8 mode corrupts the (K,) float32 dequantization scales (the
    single-NaN-scale failure of DESIGN.md §11; garbage inflates them
    by :data:`GARBAGE_MAGNITUDE`) -- the int8 values themselves cannot
    encode a NaN.  Float modes corrupt the transmitted values
    directly, exactly like :func:`corrupt_block`.
    """
    if comp.quantize == "int8":
        s = payload.scales
        bad = jnp.where(code == CORRUPT_NAN, jnp.asarray(jnp.nan, s.dtype), s)
        bad = jnp.where(code == CORRUPT_INF, jnp.asarray(jnp.inf, s.dtype),
                        bad)
        bad = jnp.where(code == CORRUPT_GARBAGE, s * GARBAGE_MAGNITUDE, bad)
        return payload._replace(scales=bad)
    return payload._replace(values=corrupt_block(code, payload.values))


# ---------------------------------------------------------------------------
# Screening, masked and trimmed aggregation
# ---------------------------------------------------------------------------


def screen_weight(agg: Aggregation, block: jnp.ndarray) -> jnp.ndarray:
    """Per-machine screening weight in {0., 1.} for one (d, K) block.

    Non-finite anywhere -> 0 (when ``agg.screen``); any |coordinate|
    over ``agg.envelope`` -> 0.  NaN compares false against the
    envelope, so either check alone also rejects NaN blocks.  Returns
    1. when both checks are disabled.
    """
    ok = None
    if agg.screen:
        ok = jnp.all(jnp.isfinite(block))
    if agg.envelope is not None:
        in_env = jnp.all(jnp.abs(block) <= agg.envelope)
        ok = in_env if ok is None else ok & in_env
    if ok is None:
        return jnp.ones((), block.dtype)
    return ok.astype(block.dtype)


def masked_mean(stack: jnp.ndarray, w: jnp.ndarray):
    """Liveness-masked mean over the machine axis of an (m, d, K) stack.

    Zero-weight machines contribute NOTHING (selected out with
    ``where``, never multiplied -- 0 * NaN would re-poison the sum)
    and the divisor is the live count, not m.  Returns ``(mean,
    count)``; with ``count == 0`` the mean is 0 and the caller falls
    back to its last-good aggregate.
    """
    keep = (w > 0).reshape(w.shape + (1,) * (stack.ndim - 1))
    den = jnp.sum(w)
    num = jnp.sum(jnp.where(keep, stack, 0.0), axis=0)
    return num / jnp.maximum(den, 1.0), den


def trimmed_mean(stack: jnp.ndarray, w: jnp.ndarray, trim: float):
    """Per-coordinate trimmed mean over the machine axis.

    Dead/screened machines sort to the top as +inf and are excluded by
    the rank mask; the per-side cut floor(trim * m) shrinks to
    floor((live - 1) / 2) when few machines are live, so at least one
    value survives whenever any machine is.  Returns ``(mean, count)``
    with ``count`` the LIVE count (0 -> caller falls back).  NaN
    contributions must be screened to weight 0 before trimming (sort
    order against NaN is undefined) -- :class:`Aggregation` defaults
    ``screen=True`` for exactly this reason.
    """
    m = stack.shape[0]
    keep = (w > 0).reshape(w.shape + (1,) * (stack.ndim - 1))
    srt = jnp.sort(jnp.where(keep, stack, jnp.inf), axis=0)
    den = jnp.sum(w)
    k_eff = jnp.clip(jnp.floor((den - 1.0) / 2.0), 0,
                     int(trim * m)).astype(jnp.int32)
    ranks = jnp.arange(m, dtype=jnp.int32)
    mask = (ranks >= k_eff) & (ranks.astype(jnp.float32)
                               < den - k_eff.astype(jnp.float32))
    mask = mask.reshape((m,) + (1,) * (stack.ndim - 1))
    count = den - 2.0 * k_eff.astype(jnp.float32)
    num = jnp.sum(jnp.where(mask, srt, 0.0), axis=0)
    return num / jnp.maximum(count, 1.0), den


def select_anchor(history: Sequence[jnp.ndarray], stale: jnp.ndarray,
                  t: int, bound: int) -> jnp.ndarray:
    """Per-machine round-``t`` anchor under bounded staleness.

    ``history[j - 1]`` is the round-j anchor (entry 0 the per-machine
    round-1 anchor).  A straggler with requested staleness s anchors
    at round t - s_eff, where s_eff clips s into [0, min(t - 1,
    bound)] -- a machine can never be staler than the bound, nor reach
    before round 1.  Mesh entries are (d, K) with scalar ``stale``;
    sim entries are (m, d, K) with (m,) ``stale``.
    """
    stacked = jnp.stack(list(history)[:t])
    idx = (t - 1) - jnp.clip(stale, 0, min(t - 1, bound))
    if stacked.ndim == 3:  # mesh: one machine's scalar request
        return jnp.take(stacked, idx, axis=0)
    return jax.vmap(lambda hist, i: jnp.take(hist, i, axis=0),
                    in_axes=(1, 0))(stacked, idx)


def gather_machines(x: jnp.ndarray, data_axes: Sequence[str]) -> jnp.ndarray:
    """Machine-stack ``x`` over the data axes: (...) -> (m, ...).

    The mesh twin of the sim path's already-materialized machine axis,
    used by the trimmed mean (which needs every machine's block) and
    by the masked compressed path (which gathers the scalar liveness
    weights next to the payload).  Lives here -- not in rounds.py --
    because ``all_gather`` calls are allow-listed per module by
    :func:`repro.analysis.imports.exclusive_call_violations`.
    """
    return jax.lax.all_gather(x, tuple(data_axes))
