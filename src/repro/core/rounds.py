"""Multi-round refinement: past the one-shot m-barrier (DESIGN.md §8).

The paper's one-shot aggregation attains the centralized rate only
while the machine count m stays below Theorem 4.5's threshold; past it
the averaged debiased estimator degrades and the one-shot schedule has
no recourse.  Wang et al.'s EDSL and Lee et al.'s one-shot sparse
regression show the fix: a few extra O(d)-communication rounds recover
the centralized rate under much weaker conditions on m.

The refinement iteration here re-applies each worker's debias
correction AROUND THE MASTER'S AGGREGATE instead of the worker's own
biased estimate.  With anchor_1 = beta_hat (the local estimate), every
round t = 1..T is the SAME closed-form map

    beta_tilde_t^i = anchor_t^i - Theta_hat_i^T (Sigma_hat_i anchor_t^i - rhs_i)
    beta_bar_t     = mean_i beta_tilde_t^i        (ONE pmean of (d, K))
    anchor_{t+1}^i = beta_bar_t                   (replicated post-pmean)

so T = 1 IS the paper's one-shot estimator, bit for bit.  Writing
M = mean_i Theta_i^T Sigma_i, the aggregate error contracts as
``e_t = (I - M) e_{t-1}``: per-machine CLIME/covariance noise makes
``I - Theta_i^T Sigma_i`` small (entrywise <= lam' by the CLIME
constraint), and the FIXED POINT solves ``mean_i Theta_i^T (Sigma_i
beta - rhs_i) = 0`` -- its deviation from beta* averages the m
machines' score noise, i.e. the centralized rate, with no condition
tying m to the one-shot threshold.  The hard threshold stays a
master-side O(dK) postlude, exactly as in eq. 3.5.

Cost accounting (the whole point of the design):

* **Compute.**  Every round reuses the worker's ONE
  :class:`~repro.kernels.spectral.SpectralFactor`, its already-solved
  CLIME block and direction solve (:class:`~repro.core.pipeline.
  WorkerSolves`): a round is two (d, d) x (d, K) matmuls -- ZERO extra
  eigendecompositions, ZERO extra ADMM iterations.
* **Communication.**  One ``pmean`` of a (d, K) block per round over
  the data axes (T rounds = exactly T times the paper's per-round
  budget), plus the intra-machine model-axis ``all_gather`` of the
  correction slice -- inside a machine in the paper's cost model,
  exactly as in the one-shot schedule.  Masked aggregation
  (DESIGN.md §11) adds ONE scalar f32 psum per round (the live
  count); the trimmed mean and the masked compressed path gather
  per-machine blocks/weights instead.
* **Warm re-entry.**  ``collect_info=True`` threads both solves
  through the full dispatched result, so the returned
  :class:`~repro.core.pipeline.WorkerSolves` carries the warm
  rho/:class:`~repro.core.dantzig.AdmmState`/iteration counts.  A
  re-entry (a tuning loop re-running the rounds pipeline after moving
  lambda or t) passes them back and resumes each ADMM solve instead of
  restarting from zero -- with ``cfg.tol`` set, measurably fewer
  iterations (gated by ``benchmarks/multi_round.py``).

The round-loop body itself lives ONCE in :func:`_refinement_rounds`:
the mesh driver (:class:`_MeshRound`, collectives) and the vmap twin
(:class:`_SimRound`, machine-axis reductions) supply only the
axis-specific operations, so the two paths cannot drift -- the fault
and staleness logic of :mod:`repro.core.faults` is written once and
exercised identically by both.  The T (static, small) rounds unroll so
the jaxpr pins can count exactly T (d, K) ``pmean``s and ONE ``eigh``
per worker.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.analysis import (
    AxisPayloadBits,
    CollectiveContract,
    DtypePolicy,
    Param,
    PrimitiveBudget,
    VmemConformance,
    trace_contract,
)
from repro.core import compression as compression_core
from repro.core import faults as faults_core
from repro.core import pipeline
from repro.core import transport as transport_core
from repro.core.compression import Compression
from repro.core.dantzig import AdmmState, DantzigConfig
from repro.core.faults import Aggregation, FaultPlan, FaultSchedule
from repro.core.pipeline import DiscriminantHead, WorkerSolves
from repro.core.transport import CommPlan, Transport, TransportState

__all__ = [
    "refine_step",
    "worker_rounds",
    "simulate_multi_round",
    "simulate_round_loop",
]


def refine_step(ws: WorkerSolves, anchor: jnp.ndarray,
                model_axis: str | None = None) -> jnp.ndarray:
    """One worker's closed-form debias correction around ``anchor``.

    ``beta_tilde = anchor - Theta_hat^T (Sigma_hat anchor - rhs)``:
    round 1 anchors at the worker's own ``beta_hat`` (the paper's
    eq. 3.4), later rounds at the replicated aggregate.  No solver
    runs -- the round reuses the :class:`WorkerSolves` CLIME block
    (sharded blocks reassemble through the same masked intra-machine
    gather as the one-shot path).
    """
    resid = ws.stats.sigma @ anchor - ws.stats.rhs  # (d, K)
    return anchor - pipeline.apply_correction(
        ws.theta, ws.valid, resid, model_axis)


class _MeshRound:
    """One machine's view of a round: collectives aggregate (shard_map)."""

    def __init__(self, ws: WorkerSolves, model_axis: str | None,
                 data_axes: Sequence[str]):
        self.ws = ws
        self.model_axis = model_axis
        self.data_axes = tuple(data_axes)

    def correction(self, anchor):
        return refine_step(self.ws, anchor, self.model_axis)

    def mean(self, x):
        for ax in self.data_axes:
            x = jax.lax.pmean(x, ax)
        return x

    def sum(self, x):
        for ax in self.data_axes:
            x = jax.lax.psum(x, ax)
        return x

    def stack(self, x):
        """Machine-stack a per-machine value: (...) -> (m, ...)."""
        return faults_core.gather_machines(x, self.data_axes)

    def expand(self, w):
        return w  # this machine's scalar weight broadcasts against (d, K)

    def corrupt(self, code, block):
        return faults_core.corrupt_block(code, block)

    def screen(self, agg, block):
        return faults_core.screen_weight(agg, block)

    def broadcast(self, bar):
        return bar  # already this machine's replicated copy

    def agg_zeros(self, anchor):
        return jnp.zeros_like(anchor)

    def ef(self, comp, message, resid, ref):
        return compression_core.ef_step(comp, message, resid, ref)

    def corrupt_payload(self, comp, code, payload):
        return faults_core.corrupt_payload(comp, code, payload)

    def sparse_mean(self, comp, payload, ref):
        return compression_core.sparse_mean_mesh(
            comp, payload, ref, self.data_axes)

    def stack_payload(self, comp, payload):
        return compression_core.gather_payloads(
            comp, payload, self.data_axes)

    def downlink_wire(self, comp, payload, code):
        """The aggregator's broadcast: master-masked psum of the leaves.

        ``code`` is THIS machine's corruption code; only the master's
        survives the mask, so the downlink's fate is the aggregator's
        fault row and every receiver sees the same wire."""
        if code is not None:
            payload = faults_core.corrupt_payload(comp, code, payload)
        return transport_core.psum_broadcast(payload, self.data_axes)


class _SimRound:
    """The vmap twin: machines are a leading axis, reductions are local."""

    def __init__(self, ws: WorkerSolves):
        self.ws = ws
        self.m = ws.beta_hat.shape[0]

    def correction(self, anchor):
        return jax.vmap(refine_step)(self.ws, anchor)

    def mean(self, x):
        return jnp.mean(x, axis=0)  # the round's one "pmean"

    def sum(self, x):
        return jnp.sum(x, axis=0)

    def stack(self, x):
        return x  # the machine axis is already materialized

    def expand(self, w):
        return w.reshape(w.shape + (1, 1))

    def corrupt(self, code, block):
        return jax.vmap(faults_core.corrupt_block)(code, block)

    def screen(self, agg, block):
        return jax.vmap(lambda b: faults_core.screen_weight(agg, b))(block)

    def broadcast(self, bar):
        return jnp.broadcast_to(bar[None], (self.m,) + bar.shape)

    def agg_zeros(self, anchor):
        return jnp.zeros(anchor.shape[1:], anchor.dtype)

    def ef(self, comp, message, resid, ref):
        return jax.vmap(lambda msg, res: compression_core.ef_step(
            comp, msg, res, ref))(message, resid)

    def corrupt_payload(self, comp, code, payload):
        return jax.vmap(lambda c, p: faults_core.corrupt_payload(
            comp, c, p))(code, payload)

    def sparse_mean(self, comp, payload, ref):
        return compression_core.decode_mean(comp, payload, ref)

    def stack_payload(self, comp, payload):
        return payload

    def downlink_wire(self, comp, payload, code):
        """Machine 0 is the aggregator: its fault row corrupts the wire."""
        if code is not None:
            payload = faults_core.corrupt_payload(comp, code[0], payload)
        return payload


def _refinement_rounds(
    drv,
    *,
    rounds: int,
    anchor: jnp.ndarray,
    transport: Transport,
    plan: FaultPlan | None = None,
    state: TransportState | None = None,
    ref: jnp.ndarray | None = None,
    return_all_rounds: bool = False,
):
    """The ONE T-round body both drivers run (DESIGN.md §8/§10/§11/§13).

    ``drv`` supplies the axis-specific operations (mesh collectives vs
    machine-axis reductions); ``transport`` the per-round
    uplink/downlink codecs, aggregation policy, and staleness bound --
    everything else (the anchor/EF-residual/reference iteration, fault
    injection, screening, masked/trimmed aggregation, bounded
    staleness, and the last-good fallback) is written exactly once so
    the mesh and vmap twins cannot drift.

    With a default :class:`CommPlan` (no codecs, no plan, no
    aggregation) the branches reduce LITERALLY to the pre-fault code
    path: the legacy jaxpr (and its golden pins) is reproduced bit for
    bit.  ``ref`` seeds the SHARED delta reference on re-entry (the
    previous *received* aggregate); None starts at zeros, the round-1
    convention.  Both wires encode against this one reference: the
    uplink's per-machine EF residual and the downlink's
    aggregator-held residual ride in/out through ``state``.

    The downlink round close (transport contract, DESIGN.md §13): the
    aggregator EF-encodes the round's aggregate against ``ref``, the
    payload crosses the data axis on the master-masked psum of
    :func:`repro.core.transport.psum_broadcast` (where ``corrupt_payload``
    can hit it), and every machine -- master included -- applies the
    same whole-block finite screen to the same post-wire payload: on a
    corrupted round all of them fall back to ``ref`` together and the
    aggregator's residual drops (the rolled-back anchors regenerate the
    lost step next round), so the master/receiver reference views can
    never diverge and the stream resumes exactly one round delayed.

    Returns ``(bar-or-trajectory, final TransportState)``.
    """
    aggregation = transport.aggregation
    staleness = transport.staleness
    masked = aggregation is not None
    faulted = plan is not None
    if masked:
        aggregation.validate()
        # replicated, so an ALL-dead final round still returns a value
        # every machine agrees on (zeros before any round succeeded)
        last_good = drv.agg_zeros(anchor)
    resid = state.up_residual if state is not None else None
    down_resid = state.down_residual if state is not None else None
    if transport.any_up and resid is None:
        resid = jnp.zeros_like(anchor)
    if transport.any_down and down_resid is None:
        down_resid = drv.agg_zeros(anchor)  # replicated, like the aggregate
    if (transport.any_up or transport.any_down) and ref is None:
        # round-1 reference is zeros (the anchor is still per-machine);
        # afterwards the replicated RECEIVED aggregate -- both wires
        # share it
        ref = drv.agg_zeros(anchor)
    history = [anchor]  # entry j-1 = the round-j anchor
    bars = []
    for t in range(1, rounds + 1):  # static T: the jaxpr shows T rounds
        compression = transport.up(t).comp
        live = code = None
        if faulted:
            live, stale, code = plan.row(t)
        a = history[-1]
        if faulted and staleness > 0 and t > 1:
            a = faults_core.select_anchor(history, stale, t, staleness)
        beta_tilde = drv.correction(a)
        if compression is None:
            wire = drv.corrupt(code, beta_tilde) if faulted else beta_tilde
            if not masked and not faulted:
                bar = drv.mean(wire)  # the legacy bit-exact round
            elif not masked:
                # the fragile baseline under faults: a dropped machine's
                # slot contributes zeros but the divisor stays m, and
                # corrupt payloads reach the mean unscreened
                bar = drv.mean(jnp.where(drv.expand(live) > 0, wire, 0.0))
            else:
                w = drv.screen(aggregation, wire)
                if faulted:
                    w = live * w
                if aggregation.trim > 0:
                    bar, den = faults_core.trimmed_mean(
                        drv.stack(wire), drv.stack(w), aggregation.trim)
                else:
                    # select, never multiply: 0 * NaN would re-poison
                    num = drv.sum(jnp.where(drv.expand(w) > 0, wire, 0.0))
                    den = drv.sum(w)  # the liveness mask on the wire
                    bar = num / jnp.maximum(den, 1.0)
                bar = jnp.where(den > 0, bar, last_good)
        else:
            payload, new_resid = drv.ef(compression, beta_tilde, resid, ref)
            if faulted:
                # a dropped machine computed nothing this round: its EF
                # carry is untouched.  Corruption happens on the WIRE,
                # after the (honest) machine updated its own residual.
                resid = jnp.where(drv.expand(live) > 0, new_resid, resid)
                payload = drv.corrupt_payload(compression, code, payload)
            else:
                resid = new_resid
            if not masked and not faulted:
                bar = drv.sparse_mean(compression, payload, ref)  # legacy
            else:
                stacked = drv.stack_payload(compression, payload)
                w_live = drv.stack(live) if faulted else None
                if masked:
                    # decode RAW: the screen must see poisoned values to
                    # zero the whole machine, not a ref-filled repair
                    dense = compression_core.decode_stack(
                        compression, stacked, ref, screen_nonfinite=False)
                    w = jax.vmap(lambda b: faults_core.screen_weight(
                        aggregation, b))(dense)
                    if w_live is not None:
                        w = w_live * w
                    if aggregation.trim > 0:
                        bar, den = faults_core.trimmed_mean(
                            dense, w, aggregation.trim)
                    else:
                        bar, den = faults_core.masked_mean(dense, w)
                    bar = jnp.where(den > 0, bar, last_good)
                else:
                    # fragile baseline: a dropped machine's missing
                    # payload decodes to the reference (set semantics),
                    # still diluting the mean by the full m
                    dense = compression_core.decode_stack(
                        compression, stacked, ref)
                    keep = (w_live > 0).reshape(w_live.shape + (1, 1))
                    bar = jnp.mean(jnp.where(keep, dense, ref), axis=0)
        # ---- the downlink close (DESIGN.md §13): the aggregate back
        # down the wire, EF-compressed against the SAME reference ----
        down = transport.down(t)
        if down.compressed:
            u = bar + down_resid
            payload = down.encode(u, ref)
            wire = drv.downlink_wire(down.comp, payload, code)
            decoded = down.decode(wire, ref, screen_nonfinite=False)
            # whole-block receiver screen, replicated: a poisoned wire
            # rolls EVERY machine (master included) back to the last
            # received aggregate, so the shared reference never forks
            ok = jnp.all(jnp.isfinite(decoded))
            honest = down.decode(payload, ref, screen_nonfinite=False)
            # delivered: residual = quantization/selection leftovers.
            # rejected: DROP the carry -- receivers roll back to ref, so
            # next round's anchors regenerate the lost step themselves;
            # re-arming with it would deliver the step twice (and a
            # poisoned upstream aggregate would ride the carry forever)
            down_resid = jnp.where(ok, u - honest, jnp.zeros_like(u))
            bar = jnp.where(ok, decoded, ref)
        if transport.any_up or transport.any_down:
            ref = bar  # the received aggregate seeds both wires' deltas
        if masked:
            last_good = bar  # what receivers actually hold
        bars.append(bar)
        history.append(drv.broadcast(bar))
    out = jnp.stack(bars) if return_all_rounds else bars[-1]
    return out, TransportState(
        resid if transport.any_up else None,
        down_resid if transport.any_down else None)


def _check_plan(faults, expect_shape, where: str):
    if faults is None:
        return
    if isinstance(faults, FaultSchedule):
        raise TypeError(
            f"{where} takes a materialized FaultPlan (the faces call "
            "FaultSchedule.plan(m, rounds, staleness)); got a schedule")
    if faults.live.shape != expect_shape:
        raise ValueError(
            f"{where}: FaultPlan leaves must be {expect_shape}, got "
            f"{faults.live.shape}")


@trace_contract(
    "rounds.worker_rounds",
    contracts=(
        # refinement rounds reuse the round-one SpectralFactor
        PrimitiveBudget("eigh", exact=1),
        # the DENSE uplink: one (d, K) f32 psum per dense round over the
        # data axis -- count AND payload are pinned (0 when compressed:
        # a compressed trace must hold NO dense data-axis psum at all)
        CollectiveContract("psum", count=Param("dense_psums"), axis="data",
                           shape=Param("psum_payload"), dtype="float32"),
        # the liveness mask of DESIGN.md §11: one scalar f32 psum (the
        # live count) per masked dense round, nothing on the legacy path
        CollectiveContract("psum", count=Param("live_psums"), axis="data",
                           shape=(), dtype="float32"),
        PrimitiveBudget("psum", exact=Param("total_psums")),
        # intra-machine CLIME reassembly: one model-axis gather per round
        CollectiveContract("all_gather", count=Param("rounds"),
                           axis="model"),
        # the COMPRESSED uplink payload gathers, plus the fault layer's
        # block/weight gathers (0 on the legacy dense path) ...
        CollectiveContract("all_gather", count=Param("data_gathers"),
                           axis="data"),
        # ... and the bits everything moves per link, exactly, split by
        # direction: uplink payloads ride all_gathers, dense uplinks +
        # liveness masks + downlink payloads ride psums -- pinning each
        # primitive family to its analytic schedule total means a
        # hidden dense block in EITHER direction blows its own budget
        AxisPayloadBits("data", exact_bits=Param("data_gather_bits"),
                        prims=("all_gather",)),
        AxisPayloadBits("data", exact_bits=Param("data_psum_bits"),
                        prims=("psum",)),
        AxisPayloadBits("data", exact_bits=Param("data_total_bits")),
        # per-machine screening + decode sanitization are is_finite eqns
        PrimitiveBudget("is_finite", exact=Param("screen_ops")),
        PrimitiveBudget("pallas_call", exact=Param("pallas_calls")),
        DtypePolicy(),
        VmemConformance(),
    ),
)
def worker_rounds(
    head: DiscriminantHead,
    *data: jnp.ndarray,
    lam,
    lam_prime,
    rounds: int = 1,
    cfg: DantzigConfig = DantzigConfig(),
    data_axes: Sequence[str] = ("data",),
    model_axis: str | None = None,
    model_axis_size: int = 1,
    comm: CommPlan | None = None,
    compression: Compression | None = None,
    ef_residual: jnp.ndarray | None = None,
    down_residual: jnp.ndarray | None = None,
    resume_from: jnp.ndarray | None = None,
    faults: FaultPlan | None = None,
    staleness: int = 0,
    aggregation: Aggregation | None = None,
    rho_beta: jnp.ndarray | None = None,
    rho_theta: jnp.ndarray | None = None,
    state_beta: AdmmState | None = None,
    state_theta: AdmmState | None = None,
    collect_info: bool = False,
    return_ef_residual: bool = False,
    return_transport_state: bool = False,
):
    """T-round refined aggregate, from inside shard_map over the mesh.

    Runs :func:`~repro.core.pipeline.worker_solves` ONCE (suff stats,
    one eigh, direction + CLIME ADMM -- warm-startable via the
    ``rho_*`` / ``state_*`` carries of a previous invocation's
    :class:`WorkerSolves`), then ``rounds`` closed-form refinement
    rounds driven by ONE static comms config: ``comm`` (a
    :class:`~repro.core.transport.CommPlan`).  The default plan closes
    each round with one dense (d, K) ``pmean`` over ``data_axes`` --
    bit-identical to the pre-compression path; ``comm.uplink`` moves
    each round's top-k error-feedback payload through
    :func:`~repro.core.compression.sparse_mean_mesh` instead (residual
    seeded by ``ef_residual``), ``comm.downlink`` EF-compresses the
    aggregate's broadcast back down against the same reference
    (aggregator residual seeded by ``down_residual``), and
    ``comm.schedule`` (a :class:`~repro.core.transport.BitBudget`)
    replans both directions per round under a total bit budget.
    ``rounds=1`` dense reproduces the one-shot worker + single
    averaging round of Algorithm 1 exactly.

    The legacy ``compression=`` / ``staleness=`` / ``aggregation=``
    kwargs remain as deprecation shims (mutually exclusive with
    ``comm``); ``comm.faults`` must stay None here -- fault SCHEDULES
    are materialized by the faces, and ``faults`` is THIS machine's
    materialized :class:`~repro.core.faults.FaultPlan` row ((rounds,)
    leaves -- the per-machine liveness operand the faces shard in).
    ``aggregation`` switches the round close to the liveness-masked
    (or trimmed) robust mean of :mod:`repro.core.faults`;
    ``staleness`` bounds how many rounds a straggler's anchor may lag.

    ``resume_from`` re-enters a round stream mid-way: it seeds the
    round-1 anchor AND the shared delta reference with the previous
    received aggregate, so a split T-round run (with the carried
    residuals) matches an uninterrupted one.

    Returns ``(beta_bar, solves)``: the replicated (d, K) aggregate
    (un-thresholded -- the master's hard threshold is the caller's
    O(dK) postlude) and the worker's solves for reuse/warm re-entry.
    ``return_ef_residual`` appends the final uplink error-feedback
    residual (None on a dense uplink); ``return_transport_state``
    appends the full :class:`~repro.core.transport.TransportState`
    (both wires' residuals) for a bit-exact resume.
    """
    if rounds < 1:
        raise ValueError(f"rounds must be >= 1, got {rounds}")
    comm = transport_core.resolve_comm(
        comm, compression=compression, staleness=staleness,
        aggregation=aggregation, where="worker_rounds")
    if comm.faults is not None:
        raise TypeError(
            "worker_rounds: CommPlan.faults is a schedule -- the faces "
            "materialize it; pass this machine's FaultPlan row via faults=")
    _check_plan(faults, (rounds,), "worker_rounds")
    ws = pipeline.worker_solves(
        head, *data, lam=lam, lam_prime=lam_prime, cfg=cfg,
        model_axis=model_axis, model_axis_size=model_axis_size,
        rho_beta=rho_beta, rho_theta=rho_theta,
        state_beta=state_beta, state_theta=state_theta,
        full=collect_info,
    )
    anchor = ws.beta_hat if resume_from is None else resume_from
    tr = Transport(comm, anchor.shape[0], anchor.shape[1], rounds)
    anchor, tstate = _refinement_rounds(
        _MeshRound(ws, model_axis, data_axes),
        rounds=rounds, anchor=anchor, transport=tr, plan=faults,
        state=TransportState(ef_residual, down_residual), ref=resume_from)
    out = [anchor, ws]
    if return_ef_residual:
        out.append(tstate.up_residual)
    if return_transport_state:
        out.append(tstate)
    return tuple(out)


def simulate_round_loop(
    ws: WorkerSolves,
    *,
    rounds: int,
    comm: CommPlan | None = None,
    compression: Compression | None = None,
    ef_residual: jnp.ndarray | None = None,
    down_residual: jnp.ndarray | None = None,
    resume_from: jnp.ndarray | None = None,
    faults: FaultPlan | FaultSchedule | None = None,
    staleness: int = 0,
    aggregation: Aggregation | None = None,
    return_all_rounds: bool = False,
    return_ef_residual: bool = False,
    return_transport_state: bool = False,
):
    """The T refinement rounds alone, on already-computed machine solves.

    ``ws`` is an (m, ...)-stacked :class:`WorkerSolves` (the output of
    :func:`simulate_multi_round`'s vmap).  Splitting the loop from the
    solves lets one set of per-machine solves -- the expensive part --
    drive many round schedules: the compressed-uplink and fault
    benchmarks replay the SAME solves under every
    :class:`Compression` / :class:`~repro.core.faults.FaultSchedule`
    config, so the curves differ only in the uplink and its faults.

    Same shared round body as the mesh path
    (:func:`_refinement_rounds`), with machine-axis reductions where
    the mesh does collectives.  ``comm`` is the one static
    :class:`~repro.core.transport.CommPlan` (its ``faults`` -- a
    hashable :class:`~repro.core.faults.FaultSchedule` -- is
    materialized here against ``m``); the legacy ``compression`` /
    ``faults`` / ``staleness`` / ``aggregation`` kwargs remain as
    deprecation shims, with ``faults`` additionally accepting an
    already-materialized :class:`~repro.core.faults.FaultPlan`
    ((m, rounds) leaves).  ``resume_from`` as in :func:`worker_rounds`.

    Returns ``beta_bar`` (d, K), or the (rounds, d, K) trajectory when
    ``return_all_rounds``; ``return_ef_residual`` appends the final
    (m, d, K) uplink residual (None on a dense uplink) and
    ``return_transport_state`` the full
    :class:`~repro.core.transport.TransportState` for a bit-exact
    resume of both wires.
    """
    if rounds < 1:
        raise ValueError(f"rounds must be >= 1, got {rounds}")
    drv = _SimRound(ws)
    if comm is not None and isinstance(faults, FaultSchedule):
        raise TypeError(
            "simulate_round_loop: pass the fault schedule inside "
            "comm=CommPlan(faults=...), not alongside it (a materialized "
            "FaultPlan is data and may ride next to comm)")
    comm = transport_core.resolve_comm(
        comm, compression=compression, staleness=staleness,
        aggregation=aggregation, where="simulate_round_loop")
    plan = faults if faults is not None else comm.faults
    if isinstance(plan, FaultSchedule):
        plan = plan.plan(drv.m, rounds, max(comm.staleness, 1))
    _check_plan(plan, (drv.m, rounds), "simulate_round_loop")
    anchor = (ws.beta_hat if resume_from is None
              else drv.broadcast(resume_from))
    tr = Transport(comm, anchor.shape[1], anchor.shape[2], rounds)
    out, tstate = _refinement_rounds(
        drv, rounds=rounds, anchor=anchor, transport=tr, plan=plan,
        state=TransportState(ef_residual, down_residual),
        ref=resume_from, return_all_rounds=return_all_rounds)
    res = [out]
    if return_ef_residual:
        res.append(tstate.up_residual)
    if return_transport_state:
        res.append(tstate)
    return tuple(res) if len(res) > 1 else out


def simulate_multi_round(
    head: DiscriminantHead,
    data: Sequence[jnp.ndarray],
    *,
    lam,
    lam_prime,
    rounds: int = 1,
    cfg: DantzigConfig = DantzigConfig(),
    comm: CommPlan | None = None,
    compression: Compression | None = None,
    ef_residual: jnp.ndarray | None = None,
    faults: FaultPlan | FaultSchedule | None = None,
    staleness: int = 0,
    aggregation: Aggregation | None = None,
    rho_beta: jnp.ndarray | None = None,
    rho_theta: jnp.ndarray | None = None,
    state_beta: AdmmState | None = None,
    state_theta: AdmmState | None = None,
    collect_info: bool = False,
    return_all_rounds: bool = False,
) -> tuple[jnp.ndarray, WorkerSolves]:
    """Single-device twin of :func:`worker_rounds`: machines are vmapped.

    ``data`` holds the head's samples stacked over a leading machine
    axis (``(xs, ys)`` with (m, n, d) leaves for the binary head).
    Identical math to the mesh path: per-machine solves under ``vmap``,
    then the round loop of :func:`simulate_round_loop` -- a machine-axis
    ``mean`` per dense round, or the top-k error-feedback payload mean
    when ``compression`` is set, under the same ``faults`` /
    ``staleness`` / ``aggregation`` fault model as the mesh.  Warm
    carries are the (m, ...)-stacked fields of a previous invocation's
    returned :class:`WorkerSolves`.

    Returns ``(beta_bar, solves)`` with ``beta_bar`` (d, K), or
    (rounds, d, K) -- the whole per-round trajectory -- when
    ``return_all_rounds`` (the error-vs-T benchmark reads every T from
    ONE set of solves).
    """
    if rounds < 1:
        raise ValueError(f"rounds must be >= 1, got {rounds}")
    # None carries are empty pytrees: vmap maps only the provided ones
    warms = dict(rho_beta=rho_beta, rho_theta=rho_theta,
                 state_beta=state_beta, state_theta=state_theta)

    def one_machine(args, warm):
        return pipeline.worker_solves(
            head, *args, lam=lam, lam_prime=lam_prime, cfg=cfg,
            full=collect_info, **warm)

    ws = jax.vmap(one_machine)(tuple(data), warms)
    out = simulate_round_loop(
        ws, rounds=rounds, comm=comm, compression=compression,
        ef_residual=ef_residual, faults=faults, staleness=staleness,
        aggregation=aggregation, return_all_rounds=return_all_rounds)
    return out, ws
