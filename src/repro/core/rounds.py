"""Multi-round refinement: past the one-shot m-barrier (DESIGN.md §8).

The paper's one-shot aggregation attains the centralized rate only
while the machine count m stays below Theorem 4.5's threshold; past it
the averaged debiased estimator degrades and the one-shot schedule has
no recourse.  Wang et al.'s EDSL and Lee et al.'s one-shot sparse
regression show the fix: a few extra O(d)-communication rounds recover
the centralized rate under much weaker conditions on m.

The refinement iteration here re-applies each worker's debias
correction AROUND THE MASTER'S AGGREGATE instead of the worker's own
biased estimate.  With anchor_1 = beta_hat (the local estimate), every
round t = 1..T is the SAME closed-form map

    beta_tilde_t^i = anchor_t^i - Theta_hat_i^T (Sigma_hat_i anchor_t^i - rhs_i)
    beta_bar_t     = mean_i beta_tilde_t^i        (ONE pmean of (d, K))
    anchor_{t+1}^i = beta_bar_t                   (replicated post-pmean)

so T = 1 IS the paper's one-shot estimator, bit for bit.  Writing
M = mean_i Theta_i^T Sigma_i, the aggregate error contracts as
``e_t = (I - M) e_{t-1}``: per-machine CLIME/covariance noise makes
``I - Theta_i^T Sigma_i`` small (entrywise <= lam' by the CLIME
constraint), and the FIXED POINT solves ``mean_i Theta_i^T (Sigma_i
beta - rhs_i) = 0`` -- its deviation from beta* averages the m
machines' score noise, i.e. the centralized rate, with no condition
tying m to the one-shot threshold.  The hard threshold stays a
master-side O(dK) postlude, exactly as in eq. 3.5.

Cost accounting (the whole point of the design):

* **Compute.**  Every round reuses the worker's ONE
  :class:`~repro.kernels.spectral.SpectralFactor`, its already-solved
  CLIME block and direction solve (:class:`~repro.core.pipeline.
  WorkerSolves`): a round is two (d, d) x (d, K) matmuls -- ZERO extra
  eigendecompositions, ZERO extra ADMM iterations.
* **Communication.**  One ``pmean`` of a (d, K) block per round over
  the data axes (T rounds = exactly T times the paper's per-round
  budget), plus the intra-machine model-axis ``all_gather`` of the
  correction slice -- inside a machine in the paper's cost model,
  exactly as in the one-shot schedule.
* **Warm re-entry.**  ``collect_info=True`` threads both solves
  through the full dispatched result, so the returned
  :class:`~repro.core.pipeline.WorkerSolves` carries the warm
  rho/:class:`~repro.core.dantzig.AdmmState`/iteration counts.  A
  re-entry (a tuning loop re-running the rounds pipeline after moving
  lambda or t) passes them back and resumes each ADMM solve instead of
  restarting from zero -- with ``cfg.tol`` set, measurably fewer
  iterations (gated by ``benchmarks/multi_round.py``).

The round loop body is a plain carry -> carry map (``lax.fori_loop``-
able); the drivers unroll the T (static, small) rounds so the jaxpr
pins in ``tests/test_rounds.py`` can count exactly T (d, K) ``pmean``s
and ONE ``eigh`` per worker.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.analysis import (
    AxisPayloadBits,
    CollectiveContract,
    DtypePolicy,
    Param,
    PrimitiveBudget,
    VmemConformance,
    trace_contract,
)
from repro.core import compression as compression_core
from repro.core import pipeline
from repro.core.compression import Compression
from repro.core.dantzig import AdmmState, DantzigConfig
from repro.core.pipeline import DiscriminantHead, WorkerSolves

__all__ = [
    "refine_step",
    "worker_rounds",
    "simulate_multi_round",
    "simulate_round_loop",
]


def refine_step(ws: WorkerSolves, anchor: jnp.ndarray,
                model_axis: str | None = None) -> jnp.ndarray:
    """One worker's closed-form debias correction around ``anchor``.

    ``beta_tilde = anchor - Theta_hat^T (Sigma_hat anchor - rhs)``:
    round 1 anchors at the worker's own ``beta_hat`` (the paper's
    eq. 3.4), later rounds at the replicated aggregate.  No solver
    runs -- the round reuses the :class:`WorkerSolves` CLIME block
    (sharded blocks reassemble through the same masked intra-machine
    gather as the one-shot path).
    """
    resid = ws.stats.sigma @ anchor - ws.stats.rhs  # (d, K)
    return anchor - pipeline.apply_correction(
        ws.theta, ws.valid, resid, model_axis)


@trace_contract(
    "rounds.worker_rounds",
    contracts=(
        # refinement rounds reuse the round-one SpectralFactor
        PrimitiveBudget("eigh", exact=1),
        # the DENSE uplink: one (d, K) f32 psum per dense round over the
        # data axis -- count AND payload are pinned (0 when compressed:
        # a compressed trace must hold NO dense data-axis psum at all)
        CollectiveContract("psum", count=Param("dense_psums"), axis="data",
                           shape=Param("psum_payload"), dtype="float32"),
        PrimitiveBudget("psum", exact=Param("dense_psums")),
        # intra-machine CLIME reassembly: one model-axis gather per round
        CollectiveContract("all_gather", count=Param("rounds"),
                           axis="model"),
        # the COMPRESSED uplink: values/indices(/scales) gathers over the
        # data axis (0 on the dense path) ...
        CollectiveContract("all_gather", count=Param("data_gathers"),
                           axis="data"),
        # ... and the total bits they move per link, exactly: a hidden
        # dense block anywhere on the data axis blows this budget
        AxisPayloadBits("data", exact_bits=Param("data_uplink_bits")),
        PrimitiveBudget("pallas_call", exact=Param("pallas_calls")),
        DtypePolicy(),
        VmemConformance(),
    ),
)
def worker_rounds(
    head: DiscriminantHead,
    *data: jnp.ndarray,
    lam,
    lam_prime,
    rounds: int = 1,
    cfg: DantzigConfig = DantzigConfig(),
    data_axes: Sequence[str] = ("data",),
    model_axis: str | None = None,
    model_axis_size: int = 1,
    compression: Compression | None = None,
    ef_residual: jnp.ndarray | None = None,
    rho_beta: jnp.ndarray | None = None,
    rho_theta: jnp.ndarray | None = None,
    state_beta: AdmmState | None = None,
    state_theta: AdmmState | None = None,
    collect_info: bool = False,
    return_ef_residual: bool = False,
):
    """T-round refined aggregate, from inside shard_map over the mesh.

    Runs :func:`~repro.core.pipeline.worker_solves` ONCE (suff stats,
    one eigh, direction + CLIME ADMM -- warm-startable via the
    ``rho_*`` / ``state_*`` carries of a previous invocation's
    :class:`WorkerSolves`), then ``rounds`` closed-form refinement
    rounds.  ``compression=None`` (default) closes each round with one
    dense (d, K) ``pmean`` over ``data_axes`` -- bit-identical to the
    pre-compression path; a :class:`~repro.core.compression.Compression`
    instead uplinks each round's top-k error-feedback payload through
    :func:`~repro.core.compression.sparse_mean_mesh`, carrying the
    per-machine residual across rounds (seeded by ``ef_residual``, zero
    by default).  ``rounds=1`` dense reproduces the one-shot worker +
    single averaging round of Algorithm 1 exactly.

    Returns ``(beta_bar, solves)``: the replicated (d, K) aggregate
    (un-thresholded -- the master's hard threshold is the caller's
    O(dK) postlude) and the worker's solves for reuse/warm re-entry.
    With ``return_ef_residual`` a third element carries the final
    error-feedback residual (None on the dense path) so a re-entry can
    resume the compressed stream where it left off.
    """
    if rounds < 1:
        raise ValueError(f"rounds must be >= 1, got {rounds}")
    ws = pipeline.worker_solves(
        head, *data, lam=lam, lam_prime=lam_prime, cfg=cfg,
        model_axis=model_axis, model_axis_size=model_axis_size,
        rho_beta=rho_beta, rho_theta=rho_theta,
        state_beta=state_beta, state_theta=state_theta,
        full=collect_info,
    )
    anchor = ws.beta_hat
    resid = ef_residual
    if compression is None:
        for _ in range(rounds):  # static T: the jaxpr shows T pmeans
            beta_tilde = refine_step(ws, anchor, model_axis)
            for ax in data_axes:
                beta_tilde = jax.lax.pmean(beta_tilde, ax)
            anchor = beta_tilde  # replicated: next round anchors here
    else:
        compression.validate(anchor.shape[0])
        if resid is None:
            resid = jnp.zeros_like(anchor)
        # round-1 reference is zeros (the anchor is still per-machine);
        # afterwards it is the replicated aggregate every machine holds
        ref = jnp.zeros_like(anchor)
        for _ in range(rounds):
            beta_tilde = refine_step(ws, anchor, model_axis)
            payload, resid = compression_core.ef_step(
                compression, beta_tilde, resid, ref)
            anchor = compression_core.sparse_mean_mesh(
                compression, payload, ref, data_axes)
            ref = anchor
    if return_ef_residual:
        return anchor, ws, resid
    return anchor, ws


def simulate_round_loop(
    ws: WorkerSolves,
    *,
    rounds: int,
    compression: Compression | None = None,
    ef_residual: jnp.ndarray | None = None,
    return_all_rounds: bool = False,
    return_ef_residual: bool = False,
):
    """The T refinement rounds alone, on already-computed machine solves.

    ``ws`` is an (m, ...)-stacked :class:`WorkerSolves` (the output of
    :func:`simulate_multi_round`'s vmap).  Splitting the loop from the
    solves lets one set of per-machine solves -- the expensive part --
    drive many round schedules: the compressed-uplink benchmark replays
    the SAME solves under every :class:`Compression` config, so
    accuracy-vs-bits curves differ only in the uplink.

    Dense (``compression=None``): T rounds of machine-axis ``mean``
    where the mesh does its ``pmean``.  Compressed: each machine's
    round message runs through top-k error feedback
    (:func:`~repro.core.compression.ef_step`, residual seeded by
    ``ef_residual`` or zero) and the aggregate is the decoded mean of
    the m payloads -- the exact math of the mesh path's
    :func:`~repro.core.compression.sparse_mean_mesh`.

    Returns ``beta_bar`` (d, K), or the (rounds, d, K) trajectory when
    ``return_all_rounds``; with ``return_ef_residual`` a trailing
    element adds the final (m, d, K) residual (None on the dense path).
    """
    if rounds < 1:
        raise ValueError(f"rounds must be >= 1, got {rounds}")
    anchor = ws.beta_hat  # (m, d, K)
    resid = ef_residual
    ref = None
    if compression is not None:
        compression.validate(anchor.shape[1])
        if resid is None:
            resid = jnp.zeros_like(anchor)
        # round-1 reference is zeros (the anchor is still per-machine);
        # afterwards it is the aggregate every machine holds
        ref = jnp.zeros(anchor.shape[1:], anchor.dtype)
    bars = []
    for _ in range(rounds):
        beta_tilde = jax.vmap(refine_step)(ws, anchor)  # (m, d, K)
        if compression is None:
            bar = jnp.mean(beta_tilde, axis=0)  # the round's one pmean
        else:
            payload, resid = jax.vmap(
                lambda msg, res: compression_core.ef_step(
                    compression, msg, res, ref)
            )(beta_tilde, resid)
            bar = compression_core.decode_mean(compression, payload, ref)
            ref = bar
        bars.append(bar)
        anchor = jnp.broadcast_to(bar[None], beta_tilde.shape)
    out = jnp.stack(bars) if return_all_rounds else bars[-1]
    if return_ef_residual:
        return out, resid
    return out


def simulate_multi_round(
    head: DiscriminantHead,
    data: Sequence[jnp.ndarray],
    *,
    lam,
    lam_prime,
    rounds: int = 1,
    cfg: DantzigConfig = DantzigConfig(),
    compression: Compression | None = None,
    ef_residual: jnp.ndarray | None = None,
    rho_beta: jnp.ndarray | None = None,
    rho_theta: jnp.ndarray | None = None,
    state_beta: AdmmState | None = None,
    state_theta: AdmmState | None = None,
    collect_info: bool = False,
    return_all_rounds: bool = False,
) -> tuple[jnp.ndarray, WorkerSolves]:
    """Single-device twin of :func:`worker_rounds`: machines are vmapped.

    ``data`` holds the head's samples stacked over a leading machine
    axis (``(xs, ys)`` with (m, n, d) leaves for the binary head).
    Identical math to the mesh path: per-machine solves under ``vmap``,
    then the round loop of :func:`simulate_round_loop` -- a machine-axis
    ``mean`` per dense round, or the top-k error-feedback payload mean
    when ``compression`` is set.  Warm carries are the (m, ...)-stacked
    fields of a previous invocation's returned :class:`WorkerSolves`.

    Returns ``(beta_bar, solves)`` with ``beta_bar`` (d, K), or
    (rounds, d, K) -- the whole per-round trajectory -- when
    ``return_all_rounds`` (the error-vs-T benchmark reads every T from
    ONE set of solves).
    """
    if rounds < 1:
        raise ValueError(f"rounds must be >= 1, got {rounds}")
    # None carries are empty pytrees: vmap maps only the provided ones
    warms = dict(rho_beta=rho_beta, rho_theta=rho_theta,
                 state_beta=state_beta, state_theta=state_theta)

    def one_machine(args, warm):
        return pipeline.worker_solves(
            head, *args, lam=lam, lam_prime=lam_prime, cfg=cfg,
            full=collect_info, **warm)

    ws = jax.vmap(one_machine)(tuple(data), warms)
    out = simulate_round_loop(
        ws, rounds=rounds, compression=compression,
        ef_residual=ef_residual, return_all_rounds=return_all_rounds)
    return out, ws
